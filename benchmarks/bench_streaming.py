"""Continuous-ingestion service: steady-state ingest throughput + query tail.

The serving claim behind the streaming mode: after one staged compile the
service absorbs micro-batches as plain AOT dispatches (no re-trace, no
re-tune — asserted against the plan-cache counters) and answers live
snapshot queries without pausing ingestion.  Reports steady-state ingest
cost (-> pairs/sec), snapshot latency percentiles under a 4-slot sliding-
window merge, and the one-shot batch dispatch of the same micro-batch for
comparison.  Checks bitwise parity of N ingests vs one batch run first.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_scale, row, time_fn
from repro.core import ExecutionOptions, MapReduce, make_app
from repro.core import plan_cache as pc
from repro.streaming import sliding

VOCAB = 512
SNAPSHOT_ITERS = 100


def wc_app():
    return make_app(
        map_fn=lambda item, emit: emit.emit(item % VOCAB,
                                            jnp.ones((), jnp.int32)),
        reduce_fn=lambda k, vs, n: vs.sum(),
        key_space=VOCAB,
        value_aval=jax.ShapeDtypeStruct((), jnp.int32),
        emit_capacity=1,
    )


def main():
    rng = np.random.default_rng(0)
    B = max(64, int(8192 * bench_scale()))
    batch = jnp.asarray(rng.integers(0, VOCAB, size=B), dtype=jnp.int32)
    batches = [jnp.asarray(rng.integers(0, VOCAB, size=B), dtype=jnp.int32)
               for _ in range(4)]

    # parity first: 4 ingests == one chunk-aligned batch run, bitwise
    svc = MapReduce(wc_app(), streaming=True).serve(batch_capacity=B)
    for b in batches:
        svc.ingest(b)
    got = svc.snapshot()
    want = MapReduce(wc_app(), flow="stream").run(
        jnp.concatenate(batches), options=ExecutionOptions(chunk_pairs=B))
    assert np.array_equal(np.asarray(want.values), np.asarray(got.values))
    assert np.array_equal(np.asarray(want.counts), np.asarray(got.counts))

    # steady-state ingest: the returned batch id is a host int, so block
    # on the published slot states to time the actual fold dispatch
    def one_ingest():
        svc.ingest(batch)
        return svc._state.slots

    s0 = pc.stats_snapshot()
    t_ingest = time_fn(one_ingest)
    s1 = pc.stats_snapshot()
    restaged = sum(s1[c] - s0[c]
                   for c in ("derives", "autotunes", "compiles"))
    assert restaged == 0, f"steady-state ingest re-staged: {s0} -> {s1}"
    pairs_per_s = B / t_ingest

    # the same micro-batch as a one-shot staged batch dispatch
    mr = MapReduce(wc_app())
    compiled = mr.lower(batch).optimize().compile()
    t_oneshot = time_fn(lambda: compiled(batch).values)

    # snapshot tail latency while a sliding window merges 4 live slots
    svc2 = MapReduce(wc_app(), streaming=True).serve(batch_capacity=B,
                                                     window=sliding(8, 2))
    for _ in range(8):
        svc2.ingest(batch)
    lat = []
    for _ in range(SNAPSHOT_ITERS):
        t0 = time.perf_counter()
        res = svc2.snapshot()
        jax.block_until_ready((res.values, res.counts))
        lat.append(time.perf_counter() - t0)
    p50, p99 = np.percentile(lat, (50, 99))

    print(f"# streaming service: word count K={VOCAB} "
          f"batch_capacity={B} (1 pair/item)")
    print(row("streaming_ingest", t_ingest * 1e6,
              f"{pairs_per_s / 1e6:.2f}Mpairs/s steady-state; "
              "0 re-stages"))
    print(row("streaming_oneshot_batch", t_oneshot * 1e6,
              "same batch via Compiled() dispatch"))
    print(row("streaming_snapshot_p50", p50 * 1e6,
              "sliding(8,2): 4-slot merge, ingest not paused"))
    print(row("streaming_snapshot_p99", p99 * 1e6,
              f"tail of {SNAPSHOT_ITERS} queries"))
    print("# parity: 4 ingests == chunk-aligned batch run, bitwise")


if __name__ == "__main__":
    import argparse
    import os
    import sys

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload (the CI streaming job)")
    if ap.parse_args().smoke:
        os.environ.setdefault("REPRO_BENCH_SCALE", "0.05")
    sys.exit(main())
