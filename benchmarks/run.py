"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Mapping to the paper:
  bench_phoenix_suite     Figs 6/7  (the up-to-2.0x optimizer claim)
  bench_memory            Figs 8/9  (heap/GC pressure -> bytes pressure)
  bench_optimizer_overhead  §4.3    (81us detect / 7.6ms transform)
  bench_flow_sweep        Fig 10    (speedup vs (key,value) pressure)
  bench_scalability       Fig 5     (scaling -> collective-bytes scaling)
  bench_integrations      beyond paper (grad-accum / MoE / decode combiners)
  bench_streaming         beyond paper (continuous-ingestion service)
  bench_resilience        beyond paper (recovery time, failover latency)

A module that raises prints a ``*_FAILED`` row and the harness exits
non-zero at the end, so CI can gate on benchmark health.  ``--json PATH``
writes the parsed rows as a machine-readable artifact (the CI smoke job
uploads ``BENCH_ci.json`` to start the perf trajectory), and
``--preset ci`` selects a tiny workload scale via REPRO_BENCH_SCALE.
"""

from __future__ import annotations

import argparse
import contextlib
import io
import json
import os
import sys
import traceback

# self-locating: `python benchmarks/run.py` puts benchmarks/ (not the repo
# root) on sys.path; make `benchmarks.*` and `repro.*` importable either way
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

MODULE_NAMES = (
    "bench_phoenix_suite",
    "bench_memory",
    "bench_optimizer_overhead",
    "bench_flow_sweep",
    "bench_scalability",
    "bench_integrations",
    "bench_streaming",
    "bench_resilience",
)

CI_SCALE = 0.05


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--preset", choices=("full", "ci"), default="full",
                    help="ci = tiny workloads for the smoke job")
    ap.add_argument("--scale", type=float, default=None,
                    help="explicit workload scale (overrides --preset)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write parsed rows + failures as JSON")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of benchmark modules to run")
    args = ap.parse_args(argv)

    from benchmarks.common import bench_scale, parse_rows

    # precedence: --scale > --preset ci > pre-set REPRO_BENCH_SCALE > 1.0
    if args.scale is not None:
        scale = args.scale
    elif args.preset == "ci":
        scale = CI_SCALE
    else:
        scale = bench_scale()
    os.environ["REPRO_BENCH_SCALE"] = str(scale)

    import importlib

    names = args.only if args.only else MODULE_NAMES
    rows: list[dict] = []
    failures: list[dict] = []
    print("name,us_per_call,derived")
    for name in names:
        buf = io.StringIO()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            with contextlib.redirect_stdout(buf):
                mod.main()
        except Exception:
            err = traceback.format_exc()
            failures.append({"module": name, "traceback": err})
            sys.stdout.write(buf.getvalue())
            print(f"{name}_FAILED,0,")
            print(err, file=sys.stderr)
            continue
        text = buf.getvalue()
        sys.stdout.write(text)
        rows.extend(parse_rows(text))

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"scale": scale, "preset": args.preset, "rows": rows,
                       "failures": failures}, f, indent=2)
        print(f"# wrote {len(rows)} rows to {args.json}")

    if failures:
        print(f"# {len(failures)} benchmark module(s) FAILED: "
              + ", ".join(f["module"] for f in failures), file=sys.stderr)
        return 1
    return 0


if __name__ == '__main__':
    sys.exit(main())
