"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Mapping to the paper:
  bench_phoenix_suite     Figs 6/7  (the up-to-2.0x optimizer claim)
  bench_memory            Figs 8/9  (heap/GC pressure -> bytes pressure)
  bench_optimizer_overhead  §4.3    (81us detect / 7.6ms transform)
  bench_flow_sweep        Fig 10    (speedup vs (key,value) pressure)
  bench_scalability       Fig 5     (scaling -> collective-bytes scaling)
  bench_integrations      beyond paper (grad-accum / MoE / decode combiners)
"""

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_flow_sweep, bench_integrations,
                            bench_memory, bench_optimizer_overhead,
                            bench_phoenix_suite, bench_scalability)

    print("name,us_per_call,derived")
    for mod in (bench_phoenix_suite, bench_memory,
                bench_optimizer_overhead, bench_flow_sweep,
                bench_scalability, bench_integrations):
        try:
            mod.main()
        except Exception:
            print(f"{mod.__name__}_FAILED,0,", file=sys.stdout)
            traceback.print_exc()


if __name__ == '__main__':
    main()
