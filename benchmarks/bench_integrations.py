"""Beyond-paper integrations of the combiner: grad accumulation, MoE
combine-back, decode attention — combiner flow vs materialize flow on
reduced configs (CPU-measurable), plus the logsumexp-monoid loss."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_scale, row, time_fn
from repro.configs import get_config
from repro.models.registry import get_model
from repro.training import losses
from repro.training.grad_accum import accumulate_gradients, derive_grad_combiner


def bench_grad_accum():
    cfg = get_config("llama3-8b").reduced(num_layers=4, d_model=128,
                                          d_ff=256, vocab_size=512)
    model = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init_params(rng)
    batch = {"tokens": jax.random.randint(rng, (16, 64), 0, cfg.vocab_size),
             "labels": jax.random.randint(rng, (16, 64), 0, cfg.vocab_size)}
    spec = derive_grad_combiner().spec

    def loss_fn(p, b):
        return losses.lm_loss(model, p, b, mode="materialize")

    for mode in ("combiner", "materialize"):
        f = jax.jit(lambda p, b: accumulate_gradients(
            loss_fn, p, b, num_microbatches=8, mode=mode, spec=spec)[1])
        t = time_fn(f, params, batch, iters=2 if bench_scale() < 1 else 5)
        # live-memory of the accumulation path
        c = jax.jit(lambda p, b: accumulate_gradients(
            loss_fn, p, b, num_microbatches=8, mode=mode,
            spec=spec)[1]).lower(params, batch).compile()
        m = c.memory_analysis()
        peak = (m.argument_size_in_bytes + m.output_size_in_bytes +
                m.temp_size_in_bytes - m.alias_size_in_bytes)
        print(row(f"grad_accum_{mode}", t * 1e6, f"peak_bytes={peak}"))


def bench_moe_combine():
    from repro.models import moe as moe_mod

    cfg = get_config("qwen3-moe-30b-a3b").reduced(
        num_experts=8, num_experts_per_tok=2, d_model=128, d_ff=64)
    rng = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(rng, cfg)
    x = jax.random.normal(rng, (4, 64, cfg.d_model), jnp.float32)
    outs = {}
    for mode in ("combiner", "materialize"):
        f = jax.jit(lambda p, x: moe_mod.moe_ffn(cfg, p, x, mode=mode)[0])
        outs[mode] = f(p, x)
        t = time_fn(f, p, x, iters=5)
        print(row(f"moe_combine_{mode}", t * 1e6))
    err = float(jnp.max(jnp.abs(outs["combiner"] - outs["materialize"])))
    print(row("moe_combine_flows_agree", 0.0, f"max_abs_diff={err:.2e}"))


def bench_decode_attention():
    """Combiner-fold decode attention vs materialized softmax, long KV."""
    from repro.kernels import ops, ref

    B, H, Hkv, D, S = 1, 8, 2, 64, (1024 if bench_scale() < 1
                                    else 8192)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)) * 0.2, jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
    kvl = jnp.asarray([S], jnp.int32)

    ref_fn = jax.jit(lambda q, k, v: ref.flash_decode(q[0], k[0], v[0], S))
    t_ref = time_fn(ref_fn, q, k, v, iters=5)
    print(row("decode_attn_materialized", t_ref * 1e6,
              "full [H,S] logits materialized"))
    # the Pallas kernel in interpret mode measures Python, not TPU perf —
    # report bytes instead: the combiner never holds more than one KV tile
    tile = 512
    holder_bytes = H * (D + 2) * 4
    logits_bytes = H * S * 4
    print(row("decode_attn_combiner_live_bytes", holder_bytes,
              f"vs materialized logits {logits_bytes} "
              f"({logits_bytes / holder_bytes:.0f}x)"))


def main():
    print("# beyond-paper: the derived combiner applied to training/MoE/"
          "decode substrates")
    bench_grad_accum()
    bench_moe_combine()
    bench_decode_attention()


if __name__ == "__main__":
    main()
