"""Paper §4.3: optimizer overhead — 81 µs detection / 7.6 ms transformation
per class on the JVM.  Ours: jaxpr analysis (detect) + spec synthesis
(transform) + the beyond-paper numeric validation probes, per reducer."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import apps
from benchmarks.common import bench_scale, row, time_fn
from repro.core import plan_cache as pc
from repro.core.api import MapReduce, make_app
from repro.core.plan import plan_execution


def warm_cache_overhead():
    """Staged-API follow-up: a warm plan-cache dispatch must be a small
    fraction of the cold ``run()`` (derive + autotune + trace + compile).

    Returns (cold_s, warm_s); the CI smoke asserts warm < 10% of cold.
    """
    app = make_app(
        map_fn=lambda item, emit: emit.emit(item % 256,
                                            jnp.ones((), jnp.int32)),
        reduce_fn=lambda k, vs, n: vs.sum(),
        key_space=256,
        value_aval=jax.ShapeDtypeStruct((), jnp.int32),
    )
    # small payload: the metric is dispatch overhead, not compute
    items = jnp.arange(int(20_000 * bench_scale()), dtype=jnp.int32)

    pc.clear()
    t0 = time.perf_counter()
    mr = MapReduce(app)
    jax.block_until_ready(mr.run(items).values)
    cold_s = time.perf_counter() - t0

    compiled = MapReduce(app).lower(items).compile()  # all cache hits
    s0 = pc.stats_snapshot()
    warm_s = time_fn(lambda: compiled(items).values)
    s1 = pc.stats_snapshot()
    assert s1["derives"] == s0["derives"], "warm dispatch re-derived"
    assert s1["autotunes"] == s0["autotunes"], "warm dispatch re-autotuned"
    assert s1["compiles"] == s0["compiles"], "warm dispatch re-compiled"
    return cold_s, warm_s


def main():
    rng = np.random.default_rng(0)
    print("# paper §4.3: optimizer overhead per reducer "
          "(paper: 81us detect / 7.6ms transform)")
    det, tra, val = [], [], []
    for name in apps.ALL:
        app, _ = apps.build(name, rng, scale=bench_scale())
        plan = plan_execution(app)
        d = plan.derivation
        det.append(d.detect_s)
        tra.append(d.transform_s)
        val.append(d.validate_s)
        print(row(f"optimizer_{name}_detect", d.detect_s * 1e6))
        print(row(f"optimizer_{name}_transform", d.transform_s * 1e6,
                  f"strategy={d.strategy}"))
        print(row(f"optimizer_{name}_validate_probes", d.validate_s * 1e6,
                  "beyond-paper; paper trusts MapReduce semantics"))
    print(row("optimizer_mean_detect", float(np.mean(det)) * 1e6,
              "paper: 81us"))
    print(row("optimizer_mean_transform", float(np.mean(tra)) * 1e6,
              "paper: 7.6ms"))
    cold_s, warm_s = warm_cache_overhead()
    print(row("plan_cache_cold_run", cold_s * 1e6,
              "derive+autotune+trace+compile+execute"))
    print(row("plan_cache_warm_dispatch", warm_s * 1e6,
              f"{100.0 * warm_s / cold_s:.2f}% of cold"))


if __name__ == "__main__":
    main()
