"""Paper §4.3: optimizer overhead — 81 µs detection / 7.6 ms transformation
per class on the JVM.  Ours: jaxpr analysis (detect) + spec synthesis
(transform) + the beyond-paper numeric validation probes, per reducer."""

from __future__ import annotations

import numpy as np

from benchmarks import apps
from benchmarks.common import bench_scale, row
from repro.core.plan import plan_execution


def main():
    rng = np.random.default_rng(0)
    print("# paper §4.3: optimizer overhead per reducer "
          "(paper: 81us detect / 7.6ms transform)")
    det, tra, val = [], [], []
    for name in apps.ALL:
        app, _ = apps.build(name, rng, scale=bench_scale())
        plan = plan_execution(app)
        d = plan.derivation
        det.append(d.detect_s)
        tra.append(d.transform_s)
        val.append(d.validate_s)
        print(row(f"optimizer_{name}_detect", d.detect_s * 1e6))
        print(row(f"optimizer_{name}_transform", d.transform_s * 1e6,
                  f"strategy={d.strategy}"))
        print(row(f"optimizer_{name}_validate_probes", d.validate_s * 1e6,
                  "beyond-paper; paper trusts MapReduce semantics"))
    print(row("optimizer_mean_detect", float(np.mean(det)) * 1e6,
              "paper: 81us"))
    print(row("optimizer_mean_transform", float(np.mean(tra)) * 1e6,
              "paper: 7.6ms"))


if __name__ == "__main__":
    main()
