"""Timing + reporting helpers shared by the benchmark harness."""

from __future__ import annotations

import os
import time

import jax
import numpy as np


def bench_scale(default: float = 1.0) -> float:
    """Workload scale factor, settable via REPRO_BENCH_SCALE.

    ``benchmarks/run.py --preset ci`` sets a tiny scale so the CI smoke job
    exercises every benchmark path in seconds; 1.0 is the full-size run the
    perf trajectory is recorded at.
    """
    try:
        return float(os.environ.get("REPRO_BENCH_SCALE", default))
    except ValueError:
        return default


def time_fn(fn, *args, warmup: int = 3, iters: int = 10) -> float:
    """Median wall seconds per call (jit-compiled callable).

    The paper uses 5 warmup + 10 timed iterations; we use 3+10 with a
    median (single-core container, background noise)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"


def parse_rows(text: str) -> list[dict]:
    """Parse ``name,us_per_call,derived`` CSV lines into artifact rows
    (shared by run.py's harness and the standalone --json modes)."""
    rows = []
    for line in text.splitlines():
        if line.startswith("#") or "," not in line:
            continue
        parts = line.split(",", 2)
        if len(parts) < 2:
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        rows.append({"name": parts[0], "us_per_call": us,
                     "derived": parts[2] if len(parts) > 2 else ""})
    return rows
