"""Paper Figs 8/9: heap pressure -> intermediate-bytes pressure.

The JVM figures show GC time collapsing when the optimizer removes the
per-key value lists.  The TPU-native analogue: bytes accessed + peak buffer
residency of the collector path, derived from the compiled HLO of each flow
(same workload, same map), now including the streaming fused flow whose peak
intermediate state is O(K + chunk_pairs) regardless of the pair count.  Each
measured row is paired with the first-order analytic model from
``roofline.analysis`` (``model=`` column) so drift between the model and the
compiled artifact is visible in the trajectory.
"""

from __future__ import annotations

import numpy as np

from benchmarks import apps
from benchmarks.common import bench_scale, row
from repro.core import MapReduce
from repro.roofline import analysis, hlo_parser


def flow_footprint(mr: MapReduce, items):
    lowered = mr.lower(items)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = hlo_parser.analyze_text(compiled.as_text(), default_group=1)
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes +
            mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    return {"bytes_accessed": cost.bytes_accessed, "peak_bytes": float(peak)}


def _n_pairs(app, items):
    import jax

    return jax.tree.leaves(items)[0].shape[0] * app.emit_capacity


def main():
    rng = np.random.default_rng(0)
    scale = bench_scale()
    print("# paper Figs 8/9: collector memory pressure per flow "
          "(GC-time analogue: bytes through the memory system)")
    for name in ("WC", "HG", "SM"):
        app, items = apps.build(name, rng, scale=scale)
        n_pairs = _n_pairs(app, items)
        footprints = {}
        tiling = None
        for flow in ("reduce", "combine", "stream"):
            mr = MapReduce(app, flow=flow)
            if flow == "stream":
                tiling = mr.tiling  # keep the model in sync with autotuning
            footprints[flow] = flow_footprint(mr, items)
        value_bytes = int(np.dtype(app.value_aval.dtype).itemsize *
                          max(1, int(np.prod(app.value_aval.shape))))
        for flow in ("reduce", "combine", "stream"):
            f = footprints[flow]
            chunk = tiling.chunk_pairs if flow == "stream" else None
            kb = (tiling.key_block if flow == "stream" and tiling.blocked
                  else None)
            model_b = analysis.mapreduce_flow_bytes(
                flow, n_pairs=n_pairs, key_space=app.key_space,
                value_bytes=value_bytes, chunk_pairs=chunk, key_block=kb,
                max_values_per_key=app.max_values_per_key)
            model_p = analysis.mapreduce_flow_peak_bytes(
                flow, n_pairs=n_pairs, key_space=app.key_space,
                value_bytes=value_bytes, chunk_pairs=chunk, key_block=kb,
                max_values_per_key=app.max_values_per_key)
            print(row(f"memory_{name}_{flow}_peak_bytes", f["peak_bytes"],
                      f"model={model_p:.0f}"))
            print(row(f"memory_{name}_{flow}_bytes_accessed",
                      f["bytes_accessed"], f"model={model_b:.0f}"))
        f_r, f_s = footprints["reduce"], footprints["stream"]
        print(row(f"memory_{name}_stream_vs_reduce", 0.0,
                  f"traffic_ratio="
                  f"{f_r['bytes_accessed']/max(f_s['bytes_accessed'],1):.1f}x "
                  f"peak_ratio={f_r['peak_bytes']/max(f_s['peak_bytes'],1):.1f}x"))


if __name__ == "__main__":
    main()
