"""Paper Figs 8/9: heap pressure -> intermediate-bytes pressure.

The JVM figures show GC time collapsing when the optimizer removes the
per-key value lists.  The TPU-native analogue: bytes accessed + peak buffer
residency of the collector path, derived from the compiled HLO of each flow
(same workload, same map).  Also reports the analytic intermediate sizes:
reduce flow materializes O(N) pairs + an O(K·Lmax) window gather; combine
flow holds O(K) holders.
"""

from __future__ import annotations

import numpy as np

from benchmarks import apps
from benchmarks.common import row
from repro.core import MapReduce
from repro.roofline import hlo_parser


def flow_footprint(mr: MapReduce, items):
    lowered = mr.lower(items)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = hlo_parser.analyze_text(compiled.as_text(), default_group=1)
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes +
            mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    return {"bytes_accessed": cost.bytes_accessed, "peak_bytes": float(peak)}


def main():
    rng = np.random.default_rng(0)
    print("# paper Figs 8/9: collector memory pressure per flow "
          "(GC-time analogue: bytes through the memory system)")
    for name in ("WC", "HG", "SM"):
        app, items = apps.build(name, rng)
        f_r = flow_footprint(MapReduce(app, flow="reduce"), items)
        f_c = flow_footprint(MapReduce(app, flow="auto"), items)
        print(row(f"memory_{name}_reduce_peak_bytes", f_r["peak_bytes"]))
        print(row(f"memory_{name}_combine_peak_bytes", f_c["peak_bytes"],
                  f"peak_ratio={f_r['peak_bytes']/max(f_c['peak_bytes'],1):.1f}x"))
        print(row(f"memory_{name}_reduce_bytes_accessed",
                  f_r["bytes_accessed"]))
        print(row(f"memory_{name}_combine_bytes_accessed",
                  f_c["bytes_accessed"],
                  f"traffic_ratio={f_r['bytes_accessed']/max(f_c['bytes_accessed'],1):.1f}x"))


if __name__ == "__main__":
    main()
