"""Multi-job DAG fusion: fused pipeline vs per-job round-trips.

Word count feeding a count-of-counts histogram — the fused executable keeps
the K-row intermediate in registers/VMEM while the unfused form dispatches
two executables and materializes the table between them.  Checks bitwise
parity, that the analytic byte model says fused moves strictly fewer bytes,
and reports measured wall-clock for both forms.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_scale, row, time_fn
from repro.core import Pipeline, make_app
from repro.core import cost_model as cm

VOCAB = 512
BUCKETS = 32


def build_pipeline():
    wordcount = make_app(
        map_fn=lambda item, emit: emit.emit(item % VOCAB,
                                            jnp.ones((), jnp.int32)),
        reduce_fn=lambda k, vs, n: vs.sum(),
        key_space=VOCAB,
        value_aval=jax.ShapeDtypeStruct((), jnp.int32),
    )

    def hist_map(item, emit):
        count = item[1]
        emit.emit(jnp.clip(count // 16, 0, BUCKETS - 1).astype(jnp.int32),
                  jnp.ones((), jnp.int32))

    histogram = make_app(
        map_fn=hist_map,
        reduce_fn=lambda k, vs, n: vs.sum(),
        key_space=BUCKETS,
        value_aval=jax.ShapeDtypeStruct((), jnp.int32),
    )
    return Pipeline(wordcount).then(histogram)


def main():
    rng = np.random.default_rng(0)
    n = int(200_000 * bench_scale())
    items = jnp.asarray(rng.integers(0, 10 * VOCAB, size=n) % VOCAB,
                        dtype=jnp.int32)
    pipe = build_pipeline()

    fused = pipe.run(items)
    unfused = pipe.run_unfused(items)
    for a, b in ((fused.keys, unfused.keys), (fused.values, unfused.values),
                 (fused.counts, unfused.counts)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "fused pipeline result diverged from per-job execution"

    mb_fused = pipe.model_bytes(n, fused=True)
    mb_unfused = pipe.model_bytes(n, fused=False)
    assert mb_fused < mb_unfused, (mb_fused, mb_unfused)

    t_fused = time_fn(lambda: pipe.run(items).values)
    t_unfused = time_fn(lambda: pipe.run_unfused(items).values)
    oh_fused = cm.pipeline_overhead_s(2, fused=True)
    oh_unfused = cm.pipeline_overhead_s(
        2, fused=False, handoff_bytes=mb_unfused - mb_fused)

    print("# pipeline fusion: wordcount -> count-of-counts "
          f"(N={n} K={VOCAB} B={BUCKETS})")
    for line in pipe.fusion_report():
        print(f"#   {line}")
    print(row("pipeline_fused", t_fused * 1e6,
              f"model={mb_fused / 1e6:.2f}MB"))
    print(row("pipeline_unfused", t_unfused * 1e6,
              f"model={mb_unfused / 1e6:.2f}MB"))
    print(row("pipeline_model_overhead_fused", oh_fused * 1e6,
              "1 dispatch, no handoff"))
    print(row("pipeline_model_overhead_unfused", oh_unfused * 1e6,
              "2 dispatches + table round-trip"))
    print("# parity: fused == unfused bitwise; "
          f"model bytes fused < unfused by {(mb_unfused - mb_fused):.0f}B")


if __name__ == "__main__":
    main()
