"""Paper Fig 5 (scalability) — distributed version.

The paper scales MR4J over 1..64 hardware threads.  This container has one
core, so wall-clock scaling is meaningless; what CAN be measured exactly is
the quantity that governs scaling at pod scale: **collective wire bytes per
shard** as the shard count grows.  The combine flow all-reduces O(K) holder
tables (shard-count-independent per-shard volume) while the reduce flow
all-to-alls O(N) raw pairs.  Derived from compiled HLO on fake meshes in a
subprocess per shard count."""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import bench_scale, row

_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={S}"
import sys, json
sys.path.insert(0, {src!r})
import numpy as np, jax, jax.numpy as jnp
from functools import partial
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import MapReduceApp, plan_execution
from repro.core import engine as eng
from repro.roofline import hlo_parser

VOCAB = 512
class WC(MapReduceApp):
    key_space = VOCAB
    value_aval = jax.ShapeDtypeStruct((), jnp.int32)
    max_values_per_key = 4096
    emit_capacity = 8
    def map(self, item, emit): emit(item, jnp.ones_like(item))
    def reduce(self, key, values, count): return jnp.sum(values)

S = {S}
mesh = jax.make_mesh((S,), ("data",))
toks = jax.ShapeDtypeStruct((S * 256, 8), jnp.int32)
app = WC()
out = {{}}
with mesh:
    for flow in ("auto", "reduce"):
        plan = plan_execution(app, flow=flow)
        c = jax.jit(partial(eng.run_distributed, app, plan, mesh=mesh)).lower(toks).compile()
        hc = hlo_parser.analyze_text(c.as_text(), default_group=S)
        out["optimized" if plan.optimized else "reduce"] = hc.collective_bytes
        out.setdefault("optimized_flow", plan.flow if plan.optimized else None)
print("RESULT " + json.dumps(out))
"""

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    print("# paper Fig 5 analogue: per-shard collective bytes vs shard "
          "count (stream/combine flow = O(K) tables, reduce flow = "
          "O(N) pairs)")
    shard_counts = (2, 4) if bench_scale() < 1 else (2, 4, 8)
    failed = []
    for S in shard_counts:
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        env["PYTHONPATH"] = SRC
        r = subprocess.run([sys.executable, "-c", _CODE.format(S=S, src=SRC)],
                           capture_output=True, text=True, timeout=420,
                           env=env)
        line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")]
        if not line:
            print(row(f"scalability_S{S}", 0.0,
                      f"FAILED: {r.stderr[-200:]}"))
            failed.append(S)
            continue
        data = json.loads(line[0][len("RESULT "):])
        flow = data.get("optimized_flow") or "combine"
        print(row(f"scalability_S{S}_{flow}_wire_bytes", data["optimized"]))
        print(row(f"scalability_S{S}_reduce_wire_bytes", data["reduce"],
                  f"ratio={data['reduce']/max(data['optimized'],1):.1f}x"))
    if failed:  # surface subprocess failures to run.py's health gate
        raise RuntimeError(f"scalability subprocesses failed: S={failed}")


if __name__ == "__main__":
    main()
