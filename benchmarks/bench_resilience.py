"""Recovery-cost benchmark for the fault-tolerant driver (run_resilient).

Measures, per flow, the wall-clock of

  * the no-failure resilient run (driver overhead over the plain
    per-shard execution),
  * recovery from one killed host by deterministic re-execution
    (the backup rank recomputes only the lost shards),
  * recovery by restoring the checkpointed partial aggregate,
  * the naive alternative: restarting the whole job from scratch,

and reports the recovered fraction — the point of monoid partial-aggregate
recovery is that losing 1 of H hosts costs ~1/H of the map phase, not a
full restart.

The durable control plane (distributed/coordination.py) adds two rows:

  * ``failover_adopt_ledger``   lease adoption + recovery-ledger load from
                                a FileKVStore — the store round-trip a
                                failover coordinator pays before phase B,
  * ``resilient_*_coordinator_kill``  a full kill-the-coordinator chaos
                                drill (lease lapse, re-election, ledger
                                adoption, restore-or-recompute) vs the
                                clean coordinated run.

Wired into run.py's MODULE_NAMES: the wall-clock rows gate generously
(single-process timings of a simulated cluster are architecture numbers),
but recovery-time and failover-latency belong on the perf trajectory.

Usage:  PYTHONPATH=src python benchmarks/bench_resilience.py
"""

from __future__ import annotations

import os
import sys
import tempfile
import time

# self-locating like run.py: `python benchmarks/bench_resilience.py` puts
# benchmarks/ (not the repo root) on sys.path
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_scale, row
from repro.core import MapReduceApp, plan_execution
from repro.core import engine as eng
from repro.distributed import chaos as chaoslib
from repro.distributed import coordination as coordlib
from repro.distributed import fault as flt


class WC(MapReduceApp):
    key_space = 4096
    value_aval = jax.ShapeDtypeStruct((), jnp.int32)
    max_values_per_key = 4096
    emit_capacity = 8

    def map(self, item, emit):
        emit(item, jnp.ones_like(item))

    def reduce(self, key, values, count):
        return jnp.sum(values)


def _bench_failover_latency(hosts: int, shards: int) -> None:
    """Lease adoption + ledger load through a FileKVStore: the durable
    store round-trip a failover coordinator pays before resuming phase B
    (the compute side of failover is the restore/recompute rows below)."""
    reps = 5
    total = 0.0
    for _ in range(reps):
        with tempfile.TemporaryDirectory() as d:
            store = coordlib.CoordinationStore(d, lease_ttl_s=60.0)
            for s in range(shards):  # the dead coordinator's ledger
                store.record_shard(s, host=s % hosts, step=0)
            t0 = time.perf_counter()
            # host 0 (the old coordinator) is dead: 1 adopts + reads
            lease = store.adopt(1, range(1, hosts))
            ledger = store.load_ledger(0)
            total += time.perf_counter() - t0
            assert lease is not None and len(ledger) == shards
    print(row("failover_adopt_ledger", total / reps * 1e6,
              f"shards={shards} store=file"))


def _time_once(fn) -> float:
    """One timed call after one warmup (the driver is a host-side loop
    re-jitting per call; medians of re-runs measure the host loop, which
    is what the recovery fraction is about)."""
    fn()
    t0 = time.perf_counter()
    jax.block_until_ready(fn()[1])
    return time.perf_counter() - t0


def main():
    scale = bench_scale()
    n_items = max(64, int(2048 * scale))
    hosts = 8
    n_items -= n_items % hosts
    rng = np.random.default_rng(0)
    toks = jnp.asarray(
        rng.integers(0, WC.key_space, (n_items, 8)).astype(np.int32))
    app = WC()
    print("# bench_resilience: recovery cost vs restart "
          f"(n_items={n_items}, hosts={hosts})")
    _bench_failover_latency(hosts, shards=64)

    for flow in ("stream", "sort", "reduce"):
        def run(inject=None, ckpt_dir=None, chaos=None, flow=flow):
            plan = plan_execution(app, flow=flow)
            return eng.run_resilient(app, plan, toks, num_hosts=hosts,
                                     num_shards=hosts, inject=inject,
                                     ckpt_dir=ckpt_dir, chaos=chaos)

        t_clean = _time_once(lambda: run())
        t_kill = _time_once(
            lambda: run(inject=flt.FaultInjection(dead_hosts=(3,))))
        with tempfile.TemporaryDirectory() as d:
            run(ckpt_dir=d)  # seed the shard checkpoints
            t_restore = _time_once(
                lambda: run(inject=flt.FaultInjection(dead_hosts=(3,)),
                            ckpt_dir=d))
        with tempfile.TemporaryDirectory() as d:
            run(ckpt_dir=d)  # fresh seed for the chaos drill
            t_failover = _time_once(
                lambda: run(ckpt_dir=d,
                            chaos=chaoslib.ChaosPlan()
                            .kill_coordinator(after=1)))
        t_restart = t_clean + t_kill  # lose the run, start over, then pay
        # the failed attempt too — the floor a restart policy pays

        print(row(f"resilient_{flow}_clean", t_clean * 1e6))
        print(row(f"resilient_{flow}_kill1of{hosts}", t_kill * 1e6,
                  f"recompute_overhead={t_kill / t_clean:.2f}x_clean"))
        print(row(f"resilient_{flow}_restore1of{hosts}", t_restore * 1e6,
                  f"restore_overhead={t_restore / t_clean:.2f}x_clean"))
        print(row(f"resilient_{flow}_coordinator_kill", t_failover * 1e6,
                  f"failover_overhead={t_failover / t_clean:.2f}x_clean"))
        print(row(f"resilient_{flow}_restart_floor", t_restart * 1e6,
                  f"recovery_saves={t_restart / max(t_kill, 1e-9):.2f}x"))

    wire_recovery()


#: host counts of the compressed-wire recovery sweep (PR 10): past the
#: 8-host rows above, the shuffle fan-out is S^2 buckets and the wire
#: codec is what bounds the checkpoint + all-to-all bytes.
WIRE_HOSTS = (16, 64)


def wire_recovery(host_counts: tuple[int, ...] = WIRE_HOSTS):
    """Kill/recovery at 16-64 fake hosts under the shuffle wire codecs.

    The sort flow's checkpointed partial IS the encoded wire tree
    (``distributed/wire.py``), so the delta codec shrinks what recovery
    writes and restores, not just the all-to-all.  Rows per host count:
    raw vs delta clean runs (bitwise-asserted against each other) and the
    delta restore-from-compressed-checkpoint drill after killing one
    host.  Wall-clock rows; the bytes gate lives in
    ``bench_flow_sweep --wire``.
    """
    scale = bench_scale()
    rng = np.random.default_rng(1)
    app = WC()
    for hosts in host_counts:
        n_items = max(2 * hosts, int(2048 * scale))
        n_items -= n_items % hosts
        toks = jnp.asarray(
            rng.integers(0, WC.key_space, (n_items, 8)).astype(np.int32))
        # at 64 shards the 2x-uniform envelope is a couple of pairs per
        # destination: provision the full per-shard pair count so the
        # rows measure the wire, not overflow drops
        cap = (n_items // hosts) * 8

        def run(wire, inject=None, ckpt_dir=None, hosts=hosts, toks=toks,
                cap=cap):
            plan = plan_execution(app, flow="sort")
            return eng.run_resilient(app, plan, toks, num_hosts=hosts,
                                     num_shards=hosts, inject=inject,
                                     ckpt_dir=ckpt_dir, wire=wire,
                                     shuffle_capacity=cap)

        base = run("raw")
        delta = run("delta")
        np.testing.assert_array_equal(np.asarray(base[1]),
                                      np.asarray(delta[1]))
        np.testing.assert_array_equal(np.asarray(base[2]),
                                      np.asarray(delta[2]))
        t_raw = _time_once(lambda: run("raw"))
        t_delta = _time_once(lambda: run("delta"))
        with tempfile.TemporaryDirectory() as d:
            run("delta", ckpt_dir=d)  # seed COMPRESSED shard partials
            t_restore = _time_once(
                lambda: run("delta",
                            inject=flt.FaultInjection(dead_hosts=(3,)),
                            ckpt_dir=d))
        print(row(f"resilient_sort_h{hosts}_wire_raw_clean", t_raw * 1e6,
                  f"n_items={n_items}"))
        print(row(f"resilient_sort_h{hosts}_wire_delta_clean",
                  t_delta * 1e6,
                  f"raw={t_raw * 1e6:.0f}us "
                  f"ratio={t_delta / t_raw:.2f}x bitwise=ok"))
        print(row(f"resilient_sort_h{hosts}_wire_delta_restore1of{hosts}",
                  t_restore * 1e6,
                  f"restore_overhead={t_restore / t_delta:.2f}x_clean "
                  f"(compressed partials)"))


if __name__ == "__main__":
    main()
