"""Assemble the EXPERIMENTS.md roofline tables from results/dryrun/*.json."""

from __future__ import annotations

import glob
import json
import os


def load(results_dir="results/dryrun"):
    cells = {}
    for f in glob.glob(os.path.join(results_dir, "*.json")):
        r = json.load(open(f))
        cells[(r["arch"], r["shape"], r["mesh"])] = r
    return cells


def table(cells, mesh="pod"):
    hdr = ("| arch | shape | peak GiB (tpu-adj) | compute s | memory s | "
           "collective s | dominant | useful ratio | MFU@roofline |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for (arch, shape, m), r in sorted(cells.items()):
        if m != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | — | — | — | — | "
                         f"SKIP: {r['reason'][:48]} | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {arch} | {shape} | ERROR {r['error'][:60]} |")
            continue
        rl = r["roofline"]
        mem = r["memory"]
        lines.append(
            f"| {arch} | {shape} | {mem['peak_per_chip_gib']:.1f} "
            f"({mem.get('peak_tpu_adjusted_gib', mem['peak_per_chip_gib']):.1f}) "
            f"| {rl['compute_s']:.3f} | {rl['memory_s']:.3f} "
            f"| {rl['collective_s']:.3f} | {rl['dominant']} "
            f"| {rl['useful_ratio']:.3f} | {rl['mfu']:.4f} |")
    return "\n".join(lines)


def collective_table(cells):
    lines = ["| arch | shape | pod collectives (count / GiB wire per chip) |",
             "|---|---|---|"]
    for (arch, shape, m), r in sorted(cells.items()):
        if m != "pod" or r["status"] != "ok":
            continue
        ops = {k: v for k, v in r["roofline"]["collective_ops"].items()
               if not k.startswith("_")}
        desc = ", ".join(
            f"{k}:{int(v['count'])}/{v['bytes']/2**30:.2f}"
            for k, v in sorted(ops.items()))
        lines.append(f"| {arch} | {shape} | {desc} |")
    return "\n".join(lines)


if __name__ == "__main__":
    cells = load()
    print("## single-pod (16×16 = 256 chips)\n")
    print(table(cells, "pod"))
    print("\n## multi-pod (2×16×16 = 512 chips)\n")
    print(table(cells, "multipod"))
    print("\n## collective breakdown (pod)\n")
    print(collective_table(cells))
