"""Diff two BENCH_*.json artifacts and fail on perf regressions.

``benchmarks/run.py --json`` writes ``{scale, preset, rows, failures}``
where each row is ``{name, us_per_call, derived}``.  This tool compares a
current artifact against a committed baseline and exits non-zero when any
metric regresses beyond tolerance — the CI gate that turns the per-commit
BENCH_ci.json trajectory into an actual guard instead of an unread upload.

Metric classes (by row name):

* ``*bytes*`` rows carry bytes in the value field and are deterministic
  compiled-HLO measurements -> tight default tolerance (``--bytes-rtol``).
* everything else is wall-clock (us/call) -> generous default tolerance
  (``--time-rtol``) plus an absolute floor (``--abs-floor-us``) so shared-
  runner jitter on sub-millisecond rows never gates a PR; the committed
  baseline may also come from different hardware than the runner.

Rows present only in the current run are reported as NEW (not gated); rows
missing from the current run FAIL unless ``--allow-missing`` (losing a
benchmark is itself a regression).  ``*_FAILED`` rows and a non-empty
``failures`` list in the current artifact always fail.

``--update-baseline`` regenerates the committed baseline from the current
artifact instead of gating on it: the diff is still computed (and written
to ``--summary`` for the job log), then the baseline file is overwritten
with the current run — replacing the old hand-edit workflow.  A current
artifact with module failures is refused (a broken run must never become
the baseline).

Usage:
  python benchmarks/compare.py BASELINE.json CURRENT.json \
      [--time-rtol 3.0] [--bytes-rtol 1.2] [--abs-floor-us 2000] \
      [--summary compare.md] [--allow-missing] [--update-baseline]
"""

from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str) -> tuple[dict[str, dict], list]:
    with open(path) as f:
        data = json.load(f)
    rows = {r["name"]: r for r in data.get("rows", [])}
    return rows, data.get("failures", [])


def is_bytes_metric(name: str) -> bool:
    return "bytes" in name


def _fmt(value: float, is_bytes: bool) -> str:
    if is_bytes:
        return (f"{value / 1e6:.2f}MB" if value >= 1e5 else f"{value:.0f}B")
    return f"{value:.1f}us"


def compare(base: dict[str, dict], cur: dict[str, dict], *,
            time_rtol: float, bytes_rtol: float, abs_floor_us: float,
            allow_missing: bool) -> tuple[list[dict], bool]:
    """Per-row verdicts + overall regression flag."""
    out: list[dict] = []
    regressed = False
    for name in sorted(set(base) | set(cur)):
        b, c = base.get(name), cur.get(name)
        isb = is_bytes_metric(name)
        rec = {"name": name, "bytes": isb}
        if c is None:
            rec.update(status="FAIL" if not allow_missing else "missing",
                       note="row missing from current run")
            regressed |= not allow_missing
            out.append(rec)
            continue
        if name.endswith("_FAILED"):
            rec.update(status="FAIL", note="benchmark module failed")
            regressed = True
            out.append(rec)
            continue
        if b is None:
            rec.update(status="new", cur=c["us_per_call"])
            out.append(rec)
            continue
        bv, cv = float(b["us_per_call"]), float(c["us_per_call"])
        rec.update(base=bv, cur=cv)
        if bv <= 0.0:  # ratio/info rows carry their payload in `derived`
            rec.update(status="info")
            out.append(rec)
            continue
        ratio = cv / bv
        rec["ratio"] = ratio
        rtol = bytes_rtol if isb else time_rtol
        over = ratio > rtol and (isb or (cv - bv) > abs_floor_us)
        if over:
            rec.update(status="FAIL",
                       note=f"{ratio:.2f}x > {rtol:.2f}x tolerance")
            regressed = True
        elif ratio < 1.0 / rtol:
            rec.update(status="improved")
        else:
            rec.update(status="ok")
        out.append(rec)
    return out, regressed


def render_markdown(verdicts: list[dict], *, title: str) -> str:
    lines = [f"### {title}", "",
             "| benchmark | baseline | current | Δ | status |",
             "|---|---:|---:|---:|---|"]
    for v in verdicts:
        base = _fmt(v["base"], v["bytes"]) if "base" in v else "—"
        cur = _fmt(v["cur"], v["bytes"]) if "cur" in v else "—"
        delta = (f"{(v['ratio'] - 1.0) * 100:+.1f}%" if "ratio" in v else "—")
        status = v["status"] + (f" ({v['note']})" if "note" in v else "")
        mark = "❌ " if v["status"] == "FAIL" else ""
        lines.append(f"| {v['name']} | {base} | {cur} | {delta} "
                     f"| {mark}{status} |")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument("current", help="fresh BENCH_*.json to gate")
    ap.add_argument("--time-rtol", type=float, default=3.0,
                    help="wall-clock regression tolerance (x baseline)")
    ap.add_argument("--bytes-rtol", type=float, default=1.2,
                    help="bytes-metric regression tolerance (x baseline)")
    ap.add_argument("--abs-floor-us", type=float, default=2000.0,
                    help="ignore wall-clock deltas smaller than this")
    ap.add_argument("--summary", default=None, metavar="PATH",
                    help="write a markdown delta table (for the CI "
                         "job summary)")
    ap.add_argument("--allow-missing", action="store_true",
                    help="missing rows warn instead of failing")
    ap.add_argument("--update-baseline", action="store_true",
                    help="overwrite BASELINE with CURRENT after diffing "
                         "(the workflow_dispatch regeneration job); exits "
                         "0 unless the current run has module failures")
    args = ap.parse_args(argv)

    base, _ = load_rows(args.baseline)
    cur, cur_failures = load_rows(args.current)
    verdicts, regressed = compare(
        base, cur, time_rtol=args.time_rtol, bytes_rtol=args.bytes_rtol,
        abs_floor_us=args.abs_floor_us, allow_missing=args.allow_missing)
    if cur_failures:
        regressed = True
        verdicts.append({"name": "(modules)", "bytes": False,
                         "status": "FAIL",
                         "note": ", ".join(f["module"] for f in cur_failures)
                                 + " failed"})

    n_fail = sum(v["status"] == "FAIL" for v in verdicts)
    title = (f"Benchmark comparison: "
             f"{'REGRESSED (' + str(n_fail) + ' failing)' if regressed else 'ok'}")
    md = render_markdown(verdicts, title=title)
    if args.summary:
        with open(args.summary, "w") as f:
            f.write(md)
    for v in verdicts:
        if v["status"] in ("FAIL", "improved", "new", "missing"):
            base_s = _fmt(v["base"], v["bytes"]) if "base" in v else "—"
            cur_s = _fmt(v["cur"], v["bytes"]) if "cur" in v else "—"
            print(f"{v['status']:>9}  {v['name']}  {base_s} -> {cur_s}"
                  + (f"  [{v['note']}]" if "note" in v else ""))
    ok = sum(v["status"] == "ok" for v in verdicts)
    print(f"# {len(verdicts)} rows: {ok} ok, {n_fail} failing "
          f"(time_rtol={args.time_rtol}x bytes_rtol={args.bytes_rtol}x "
          f"abs_floor={args.abs_floor_us}us)")
    if args.update_baseline:
        if cur_failures:
            print("# refusing to update the baseline: current artifact has "
                  "module failures")
            return 1
        with open(args.current) as f:
            data = json.load(f)
        with open(args.baseline, "w") as f:
            json.dump(data, f, indent=2)
            f.write("\n")
        print(f"# baseline {args.baseline} updated from {args.current} "
              f"({len(cur)} rows; the table above is the old-vs-new diff)")
        return 0
    return 1 if regressed else 0


if __name__ == "__main__":
    sys.exit(main())
