"""Paper Fig 10: speedup of the optimized flow across workload shapes.

The paper sweeps GC configs and finds the benchmarks with the greatest
(key, value)-pair pressure (HG: 768 keys × 1.4e9 values; WC) improve most,
while SM (4 keys × 910 values) does not.  We sweep the (key_space, pairs)
grid directly with a synthetic sum-reducer workload and report the
combine/reduce speedup surface — the same monotonic trend, parameterized.

PR 2 extends the sweep past the old one-hot VMEM envelope (K = 32768): the
autotuned streaming flow must stay on the scatter-free one-hot fold there
(key-blocked in the Pallas kernel path) with the paper's bytes ordering
``stream ≤ combine < reduce`` intact — both asserted, so a regression back
to the silent scatter fallback fails the benchmark job.  The scatter
fallback is also timed A/B (``fold=scatter`` rows): on XLA:CPU the
serialized scatter can win wall-clock at large K (the one-hot path pays
O(N·K) vectorized compute) but loses the bytes/residency axis by orders of
magnitude — the MXU trade the paper's Figs 8/9 are about.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_scale, row, time_fn
from repro.core import MapReduce, MapReduceApp
from repro.core import engine as eng
from repro.roofline import hlo_parser

#: the large-K config (past onehot VMEM residency) whose stream lowering
#: and bytes ordering are asserted, per the PR 2 acceptance criteria.
BIG_K = 32768


def make_app(key_space, lmax):
    class App(MapReduceApp):
        pass

    a = App()
    a.key_space = key_space
    a.value_aval = jax.ShapeDtypeStruct((), jnp.int32)
    a.max_values_per_key = lmax
    a.emit_capacity = 8
    a.map = lambda item, emit: emit(item, jnp.ones_like(item))
    a.reduce = lambda k, v, c: jnp.sum(v)
    return a


def _flow_bytes(mr, items) -> float:
    c = mr.lower(items).compile()
    return hlo_parser.analyze_text(c.as_text()).bytes_accessed


def _check_large_k(app, items, mr_stream):
    """PR 2 acceptance: at K >= 32768 the stream flow keeps the one-hot
    fold (no scatter fallback) and stream ≤ combine < reduce bytes hold."""
    t = mr_stream.tiling
    assert t is not None and t.mode == "additive", (
        f"large-K stream flow degraded to mode={getattr(t, 'mode', None)}")
    b = {
        "stream": _flow_bytes(mr_stream, items),
        "combine": _flow_bytes(MapReduce(app, flow="combine"), items),
        "reduce": _flow_bytes(MapReduce(app, flow="reduce"), items),
    }
    assert b["stream"] <= b["combine"] < b["reduce"], (
        f"bytes ordering violated at K={app.key_space}: {b}")
    return b


def main():
    rng = np.random.default_rng(0)
    print("# paper Fig 10: speedup surface over (keys × pairs) pressure")
    scale = bench_scale()
    pair_grid = sorted({1 << 10, max(1 << 10, int((1 << 14) * scale))})
    for K in (4, 256, 4096, BIG_K):
        for n_pairs in pair_grid:
            toks = rng.integers(0, K, size=(n_pairs // 8, 8)).astype(np.int32)
            lmax = int(np.bincount(toks.reshape(-1), minlength=K).max())
            lmax = max(8, 1 << int(np.ceil(np.log2(lmax + 1))))
            app = make_app(K, lmax)
            items = jnp.asarray(toks)
            mr_s = MapReduce(app)  # auto flow -> autotuned stream
            t_c = time_fn(lambda x: mr_s.run(x).counts, items, iters=5)
            t_r = time_fn(
                lambda x: MapReduce(app, flow="reduce").run(x).counts,
                items, iters=5)
            tiling = mr_s.tiling
            print(row(f"flow_sweep_K{K}_N{n_pairs}", t_c * 1e6,
                      f"speedup={t_r / t_c:.2f}x {tiling.describe()}"))

        # large-K: assert the one-hot path + bytes ordering, and A/B the
        # scatter fallback + key-blocked Pallas kernel on the small config
        if K == BIG_K:
            n_chk = pair_grid[0]
            toks = rng.integers(0, K, size=(n_chk // 8, 8)).astype(np.int32)
            app = make_app(K, 8)
            items = jnp.asarray(toks)
            mr_s = MapReduce(app)
            b = _check_large_k(app, items, mr_s)
            print(row(f"flow_sweep_K{K}_stream_bytes", b["stream"],
                      f"combine={b['combine']:.0f} reduce={b['reduce']:.0f} "
                      "ordering=ok"))

            spec = mr_s.plan.spec
            fold_scatter = jax.jit(lambda x: eng.run_local_stream(
                app, spec, x, chunk_pairs=mr_s.stream_chunk_pairs,
                fold_mode="scatter")[2])
            t_sc = time_fn(fold_scatter, items, iters=5)
            t_oh = time_fn(lambda x: mr_s.run(x).counts, items, iters=5)
            print(row(f"flow_sweep_K{K}_scatterAB", t_sc * 1e6,
                      f"onehot={t_oh * 1e6:.1f}us "
                      f"onehot_pays={t_oh / t_sc:.1f}x_compute_on_cpu "
                      f"bytes_win={b['reduce'] / max(b['stream'], 1):.0f}x"))

            # float holders engage the fused Pallas fold kernel, whose
            # key-block grid axis is sized against the VMEM model
            appf = make_app(K, 8)
            appf.value_aval = jax.ShapeDtypeStruct((), jnp.float32)
            appf.map = lambda item, emit: emit(
                item, jnp.ones_like(item, jnp.float32))
            appf.reduce = lambda k, v, c: jnp.sum(v)
            mr_k = MapReduce(appf, use_kernels=True)
            tk = mr_k.tiling
            assert tk.mode == "additive" and tk.blocked, (
                "kernel path should key-block at K=32768")
            res_k = mr_k.run(items)
            want = np.bincount(toks.reshape(-1), minlength=K)
            np.testing.assert_array_equal(np.asarray(res_k.values), want)
            t_k = time_fn(lambda x: mr_k.run(x).counts, items, iters=3)
            print(row(f"flow_sweep_K{K}_kernel_blocked", t_k * 1e6,
                      tk.describe()))


if __name__ == "__main__":
    main()
