"""Paper Fig 10: speedup of the optimized flow across workload shapes.

The paper sweeps GC configs and finds the benchmarks with the greatest
(key, value)-pair pressure (HG: 768 keys × 1.4e9 values; WC) improve most,
while SM (4 keys × 910 values) does not.  We sweep the (key_space, pairs)
grid directly with a synthetic sum-reducer workload and report the
combine/reduce speedup surface — the same monotonic trend, parameterized."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_scale, row, time_fn
from repro.core import MapReduce, MapReduceApp


def make_app(key_space, lmax):
    class App(MapReduceApp):
        pass

    a = App()
    a.key_space = key_space
    a.value_aval = jax.ShapeDtypeStruct((), jnp.int32)
    a.max_values_per_key = lmax
    a.emit_capacity = 8
    a.map = lambda item, emit: emit(item, jnp.ones_like(item))
    a.reduce = lambda k, v, c: jnp.sum(v)
    return a


def main():
    rng = np.random.default_rng(0)
    print("# paper Fig 10: speedup surface over (keys × pairs) pressure")
    scale = bench_scale()
    pair_grid = sorted({1 << 10, max(1 << 10, int((1 << 14) * scale))})
    for K in (4, 256, 4096):
        for n_pairs in pair_grid:
            toks = rng.integers(0, K, size=(n_pairs // 8, 8)).astype(np.int32)
            lmax = int(np.bincount(toks.reshape(-1), minlength=K).max())
            lmax = max(8, 1 << int(np.ceil(np.log2(lmax + 1))))
            app = make_app(K, lmax)
            items = jnp.asarray(toks)
            t_c = time_fn(lambda x: MapReduce(app).run(x).counts, items,
                          iters=5)
            t_r = time_fn(
                lambda x: MapReduce(app, flow="reduce").run(x).counts,
                items, iters=5)
            print(row(f"flow_sweep_K{K}_N{n_pairs}", t_c * 1e6,
                      f"speedup={t_r / t_c:.2f}x"))


if __name__ == "__main__":
    main()
