"""Paper Fig 10: speedup of the optimized flow across workload shapes —
extended (PR 3) with the sort flow and the cost-model crossover.

The paper sweeps GC configs and finds the benchmarks with the greatest
(key, value)-pair pressure (HG: 768 keys × 1.4e9 values; WC) improve most,
while SM (4 keys × 910 values) does not.  We sweep the (key_space, pairs)
grid directly with a synthetic sum-reducer workload and report the
combine/reduce speedup surface — the same monotonic trend, parameterized.

PR 2 extended the sweep past the old one-hot VMEM envelope (K = 32768): the
autotuned streaming flow must stay on the scatter-free one-hot fold there
(key-blocked in the Pallas kernel path) with the paper's bytes ordering
``stream ≤ combine < reduce`` intact — both asserted.  The scatter fallback
is also timed A/B (``fold=scatter`` rows): on XLA:CPU the serialized
scatter wins wall-clock at large K (the one-hot path pays O(N·K) vectorized
compute) but loses the bytes/residency axis by orders of magnitude.

PR 3 adds the flow the optimizer was missing in that trade: ``flow="sort"``
(radix-bucketed segment reduce, O(N·log N + K) compute, O(N + K) bytes).
Every sweep row now times the sort flow next to the stream fold, and the
cost model's choice (``core/cost_model.py``) is ASSERTED to match the
measured winner on every row.  The K=32768 crossover rows pin the headline:
the sort flow beats the one-hot fold (and the combine/reduce flows) by
orders of magnitude of wall-clock while holding the model bytes chain
``sort ≤ combine < reduce``.  Against the serialized scatter fold the sort
flow is in the same wall-clock class on XLA:CPU (the comparator sort and
the scatter loop have near-identical per-pair constants — asserted within
a 6× class bound, ratio reported) while winning the counted-bytes axis ~25×; on TPU the
radix kernel keeps the partition VMEM-resident, which is what the cost
model's TPU profile prices (see ``flow_sweep_K32768_sort_bytes`` for the
model-vs-measured split).

PR 4 takes the sort flow past one bucket sweep: ``--big`` adds the
K=1,048,576 crossover rows where the MULTI-PASS hierarchy is what keeps the
fast path — the pure-JAX lowering runs the two-pass packed radix sort
(``stable_sort_by_key(impl="radix")``; the forced single-pass two-key
comparator sort is timed A/B and loses), the kernel pipeline runs the
two-level hierarchical partition (parity-asserted in interpret mode), the
cost model (extended with per-pass terms) must still pick sort for
``flow="auto"``, and the model bytes chain ``sort ≤ combine < reduce``
must hold.  The nightly CI job runs ``--crossover --big --json
BENCH_nightly.json`` and diffs against the committed nightly baseline.

PR 9 adds the skew rows (``--skew``): a Zipf(1.1) key stream driven through
the mesh-less resilient sort flow on 8 shards with
``ShuffleOptions(skew="auto")`` — the sampled histogram derives balanced
range boundaries + hot-key splits (``core/skew.py``), so the zipf row must
stay within 1.5× of the uniform row's wall-clock and raise ZERO
shuffle-overflow ``LoweringFallbackWarning``s, with bitwise parity against
the single-host oracle asserted on both rows.

``python benchmarks/bench_flow_sweep.py --crossover`` runs only the
crossover rows (the CI smoke step).
"""

from __future__ import annotations

import os
import sys
import time

# self-locating like run.py: `python benchmarks/bench_flow_sweep.py` puts
# benchmarks/ (not the repo root) on sys.path
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import warnings

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_scale, row, time_fn
from repro.core import (ExecutionOptions, LoweringFallbackWarning, MapReduce,
                        MapReduceApp, ShuffleOptions)
from repro.core import engine as eng
from repro.core.plan import flow_cost_report
from repro.roofline import analysis as roofline
from repro.roofline import hlo_parser

#: the large-K config (past onehot VMEM residency) whose stream lowering,
#: bytes ordering and sort-flow crossover are asserted.
BIG_K = 32768
#: pair count of the crossover rows (tiny preset).
CROSS_N = 1024


def make_app(key_space, lmax, dtype=jnp.int32):
    class App(MapReduceApp):
        pass

    a = App()
    a.key_space = key_space
    a.value_aval = jax.ShapeDtypeStruct((), dtype)
    a.max_values_per_key = lmax
    a.emit_capacity = 8
    a.map = lambda item, emit: emit(item, jnp.ones_like(
        item, a.value_aval.dtype))
    a.reduce = lambda k, v, c: jnp.sum(v)
    return a


def _flow_bytes(mr, items) -> float:
    c = mr.lower(items).compile()
    return hlo_parser.analyze_text(c.as_text()).bytes_accessed


def _check_large_k(app, items, mr_stream):
    """PR 2 acceptance: at K >= 32768 the stream flow keeps the one-hot
    fold (no scatter fallback) and stream ≤ combine < reduce bytes hold."""
    t = mr_stream.tiling
    assert t is not None and t.mode == "additive", (
        f"large-K stream flow degraded to mode={getattr(t, 'mode', None)}")
    b = {
        "stream": _flow_bytes(mr_stream, items),
        "combine": _flow_bytes(MapReduce(app, flow="combine"), items),
        "reduce": _flow_bytes(MapReduce(app, flow="reduce"), items),
    }
    assert b["stream"] <= b["combine"] < b["reduce"], (
        f"bytes ordering violated at K={app.key_space}: {b}")
    return b


def sweep():
    rng = np.random.default_rng(0)
    print("# paper Fig 10: speedup surface over (keys × pairs) pressure")
    scale = bench_scale()
    pair_grid = sorted({1 << 10, max(1 << 10, int((1 << 14) * scale))})
    for K in (4, 256, 4096, BIG_K):
        for n_pairs in pair_grid:
            toks = rng.integers(0, K, size=(n_pairs // 8, 8)).astype(np.int32)
            lmax = int(np.bincount(toks.reshape(-1), minlength=K).max())
            lmax = max(8, 1 << int(np.ceil(np.log2(lmax + 1))))
            app = make_app(K, lmax)
            items = jnp.asarray(toks)
            mr_s = MapReduce(app)  # auto flow, no hint -> autotuned stream
            t_c = time_fn(lambda x: mr_s.run(x).counts, items, iters=5)
            t_r = time_fn(
                lambda x: MapReduce(app, flow="reduce").run(x).counts,
                items, iters=5)
            tiling = mr_s.tiling
            print(row(f"flow_sweep_K{K}_N{n_pairs}", t_c * 1e6,
                      f"speedup={t_r / t_c:.2f}x {tiling.describe()}"))

            # PR 3: sort flow A/B + cost-model agreement.  The model's
            # chosen flow (given the row's workload hint) must match the
            # measured stream/sort winner on every sweep row where the
            # measured gap is material (≥ 2× — inside that band XLA:CPU's
            # single-shot vs chunked-scan lowerings differ by more than
            # any analytic model resolves, and either choice costs < 2×).
            mr_sort = MapReduce(app, flow="sort", n_pairs_hint=n_pairs)
            t_sort = time_fn(lambda x: mr_sort.run(x).counts, items, iters=5)
            winner = "sort" if t_sort < t_c else "stream"
            # the model's verdict, from the already-derived spec (a fresh
            # MapReduce would re-pay derivation + validation per row)
            chosen = flow_cost_report(app, mr_sort.plan.spec,
                                      n_pairs).chosen
            margin = max(t_sort, t_c) / max(min(t_sort, t_c), 1e-9)
            if margin >= 2.0:
                assert chosen == winner, (
                    f"cost model chose {chosen} but measured winner at "
                    f"K={K}, N={n_pairs} is {winner} by {margin:.1f}x "
                    f"(stream={t_c * 1e6:.0f}us sort={t_sort * 1e6:.0f}us)")
                verdict = "agree=ok"
            else:
                verdict = (f"agree={'ok' if chosen == winner else 'close'}"
                           f" (margin {margin:.2f}x < 2x, not gated)")
            print(row(f"flow_sweep_K{K}_N{n_pairs}_sort", t_sort * 1e6,
                      f"stream={t_c * 1e6:.1f}us winner={winner} "
                      f"model={chosen} {verdict}"))

        # large-K: assert the one-hot path + bytes ordering, and A/B the
        # scatter fallback + key-blocked Pallas kernel on the small config
        if K == BIG_K:
            n_chk = pair_grid[0]
            toks = rng.integers(0, K, size=(n_chk // 8, 8)).astype(np.int32)
            app = make_app(K, 8)
            items = jnp.asarray(toks)
            mr_s = MapReduce(app)
            b = _check_large_k(app, items, mr_s)
            print(row(f"flow_sweep_K{K}_stream_bytes", b["stream"],
                      f"combine={b['combine']:.0f} reduce={b['reduce']:.0f} "
                      "ordering=ok"))

            spec = mr_s.plan.spec
            fold_scatter = jax.jit(lambda x: eng.run_local_stream(
                app, spec, x, chunk_pairs=mr_s.stream_chunk_pairs,
                fold_mode="scatter")[2])
            t_sc = time_fn(fold_scatter, items, iters=5)
            t_oh = time_fn(lambda x: mr_s.run(x).counts, items, iters=5)
            print(row(f"flow_sweep_K{K}_scatterAB", t_sc * 1e6,
                      f"onehot={t_oh * 1e6:.1f}us "
                      f"onehot_pays={t_oh / t_sc:.1f}x_compute_on_cpu "
                      f"bytes_win={b['reduce'] / max(b['stream'], 1):.0f}x"))

            # float holders engage the fused Pallas fold kernel, whose
            # key-block grid axis is sized against the VMEM model
            appf = make_app(K, 8, jnp.float32)
            mr_k = MapReduce(appf, use_kernels=True)
            tk = mr_k.tiling
            assert tk.mode == "additive" and tk.blocked, (
                "kernel path should key-block at K=32768")
            res_k = mr_k.run(items)
            want = np.bincount(toks.reshape(-1), minlength=K)
            np.testing.assert_array_equal(np.asarray(res_k.values), want)
            t_k = time_fn(lambda x: mr_k.run(x).counts, items, iters=3)
            print(row(f"flow_sweep_K{K}_kernel_blocked", t_k * 1e6,
                      tk.describe()))


def crossover():
    """The PR 3 headline rows: the sort flow's measured crossover at BIG_K.

    Asserted: sort beats the one-hot stream fold AND the combine/reduce
    flows wall-clock by a wide margin; the model bytes chain
    ``sort ≤ combine < reduce`` holds; the cost model picks sort; and the
    sort flow stays in the serialized scatter fold's wall-clock class
    (≤ 6× — on XLA:CPU the scatter loop's per-pair constant matches the
    comparator sort's, and the measured ratio swings 0.4×–2.4× run-to-run
    on a shared box with occasional tail spikes, so the class bound needs
    that headroom; the scatter
    meanwhile loses the counted-bytes axis ~25×, and the TPU radix kernel
    path is where the partition goes VMEM-resident).
    """
    rng = np.random.default_rng(1)
    K, N = BIG_K, CROSS_N
    toks = rng.integers(0, K, size=(N // 8, 8)).astype(np.int32)
    items = jnp.asarray(toks)
    app = make_app(K, 8, jnp.float32)

    mr_sort = MapReduce(app, flow="sort", n_pairs_hint=N)
    mr_stream = MapReduce(app, flow="stream")
    mr_reduce = MapReduce(app, flow="reduce")
    want = np.bincount(toks.reshape(-1), minlength=K)
    np.testing.assert_allclose(np.asarray(mr_sort.run(items).values), want)

    t_sort = time_fn(lambda x: mr_sort.run(x).counts, items, iters=7)
    t_oh = time_fn(lambda x: mr_stream.run(x).counts, items, iters=3)
    t_red = time_fn(lambda x: mr_reduce.run(x).counts, items, iters=3)
    spec = mr_stream.plan.spec
    fold_scatter = jax.jit(lambda x: eng.run_local_stream(
        app, spec, x, chunk_pairs=mr_stream.stream_chunk_pairs,
        fold_mode="scatter")[2])
    t_sc = time_fn(fold_scatter, items, iters=7)

    assert t_sort < t_oh, (
        f"sort flow must beat the one-hot fold at K={K}: "
        f"sort={t_sort * 1e6:.0f}us onehot={t_oh * 1e6:.0f}us")
    assert t_sort < t_red, (
        f"sort flow must beat the reduce flow at K={K}")
    # class bound, not a ratio claim: the measured ratio swings 0.4×–2.4×
    # run-to-run on a shared box with occasional tail spikes past 4×, so
    # the gate needs that headroom (median ≈ 2×)
    assert t_sort <= 6.0 * t_sc, (
        f"sort flow left the scatter fold's wall-clock class: "
        f"sort={t_sort * 1e6:.0f}us scatter={t_sc * 1e6:.0f}us")
    chosen = flow_cost_report(app, mr_sort.plan.spec, N).chosen
    assert chosen == "sort", f"cost model chose {chosen} at the crossover"

    print(row(f"flow_sweep_K{K}_crossover", t_sort * 1e6,
              f"onehot={t_oh * 1e6:.1f}us reduce={t_red * 1e6:.1f}us "
              f"scatterAB={t_sc * 1e6:.1f}us "
              f"beats_onehot={t_oh / t_sort:.0f}x "
              f"sort_vs_scatter={t_sc / t_sort:.2f}x model={chosen}"))

    # bytes: the analytic chain is asserted (kernel/fused lowerings, the
    # same assumption every flow model makes); the measured XLA:CPU number
    # is reported next to it — the pure-JAX densify pays the counted
    # scatter loop, exactly like the scatterAB row it replaces.
    value_bytes = 4
    mb = {f: roofline.mapreduce_flow_bytes(
        f, n_pairs=N, key_space=K, value_bytes=value_bytes,
        chunk_pairs=mr_sort.stream_chunk_pairs, max_values_per_key=8)
        for f in ("sort", "combine", "reduce")}
    assert mb["sort"] <= mb["combine"] < mb["reduce"], mb
    measured = _flow_bytes(mr_sort, items)
    print(row(f"flow_sweep_K{K}_sort_bytes", mb["sort"],
              f"model combine={mb['combine']:.0f} reduce={mb['reduce']:.0f} "
              f"ordering=ok measured_cpu={measured:.0f} "
              f"(pure-JAX densify pays the counted scatter loop; the radix "
              f"kernel keeps the partition VMEM-resident)"))


#: the multi-pass regime: one million keys, the ISSUE 4 acceptance point.
HUGE_K = 1 << 20
#: pairs per chunk of the headline huge-K row.
HUGE_N = 4096


def crossover_big():
    """The PR 4 headline rows: K=1M, where the hierarchy carries the flow.

    Asserted: the multi-pass sort flow beats the one-hot stream fold
    wall-clock (measured ~670× on this container — the one-hot fold pays
    the O(N·K) sweep at K=1M); the model bytes chain ``sort ≤ combine <
    reduce`` holds; ``flow="auto"`` with the workload hint picks sort via
    the extended cost model; the tiling records two hierarchy levels and
    two packed-sort passes; and at the default 16k chunk the multi-pass
    radix sort beats the forced single-pass two-key comparator sort both
    sort-only (~4.5×) and flow-level (~1.3× — the O(K) table merge is
    shared).  The kernel hierarchical pipeline is parity-checked in
    interpret mode (timing reported as info, not gated: interpret mode
    executes kernel bodies in Python).
    """
    rng = np.random.default_rng(2)
    K, N = HUGE_K, HUGE_N
    toks = rng.integers(0, K, size=(N // 8, 8)).astype(np.int32)
    items = jnp.asarray(toks)
    app = make_app(K, 8, jnp.float32)
    want = np.bincount(toks.reshape(-1), minlength=K)

    mr_sort = MapReduce(app, flow="sort", n_pairs_hint=N)
    t = mr_sort.tiling
    assert len(t.level_fanouts) == 2 and t.sort_passes == 2, (
        f"K=1M must engage the hierarchy: {t.describe()}")
    np.testing.assert_allclose(np.asarray(mr_sort.run(items).values), want)
    t_sort = time_fn(lambda x: mr_sort.run(x).counts, items, iters=7)

    mr_stream = MapReduce(app, flow="stream")
    t_oh = time_fn(lambda x: mr_stream.run(x).counts, items,
                   warmup=1, iters=2)
    assert t_sort * 10 < t_oh, (
        f"multi-pass sort flow must beat the one-hot fold at K={K}: "
        f"sort={t_sort * 1e6:.0f}us onehot={t_oh * 1e6:.0f}us")
    assert MapReduce(app, n_pairs_hint=N).plan.flow == "sort", (
        "flow='auto' with the hint must pick sort at K=1M")
    chosen = flow_cost_report(app, mr_sort.plan.spec, N).chosen
    assert chosen == "sort", f"cost model chose {chosen} at K=1M"
    print(row(f"flow_sweep_K{K}_crossover", t_sort * 1e6,
              f"onehot={t_oh * 1e6:.0f}us beats_onehot={t_oh / t_sort:.0f}x "
              f"model={chosen} {t.describe()}"))

    # forced single-level A/B: the two-key comparator sort the multi-pass
    # radix replaces, at the default 16k chunk where the sort term matters
    N2 = eng.DEFAULT_SORT_CHUNK_PAIRS
    toks2 = rng.integers(0, K, size=(N2 // 8, 8)).astype(np.int32)
    items2 = jnp.asarray(toks2)
    mr2 = MapReduce(app, flow="sort", n_pairs_hint=N2)
    spec = mr2.plan.spec
    t_multi = time_fn(lambda x: mr2.run(x).counts, items2, iters=7)
    single = jax.jit(lambda x: eng.run_local_sort(
        app, spec, x, chunk_pairs=mr2.stream_chunk_pairs,
        sort_impl="two_key")[2])
    t_single = time_fn(single, items2, iters=7)
    from repro.core import collector as col
    keys_only = jnp.asarray(rng.integers(0, K, N2).astype(np.int32))
    t_sr = time_fn(jax.jit(lambda x: col.stable_sort_by_key(
        x, K, impl="radix")[0]), keys_only, iters=10)
    t_st = time_fn(jax.jit(lambda x: col.stable_sort_by_key(
        x, K, impl="two_key")[0]), keys_only, iters=10)
    # sort-only is the decisive A/B (measured ~3–4.5× across runs); the
    # flow-level numbers share the dominant O(K) table merge, so that
    # ratio swings with scheduler noise (0.9×–1.4× run-to-run) — gate it
    # as a class bound only
    assert t_sr * 1.5 < t_st, (
        f"multi-pass radix sort must beat the two-key comparator sort: "
        f"radix={t_sr * 1e6:.0f}us two_key={t_st * 1e6:.0f}us")
    assert t_multi < t_single * 1.5, (
        f"hierarchical sort flow left the single-level class: "
        f"multi={t_multi * 1e6:.0f}us single={t_single * 1e6:.0f}us")
    print(row(f"flow_sweep_K{K}_single_level_AB", t_multi * 1e6,
              f"forced_two_key={t_single * 1e6:.0f}us "
              f"flow_gain={t_single / t_multi:.2f}x "
              f"sort_only: radix={t_sr * 1e6:.0f}us "
              f"two_key={t_st * 1e6:.0f}us ({t_st / t_sr:.2f}x)"))

    # model bytes chain under the kernel-lowering assumption every flow
    # model makes (sort_levels=1: the hierarchical partition's inner passes
    # stay in fast memory, like the single-level partition and the fused
    # one-hot); the pure-JAX multi-pass pays (levels-1)·2N int32 extra —
    # reported next to the chain
    mb = {f: roofline.mapreduce_flow_bytes(
        f, n_pairs=N, key_space=K, value_bytes=4,
        chunk_pairs=mr_sort.stream_chunk_pairs, max_values_per_key=8)
        for f in ("sort", "combine", "reduce")}
    assert mb["sort"] <= mb["combine"] < mb["reduce"], mb
    mb_jax = roofline.mapreduce_flow_bytes(
        "sort", n_pairs=N, key_space=K, value_bytes=4,
        chunk_pairs=mr_sort.stream_chunk_pairs, max_values_per_key=8,
        sort_levels=t.sort_passes)
    measured = _flow_bytes(mr_sort, items)
    print(row(f"flow_sweep_K{K}_sort_bytes", mb["sort"],
              f"model combine={mb['combine']:.0f} reduce={mb['reduce']:.0f} "
              f"ordering=ok purejax_multipass={mb_jax:.0f} "
              f"measured_cpu={measured:.0f}"))

    # kernel hierarchical pipeline: interpret-mode parity (info row)
    mr_k = MapReduce(app, flow="sort", use_kernels=True, n_pairs_hint=N)
    np.testing.assert_allclose(np.asarray(mr_k.run(items).values), want)
    print(row(f"flow_sweep_K{K}_kernel_hierarchy", 0.0,
              f"parity=ok {mr_k.tiling.describe()} (interpret mode, "
              f"not timed)"))


#: key space of the skew rows (big enough that zipf's heavy head and long
#: tail land in different fixed-width ranges).
SKEW_K = 8192
#: shard count the skew rows drive the mesh-less resilient path at.
SKEW_S = 8


def skew_bench():
    """The PR 9 headline rows: skew-adaptive shuffle planning.

    A Zipf(1.1) key stream is driven through the mesh-less resilient sort
    flow on 8 shards with ``ShuffleOptions(skew="auto")``: the sampled key
    histogram (``core/skew.py``) derives balanced range boundaries, splits
    the hot head keys across shards and sizes the capacity envelope to the
    sampled p-max destination load.  Gated: the zipf row stays within 1.5×
    of the uniform row's wall-clock, raises ZERO shuffle-overflow
    ``LoweringFallbackWarning``s, and both rows are bitwise-identical to
    the single-host oracle (the uniform row snaps to the identity plan, so
    it IS the legacy fixed-width arithmetic).
    """
    rng = np.random.default_rng(3)
    K, S = SKEW_K, SKEW_S
    # floor at 8k pairs: below ~1k pairs/shard the rows time host dispatch,
    # not shuffle behaviour, and the ratio gate drowns in scheduler jitter
    N = max(1 << 13, int((1 << 14) * bench_scale()))
    app = make_app(K, max(4096, N))
    opts = ExecutionOptions(num_hosts=S, num_shards=S,
                            shuffle=ShuffleOptions(skew="auto"))

    uni = rng.integers(0, K, size=(N // 8, 8)).astype(np.int32)
    zpf = (rng.zipf(1.1, size=(N // 8, 8)) % K).astype(np.int32)

    results = {}
    for name, toks in (("uniform", uni), ("zipf", zpf)):
        items = jnp.asarray(toks)
        mr = MapReduce(app, flow="sort", cache=False)
        want = np.bincount(toks.reshape(-1), minlength=K)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            res = mr.run_resilient(items, options=opts)
        bad = [w for w in caught
               if issubclass(w.category, LoweringFallbackWarning)]
        assert not bad, (
            f"skew row '{name}' raised overflow/fallback warnings: "
            f"{[str(w.message) for w in bad]}")
        np.testing.assert_array_equal(np.asarray(res.values), want)
        results[name] = (mr, items, res)

    mr_u, it_u, res_u = results["uniform"]
    mr_z, it_z, res_z = results["zipf"]

    # interleave the two rows call-by-call: machine-load drift over the
    # measurement window then hits both rows alike and cancels out of the
    # ratio, which is what the gate scores
    for _ in range(2):
        mr_u.run_resilient(it_u, options=opts)
        mr_z.run_resilient(it_z, options=opts)
    tus, tzs = [], []
    for _ in range(11):
        t0 = time.perf_counter()
        mr_u.run_resilient(it_u, options=opts)
        tus.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        mr_z.run_resilient(it_z, options=opts)
        tzs.append(time.perf_counter() - t0)
    t_u = float(np.median(tus))
    t_z = float(np.median(tzs))
    plan_lines = tuple(res_z.recovery.skew_plan)
    assert plan_lines, "zipf row must engage the skew planner"
    assert not tuple(res_u.recovery.skew_plan), (
        "uniform row must snap to the identity plan (legacy arithmetic)")
    assert t_z <= 1.5 * t_u, (
        f"zipf row left the uniform row's wall-clock class: "
        f"zipf={t_z * 1e6:.0f}us uniform={t_u * 1e6:.0f}us "
        f"({t_z / t_u:.2f}x > 1.5x)")
    print(row("flow_sweep_skew_sort_uniform", t_u * 1e6,
              f"S={S} K={K} N={N} plan=identity-snap (bitwise-legacy)"))
    print(row("flow_sweep_skew_sort_zipf", t_z * 1e6,
              f"uniform={t_u * 1e6:.1f}us ratio={t_z / t_u:.2f}x "
              f"(gate <=1.5x) overflow_warnings=0 {'; '.join(plan_lines)}"))


#: key space of the wire rows: span = K/S = 512 at 16 hosts, so the delta
#: codec's range residuals pack to 10 bits against 32-bit raw keys.
WIRE_K = 8192
#: default fake-host count of the wire rows (--hosts overrides).
WIRE_S = 16


def wire_bench(hosts: int | None = None):
    """The PR 10 headline rows: the compressed shuffle wire.

    A SORTED Zipf(1.1) key stream (each shard holds a contiguous key
    range — the worst case for per-destination bucket balance, the best
    case for a columnar wire) drives the mesh-less resilient sort flow on
    16 fake hosts with an int16-value app, raw vs delta codec.  Gated:

    * both rows bitwise-equal each other AND the single-host oracle
      (delta is lossless by construction — ``distributed/wire.py``);
    * measured wire bytes/shard under delta <= 0.6x raw (the 10-bit key
      residuals vs 32-bit keys do the work; values ride unchanged);
    * the cost model's wire term equals the MEASURED bytes exactly
      (``roofline.shuffle_wire_bytes`` and the real encoded tree are the
      same arithmetic — asserted, not modeled twice).
    """
    S = hosts or WIRE_S
    K = WIRE_K
    rng = np.random.default_rng(3)
    # same floor rationale as skew_bench: keep >=1k pairs/shard in play
    N = max(1 << 13, int((1 << 14) * bench_scale()))
    N -= N % (8 * S)
    keys = np.sort((rng.zipf(1.1, size=N) % K).astype(np.int32))
    items = jnp.asarray(keys.reshape(-1, 8))
    # sorted keys concentrate each shard's pairs on few destinations:
    # provision the full per-shard pair count so neither codec overflows
    per_pairs = (N // 8 // S) * 8

    app = make_app(K, max(4096, N), dtype=jnp.int16)
    app.map = lambda item, emit: emit(item, (item % 1000).astype(jnp.int16))
    app.reduce = lambda k, v, c: jnp.max(v)

    def opts(codec):
        return ExecutionOptions(
            num_hosts=S, num_shards=S,
            shuffle=ShuffleOptions(wire=codec, capacity=per_pairs))

    want = np.full(K, np.iinfo(np.int16).min, np.int64)
    np.maximum.at(want, keys, keys % 1000)
    cnt = np.bincount(keys, minlength=K)
    results = {}
    for codec in ("raw", "delta"):
        mr = MapReduce(app, flow="sort", cache=False)
        res = mr.run_resilient(items, options=opts(codec))
        got = np.asarray(res.values, np.int64)
        np.testing.assert_array_equal(np.asarray(res.counts), cnt)
        np.testing.assert_array_equal(got[cnt > 0], want[cnt > 0])
        results[codec] = (mr, res)
    np.testing.assert_array_equal(
        np.asarray(results["raw"][1].values),
        np.asarray(results["delta"][1].values))

    # measured wire bytes: encode shard 0's REAL pair stream through the
    # wire layer and count the tree's bytes (== encoded_nbytes, asserted)
    from repro.distributed import wire as wirelib
    stream = eng.map_phase(app, items[: items.shape[0] // S])
    bytes_shard = {}
    for codec in ("raw", "delta"):
        fmt = wirelib.wire_format(
            key_space=K, num_shards=S, n_pairs=stream.keys.shape[0],
            value_avals=stream.values, codec=codec, capacity=per_pairs)
        sk, sv, overflow = wirelib.bucketize(fmt, stream)
        assert int(overflow) == 0, f"wire row '{codec}' overflowed"
        measured = wirelib.tree_nbytes(wirelib.encode(fmt, sk, sv))
        assert measured == wirelib.encoded_nbytes(fmt)
        bytes_shard[codec] = measured * (S - 1) / S
        model = roofline.shuffle_wire_bytes(
            codec, n_pairs=stream.keys.shape[0], key_space=K, num_shards=S,
            value_bytes=2, value_dtype="int16", capacity=per_pairs)
        assert model == bytes_shard[codec], (
            f"cost-model wire bytes diverged from measured for '{codec}': "
            f"model={model} measured={bytes_shard[codec]}")
    ratio = bytes_shard["delta"] / bytes_shard["raw"]
    assert ratio <= 0.6, (
        f"delta wire bytes left the gate: {bytes_shard['delta']:.0f}B "
        f"vs raw {bytes_shard['raw']:.0f}B ({ratio:.3f}x > 0.6x)")

    # interleave raw/delta call-by-call (same drift-cancellation argument
    # as skew_bench: the ratio is what the derived column reports)
    mr_r, _ = results["raw"]
    mr_d, _ = results["delta"]
    for _ in range(2):
        mr_r.run_resilient(items, options=opts("raw"))
        mr_d.run_resilient(items, options=opts("delta"))
    trs, tds = [], []
    for _ in range(11):
        t0 = time.perf_counter()
        mr_r.run_resilient(items, options=opts("raw"))
        trs.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        mr_d.run_resilient(items, options=opts("delta"))
        tds.append(time.perf_counter() - t0)
    t_r = float(np.median(trs))
    t_d = float(np.median(tds))
    assert t_d <= 3.0 * t_r, (
        f"delta row left the raw row's wall-clock class: "
        f"delta={t_d * 1e6:.0f}us raw={t_r * 1e6:.0f}us "
        f"({t_d / t_r:.2f}x > 3x)")
    print(row(f"flow_sweep_wire_sort_raw_h{S}", t_r * 1e6,
              f"S={S} K={K} N={N} sorted-zipf codec=raw"))
    print(row(f"flow_sweep_wire_sort_delta_h{S}", t_d * 1e6,
              f"raw={t_r * 1e6:.1f}us ratio={t_d / t_r:.2f}x "
              f"(class gate <=3x) bitwise=ok"))
    print(row(f"flow_sweep_wire_bytes_delta_h{S}", bytes_shard["delta"],
              f"raw={bytes_shard['raw']:.0f}B ratio={ratio:.3f}x "
              f"(gate <=0.6x) model=exact int16-values"))


def main():
    sweep()
    crossover()
    skew_bench()
    wire_bench()


if __name__ == "__main__":
    import argparse
    import contextlib
    import io
    import json

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--crossover", action="store_true",
                    help="run only the K=32768 sort-flow crossover rows "
                         "(the CI smoke step)")
    ap.add_argument("--big", action="store_true",
                    help="add the K=1M multi-pass crossover rows (the "
                         "nightly stress job)")
    ap.add_argument("--skew", action="store_true",
                    help="run only the skew-adaptive shuffle rows (uniform "
                         "vs Zipf(1.1) on the resilient sort flow)")
    ap.add_argument("--wire", action="store_true",
                    help="run only the compressed-wire rows (raw vs delta "
                         "codec on the sorted-Zipf resilient sort flow)")
    ap.add_argument("--hosts", type=int, default=None, metavar="S",
                    help=f"fake-host count for the --wire rows "
                         f"(default {WIRE_S})")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write parsed rows as a BENCH_*.json artifact "
                         "(compare.py-compatible)")
    args = ap.parse_args()

    buf = io.StringIO()

    class _Tee(io.TextIOBase):
        def write(self, s):
            buf.write(s)
            return sys.__stdout__.write(s)

    print("name,us_per_call,derived")
    with contextlib.redirect_stdout(_Tee()):
        if args.crossover or args.big or args.skew or args.wire:
            if args.crossover:
                crossover()
            if args.big:
                crossover_big()
            if args.skew:
                skew_bench()
            if args.wire:
                wire_bench(hosts=args.hosts)
        else:
            main()
    if args.json:
        from benchmarks.common import parse_rows

        mode = "+".join([m for m, on in (("crossover", args.crossover),
                                         ("big", args.big),
                                         ("skew", args.skew),
                                         ("wire", args.wire)) if on]) or "full"
        with open(args.json, "w") as f:
            json.dump({"scale": bench_scale(), "preset": mode,
                       "rows": parse_rows(buf.getvalue()), "failures": []},
                      f, indent=2)
        print(f"# wrote {args.json}")
