"""Paper Figs 6/7: the 7 Phoenix benchmarks, reduce flow vs combine flow.

The paper's claim: the semantic-aware optimizer speeds MR4J by up to 2.0x,
with String Match as the exception (overheads not amortized).  We report the
same relative quantity: speedup = t(reduce flow) / t(combine flow), with the
combiner DERIVED by the optimizer in every case (strategy column).
"""

from __future__ import annotations

import numpy as np

from benchmarks import apps
from benchmarks.common import bench_scale, row, time_fn
from repro.core import MapReduce


def run_one(name: str, rng, iters: int = 10):
    app, items = apps.build(name, rng, scale=bench_scale())
    mr_c = MapReduce(app, flow="auto")
    assert mr_c.plan.optimized, f"{name}: optimizer failed: {mr_c.plan.reason}"
    mr_r = MapReduce(app, flow="reduce")

    # correctness cross-check before timing
    rc = mr_c.run(items)
    rr = mr_r.run(items)
    cm = np.asarray(rc.counts)
    mask = cm > 0
    vc = np.asarray(rc.values, np.float64)
    vr = np.asarray(rr.values, np.float64)
    assert np.array_equal(cm, np.asarray(rr.counts)), name
    assert np.allclose(vc[mask], vr[mask], rtol=1e-3, atol=1e-3), name

    t_c = time_fn(lambda x: mr_c.run(x).counts, items, iters=iters)
    t_r = time_fn(lambda x: mr_r.run(x).counts, items, iters=iters)
    return {
        "bench": name,
        "t_reduce_us": t_r * 1e6,
        "t_combine_us": t_c * 1e6,
        "speedup": t_r / t_c,
        "strategy": mr_c.plan.derivation.strategy,
    }


def wordcount_end_to_end(rng, iters: int = 10):
    """End-to-end WC with a realistic map phase (synthetic tokenizer cost).

    The paper's 2.0x is an END-TO-END number: its map phase (regex
    tokenization) is roughly half the runtime, so even an infinitely fast
    collector caps at ~2x (Amdahl).  Here the map hashes every token through
    24 integer rounds (≈ tokenizer cost), making map ≈ 50% of the baseline
    step, then we measure both flows end to end.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import MapReduceApp

    n_tok = max(4096, int((1 << 16) * bench_scale()) // 16 * 16)
    toks, vocab = __import__("repro.data.datasets", fromlist=["d"]).\
        wordcount_data(rng, tokens=n_tok, vocab=4096)

    class WCWork(MapReduceApp):
        key_space = vocab
        value_aval = jax.ShapeDtypeStruct((), jnp.int32)
        emit_capacity = 16
        max_values_per_key = 16384

        def map(self, window, emit):
            h = window.astype(jnp.uint32)
            for _ in range(24):  # tokenizer-cost stand-in
                h = h * jnp.uint32(1103515245) + jnp.uint32(12345)
                h = h ^ (h >> 13)
            emit((h % jnp.uint32(vocab)).astype(jnp.int32),
                 jnp.ones_like(window))

        def reduce(self, key, values, count):
            return jnp.sum(values)

    items = jnp.asarray(toks.reshape(-1, 16))
    mr_c = MapReduce(WCWork(), flow="auto")
    mr_r = MapReduce(WCWork(), flow="reduce")
    t_c = time_fn(lambda x: mr_c.run(x).counts, items, iters=iters)
    t_r = time_fn(lambda x: mr_r.run(x).counts, items, iters=iters)
    return t_r, t_c


def main(iters: int | None = None):
    rng = np.random.default_rng(0)
    if iters is None:
        iters = 3 if bench_scale() < 1 else 10
    results = [run_one(n, rng, iters) for n in apps.ALL]
    print("# paper Fig 7: per-benchmark speedup of the optimized "
          "(combine) flow over the baseline (reduce) flow")
    for r in results:
        print(row(f"phoenix_{r['bench']}_reduce_flow", r["t_reduce_us"]))
        print(row(f"phoenix_{r['bench']}_combine_flow", r["t_combine_us"],
                  f"speedup={r['speedup']:.2f}x strategy={r['strategy']}"))
    best = max(r["speedup"] for r in results)
    sm = next(r for r in results if r["bench"] == "SM")
    print(row("phoenix_best_collector_speedup", 0.0,
              f"{best:.2f}x (collector path only; see Amdahl rows)"))
    print(row("phoenix_SM_speedup", 0.0,
              f"{sm['speedup']:.2f}x (paper: SM is the regression case)"))

    # END-TO-END with a real map cost.  NOTE: our baseline collector is
    # architecturally slower than the JVM's ragged lists (dense windows +
    # sort), so map work stays a small share of the BASELINE here and the
    # e2e ratio still reflects the collector gap; the paper-comparable
    # number is the Amdahl projection at the paper's ~50% map share below.
    t_r, t_c = wordcount_end_to_end(rng, iters)
    map_share_opt = 1.0 - 169.5 / max(t_c * 1e6, 1)  # map share post-opt
    print(row("phoenix_WC_end_to_end_reduce", t_r * 1e6))
    print(row("phoenix_WC_end_to_end_combine", t_c * 1e6,
              f"speedup={t_r / t_c:.2f}x (map is already "
              f"~{100 * max(map_share_opt, 0):.0f}% of the OPTIMIZED step "
              "-> further collector gains capped, per the paper's Amdahl "
              "argument)"))
    # Amdahl projection at the paper's ~50% map share, from collector ratios
    for r in results:
        s = r["speedup"]
        proj = 1.0 / (0.5 + 0.5 / s)
        r["amdahl_projected"] = proj
    wc = next(r for r in results if r["bench"] == "WC")
    print(row("phoenix_WC_amdahl_projected_e2e", 0.0,
              f"{wc['amdahl_projected']:.2f}x at the paper's 50% map share "
              "— reproduces the paper's 2.0x ceiling"))
    return results


if __name__ == "__main__":
    main()
