import os
import sys

import pytest

# allow running without PYTHONPATH=src
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ---------------------------------------------------------------------------
# CI flow×lowering matrix overrides
#
# The `flow-matrix` CI job runs the core + integration suites across every
# execution flow and both lowerings so each flow's path is exercised on
# every PR, not only the default:
#
#   REPRO_TEST_FLOW=stream|sort|combine|reduce
#       resolves flow="auto" MapReduce constructions to the given flow.
#       Only the AUTO default is overridden — tests that force a specific
#       flow keep it, and apps whose combiner cannot run the forced flow
#       (derivation failure) silently fall back to "auto" so
#       reduce-only workloads still pass.  Tests that assert the auto
#       resolution itself skip under the override (they declare it).
#   REPRO_TEST_KERNELS=1
#       flips the use_kernels default to True (combine with
#       JAX_PALLAS_INTERPRET=1 to exercise the Pallas kernel lowerings).
#   REPRO_TEST_SKEW=zipf
#       flips the ShuffleOptions.skew default to "auto", so every
#       distributed/resilient run in the suite goes through the sampled-
#       histogram shuffle planner (bitwise-parity guarantees make this a
#       pure routing change).  Tests that assert the fixed-width shuffle
#       arithmetic itself mark themselves `fixed_shuffle` and skip.
#   REPRO_TEST_WIRE=delta
#       flips the ShuffleOptions.wire default, so every distributed/
#       resilient shuffle encodes its all-to-all + checkpointed partials
#       under the given wire codec (delta is lossless and bitwise —
#       another pure routing change).  Tests that assert the raw wire
#       layout itself mark themselves `raw_wire` and skip.
# ---------------------------------------------------------------------------

def _env_override(name: str) -> str | None:
    """Matrix override value, with the ci.yml "off" default (the matrix
    sets ``REPRO_TEST_*: ${{ matrix.x || 'off' }}``) parsed as absent."""
    v = os.environ.get(name, "").strip().lower()
    return None if v in ("", "off", "0", "false", "no") else v


FLOW_OVERRIDE = _env_override("REPRO_TEST_FLOW")
KERNELS_OVERRIDE = (os.environ.get("REPRO_TEST_KERNELS", "").strip().lower()
                    not in ("", "0", "false", "no"))
SKEW_OVERRIDE = _env_override("REPRO_TEST_SKEW")
WIRE_OVERRIDE = _env_override("REPRO_TEST_WIRE")


def _apply_shuffle_overrides() -> None:
    if SKEW_OVERRIDE is None and WIRE_OVERRIDE is None:
        return
    import dataclasses

    from repro.core import skew

    # flip only the DEFAULTS of the frozen options record: every field has
    # a default, so __init__.__defaults__ lines up with the field order
    fields = [f.name for f in dataclasses.fields(skew.ShuffleOptions)]
    defaults = list(skew.ShuffleOptions.__init__.__defaults__)
    if SKEW_OVERRIDE is not None:
        defaults[fields.index("skew")] = "auto"
    if WIRE_OVERRIDE is not None:
        defaults[fields.index("wire")] = WIRE_OVERRIDE
    skew.ShuffleOptions.__init__.__defaults__ = tuple(defaults)

    # ExecutionOptions(shuffle=None) must also route through the planner/
    # codec: materialize the overridden record where None would have kept
    # the legacy fixed-width arithmetic / raw wire
    from repro.core import api

    orig_post = api.ExecutionOptions.__post_init__

    def patched_post(self):
        orig_post(self)
        if self.shuffle is None:
            object.__setattr__(self, "shuffle", skew.ShuffleOptions())

    api.ExecutionOptions.__post_init__ = patched_post


def _apply_matrix_overrides() -> None:
    _apply_shuffle_overrides()
    if FLOW_OVERRIDE is None and not KERNELS_OVERRIDE:
        return
    from repro.core import api

    orig_init = api.MapReduce.__init__

    def patched(self, app, *, flow="auto", **kw):
        # flip only the DEFAULTS: an explicit use_kernels=False (an A/B
        # contrast leg) or a forced flow keeps what the test asked for
        if KERNELS_OVERRIDE and "use_kernels" not in kw:
            kw["use_kernels"] = True
        if FLOW_OVERRIDE is not None and flow == "auto":
            try:
                orig_init(self, app, flow=FLOW_OVERRIDE, **kw)
                return
            except ValueError:
                pass  # not derivable under the forced flow -> keep auto
        orig_init(self, app, flow=flow, **kw)

    api.MapReduce.__init__ = patched


_apply_matrix_overrides()


@pytest.fixture
def matrix_flows():
    """Flow list for tests that iterate execution flows EXPLICITLY (the
    fault-injection recovery drills): under the REPRO_TEST_FLOW matrix
    override, restrict to the overridden flow so each matrix leg
    exercises its own flow instead of re-running all of them."""

    def pick(flows=("stream", "sort", "combine", "reduce")):
        if FLOW_OVERRIDE is not None and FLOW_OVERRIDE in flows:
            return (FLOW_OVERRIDE,)
        return tuple(flows)

    return pick


@pytest.fixture
def matrix_use_kernels():
    """True on the flow-matrix kernels leg (REPRO_TEST_KERNELS): tests
    that build engine runs directly (not through the patched MapReduce
    API) use this to put the Pallas lowerings under the same override."""
    return KERNELS_OVERRIDE


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "auto_flow: asserts how flow='auto' resolves (skipped "
        "under the REPRO_TEST_FLOW matrix override)")
    config.addinivalue_line(
        "markers", "purejax_lowering: measures the pure-JAX default "
        "lowering's compiled profile (skipped under REPRO_TEST_KERNELS)")
    config.addinivalue_line(
        "markers", "fixed_shuffle: asserts the fixed-width shuffle "
        "arithmetic/overflow behaviour (skipped under REPRO_TEST_SKEW)")
    config.addinivalue_line(
        "markers", "raw_wire: asserts the raw wire layout / bucket bytes "
        "(skipped under REPRO_TEST_WIRE)")


def pytest_collection_modifyitems(config, items):
    """One source of truth for the matrix-override skips (the markers
    above); the override env reads live at the top of this file."""
    skip_flow = pytest.mark.skip(
        reason="asserts flow='auto' resolution; REPRO_TEST_FLOW overrides it")
    skip_kern = pytest.mark.skip(
        reason="measures the pure-JAX lowering's compiled profile; "
               "REPRO_TEST_KERNELS overrides the lowering")
    skip_skew = pytest.mark.skip(
        reason="asserts the fixed-width shuffle arithmetic; "
               "REPRO_TEST_SKEW routes through the skew planner")
    skip_wire = pytest.mark.skip(
        reason="asserts the raw wire layout; REPRO_TEST_WIRE re-encodes "
               "the shuffle wire")
    for item in items:
        if FLOW_OVERRIDE is not None and "auto_flow" in item.keywords:
            item.add_marker(skip_flow)
        if KERNELS_OVERRIDE and "purejax_lowering" in item.keywords:
            item.add_marker(skip_kern)
        if SKEW_OVERRIDE is not None and "fixed_shuffle" in item.keywords:
            item.add_marker(skip_skew)
        if WIRE_OVERRIDE is not None and "raw_wire" in item.keywords:
            item.add_marker(skip_wire)
