"""End-to-end behaviour of the reproduced system (the paper's full story):

user writes map+reduce only -> optimizer derives the combiner -> combine
flow replaces the reduce flow -> same answer, fewer intermediates -> the
same CombinerSpec drives the training substrate.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MapReduce, MapReduceApp
from repro.roofline import hlo_parser


class WordCount(MapReduceApp):
    key_space = 512
    value_aval = jax.ShapeDtypeStruct((), jnp.int32)
    emit_capacity = 8
    max_values_per_key = 1024

    def map(self, window, emit):
        emit(window, jnp.ones_like(window))

    def reduce(self, key, values, count):
        return jnp.sum(values)


def test_paper_story_end_to_end():
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 512, (128, 8)).astype(np.int32))
    want = np.bincount(np.asarray(toks).reshape(-1), minlength=512)

    # 1. the optimizer derives a combiner from unmodified user code
    mr_opt = MapReduce(WordCount(), flow="auto")
    assert mr_opt.plan.optimized
    d = mr_opt.plan.derivation
    assert d.strategy == "monoid" and d.validated

    # 2. both flows agree (the transformation is semantics-preserving)
    res_opt = mr_opt.run(toks)
    res_base = MapReduce(WordCount(), flow="reduce").run(toks)
    np.testing.assert_array_equal(np.asarray(res_opt.values), want)
    mask = want > 0
    np.testing.assert_array_equal(
        np.asarray(res_base.values)[mask], want[mask])

    # 3. the combine flow moves fewer bytes through memory (Figs 8/9)
    def flow_bytes(mr):
        c = mr.lower(toks).compile()
        return hlo_parser.analyze_text(c.as_text()).bytes_accessed

    assert flow_bytes(mr_opt) < flow_bytes(MapReduce(WordCount(),
                                                     flow="reduce"))

    # 4. the same machinery trains a model (combiner grad accumulation)
    from repro.configs import get_config
    from repro.models.registry import get_model
    from repro.training.train_step import (TrainConfig, init_train_state,
                                           make_train_step)

    cfg = get_config("llama3-8b").reduced()
    model = get_model(cfg)
    step = jax.jit(make_train_step(
        model, TrainConfig(num_microbatches=2, vocab_chunk=64,
                           warmup_steps=1, total_steps=20)))
    state = init_train_state(model, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                          cfg.vocab_size)}
    losses = []
    for _ in range(3):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] and np.isfinite(losses).all()
