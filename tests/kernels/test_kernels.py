"""Per-kernel shape/dtype sweeps, assert_allclose against the ref.py oracle.

All kernels run in interpret mode on CPU (the kernel bodies execute in
Python; BlockSpec tiling logic is exercised for real).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def _vals(shape, dtype):
    if jnp.issubdtype(dtype, jnp.integer):
        return RNG.integers(-5, 6, size=shape).astype(dtype)
    return RNG.standard_normal(shape).astype(dtype)


@pytest.mark.parametrize("n,d,k", [(16, 8, 5), (100, 16, 37), (1000, 64, 256),
                                   (17, 3, 8), (513, 128, 1024)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_onehot_combine(n, d, k, dtype):
    dtype = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    keys = RNG.integers(0, k + 1, size=n).astype(np.int32)  # incl. sentinel
    vals = jnp.asarray(_vals((n, d), np.float32), dtype)
    got = ops.onehot_combine(jnp.asarray(keys), vals, k)
    want = ref.onehot_combine(jnp.asarray(keys), vals, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("op", ["add", "max", "min"])
@pytest.mark.parametrize("n,d,k", [(50, 4, 11), (300, 16, 64), (64, 1, 3)])
def test_combine_scatter(op, n, d, k):
    keys = RNG.integers(0, k + 1, size=n).astype(np.int32)
    vals = _vals((n, d), np.float32)
    got = ops.combine_scatter(jnp.asarray(keys), jnp.asarray(vals), k, op)
    want = ref.combine_scatter(jnp.asarray(keys), jnp.asarray(vals), k, op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("op", ["add", "max"])
@pytest.mark.parametrize("n,d,k,tile", [(200, 8, 512, 64), (1000, 4, 4096, 256),
                                        (64, 16, 64, 32)])
def test_segment_reduce(op, n, d, k, tile):
    keys = np.sort(RNG.integers(0, k, size=n)).astype(np.int32)
    vals = _vals((n, d), np.float32)
    got = ops.segment_reduce(jnp.asarray(keys), jnp.asarray(vals), k, op,
                             tile_n=tile)
    want = ref.segment_reduce(jnp.asarray(keys), jnp.asarray(vals), k, op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_segment_reduce_skewed_keys():
    """One giant run + many singletons (stresses block-id prefetch)."""
    k = 2048
    keys = np.sort(np.concatenate([np.zeros(500, np.int32),
                                   RNG.integers(0, k, size=100)])).astype(np.int32)
    vals = _vals((600, 8), np.float32)
    got = ops.segment_reduce(jnp.asarray(keys), jnp.asarray(vals), k, "add")
    want = ref.segment_reduce(jnp.asarray(keys), jnp.asarray(vals), k, "add")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,h,hkv,d,s", [
    (2, 8, 2, 64, 300), (1, 4, 4, 32, 128), (3, 16, 4, 128, 1000),
    (1, 8, 1, 64, 256),  # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(b, h, hkv, d, s, dtype):
    q = jnp.asarray(_vals((b, h, d), np.float32), dtype)
    k = jnp.asarray(_vals((b, s, hkv, d), np.float32) * 0.3, dtype)
    v = jnp.asarray(_vals((b, s, hkv, d), np.float32), dtype)
    kvl = RNG.integers(1, s + 1, size=b).astype(np.int32)
    got = ops.flash_decode(q, k, v, jnp.asarray(kvl), tile_s=128)
    want = np.stack([
        ref.flash_decode(q[i], k[i], v[i], int(kvl[i])) for i in range(b)])
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got), want, rtol=tol, atol=tol)


def test_flash_decode_matches_monoid():
    """The kernel IS the attention combiner: folding KV tiles with the
    (m, l, acc) monoid gives the same answer as the fused kernel."""
    b, h, hkv, d, s, tile = 1, 2, 1, 16, 64, 16
    q = jnp.asarray(_vals((b, h, d), np.float32))
    k = jnp.asarray(_vals((b, s, hkv, d), np.float32))
    v = jnp.asarray(_vals((b, s, hkv, d), np.float32))
    kernel = ops.flash_decode(q, k, v, jnp.asarray([s], np.int32), tile_s=tile)

    # manual fold over tiles with the monoid
    scale = 1.0 / np.sqrt(d)
    qf = np.asarray(q[0], np.float64) * scale
    kf = np.repeat(np.asarray(k[0], np.float64), h // hkv, axis=1)
    vf = np.repeat(np.asarray(v[0], np.float64), h // hkv, axis=1)
    m = np.full((h,), -np.inf)
    l = np.zeros((h,))
    acc = np.zeros((h, d))
    for t0 in range(0, s, tile):
        logits = np.einsum("hd,thd->ht", qf, kf[t0:t0 + tile])
        m_new = np.maximum(m, logits.max(1))
        alpha = np.exp(m - m_new)
        p = np.exp(logits - m_new[:, None])
        l = l * alpha + p.sum(1)
        acc = acc * alpha[:, None] + np.einsum("ht,thd->hd", p, vf[t0:t0 + tile])
        m = m_new
    want = acc / l[:, None]
    np.testing.assert_allclose(np.asarray(kernel[0]), want, rtol=1e-4,
                               atol=1e-4)


def test_onehot_vmem_guard():
    with pytest.raises(ValueError, match="VMEM"):
        ops.onehot_combine(jnp.zeros(8, jnp.int32), jnp.zeros((8, 256)),
                           key_space=2 ** 21)


@pytest.mark.parametrize("n,d,k", [(16, 4, 5), (100, 8, 64), (513, 2, 300)])
def test_onehot_fold(n, d, k):
    """Streaming-chunk additive fold accumulates on top of the carry."""
    keys = RNG.integers(0, k + 1, size=n).astype(np.int32)  # incl. sentinel
    vals = jnp.asarray(_vals((n, d), np.float32))
    acc = jnp.asarray(_vals((k, d), np.float32))
    got = ops.onehot_fold(jnp.asarray(keys), vals, acc)
    want = ref.onehot_fold(jnp.asarray(keys), vals, acc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("op", ["add", "max", "min"])
@pytest.mark.parametrize("n,d,k", [(50, 4, 11), (200, 2, 37)])
def test_chunk_monoid_fold(op, n, d, k):
    """Unsorted-chunk monoid fold: carry rows for absent keys unchanged."""
    keys = RNG.integers(0, k + 1, size=n).astype(np.int32)
    vals = jnp.asarray(_vals((n, d), np.float32))
    acc = jnp.asarray(_vals((k, d), np.float32))
    got = ops.chunk_monoid_fold(jnp.asarray(keys), vals, acc, op)
    want = ref.chunk_monoid_fold(jnp.asarray(keys), vals, acc, op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_chunk_fold_chain_matches_single_shot():
    """Folding a stream chunk-by-chunk == one-shot combine (holder carry)."""
    n, d, k, chunk = 96, 4, 17, 32
    keys = RNG.integers(0, k, size=n).astype(np.int32)
    vals = _vals((n, d), np.float32)
    acc = jnp.zeros((k, d), jnp.float32)
    for t0 in range(0, n, chunk):
        acc = ops.onehot_fold(jnp.asarray(keys[t0:t0 + chunk]),
                              jnp.asarray(vals[t0:t0 + chunk]), acc)
    want = ref.onehot_combine(jnp.asarray(keys), jnp.asarray(vals), k)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_fold_kernels_empty_chunk():
    """n=0 chunks return the accumulator unchanged instead of crashing."""
    acc = jnp.asarray(_vals((9, 3), np.float32))
    got = ops.onehot_fold(jnp.zeros((0,), jnp.int32),
                          jnp.zeros((0, 3), jnp.float32), acc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(acc))
    got = ops.chunk_monoid_fold(jnp.zeros((0,), jnp.int32),
                                jnp.zeros((0, 3), jnp.float32), acc, "max")
    np.testing.assert_allclose(np.asarray(got), np.asarray(acc))


@pytest.mark.parametrize("n,k,bs,pa", [
    (64, 64, 16, 8), (200, 128, 32, 16), (300, 100, 32, 16),  # K % bs != 0
    (50, 256, 256, 16),  # single bucket
])
def test_radix_partition_matches_ref(n, k, bs, pa):
    """Two-pass histogram + bucket-scatter vs the argsort oracle: identical
    padded layout, bucket-grouped keys, stable within-bucket order."""
    keys = RNG.integers(0, k + 1, size=n).astype(np.int32)  # incl. sentinel
    vals = _vals((n, 4), np.float32)
    got_k, got_v, got_s = ops.radix_partition(
        jnp.asarray(keys), jnp.asarray(vals), k, bucket_size=bs, pad_align=pa,
        tile_n=pa)
    want_k, want_v, want_s = ref.radix_partition(
        jnp.asarray(keys), jnp.asarray(vals), k, bucket_size=bs, pad_align=pa)
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))
    np.testing.assert_array_equal(np.asarray(got_k), np.asarray(want_k))
    # value rows: only real-pair slots are contractual (pad slots carry
    # zeros in both; sentinel/trash slot contents are dropped downstream)
    real = np.asarray(want_k) < k
    np.testing.assert_allclose(np.asarray(got_v)[real],
                               np.asarray(want_v)[real], rtol=1e-6)


def test_radix_partition_bucket_invariants():
    """Every non-sentinel key lies inside its bucket's key range and every
    bucket region is pad_align-aligned."""
    n, k, bs, pa = 500, 512, 64, 32
    keys = RNG.integers(0, k, size=n).astype(np.int32)
    vals = _vals((n, 1), np.float32)
    pk, _, starts = ops.radix_partition(jnp.asarray(keys), jnp.asarray(vals),
                                        k, bucket_size=bs, pad_align=pa)
    pk, starts = np.asarray(pk), np.asarray(starts)
    assert (starts % pa == 0).all()
    for b in range(k // bs):
        lo = starts[b]
        hi = starts[b + 1] if b + 1 < len(starts) else len(pk)
        seg = pk[lo:hi]
        real = seg[seg < k]
        assert ((real >= b * bs) & (real < (b + 1) * bs)).all(), b
    got = np.sort(pk[pk < k])
    np.testing.assert_array_equal(got, np.sort(keys))


@pytest.mark.parametrize("op", ["add", "max", "min"])
@pytest.mark.parametrize("n,d,k,bs", [(100, 3, 64, 16), (333, 2, 1000, 256)])
def test_sort_segment_fold_matches_ref(op, n, d, k, bs):
    """Radix partition + segment_reduce pipeline == argsort/segment oracle,
    merged into a carried accumulator."""
    keys = RNG.integers(0, k + 1, size=n).astype(np.int32)
    vals = jnp.asarray(_vals((n, d), np.float32))
    acc = jnp.asarray(_vals((k, d), np.float32))
    got = ops.sort_segment_fold(jnp.asarray(keys), vals, acc, op,
                                bucket_size=bs)
    want = ref.sort_segment_fold(jnp.asarray(keys), vals, acc, op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_radix_partition_vmem_guard():
    with pytest.raises(ValueError, match="VMEM"):
        ops.radix_partition(jnp.zeros(1 << 16, jnp.int32),
                            jnp.zeros((1 << 16, 128), jnp.float32),
                            key_space=1 << 20, bucket_size=256)


# ---------------------------------------------------------------------------
# Multi-pass hierarchical radix partition
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,k,bs,fanouts,pa", [
    (200, 256, 16, (4, 4), 16),
    (300, 100, 8, (4, 4), 16),        # K % (bs·ΠB) != 0, cover > K
    (500, 1000, 16, (4, 4, 4), 32),   # three levels
    (64, 64, 4, (4, 4), 8),
    (333, 2000, 64, (8, 4), 16),      # non-uniform fan-outs
])
def test_radix_partition_multi_matches_single_level_oracle(n, k, bs,
                                                           fanouts, pa):
    """The hierarchical multi-pass layout is bitwise identical to the
    single-level partition at the leaf bucket (stability per level composes
    to the stable leaf grouping) — the argsort oracle covers both."""
    keys = RNG.integers(0, k + 1, size=n).astype(np.int32)  # incl. sentinel
    vals = _vals((n, 3), np.float32)
    got_k, got_v, got_s = ops.radix_partition(
        jnp.asarray(keys), jnp.asarray(vals), k, bucket_size=bs,
        fanouts=fanouts, pad_align=pa, tile_n=pa)
    want_k, want_v, want_s = ref.radix_partition(
        jnp.asarray(keys), jnp.asarray(vals), k, bucket_size=bs, pad_align=pa)
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))
    np.testing.assert_array_equal(np.asarray(got_k), np.asarray(want_k))
    real = np.asarray(want_k) < k
    np.testing.assert_allclose(np.asarray(got_v)[real],
                               np.asarray(want_v)[real], rtol=1e-6)


def test_radix_partition_multi_bucket_invariants():
    """Leaf regions of the hierarchy: every real key inside its leaf range,
    aligned region starts, nothing lost, trash slots sentinel-normalized."""
    n, k, bs, pa = 400, 512, 16, 16
    keys = RNG.integers(0, k + 1, size=n).astype(np.int32)
    vals = _vals((n, 1), np.float32)
    pk, _, starts = ops.radix_partition(
        jnp.asarray(keys), jnp.asarray(vals), k, bucket_size=bs,
        fanouts=(8, 4), pad_align=pa, tile_n=pa)
    pk, starts = np.asarray(pk), np.asarray(starts)
    assert starts.shape[0] == k // bs
    assert (starts % pa == 0).all()
    assert (pk <= k).all()  # every dropped slot carries THE sentinel
    for b in range(k // bs):
        lo = starts[b]
        hi = starts[b + 1] if b + 1 < len(starts) else len(pk)
        real = pk[lo:hi][pk[lo:hi] < k]
        assert ((real >= b * bs) & (real < (b + 1) * bs)).all(), b
    np.testing.assert_array_equal(np.sort(pk[pk < k]),
                                  np.sort(keys[keys < k]))


@pytest.mark.parametrize("op", ["add", "max", "min"])
def test_sort_segment_fold_multi_level_matches_ref(op):
    """The full hierarchical pipeline (multi-pass partition feeding
    segment_reduce leaf blocks) == the argsort/segment oracle, merged into
    a carried accumulator."""
    n, d, k = 333, 2, 3000
    keys = RNG.integers(0, k + 1, size=n).astype(np.int32)
    vals = jnp.asarray(_vals((n, d), np.float32))
    acc = jnp.asarray(_vals((k, d), np.float32))
    plan = ops.plan_radix_levels(k, d=d, max_fanout=4, leaf_cap=256)
    assert plan.levels >= 2  # the hierarchy is actually engaged
    got = ops.sort_segment_fold(jnp.asarray(keys), vals, acc, op,
                                bucket_size=plan.bucket_size,
                                fanouts=plan.fanouts)
    want = ref.sort_segment_fold(jnp.asarray(keys), vals, acc, op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_plan_radix_levels_small_keyspaces_stay_single_level():
    """The decomposition preserves the PR 3 behaviour below one sweep:
    single bucket for tiny K, one level while leaves fit the fan-out."""
    assert ops.plan_radix_levels(512).fanouts == ()
    p = ops.plan_radix_levels(32768, d=2)
    assert p.levels == 1 and p.bucket_size == 2048
    assert p.bucket_size == ops.auto_bucket_size(32768, d=2)


def test_plan_radix_levels_multi_level_and_budget():
    p = ops.plan_radix_levels(1 << 20, d=2)
    assert p.feasible and p.levels == 2
    assert all(b <= ops.MAX_RADIX_FANOUT for b in p.fanouts)
    cover = p.bucket_size
    for b in p.fanouts:
        cover *= b
    assert cover >= 1 << 20
    assert p.bucket_size <= ops.LEAF_BUCKET_CAP
    # past the level budget: infeasible is REPORTED, never silently clamped
    bad = ops.plan_radix_levels(1 << 20, d=2, max_levels=1)
    assert not bad.feasible and "max_levels=1" in bad.reason
    assert "INFEASIBLE" in bad.describe()


def test_radix_partition_multi_requires_aligned_tiles():
    with pytest.raises(ValueError, match="cover|tile_n"):
        from repro.kernels import radix_partition as rp
        rp.radix_partition_multi(
            jnp.zeros(64, jnp.int32), jnp.zeros((64, 1), jnp.float32),
            256, bucket_size=16, fanouts=(4, 4), pad_align=16, tile_n=32)


def test_fold_kernel_autoblocks_past_vmem_budget():
    """A key space whose [Tn, K] one-hot would blow VMEM is auto-partitioned
    into key blocks instead of raising; an explicitly oversized block still
    trips the guard (which accounts for the one-hot, not just the table)."""
    K = 1 << 20
    assert ops.auto_key_block(K, d=1, tile_n=512) < K
    keys = jnp.asarray(RNG.integers(0, K, 512).astype(np.int32))
    got = ops.onehot_fold(keys, jnp.ones((512, 1), jnp.float32),
                          jnp.zeros((K, 1), jnp.float32))
    want = np.zeros(K); np.add.at(want, np.asarray(keys), 1.0)
    np.testing.assert_array_equal(np.asarray(got)[:, 0], want)
    with pytest.raises(ValueError, match="VMEM"):
        ops.onehot_fold(jnp.zeros(512, jnp.int32), jnp.zeros((512, 1)),
                        jnp.zeros((K, 1)), block_k=K)
