"""Training substrate: losses agree across modes, accumulation flows agree,
optimizer sanity, schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.registry import get_model
from repro.training import losses, optim
from repro.training.grad_accum import accumulate_gradients, derive_grad_combiner

RNG = jax.random.PRNGKey(0)


def test_xent_modes_agree():
    rng = np.random.default_rng(0)
    B, S, E, V = 2, 8, 16, 100
    hidden = jnp.asarray(rng.standard_normal((B, S, E)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((V, E)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    a = losses.xent_materialize(hidden, w, labels)
    b = losses.xent_chunked(hidden, w, labels, chunk=32)
    c = losses.xent_sharded(hidden, w, labels)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-5)
    np.testing.assert_allclose(float(a), float(c), rtol=1e-5)


def test_xent_chunked_grad_matches():
    rng = np.random.default_rng(1)
    B, S, E, V = 2, 4, 8, 50
    hidden = jnp.asarray(rng.standard_normal((B, S, E)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((V, E)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    g1 = jax.grad(lambda h: losses.xent_materialize(h, w, labels))(hidden)
    g2 = jax.grad(lambda h: losses.xent_chunked(h, w, labels, chunk=16))(hidden)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4,
                               atol=1e-5)


def test_label_masking():
    rng = np.random.default_rng(2)
    B, S, E, V = 1, 6, 8, 20
    hidden = jnp.asarray(rng.standard_normal((B, S, E)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((V, E)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    mask = jnp.asarray([[1, 1, 0, 0, 1, 1]], jnp.float32)
    a = losses.xent_materialize(hidden, w, labels, mask=mask)
    # manually: loss over kept positions only
    full = losses.xent_materialize(hidden[:, [0, 1, 4, 5]], w,
                                   labels[:, [0, 1, 4, 5]])
    np.testing.assert_allclose(float(a), float(full), rtol=1e-5)


def test_grad_combiner_derivation_is_monoid():
    d = derive_grad_combiner()
    assert d.strategy == "monoid" and d.validated


def test_accumulation_flows_agree():
    """combiner flow == materialize flow == single-batch gradient."""
    cfg = get_config("llama3-8b").reduced()
    model = get_model(cfg)
    params = model.init_params(RNG)
    batch = {
        "tokens": jax.random.randint(RNG, (4, 8), 0, cfg.vocab_size),
        "labels": jax.random.randint(RNG, (4, 8), 0, cfg.vocab_size),
    }

    def loss_fn(p, b):
        return losses.lm_loss(model, p, b, mode="materialize")

    spec = derive_grad_combiner().spec
    (l0, _), g0 = accumulate_gradients(loss_fn, params, batch)
    (l1, _), g1 = accumulate_gradients(loss_fn, params, batch,
                                       num_microbatches=4, mode="combiner",
                                       spec=spec)
    (l2, _), g2 = accumulate_gradients(loss_fn, params, batch,
                                       num_microbatches=4, mode="materialize",
                                       spec=spec)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    # microbatched grads equal full-batch grads (mean loss => mean grads;
    # per-microbatch masked token counts are equal here)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_adamw_moves_params_and_clips():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    st = optim.init_opt_state(params)
    grads = {"w": jnp.full((4, 4), 100.0, jnp.float32)}  # should clip
    cfgd = optim.AdamWConfig(lr=1e-2, grad_clip=1.0)
    st2, stats = optim.adamw_update(cfgd, grads, st)
    assert float(stats["grad_norm"]) > 1.0
    assert not np.allclose(np.asarray(st2["master"]["w"]),
                           np.asarray(st["master"]["w"]))
    assert int(st2["step"]) == 1


def test_cosine_schedule():
    s = optim.cosine_schedule(jnp.int32(0), warmup=10, total=100)
    assert float(s) == 0.0
    s = optim.cosine_schedule(jnp.int32(10), warmup=10, total=100)
    assert abs(float(s) - 1.0) < 1e-6
    s_end = optim.cosine_schedule(jnp.int32(100), warmup=10, total=100,
                                  min_frac=0.1)
    assert abs(float(s_end) - 0.1) < 1e-6


def test_grad_compression_error_feedback():
    from repro.distributed.compression import ErrorFeedback, fake_quant_int8

    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((32, 32)),
                          jnp.float32)}
    res = ErrorFeedback.init(g)
    comp, res = ErrorFeedback.apply(g, res)
    # compressed + residual == original (exact decomposition)
    np.testing.assert_allclose(
        np.asarray(comp["w"] + res["w"]), np.asarray(g["w"]), rtol=1e-6)
    # quantization error is bounded by the scale
    err = np.abs(np.asarray(fake_quant_int8(g["w"]) - g["w"]))
    assert err.max() <= float(jnp.max(jnp.abs(g["w"]))) / 127 + 1e-6
