"""Quantized-collective building blocks (distributed/compression.py).

These are the primitives the ``packed`` wire codec borrows for float
value leaves (``wire.encode``'s per-destination int8 quantization), so
their error bounds are load-bearing for the shuffle layer too:

* ``quant_int8``/``dequant_int8``: elementwise error <= scale/2 with
  scale = max|x|/127 (hypothesis property), zero error at 0, exact on
  the +/-max elements up to rounding;
* ``fake_quant_int8`` is idempotent: re-quantizing a dequantized tensor
  is exact (the lattice points are fixed points);
* ``compressed_psum`` tracks the exact psum within the summed per-shard
  quantization bounds;
* ``ErrorFeedback`` telescopes: over T steps the TRANSMITTED total
  equals the true gradient total up to one step's quantization error,
  not T of them (the unbiased-in-the-limit argument).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.distributed import compression as comp

jax.config.update("jax_platform_name", "cpu")


def _bound(x):
    """The per-tensor int8 quantization half-step."""
    return max(float(np.max(np.abs(x))), 1e-12) / 127.0 / 2.0


def test_quant_roundtrip_bound_simple():
    x = jnp.asarray(np.linspace(-3.0, 5.0, 101), jnp.float32)
    q, s = comp.quant_int8(x)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    err = np.abs(np.asarray(comp.dequant_int8(q, s)) - np.asarray(x))
    assert err.max() <= _bound(x) + 1e-7


def test_quant_zero_is_exact():
    x = jnp.zeros((8,), jnp.float32)
    q, s = comp.quant_int8(x)
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.asarray(comp.dequant_int8(q, s)) == 0.0)


def test_fake_quant_idempotent():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(256), jnp.float32)
    once = comp.fake_quant_int8(x)
    twice = comp.fake_quant_int8(once)
    assert np.array_equal(np.asarray(once), np.asarray(twice))


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False


if HAVE_HYP:
    @settings(max_examples=50, deadline=None)
    @given(
        n=st.integers(1, 64),
        scale=st.floats(1e-6, 1e6),
        seed=st.integers(0, 2 ** 16),
    )
    def test_quant_roundtrip_bound_property(n, scale, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal(n) * scale, jnp.float32)
        q, s = comp.quant_int8(x)
        err = np.abs(np.asarray(comp.dequant_int8(q, s)) - np.asarray(x))
        assert err.max() <= _bound(x) * (1 + 1e-5) + 1e-12

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(1, 32),
        steps=st.integers(1, 8),
        seed=st.integers(0, 2 ** 16),
    )
    def test_error_feedback_telescopes(n, steps, seed):
        """sum_t c_t = sum_t g_t - e_T: the residual chain cancels, so
        the transmitted total is off by ONE quantization error, however
        many steps ran."""
        rng = np.random.default_rng(seed)
        grads = {"w": jnp.zeros((n,), jnp.float32)}
        res = comp.ErrorFeedback.init(grads)
        sent = np.zeros(n, np.float64)
        true = np.zeros(n, np.float64)
        last_x = np.zeros(n, np.float64)
        for _ in range(steps):
            g = {"w": jnp.asarray(rng.standard_normal(n), jnp.float32)}
            last_x = np.asarray(g["w"], np.float64) + np.asarray(
                res["w"], np.float64)
            c, res = comp.ErrorFeedback.apply(g, res)
            sent += np.asarray(c["w"], np.float64)
            true += np.asarray(g["w"], np.float64)
        # sent == true - final residual  (float32 chain, so allow eps)
        gap = np.abs(sent + np.asarray(res["w"], np.float64) - true)
        assert gap.max() <= 1e-4 * max(1.0, np.abs(true).max())
        # and the final residual is ONE step's quantization error (of the
        # last compressed input), not an accumulation over T steps
        assert np.abs(np.asarray(res["w"])).max() \
            <= _bound(last_x) * (1 + 1e-5) + 1e-12


def test_error_feedback_single_step_residual_is_quant_error():
    rng = np.random.default_rng(3)
    g = {"w": jnp.asarray(rng.standard_normal(64), jnp.float32)}
    res = comp.ErrorFeedback.init(g)
    c, res2 = comp.ErrorFeedback.apply(g, res)
    want = np.asarray(g["w"]) - np.asarray(comp.fake_quant_int8(g["w"]))
    assert np.allclose(np.asarray(res2["w"]), want, atol=1e-7)
    assert np.abs(np.asarray(res2["w"])).max() <= _bound(g["w"]) + 1e-7


def test_compressed_psum_tracks_exact_psum():
    """shard_map all-gather path: the int8-on-the-wire sum equals the
    exact psum within the sum of per-shard quantization bounds."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    n_dev = jax.local_device_count()
    if n_dev < 2:
        pytest.skip("needs >=2 devices (run under "
                    "XLA_FLAGS=--xla_force_host_platform_device_count)")
    mesh = Mesh(np.array(jax.devices()[:n_dev]), ("d",))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((n_dev, 32)), jnp.float32)

    exact = shard_map(
        lambda v: jax.lax.psum(v, "d"), mesh=mesh,
        in_specs=P("d"), out_specs=P())(x)
    # check_rep can't see through the all_gather+sum, but the result IS
    # replicated (every shard gathers the same int8+scale rows)
    approx = shard_map(
        lambda v: comp.compressed_psum(v[0], "d"), mesh=mesh,
        in_specs=P("d"), out_specs=P(), check_rep=False)(x)
    bound = sum(_bound(x[i]) for i in range(n_dev))
    assert np.abs(np.asarray(approx) - np.asarray(exact)).max() \
        <= bound + 1e-6
