"""The shuffle wire layer (distributed/wire.py).

Contracts under test:

* encode∘decode == identity for every codec on int-exact value specs —
  including skewed, empty, and capacity-boundary buckets, and hot-key
  symbols under a skew plan (hypothesis properties);
* the ``delta`` codec reproduces the RAW buckets bitwise (keys and value
  slots untouched), which is what makes every downstream flow
  bit-identical under it;
* the byte accounting (``encoded_nbytes``) matches the real encoded tree
  leaf for leaf, and the cost model's wire term equals those bytes over
  the link bandwidth;
* the resilient driver's checkpointed shard partials ARE the wire
  layer's encoding (satellite bugfix: one source of truth for the send
  buckets) — asserted bitwise against the npz trees on disk;
* a kill/restore drill under ``wire="delta"`` stays bitwise with the raw
  run, restoring compressed partials from disk;
* a checkpoint written under a DIFFERENT codec (or a foreign layout) is
  rejected at restore and the shard recomputes — never silently merged.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.core import (ExecutionOptions, MapReduce, MapReduceApp,
                        ShuffleOptions)
from repro.core import engine as eng
from repro.core import skew
from repro.core.plan import plan_execution
from repro.core import collector as col
from repro.distributed import fault as flt
from repro.distributed import wire

I32 = jnp.int32


def make_app(key_space, *, emit=4, dtype=I32):
    class App(MapReduceApp):
        pass

    app = App()
    app.key_space = key_space
    app.value_aval = jax.ShapeDtypeStruct((), dtype)
    app.max_values_per_key = 4096
    app.emit_capacity = emit
    app.map = lambda item, emit_: emit_(item, jnp.ones_like(item))
    app.reduce = lambda k, v, c: jnp.sum(v)
    return app


def make_stream(keys, values, key_space):
    return col.PairStream(jnp.asarray(keys, I32), jnp.asarray(values),
                          key_space)


def roundtrip(fmt, sk, sv):
    """Encode then decode each destination's own row — the receive side
    of a loopback all-to-all.  Returns [S, B]-shaped buckets (decode
    keeps the leading source axis)."""
    enc = wire.encode(fmt, sk, sv)
    ks, vs = [], []
    for d in range(fmt.num_shards):
        renc = jax.tree.map(lambda v, d=d: v[d:d + 1], enc)
        k, v = wire.decode(fmt, renc, d)
        ks.append(k)
        vs.append(v)
    keys = jnp.concatenate(ks)
    vals = jax.tree.map(lambda *ls: jnp.concatenate(ls), *vs)
    return keys, vals


def assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y)), (x, y)


# ---------------------------------------------------------------------------
# format resolution
# ---------------------------------------------------------------------------


def test_capacity_resolution_chain():
    assert wire.resolve_capacity(100, 4) == eng.shuffle_bucket_capacity(
        100, 4) == 50
    assert wire.resolve_capacity(100, 4, capacity=7) == 7
    plan = skew.ShufflePlan(key_space=16, num_shards=4,
                            boundaries=(0, 4, 8, 12, 16), max_dest_frac=0.9)
    assert wire.resolve_capacity(100, 4, plan=plan) == plan.capacity_for(100)
    # explicit beats the plan
    assert wire.resolve_capacity(100, 4, capacity=7, plan=plan) == 7


def test_wire_format_rejects_unknown_codec():
    with pytest.raises(ValueError, match="unknown wire codec"):
        wire.WireFormat(codec="zstd", num_shards=2, capacity=4,
                        key_space=8, lo=(0, 4), span=4)


def test_epoch_fingerprints_full_layout():
    base = dict(codec="raw", num_shards=2, capacity=4, key_space=8,
                lo=(0, 4), span=4)
    f = wire.WireFormat(**base)
    assert f.epoch != 0
    for change in (dict(codec="delta"), dict(capacity=8),
                   dict(hot_keys=(3,)), dict(plan_epoch=1),
                   dict(value_leaves=(("int16", 1),))):
        g = dataclasses.replace(f, **change)
        assert g.epoch != f.epoch, change


def test_delta_bits_static_width():
    f = wire.WireFormat(codec="delta", num_shards=2, capacity=4,
                        key_space=8, lo=(0, 4), span=4)
    # span 4 + 0 hot + sentinel = 5 symbols -> 3 bits
    assert f.delta_bits == 3
    assert f.packed_row_bytes == -(-4 * 3 // 8)


# ---------------------------------------------------------------------------
# codec round-trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", wire.CODECS)
def test_roundtrip_simple(codec):
    K, S = 32, 4
    keys = np.array([0, 5, 9, 17, 25, 31, 8, 8], np.int32)
    vals = np.arange(8, dtype=np.int32) - 3
    stream = make_stream(keys, vals, K)
    fmt = wire.wire_format(key_space=K, num_shards=S, n_pairs=8,
                           value_avals=stream.values, codec=codec)
    sk_, sv, _ = wire.bucketize(fmt, stream)
    k, v = roundtrip(fmt, sk_, sv)
    assert np.array_equal(np.asarray(k), np.asarray(sk_))
    assert np.array_equal(np.asarray(v), np.asarray(sv))


@pytest.mark.parametrize("codec", ("delta", "packed"))
def test_roundtrip_empty_and_capacity_boundary(codec):
    K, S = 16, 4
    # empty: every key invalid (sentinel) -> all-pad buckets round-trip
    stream = make_stream(np.full(8, K, np.int32),
                         np.zeros(8, np.int32), K)
    fmt = wire.wire_format(key_space=K, num_shards=S, n_pairs=8,
                           value_avals=stream.values, codec=codec)
    sk_, sv, overflow = wire.bucketize(fmt, stream)
    assert int(overflow) == 0
    k, v = roundtrip(fmt, sk_, sv)
    assert np.array_equal(np.asarray(k), np.asarray(sk_))

    # capacity boundary: B pairs on one dest fit exactly; B+1 overflows
    B = fmt.capacity
    keys = np.zeros(B, np.int32)
    stream = make_stream(keys, np.arange(B, dtype=np.int32), K)
    fmt2 = wire.wire_format(key_space=K, num_shards=S, n_pairs=B,
                            value_avals=stream.values, codec=codec,
                            capacity=B)
    sk_, sv, overflow = wire.bucketize(fmt2, stream)
    assert int(overflow) == 0
    k, v = roundtrip(fmt2, sk_, sv)
    assert np.array_equal(np.asarray(k), np.asarray(sk_))
    stream = make_stream(np.zeros(B + 1, np.int32),
                         np.arange(B + 1, dtype=np.int32), K)
    fmt3 = wire.wire_format(key_space=K, num_shards=S, n_pairs=B + 1,
                            value_avals=stream.values, codec=codec,
                            capacity=B)
    _, _, overflow = wire.bucketize(fmt3, stream)
    assert int(overflow) == 1


def test_roundtrip_hot_key_symbols():
    """Hot split keys route OUTSIDE their owner's range; the delta codec
    gives them reserved symbols past the span and must still reproduce
    the raw buckets bitwise."""
    K, S = 64, 4
    plan = skew.ShufflePlan(key_space=K, num_shards=S,
                            boundaries=(0, 16, 32, 48, 64),
                            hot_keys=(3,), hot_ways=(4,))
    rng = np.random.default_rng(0)
    keys = rng.integers(0, K, 64).astype(np.int32)
    keys[::2] = 3  # heavy hot key, round-robined over all 4 dests
    stream = make_stream(keys, np.ones(64, np.int32), K)
    raw_fmt = wire.wire_format(key_space=K, num_shards=S, n_pairs=64,
                               value_avals=stream.values, codec="raw",
                               plan=plan)
    fmt = dataclasses.replace(raw_fmt, codec="delta")
    sk_raw, sv_raw, _ = wire.bucketize(raw_fmt, stream, plan)
    sk_, sv, _ = wire.bucketize(fmt, stream, plan)
    assert np.array_equal(np.asarray(sk_), np.asarray(sk_raw))
    k, v = roundtrip(fmt, sk_, sv)
    assert np.array_equal(np.asarray(k), np.asarray(sk_raw))
    assert np.array_equal(np.asarray(v), np.asarray(sv_raw))


def test_bucketize_rejects_foreign_plan():
    K, S = 64, 4
    p1 = skew.ShufflePlan(key_space=K, num_shards=S,
                          boundaries=(0, 16, 32, 48, 64))
    p2 = skew.ShufflePlan(key_space=K, num_shards=S,
                          boundaries=(0, 8, 32, 48, 64))
    stream = make_stream(np.zeros(8, np.int32), np.ones(8, np.int32), K)
    fmt = wire.wire_format(key_space=K, num_shards=S, n_pairs=8,
                           value_avals=stream.values, plan=p1)
    with pytest.raises(ValueError, match="not the one this WireFormat"):
        wire.bucketize(fmt, stream, p2)


def test_packed_float_values_quantize_within_bound():
    """packed float values are an explicit lossy opt-in: per-destination
    int8 quantization with error <= scale/2 (the compression.py bound)."""
    K, S = 16, 2
    rng = np.random.default_rng(3)
    keys = rng.integers(0, K, 32).astype(np.int32)
    vals = rng.standard_normal(32).astype(np.float32)
    stream = make_stream(keys, vals, K)
    fmt = wire.wire_format(key_space=K, num_shards=S, n_pairs=32,
                           value_avals=stream.values, codec="packed")
    sk_, sv, _ = wire.bucketize(fmt, stream)
    k, v = roundtrip(fmt, sk_, sv)
    assert np.array_equal(np.asarray(k), np.asarray(sk_))
    got = np.asarray(v).reshape(fmt.num_shards, fmt.capacity)
    want = np.asarray(sv)
    for d in range(S):
        scale = max(np.abs(want[d]).max(), 1e-12) / 127.0
        assert np.abs(got[d] - want[d]).max() <= scale / 2 + 1e-7


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False


if HAVE_HYP:
    @settings(max_examples=40, deadline=None)
    @given(
        codec=st.sampled_from(("delta", "packed")),
        key_space=st.integers(2, 200),
        num_shards=st.integers(1, 9),
        n=st.integers(1, 64),
        skewed=st.booleans(),
        seed=st.integers(0, 2 ** 16),
    )
    def test_roundtrip_property(codec, key_space, num_shards, n, skewed,
                                seed):
        """encode∘decode == identity on int-exact specs, for uniform and
        skewed buckets, any (K, S, N) shape."""
        rng = np.random.default_rng(seed)
        keys = rng.integers(0, key_space, n).astype(np.int32)
        if skewed:
            keys[: n // 2 + 1] = int(keys[0])  # half the mass on one key
        keys[rng.random(n) < 0.1] = key_space  # some invalid pairs
        vals = rng.integers(-100, 101, n).astype(np.int32)  # int8-exact
        stream = make_stream(keys, vals, key_space)
        fmt = wire.wire_format(key_space=key_space, num_shards=num_shards,
                               n_pairs=n, value_avals=stream.values,
                               codec=codec, capacity=n)
        sk_, sv, overflow = wire.bucketize(fmt, stream)
        assert int(overflow) == 0  # capacity=n always fits
        k, v = roundtrip(fmt, sk_, sv)
        assert np.array_equal(np.asarray(k), np.asarray(sk_))
        assert np.array_equal(np.asarray(v), np.asarray(sv))

    @settings(max_examples=25, deadline=None)
    @given(
        codec=st.sampled_from(wire.CODECS),
        key_space=st.integers(2, 200),
        num_shards=st.integers(1, 9),
        n=st.integers(1, 64),
        seed=st.integers(0, 2 ** 16),
    )
    def test_encoded_nbytes_matches_real_tree(codec, key_space, num_shards,
                                              n, seed):
        rng = np.random.default_rng(seed)
        stream = make_stream(rng.integers(0, key_space, n).astype(np.int32),
                             rng.integers(-100, 101, n).astype(np.int32),
                             key_space)
        fmt = wire.wire_format(key_space=key_space, num_shards=num_shards,
                               n_pairs=n, value_avals=stream.values,
                               codec=codec)
        sk_, sv, _ = wire.bucketize(fmt, stream)
        enc = wire.encode(fmt, sk_, sv)
        assert wire.encoded_nbytes(fmt) == wire.tree_nbytes(enc)


# ---------------------------------------------------------------------------
# byte accounting + cost-model wire term
# ---------------------------------------------------------------------------


def test_delta_shrinks_wire_bytes():
    fmt = wire.wire_format(key_space=8192, num_shards=16, n_pairs=4096,
                           value_avals=jax.ShapeDtypeStruct((4096,),
                                                            jnp.int16),
                           codec="delta")
    assert wire.encoded_nbytes(fmt) < wire.raw_nbytes(fmt)
    # int16 values: 10-bit residuals vs 32-bit keys -> well under 0.6x
    ratio = wire.encoded_nbytes(fmt) / wire.raw_nbytes(fmt)
    assert ratio <= 0.6, ratio


def test_cost_model_wire_term_matches_wire_layer():
    from repro.core import cost_model as cm
    from repro.roofline import analysis as roofline

    n, K, S = 8192, 1024, 16
    for codec in wire.CODECS:
        fc = cm.estimate_flow_cost("sort", n_pairs=n, key_space=K,
                                   num_shards=S, wire=codec)
        per = -(-n // S)
        fmt = wire.wire_format(
            key_space=K, num_shards=S, n_pairs=per,
            value_avals=jax.ShapeDtypeStruct((per, 1), jnp.int32),
            codec=codec)
        want = wire.wire_bytes_per_shard(fmt) / roofline.LINK_BW
        assert dict(fc.terms)["wire"] == pytest.approx(want)
        assert roofline.shuffle_wire_bytes(
            codec, n_pairs=n, key_space=K,
            num_shards=S) == pytest.approx(wire.wire_bytes_per_shard(fmt))
    # the stream flow has no shuffle: no wire term
    fc = cm.estimate_flow_cost("stream", n_pairs=n, key_space=K,
                               num_shards=S, wire="delta")
    assert "wire" not in dict(fc.terms)


# ---------------------------------------------------------------------------
# resilient partials == the wire encoding (the satellite bugfix)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", ("raw", "delta"))
def test_checkpointed_partials_are_wire_encoding(tmp_path, codec):
    """The resilient driver's durable shard partials must be EXACTLY the
    wire layer's encoding of that shard's send buckets — one source of
    truth (previously engine._shuffle_pairs and run_resilient each built
    buckets with separately-maintained capacity plumbing)."""
    K, S = 64, 4
    app = make_app(K, emit=4)
    rng = np.random.default_rng(7)
    items = jnp.asarray(rng.integers(0, K, (16, 4)).astype(np.int32))
    plan = plan_execution(app, flow="sort")
    d = str(tmp_path / codec)
    eng.run_resilient(app, plan, items, num_hosts=S, ckpt_dir=d,
                      wire=codec)

    # rebuild shard 0's buckets through the wire layer directly
    per = items.shape[0] // S
    stream = eng.map_phase(app, items[:per])
    fmt = wire.wire_format(key_space=K, num_shards=S,
                           n_pairs=stream.keys.shape[0],
                           value_avals=stream.values, codec=codec)
    sk_, sv, overflow = wire.bucketize(fmt, stream)
    want = {"wire": wire.encode(fmt, sk_, sv), "overflow": overflow,
            "wire_epoch": jnp.full((1,), fmt.epoch, jnp.uint32)}
    got, step = ckpt.restore(ckpt.shard_partial_dir(d, 0), want, step=0)
    assert_trees_equal(got, want)


def test_resilient_delta_kill_restore_bitwise(tmp_path):
    """Kill/restore drill under wire='delta': recovery restores the
    COMPRESSED partials from disk and the answer stays bitwise the raw
    fault-free run."""
    K = 128
    app = make_app(K, emit=8)
    keys = np.array(np.random.default_rng(5).zipf(1.1, (64, 8)) % K)
    items = jnp.asarray(keys, I32)
    # zipf keys overflow the 2x-uniform envelope: provision the full
    # per-shard pair count so the drill compares complete answers
    opts = ExecutionOptions(num_hosts=8, num_shards=8,
                            shuffle=ShuffleOptions(wire="raw", capacity=64))
    mr = MapReduce(app, flow="sort", cache=False)
    base = mr.run_resilient(items, options=opts)

    dopts = ExecutionOptions(
        num_hosts=8, num_shards=8, ckpt_dir=str(tmp_path),
        shuffle=ShuffleOptions(wire="delta", capacity=64))
    mr2 = MapReduce(app, flow="sort", cache=False)
    mr2.run_resilient(items, options=dopts)  # seed compressed checkpoints
    drill = mr2.run_resilient(items, options=dataclasses.replace(
        dopts, inject=flt.FaultInjection(dead_hosts=(3,),
                                         die_after_shards=0)))
    assert np.array_equal(np.asarray(drill.values), np.asarray(base.values))
    assert np.array_equal(np.asarray(drill.counts), np.asarray(base.counts))
    assert drill.recovery.restored, drill.recovery.summary()


def test_codec_change_rejected_at_restore(tmp_path):
    """A partial checkpointed under a DIFFERENT wire codec must never be
    merged (its bytes mean different things): the wire epoch rejects it
    and the shard recomputes — the answer stays exact."""
    K = 64
    app = make_app(K, emit=4)
    rng = np.random.default_rng(9)
    items = jnp.asarray(rng.integers(0, K, (32, 4)).astype(np.int32))

    def run(codec, inject=None):
        mr = MapReduce(app, flow="sort", cache=False)
        return mr.run_resilient(items, options=ExecutionOptions(
            num_hosts=4, num_shards=8, ckpt_dir=str(tmp_path),
            inject=inject, shuffle=ShuffleOptions(wire=codec, capacity=32)))

    base = run("raw")  # seeds raw-codec checkpoints for every shard
    drill = run("delta", inject=flt.FaultInjection(dead_hosts=(1,),
                                                   die_after_shards=1))
    assert np.array_equal(np.asarray(drill.values), np.asarray(base.values))
    assert np.array_equal(np.asarray(drill.counts), np.asarray(base.counts))
    # the shard host 1 completed BEFORE dying was checkpointed under
    # delta by the drill itself and restores fine; the one it never
    # reached only has the seeded raw partial, which must be rejected
    assert drill.recovery.epoch_rejects, drill.recovery.summary()


def test_stale_layout_structure_rejected_at_restore(tmp_path):
    """A partial whose npz leaf STRUCTURE no longer matches (e.g. written
    under the packed codec, restored under raw) is caught by the restore
    guard — rejected with a recompute, not a crash or a silent misread."""
    K = 64
    app = make_app(K, emit=4, dtype=jnp.float32)
    app.map = lambda item, emit_: emit_(
        item, jnp.ones_like(item, jnp.float32))
    rng = np.random.default_rng(11)
    items = jnp.asarray(rng.integers(0, K, (32, 4)).astype(np.int32))

    def run(codec, inject=None):
        mr = MapReduce(app, flow="sort", cache=False)
        return mr.run_resilient(items, options=ExecutionOptions(
            num_hosts=4, num_shards=8, ckpt_dir=str(tmp_path),
            inject=inject, shuffle=ShuffleOptions(wire=codec, capacity=32)))

    run("packed")  # float values -> extra per-dest scales leaf on disk
    base_mr = MapReduce(app, flow="sort", cache=False)
    base = base_mr.run_resilient(items, options=ExecutionOptions(
        num_hosts=4, num_shards=8,
        shuffle=ShuffleOptions(wire="raw", capacity=32)))
    drill = run("raw", inject=flt.FaultInjection(dead_hosts=(1,),
                                                 die_after_shards=1))
    assert np.array_equal(np.asarray(drill.values), np.asarray(base.values))
    assert drill.recovery.epoch_rejects, drill.recovery.summary()


# ---------------------------------------------------------------------------
# plan provenance
# ---------------------------------------------------------------------------


def test_explain_shows_wire_codec_and_bytes():
    K = 1024
    app = make_app(K, emit=8)
    rng = np.random.default_rng(0)
    items = jnp.asarray(rng.integers(0, K, (64, 8)).astype(np.int32))
    mr = MapReduce(app, cache=False)
    low = mr.lower(items, options=ExecutionOptions(
        num_hosts=16, shuffle=ShuffleOptions(wire="delta")),
        mode="resilient")
    text = low.mr.plan.explain()
    assert "wire: codec delta" in text
    assert "x raw" in text  # modeled encoded-vs-raw bytes line
