"""The trip-count-aware HLO cost parser vs closed-form ground truth."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import hlo_parser
from repro.roofline.analysis import collective_stats


def test_scan_trip_count_multiplication():
    """flops of scan(matmul, length=L) must be ~L x the single matmul."""
    n, L = 256, 12

    def one(x):
        return jnp.tanh(x @ x)

    def scanned(x):
        y, _ = jax.lax.scan(lambda c, _: (one(c), None), x, None, length=L)
        return y

    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    c1 = hlo_parser.analyze_text(
        jax.jit(scanned).lower(x).compile().as_text())
    c0 = hlo_parser.analyze_text(
        jax.jit(one).lower(x).compile().as_text())
    dot_flops = 2 * n * n * n
    assert abs(c0.flops - dot_flops) / dot_flops < 0.01
    assert abs(c1.flops - L * c0.flops) / (L * c0.flops) < 0.02


def test_bytes_dus_not_full_buffer():
    """dynamic-update-slice charges the slice, not the whole buffer."""
    buf = jax.ShapeDtypeStruct((1 << 20,), jnp.float32)  # 4 MiB
    upd = jax.ShapeDtypeStruct((8,), jnp.float32)

    def f(b, u):
        return jax.lax.dynamic_update_slice(b, u, (5,))

    # donate the buffer (as the decode steps do) so the update is in place;
    # without donation XLA copies the whole buffer defensively
    c = hlo_parser.analyze_text(
        jax.jit(f, donate_argnums=(0,)).lower(buf, upd).compile().as_text())
    assert c.bytes_accessed < 1 << 16  # slice-sized, not 8 MiB


def test_wire_factors():
    assert hlo_parser._wire_factor("all-reduce", 4) == pytest.approx(1.5)
    assert hlo_parser._wire_factor("all-gather", 4) == pytest.approx(0.75)
    assert hlo_parser._wire_factor("all-reduce", 1) == 0.0
    assert hlo_parser._wire_factor("collective-permute", 8) == 1.0


def test_parse_module_roundtrip():
    def f(x):
        return jnp.sum(jnp.exp(x) @ x)

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    txt = jax.jit(f).lower(x).compile().as_text()
    comps = hlo_parser.parse_module(txt)
    assert any(c.is_entry for c in comps.values())
    ops = [op.opcode for c in comps.values() for op in c.ops]
    assert "dot" in ops
