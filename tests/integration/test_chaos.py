"""Chaos drills for the durable control plane (distributed/coordination.py
+ chaos.py): lease-based coordinator failover, checksummed checkpoint
quarantine, bounded deterministic retry/backoff, network partitions and
multi-fault scripts — every recovered answer bitwise-identical to the
clean run, for the stream, sort and reduce flows (honoring the
REPRO_TEST_FLOW / REPRO_TEST_KERNELS CI matrix)."""

import os
import tempfile
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _subproc import run_with_devices

from repro.checkpoint import ckpt
from repro.core import MapReduceApp, plan_execution
from repro.core import engine as eng
from repro.distributed import chaos as chaoslib
from repro.distributed import coordination as coordlib
from repro.distributed import fault

VOCAB = 48


class WC(MapReduceApp):
    key_space = VOCAB
    value_aval = jax.ShapeDtypeStruct((), jnp.int32)
    max_values_per_key = 256
    emit_capacity = 8

    def map(self, item, emit):
        emit(item, jnp.ones_like(item))

    def reduce(self, key, values, count):
        return jnp.sum(values)


def _tokens(n_items=64):
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(0, VOCAB, (n_items, 8)).astype(np.int32))


def _bitwise_equal(a, b):
    return all(np.asarray(x).tobytes() == np.asarray(y).tobytes()
               for x, y in zip(a[:3], b[:3]))


def _chaos_flows(matrix_flows):
    # the ISSUE's acceptance flows; `combine` rides along in test_fault.py
    return matrix_flows(("stream", "sort", "reduce"))


# ---------------------------------------------------------------------------
# RetryPolicy: bounded, deterministic, no silent retries
# ---------------------------------------------------------------------------


def test_retry_schedule_deterministic_capped():
    pol = coordlib.RetryPolicy(max_attempts=5, base_delay_s=0.1,
                               multiplier=2.0, max_delay_s=0.5)
    assert pol.schedule() == (0.1, 0.2, 0.4, 0.5)
    assert pol.schedule() == pol.schedule()  # jitter-free


def test_retry_backoff_then_success_records_events():
    pol = coordlib.RetryPolicy(max_attempts=4, base_delay_s=0.01)
    calls, slept, events = [], [], []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise coordlib.StoreTimeout("transient")
        return "ok"

    out = pol.call(flaky, op="flaky op", sleep=slept.append,
                   on_event=events.append)
    assert out == "ok" and len(calls) == 3
    assert slept == [0.01, 0.02]  # the deterministic schedule, no jitter
    assert any("backing off" in e for e in events)
    assert any("succeeded on attempt 3/4" in e for e in events)


def test_retry_bounded_raises_after_cap():
    """No unbounded loops: a persistently failing op raises RetryError
    after exactly max_attempts tries."""
    pol = coordlib.RetryPolicy(max_attempts=3, base_delay_s=0.0)
    calls = []

    def always_fails():
        calls.append(1)
        raise coordlib.StoreTimeout("down")

    with pytest.raises(coordlib.RetryError, match="3 bounded attempts"):
        pol.call(always_fails, op="dead store", sleep=lambda _: None)
    assert len(calls) == 3


def test_retry_does_not_retry_missing_files():
    """FileNotFoundError is not transient: a missing checkpoint must not
    burn the whole backoff schedule before surfacing."""
    pol = coordlib.RetryPolicy(max_attempts=5)
    calls = []

    def missing():
        calls.append(1)
        raise FileNotFoundError("no checkpoint")

    with pytest.raises(FileNotFoundError):
        pol.call(missing, sleep=lambda _: None)
    assert len(calls) == 1


# ---------------------------------------------------------------------------
# Lease election: deterministic, exactly one winner
# ---------------------------------------------------------------------------


def test_elect_lowest_live_rank():
    assert coordlib.elect([3, 1, 5]) == 1
    assert coordlib.elect(range(8)) == 0
    assert coordlib.elect({7}) == 7
    with pytest.raises(ValueError):
        coordlib.elect([])


def test_lease_expiry_failover_deterministic():
    """Holder 0 stops renewing; after the TTL only the lowest-ranked live
    host can adopt, at a bumped epoch — every other claimant is refused."""
    clk = fault.StepClock()
    store = coordlib.CoordinationStore(coordlib.MemKVStore(),
                                       lease_ttl_s=10.0, clock=clk)
    first = store.adopt(0, range(4))
    assert first is not None and (first.holder, first.epoch) == (0, 1)
    clk.advance(5.0)
    assert store.adopt(2, range(4)) is None  # live holder keeps it
    clk.advance(6.0)  # lease expired; holder 0 presumed dead
    alive = [2, 3]
    assert store.adopt(3, alive) is None  # not the lowest live rank
    second = store.adopt(2, alive)
    assert second is not None and (second.holder, second.epoch) == (2, 2)
    assert any("adopted coordination" in e for e in store.events)


def test_lease_adoption_exactly_one_winner_exhaustive():
    """For every claim order over a small alive-set, exactly one host
    ends up holding the lease: elect()'s winner."""
    import itertools

    for alive in ([0, 1, 2], [1, 3], [2], [0, 2, 5, 7]):
        for order in itertools.permutations(alive):
            store = coordlib.CoordinationStore(
                coordlib.MemKVStore(), lease_ttl_s=10.0,
                clock=fault.StepClock())
            wins = [h for h in order if store.adopt(h, alive) is not None]
            assert wins == [min(alive)], (alive, order, wins)


def test_lease_election_deterministic_hypothesis():
    """Property drill: for ANY alive-set and ANY adoption attempt order,
    election is deterministic and picks exactly one live host — the
    lowest rank."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(
        alive=st.sets(st.integers(min_value=0, max_value=15), min_size=1,
                      max_size=16),
        seed=st.integers(min_value=0, max_value=2**31 - 1))
    @hyp.settings(max_examples=200, deadline=None)
    def drill(alive, seed):
        assert coordlib.elect(alive) == min(alive)  # pure + deterministic
        order = sorted(alive,
                       key=lambda h: np.random.default_rng(seed + h)
                       .integers(0, 1 << 30))
        store = coordlib.CoordinationStore(
            coordlib.MemKVStore(), lease_ttl_s=10.0,
            clock=fault.StepClock())
        winners = [h for h in order if store.adopt(h, alive) is not None]
        assert winners == [min(alive)]

    drill()


# ---------------------------------------------------------------------------
# Checksummed checkpoint store (unit level; the matrix drills below use it
# through the resilient driver)
# ---------------------------------------------------------------------------


def _tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "n": jnp.asarray([7], jnp.int32)}


def test_checkpoint_verify_and_quarantine():
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 3, _tree())
        ckpt.verify_step(d, 3)  # intact: no raise
        assert ckpt.has_valid_step(d, 3)
        chaoslib.corrupt_payload(os.path.join(d, "step_3", "arrays.npz"))
        with pytest.raises(ckpt.CheckpointCorruptError) as ei:
            ckpt.verify_step(d, 3)
        assert "step 3" in str(ei.value) and "step_3" in str(ei.value)
        assert not ckpt.has_valid_step(d, 3)
        q = ckpt.quarantine_step(d, 3)
        assert q.endswith("step_3.corrupt") and os.path.isdir(q)
        # quarantined neighbors must not break step listing or gc
        ckpt.save(d, 4, _tree())
        assert ckpt.latest_step(d) == 4


def test_restore_explicit_corrupt_step_raises_and_quarantines():
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 5, _tree())
        chaoslib.truncate_payload(os.path.join(d, "step_5", "arrays.npz"))
        with pytest.raises(ckpt.CheckpointCorruptError, match="step 5"):
            ckpt.restore(d, _tree(), step=5)
        assert os.path.isdir(os.path.join(d, "step_5.corrupt"))
        assert not os.path.isdir(os.path.join(d, "step_5"))


def test_restore_falls_back_to_newest_valid():
    """A torn newest write degrades to the previous snapshot — the
    satellite acceptance for MapReduceService.restore(step=None)."""
    with tempfile.TemporaryDirectory() as d:
        t = _tree()
        ckpt.save(d, 1, t)
        ckpt.save(d, 2, jax.tree.map(lambda a: a + 1, t))
        ckpt.save(d, 3, jax.tree.map(lambda a: a + 2, t))
        chaoslib.truncate_payload(os.path.join(d, "step_3", "arrays.npz"))
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            tree, step = ckpt.restore(d, t, step=None)
        assert step == 2
        assert np.array_equal(np.asarray(tree["w"]),
                              np.asarray(t["w"]) + 1)
        assert any("quarantined" in str(x.message) for x in w)
        assert os.path.isdir(os.path.join(d, "step_3.corrupt"))
        # all candidates corrupt -> clear FileNotFoundError, no crash
        chaoslib.corrupt_payload(os.path.join(d, "step_2", "arrays.npz"))
        chaoslib.corrupt_payload(os.path.join(d, "step_1", "arrays.npz"))
        with pytest.raises(FileNotFoundError, match="no VALID checkpoint"), \
                warnings.catch_warnings():
            warnings.simplefilter("ignore")
            ckpt.restore(d, t, step=None)


def test_legacy_checkpoint_without_checksum_still_restores():
    """Pre-checksum checkpoints (no manifest.crc / checksum field) must
    stay readable — upgrades cannot orphan existing snapshots."""
    with tempfile.TemporaryDirectory() as d:
        t = _tree()
        ckpt.save(d, 1, t)
        os.remove(os.path.join(d, "step_1", "manifest.crc"))
        import json

        mpath = os.path.join(d, "step_1", "manifest.json")
        with open(mpath) as f:
            m = json.load(f)
        del m["checksum"]
        with open(mpath, "w") as f:
            json.dump(m, f)
        ckpt.verify_step(d, 1)  # legacy accepted
        tree, step = ckpt.restore(d, t)
        assert step == 1
        assert np.array_equal(np.asarray(tree["w"]), np.asarray(t["w"]))


# ---------------------------------------------------------------------------
# FileKVStore + CoordinationStore
# ---------------------------------------------------------------------------


def test_file_kv_store_roundtrip_and_atomicity():
    with tempfile.TemporaryDirectory() as d:
        kv = coordlib.FileKVStore(d)
        kv.put("hosts/3", b'{"host": 3}')
        kv.put("lease", b'{"holder": 0}')
        assert kv.get("hosts/3") == b'{"host": 3}'
        assert kv.get("missing") is None
        assert kv.keys("hosts/") == ["hosts/3"]
        assert sorted(kv.keys()) == ["hosts/3", "lease"]
        kv.delete("hosts/3")
        assert kv.get("hosts/3") is None
        with pytest.raises(ValueError):
            kv.put("../escape", b"nope")


def test_coordination_store_heartbeats_and_ledger_survive_restart():
    """The durability bar: a brand-new CoordinationStore over the same
    directory (a failover coordinator on another host) reads the same
    heartbeats, lease and ledger the dead one wrote."""
    clk = fault.StepClock()
    with tempfile.TemporaryDirectory() as d:
        c1 = coordlib.CoordinationStore(d, clock=clk, lease_ttl_s=5.0)
        c1.beat(0, step=2)
        c1.beat(1, step=1)
        c1.adopt(0, [0, 1])
        c1.record_shard(4, host=0, step=7)
        c1.record_shard(5, host=1, step=7)

        c2 = coordlib.CoordinationStore(d, clock=clk, lease_ttl_s=5.0)
        recs = c2.host_records()
        assert recs[0]["step"] == 2 and recs[1]["step"] == 1
        lease = c2.lease()
        assert (lease.holder, lease.epoch) == (0, 1)
        assert c2.load_ledger(7) == {4: 0, 5: 1}
        assert c2.load_ledger(8) == {}


def test_durable_monitor_partition_drops_beats():
    clk = fault.StepClock()
    store = coordlib.CoordinationStore(coordlib.MemKVStore(), clock=clk)
    mon = coordlib.DurableHeartbeatMonitor(store, 3, timeout_s=10.0,
                                           clock=clk)
    for h in range(3):
        mon.beat(h, step=1)
    mon.partition(2)
    clk.advance(11.0)
    mon.beat(0, step=2)
    mon.beat(1, step=2)
    mon.beat(2, step=2)  # dropped at the transport
    assert mon.dead_hosts() == [2]
    assert sorted(mon.alive_hosts()) == [0, 1]


# ---------------------------------------------------------------------------
# The chaos matrix: in-process drills on the resilient driver (bitwise
# vs the clean run, stream/sort/reduce, flow-matrix aware)
# ---------------------------------------------------------------------------


def _clean(flow, toks, use_kernels):
    plan = plan_execution(WC(), flow=flow)
    return eng.run_resilient(WC(), plan, toks, num_hosts=4, num_shards=8,
                             use_kernels=use_kernels)


def test_chaos_coordinator_kill_midphase_failover_bitwise(
        matrix_flows, matrix_use_kernels):
    """Coordinator (host 0, the elected lease holder) dies mid-phase-A:
    the lowest-ranked survivor adopts the lease + durable ledger at a
    bumped epoch and phase B resumes from durable partials, bitwise."""
    toks = _tokens()
    for flow in _chaos_flows(matrix_flows):
        base = _clean(flow, toks, matrix_use_kernels)
        with tempfile.TemporaryDirectory() as d:
            plan = plan_execution(WC(), flow=flow)
            out = eng.run_resilient(
                WC(), plan, toks, num_hosts=4, num_shards=8, ckpt_dir=d,
                use_kernels=matrix_use_kernels,
                chaos=chaoslib.ChaosPlan().kill_coordinator(after=1))
            assert _bitwise_equal(base, out), flow
            log = out[3]
            assert log.coordinator == 0
            assert log.failover == (0, 1, 2), log.failover  # epoch bumped
            assert 0 in log.dead_hosts
            # the failover + adoption provenance reaches explain()
            assert any("failover" in e and "adopted" in e
                       for e in plan.recovery)
            # host 0 checkpointed its first shard before dying: restored
            assert log.restored, log


def test_chaos_corrupt_one_of_eight_shard_partials(matrix_flows,
                                                   matrix_use_kernels):
    """1-of-8 durable shard partials is corrupt: the checksum layer
    detects it, quarantines to *.corrupt, and the shard is recomputed
    deterministically — never restored, never crashed, still bitwise."""
    toks = _tokens()
    for flow in _chaos_flows(matrix_flows):
        base = _clean(flow, toks, matrix_use_kernels)
        with tempfile.TemporaryDirectory() as d:
            plan = plan_execution(WC(), flow=flow)
            # host 2 owns shards {2, 6}; it dies AFTER checkpointing both,
            # and shard 2's checkpoint is then corrupted on disk
            out = eng.run_resilient(
                WC(), plan, toks, num_hosts=4, num_shards=8, ckpt_dir=d,
                use_kernels=matrix_use_kernels,
                chaos=(chaoslib.ChaosPlan()
                       .kill_host(2, after=2)
                       .corrupt_checkpoint(2)))
            assert _bitwise_equal(base, out), flow
            log = out[3]
            assert log.corrupt == [2]
            assert 2 not in log.restored and 6 in log.restored
            assert 2 in [s for s, _ in log.recomputed]
            assert os.path.isdir(os.path.join(
                ckpt.shard_partial_dir(d, 2), "step_0.corrupt"))
            assert any("quarantined" in e for e in plan.recovery)


def test_chaos_store_timeout_backoff_success(matrix_flows,
                                             matrix_use_kernels):
    """Store write timeouts on the first checkpoint ops: absorbed by the
    bounded deterministic backoff (retry -> success), every attempt on
    the record, output bitwise."""
    toks = _tokens()
    for flow in _chaos_flows(matrix_flows):
        base = _clean(flow, toks, matrix_use_kernels)
        with tempfile.TemporaryDirectory() as d:
            plan = plan_execution(WC(), flow=flow)
            out = eng.run_resilient(
                WC(), plan, toks, num_hosts=4, num_shards=8, ckpt_dir=d,
                use_kernels=matrix_use_kernels,
                retry=coordlib.RetryPolicy(max_attempts=4,
                                           base_delay_s=0.01),
                chaos=chaoslib.ChaosPlan().delay_store(2))
            assert _bitwise_equal(base, out), flow
            log = out[3]
            assert any("backing off" in e for e in log.store_events)
            assert any("succeeded on attempt" in e
                       for e in log.store_events)
            # provenance reaches the plan diagnostics — no silent retries
            assert any("retry:" in e for e in plan.recovery)


def test_chaos_store_timeouts_exhaust_bounded_budget():
    """More injected timeouts than the retry budget: the driver fails
    with RetryError after the capped attempts — never an unbounded loop."""
    toks = _tokens()
    with tempfile.TemporaryDirectory() as d:
        plan = plan_execution(WC(), flow="stream")
        with pytest.raises(coordlib.RetryError, match="bounded attempts"):
            eng.run_resilient(
                WC(), plan, toks, num_hosts=4, num_shards=8, ckpt_dir=d,
                retry=coordlib.RetryPolicy(max_attempts=2,
                                           base_delay_s=0.0),
                chaos=chaoslib.ChaosPlan().delay_store(50))


def test_chaos_partitioned_host_recovered(matrix_flows, matrix_use_kernels):
    """A partitioned host keeps computing but its beats/writes never
    reach the store: the cluster declares it dead and recovers its
    shards on live ranks, bitwise."""
    toks = _tokens()
    for flow in _chaos_flows(matrix_flows):
        base = _clean(flow, toks, matrix_use_kernels)
        with tempfile.TemporaryDirectory() as d:
            plan = plan_execution(WC(), flow=flow)
            out = eng.run_resilient(
                WC(), plan, toks, num_hosts=4, num_shards=8, ckpt_dir=d,
                use_kernels=matrix_use_kernels,
                chaos=chaoslib.ChaosPlan().partition(3))
            assert _bitwise_equal(base, out), flow
            log = out[3]
            assert log.partitioned == [3]
            assert 3 in log.dead_hosts  # detected via dropped beats
            assert any("partition" in e for e in plan.recovery)


def test_chaos_multifault_drill(matrix_flows, matrix_use_kernels):
    """The full drill: coordinator killed mid-run + one corrupt
    checkpoint + one straggler + flaky store, in ONE run — recovery is
    still bitwise-identical to the clean answer."""
    toks = _tokens()
    for flow in _chaos_flows(matrix_flows):
        base = _clean(flow, toks, matrix_use_kernels)
        with tempfile.TemporaryDirectory() as d:
            plan = plan_execution(WC(), flow=flow)
            ch = (chaoslib.ChaosPlan()
                  .kill_coordinator(after=1)
                  .corrupt_checkpoint(0)
                  .straggler(3)
                  .delay_store(1))
            out = eng.run_resilient(
                WC(), plan, toks, num_hosts=4, num_shards=8, ckpt_dir=d,
                use_kernels=matrix_use_kernels,
                retry=coordlib.RetryPolicy(max_attempts=4,
                                           base_delay_s=0.01),
                chaos=ch)
            assert _bitwise_equal(base, out), flow
            log = out[3]
            assert log.failover == (0, 1, 2)
            assert log.corrupt == [0]
            assert log.straggler_hosts == [3]


def test_chaos_events_reach_explain(matrix_use_kernels):
    """`explain()` shows the full control-plane story: the lease
    election, the backoff schedule taken and which host adopted — the
    no-silent-retries satellite."""
    toks = _tokens()
    with tempfile.TemporaryDirectory() as d:
        plan = plan_execution(WC(), flow="stream")
        eng.run_resilient(
            WC(), plan, toks, num_hosts=4, num_shards=8, ckpt_dir=d,
            use_kernels=matrix_use_kernels,
            retry=coordlib.RetryPolicy(max_attempts=3, base_delay_s=0.25),
            chaos=(chaoslib.ChaosPlan().kill_coordinator(after=1)
                   .delay_store(1)))
        text = plan.explain()
        assert "recovery: lease: host 0 elected coordinator" in text
        assert "backing off 0.25s" in text  # the schedule actually taken
        assert "host 1 adopted" in text


def test_chaos_knobs_through_execution_options():
    """The coord/retry/chaos knobs travel through ExecutionOptions and
    the staged run_resilient wrapper (the README example's shape)."""
    from repro.core import ExecutionOptions, MapReduce

    toks = _tokens()
    mr = MapReduce(WC())
    with tempfile.TemporaryDirectory() as d:
        res = mr.run_resilient(toks, options=ExecutionOptions(
            num_hosts=4, num_shards=8, ckpt_dir=d,
            coord=os.path.join(d, "coord"),
            retry=coordlib.RetryPolicy(max_attempts=4, base_delay_s=0.01),
            chaos=chaoslib.ChaosPlan().kill_coordinator(after=1)
            .delay_store(1)))
        log = res.recovery
        assert log.failover == (0, 1, 2)
        assert any("backing off" in e for e in log.store_events)
        text = mr.explain()
        assert "adopted" in text and "backing off" in text


# ---------------------------------------------------------------------------
# The acceptance drill: fake 8-device mesh, multi-fault, vs run_distributed
# ---------------------------------------------------------------------------


def test_chaos_multifault_bitwise_vs_distributed_mesh_subprocess():
    """ISSUE acceptance: on a fake 8-device mesh, with the coordinator
    killed mid-run, one checkpoint corrupted and one straggler host, the
    recovered output is bitwise-identical to the fault-free
    ``run_distributed`` answer for stream, sort and reduce."""
    out = run_with_devices("""
        import os, tempfile, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import MapReduceApp, plan_execution
        from repro.core import engine as eng
        from repro.distributed import chaos as chaoslib
        from repro.distributed import coordination as coordlib

        UK = os.environ.get("REPRO_TEST_KERNELS", "").lower() not in (
            "", "0", "false", "no")
        OVR = os.environ.get("REPRO_TEST_FLOW", "").strip().lower()
        FLOWS = (OVR,) if OVR in ("stream", "sort", "reduce") else (
            "stream", "sort", "reduce")

        VOCAB = 48
        class WC(MapReduceApp):
            key_space = VOCAB
            value_aval = jax.ShapeDtypeStruct((), jnp.int32)
            max_values_per_key = 256
            emit_capacity = 8
            def map(self, item, emit): emit(item, jnp.ones_like(item))
            def reduce(self, key, values, count): return jnp.sum(values)

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        toks = jax.device_put(
            jnp.asarray(rng.integers(0, VOCAB, (64, 8)).astype(np.int32)),
            NamedSharding(mesh, P("data")))
        app = WC()

        def bits(arrs):
            return [np.asarray(a).tobytes() for a in arrs]

        for flow in FLOWS:
            with mesh:
                plan0 = plan_execution(app, flow=flow)
                ref = bits(eng.run_distributed(app, plan0, toks, mesh=mesh,
                                               use_kernels=UK))
            with tempfile.TemporaryDirectory() as d:
                # seed every durable shard partial, coordinated
                plan1 = plan_execution(app, flow=flow)
                eng.run_resilient(app, plan1, toks, mesh=mesh,
                                  use_kernels=UK, ckpt_dir=d,
                                  coord=os.path.join(d, "coord"))
                # the multi-fault drill: coordinator (host 0) dies after
                # its first shard, shard 5's durable partial is corrupt,
                # host 6 straggles, the store times out twice
                plan2 = plan_execution(app, flow=flow)
                ch = (chaoslib.ChaosPlan()
                      .kill_coordinator(after=1)
                      .corrupt_checkpoint(5)
                      .straggler(6)
                      .delay_store(2))
                k, v, c, log = eng.run_resilient(
                    app, plan2, toks, mesh=mesh, use_kernels=UK,
                    ckpt_dir=d, coord=os.path.join(d, "coord"),
                    retry=coordlib.RetryPolicy(max_attempts=4,
                                               base_delay_s=0.01),
                    chaos=ch)
                assert bits((k, v, c)) == ref, ("chaos", flow)
                assert log.coordinator == 0
                assert log.failover == (0, 1, 2), log.failover
                assert log.corrupt == [5]
                assert log.straggler_hosts == [6]
                assert any("backing off" in e for e in log.store_events)
            print("CHAOS_BITWISE_OK", flow)
    """, n=8)
    assert out.count("CHAOS_BITWISE_OK") >= 1


# ---------------------------------------------------------------------------
# Streaming service under chaos: torn snapshot -> newest valid
# ---------------------------------------------------------------------------


def test_service_restores_newest_valid_after_torn_write():
    """ISSUE acceptance (streaming half): after a torn checkpoint write,
    ``restore(step=None)`` falls back to the newest VALID snapshot and
    resumes bitwise; the torn artifact is quarantined, and an explicit
    ``restore(step=torn)`` raises CheckpointCorruptError naming the step
    and path."""
    from repro.core.api import MapReduce

    I32 = jnp.int32
    B = 16

    class KV(MapReduceApp):
        key_space = VOCAB
        value_aval = jax.ShapeDtypeStruct((), I32)
        max_values_per_key = 4096
        emit_capacity = 1

        def map(self, item, emit):
            emit(item, jnp.ones_like(item))

        def reduce(self, key, values, count):
            return jnp.sum(values)

    rng = np.random.default_rng(11)
    batches = [jnp.asarray(rng.integers(0, VOCAB, (B,)).astype(np.int32))
               for _ in range(8)]
    spec = jax.ShapeDtypeStruct((), I32)

    def build(d):
        return MapReduce(KV(), streaming=True).serve(
            batch_capacity=B, ckpt_dir=d, ckpt_every=2, item_spec=spec)

    with tempfile.TemporaryDirectory() as d:
        svc = build(d)
        for i, b in enumerate(batches):
            svc.ingest(b)
            if i == 5:  # snapshot state at the batch-6 checkpoint
                want6 = svc.snapshot()
        assert ckpt.latest_step(ckpt.service_state_dir(d)) == 8
        # tear the newest snapshot on disk
        assert chaoslib.corrupt_service_checkpoint(d, 8) is not None

        # restore(step=None): falls back to batch 6, bitwise
        fresh = build(d)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            got = fresh.restore()
        assert got == 6 and fresh.batch_id == 6
        snap = fresh.snapshot()
        assert (np.asarray(snap.values).tobytes()
                == np.asarray(want6.values).tobytes())
        assert os.path.isdir(os.path.join(
            ckpt.service_state_dir(d), "step_8.corrupt"))

        # replaying batches 7..8 reconverges bitwise with the original
        for b in batches[6:]:
            fresh.ingest(b)
        want = svc.snapshot()
        got = fresh.snapshot()
        assert (np.asarray(got.values).tobytes()
                == np.asarray(want.values).tobytes())
        assert got.batch_id == want.batch_id == 8


def test_service_explicit_corrupt_step_raises_with_name():
    from repro.core.api import MapReduce

    I32 = jnp.int32
    B = 8

    class KV(MapReduceApp):
        key_space = VOCAB
        value_aval = jax.ShapeDtypeStruct((), I32)
        max_values_per_key = 4096
        emit_capacity = 1

        def map(self, item, emit):
            emit(item, jnp.ones_like(item))

        def reduce(self, key, values, count):
            return jnp.sum(values)

    spec = jax.ShapeDtypeStruct((), I32)
    with tempfile.TemporaryDirectory() as d:
        svc = MapReduce(KV(), streaming=True).serve(
            batch_capacity=B, ckpt_dir=d, ckpt_every=1, item_spec=spec)
        svc.ingest(jnp.zeros((B,), I32))
        assert chaoslib.corrupt_service_checkpoint(d, 1) is not None
        fresh = MapReduce(KV(), streaming=True).serve(
            batch_capacity=B, ckpt_dir=d, item_spec=spec)
        with pytest.raises(ckpt.CheckpointCorruptError) as ei:
            fresh.restore(step=1)
        assert "step 1" in str(ei.value) and "step_1" in str(ei.value)
