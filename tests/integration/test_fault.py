"""Fault tolerance: heartbeats, stragglers, deterministic shard assignment,
the stateless data pipeline they rely on, and the resilient MapReduce
driver (``engine.run_resilient``) built on top of them — kill-a-shard
recovery, checkpointed partial-aggregate restore, straggler speculation and
elastic remesh, all bitwise-identical to the no-failure run."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _subproc import run_with_devices

from repro.core import MapReduceApp, plan_execution
from repro.core import engine as eng
from repro.data import pipeline
from repro.distributed import fault


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# HeartbeatMonitor
# ---------------------------------------------------------------------------


def test_heartbeat_death_detection():
    clk = FakeClock()
    mon = fault.HeartbeatMonitor(4, timeout_s=10, clock=clk)
    for h in range(4):
        mon.beat(h, step=0)
    clk.t = 5
    for h in (0, 1, 2):
        mon.beat(h, step=1)
    clk.t = 12  # host 3 silent for 12s
    assert mon.dead_hosts() == [3]
    assert sorted(mon.alive_hosts()) == [0, 1, 2]


def test_heartbeat_no_false_deaths_at_construction():
    """Seed regression: ``HostState.last_beat=0.0`` against a monotonic
    clock declared every host dead before any beat.  ``last_beat`` must
    initialize from the injected clock, with a startup grace period for
    hosts that have never beaten."""
    clk = FakeClock(t=1000.0)  # monotonic clocks do not start at zero
    mon = fault.HeartbeatMonitor(4, timeout_s=10, clock=clk)
    assert mon.dead_hosts() == []  # seed behavior: all 4 dead here
    assert sorted(mon.alive_hosts()) == [0, 1, 2, 3]

    # within timeout + grace, a silent-from-birth host is still booting
    clk.t = 1015.0
    assert mon.dead_hosts() == []
    # a host that HAS beaten gets only the plain timeout afterwards
    mon.beat(0, step=1)
    clk.t = 1026.0  # host 0 silent 11s > timeout; others in grace til 1020+
    assert 0 in mon.dead_hosts()
    # past timeout+grace with no beat ever: genuinely dead
    assert set(mon.dead_hosts()) == {0, 1, 2, 3}


def test_heartbeat_real_clock_not_all_dead():
    """The literal seed bug: constructing against time.monotonic() made
    ``dead_hosts()`` return every host immediately."""
    mon = fault.HeartbeatMonitor(4, timeout_s=60)
    assert mon.dead_hosts() == []


def test_straggler_detection():
    clk = FakeClock()
    mon = fault.HeartbeatMonitor(3, timeout_s=100, clock=clk)
    mon.beat(0, 10)
    mon.beat(1, 10)
    mon.beat(2, 7)  # 3 steps behind
    assert mon.stragglers(lag=2) == [2]
    assert mon.stragglers(lag=4) == []


# ---------------------------------------------------------------------------
# Deterministic shard assignment (now uneven-safe)
# ---------------------------------------------------------------------------


def test_shard_assignment_partition():
    """Every shard assigned exactly once per step, rotating across steps."""
    H, S = 4, 16
    for step in range(5):
        seen = []
        for h in range(H):
            seen += fault.shard_for(step, h, H, S)
        assert sorted(seen) == list(range(S))
    a0 = fault.shard_for(0, 0, H, S)
    a1 = fault.shard_for(1, 0, H, S)
    assert a0 != a1  # rotation


def test_shard_assignment_uneven():
    """Seed regression: ``assert num_shards % num_hosts == 0`` crashed the
    elastic 8->7 remesh that the recovery path exists to serve.  Uneven
    counts must stay a partition with per-host load within one shard."""
    for H, S in [(7, 8), (3, 8), (5, 16), (8, 3), (4, 1)]:
        for step in range(3):
            seen = []
            loads = []
            for h in range(H):
                owned = fault.shard_for(step, h, H, S)
                seen += owned
                loads.append(len(owned))
            assert sorted(seen) == list(range(S)), (H, S, step)
            assert max(loads) - min(loads) <= 1, (H, S, step, loads)
    # backup assignment survives the uneven case too (the seed assert
    # lived on the recovery path)
    backup, shards = fault.backup_assignment(0, 6, 7, 8)
    assert backup == 0 and shards == fault.shard_for(0, 6, 7, 8)


def test_shard_assignment_invalid_inputs():
    with pytest.raises(ValueError):
        fault.shard_for(0, 0, 0, 8)
    with pytest.raises(ValueError):
        fault.shard_for(0, 4, 4, 8)
    with pytest.raises(ValueError):
        fault.shard_for(0, -1, 4, 8)
    with pytest.raises(ValueError):
        fault.shard_for(0, 0, 4, -1)
    with pytest.raises(ValueError):
        fault.backup_assignment(0, 0, 1, 4)
    with pytest.raises(ValueError):
        fault.backup_assignment(0, 5, 4, 8)


def test_backup_assignment_is_deterministic():
    b1 = fault.backup_assignment(3, dead_host=1, num_hosts=4, num_shards=16)
    b2 = fault.backup_assignment(3, dead_host=1, num_hosts=4, num_shards=16)
    assert b1 == b2
    backup, shards = b1
    assert backup == 2
    assert shards == fault.shard_for(3, 1, 4, 16)
    # the alive filter skips dead candidates deterministically
    backup_alive, _ = fault.backup_assignment(3, 1, 4, 16, alive=[0, 3])
    assert backup_alive == 3


def test_shard_assignment_properties_hypothesis():
    pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (pip install -r "
               "requirements-dev.txt)")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=200, deadline=None)
    @given(step=st.integers(0, 50), num_hosts=st.integers(1, 12),
           num_shards=st.integers(0, 64))
    def check(step, num_hosts, num_shards):
        per_host = [fault.shard_for(step, h, num_hosts, num_shards)
                    for h in range(num_hosts)]
        # partition: every shard owned exactly once
        flat = sorted(s for owned in per_host for s in owned)
        assert flat == list(range(num_shards))
        # balance: within one shard of the uniform share
        loads = [len(o) for o in per_host]
        assert max(loads) - min(loads) <= 1
        # rotation is a pure shift: step+num_hosts reproduces step
        assert per_host == [
            fault.shard_for(step + num_hosts, h, num_hosts, num_shards)
            for h in range(num_hosts)]
        # and any host can recompute any other host's assignment
        if num_hosts > 1:
            dead = step % num_hosts
            backup, shards = fault.backup_assignment(
                step, dead, num_hosts, num_shards)
            assert backup != dead
            assert shards == per_host[dead]

    check()


def test_data_pipeline_statelessness():
    dc = pipeline.DataConfig(seed=7, global_batch=8, seq_len=16)
    b1 = pipeline.global_batch(dc, step=42)
    b2 = pipeline.global_batch(dc, step=42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # host batches tile the global batch
    parts = [pipeline.host_batch(dc, 42, h, 4)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_restart_policy():
    p = fault.RestartPolicy(max_restarts=2)
    assert p.on_failure() and p.on_failure()
    assert not p.on_failure()


# ---------------------------------------------------------------------------
# run_resilient: in-process recovery drills (single device, no mesh —
# the driver's shard partials and merges never need collectives)
# ---------------------------------------------------------------------------


VOCAB = 48


class WC(MapReduceApp):
    key_space = VOCAB
    value_aval = jax.ShapeDtypeStruct((), jnp.int32)
    max_values_per_key = 256
    emit_capacity = 8

    def map(self, item, emit):
        emit(item, jnp.ones_like(item))

    def reduce(self, key, values, count):
        return jnp.sum(values)


def _tokens(n_items=64):
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(0, VOCAB, (n_items, 8)).astype(np.int32))


def _dense(keys, values, counts):
    got = np.zeros(VOCAB, np.int64)
    for k, v, c in zip(np.asarray(keys), np.asarray(values),
                       np.asarray(counts)):
        if k < VOCAB and c > 0:
            got[k] = v
    return got


def _bitwise_equal(a, b):
    return all(np.asarray(x).tobytes() == np.asarray(y).tobytes()
               for x, y in zip(a[:3], b[:3]))


def test_resilient_no_failure_all_flows(matrix_flows, matrix_use_kernels):
    toks = _tokens()
    want = np.bincount(np.asarray(toks).reshape(-1), minlength=VOCAB)
    for flow in matrix_flows():
        plan = plan_execution(WC(), flow=flow)
        out = eng.run_resilient(WC(), plan, toks, num_hosts=4, num_shards=4,
                                use_kernels=matrix_use_kernels)
        assert np.array_equal(_dense(*out[:3]), want), flow
        log = out[3]
        assert len(log.computed) == 4 and not log.recomputed


def test_resilient_kill_host_recovery_bitwise(matrix_flows,
                                              matrix_use_kernels):
    """Kill a host (in-memory partials lost, no checkpoints): its shards
    are recomputed on the deterministic backup rank and the answer is
    bitwise the no-failure one."""
    toks = _tokens()
    for flow in matrix_flows():
        base_plan = plan_execution(WC(), flow=flow)
        base = eng.run_resilient(WC(), base_plan, toks, num_hosts=4,
                                 num_shards=8,
                                 use_kernels=matrix_use_kernels)
        plan = plan_execution(WC(), flow=flow)
        out = eng.run_resilient(
            WC(), plan, toks, num_hosts=4, num_shards=8,
            use_kernels=matrix_use_kernels,
            inject=fault.FaultInjection(dead_hosts=(2,)))
        assert _bitwise_equal(base, out), flow
        log = out[3]
        assert log.dead_hosts == [2]
        # host 2 owned shards {s : s % 4 == 2} = {2, 6}; backup rank is 3
        assert log.recomputed == [(2, 3), (6, 3)], log.recomputed
        assert any("recomputed" in e for e in plan.recovery)


def test_resilient_checkpoint_restore(matrix_flows, matrix_use_kernels):
    """Partial-aggregate recovery: a host that checkpointed some shards
    before dying contributes them by RESTORE, not re-execution; the rest
    recompute.  Monoid merge makes the mix bitwise-exact."""
    toks = _tokens()
    for flow in matrix_flows():
        base_plan = plan_execution(WC(), flow=flow)
        base = eng.run_resilient(WC(), base_plan, toks, num_hosts=4,
                                 num_shards=8,
                                 use_kernels=matrix_use_kernels)
        with tempfile.TemporaryDirectory() as d:
            plan = plan_execution(WC(), flow=flow)
            out = eng.run_resilient(
                WC(), plan, toks, num_hosts=4, num_shards=8, ckpt_dir=d,
                use_kernels=matrix_use_kernels,
                inject=fault.FaultInjection(dead_hosts=(1,),
                                            die_after_shards=1))
            assert _bitwise_equal(base, out), flow
            log = out[3]
            # host 1 owned {1, 5}: completed+checkpointed 1, lost 5
            assert log.restored == [1], log.restored
            assert log.recomputed == [(5, 2)], log.recomputed

            # dead disk: the same crash with checkpoint_survives=False
            # falls back to recompute for every lost shard
            plan2 = plan_execution(WC(), flow=flow)
            out2 = eng.run_resilient(
                WC(), plan2, toks, num_hosts=4, num_shards=8,
                ckpt_dir=os.path.join(d, "gone"),
                use_kernels=matrix_use_kernels,
                inject=fault.FaultInjection(dead_hosts=(1,),
                                            die_after_shards=1,
                                            checkpoint_survives=False))
            assert _bitwise_equal(base, out2), flow
            assert not out2[3].restored
            assert [s for s, _ in out2[3].recomputed] == [1, 5]


def test_resilient_straggler_speculation(matrix_flows, matrix_use_kernels):
    """A lagging host's shards are speculatively re-executed on the
    deterministic backup rank (next alive, non-straggler rank)."""
    toks = _tokens()
    for flow in matrix_flows():
        base_plan = plan_execution(WC(), flow=flow)
        base = eng.run_resilient(WC(), base_plan, toks, num_hosts=4,
                                 num_shards=4,
                                 use_kernels=matrix_use_kernels)
        plan = plan_execution(WC(), flow=flow)
        out = eng.run_resilient(
            WC(), plan, toks, num_hosts=4, num_shards=4,
            use_kernels=matrix_use_kernels,
            inject=fault.FaultInjection(straggler_hosts=(1,)))
        assert _bitwise_equal(base, out), flow
        log = out[3]
        assert log.straggler_hosts == [1]
        assert log.speculated == [(1, 2)], log.speculated  # next alive rank
        assert any("speculatively" in e for e in plan.recovery)


def test_resilient_elastic_shrink_uneven(matrix_flows, matrix_use_kernels):
    """Elastic 4 -> 3 hosts with the shard count FIXED at 4 (the all-to-all
    key ranges are the re-partition boundary): the uneven 4-shards-over-
    3-hosts assignment — which crashed the seed's shard_for — re-runs only
    the shards whose partials left with the removed host."""
    toks = _tokens()
    for flow in matrix_flows():
        base_plan = plan_execution(WC(), flow=flow)
        base = eng.run_resilient(WC(), base_plan, toks, num_hosts=4,
                                 num_shards=4,
                                 use_kernels=matrix_use_kernels)
        plan = plan_execution(WC(), flow=flow)
        out = eng.run_resilient(
            WC(), plan, toks, num_hosts=4, num_shards=4,
            use_kernels=matrix_use_kernels,
            inject=fault.FaultInjection(resize_to=3))
        assert _bitwise_equal(base, out), flow
        log = out[3]
        assert log.resized == (4, 3)
        # only host 3's shard (shard 3) was lost and re-run
        assert [s for s, _ in log.recomputed] == [3], log.recomputed
        assert any("elastic resize" in e for e in plan.recovery)


def test_resilient_uneven_split_no_false_stragglers():
    """An uneven shard/host split (6 shards over 4 hosts) legitimately
    gives some hosts one fewer shard — finishing a smaller assignment must
    not read as straggling (or shrink the backup pool) on a fault-free
    run."""
    toks = _tokens(60)  # 60 items over 6 shards
    want = np.bincount(np.asarray(toks).reshape(-1), minlength=VOCAB)
    plan = plan_execution(WC(), flow="stream")
    out = eng.run_resilient(WC(), plan, toks, num_hosts=4, num_shards=6)
    assert np.array_equal(_dense(*out[:3]), want)
    log = out[3]
    assert log.straggler_hosts == [] and not log.speculated, (
        log.straggler_hosts, log.speculated)
    assert not log.recomputed and len(log.computed) == 6


def test_resilient_validates_inputs():
    toks = _tokens(60)  # 60 items do not divide into 8 shards
    plan = plan_execution(WC(), flow="stream")
    with pytest.raises(ValueError, match="divide"):
        eng.run_resilient(WC(), plan, toks, num_hosts=8, num_shards=8)
    with pytest.raises(ValueError, match="positive"):
        eng.run_resilient(WC(), plan, _tokens(), num_hosts=0)


# ---------------------------------------------------------------------------
# run_resilient vs run_distributed: bitwise parity on a fake 8-device mesh
# (subprocess so the main process keeps seeing one device)
# ---------------------------------------------------------------------------


def test_resilient_bitwise_vs_distributed_mesh():
    """The acceptance bar: with a killed shard, a straggler, or a restored
    checkpoint, ``run_resilient`` reproduces the fault-free
    ``run_distributed`` output bit for bit, for stream, sort and reduce.
    Honors the flow-matrix overrides (REPRO_TEST_FLOW restricts the flow
    list; REPRO_TEST_KERNELS flips the lowering)."""
    out = run_with_devices("""
        import os, tempfile, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import MapReduceApp, plan_execution
        from repro.core import engine as eng
        from repro.distributed import fault as flt

        UK = os.environ.get("REPRO_TEST_KERNELS", "").lower() not in (
            "", "0", "false", "no")
        OVR = os.environ.get("REPRO_TEST_FLOW", "").strip().lower()
        FLOWS = (OVR,) if OVR in ("stream", "sort", "reduce") else (
            "stream", "sort", "reduce")

        VOCAB = 48
        class WC(MapReduceApp):
            key_space = VOCAB
            value_aval = jax.ShapeDtypeStruct((), jnp.int32)
            max_values_per_key = 256
            emit_capacity = 8
            def map(self, item, emit): emit(item, jnp.ones_like(item))
            def reduce(self, key, values, count): return jnp.sum(values)

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        toks = jax.device_put(
            jnp.asarray(rng.integers(0, VOCAB, (64, 8)).astype(np.int32)),
            NamedSharding(mesh, P("data")))
        app = WC()

        def bits(arrs):
            return [np.asarray(a).tobytes() for a in arrs]

        for flow in FLOWS:
            with mesh:
                plan0 = plan_execution(app, flow=flow)
                ref = bits(eng.run_distributed(app, plan0, toks, mesh=mesh,
                                               use_kernels=UK))

            # kill-a-shard: host 3 dies, backup rank 4 recomputes
            plan1 = plan_execution(app, flow=flow)
            k, v, c, log = eng.run_resilient(
                app, plan1, toks, mesh=mesh, use_kernels=UK,
                inject=flt.FaultInjection(dead_hosts=(3,)))
            assert bits((k, v, c)) == ref, ("kill", flow)
            assert log.recomputed == [(3, 4)], (flow, log.recomputed)

            # straggler: host 2 lags, rank 3 speculatively re-executes
            plan2 = plan_execution(app, flow=flow)
            k, v, c, log = eng.run_resilient(
                app, plan2, toks, mesh=mesh, use_kernels=UK,
                inject=flt.FaultInjection(straggler_hosts=(2,)))
            assert bits((k, v, c)) == ref, ("straggler", flow)
            assert log.speculated == [(2, 3)], (flow, log.speculated)

            # partial-aggregate restore: run once to checkpoint all 8
            # partials, then kill host 3 — its shard must come back by
            # RESTORE (not re-execution) and stay bitwise-exact
            with tempfile.TemporaryDirectory() as d:
                plan3 = plan_execution(app, flow=flow)
                eng.run_resilient(app, plan3, toks, mesh=mesh,
                                  use_kernels=UK, ckpt_dir=d)
                plan4 = plan_execution(app, flow=flow)
                k, v, c, log = eng.run_resilient(
                    app, plan4, toks, mesh=mesh, use_kernels=UK,
                    ckpt_dir=d,
                    inject=flt.FaultInjection(dead_hosts=(3,)))
                assert bits((k, v, c)) == ref, ("restore", flow)
                assert log.restored == [3] and not log.recomputed, (
                    flow, log.restored, log.recomputed)
            print("RESILIENT_BITWISE_OK", flow)
    """, n=8)
    assert out.count("RESILIENT_BITWISE_OK") >= 1


def test_resilient_elastic_remesh_8_to_4_mesh():
    """Elastic 8 -> 4 remesh: ``best_mesh`` rebuilds the data mesh over the
    surviving devices, the shard count (== all-to-all key ranges) stays 8,
    and only the shards whose partials left with the removed hosts re-run
    — the answer still bitwise-matches the fault-free 8-wide run.  The
    MapReduce API surface (run_resilient + explain) is exercised too."""
    out = run_with_devices("""
        import os, tempfile, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import MapReduce, MapReduceApp, plan_execution
        from repro.core import engine as eng
        from repro.distributed import fault as flt

        UK = os.environ.get("REPRO_TEST_KERNELS", "").lower() not in (
            "", "0", "false", "no")
        OVR = os.environ.get("REPRO_TEST_FLOW", "").strip().lower()
        FLOWS = (OVR,) if OVR in ("stream", "sort", "reduce") else (
            "stream", "sort", "reduce")

        VOCAB = 48
        class WC(MapReduceApp):
            key_space = VOCAB
            value_aval = jax.ShapeDtypeStruct((), jnp.int32)
            max_values_per_key = 256
            emit_capacity = 8
            def map(self, item, emit): emit(item, jnp.ones_like(item))
            def reduce(self, key, values, count): return jnp.sum(values)

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        toks = jax.device_put(
            jnp.asarray(rng.integers(0, VOCAB, (64, 8)).astype(np.int32)),
            NamedSharding(mesh, P("data")))
        app = WC()

        def bits(arrs):
            return [np.asarray(a).tobytes() for a in arrs]

        for flow in FLOWS:
            with mesh:
                plan0 = plan_execution(app, flow=flow)
                ref = bits(eng.run_distributed(app, plan0, toks, mesh=mesh,
                                               use_kernels=UK))
            # some partials checkpointed before the resize -> restored on
            # the shrunken cluster instead of re-executed
            with tempfile.TemporaryDirectory() as d:
                plan1 = plan_execution(app, flow=flow)
                eng.run_resilient(app, plan1, toks, mesh=mesh,
                                  use_kernels=UK, ckpt_dir=d)
                plan2 = plan_execution(app, flow=flow)
                k, v, c, log = eng.run_resilient(
                    app, plan2, toks, mesh=mesh, use_kernels=UK,
                    ckpt_dir=d, inject=flt.FaultInjection(resize_to=4))
                assert bits((k, v, c)) == ref, ("resize+ckpt", flow)
                assert log.resized == (8, 4)
                assert log.restored == [4, 5, 6, 7], log.restored
            # without checkpoints the moved shards re-run on their new
            # owners (shard s -> host s % 4)
            plan3 = plan_execution(app, flow=flow)
            k, v, c, log = eng.run_resilient(
                app, plan3, toks, mesh=mesh, use_kernels=UK,
                inject=flt.FaultInjection(resize_to=4))
            assert bits((k, v, c)) == ref, ("resize", flow)
            assert log.resized == (8, 4)
            assert log.moved == [4, 5, 6, 7], log.moved
            assert log.recomputed == [(4, 0), (5, 1), (6, 2), (7, 3)]
            assert log.final_mesh.shape["data"] == 4
            print("ELASTIC_RESILIENT_OK", flow)

        # the thin API surface: MapReduce(...).run_resilient + explain
        from repro.core import ExecutionOptions
        mr = MapReduce(app, flow="stream")
        res = mr.run_resilient(toks, mesh=mesh, options=ExecutionOptions(
            inject=flt.FaultInjection(dead_hosts=(1,))))
        want = np.bincount(np.asarray(toks).reshape(-1), minlength=VOCAB)
        assert np.array_equal(np.asarray(res.values), want)
        assert res.recovery.recomputed == [(1, 2)]
        assert "recovery:" in mr.explain()
        print("API_RESILIENT_OK")
    """, n=8)
    assert out.count("ELASTIC_RESILIENT_OK") >= 1
    assert "API_RESILIENT_OK" in out
