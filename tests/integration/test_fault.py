"""Fault tolerance: heartbeats, stragglers, deterministic shard assignment,
and the stateless data pipeline they rely on."""

import numpy as np

from repro.data import pipeline
from repro.distributed import fault


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_death_detection():
    clk = FakeClock()
    mon = fault.HeartbeatMonitor(4, timeout_s=10, clock=clk)
    for h in range(4):
        mon.beat(h, step=0)
    clk.t = 5
    for h in (0, 1, 2):
        mon.beat(h, step=1)
    clk.t = 12  # host 3 silent for 12s
    assert mon.dead_hosts() == [3]
    assert sorted(mon.alive_hosts()) == [0, 1, 2]


def test_straggler_detection():
    clk = FakeClock()
    mon = fault.HeartbeatMonitor(3, timeout_s=100, clock=clk)
    mon.beat(0, 10)
    mon.beat(1, 10)
    mon.beat(2, 7)  # 3 steps behind
    assert mon.stragglers(lag=2) == [2]
    assert mon.stragglers(lag=4) == []


def test_shard_assignment_partition():
    """Every shard assigned exactly once per step, rotating across steps."""
    H, S = 4, 16
    for step in range(5):
        seen = []
        for h in range(H):
            seen += fault.shard_for(step, h, H, S)
        assert sorted(seen) == list(range(S))
    a0 = fault.shard_for(0, 0, H, S)
    a1 = fault.shard_for(1, 0, H, S)
    assert a0 != a1  # rotation


def test_backup_assignment_is_deterministic():
    b1 = fault.backup_assignment(3, dead_host=1, num_hosts=4, num_shards=16)
    b2 = fault.backup_assignment(3, dead_host=1, num_hosts=4, num_shards=16)
    assert b1 == b2
    backup, shards = b1
    assert backup == 2
    assert shards == fault.shard_for(3, 1, 4, 16)


def test_data_pipeline_statelessness():
    dc = pipeline.DataConfig(seed=7, global_batch=8, seq_len=16)
    b1 = pipeline.global_batch(dc, step=42)
    b2 = pipeline.global_batch(dc, step=42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # host batches tile the global batch
    parts = [pipeline.host_batch(dc, 42, h, 4)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_restart_policy():
    p = fault.RestartPolicy(max_restarts=2)
    assert p.on_failure() and p.on_failure()
    assert not p.on_failure()
