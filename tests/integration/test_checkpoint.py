"""Checkpoint/restart: atomicity, keep-N, async, restore-into-structure."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt


def tree(seed):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.standard_normal((4, 4)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.integers(0, 9, 3), jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    t = tree(0)
    ckpt.save(str(tmp_path), 10, t)
    assert ckpt.latest_step(str(tmp_path)) == 10
    got, step = ckpt.restore(str(tmp_path), t)
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_n_gc(tmp_path):
    for s in range(6):
        ckpt.save(str(tmp_path), s, tree(s), keep=2)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [4, 5]
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_restore_specific_step(tmp_path):
    for s in (1, 2, 3):
        ckpt.save(str(tmp_path), s, tree(s), keep=10)
    got, step = ckpt.restore(str(tmp_path), tree(0), step=2)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(tree(2)["a"]))


def test_async_checkpointer(tmp_path):
    ac = ckpt.AsyncCheckpointer(str(tmp_path), keep=3)
    for s in range(3):
        ac.submit(s, tree(s))
    ac.close()
    assert ckpt.latest_step(str(tmp_path)) == 2
    got, _ = ckpt.restore(str(tmp_path), tree(0))
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(tree(2)["a"]))


def test_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path / "nope"), tree(0))


def test_train_resume_equivalence(tmp_path):
    """Train 4 steps straight == train 2, checkpoint, restore, train 2."""
    from repro.configs import get_config
    from repro.models.registry import get_model
    from repro.training.train_step import (TrainConfig, init_train_state,
                                           make_train_step)

    cfg = get_config("llama3-8b").reduced()
    model = get_model(cfg)
    tc = TrainConfig(vocab_chunk=64, warmup_steps=1, total_steps=50)
    step = jax.jit(make_train_step(model, tc))
    rng = jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(rng, (2, 8), 0, cfg.vocab_size),
             "labels": jax.random.randint(rng, (2, 8), 0, cfg.vocab_size)}

    s = init_train_state(model, rng)
    for _ in range(4):
        s, m_straight = step(s, batch)

    s2 = init_train_state(model, rng)
    for _ in range(2):
        s2, _ = step(s2, batch)
    ckpt.save(str(tmp_path), 2, s2)
    s3, _ = ckpt.restore(str(tmp_path), s2)
    for _ in range(2):
        s3, m_resumed = step(s3, batch)

    np.testing.assert_allclose(float(m_straight["loss"]),
                               float(m_resumed["loss"]), rtol=1e-5)
