"""Multi-device behaviour, via subprocesses with fake CPU devices (the main
test process must keep seeing ONE device)."""

from _subproc import run_with_devices


def test_distributed_engine_flows():
    """combine flow (all-reduce of O(K) tables) and reduce flow (all-to-all
    of O(N) pairs) both match ground truth on a 4-device mesh, and lower to
    exactly the expected collectives."""
    out = run_with_devices("""
        import numpy as np, re, jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import MapReduceApp, plan_execution
        from repro.core import engine as eng

        VOCAB = 48
        class WC(MapReduceApp):
            key_space = VOCAB
            value_aval = jax.ShapeDtypeStruct((), jnp.int32)
            max_values_per_key = 256
            emit_capacity = 8
            def map(self, item, emit): emit(item, jnp.ones_like(item))
            def reduce(self, key, values, count): return jnp.sum(values)

        mesh = jax.make_mesh((4,), ("data",))
        rng = np.random.default_rng(0)
        toks = jax.device_put(
            jnp.asarray(rng.integers(0, VOCAB, (64, 8)).astype(np.int32)),
            NamedSharding(mesh, P("data")))
        want = np.bincount(np.asarray(toks).reshape(-1), minlength=VOCAB)
        app = WC()
        with mesh:
            plan_c = plan_execution(app, flow="auto")
            k, v, c = eng.run_distributed(app, plan_c, toks, mesh=mesh)
            assert np.array_equal(np.asarray(v), want)
            plan_r = plan_execution(app, flow="reduce")
            k2, v2, c2 = eng.run_distributed(app, plan_r, toks, mesh=mesh)
            got = np.zeros(VOCAB, np.int64)
            for kk, vv, cc in zip(np.asarray(k2), np.asarray(v2), np.asarray(c2)):
                if kk < VOCAB and cc > 0: got[kk] = vv
            assert np.array_equal(got, want)
            t_c = jax.jit(partial(eng.run_distributed, app, plan_c, mesh=mesh)).lower(toks).compile().as_text()
            t_r = jax.jit(partial(eng.run_distributed, app, plan_r, mesh=mesh)).lower(toks).compile().as_text()
        assert "all-reduce" in t_c and "all-to-all" not in t_c
        assert "all-to-all" in t_r
        print("DIST_OK")
    """)
    assert "DIST_OK" in out


def test_distributed_sort_flow():
    """Sort flow on a 4-device mesh: the reduce-flow key-partitioned
    all-to-all (shard ranges == top-level radix buckets) feeding the local
    sort collector — same answer, key-sharded output, O(N) wire traffic."""
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import MapReduceApp, plan_execution
        from repro.core import engine as eng

        VOCAB = 48
        class WC(MapReduceApp):
            key_space = VOCAB
            value_aval = jax.ShapeDtypeStruct((), jnp.int32)
            max_values_per_key = 256
            emit_capacity = 8
            def map(self, item, emit): emit(item, jnp.ones_like(item))
            def reduce(self, key, values, count): return jnp.sum(values)

        mesh = jax.make_mesh((4,), ("data",))
        rng = np.random.default_rng(0)
        toks = jax.device_put(
            jnp.asarray(rng.integers(0, VOCAB, (64, 8)).astype(np.int32)),
            NamedSharding(mesh, P("data")))
        want = np.bincount(np.asarray(toks).reshape(-1), minlength=VOCAB)
        app = WC()
        with mesh:
            plan_s = plan_execution(app, flow="sort")
            k, v, c = eng.run_distributed(app, plan_s, toks, mesh=mesh)
            got = np.zeros(VOCAB, np.int64)
            for kk, vv, cc in zip(np.asarray(k), np.asarray(v), np.asarray(c)):
                if kk < VOCAB and cc > 0: got[kk] = vv
            assert np.array_equal(got, want)
            txt = jax.jit(partial(eng.run_distributed, app, plan_s,
                                  mesh=mesh)).lower(toks).compile().as_text()
        assert "all-to-all" in txt and "all-reduce" not in txt
        print("DIST_SORT_OK")
    """)
    assert "DIST_SORT_OK" in out


def test_distributed_sort_flow_hierarchical_kernels():
    """Multi-level sort flow on a 4-device mesh with the kernel pipeline:
    the shard key ranges are the hierarchy's top-level digits (the
    all-to-all wire format is unchanged), and each shard re-derives the
    remaining level decomposition for its own K/S range — shrunk budgets
    force two levels per shard."""
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import MapReduceApp, plan_execution
        from repro.core import engine as eng
        from repro.kernels import ops

        ops.LEAF_BUCKET_CAP = 64   # per-shard K/S = 1024 -> 16 leaves
        ops.MAX_RADIX_FANOUT = 4   # -> 2 levels of fan-out 4
        VOCAB = 4096
        plan_local = ops.plan_radix_levels(VOCAB // 4, d=2)
        assert plan_local.levels == 2, plan_local

        class WC(MapReduceApp):
            key_space = VOCAB
            value_aval = jax.ShapeDtypeStruct((), jnp.float32)
            max_values_per_key = 256
            emit_capacity = 8
            def map(self, item, emit):
                emit(item, jnp.ones_like(item, jnp.float32))
            def reduce(self, key, values, count): return jnp.sum(values)

        mesh = jax.make_mesh((4,), ("data",))
        rng = np.random.default_rng(1)
        toks = jax.device_put(
            jnp.asarray(rng.integers(0, VOCAB, (64, 8)).astype(np.int32)),
            NamedSharding(mesh, P("data")))
        want = np.bincount(np.asarray(toks).reshape(-1), minlength=VOCAB)
        app = WC()
        with mesh:
            plan_s = plan_execution(app, flow="sort")
            k, v, c = eng.run_distributed(app, plan_s, toks, mesh=mesh,
                                          use_kernels=True)
        got = np.zeros(VOCAB, np.int64)
        for kk, vv, cc in zip(np.asarray(k), np.asarray(v), np.asarray(c)):
            if kk < VOCAB and cc > 0: got[kk] = vv
        assert np.array_equal(got, want)
        print("DIST_SORT_MULTI_OK")
    """)
    assert "DIST_SORT_MULTI_OK" in out


def test_distributed_stream_per_shard_autotune():
    """run_distributed re-derives the streaming tiling from the per-shard
    item count (ROADMAP open item) instead of reusing a global tiling."""
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import MapReduceApp, plan_execution
        from repro.core import autotune as at
        from repro.core import engine as eng

        VOCAB = 4096
        class WC(MapReduceApp):
            key_space = VOCAB
            value_aval = jax.ShapeDtypeStruct((), jnp.int32)
            max_values_per_key = 256
            emit_capacity = 8
            def map(self, item, emit): emit(item, jnp.ones_like(item))
            def reduce(self, key, values, count): return jnp.sum(values)

        app = WC()
        mesh = jax.make_mesh((4,), ("data",))
        rng = np.random.default_rng(0)
        toks = jax.device_put(
            jnp.asarray(rng.integers(0, VOCAB, (256, 8)).astype(np.int32)),
            NamedSharding(mesh, P("data")))
        want = np.bincount(np.asarray(toks).reshape(-1), minlength=VOCAB)
        with mesh:
            plan = plan_execution(app, flow="auto")
            # default (chunk_pairs=None): per-shard autotune, answer exact
            k, v, c = eng.run_distributed(app, plan, toks, mesh=mesh)
            assert np.array_equal(np.asarray(v), want)
        # the per-shard hint changes the derived tiling vs the global one
        t_global = at.autotune_stream(app, plan.spec,
                                      n_pairs_hint=256 * 8)
        t_shard = at.autotune_stream(app, plan.spec,
                                     n_pairs_hint=(256 // 4) * 8)
        assert t_shard.chunk_pairs <= t_global.chunk_pairs
        print("SHARD_TUNE_OK")
    """)
    assert "SHARD_TUNE_OK" in out


def test_shuffle_overflow_skew_regression():
    """Seed regression: ``_shuffle_pairs`` silently dropped pairs past the
    per-destination capacity ``B`` — a skewed key distribution (every pair
    on one key) returned WRONG distributed reduce/sort results with no
    signal.  The shuffle now counts the overflow, fires a
    LoweringFallbackWarning with the per-shard counts in
    ``plan.diagnostics``, and raises under ``strict_shuffle=True``; the
    resilient driver's ledger records the same counters."""
    out = run_with_devices("""
        import warnings, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import MapReduceApp, plan_execution
        from repro.core import LoweringFallbackWarning
        from repro.core import engine as eng

        VOCAB = 32
        class Skew(MapReduceApp):
            key_space = VOCAB
            value_aval = jax.ShapeDtypeStruct((), jnp.int32)
            max_values_per_key = 1024
            emit_capacity = 8
            def map(self, item, emit):
                emit(jnp.zeros_like(item), jnp.ones_like(item))  # all key 0
            def reduce(self, key, values, count): return jnp.sum(values)

        mesh = jax.make_mesh((4,), ("data",))
        toks = jax.device_put(jnp.zeros((64, 8), jnp.int32),
                              NamedSharding(mesh, P("data")))
        app = Skew()
        for flow in ("reduce", "sort"):
            with mesh:
                plan = plan_execution(app, flow=flow)
                with warnings.catch_warnings(record=True) as w:
                    warnings.simplefilter("always")
                    eng.run_distributed(app, plan, toks, mesh=mesh)
                msgs = [str(x.message) for x in w
                        if issubclass(x.category, LoweringFallbackWarning)]
                # seed behavior: no warning, silently wrong counts
                assert any("overflow" in m for m in msgs), (flow, msgs)
                assert any("overflow" in d for d in plan.diagnostics)

                plan2 = plan_execution(app, flow=flow)
                try:
                    eng.run_distributed(app, plan2, toks, mesh=mesh,
                                        strict_shuffle=True)
                    raise SystemExit(f"strict did not raise for {flow}")
                except ValueError as e:
                    assert "overflow" in str(e)

                # a capacity that fits the skew keeps the answer exact and
                # quiet (the overflow counter reads zero)
                plan3 = plan_execution(app, flow=flow)
                with warnings.catch_warnings(record=True) as w3:
                    warnings.simplefilter("always")
                    k, v, c = eng.run_distributed(
                        app, plan3, toks, mesh=mesh,
                        shuffle_capacity=64 * 8,
                        strict_shuffle=True)
                assert not [x for x in w3
                            if issubclass(x.category,
                                          LoweringFallbackWarning)]
                got = {int(kk): int(vv) for kk, vv, cc in
                       zip(np.asarray(k), np.asarray(v), np.asarray(c))
                       if kk < VOCAB and cc > 0}
                assert got == {0: 64 * 8}, got

                # overflow must stay loud even when an earlier lowering
                # fallback already spent the plan's once-per-plan warning
                # latch — it signals WRONG OUTPUT, not a lowering downgrade
                plan3b = plan_execution(app, flow=flow)
                plan3b._fallback_warned = True
                with warnings.catch_warnings(record=True) as w3b:
                    warnings.simplefilter("always")
                    eng.run_distributed(app, plan3b, toks, mesh=mesh)
                assert any("overflow" in str(x.message) for x in w3b
                           if issubclass(x.category,
                                         LoweringFallbackWarning)), flow

            # the resilient driver surfaces the same counters
            plan4 = plan_execution(app, flow=flow)
            with warnings.catch_warnings(record=True) as w4:
                warnings.simplefilter("always")
                _, _, _, log = eng.run_resilient(
                    app, plan4, toks, mesh=mesh)
            assert sum(log.shuffle_overflow) > 0
            assert any("overflow" in str(x.message) for x in w4
                       if issubclass(x.category, LoweringFallbackWarning))
            print("SKEW_OK", flow)
    """)
    assert out.count("SKEW_OK") == 2


def test_elastic_reshard_8_to_4():
    """Checkpoint on an (4,2) mesh, restore resharded onto (2,2)."""
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp, tempfile, os
        from repro.checkpoint import ckpt
        from repro.distributed import elastic, sharding as shd
        from repro.configs import get_config
        from repro.models.registry import get_model

        cfg = get_config("llama3-8b").reduced()
        model = get_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))

        mesh8 = jax.make_mesh((4, 2), ("data", "model"))
        sh8 = shd.param_shardings(params, mesh8)
        p8 = jax.tree.map(jax.device_put, params, sh8)
        d = tempfile.mkdtemp()
        ckpt.save(d, 5, p8)

        # "lose half the fleet": remesh over 4 devices
        mesh4 = jax.make_mesh((2, 2), ("data", "model"))
        import numpy as _np
        from jax.sharding import Mesh
        mesh4 = Mesh(_np.asarray(jax.devices()[:4]).reshape(2, 2),
                     ("data", "model"))
        restored, step = elastic.elastic_restore(d, params, mesh4)
        assert step == 5
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("ELASTIC_OK")
    """, n=8)
    assert "ELASTIC_OK" in out


def test_compressed_psum_wire_dtype():
    """int8 compressed all-reduce moves int8 on the wire and approximates
    the exact sum."""
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import compressed_psum

        mesh = jax.make_mesh((4,), ("d",))
        x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 64)),
                        jnp.float32)
        f = shard_map(lambda a: compressed_psum(a[0], "d"), mesh=mesh,
                      in_specs=(P("d"),), out_specs=P(), check_rep=False)
        with mesh:
            got = jax.jit(f)(x)
            txt = jax.jit(f).lower(x).compile().as_text()
        want = np.asarray(x).sum(0)
        err = np.abs(np.asarray(got) - want).max()
        scale = np.abs(np.asarray(x)).max(axis=-1).sum() / 127
        assert err <= scale + 1e-5, (err, scale)
        assert "s8[" in txt and "all-gather" in txt
        print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out


def test_dryrun_smallmesh_train_and_decode():
    """The dry-run builder lowers + compiles on a small fake mesh (fast
    proxy for the 512-chip run, exercised fully by launch/dryrun.py)."""
    out = run_with_devices("""
        import jax
        from repro.launch.dryrun import build_cell
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(2, 2)
        with mesh:
            for arch, shape in [("llama3-8b", "train_4k"),
                                ("qwen3-moe-30b-a3b", "decode_32k")]:
                fn, avals = build_cell(arch, shape, mesh, microbatches=4)
                c = fn.lower(*avals).compile()
                assert c.memory_analysis().temp_size_in_bytes > 0
                print("CELL_OK", arch, shape)
    """, n=4, timeout=560)
    assert out.count("CELL_OK") == 2
