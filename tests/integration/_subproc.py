"""Shared subprocess runner for multi-device integration tests.

The main pytest process must keep seeing ONE device, so anything needing a
fake multi-device topology runs in a subprocess with
``--xla_force_host_platform_device_count`` (used by test_distributed.py
and the test_fault.py recovery drills).
"""

import os
import subprocess
import sys
import textwrap

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..",
                                   "src"))


def run_with_devices(code: str, n: int = 4, timeout: int = 420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout
