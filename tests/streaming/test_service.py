"""MapReduceService: incremental-fold parity (N ingests ≡ one batch run,
bitwise), zero re-trace/re-tune/re-compile steady state, window expiry,
snapshot-under-ingestion consistency, and checkpointed warm restarts."""

import tempfile
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ExecutionOptions,
    MapReduce,
    MapReduceResult,
    make_app,
)
from repro.core import plan_cache as pc
from repro.core.plan import plan_execution
from repro.streaming import IngestionQueue, sliding, tumbling

I32 = jnp.int32
F32 = jnp.float32
VOCAB = 64
B = 64  # micro-batch capacity used throughout


def kv_app(reduce_fn, value_aval):
    """(keys, values) item pairs -> reduce over values per key."""
    return make_app(
        map_fn=lambda item, emit: emit(item[0], item[1]),
        reduce_fn=reduce_fn,
        key_space=VOCAB,
        value_aval=value_aval,
        emit_capacity=1,
    )


def wc_app():
    """Scalar token items -> (token, 1) word count."""
    return make_app(
        map_fn=lambda item, emit: emit(item % VOCAB, jnp.ones((), I32)),
        reduce_fn=lambda k, vs, n: vs.sum(),
        key_space=VOCAB,
        value_aval=jax.ShapeDtypeStruct((), I32),
        emit_capacity=1,
    )


def kv_batches(rng, n_batches, *, dtype=np.float32, width=()):
    out = []
    for _ in range(n_batches):
        keys = rng.integers(0, VOCAB, size=B).astype(np.int32)
        if np.issubdtype(dtype, np.integer):
            vals = rng.integers(-50, 50, size=(B,) + width).astype(dtype)
        else:
            vals = rng.standard_normal((B,) + width).astype(dtype)
        out.append((jnp.asarray(keys), jnp.asarray(vals)))
    return out


def concat(batches):
    return jax.tree.map(lambda *xs: jnp.concatenate(xs), *batches)


def batch_reference(app, batches):
    """One batch run over the concatenated items with the chunk boundary
    aligned to the micro-batch size — the bitwise reference.  flow is
    forced so the reference stays on the stream fold under every
    REPRO_TEST_FLOW matrix leg (the service side is pinned by design)."""
    cap = max(app.emit_capacity, 1)
    mr = MapReduce(app, flow="stream")
    return mr.run(concat(batches),
                  options=ExecutionOptions(chunk_pairs=B * cap))


def count_of(res, key):
    """Per-key count lookup that doesn't assume identity key order."""
    keys = np.asarray(res.keys)
    counts = np.asarray(res.counts)
    (idx,) = np.nonzero(keys == key)
    return int(counts[idx[0]]) if idx.size else 0


# ---------------------------------------------------------------------------
# Incremental-fold parity across the derivable spec matrix
# ---------------------------------------------------------------------------

SPECS = {
    "sum_i32": (lambda k, v, c: jnp.sum(v),
                jax.ShapeDtypeStruct((), I32), np.int32, ()),
    "sum_f32": (lambda k, v, c: jnp.sum(v),
                jax.ShapeDtypeStruct((), F32), np.float32, ()),
    "max_f32": (lambda k, v, c: jnp.max(v),
                jax.ShapeDtypeStruct((), F32), np.float32, ()),
    "mean_f32": (lambda k, v, c: jnp.sum(v) / jnp.maximum(c, 1).astype(F32),
                 jax.ShapeDtypeStruct((), F32), np.float32, ()),
    "count": (lambda k, v, c: c,
              jax.ShapeDtypeStruct((), I32), np.int32, ()),
    "vecsum_f32": (lambda k, v, c: jnp.sum(v, axis=0),
                   jax.ShapeDtypeStruct((4,), F32), np.float32, (4,)),
}


@pytest.mark.parametrize("name", sorted(SPECS))
def test_incremental_parity_bitwise(name):
    """N sequential ingests == one batch run over the concatenation,
    bitwise, for every derivable combiner strategy."""
    reduce_fn, aval, dtype, width = SPECS[name]
    rng = np.random.default_rng(1 + sorted(SPECS).index(name))
    batches = kv_batches(rng, 6, dtype=dtype, width=width)

    svc = MapReduce(kv_app(reduce_fn, aval),
                    streaming=True).serve(batch_capacity=B)
    for b in batches:
        svc.ingest(b)
    got = svc.snapshot()

    want = batch_reference(kv_app(reduce_fn, aval), batches)
    np.testing.assert_array_equal(np.asarray(want.keys),
                                  np.asarray(got.keys))
    np.testing.assert_array_equal(np.asarray(want.values),
                                  np.asarray(got.values))
    np.testing.assert_array_equal(np.asarray(want.counts),
                                  np.asarray(got.counts))


def test_partial_batches_exact():
    """Micro-batches below capacity are padded + masked: the pad rows
    contribute exactly nothing (parity against the unpadded run)."""
    rng = np.random.default_rng(5)
    svc = MapReduce(wc_app(), streaming=True).serve(batch_capacity=B)
    sizes = [B, 7, 1, 33, B, 12]
    chunks = [jnp.asarray(rng.integers(0, VOCAB, size=s), dtype=np.int32)
              for s in sizes]
    for c in chunks:
        svc.ingest(c)
    got = svc.snapshot()
    assert got.batch_id == len(sizes)

    want = MapReduce(wc_app(), flow="stream").run(jnp.concatenate(chunks))
    np.testing.assert_array_equal(np.asarray(want.values),
                                  np.asarray(got.values))
    np.testing.assert_array_equal(np.asarray(want.counts),
                                  np.asarray(got.counts))


def test_oversized_batch_rejected():
    svc = MapReduce(wc_app(), streaming=True).serve(batch_capacity=8)
    with pytest.raises(ValueError, match="batch_capacity"):
        svc.ingest(jnp.zeros((9,), I32))


# ---------------------------------------------------------------------------
# Zero re-trace / re-tune / re-compile steady state
# ---------------------------------------------------------------------------


def test_zero_retrace_across_100_ingests():
    """After the first ingest stages the executable, 100 more ingests (of
    varying sizes — one executable serves them all) run zero optimizer
    derives, zero autotunes, zero probes and zero staged compiles."""
    rng = np.random.default_rng(7)
    svc = MapReduce(wc_app(), streaming=True).serve(batch_capacity=32)
    svc.ingest(jnp.asarray(rng.integers(0, VOCAB, size=32), dtype=np.int32))

    s0 = pc.stats_snapshot()
    for i in range(100):
        n = 32 if i % 3 else 11
        svc.ingest(jnp.asarray(rng.integers(0, VOCAB, size=n),
                               dtype=np.int32))
        if i % 25 == 0:
            svc.snapshot()  # queries must not re-stage anything either
    s1 = pc.stats_snapshot()
    for counter in ("derives", "autotunes", "probes", "compiles"):
        assert s1[counter] == s0[counter], (counter, s0, s1)
    assert svc.batch_id == 101


def test_second_service_hits_compiled_cache():
    """A second service over a content-identical app re-uses the staged
    executable: zero compiles end to end (the plan cache's serving win)."""
    rng = np.random.default_rng(8)
    items = jnp.asarray(rng.integers(0, VOCAB, size=B), dtype=np.int32)
    MapReduce(wc_app(), streaming=True).serve(batch_capacity=B).ingest(items)
    s0 = pc.stats_snapshot()
    svc2 = MapReduce(wc_app(), streaming=True).serve(batch_capacity=B)
    svc2.ingest(items)
    s1 = pc.stats_snapshot()
    assert s1["compiles"] == s0["compiles"], (s0, s1)
    assert "compiled-cache: hit" in svc2.explain()


# ---------------------------------------------------------------------------
# Windowed aggregation: coverage + expiry, exact by construction
# ---------------------------------------------------------------------------


def sum_app():
    return kv_app(lambda k, v, c: jnp.sum(v), jax.ShapeDtypeStruct((), I32))


def test_tumbling_window_covers_current_period_only():
    rng = np.random.default_rng(11)
    batches = kv_batches(rng, 10, dtype=np.int32)
    svc = MapReduce(sum_app(), streaming=True).serve(batch_capacity=B,
                                                     window=tumbling(2))
    for b in batches:
        svc.ingest(b)
    got = svc.snapshot()
    # 10 batches, size-2 tumbling: the live window is batches 8..9
    want = batch_reference(sum_app(), batches[8:10])
    np.testing.assert_array_equal(np.asarray(want.values),
                                  np.asarray(got.values))
    np.testing.assert_array_equal(np.asarray(want.counts),
                                  np.asarray(got.counts))


def test_sliding_window_merges_live_slots():
    rng = np.random.default_rng(12)
    batches = kv_batches(rng, 9, dtype=np.int32)
    svc = MapReduce(sum_app(), streaming=True).serve(batch_capacity=B,
                                                     window=sliding(4, 2))
    for b in batches:
        svc.ingest(b)
    got = svc.snapshot()
    # 9 batches, size-4/slide-2 ring: the live slots hold the last full
    # slide period {6,7} plus the in-progress one {8}
    want = batch_reference(sum_app(), batches[6:9])
    np.testing.assert_array_equal(np.asarray(want.values),
                                  np.asarray(got.values))
    np.testing.assert_array_equal(np.asarray(want.counts),
                                  np.asarray(got.counts))


def test_window_expiry_drops_old_keys():
    """Keys seen only in expired batches disappear from snapshots — the
    ring-slot overwrite IS the TTL."""
    svc = MapReduce(wc_app(), streaming=True).serve(batch_capacity=B,
                                                    window=tumbling(2))
    hot = jnp.full((B,), 3, dtype=I32)
    cold = jnp.full((B,), 40, dtype=I32)
    svc.ingest(hot)
    svc.ingest(hot)
    assert count_of(svc.snapshot(), 3) == 2 * B
    svc.ingest(cold)  # new period: the hot batches expire
    snap = svc.snapshot()
    assert count_of(snap, 3) == 0
    assert count_of(snap, 40) == B


def test_window_invalid_config():
    with pytest.raises(ValueError, match="multiple of slide"):
        sliding(5, 2)
    with pytest.raises(ValueError, match="positive"):
        tumbling(0)


# ---------------------------------------------------------------------------
# Snapshot-under-ingestion consistency
# ---------------------------------------------------------------------------


def test_snapshot_consistent_under_concurrent_ingestion():
    """Snapshots taken while a background IngestionQueue folds batches
    always see a whole number of batches: every batch contributes exactly
    B pairs, so a torn/partially-applied view would break
    counts.sum() == batch_id * B."""
    rng = np.random.default_rng(13)
    svc = MapReduce(wc_app(), streaming=True).serve(batch_capacity=B)
    q = IngestionQueue(svc, maxsize=4)
    n_batches = 30
    deadline = time.monotonic() + 120.0
    for _ in range(n_batches):
        q.put(jnp.asarray(rng.integers(0, VOCAB, size=B), dtype=np.int32),
              timeout=120.0)

    seen = []
    while svc.batch_id < n_batches and time.monotonic() < deadline:
        if svc.batch_id == 0:
            time.sleep(0.001)  # not staged yet: first ingest in flight
            continue
        snap = svc.snapshot()
        total = int(np.asarray(snap.counts).sum())
        assert total == snap.batch_id * B, (total, snap.batch_id)
        seen.append(snap.batch_id)
    q.close()
    assert seen == sorted(seen)  # monotone generations
    final = svc.snapshot()
    assert final.batch_id == n_batches
    assert int(np.asarray(final.counts).sum()) == n_batches * B


def test_ingestion_queue_surfaces_worker_errors():
    svc = MapReduce(wc_app(), streaming=True).serve(batch_capacity=4)
    q = IngestionQueue(svc, maxsize=2)
    q.put(jnp.zeros((16,), I32))  # oversized: worker raises
    with pytest.raises(ValueError, match="batch_capacity"):
        q.join()
    q.close()


def test_ingestion_queue_quarantines_poison_batch():
    """A poison batch is quarantined with its sequence number; the worker
    keeps consuming and the service keeps folding + serving snapshots."""
    svc = MapReduce(wc_app(), streaming=True).serve(batch_capacity=8)
    q = IngestionQueue(svc, maxsize=4)
    q.put(jnp.zeros((8,), I32))        # seq 1: fine
    q.put(jnp.zeros((16,), I32))       # seq 2: poison (oversized)
    q.put(jnp.full((8,), 5, I32))      # seq 3: still folded after poison
    with pytest.raises(ValueError, match="batch_capacity"):
        q.join()
    q.close()
    assert [p.seq for p in q.quarantined] == [2]
    assert "batch_capacity" in str(q.quarantined[0].error)
    snap = svc.snapshot()
    assert snap.batch_id == 2        # batches 1 and 3 both landed
    assert count_of(snap, 5) == 8
    assert not svc.failed            # poison != service failure


def test_ingestion_queue_worker_death_unstrands_producers():
    """Regression: a fatal (non-batch) worker death used to kill the
    thread silently — producers then blocked forever on a full queue and
    close() hung.  Now the death surfaces as WorkerDiedError on the next
    put()/close(), and the service is marked failed."""
    from repro.streaming import ServiceFailedError, WorkerDiedError

    class Dying:
        batch_id = 0

        def __init__(self):
            self.failure = None

        def ingest(self, items):
            raise KeyboardInterrupt("simulated fatal worker death")

        def fail(self, exc):
            self.failure = exc

    svc = Dying()
    q = IngestionQueue(svc, maxsize=1)
    q.put(jnp.zeros((4,), I32))  # worker dies processing this
    with pytest.raises(WorkerDiedError):
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:  # death is asynchronous
            q.put(jnp.zeros((4,), I32), timeout=5.0)
    with pytest.raises(WorkerDiedError):
        q.close()
    assert isinstance(svc.failure, KeyboardInterrupt)

    # a real service marked failed: ingest raises, snapshots keep serving
    real = MapReduce(wc_app(), streaming=True).serve(batch_capacity=8)
    real.ingest(jnp.full((8,), 7, I32))
    real.fail(RuntimeError("ingestion worker died"))
    assert real.failed
    with pytest.raises(ServiceFailedError, match="worker died"):
        real.ingest(jnp.zeros((8,), I32))
    snap = real.snapshot()  # reads stay up for the last good state
    assert snap.batch_id == 1 and count_of(snap, 7) == 8
    assert "FAILED" in real.explain()


# ---------------------------------------------------------------------------
# Checkpointed warm restart
# ---------------------------------------------------------------------------


def test_restore_resumes_bitwise():
    rng = np.random.default_rng(17)
    batches = kv_batches(rng, 12, dtype=np.int32)

    def build(d):
        return MapReduce(sum_app(), streaming=True).serve(
            batch_capacity=B, window=sliding(4, 2), ckpt_dir=d,
            ckpt_every=4,
            item_spec=(jax.ShapeDtypeStruct((), I32),
                       jax.ShapeDtypeStruct((), I32)))

    with tempfile.TemporaryDirectory() as d:
        svc = build(d)
        for b in batches:
            svc.ingest(b)
        want = svc.snapshot()

        # "crash" after batch 8's checkpoint: a fresh service restores it
        # and replays 8..12 — bitwise the unfailed run
        svc2 = build(d)
        assert svc2.restore(step=8) == 8
        assert svc2.batch_id == 8
        for b in batches[8:]:
            svc2.ingest(b)
        got = svc2.snapshot()
        np.testing.assert_array_equal(np.asarray(want.values),
                                      np.asarray(got.values))
        np.testing.assert_array_equal(np.asarray(want.counts),
                                      np.asarray(got.counts))

        # restoring the newest checkpoint reproduces the final tables
        # directly (no replay)
        svc3 = build(d)
        assert svc3.restore() == 12
        got3 = svc3.snapshot()
        np.testing.assert_array_equal(np.asarray(want.values),
                                      np.asarray(got3.values))


def test_restore_requires_staging():
    with tempfile.TemporaryDirectory() as d:
        svc = MapReduce(wc_app(), streaming=True).serve(
            batch_capacity=B, ckpt_dir=d, ckpt_every=1)
        with pytest.raises(RuntimeError, match="item_spec"):
            svc.restore()


# ---------------------------------------------------------------------------
# Staging guards + the unified result/explain surface
# ---------------------------------------------------------------------------


def test_streaming_pins_stream_flow():
    with pytest.raises(ValueError, match="stream"):
        MapReduce(wc_app(), streaming=True, flow="sort")
    with pytest.raises(ValueError, match="stream"):
        plan_execution(wc_app(), streaming=True, flow="reduce")
    # a non-derivable reducer (order-dependent) cannot stream at all
    bad = make_app(
        map_fn=lambda item, emit: emit(item % 8, item.astype(F32)),
        reduce_fn=lambda k, vs, n: vs[0] - vs[-1],
        key_space=8,
        value_aval=jax.ShapeDtypeStruct((), F32),
        emit_capacity=1,
    )
    with pytest.raises(ValueError, match="derivation failed"):
        MapReduce(bad, streaming=True)


def test_service_rejects_non_stream_plan():
    from repro.streaming import MapReduceService

    mr = MapReduce(wc_app(), flow="combine")
    with pytest.raises(ValueError, match="stream"):
        MapReduceService(mr, batch_capacity=B)


def test_snapshot_returns_mapreduce_result():
    svc = MapReduce(wc_app(), streaming=True).serve(batch_capacity=B)
    svc.ingest(jnp.zeros((B,), I32))
    res = svc.snapshot()
    assert isinstance(res, MapReduceResult)
    assert res.plan is not None and res.plan.flow == "stream"
    assert isinstance(res.diagnostics, tuple)
    assert res.batch_id == 1
    with pytest.warns(DeprecationWarning, match="named fields"):
        keys, values, counts = res
    np.testing.assert_array_equal(np.asarray(keys), np.asarray(res.keys))


def test_explain_reports_service_surface():
    with tempfile.TemporaryDirectory() as d:
        svc = MapReduce(wc_app(), streaming=True).serve(
            batch_capacity=B, window=sliding(6, 3), ckpt_dir=d,
            ckpt_every=5)
        svc.ingest(jnp.zeros((B,), I32))
        text = svc.explain()
        assert "mode: streaming" in text
        assert "plan-cache:" in text
        assert "compiled-cache:" in text  # provenance: hit/miss + key
        assert "window: sliding size=6 slide=3" in text
        assert "residency: holder tables" in text
        assert "every 5 batches" in text
        assert f"batch_capacity={B}" in text


def test_streaming_compiled_rejects_batch_call():
    svc = MapReduce(wc_app(), streaming=True).serve(batch_capacity=B)
    svc.ingest(jnp.zeros((B,), I32))
    with pytest.raises(TypeError, match="MapReduceService"):
        svc._compiled(jnp.zeros((B,), I32))


def test_unwindowed_snapshot_before_ingest_is_empty():
    svc = MapReduce(wc_app(), streaming=True).serve(
        batch_capacity=B, item_spec=jax.ShapeDtypeStruct((), I32))
    res = svc.snapshot()
    assert res.batch_id == 0
    assert int(np.asarray(res.counts).sum()) == 0


def test_field_access_emits_no_deprecation():
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        svc = MapReduce(wc_app(), streaming=True).serve(batch_capacity=B)
        svc.ingest(jnp.zeros((B,), I32))
        res = svc.snapshot()
        res.keys, res.values, res.counts  # noqa: B018 — named-field access
    assert not [w for w in caught
                if issubclass(w.category, DeprecationWarning)
                and "named fields" in str(w.message)]
