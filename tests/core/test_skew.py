"""Skew-adaptive shuffle planning (core/skew.py + the engine routing).

The exactness contracts under test:

* uniform keys: the planner SNAPS to the identity plan, so the engine runs
  the bitwise-legacy fixed-width arithmetic — skew="auto" output is
  bitwise-identical to the default, on every flow;
* skewed keys (Zipf, forced hot key): balanced boundaries + hot-key
  splitting still equal the single-host oracle bitwise (integer monoids),
  including a hot key whose mass exceeds one shard's uniform capacity;
* hot-split recombine: for every commutative-monoid spec in the matrix,
  splitting a key's pairs over several destinations and merging the
  partial aggregates equals the unsplit reduce (hypothesis property);
* the resilient driver's recovery (kill 1 of 8 hosts, restore from
  checkpointed partials) stays bitwise under skew boundaries, and a
  checkpoint written under DIFFERENT boundaries is rejected by its epoch
  stamp and recomputed;
* the derived capacity envelope sizes to the sampled p-max destination
  load — a mild-skew run no longer overflows/warns (the PR's bugfix).
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ExecutionOptions, LoweringFallbackWarning, MapReduce,
                        MapReduceApp, ShuffleOptions)
from repro.core import engine as eng
from repro.core import plan_cache as pc
from repro.core import skew
from repro.core.plan import plan_execution

I32 = jnp.int32


def make_app(key_space, *, emit=4, reduce=None):
    class App(MapReduceApp):
        pass

    app = App()
    app.key_space = key_space
    app.value_aval = jax.ShapeDtypeStruct((), I32)
    app.max_values_per_key = 4096
    app.emit_capacity = emit
    app.map = lambda item, emit_: emit_(item, jnp.ones_like(item))
    app.reduce = reduce or (lambda k, v, c: jnp.sum(v))
    return app


def zipf_items(key_space, n_items, emit, *, a=1.1, seed=0):
    rng = np.random.default_rng(seed)
    keys = rng.zipf(a, size=(n_items, emit)) % key_space
    return jnp.asarray(keys, I32)


# ---------------------------------------------------------------------------
# derivation unit tests (pure host-side numpy)
# ---------------------------------------------------------------------------


def test_derive_uniform_snaps_to_identity():
    hist = np.full(64, 10, np.int64)
    d = skew.derive(hist, 8)
    assert d.boundaries is None and not d.hot_keys
    assert d.imbalance == pytest.approx(1.0)


def test_derive_balances_skewed_ranges():
    hist = np.zeros(64, np.int64)
    hist[:8] = 100  # all mass in the first fixed-width range
    d = skew.derive(hist, 4)
    assert d.boundaries is not None
    p = skew.ShufflePlan(key_space=64, num_shards=4,
                         boundaries=d.boundaries)
    loads = [int(hist[a:b].sum()) for a, b in zip(d.boundaries,
                                                  d.boundaries[1:])]
    assert max(loads) < int(hist.sum())  # no single range holds everything
    assert d.imbalance == pytest.approx(4.0)
    assert p.width >= 1


def test_derive_hot_key_split_and_envelope():
    hist = np.full(64, 10, np.int64)
    hist[3] = 5000
    d = skew.derive(hist, 8, mergeable=True)
    assert d.hot_keys == (3,)
    assert d.hot_ways[0] >= 2
    # the sampled p-max destination fraction prices the SPLIT load
    assert d.max_dest_frac is not None and d.max_dest_frac < 0.5
    # without mergeability the head key cannot split
    d2 = skew.derive(hist, 8, mergeable=False)
    assert not d2.hot_keys and d2.boundaries is not None


def test_shuffle_plan_validation_and_epoch():
    with pytest.raises(ValueError, match="strictly increasing"):
        skew.ShufflePlan(key_space=8, num_shards=2, boundaries=(0, 0, 8))
    with pytest.raises(ValueError, match="boundaries"):
        skew.ShufflePlan(key_space=8, num_shards=2, boundaries=(0, 4))
    with pytest.raises(ValueError, match="pair up"):
        ShuffleOptions(hot_keys=(1,), hot_ways=())
    p1 = skew.ShufflePlan(key_space=8, num_shards=2, boundaries=(0, 3, 8))
    p2 = skew.ShufflePlan(key_space=8, num_shards=2, boundaries=(0, 5, 8))
    assert p1.epoch != p2.epoch and p1.epoch != 0
    assert p1.hot_owner(2) == 0 and p1.hot_owner(3) == 1
    # capacity envelope: p-max load + slack, legacy 2N/S as the floor
    p3 = skew.ShufflePlan(key_space=16, num_shards=4,
                          boundaries=(0, 4, 8, 12, 16), max_dest_frac=0.6)
    assert p3.capacity_for(100) == 90       # 100*0.6*1.5 > legacy 50
    p4 = dataclasses.replace(p3, max_dest_frac=0.25)
    assert p4.capacity_for(100) == 50       # derived 38 floored at legacy
    assert p3.capacity_for(4) >= 2


# ---------------------------------------------------------------------------
# options surface: deprecation forwarding + plan-cache key digest
# ---------------------------------------------------------------------------


def test_flat_shuffle_kwargs_forward_with_deprecation():
    with pytest.warns(DeprecationWarning, match="shuffle_capacity"):
        o = ExecutionOptions(shuffle_capacity=33, strict_shuffle=True)
    assert o.shuffle is not None
    assert o.shuffle.capacity == 33 and o.shuffle.strict
    # round-trips through replace() without re-warning (record is set)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        o2 = dataclasses.replace(o, step=3)
    assert o2.shuffle_capacity == 33 and o2.strict_shuffle
    # the record is authoritative: flat fields mirror it
    o3 = ExecutionOptions(shuffle=ShuffleOptions(capacity=7, strict=True))
    assert o3.shuffle_capacity == 7 and o3.strict_shuffle
    with pytest.raises(TypeError, match="ShuffleOptions"):
        ExecutionOptions(shuffle="auto")


def test_default_options_stay_legacy_and_warning_free():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        o = ExecutionOptions()
    # None (unless the REPRO_TEST_SKEW override materialized it) keeps
    # the engine on shuffle_plan=None
    if o.shuffle is not None:
        assert o.shuffle.boundaries is None


def test_shuffle_options_digested_into_compiled_key():
    app = make_app(64)
    mr = MapReduce(app, flow="sort")
    items = zipf_items(64, 32, 4)
    base = ExecutionOptions(num_hosts=2, num_shards=4)
    a = mr.lower(items, options=dataclasses.replace(
        base, shuffle=ShuffleOptions(boundaries=(0, 2, 4, 8, 64))),
        mode="distributed")
    b = mr.lower(items, options=dataclasses.replace(
        base, shuffle=ShuffleOptions(boundaries=(0, 16, 32, 48, 64))),
        mode="distributed")
    ka = pc.compiled_key(
        app, a.items_spec, plan_key="p", flow="sort", n_bucket=32,
        mesh=None, data_axis="data", mode="distributed",
        extra=(repr(a.options.shuffle),))
    kb = pc.compiled_key(
        app, b.items_spec, plan_key="p", flow="sort", n_bucket=32,
        mesh=None, data_axis="data", mode="distributed",
        extra=(repr(b.options.shuffle),))
    assert ka != kb


def test_warm_repeat_serves_resolution_from_memo():
    skew.clear_memo()
    app = make_app(64)
    items = zipf_items(64, 64, 4, seed=3)
    opts = ExecutionOptions(num_hosts=2, num_shards=8,
                            shuffle=ShuffleOptions(skew="auto"))
    mr = MapReduce(app, flow="sort")
    before = skew.stats_snapshot()
    mr.lower(items, options=opts, mode="resilient")
    mid = skew.stats_snapshot()
    assert mid["samples"] == before["samples"] + 1
    mr.lower(items, options=opts, mode="resilient")
    after = skew.stats_snapshot()
    assert after["samples"] == mid["samples"]  # zero re-derives
    assert after["cache_hits"] == mid["cache_hits"] + 1


def test_spec_only_lowering_skips_the_probe():
    app = make_app(64)
    mr = MapReduce(app, flow="sort")
    spec = jax.ShapeDtypeStruct((64, 4), I32)
    before = skew.stats_snapshot()["samples"]
    low = mr.lower(spec, options=ExecutionOptions(
        num_hosts=2, num_shards=8, shuffle=ShuffleOptions(skew="auto")),
        mode="resilient")
    assert skew.stats_snapshot()["samples"] == before
    assert low.options.shuffle.boundaries is None


# ---------------------------------------------------------------------------
# end-to-end exactness (mesh-less resilient driver: 8 shards, 1 device)
# ---------------------------------------------------------------------------


def _oracle(app, items):
    r = MapReduce(app, flow="stream", cache=False).run(items)
    return (np.asarray(r.values), np.asarray(r.counts))


def test_uniform_keys_bitwise_parity_all_flows():
    """Identity snap: on uniform keys skew='auto' output is bitwise the
    default run's, on every flow (the shuffled ones route identically;
    the table-merge ones ignore the shuffle surface)."""
    K = 64
    app = make_app(K)
    rng = np.random.default_rng(1)
    items = jnp.asarray(
        rng.permutation(np.repeat(np.arange(K), 8)).reshape(-1, 4), I32)
    for flow in ("stream", "sort", "combine", "reduce"):
        mr = MapReduce(app, flow=flow, cache=False)
        base = mr.run_resilient(items, options=ExecutionOptions(
            num_hosts=2, num_shards=8))
        res = mr.run_resilient(items, options=ExecutionOptions(
            num_hosts=2, num_shards=8,
            shuffle=ShuffleOptions(skew="auto")))
        assert np.array_equal(np.asarray(res.values),
                              np.asarray(base.values)), flow
        assert np.array_equal(np.asarray(res.counts),
                              np.asarray(base.counts)), flow


def test_zipf_parity_with_hot_key_past_shard_capacity():
    """Zipf(1.1) + a forced hot key holding more pairs than one shard's
    uniform capacity: balanced boundaries + hot split equal the
    single-host oracle bitwise (integer monoid), with ZERO overflow."""
    K = 256
    app = make_app(K, emit=8)
    keys = np.array(zipf_items(K, 128, 8, seed=7))
    keys[::2] = 5  # hot key: half of all pairs (> any shard's 2N/S share)
    items = jnp.asarray(keys, I32)
    want_v, want_c = _oracle(app, items)
    for flow in ("sort", "reduce"):
        mr = MapReduce(app, flow=flow, cache=False)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            res = mr.run_resilient(items, options=ExecutionOptions(
                num_hosts=2, num_shards=8,
                shuffle=ShuffleOptions(skew="auto")))
        assert not [x for x in w
                    if issubclass(x.category, LoweringFallbackWarning)], flow
        assert np.array_equal(np.asarray(res.values), want_v), flow
        assert np.array_equal(np.asarray(res.counts), want_c), flow
        lines = "\n".join(res.recovery.summary())
        assert "skew" in lines, lines
        if flow == "sort":
            assert "hot keys split" in lines, lines


def test_mild_skew_default_capacity_no_longer_warns():
    """The PR's capacity bugfix: a mildly skewed run under the DERIVED
    envelope (sampled p-max load + slack) is exact and quiet, where the
    legacy uniform 2N/S envelope overflowed and warned."""
    K = 64
    app = make_app(K, emit=8)
    keys = np.array(zipf_items(K, 64, 8, seed=11))
    keys[:, :3] = 9  # ~3/8 of the mass on one key: mild, not extreme
    items = jnp.asarray(keys, I32)
    want_v, want_c = _oracle(app, items)
    plan = plan_execution(app, flow="reduce")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        k, v, c, log = eng.run_resilient(app, plan, items, num_hosts=2,
                                         num_shards=8)
    assert any("overflow" in str(x.message) for x in w), \
        "precondition lost: legacy envelope should overflow here"
    mr = MapReduce(app, flow="reduce", cache=False)
    with warnings.catch_warnings(record=True) as w2:
        warnings.simplefilter("always")
        res = mr.run_resilient(items, options=ExecutionOptions(
            num_hosts=2, num_shards=8,
            shuffle=ShuffleOptions(skew="auto")))
    assert not [x for x in w2
                if issubclass(x.category, LoweringFallbackWarning)]
    assert np.array_equal(np.asarray(res.values), want_v)
    assert np.array_equal(np.asarray(res.counts), want_c)


def test_reduce_flow_rejects_hot_keys():
    app = make_app(16)
    mr = MapReduce(app, flow="reduce", cache=False)
    items = jnp.zeros((16, 4), I32)
    with pytest.raises(ValueError, match="hot-key"):
        mr.run_resilient(items, options=ExecutionOptions(
            num_hosts=2, num_shards=4,
            shuffle=ShuffleOptions(boundaries=(0, 4, 8, 12, 16),
                                   hot_keys=(0,), hot_ways=(2,))))


# ---------------------------------------------------------------------------
# hot-split recombine == unsplit reduce (monoid matrix property)
# ---------------------------------------------------------------------------

MONOID_REDUCERS = {
    "sum": lambda k, v, c: jnp.sum(v),
    "max": lambda k, v, c: jnp.max(v),
    "min": lambda k, v, c: jnp.min(v),
    "mean": lambda k, v, c: jnp.sum(v) // jnp.maximum(c, 1),
    "sumsq": lambda k, v, c: jnp.sum(v * v),
}

def _check_hot_split_recombine(reducer, hot, ways, seed):
    K, S = 16, 4
    app = make_app(K, emit=4, reduce=MONOID_REDUCERS[reducer])
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, K, size=(16, 4))
    keys[rng.random(keys.shape) < 0.5] = hot
    items = jnp.asarray(keys, I32)
    want_v, want_c = _oracle(app, items)

    bounds = tuple(range(0, K + 1, K // S))
    mr = MapReduce(app, flow="sort", cache=False)
    res = mr.run_resilient(items, options=ExecutionOptions(
        num_hosts=2, num_shards=S,
        shuffle=ShuffleOptions(boundaries=bounds, hot_keys=(hot,),
                               hot_ways=(ways,))))
    assert np.array_equal(np.asarray(res.counts), want_c), reducer
    assert np.array_equal(np.asarray(res.values), want_v), reducer


@pytest.mark.parametrize("reducer", sorted(MONOID_REDUCERS))
@pytest.mark.parametrize("ways", (2, 4))
def test_hot_split_recombine_equals_unsplit(reducer, ways):
    _check_hot_split_recombine(reducer, hot=3, ways=ways, seed=17)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False

if HAVE_HYP:
    @settings(max_examples=12, deadline=None)
    @given(
        reducer=st.sampled_from(sorted(MONOID_REDUCERS)),
        hot=st.integers(0, 15),
        ways=st.integers(2, 4),
        seed=st.integers(0, 2 ** 16),
    )
    def test_hot_split_recombine_property(reducer, hot, ways, seed):
        _check_hot_split_recombine(reducer, hot, ways, seed)


# ---------------------------------------------------------------------------
# resilient recovery under skew boundaries
# ---------------------------------------------------------------------------


def test_resilient_kill_one_of_eight_stays_bitwise(tmp_path):
    from repro.distributed import fault as flt

    K = 128
    app = make_app(K, emit=8)
    keys = np.array(zipf_items(K, 64, 8, seed=5))
    keys[::3] = 2
    items = jnp.asarray(keys, I32)
    opts = ExecutionOptions(num_hosts=8, num_shards=8,
                            shuffle=ShuffleOptions(skew="auto"))
    mr = MapReduce(app, flow="sort", cache=False)
    base = mr.run_resilient(items, options=opts)
    drill = mr.run_resilient(items, options=dataclasses.replace(
        opts, ckpt_dir=str(tmp_path),
        inject=flt.FaultInjection(dead_hosts=(3,), die_after_shards=1)))
    assert np.array_equal(np.asarray(drill.values),
                          np.asarray(base.values))
    assert np.array_equal(np.asarray(drill.counts),
                          np.asarray(base.counts))
    assert drill.recovery.restored or drill.recovery.recomputed
    assert drill.recovery.boundary_epoch != 0
    assert any("skew" in ln for ln in drill.recovery.summary())


def test_stale_boundary_epoch_rejected_at_restore(tmp_path):
    """A partial checkpointed under DIFFERENT boundaries must not be
    merged: the epoch stamp rejects it and the shard recomputes."""
    from repro.distributed import fault as flt

    K = 64
    app = make_app(K, emit=4)
    items = zipf_items(K, 32, 4, seed=9)
    want_v, want_c = _oracle(app, items)

    def run(bounds, inject=None):
        # explicit boundaries carry no sampled envelope, so provision the
        # full per-shard pair count (zipf keys overflow the 2x-uniform
        # legacy floor)
        mr = MapReduce(app, flow="sort", cache=False)
        return mr.run_resilient(items, options=ExecutionOptions(
            num_hosts=4, num_shards=8, ckpt_dir=str(tmp_path),
            inject=inject,
            shuffle=ShuffleOptions(boundaries=bounds, capacity=16)))

    # seed checkpoints under layout A (all shards persist their partials)
    run((0, 8, 16, 24, 32, 40, 48, 56, 64))
    # now run under layout B with a dead host that completed only its
    # FIRST shard: the lost second shard's surviving checkpoint is the
    # layout-A one, which the epoch check must REJECT, then recompute
    drill = run((0, 4, 12, 20, 28, 36, 44, 52, 64),
                inject=flt.FaultInjection(dead_hosts=(1,),
                                          die_after_shards=1))
    assert np.array_equal(np.asarray(drill.values), want_v)
    assert np.array_equal(np.asarray(drill.counts), want_c)
    assert drill.recovery.epoch_rejects, drill.recovery.summary()
    assert any("stale boundary" in ln for ln in drill.recovery.summary())


# ---------------------------------------------------------------------------
# fake 8-device mesh: the jitted shard_map path (subprocess)
# ---------------------------------------------------------------------------


def test_distributed_mesh_parity_uniform_and_zipf():
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "integration"))
    from _subproc import run_with_devices

    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp, warnings
        from jax.sharding import Mesh
        from repro.core import (MapReduce, MapReduceApp, ExecutionOptions,
                                ShuffleOptions, LoweringFallbackWarning)

        class WC(MapReduceApp):
            key_space = 256
            value_aval = jax.ShapeDtypeStruct((), jnp.int32)
            max_values_per_key = 4096
            emit_capacity = 8
            def map(self, item, emit): emit(item, jnp.ones_like(item))
            def reduce(self, key, values, count): return jnp.sum(values)

        mesh = Mesh(np.array(jax.devices()), ("data",))
        rng = np.random.default_rng(0)
        zipf = (rng.zipf(1.1, size=(128, 8)) % 256).astype(np.int32)
        zipf[::2] = 7  # hot key past one shard's uniform capacity
        uni = rng.permutation(np.repeat(np.arange(256), 4)).reshape(
            128, 8).astype(np.int32)
        for flow in ("sort", "reduce"):
            mr = MapReduce(WC(), flow=flow, cache=False)
            for name, arr in (("uniform", uni), ("zipf", zipf)):
                items = jnp.asarray(arr)
                ref = mr.run(items)
                legacy = mr.run_distributed(items, mesh=mesh)
                with warnings.catch_warnings(record=True) as w:
                    warnings.simplefilter("always")
                    res = mr.run_distributed(
                        items, mesh=mesh, options=ExecutionOptions(
                            shuffle=ShuffleOptions(skew="auto")))
                ovf = [x for x in w if issubclass(
                    x.category, LoweringFallbackWarning)]
                assert not ovf, (flow, name, [str(x.message) for x in ovf])
                assert np.array_equal(np.asarray(res.values),
                                      np.asarray(ref.values)), (flow, name)
                assert np.array_equal(np.asarray(res.counts),
                                      np.asarray(ref.counts)), (flow, name)
                if name == "uniform":
                    # identity snap: bitwise the legacy fixed-width run
                    assert np.array_equal(np.asarray(res.values),
                                          np.asarray(legacy.values))
        print("MESH_SKEW_OK")
    """, n=8)
    assert "MESH_SKEW_OK" in out
