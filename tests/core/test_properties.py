"""Hypothesis property tests for the system's invariants.

Invariant 1 (the paper's correctness claim): for any combinable reducer, the
combine flow computes exactly what the reduce flow computes, for any key
distribution and emission order.

Invariant 2: derived combiners satisfy fold-split equivalence on random
splits (associativity of the fold across chunk boundaries).

Invariant 3: the engine result is invariant under permutation of the input
items (MapReduce's order-insensitivity contract).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import MapReduce, MapReduceApp, combiner as C
from repro.core.optimizer import derive_combiner

F32 = jnp.float32


def make_wc_app(key_space):
    class App(MapReduceApp):
        pass

    app = App()
    app.key_space = key_space
    app.value_aval = jax.ShapeDtypeStruct((), F32)
    app.max_values_per_key = 128
    app.emit_capacity = 4
    app.map = lambda item, emit: emit(item[0], item[1])
    return app


REDUCERS = {
    "sum": lambda k, v, c: jnp.sum(v),
    "max": lambda k, v, c: jnp.max(v),
    "mean": lambda k, v, c: jnp.sum(v) / jnp.maximum(c, 1).astype(F32),
    "sumsq": lambda k, v, c: jnp.sum(v * v),
}
PADS = {"sum": 0.0, "max": -np.inf, "mean": 0.0, "sumsq": 0.0}


@settings(max_examples=15, deadline=None)
@given(
    reducer=st.sampled_from(sorted(REDUCERS)),
    key_space=st.integers(2, 12),
    n=st.integers(1, 40),
    seed=st.integers(0, 2 ** 16),
)
def test_combine_flow_equals_reduce_flow(reducer, key_space, n, seed):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, key_space, size=(n, 4)).astype(np.int32)
    vals = rng.standard_normal((n, 4)).astype(np.float32)

    app = make_wc_app(key_space)
    app.reduce = REDUCERS[reducer]
    app.pad_value = PADS[reducer]

    items = (jnp.asarray(keys), jnp.asarray(vals))
    r_comb = MapReduce(app, flow="auto").run(items)
    r_red = MapReduce(app, flow="reduce").run(items)

    cnt = np.asarray(r_red.counts)
    mask = cnt > 0
    np.testing.assert_array_equal(np.asarray(r_comb.counts), cnt)
    np.testing.assert_allclose(
        np.asarray(r_comb.values)[mask], np.asarray(r_red.values)[mask],
        rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    reducer=st.sampled_from(sorted(REDUCERS)),
    n=st.integers(2, 24),
    split=st.integers(1, 23),
    seed=st.integers(0, 2 ** 16),
)
def test_fold_split_equivalence(reducer, n, split, seed):
    split = min(split, n - 1)
    d = derive_combiner(REDUCERS[reducer],
                        jax.ShapeDtypeStruct((), jnp.int32),
                        jax.ShapeDtypeStruct((), F32))
    assert d.combinable
    spec = d.spec
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.standard_normal(n), F32)

    ha = C.fold_values(spec, vals[:split])
    hb = C.fold_values(spec, vals[split:])
    hm = spec.merge(ha, hb, jnp.int32(split), jnp.int32(n - split))
    got = spec.finalize(0, hm, jnp.int32(n))
    want = REDUCERS[reducer](0, vals, jnp.int32(n))
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), n=st.integers(2, 30))
def test_permutation_invariance(seed, n):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 6, size=(n, 4)).astype(np.int32)
    vals = rng.standard_normal((n, 4)).astype(np.float32)
    app = make_wc_app(6)
    app.reduce = REDUCERS["sum"]
    mr = MapReduce(app)

    r1 = mr.run((jnp.asarray(keys), jnp.asarray(vals)))
    perm = rng.permutation(n)
    r2 = mr.run((jnp.asarray(keys[perm]), jnp.asarray(vals[perm])))
    np.testing.assert_allclose(np.asarray(r1.values), np.asarray(r2.values),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_logsumexp_monoid_stability(seed):
    """The (m,l) monoid must match direct logsumexp on extreme values."""
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.standard_normal(16) * 100, F32)  # extreme range
    spec = C.logsumexp_spec()
    got = C.finalize_fold(spec, vals)
    want = jax.scipy.special.logsumexp(vals)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


# ---------------------------------------------------------------------------
# Invariant (PR 3): the sort flow (radix-bucketed segment reduce) computes
# exactly what the reduce flow computes, for any reducer/keys/chunking —
# including chunk boundaries that split key runs.
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    reducer=st.sampled_from(sorted(REDUCERS)),
    key_space=st.integers(2, 12),
    n=st.integers(1, 40),
    chunk=st.sampled_from([16, 64, 4096]),
    seed=st.integers(0, 2 ** 16),
)
def test_sort_flow_equals_reduce_flow(reducer, key_space, n, chunk, seed):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, key_space, size=(n, 4)).astype(np.int32)
    vals = rng.standard_normal((n, 4)).astype(np.float32)

    app = make_wc_app(key_space)
    app.reduce = REDUCERS[reducer]
    app.pad_value = PADS[reducer]

    items = (jnp.asarray(keys), jnp.asarray(vals))
    r_sort = MapReduce(app, flow="sort", stream_chunk_pairs=chunk).run(items)
    r_red = MapReduce(app, flow="reduce").run(items)

    cnt = np.asarray(r_red.counts)
    mask = cnt > 0
    np.testing.assert_array_equal(np.asarray(r_sort.counts), cnt)
    np.testing.assert_allclose(
        np.asarray(r_sort.values)[mask], np.asarray(r_red.values)[mask],
        rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Invariant 4 (PR 2): key-blocked streaming folds are bitwise-equal to the
# unblocked reference across key spaces straddling the block boundary, and
# autotuned tilings respect the budget models.
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    kb=st.sampled_from([8, 16, 32, 64]),
    koff=st.integers(-3, 3),  # key space straddles the block boundary
    n=st.integers(1, 80),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_blocked_collector_fold_bitwise_equals_unblocked(kb, koff, n, seed):
    from repro.core import collector as col

    K = max(kb * 3 + koff, 2)  # 3 blocks ± straddle
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, K + 1, n).astype(np.int32))
    vals = jnp.asarray(rng.integers(-5, 6, n).astype(np.int32))
    stream = col.PairStream(keys, vals, K)
    aval = jax.ShapeDtypeStruct((), jnp.int32)

    def fold(key_block):
        sc = col.StreamCombiner(C.sum_spec(), K, aval, chunk_pairs=n,
                                key_block=key_block)
        assert sc.mode == "additive"
        tabs, counts = sc.tables_counts(
            sc.fold_chunk(sc.init_state(), stream))
        return (np.asarray(jax.tree.leaves(tabs)[0]), np.asarray(counts))

    base_t, base_c = fold(None)
    got_t, got_c = fold(kb)
    np.testing.assert_array_equal(got_t, base_t)
    np.testing.assert_array_equal(got_c, base_c)


@settings(max_examples=20, deadline=None)
@given(
    kb=st.sampled_from([8, 16, 64]),
    koff=st.integers(-3, 3),
    n=st.integers(1, 64),
    d=st.integers(1, 4),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_blocked_fold_kernel_bitwise_equals_unblocked(kb, koff, n, d, seed):
    """The Pallas kernel's key-block grid axis partitions only the key
    axis, so per-key accumulation order is unchanged — bitwise equality
    holds even for floats carrying exact small integers."""
    from repro.kernels import ops, ref

    K = max(kb * 2 + koff, 2)
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, K + 1, n).astype(np.int32))
    vals = jnp.asarray(rng.integers(-4, 5, (n, d)).astype(np.float32))
    acc = jnp.asarray(rng.integers(-4, 5, (K, d)).astype(np.float32))
    blocked = ops.onehot_fold(keys, vals, acc, block_k=kb)
    unblocked = ops.onehot_fold(keys, vals, acc, block_k=K)
    want = ref.onehot_fold(keys, vals, acc)
    np.testing.assert_array_equal(np.asarray(blocked), np.asarray(unblocked))
    np.testing.assert_array_equal(np.asarray(blocked), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    logk=st.integers(3, 21),  # key spaces 8 .. 2M
    use_kernels=st.booleans(),
)
def test_autotuned_tiling_respects_budget_models(logk, use_kernels):
    from repro.core import autotune as at
    from repro.core import collector as col
    from repro.kernels import ops
    from repro.roofline import analysis as roofline

    K = 1 << logk
    app = make_wc_app(K)
    app.reduce = REDUCERS["sum"]
    spec = C.sum_spec()
    t = at.autotune_stream(app, spec, use_kernels=use_kernels)
    assert t.chunk_pairs <= at.MAX_CHUNK_PAIRS
    if t.mode == "additive" and not use_kernels:
        # pure-JAX one-hot folds stay inside the fused-contraction regime
        assert t.chunk_pairs <= col.ADDITIVE_FOLD_PAIRS_FUSED
    if use_kernels:
        ws = roofline.stream_working_set_bytes(
            chunk_pairs=t.chunk_pairs, key_block=t.key_block, d=2)
        assert ws <= ops.VMEM_BUDGET // 2 + roofline.stream_working_set_bytes(
            chunk_pairs=t.chunk_pairs, key_block=1, d=2)
    big_n = 1 << 24
    peak = roofline.mapreduce_flow_peak_bytes(
        "stream", n_pairs=big_n, key_space=K, chunk_pairs=t.chunk_pairs,
        key_block=t.key_block)
    assert peak < roofline.mapreduce_flow_peak_bytes(
        "combine", n_pairs=big_n, key_space=K)
