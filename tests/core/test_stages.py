"""Staged execution surface: lower() -> optimize() -> compile() -> call,
ExecutionOptions as the single options vocabulary, the retired
legacy-kwarg surface (TypeError), and explain() at every stage."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import (
    Compiled,
    ExecutionOptions,
    Lowered,
    MapReduce,
    Optimized,
    make_app,
)
from repro.core import plan_cache as pc

VOCAB = 64


@pytest.fixture(scope="module")
def app():
    return make_app(
        map_fn=lambda item, emit: emit.emit(item % VOCAB,
                                            jnp.ones((), jnp.int32)),
        reduce_fn=lambda k, vs, n: vs.sum(),
        key_space=VOCAB,
        value_aval=jax.ShapeDtypeStruct((), jnp.int32),
    )


@pytest.fixture(scope="module")
def items():
    rng = np.random.default_rng(7)
    return jnp.asarray(rng.integers(0, VOCAB, size=3000), dtype=jnp.int32)


def test_staged_path_matches_run(app, items):
    mr = MapReduce(app)
    want = mr.run(items)

    low = mr.lower(items)
    assert isinstance(low, Lowered)
    opt = low.optimize()
    assert isinstance(opt, Optimized)
    comp = opt.compile()
    assert isinstance(comp, Compiled)
    got = comp(items)

    np.testing.assert_array_equal(np.asarray(want.values),
                                  np.asarray(got.values))
    np.testing.assert_array_equal(np.asarray(want.counts),
                                  np.asarray(got.counts))


def test_explain_at_every_stage(app, items):
    mr = MapReduce(app)
    assert "flow:" in mr.explain()
    low = mr.lower(items)
    assert "stage: lowered" in low.explain()
    assert "items:" in low.explain()
    comp = low.optimize().compile()
    comp(items)
    text = comp.explain()
    assert "stage: compiled" in text
    assert "mode: local" in text
    assert "plan-cache:" in text  # cache outcome + key always reported


def test_lowered_compile_shortcut_keeps_introspection(app, items):
    comp = MapReduce(app).lower(items).compile()
    assert "HloModule" in comp.as_text() or len(comp.as_text()) > 0
    assert comp.memory_analysis() is not None


def test_execution_options_on_run(app, items):
    mr = MapReduce(app)
    want = np.asarray(mr.run(items).values)
    got = mr.run(items, options=ExecutionOptions())
    np.testing.assert_array_equal(want, np.asarray(got.values))


def test_pow2_items_bucket_bitwise(app, items):
    mr = MapReduce(app)
    want = np.asarray(mr.run(items).values)
    got = mr.run(items, options=ExecutionOptions(items_bucket="pow2"))
    np.testing.assert_array_equal(want, np.asarray(got.values))
    # a second, slightly different N in the same pow2 bucket reuses the
    # padded executable instead of compiling a new one
    comp1 = mr.lower(items, options=ExecutionOptions(
        items_bucket="pow2")).compile()
    s0 = pc.stats_snapshot()
    comp2 = mr.lower(items[:-5], options=ExecutionOptions(
        items_bucket="pow2")).compile()
    s1 = pc.stats_snapshot()
    assert s1["compiles"] == s0["compiles"]
    assert comp1.n_bucket == comp2.n_bucket
    got2 = comp2(items[:-5])
    want2 = np.asarray(mr.run(items[:-5]).values)
    np.testing.assert_array_equal(want2, np.asarray(got2.values))


def test_padded_and_exact_executables_do_not_collide(app):
    """A pow2 batch of 5 padded to 8 compiles an ``(items, n_valid)``
    executable; exact-fit batches of 8 must not hit that entry (regression:
    identical keys dispatched the cached executable with the wrong arity)."""
    mr = MapReduce(app)
    rng = np.random.default_rng(13)
    five = jnp.asarray(rng.integers(0, VOCAB, size=5), dtype=jnp.int32)
    eight = jnp.asarray(rng.integers(0, VOCAB, size=8), dtype=jnp.int32)

    comp5 = mr.lower(five, options=ExecutionOptions(
        items_bucket="pow2")).compile()
    comp8_exact = mr.lower(eight).compile()
    comp8_pow2 = mr.lower(eight, options=ExecutionOptions(
        items_bucket="pow2")).compile()
    assert comp5.cache_key != comp8_exact.cache_key
    assert comp5.cache_key != comp8_pow2.cache_key

    want5 = np.asarray(mr.run(five).values)
    want8 = np.asarray(mr.run(eight).values)
    np.testing.assert_array_equal(want5, np.asarray(comp5(five).values))
    np.testing.assert_array_equal(want8,
                                  np.asarray(comp8_exact(eight).values))
    np.testing.assert_array_equal(want8,
                                  np.asarray(comp8_pow2(eight).values))


def test_compiled_plan_not_shared_across_cache_hits(app, items):
    """Each Compiled carries its own plan copy: run-time diagnostics from
    one caller must not leak into other Compiled objects sharing the
    cache entry (regression)."""
    mr = MapReduce(app)
    c1 = mr.lower(items).compile()
    c2 = mr.lower(items).compile()
    assert c1.plan is not c2.plan
    c1.plan.diagnostics += ("polluted",)
    assert "polluted" not in c2.plan.diagnostics
    c3 = mr.lower(items).compile()
    assert "polluted" not in c3.plan.diagnostics


def test_run_distributed_requires_mesh(app, items):
    mr = MapReduce(app)
    with pytest.raises(TypeError):
        mr.run_distributed(items)


def test_run_distributed_via_options(app, items):
    mr = MapReduce(app)
    want = np.asarray(mr.run(items).values)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    got = mr.run_distributed(items, options=ExecutionOptions(mesh=mesh))
    np.testing.assert_array_equal(want, np.asarray(got.values))


def test_run_resilient_staged(app, items):
    mr = MapReduce(app)
    want = np.asarray(mr.run(items).values)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    got = mr.run_resilient(items, options=ExecutionOptions(mesh=mesh))
    np.testing.assert_array_equal(want, np.asarray(got.values))


def test_legacy_kwargs_raise_type_error(app, items):
    """The PR 6 deprecation shim is retired: formerly-scattered kwargs now
    fail fast with a pointer at ExecutionOptions instead of forwarding."""
    mr = MapReduce(app)
    with pytest.raises(TypeError, match="ExecutionOptions"):
        mr.run(items, strict_shuffle=False)


def test_legacy_kwargs_raise_on_distributed(app, items):
    mr = MapReduce(app)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    with pytest.raises(TypeError, match="ExecutionOptions"):
        mr.run_distributed(items, mesh=mesh, scatter_output=False)


def test_unknown_kwarg_raises_type_error(app, items):
    mr = MapReduce(app)
    with pytest.raises(TypeError, match="unexpected keyword"):
        mr.run(items, not_an_option=1)


def test_options_path_emits_no_deprecation(app, items):
    mr = MapReduce(app)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        mr.run(items, options=ExecutionOptions(strict_shuffle=False))


def test_optimize_hints_override_options(app, items):
    low = MapReduce(app).lower(items)
    opt = low.optimize(items_bucket="pow2")
    assert opt.options.items_bucket == "pow2"
    with pytest.raises(TypeError):
        low.optimize(bogus_hint=1)
