"""Multi-job DAG fusion: jaxpr semantics extraction, fused-vs-unfused
bitwise parity, dead-column elimination, filter pushdown, and the roofline
handoff-bytes model (fused strictly fewer)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MapReduce, Pipeline, make_app
from repro.core import plan_cache as pc
from repro.core.pipeline import extract_semantics

VOCAB = 64
BUCKETS = 16


def wordcount():
    return make_app(
        map_fn=lambda item, emit: emit.emit(item % VOCAB,
                                            jnp.ones((), jnp.int32)),
        reduce_fn=lambda k, vs, n: vs.sum(),
        key_space=VOCAB,
        value_aval=jax.ShapeDtypeStruct((), jnp.int32),
    )


def histogram():
    """Second job reading the VALUE column of the word-count table."""
    def hist_map(item, emit):
        count = item[1]
        emit.emit(jnp.clip(count // 8, 0, BUCKETS - 1).astype(jnp.int32),
                  jnp.ones((), jnp.int32))

    return make_app(
        map_fn=hist_map,
        reduce_fn=lambda k, vs, n: vs.sum(),
        key_space=BUCKETS,
        value_aval=jax.ShapeDtypeStruct((), jnp.int32),
    )


def key_presence():
    """Second job reading only the KEY column — the value column is dead."""
    def pres_map(item, emit):
        emit.emit(item[0] % 8, jnp.ones((), jnp.int32))

    return make_app(
        map_fn=pres_map,
        reduce_fn=lambda k, vs, n: vs.sum(),
        key_space=8,
        value_aval=jax.ShapeDtypeStruct((), jnp.int32),
    )


@pytest.fixture(scope="module")
def items():
    rng = np.random.default_rng(11)
    return jnp.asarray(rng.integers(0, 5 * VOCAB, size=6000) % VOCAB,
                       dtype=jnp.int32)


def assert_same(a, b):
    np.testing.assert_array_equal(np.asarray(a.keys), np.asarray(b.keys))
    np.testing.assert_array_equal(np.asarray(a.values), np.asarray(b.values))
    np.testing.assert_array_equal(np.asarray(a.counts), np.asarray(b.counts))


# ---------------------------------------------------------------------------
# Semantics extraction
# ---------------------------------------------------------------------------


def test_semantics_value_reader():
    spec = (jax.ShapeDtypeStruct((), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))
    sem = extract_semantics(histogram(), spec)
    assert sem.reads_value
    assert not sem.reads_key


def test_semantics_key_only_reader():
    spec = (jax.ShapeDtypeStruct((), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))
    sem = extract_semantics(key_presence(), spec)
    assert sem.reads_key
    assert not sem.reads_value


# ---------------------------------------------------------------------------
# Fused execution parity
# ---------------------------------------------------------------------------


def test_fused_matches_unfused_value_consumer(items):
    pipe = Pipeline(wordcount()).then(histogram())
    assert_same(pipe.run(items), pipe.run_unfused(items))


def test_fused_matches_unfused_dead_value(items):
    pipe = Pipeline(wordcount()).then(key_presence())
    assert_same(pipe.run(items), pipe.run_unfused(items))
    assert any("dead column eliminated" in line
               for line in pipe.fusion_report())


def test_fused_matches_separate_jobs(items):
    """Fusion is bitwise against genuinely independent MapReduce runs, not
    just against the pipeline's own unfused mode."""
    wc, hist = wordcount(), histogram()
    stage1 = MapReduce(wc).run(items)
    mask = np.asarray(stage1.counts) > 0
    table = (jnp.asarray(np.asarray(stage1.keys)[mask]),
             jnp.asarray(np.asarray(stage1.values)[mask]),
             jnp.asarray(np.asarray(stage1.counts)[mask]))
    want = MapReduce(hist).run(table)

    got = Pipeline(wc).then(hist).run(items)
    np.testing.assert_array_equal(np.asarray(want.values),
                                  np.asarray(got.values))


def test_filter_pushdown(items):
    pipe = Pipeline(wordcount()).then(
        histogram(), where=lambda key, value, count: value > 90)
    assert_same(pipe.run(items), pipe.run_unfused(items))
    assert any("filter pushed below the shuffle" in line
               for line in pipe.fusion_report())


def test_value_filter_disables_dead_column(items):
    """The edge predicate reads the value column even when the consumer
    map doesn't: dead-column elimination must stay off or the fused path
    would evaluate ``where`` on zeroed values (regression)."""
    pipe = Pipeline(wordcount()).then(
        key_presence(), where=lambda key, value, count: value > 90)
    assert not pipe.stages[1].dead_value
    assert not any("dead column eliminated" in line
                   for line in pipe.fusion_report())
    assert_same(pipe.run(items), pipe.run_unfused(items))


def test_three_stage_chain(items):
    pipe = Pipeline(wordcount()).then(histogram()).then(key_presence())
    assert_same(pipe.run(items), pipe.run_unfused(items))


# ---------------------------------------------------------------------------
# Byte model + explain + caching
# ---------------------------------------------------------------------------


def test_model_bytes_fused_strictly_fewer(items):
    n = int(items.shape[0])
    for pipe in (Pipeline(wordcount()).then(histogram()),
                 Pipeline(wordcount()).then(key_presence())):
        assert pipe.model_bytes(n, fused=True) < \
            pipe.model_bytes(n, fused=False)


def test_dead_column_widens_the_gap(items):
    n = int(items.shape[0])
    live = Pipeline(wordcount()).then(histogram())
    dead = Pipeline(wordcount()).then(key_presence())
    gap_live = (live.model_bytes(n, fused=False)
                - live.model_bytes(n, fused=True))
    gap_dead = (dead.model_bytes(n, fused=False)
                - dead.model_bytes(n, fused=True))
    assert gap_dead > gap_live


def test_pipeline_explain_reports_fusion(items):
    pipe = Pipeline(wordcount()).then(histogram())
    pipe.run(items)
    text = pipe.explain()
    assert "fused handoff" in text
    assert "stage: pipeline" in text


def test_pipeline_compile_is_cached(items):
    pc.clear()
    pipe = Pipeline(wordcount()).then(histogram())
    s0 = pc.stats_snapshot()
    pipe.run(items)
    s1 = pc.stats_snapshot()
    assert s1["compiles"] - s0["compiles"] == 1

    fresh = Pipeline(wordcount()).then(histogram())
    s2 = pc.stats_snapshot()
    fresh.run(items)
    s3 = pc.stats_snapshot()
    assert s3["compiles"] - s2["compiles"] == 0, \
        "identical pipeline content must reuse the fused executable"
    assert s3["hits"] - s2["hits"] >= 1


def test_single_stage_pipeline_rejected(items):
    with pytest.raises(ValueError):
        Pipeline(wordcount()).compile(items)


def test_distributed_pipeline_not_supported(items):
    from repro.core import ExecutionOptions
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    pipe = Pipeline(wordcount()).then(histogram())
    with pytest.raises(NotImplementedError):
        pipe.run(items, options=ExecutionOptions(mesh=mesh))
