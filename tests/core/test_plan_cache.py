"""Content-keyed plan cache: hit/miss keying, the zero-re-trace contract
(asserted via the process-wide counters), the corrupt-safe file layer, and
bitwise parity of cached executions against cold runs on every flow."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ExecutionOptions, MapReduce, make_app
from repro.core import plan_cache as pc


def build_app(vocab=64, dtype=jnp.int32):
    return make_app(
        map_fn=lambda item, emit: emit.emit(item % vocab,
                                            jnp.ones((), dtype)),
        reduce_fn=lambda k, vs, n: vs.sum(),
        key_space=vocab,
        value_aval=jax.ShapeDtypeStruct((), dtype),
    )


@pytest.fixture(scope="module")
def items():
    rng = np.random.default_rng(3)
    return jnp.asarray(rng.integers(0, 64, size=2500), dtype=jnp.int32)


def delta(fn):
    s0 = pc.stats_snapshot()
    out = fn()
    s1 = pc.stats_snapshot()
    return out, {k: s1[k] - s0[k] for k in s1}


# ---------------------------------------------------------------------------
# In-memory keying
# ---------------------------------------------------------------------------


def test_warm_repeat_zero_retrace_zero_autotune(items):
    pc.clear()
    app = build_app()
    want = np.asarray(MapReduce(app).run(items).values)

    def warm():
        return np.asarray(MapReduce(build_app()).run(items).values)

    got, d = delta(warm)
    assert d["derives"] == 0, "plan-cache hit must skip combiner derivation"
    assert d["autotunes"] == 0, "plan-cache hit must skip the autotuner"
    assert d["probes"] == 0
    assert d["compiles"] == 0, "compiled-cache hit must skip XLA compile"
    assert d["plan_hits"] == 1 and d["hits"] == 1
    np.testing.assert_array_equal(want, got)


def test_changed_key_space_misses(items):
    pc.clear()
    MapReduce(build_app(vocab=64))
    _, d = delta(lambda: MapReduce(build_app(vocab=128)))
    assert d["plan_misses"] == 1 and d["plan_hits"] == 0


def test_changed_dtype_misses(items):
    pc.clear()
    MapReduce(build_app(dtype=jnp.int32))
    _, d = delta(lambda: MapReduce(build_app(dtype=jnp.float32)))
    assert d["plan_misses"] == 1 and d["plan_hits"] == 0


def test_changed_flow_misses(items):
    pc.clear()
    app = build_app()
    MapReduce(app, flow="stream")
    _, d = delta(lambda: MapReduce(app, flow="sort"))
    assert d["plan_misses"] == 1 and d["plan_hits"] == 0


def test_plan_key_distinguishes_mesh_and_shape(items):
    app = build_app()
    spec = pc.items_spec_of(items)
    pk = pc.plan_key(app, flow="auto", trust_semantics=False,
                     n_pairs_hint=None, use_kernels=False,
                     combine_impl="auto", chunk_pairs="auto",
                     key_block="auto", autotune_probe=False)
    base = pc.compiled_key(app, spec, plan_key=pk, flow="stream",
                           n_bucket=2500, mesh=None, data_axis="data",
                           mode="local", extra=())
    other_shape = pc.compiled_key(
        app, pc.items_spec_of(items[:-100]), plan_key=pk, flow="stream",
        n_bucket=2400, mesh=None, data_axis="data", mode="local", extra=())
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    other_mesh = pc.compiled_key(app, spec, plan_key=pk, flow="stream",
                                 n_bucket=2500, mesh=mesh,
                                 data_axis="data", mode="distributed",
                                 extra=())
    assert len({base, other_shape, other_mesh}) == 3


def test_closure_constants_are_part_of_the_key(items):
    """Two maps that differ only in a captured array must not collide."""
    def with_bias(bias):
        arr = jnp.full((), bias, jnp.int32)
        return make_app(
            map_fn=lambda item, emit: emit.emit((item + arr) % 64,
                                                jnp.ones((), jnp.int32)),
            reduce_fn=lambda k, vs, n: vs.sum(),
            key_space=64,
            value_aval=jax.ShapeDtypeStruct((), jnp.int32),
        )

    a, b = with_bias(0), with_bias(3)
    spec = pc.item_spec_of(items)
    assert pc.map_fingerprint(a, spec) != pc.map_fingerprint(b, spec)


def test_untraceable_fallback_keys_unique_and_stable(items):
    """Untraceable fns fall back to a per-app uid: stable on one app,
    never shared between apps (regression: the old ``id(app)`` fallback
    could alias a garbage-collected app's key)."""
    def bad_map(item, emit):
        if int(item) > 0:  # host branch on a tracer: untraceable
            emit.emit(item, jnp.ones((), jnp.int32))

    def bad_reduce(k, vs, n):
        return vs.sum() if int(n) > 0 else vs.sum()

    def build():
        return make_app(map_fn=bad_map, reduce_fn=bad_reduce, key_space=64,
                        value_aval=jax.ShapeDtypeStruct((), jnp.int32))

    a, b = build(), build()
    spec = pc.item_spec_of(pc.items_spec_of(items))
    assert pc.reduce_fingerprint(a) == pc.reduce_fingerprint(a)
    assert pc.reduce_fingerprint(a) != pc.reduce_fingerprint(b)
    assert pc.map_fingerprint(a, spec) == pc.map_fingerprint(a, spec)
    assert pc.map_fingerprint(a, spec) != pc.map_fingerprint(b, spec)


def test_cache_false_bypasses(items):
    pc.clear()
    app = build_app()

    def cold():
        mr = MapReduce(app, cache=False)
        return mr.run(items, options=ExecutionOptions(cache=False))

    _, d1 = delta(cold)
    _, d2 = delta(cold)
    assert d2["derives"] == d1["derives"] and d2["compiles"] == 1
    assert d2["hits"] == 0 and d2["plan_hits"] == 0
    assert pc.sizes() == (0, 0)


# ---------------------------------------------------------------------------
# Bitwise parity: cached executions vs cold runs, every flow
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("flow", ["stream", "sort", "combine", "reduce"])
def test_cached_plan_bitwise_identical(flow, items):
    pc.clear()
    app = build_app()
    cold = MapReduce(app, flow=flow).run(items)

    def warm():
        return MapReduce(build_app(), flow=flow).run(items)

    hot, d = delta(warm)
    assert d["derives"] == 0 and d["compiles"] == 0 and d["autotunes"] == 0
    np.testing.assert_array_equal(np.asarray(cold.keys),
                                  np.asarray(hot.keys))
    np.testing.assert_array_equal(np.asarray(cold.values),
                                  np.asarray(hot.values))
    np.testing.assert_array_equal(np.asarray(cold.counts),
                                  np.asarray(hot.counts))


# ---------------------------------------------------------------------------
# File-backed advisory layer
# ---------------------------------------------------------------------------


def test_file_layer_round_trip(tmp_path, monkeypatch, items):
    path = tmp_path / "plans.json"
    monkeypatch.setenv(pc.PLAN_CACHE_ENV, str(path))
    pc.clear()
    mr, d0 = delta(lambda: MapReduce(build_app(), autotune_probe=True))
    mr.run(items)
    if mr.plan.flow == "stream":  # sort's tuner is analytic, no probes
        assert d0["probes"] > 0, "cold construction should measure probes"
    assert path.exists()
    data = json.loads(path.read_text())
    assert mr._plan_key in data
    entry = data[mr._plan_key]
    assert entry["flow"] in ("stream", "sort", "combine", "reduce")
    assert isinstance(entry["chunk_pairs"], int)

    # simulate a fresh process: drop the in-memory layers, keep the file
    pc.clear()
    fresh, d = delta(lambda: MapReduce(build_app(), autotune_probe=True))
    assert d["file_hits"] == 1
    assert d["probes"] == 0, \
        "file-pinned tiling must skip the measured probes cross-process"
    if fresh.plan.flow in ("stream", "sort"):  # nothing to pin otherwise
        assert fresh.plan.cache_event == "file-hit"
        assert fresh.tiling.chunk_pairs == entry["chunk_pairs"]


def test_file_layer_corrupt_is_ignored(tmp_path, monkeypatch, items):
    path = tmp_path / "plans.json"
    monkeypatch.setenv(pc.PLAN_CACHE_ENV, str(path))
    path.write_text("{this is not json")
    pc.clear()
    mr = MapReduce(build_app())
    res = mr.run(items)  # must not raise
    assert int(np.asarray(res.counts).sum()) == items.shape[0]


def test_file_layer_stale_entry_is_ignored(tmp_path, monkeypatch, items):
    path = tmp_path / "plans.json"
    monkeypatch.setenv(pc.PLAN_CACHE_ENV, str(path))
    pc.clear()
    mr = MapReduce(build_app())
    # poison this exact key with wrong-typed fields (an older schema)
    path.write_text(json.dumps(
        {mr._plan_key: {"flow": "stream", "chunk_pairs": "not-an-int"}}))
    pc.clear()
    fresh, d = delta(lambda: MapReduce(build_app()))
    assert d["file_hits"] == 0, "wrong-typed entry must read as no-entry"
    assert fresh.plan.cache_event == "miss"


def test_file_layer_unknown_flow_is_ignored(tmp_path, monkeypatch, items):
    path = tmp_path / "plans.json"
    monkeypatch.setenv(pc.PLAN_CACHE_ENV, str(path))
    pc.clear()
    mr = MapReduce(build_app())
    path.write_text(json.dumps(
        {mr._plan_key: {"flow": "warp-drive", "chunk_pairs": 2048}}))
    pc.clear()
    _, d = delta(lambda: MapReduce(build_app()))
    assert d["file_hits"] == 0
