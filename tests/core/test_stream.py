"""Streaming map+combine fusion: parity with the legacy flows + the
bytes-pressure ordering the paper's Figs 8/9 claim (stream ≤ combine <
reduce on the WordCount system workload).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MapReduce, MapReduceApp, make_app
from repro.core import combiner as C
from repro.roofline import hlo_parser

VOCAB = 512


class WordCount(MapReduceApp):
    key_space = VOCAB
    value_aval = jax.ShapeDtypeStruct((), jnp.int32)
    emit_capacity = 8
    max_values_per_key = 1024

    def map(self, window, emit):
        emit(window, jnp.ones_like(window))

    def reduce(self, key, values, count):
        return jnp.sum(values)


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return rng.integers(0, VOCAB, size=(128, 8)).astype(np.int32)


# ---------------------------------------------------------------------------
# Parity: stream == combine == reduce on the canonical apps
# ---------------------------------------------------------------------------


def test_wordcount_three_flow_parity(tokens):
    want = np.bincount(tokens.reshape(-1), minlength=VOCAB)
    results = {
        flow: MapReduce(WordCount(), flow=flow).run(jnp.asarray(tokens))
        for flow in ("stream", "combine", "reduce")
    }
    for flow in ("stream", "combine"):
        np.testing.assert_array_equal(np.asarray(results[flow].values), want)
        np.testing.assert_array_equal(np.asarray(results[flow].counts), want)
    mask = want > 0
    np.testing.assert_array_equal(
        np.asarray(results["reduce"].values)[mask], want[mask])


def test_histogram_parity_multichunk():
    """Chunking engages (pairs >> chunk size); all flows agree."""
    rng = np.random.default_rng(1)
    px = rng.integers(0, 256, size=(4096, 3)).astype(np.int32)

    class Histogram(MapReduceApp):
        key_space = 768
        value_aval = jax.ShapeDtypeStruct((), jnp.int32)
        emit_capacity = 3
        max_values_per_key = 8192

        def map(self, pixel, emit):
            emit(jnp.arange(3, dtype=jnp.int32) * 256 + pixel,
                 jnp.ones((3,), jnp.int32))

        def reduce(self, key, values, count):
            return jnp.sum(values)

    want = np.bincount(
        (np.arange(3) * 256 + px).reshape(-1), minlength=768)
    mr = MapReduce(Histogram(), flow="stream", stream_chunk_pairs=1024)
    res = mr.run(jnp.asarray(px))
    np.testing.assert_array_equal(np.asarray(res.values), want)
    res_c = MapReduce(Histogram(), flow="combine").run(jnp.asarray(px))
    np.testing.assert_array_equal(np.asarray(res_c.values), want)


def test_mean_reducer_parity_stream():
    """Finalizing combiner (sum/count product) through the stream flow."""
    rng = np.random.default_rng(2)
    cids = rng.integers(0, 5, size=333).astype(np.int32)  # non-divisible
    pts = rng.standard_normal((333, 3)).astype(np.float32)
    app = make_app(
        lambda item, emit: emit(item[0].astype(jnp.int32), item[1]),
        lambda k, v, c: jnp.sum(v, axis=0) / jnp.maximum(c, 1).astype(
            jnp.float32),
        key_space=5,
        value_aval=jax.ShapeDtypeStruct((3,), jnp.float32),
        max_values_per_key=512,
        emit_capacity=1,
    )
    res = MapReduce(app, flow="stream", stream_chunk_pairs=64).run(
        (jnp.asarray(cids), jnp.asarray(pts)))
    got = np.asarray(res.values)
    for k in range(5):
        np.testing.assert_allclose(got[k], pts[cids == k].mean(0), atol=1e-5)


def test_masked_emission_stream():
    app = make_app(
        lambda item, emit: emit(item, jnp.ones_like(item), valid=item != 3),
        lambda k, v, c: jnp.sum(v),
        key_space=8,
        value_aval=jax.ShapeDtypeStruct((), jnp.int32),
        emit_capacity=8, max_values_per_key=64,
    )
    toks = jnp.asarray([[0, 3, 3, 1, 2, 3, 0, 1]] * 40, jnp.int32)
    res = MapReduce(app, flow="stream", stream_chunk_pairs=64).run(toks)
    assert int(res.counts[3]) == 0
    assert int(res.values[0]) == 80


def test_first_idiom_stream():
    """First-element idiom: holder keeps the first-arriving value across
    chunk boundaries."""
    app = make_app(
        lambda item, emit: emit(item[0], item[1]),
        lambda k, v, c: v[0],
        key_space=4,
        value_aval=jax.ShapeDtypeStruct((), jnp.float32),
        emit_capacity=1, max_values_per_key=256,
    )
    keys = np.array([2, 0, 2, 1, 0, 1, 3, 2] * 16, np.int32)
    vals = np.arange(len(keys), dtype=np.float32)
    mr = MapReduce(app, flow="stream", stream_chunk_pairs=16)
    assert mr.plan.derivation.strategy == C.STRATEGY_FIRST
    res = mr.run((jnp.asarray(keys), jnp.asarray(vals)))
    got = np.asarray(res.values)
    for k in range(4):
        assert got[k] == vals[np.argmax(keys == k)]


def test_generic_holder_stream_matches_segment():
    """Coupled-holder combiner (logsumexp) exercises the sequential
    holder-carry fallback across chunks."""
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 8, 200).astype(np.int32)
    vals = rng.standard_normal(200).astype(np.float32)
    app = make_app(
        lambda item, emit: emit(item[0], item[1]),
        lambda k, v, c: jax.scipy.special.logsumexp(v),
        key_space=8,
        value_aval=jax.ShapeDtypeStruct((), jnp.float32),
        emit_capacity=1, max_values_per_key=256,
        manual_combiner=C.logsumexp_spec(),
    )
    res_s = MapReduce(app, flow="stream", stream_chunk_pairs=32).run(
        (jnp.asarray(keys), jnp.asarray(vals)))
    res_c = MapReduce(app, flow="combine").run(
        (jnp.asarray(keys), jnp.asarray(vals)))
    np.testing.assert_allclose(np.asarray(res_s.values),
                               np.asarray(res_c.values), atol=1e-5)


def test_stream_use_kernels_parity(tokens):
    want = np.bincount(tokens.reshape(-1), minlength=VOCAB)
    res = MapReduce(WordCount(), flow="stream", use_kernels=True,
                    stream_chunk_pairs=256).run(jnp.asarray(tokens))
    np.testing.assert_array_equal(np.asarray(res.values), want)
    np.testing.assert_array_equal(np.asarray(res.counts), want)


# ---------------------------------------------------------------------------
# Bytes pressure: the paper's Figs 8/9 ordering, un-inverted
# ---------------------------------------------------------------------------


def _flow_bytes(mr, items):
    c = mr.lower(items).compile()
    return hlo_parser.analyze_text(c.as_text()).bytes_accessed


def test_bytes_monotonicity_stream_combine_reduce(tokens):
    """stream ≤ combine < reduce on the WordCount system workload: the
    derived-combiner flows move fewer bytes than the baseline, and the
    fused streaming flow is never worse than the legacy combine flow."""
    toks = jnp.asarray(tokens)
    b = {flow: _flow_bytes(MapReduce(WordCount(), flow=flow), toks)
         for flow in ("stream", "combine", "reduce")}
    assert b["stream"] <= b["combine"], b
    assert b["combine"] < b["reduce"], b


@pytest.mark.purejax_lowering  # skipped under the CI kernels override
def test_stream_peak_residency_bounded():
    """Peak live bytes of the stream flow stay O(K + chunk) while the
    legacy combine flow's grow with the full pair stream (Figs 8/9: the
    heap-pressure collapse)."""
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(0, VOCAB, (4096, 8)).astype(np.int32))

    def peak(mr):
        m = mr.lower(toks).compile().memory_analysis()
        return (m.argument_size_in_bytes + m.output_size_in_bytes +
                m.temp_size_in_bytes - m.alias_size_in_bytes)

    peak_stream = peak(MapReduce(WordCount(), flow="stream"))
    peak_combine = peak(MapReduce(WordCount(), flow="combine"))
    assert peak_stream < peak_combine / 2, (peak_stream, peak_combine)


def test_large_key_space_keeps_onehot_path():
    """key_space beyond the old dense-fold budget now stays on the one-hot
    additive fold (key-blocked where the lowering needs it) instead of
    silently degrading to the scatter fallback."""
    from repro.core import collector as col
    from repro.core import engine as eng

    BIG_K = (col.DENSE_FOLD_ELEMS_BUDGET // 256) + 1  # old scatter threshold
    app = make_app(
        lambda item, emit: emit(item, jnp.ones_like(item)),
        lambda k, v, c: jnp.sum(v),
        key_space=BIG_K,
        value_aval=jax.ShapeDtypeStruct((), jnp.int32),
        emit_capacity=4, max_values_per_key=64,
    )
    rng = np.random.default_rng(5)
    keys = rng.integers(0, BIG_K, (128, 4)).astype(np.int32)
    mr = MapReduce(app, flow="stream", stream_chunk_pairs=256)
    assert mr.tiling is not None and mr.tiling.mode == "additive"
    sc = eng._stream_combiner(app, mr.plan.spec, chunk_pairs=256)
    assert sc.mode == "additive"
    res = mr.run(jnp.asarray(keys))
    want = np.bincount(keys.reshape(-1), minlength=BIG_K)
    present = np.flatnonzero(want)
    np.testing.assert_array_equal(np.asarray(res.values)[present],
                                  want[present])


def test_scatter_fallback_beyond_fused_regime_warns():
    """Only past the fused-contraction pair regime does the pure-JAX
    streaming fold degrade to exact scatter — and it says so instead of
    choosing silently.  The Pallas kernel path is exempt (VMEM-resident
    one-hot tile)."""
    import pytest as _pytest

    from repro.core import collector as col
    from repro.core import combiner as C
    from repro.kernels import ops

    # past the fused pair regime AND the blocked dense budget at this
    # (chunk, key_block) — nothing scatter-free is left
    K = 1 << 16
    chunk = col.ADDITIVE_FOLD_PAIRS_FUSED * 2
    with _pytest.warns(col.LoweringFallbackWarning):
        sc = col.StreamCombiner(C.sum_spec(), K,
                                jax.ShapeDtypeStruct((), jnp.int32),
                                chunk_pairs=chunk)
    assert sc.mode == "scatter"
    # kernel path (float holders -> fused kernel runs): VMEM-resident
    # one-hot tile, no pair-regime limit
    sck = col.StreamCombiner(C.sum_spec(), K,
                             jax.ShapeDtypeStruct((), jnp.float32),
                             chunk_pairs=chunk, fold_fn=ops.onehot_fold)
    assert sck.mode == "additive"
    # ...but int holders bypass the fused kernel (exact-accumulation path),
    # so the pure-JAX budgets still apply under use_kernels
    sci = col.StreamCombiner(C.sum_spec(), K,
                             jax.ShapeDtypeStruct((), jnp.int32),
                             chunk_pairs=chunk, fold_fn=ops.onehot_fold)
    assert sci.mode == "scatter"


def test_int_tables_accumulate_exactly_per_chunk():
    """Integer holder tables accumulate in their own dtype across chunks
    (per-chunk f32 deltas are exact; the running sum is int32)."""
    app = make_app(
        lambda item, emit: emit(jnp.zeros_like(item), item),
        lambda k, v, c: jnp.sum(v),
        key_space=2,
        value_aval=jax.ShapeDtypeStruct((), jnp.int32),
        emit_capacity=1, max_values_per_key=1 << 12,
    )
    # each value near 2^20; 1024 of them sum to ~2^30 — far beyond f32's
    # 2^24 exact-integer range (an f32 running accumulator would drift by
    # the rounded-off low bits) but within int32, so exactness requires
    # the int32 table carry
    vals = np.full(1024, (1 << 20) + 7, np.int32)
    res = MapReduce(app, flow="stream", stream_chunk_pairs=64).run(
        jnp.asarray(vals))
    assert int(res.values[0]) == int(vals.astype(np.int64).sum())
