"""Semantic optimizer: derivation strategies on every reducer family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.core import combiner as C
from repro.core.optimizer import derive_combiner

KEY = jax.ShapeDtypeStruct((), jnp.int32)
F32 = jnp.float32


def scalar(dt=F32):
    return jax.ShapeDtypeStruct((), dt)


def vec(n, dt=F32):
    return jax.ShapeDtypeStruct((n,), dt)


CASES = [
    # (name, reduce_fn, value_aval, expected_strategy)
    ("sum", lambda k, v, c: jnp.sum(v), scalar(), "monoid"),
    ("mean", lambda k, v, c: jnp.sum(v) / c.astype(F32), scalar(), "monoid"),
    ("max_affine", lambda k, v, c: jnp.max(v * 2.0 + 1.0), scalar(), "monoid"),
    ("min", lambda k, v, c: jnp.min(v), scalar(), "monoid"),
    ("prod", lambda k, v, c: jnp.prod(v), scalar(), "monoid"),
    ("any", lambda k, v, c: jnp.any(v > 0), scalar(), "monoid"),
    ("all", lambda k, v, c: jnp.all(v > 0), scalar(), "monoid"),
    ("centroid", lambda k, v, c: jnp.sum(v, axis=0) / c.astype(F32),
     vec(3), "monoid"),
    ("variance",
     lambda k, v, c: jnp.sum(v * v) / c.astype(F32)
     - (jnp.sum(v) / c.astype(F32)) ** 2, scalar(), "monoid"),
    ("range", lambda k, v, c: jnp.max(v) - jnp.min(v), scalar(), "monoid"),
    ("weighted_mean",
     lambda k, v, c: jnp.sum(v[:, 0] * v[:, 1])
     / jnp.maximum(jnp.sum(v[:, 1]), 1e-6), vec(2), "monoid"),
    ("sum_exp", lambda k, v, c: jnp.sum(jnp.exp(v)), scalar(), "monoid"),
    ("first", lambda k, v, c: v[0], scalar(), "idiom_first"),
    ("size_only", lambda k, v, c: c * 2, scalar(), "idiom_size"),
    ("size_affine", lambda k, v, c: 3.0 * c.astype(F32) + 1.0, scalar(),
     "idiom_size"),
    ("scan_fold",
     lambda k, v, c: lax.scan(lambda a, x: (a + x * x, None), 0.0, v)[0],
     scalar(), "scan_fold"),
]

NEGATIVE = [
    ("median", lambda k, v, c: jnp.sort(v)[c // 2], scalar()),
    ("positional",
     lambda k, v, c: jnp.sum(v * jnp.arange(v.shape[0], dtype=F32)),
     scalar()),
    ("last_by_count", lambda k, v, c: v[c - 1], scalar()),
    # order-sensitive scan (EMA fold) must be caught by the numeric probes
    ("order_sensitive_scan",
     lambda k, v, c: lax.scan(lambda a, x: (a * 0.5 + x, None),
                              0.0, v)[0], scalar()),
]


@pytest.mark.parametrize("name,fn,vaval,strategy",
                         CASES, ids=[c[0] for c in CASES])
def test_derivation_strategy(name, fn, vaval, strategy):
    d = derive_combiner(fn, KEY, vaval)
    assert d.combinable, f"{name}: {d.failure}"
    assert d.strategy == strategy
    assert d.validated


@pytest.mark.parametrize("name,fn,vaval,_strategy", CASES[:12],
                         ids=[c[0] for c in CASES[:12]])
def test_fold_matches_reduce(name, fn, vaval, _strategy):
    d = derive_combiner(fn, KEY, vaval)
    rng = np.random.default_rng(3)
    vals = jnp.asarray(
        rng.standard_normal((13,) + tuple(vaval.shape)), F32)
    got = C.finalize_fold(d.spec, vals, jnp.int32(0))
    want = fn(jnp.int32(0), vals, jnp.int32(13))
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name,fn,vaval", NEGATIVE,
                         ids=[c[0] for c in NEGATIVE])
def test_rejections(name, fn, vaval):
    d = derive_combiner(fn, KEY, vaval)
    assert not d.combinable, f"{name} wrongly accepted ({d.strategy})"


def test_detection_times_recorded():
    d = derive_combiner(lambda k, v, c: jnp.sum(v), KEY, scalar())
    # the paper reports 81us detect / 7.6ms transform per class; ours must
    # at least be measured and sane
    assert 0 < d.detect_s < 5.0
    assert 0 <= d.transform_s < 5.0


def test_trust_semantics_skips_probes():
    d = derive_combiner(lambda k, v, c: jnp.sum(v), KEY, scalar(),
                        trust_semantics=True)
    assert d.combinable and not d.validated


def test_reapply_probe():
    d_sum = derive_combiner(lambda k, v, c: jnp.sum(v), KEY, scalar())
    assert d_sum.reapply_ok  # sum of sums == sum
    d_mean = derive_combiner(lambda k, v, c: jnp.sum(v) / c.astype(F32),
                             KEY, scalar())
    assert not d_mean.reapply_ok  # mean of unequal-split means != mean
