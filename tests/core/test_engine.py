"""Engine behaviour: both flows on the canonical apps, emitter semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MapReduce, MapReduceApp, make_app

VOCAB = 50


class WordCount(MapReduceApp):
    key_space = VOCAB
    value_aval = jax.ShapeDtypeStruct((), jnp.int32)
    max_values_per_key = 256
    emit_capacity = 8

    def map(self, item, emit):
        emit(item, jnp.ones_like(item))

    def reduce(self, key, values, count):
        return jnp.sum(values)


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return rng.integers(0, VOCAB, size=(40, 8)).astype(np.int32)


@pytest.mark.parametrize("flow", ["auto", "stream", "combine", "reduce"])
def test_wordcount(tokens, flow):
    want = np.bincount(tokens.reshape(-1), minlength=VOCAB)
    mr = MapReduce(WordCount(), flow=flow)
    res = mr.run(jnp.asarray(tokens))
    np.testing.assert_array_equal(np.asarray(res.counts), want)
    got = np.asarray(res.values)
    np.testing.assert_array_equal(got[want > 0], want[want > 0])
    # the optimizer's recommended flow is the streaming fusion (the CI
    # flow-matrix override redirects the auto default — honor it here,
    # normalized exactly like conftest's FLOW_OVERRIDE)
    import os
    auto_flow = (os.environ.get("REPRO_TEST_FLOW", "").strip().lower()
                 or "stream")
    assert mr.plan.flow == (auto_flow if flow == "auto" else flow)


@pytest.mark.parametrize("impl", ["scatter", "onehot", "segment"])
def test_combine_impls_agree(tokens, impl):
    mr = MapReduce(WordCount(), flow="combine", combine_impl=impl,
                   use_kernels=(impl == "onehot"))
    res = mr.run(jnp.asarray(tokens))
    want = np.bincount(tokens.reshape(-1), minlength=VOCAB)
    np.testing.assert_array_equal(np.asarray(res.values)[want > 0],
                                  want[want > 0])


def test_centroid_app():
    rng = np.random.default_rng(1)
    cids = rng.integers(0, 5, size=60).astype(np.int32)
    pts = rng.standard_normal((60, 3)).astype(np.float32)

    app = make_app(
        lambda item, emit: emit(item[0].astype(jnp.int32), item[1]),
        lambda k, v, c: jnp.sum(v, axis=0) / jnp.maximum(c, 1).astype(jnp.float32),
        key_space=5,
        value_aval=jax.ShapeDtypeStruct((3,), jnp.float32),
        max_values_per_key=64,
        emit_capacity=1,
    )
    for flow in ("auto", "stream", "combine", "reduce"):
        res = MapReduce(app, flow=flow).run((jnp.asarray(cids), jnp.asarray(pts)))
        got = np.asarray(res.values)
        for k in range(5):
            if (cids == k).any():
                np.testing.assert_allclose(got[k], pts[cids == k].mean(0),
                                           atol=1e-5)


def test_masked_emission():
    """emit(..., valid=mask) drops invalid pairs."""
    app = make_app(
        lambda item, emit: emit(item, jnp.ones_like(item), valid=item != 3),
        lambda k, v, c: jnp.sum(v),
        key_space=8,
        value_aval=jax.ShapeDtypeStruct((), jnp.int32),
        emit_capacity=8, max_values_per_key=64,
    )
    toks = jnp.asarray([[0, 3, 3, 1, 2, 3, 0, 1]], jnp.int32)
    res = MapReduce(app).run(toks)
    assert int(res.counts[3]) == 0
    assert int(res.values[0]) == 2


def test_emit_capacity_enforced():
    app = make_app(
        lambda item, emit: emit(item, jnp.ones_like(item)),
        lambda k, v, c: jnp.sum(v),
        key_space=8, value_aval=jax.ShapeDtypeStruct((), jnp.int32),
        emit_capacity=4, max_values_per_key=64,
    )
    with pytest.raises(Exception, match="emit_capacity"):
        MapReduce(app).run(jnp.zeros((2, 8), jnp.int32))


def test_forced_combine_on_noncombinable_raises():
    app = make_app(
        lambda item, emit: emit(item, item.astype(jnp.float32)),
        lambda k, v, c: jnp.sort(v)[0],
        key_space=8, value_aval=jax.ShapeDtypeStruct((), jnp.float32),
        emit_capacity=8, max_values_per_key=64,
    )
    with pytest.raises(ValueError, match="derivation failed"):
        MapReduce(app, flow="combine")


def test_result_to_dict(tokens):
    res = MapReduce(WordCount()).run(jnp.asarray(tokens))
    d = res.to_dict()
    want = np.bincount(tokens.reshape(-1), minlength=VOCAB)
    assert set(d) == set(np.nonzero(want)[0].tolist())
