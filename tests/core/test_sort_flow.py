"""The sort-based shuffle flow (radix-bucketed segment reduce) and the
cost-model flow selection.

Parity: the sort flow computes exactly what the reduce/stream flows compute
for every combiner strategy (monoid, product, first, size, sequential),
across chunk boundaries, with exact integer accumulation.

Selection: with a workload hint the planner ranks stream vs sort with the
roofline+compute cost model, the report lands on the plan, and explain()
shows flow + bucket count + cost terms.

Satellites: the per-plan LoweringFallbackWarning dedupe and the persistent
autotune probe cache.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

#: tests below that assert how flow="auto" RESOLVES cannot run under the
#: CI flow-matrix override (conftest redirects the auto default there and
#: owns the skip, via pytest_collection_modifyitems).
auto_flow_semantics = pytest.mark.auto_flow

from repro.core import MapReduce, MapReduceApp, make_app
from repro.core import autotune as at
from repro.core import collector as col
from repro.core import combiner as C
from repro.core import cost_model as cm

VOCAB = 512


class WordCount(MapReduceApp):
    key_space = VOCAB
    value_aval = jax.ShapeDtypeStruct((), jnp.int32)
    emit_capacity = 8
    max_values_per_key = 1024

    def map(self, window, emit):
        emit(window, jnp.ones_like(window))

    def reduce(self, key, values, count):
        return jnp.sum(values)


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return rng.integers(0, VOCAB, size=(128, 8)).astype(np.int32)


# ---------------------------------------------------------------------------
# Parity with the other flows
# ---------------------------------------------------------------------------


def test_wordcount_sort_flow_bitwise_parity(tokens):
    want = np.bincount(tokens.reshape(-1), minlength=VOCAB)
    res = MapReduce(WordCount(), flow="sort").run(jnp.asarray(tokens))
    np.testing.assert_array_equal(np.asarray(res.values), want)
    np.testing.assert_array_equal(np.asarray(res.counts), want)
    res_r = MapReduce(WordCount(), flow="reduce").run(jnp.asarray(tokens))
    mask = want > 0
    np.testing.assert_array_equal(np.asarray(res_r.values)[mask],
                                  np.asarray(res.values)[mask])


def test_sort_flow_multichunk_matches_single_chunk(tokens):
    want = np.bincount(tokens.reshape(-1), minlength=VOCAB)
    res = MapReduce(WordCount(), flow="sort",
                    stream_chunk_pairs=128).run(jnp.asarray(tokens))
    np.testing.assert_array_equal(np.asarray(res.values), want)
    np.testing.assert_array_equal(np.asarray(res.counts), want)


def test_sort_flow_max_monoid_segmented_scan():
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 50, 500).astype(np.int32)
    vals = rng.standard_normal(500).astype(np.float32)
    app = make_app(
        lambda item, emit: emit(item[0], item[1]),
        lambda k, v, c: jnp.max(v),
        key_space=50, value_aval=jax.ShapeDtypeStruct((), jnp.float32),
        emit_capacity=1, max_values_per_key=512, pad_value=-np.inf,
    )
    res = MapReduce(app, flow="sort", stream_chunk_pairs=128).run(
        (jnp.asarray(keys), jnp.asarray(vals)))
    got = np.asarray(res.values)
    for k in range(50):
        if (keys == k).any():
            np.testing.assert_allclose(got[k], vals[keys == k].max(),
                                       rtol=1e-6)


def test_sort_flow_mean_product_spec():
    rng = np.random.default_rng(2)
    cids = rng.integers(0, 5, 333).astype(np.int32)
    pts = rng.standard_normal((333, 3)).astype(np.float32)
    app = make_app(
        lambda item, emit: emit(item[0].astype(jnp.int32), item[1]),
        lambda k, v, c: jnp.sum(v, axis=0) / jnp.maximum(c, 1).astype(
            jnp.float32),
        key_space=5, value_aval=jax.ShapeDtypeStruct((3,), jnp.float32),
        emit_capacity=1, max_values_per_key=512,
    )
    res = MapReduce(app, flow="sort", stream_chunk_pairs=64).run(
        (jnp.asarray(cids), jnp.asarray(pts)))
    got = np.asarray(res.values)
    for k in range(5):
        np.testing.assert_allclose(got[k], pts[cids == k].mean(0), atol=1e-5)


def test_sort_flow_first_idiom_stable_across_chunks():
    """The packed sort is stable, so the run start IS the first-arrived
    value — including across chunk boundaries via the count gate."""
    app = make_app(
        lambda item, emit: emit(item[0], item[1]),
        lambda k, v, c: v[0],
        key_space=4, value_aval=jax.ShapeDtypeStruct((), jnp.float32),
        emit_capacity=1, max_values_per_key=256,
    )
    keys = np.array([2, 0, 2, 1, 0, 1, 3, 2] * 16, np.int32)
    vals = np.arange(len(keys), dtype=np.float32)
    mr = MapReduce(app, flow="sort", stream_chunk_pairs=16)
    assert mr.plan.derivation.strategy == C.STRATEGY_FIRST
    res = mr.run((jnp.asarray(keys), jnp.asarray(vals)))
    got = np.asarray(res.values)
    for k in range(4):
        assert got[k] == vals[np.argmax(keys == k)]


def test_sort_flow_sequential_fallback_logsumexp():
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 8, 200).astype(np.int32)
    vals = rng.standard_normal(200).astype(np.float32)
    app = make_app(
        lambda item, emit: emit(item[0], item[1]),
        lambda k, v, c: jax.scipy.special.logsumexp(v),
        key_space=8, value_aval=jax.ShapeDtypeStruct((), jnp.float32),
        emit_capacity=1, max_values_per_key=256,
        manual_combiner=C.logsumexp_spec(),
    )
    res_s = MapReduce(app, flow="sort", stream_chunk_pairs=32).run(
        (jnp.asarray(keys), jnp.asarray(vals)))
    res_c = MapReduce(app, flow="combine").run(
        (jnp.asarray(keys), jnp.asarray(vals)))
    np.testing.assert_allclose(np.asarray(res_s.values),
                               np.asarray(res_c.values), atol=1e-5)


def test_sort_flow_masked_emission():
    app = make_app(
        lambda item, emit: emit(item, jnp.ones_like(item), valid=item != 3),
        lambda k, v, c: jnp.sum(v),
        key_space=8, value_aval=jax.ShapeDtypeStruct((), jnp.int32),
        emit_capacity=8, max_values_per_key=64,
    )
    toks = jnp.asarray([[0, 3, 3, 1, 2, 3, 0, 1]] * 40, jnp.int32)
    res = MapReduce(app, flow="sort", stream_chunk_pairs=64).run(toks)
    assert int(res.counts[3]) == 0
    assert int(res.values[0]) == 80


def test_sort_flow_int_tables_exact_beyond_f32():
    """Integer holder specs bypass the fused f32 accumulator: per-chunk
    int32 cumsums merge into int32 tables, exact past 2^24."""
    app = make_app(
        lambda item, emit: emit(jnp.zeros_like(item), item),
        lambda k, v, c: jnp.sum(v),
        key_space=2, value_aval=jax.ShapeDtypeStruct((), jnp.int32),
        emit_capacity=1, max_values_per_key=1 << 12,
    )
    vals = np.full(1024, (1 << 20) + 7, np.int32)
    res = MapReduce(app, flow="sort", stream_chunk_pairs=64).run(
        jnp.asarray(vals))
    assert int(res.values[0]) == int(vals.astype(np.int64).sum())


def test_sort_flow_use_kernels_parity(tokens):
    """The radix-partition + segment_reduce pipeline (interpret mode)."""
    want = np.bincount(tokens.reshape(-1), minlength=VOCAB)
    app = make_app(
        lambda item, emit: emit(item, jnp.ones_like(item, jnp.float32)),
        lambda k, v, c: jnp.sum(v),
        key_space=VOCAB, value_aval=jax.ShapeDtypeStruct((), jnp.float32),
        emit_capacity=8, max_values_per_key=1024,
    )
    res = MapReduce(app, flow="sort", use_kernels=True,
                    stream_chunk_pairs=512).run(jnp.asarray(tokens))
    np.testing.assert_array_equal(np.asarray(res.values), want)
    np.testing.assert_array_equal(np.asarray(res.counts), want)


def test_sort_combiner_fused_accumulator_engaged():
    spec = C.sum_spec()
    aval_f = jax.ShapeDtypeStruct((), jnp.float32)
    aval_i = jax.ShapeDtypeStruct((), jnp.int32)
    assert col.SortCombiner(spec, 64, aval_f)._fused_acc
    assert not col.SortCombiner(spec, 64, aval_i)._fused_acc  # exactness
    rng = np.random.default_rng(4)
    keys = jnp.asarray(rng.integers(0, 65, 200).astype(np.int32))  # + sentinel
    vals = jnp.asarray(rng.standard_normal(200).astype(np.float32))
    stream = col.PairStream(keys, vals, 64)
    grouped = col.sort_flow(spec, stream)
    want = np.zeros(64, np.float64)
    np.add.at(want, np.asarray(keys)[np.asarray(keys) < 64],
              np.asarray(vals, np.float64)[np.asarray(keys) < 64])
    np.testing.assert_allclose(np.asarray(grouped.values), want, atol=1e-4)


def test_forced_sort_on_noncombinable_raises():
    app = make_app(
        lambda item, emit: emit(item, item.astype(jnp.float32)),
        lambda k, v, c: jnp.sort(v)[0],
        key_space=8, value_aval=jax.ShapeDtypeStruct((), jnp.float32),
        emit_capacity=8, max_values_per_key=64,
    )
    with pytest.raises(ValueError, match="derivation failed"):
        MapReduce(app, flow="sort")


# ---------------------------------------------------------------------------
# Multi-pass hierarchical radix shuffle (ISSUE 4)
# ---------------------------------------------------------------------------


BIG_SORT_K = 1 << 17  # past the 31-bit packed-sort regime at 16k chunks


def test_stable_sort_multi_pass_equals_two_key():
    """The lax.scan-over-levels radix sort is stable and bitwise equal to
    the two-key comparator sort it replaces (keys + permutation)."""
    rng = np.random.default_rng(7)
    keys = jnp.asarray(rng.integers(0, BIG_SORT_K + 1, 1 << 14)
                       .astype(np.int32))  # incl. sentinel
    sk_r, ord_r = jax.jit(lambda x: col.stable_sort_by_key(
        x, BIG_SORT_K, impl="radix"))(keys)
    sk_t, ord_t = jax.jit(lambda x: col.stable_sort_by_key(
        x, BIG_SORT_K, impl="two_key"))(keys)
    np.testing.assert_array_equal(np.asarray(sk_r), np.asarray(sk_t))
    np.testing.assert_array_equal(np.asarray(ord_r), np.asarray(ord_t))
    # auto resolves to the multi-pass radix here (the old silent degrade)
    sk_a, ord_a = jax.jit(lambda x: col.stable_sort_by_key(
        x, BIG_SORT_K))(keys)
    np.testing.assert_array_equal(np.asarray(ord_a), np.asarray(ord_t))


def test_sort_radix_passes_regimes():
    assert col.sort_radix_passes(1 << 14, 1 << 15) == 1  # packed fits
    assert col.sort_radix_passes(1 << 14, BIG_SORT_K) == 2
    assert col.sort_radix_passes(4096, 1 << 20) == 2
    with pytest.raises(ValueError, match="packed"):
        col.stable_sort_by_key(jnp.zeros(1 << 14, jnp.int32), 1 << 20,
                               impl="packed")


def test_sort_flow_multi_pass_regime_parity():
    """flow="sort" past the packed regime: 16k-pair chunks at K=2^17 push
    (key, index) past 31 bits, so the fold runs the multi-pass radix —
    exact parity with the bincount ground truth, across chunk boundaries."""
    rng = np.random.default_rng(8)
    toks = rng.integers(0, BIG_SORT_K, size=(4096, 8)).astype(np.int32)
    app = _sum_app(BIG_SORT_K)
    want = np.bincount(toks.reshape(-1), minlength=BIG_SORT_K)
    mr = MapReduce(app, flow="sort", stream_chunk_pairs=1 << 14)
    assert mr.tiling.sort_passes > 1  # the multi-pass regime is engaged
    res = mr.run(jnp.asarray(toks))
    np.testing.assert_array_equal(np.asarray(res.values), want)
    np.testing.assert_array_equal(np.asarray(res.counts), want)


def test_sort_flow_kernel_hierarchy_parity(monkeypatch):
    """use_kernels with a key space past one bucket sweep: the hierarchical
    multi-pass pipeline (levels > 1) stays bitwise exact.  Budgets shrunk
    so the hierarchy engages at test-sized K."""
    from repro.kernels import ops

    monkeypatch.setattr(ops, "LEAF_BUCKET_CAP", 256)
    monkeypatch.setattr(ops, "MAX_RADIX_FANOUT", 4)
    K = 4096
    app = make_app(
        lambda item, emit: emit(item, jnp.ones_like(item, jnp.float32)),
        lambda k, v, c: jnp.sum(v),
        key_space=K, value_aval=jax.ShapeDtypeStruct((), jnp.float32),
        emit_capacity=8, max_values_per_key=1024,
    )
    plan = ops.plan_radix_levels(K, d=2)
    assert plan.levels == 2  # 16 leaves of 256 keys at fan-out 4
    rng = np.random.default_rng(9)
    toks = rng.integers(0, K, size=(128, 8)).astype(np.int32)
    mr = MapReduce(app, flow="sort", use_kernels=True,
                   stream_chunk_pairs=512)
    assert mr.tiling.level_fanouts == plan.fanouts
    res = mr.run(jnp.asarray(toks))
    want = np.bincount(toks.reshape(-1), minlength=K)
    np.testing.assert_array_equal(np.asarray(res.values), want)
    np.testing.assert_array_equal(np.asarray(res.counts), want)


def test_sort_flow_level_budget_fallback_warns_once(monkeypatch):
    """Satellite fix: a key space past the level budget fires ONE
    LoweringFallbackWarning with plan diagnostics and degrades to the
    pure-JAX multi-pass sorted fold — instead of silently clamping the
    bucket count (results stay exact either way)."""
    from repro.kernels import ops

    monkeypatch.setattr(ops, "MAX_RADIX_LEVELS", 1)
    monkeypatch.setattr(ops, "LEAF_BUCKET_CAP", 256)
    monkeypatch.setattr(ops, "MAX_RADIX_FANOUT", 4)
    K = 4096  # needs 2 levels under the shrunk budget
    app = make_app(
        lambda item, emit: emit(item, jnp.ones_like(item, jnp.float32)),
        lambda k, v, c: jnp.sum(v),
        key_space=K, value_aval=jax.ShapeDtypeStruct((), jnp.float32),
        emit_capacity=8, max_values_per_key=1024,
    )
    rng = np.random.default_rng(10)
    toks = rng.integers(0, K, size=(64, 8)).astype(np.int32)
    mr = MapReduce(app, flow="sort", use_kernels=True)
    assert any("LEVEL BUDGET" in n for n in mr.tiling.notes)
    with pytest.warns(col.LoweringFallbackWarning, match="radix levels"):
        res = mr.run(jnp.asarray(toks))
    want = np.bincount(toks.reshape(-1), minlength=K)
    np.testing.assert_array_equal(np.asarray(res.values), want)
    assert any("radix levels" in d for d in mr.plan.diagnostics)
    with warnings.catch_warnings():  # re-trace: deduped per plan
        warnings.simplefilter("error", col.LoweringFallbackWarning)
        mr.run(jnp.asarray(rng.integers(0, K, size=(80, 8))
                           .astype(np.int32)))


def test_sort_cost_model_prices_multi_pass():
    """The extended cost model charges the pure-JAX lowering one packed
    sort per digit pass — the sort estimate must grow past the packed
    regime — while still picking sort over the one-hot fold at K=1M."""
    small = cm.estimate_flow_cost("sort", n_pairs=4096, key_space=1 << 15)
    big = cm.estimate_flow_cost("sort", n_pairs=4096, key_space=1 << 20)
    assert dict(big.terms)["sort"] > dict(small.terms)["sort"]
    report = cm.choose_flow(n_pairs=4096, key_space=1 << 20, backend="cpu")
    assert report.chosen == "sort"


def test_explain_shows_levels_at_large_k():
    mr = MapReduce(_sum_app(1 << 20, jnp.float32), flow="sort",
                   n_pairs_hint=4096)
    text = mr.explain()
    assert "levels=2" in text and "buckets=" in text
    assert mr.tiling.level_fanouts and mr.tiling.levels == 2
    assert mr.tiling.sort_passes == 2


# -- hypothesis: multi-pass ≡ single-pass ≡ reduce --------------------------

try:  # optional dependency (mirrors tests/core/test_properties.py)
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=12, deadline=None)
    @given(
        bucket_pow=st.integers(2, 4),       # leaf 4..16 keys
        fan_pows=st.lists(st.integers(1, 2), min_size=2, max_size=3),
        k_off=st.integers(0, 3),            # K not a bucket·ΠB multiple
        n=st.integers(1, 120),
        seed=st.integers(0, 2 ** 16),
    )
    def test_multi_pass_equals_single_pass_equals_reduce(
            bucket_pow, fan_pows, k_off, n, seed):
        """Random level splits: the hierarchical kernel fold, the
        single-level kernel fold and the reduce-flow ground truth agree,
        including K % bucket^levels != 0 and sentinel/trash invariants."""
        from repro.kernels import ops, ref

        bs = 1 << bucket_pow
        fanouts = tuple(1 << p for p in fan_pows)
        cover = bs
        for b in fanouts:
            cover *= b
        k = max(cover - k_off, bs + 1)  # force >1 bucket, ragged last leaf
        rng = np.random.default_rng(seed)
        keys = rng.integers(0, k + 1, size=n).astype(np.int32)  # + sentinel
        vals = rng.standard_normal((n, 1)).astype(np.float32)
        pa = 8
        multi_k, multi_v, _ = ops.radix_partition(
            jnp.asarray(keys), jnp.asarray(vals), k, bucket_size=bs,
            fanouts=fanouts, pad_align=pa, tile_n=pa)
        single_k, single_v, _ = ops.radix_partition(
            jnp.asarray(keys), jnp.asarray(vals), k, bucket_size=bs,
            pad_align=pa, tile_n=pa)
        np.testing.assert_array_equal(np.asarray(multi_k),
                                      np.asarray(single_k))
        real = np.asarray(single_k) < k
        np.testing.assert_allclose(np.asarray(multi_v)[real],
                                   np.asarray(single_v)[real], rtol=1e-6)
        # sentinel/trash invariants: dropped slots normalized, none lost
        mk = np.asarray(multi_k)
        np.testing.assert_array_equal(np.sort(mk[mk < k]),
                                      np.sort(keys[keys < k]))
        assert (mk <= k).all()
        # the folded table == the reduce-flow per-key sums (ground truth)
        acc = jnp.zeros((k, 1), jnp.float32)
        got = ops.sort_segment_fold(jnp.asarray(keys), jnp.asarray(vals),
                                    acc, "add", bucket_size=bs,
                                    fanouts=fanouts, pad_align=pa)
        want = np.zeros((k, 1), np.float64)
        np.add.at(want, keys[keys < k],
                  vals[keys < k].astype(np.float64))
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-4)
        oracle = ref.sort_segment_fold(jnp.asarray(keys), jnp.asarray(vals),
                                       acc, "add")
        np.testing.assert_allclose(np.asarray(got), np.asarray(oracle),
                                   atol=1e-4)


# ---------------------------------------------------------------------------
# Cost-model flow selection + explain()
# ---------------------------------------------------------------------------


def _sum_app(key_space, dtype=jnp.int32):
    return make_app(
        lambda item, emit: emit(item, jnp.ones_like(item)),
        lambda k, v, c: jnp.sum(v),
        key_space=key_space, value_aval=jax.ShapeDtypeStruct((), dtype),
        emit_capacity=8, max_values_per_key=64,
    )


@auto_flow_semantics
def test_cost_model_picks_sort_at_large_sparse_k():
    mr = MapReduce(_sum_app(32768), n_pairs_hint=1024)
    assert mr.plan.flow == "sort"
    assert mr.plan.cost is not None and mr.plan.cost.chosen == "sort"
    sort_c = mr.plan.cost.cost_of("sort")
    stream_c = mr.plan.cost.cost_of("stream")
    assert sort_c.est_s < stream_c.est_s
    # the separating term is compute: the one-hot fold's O(N·K)
    assert dict(stream_c.terms)["onehot"] > dict(sort_c.terms)["sort"]


@auto_flow_semantics
def test_cost_model_keeps_stream_at_small_k():
    mr = MapReduce(_sum_app(4), n_pairs_hint=1024)
    assert mr.plan.flow == "stream"


@auto_flow_semantics
def test_auto_without_hint_keeps_stream_default():
    """No workload hint -> the paper's one-flag behaviour is unchanged."""
    mr = MapReduce(_sum_app(32768))
    assert mr.plan.flow == "stream"


@auto_flow_semantics
def test_cost_model_not_offered_for_coupled_holders():
    """Scan-fold specs can't take the vectorized sort path; the model only
    ranks flows the combiner can actually run."""
    app = make_app(
        lambda item, emit: emit(item[0], item[1]),
        lambda k, v, c: jax.scipy.special.logsumexp(v),
        key_space=32768, value_aval=jax.ShapeDtypeStruct((), jnp.float32),
        emit_capacity=1, max_values_per_key=64,
        manual_combiner=C.logsumexp_spec(),
    )
    mr = MapReduce(app, n_pairs_hint=1024)
    assert mr.plan.flow == "stream"
    assert tuple(c.flow for c in mr.plan.cost.costs) == ("stream",)


@auto_flow_semantics
def test_explain_reports_flow_buckets_and_cost_terms():
    mr = MapReduce(_sum_app(32768), n_pairs_hint=1024)
    text = mr.explain()
    assert "flow: sort" in text
    assert "cost model" in text and "est=" in text
    assert "buckets=" in text  # radix bucket count via the tiling record
    assert mr.tiling.mode == "sort" and mr.tiling.n_key_blocks >= 1


def test_flow_cost_model_bytes_ordering():
    """The analytic bytes chain the crossover benchmark asserts:
    sort ≤ combine < reduce (single chunk — sort == combine there)."""
    kw = dict(n_pairs=1024, key_space=32768, max_values_per_key=8,
              backend="cpu")
    b = {f: cm.estimate_flow_cost(f, **kw).model_bytes
         for f in ("sort", "combine", "reduce")}
    assert b["sort"] <= b["combine"] < b["reduce"]


def test_tpu_profile_moves_crossover_right():
    """On the MXU profile the one-hot fold stays cheap far past the CPU
    crossover (the co-design point: same semantics, different winner per
    architecture) — the radix partition's per-pair scalar stores only pay
    off when K reaches the few-hundred-k range."""
    cpu = cm.choose_flow(n_pairs=1024, key_space=32768, backend="cpu")
    tpu = cm.choose_flow(n_pairs=1024, key_space=32768, backend="tpu")
    assert cpu.chosen == "sort"
    assert tpu.chosen == "stream"
    tpu_big = cm.choose_flow(n_pairs=1024, key_space=1 << 21, backend="tpu")
    assert tpu_big.chosen == "sort"


# ---------------------------------------------------------------------------
# Satellite: per-plan LoweringFallbackWarning dedupe
# ---------------------------------------------------------------------------


def test_fallback_warning_deduped_per_plan():
    """The dense-budget degrade warns ONCE per plan (not once per trace)
    while the plan diagnostic list stays complete."""
    app = _sum_app(1 << 16)
    # chunk past the fused regime AND blocking disabled -> nothing
    # scatter-free is left, the collector degrades (and used to warn on
    # every trace)
    mr = MapReduce(app, flow="stream", stream_chunk_pairs=4096,
                   stream_key_block=None)
    rng = np.random.default_rng(5)
    with pytest.warns(col.LoweringFallbackWarning):
        mr.run(jnp.asarray(
            rng.integers(0, 1 << 16, (1024, 8)).astype(np.int32)))
    with warnings.catch_warnings():
        warnings.simplefilter("error", col.LoweringFallbackWarning)
        # a NEW shape forces a re-trace of the same plan — no second warning
        mr.run(jnp.asarray(
            rng.integers(0, 1 << 16, (1536, 8)).astype(np.int32)))
    assert any("scatter" in d for d in mr.plan.diagnostics)


def test_direct_collector_construction_still_warns():
    """Without a plan sink the legacy warn-at-construction behaviour holds
    (tests and direct users keep their signal)."""
    with pytest.warns(col.LoweringFallbackWarning):
        col.StreamCombiner(C.sum_spec(), 1 << 16,
                           jax.ShapeDtypeStruct((), jnp.int32),
                           chunk_pairs=col.ADDITIVE_FOLD_PAIRS_FUSED * 2)


# ---------------------------------------------------------------------------
# Satellite: persistent autotune probe cache
# ---------------------------------------------------------------------------


def test_tune_cache_persists_probe_results(tmp_path, monkeypatch):
    path = str(tmp_path / "tune.json")
    monkeypatch.setenv(at.TUNE_CACHE_ENV, path)
    app = _sum_app(64)
    spec = C.sum_spec()
    t1 = at.autotune_stream(app, spec, probe=True, probe_pairs=256)
    assert t1.source == "probe"
    cache = at.load_tune_cache(path)
    assert len(cache) == 1
    (entry,) = cache.values()
    assert entry["chunk_pairs"] == t1.chunk_pairs
    # second run: measured result reused, no re-probing
    t2 = at.autotune_stream(app, spec, probe=True, probe_pairs=256)
    assert t2.source == "cache"
    assert t2.chunk_pairs == t1.chunk_pairs
    assert any("cache hit" in n for n in t2.notes)


def test_tune_cache_off_by_default(monkeypatch):
    monkeypatch.delenv(at.TUNE_CACHE_ENV, raising=False)
    assert at.tune_cache_path() is None
    t = at.autotune_stream(_sum_app(64), C.sum_spec(), probe=True,
                           probe_pairs=256)
    assert t.source == "probe"  # measured, nothing persisted


def test_tune_cache_ignores_corrupt_file(tmp_path, monkeypatch):
    path = str(tmp_path / "tune.json")
    with open(path, "w") as f:
        f.write("{not json")
    monkeypatch.setenv(at.TUNE_CACHE_ENV, path)
    t = at.autotune_stream(_sum_app(64), C.sum_spec(), probe=True,
                           probe_pairs=256)
    assert t.source == "probe"  # advisory: bad cache never breaks a run
