"""The roofline-driven autotuner + key-blocked streaming fold.

Property 1: the key-blocked folds are bitwise-equal to the unblocked
reference across key spaces straddling the block boundary (integer
channels, where bitwise equality is well-defined regardless of reduction
shape).

Property 2: autotuned tilings respect the budget models — the kernel-path
working set fits the VMEM budget (with double-buffer headroom) and the
masked dense expansion fits its elems budget.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MapReduce, make_app
from repro.core import autotune as at
from repro.core import collector as col
from repro.core import combiner as C
from repro.kernels import ops, ref
from repro.roofline import analysis as roofline

I32 = jnp.int32


def _sum_app(key_space):
    return make_app(
        lambda item, emit: emit(item, jnp.ones_like(item)),
        lambda k, v, c: jnp.sum(v),
        key_space=key_space,
        value_aval=jax.ShapeDtypeStruct((), I32),
        emit_capacity=4, max_values_per_key=64,
    )


# ---------------------------------------------------------------------------
# Property 1: blocked == unblocked, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kb,koff,n,seed", [
    (8, -3, 1, 0), (8, 0, 33, 1), (16, 1, 80, 2), (32, -1, 64, 3),
    (64, 3, 50, 4), (64, 0, 7, 5),
])
def test_blocked_collector_fold_bitwise_equals_unblocked(kb, koff, n, seed):
    """Fixed-grid version of the hypothesis property in test_properties.py
    (runs even without hypothesis installed)."""
    K = max(kb * 3 + koff, 2)  # 3 blocks ± straddle
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, K + 1, n).astype(np.int32))
    vals = jnp.asarray(rng.integers(-5, 6, n).astype(np.int32))
    stream = col.PairStream(keys, vals, K)
    aval = jax.ShapeDtypeStruct((), I32)

    def fold(key_block):
        sc = col.StreamCombiner(C.sum_spec(), K, aval, chunk_pairs=n,
                                key_block=key_block)
        assert sc.mode == "additive"
        tabs, counts = sc.tables_counts(
            sc.fold_chunk(sc.init_state(), stream))
        return (np.asarray(jax.tree.leaves(tabs)[0]), np.asarray(counts))

    base_t, base_c = fold(None)
    got_t, got_c = fold(kb)
    np.testing.assert_array_equal(got_t, base_t)
    np.testing.assert_array_equal(got_c, base_c)


@pytest.mark.parametrize("kb,koff,n,d,seed", [
    (8, -2, 17, 1, 0), (16, 1, 64, 3, 1), (16, -1, 40, 2, 2),
    (64, 3, 33, 4, 3), (64, 0, 1, 1, 4),
])
def test_blocked_fold_kernel_bitwise_equals_unblocked(kb, koff, n, d, seed):
    """The Pallas kernel's key-block grid axis partitions only the key
    axis, so per-key accumulation order is unchanged — bitwise equality
    holds even for floats carrying exact small integers."""
    K = max(kb * 2 + koff, 2)
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, K + 1, n).astype(np.int32))
    vals = jnp.asarray(rng.integers(-4, 5, (n, d)).astype(np.float32))
    acc = jnp.asarray(rng.integers(-4, 5, (K, d)).astype(np.float32))
    blocked = ops.onehot_fold(keys, vals, acc, block_k=kb)
    unblocked = ops.onehot_fold(keys, vals, acc, block_k=K)
    want = ref.onehot_fold(keys, vals, acc)
    np.testing.assert_array_equal(np.asarray(blocked), np.asarray(unblocked))
    np.testing.assert_array_equal(np.asarray(blocked), np.asarray(want))


@pytest.mark.parametrize("op", ["add", "max", "min"])
@pytest.mark.parametrize("kb,koff", [(16, 1), (16, -1), (32, 0)])
def test_blocked_monoid_kernel_matches_refs(op, kb, koff):
    K = kb * 2 + koff
    rng = np.random.default_rng(7)
    keys = jnp.asarray(rng.integers(0, K + 1, 50).astype(np.int32))
    vals = jnp.asarray(rng.standard_normal((50, 3)).astype(np.float32))
    acc = jnp.asarray(rng.standard_normal((K, 3)).astype(np.float32))
    got = ops.chunk_monoid_fold(keys, vals, acc, op, block_k=kb)
    want = ref.chunk_monoid_fold(keys, vals, acc, op)
    want_b = ref.chunk_monoid_fold(keys, vals, acc, op, block_k=kb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(want_b), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_blocked_stream_end_to_end_parity():
    """Full MapReduce run with a forced key block straddling K."""
    K = 1000  # not a multiple of the 128-key block
    app = _sum_app(K)
    rng = np.random.default_rng(3)
    keys = rng.integers(0, K, (256, 4)).astype(np.int32)
    want = np.bincount(keys.reshape(-1), minlength=K)
    res = MapReduce(app, flow="stream", stream_chunk_pairs=256,
                    stream_key_block=128).run(jnp.asarray(keys))
    np.testing.assert_array_equal(np.asarray(res.values), want)
    np.testing.assert_array_equal(np.asarray(res.counts), want)


# ---------------------------------------------------------------------------
# Property 2: autotuned tilings respect the budget models
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("logk", [3, 9, 12, 15, 18, 21])
@pytest.mark.parametrize("use_kernels", [False, True])
def test_autotuned_tiling_respects_budget_models(logk, use_kernels):
    K = 1 << logk
    app = _sum_app(K)
    spec = C.sum_spec()
    t = at.autotune_stream(app, spec, use_kernels=use_kernels)
    # chunk stays within the clamp and the additive contraction budget
    # (unless the floor itself exceeds it, which the fallback note records)
    assert t.chunk_pairs <= at.MAX_CHUNK_PAIRS
    if t.mode == "additive" and not use_kernels:
        # pure-JAX one-hot folds stay inside the fused-contraction regime
        assert t.chunk_pairs <= col.ADDITIVE_FOLD_PAIRS_FUSED
    if use_kernels:
        # kernel path: the per-step working set fits VMEM with
        # double-buffer headroom
        ws = roofline.stream_working_set_bytes(
            chunk_pairs=t.chunk_pairs, key_block=t.key_block, d=2)
        assert ws <= ops.VMEM_BUDGET // 2 + roofline.stream_working_set_bytes(
            chunk_pairs=t.chunk_pairs, key_block=1, d=2)
    # peak residency model: O(K + chunk), never O(N)
    big_n = 1 << 24
    peak = roofline.mapreduce_flow_peak_bytes(
        "stream", n_pairs=big_n, key_space=K, chunk_pairs=t.chunk_pairs,
        key_block=t.key_block)
    assert peak < roofline.mapreduce_flow_peak_bytes(
        "combine", n_pairs=big_n, key_space=K)


def test_autotuner_blocks_kernel_path_at_large_k():
    K = 1 << 18  # 256k keys: past the VMEM-resident table limit
    # float values -> float holders -> the fused Pallas kernel actually
    # runs, so the VMEM working-set model sizes the key block
    app = make_app(
        lambda item, emit: emit(item, jnp.ones_like(item, jnp.float32)),
        lambda k, v, c: jnp.sum(v),
        key_space=K, value_aval=jax.ShapeDtypeStruct((), jnp.float32),
        emit_capacity=4, max_values_per_key=64,
    )
    t = at.autotune_stream(app, C.sum_spec(), use_kernels=True)
    assert t.mode == "additive" and t.blocked
    assert t.key_block * t.n_key_blocks >= K
    ws = roofline.stream_working_set_bytes(
        chunk_pairs=t.chunk_pairs, key_block=t.key_block, d=2)
    assert ws <= ops.VMEM_BUDGET
    # int holders bypass the fused kernel, so the same app with int values
    # gets the pure-JAX tiling (fused-regime chunk cap + dense-budget block)
    ti = at.autotune_stream(_sum_app(K), C.sum_spec(), use_kernels=True)
    assert ti.chunk_pairs <= col.ADDITIVE_FOLD_PAIRS_FUSED
    assert ti.chunk_pairs * ti.key_block <= col.DENSE_FOLD_ELEMS_BUDGET


def test_autotuner_pins_manual_knobs():
    app = _sum_app(512)
    t = at.autotune_stream(app, C.sum_spec(), chunk_pairs=128, key_block=64)
    assert (t.chunk_pairs, t.key_block, t.source) == (128, 64, "manual")


def test_probe_mode_smoke():
    app = _sum_app(64)
    t = at.autotune_stream(app, C.sum_spec(), probe=True, probe_pairs=256)
    assert t.source == "probe"
    assert any("probe" in n for n in t.notes)


# ---------------------------------------------------------------------------
# Diagnostics: fallbacks are loud and visible in explain()
# ---------------------------------------------------------------------------


@pytest.mark.auto_flow  # skipped under the CI flow-matrix override
def test_explain_reports_tiling():
    mr = MapReduce(_sum_app(1 << 15))
    text = mr.explain()
    assert "tiling:" in text and "chunk_pairs=" in text
    assert "mode=additive" in text


def test_combine_large_n_scatter_fallback_warns_and_explains():
    K = 4096  # past the legacy key-space cutoff...
    n = col.ADDITIVE_FOLD_PAIRS_FUSED * 2  # ...AND the fused pair regime
    spec = C.monoid_spec(C.ADD, premap=lambda v: (v,))
    keys = jnp.asarray((np.arange(n) % K).astype(np.int32))
    stream = col.PairStream(keys, jnp.ones((n,), I32), K)
    with pytest.warns(col.LoweringFallbackWarning):
        col.combine_flow(spec, stream)
    # plan-level diagnostic for the combine flow names the threshold
    mr = MapReduce(_sum_app(4096), flow="combine")
    assert any("scatter fallback" in d for d in mr.plan.diagnostics)
    assert "diagnostic:" in mr.explain()


def test_no_fallback_warning_on_onehot_path():
    spec = C.monoid_spec(C.ADD, premap=lambda v: (v,))
    keys = jnp.asarray(np.arange(64, dtype=np.int32))
    stream = col.PairStream(keys, jnp.ones((64,), I32), 4096)
    with warnings.catch_warnings():
        warnings.simplefilter("error", col.LoweringFallbackWarning)
        grouped = col.combine_flow(spec, stream)
    np.testing.assert_array_equal(np.asarray(grouped.counts)[:64], 1)
