"""Chunked prefill must agree with token-by-token decode (the SSD prefill
state comes out of the inter-chunk associative combine — §Perf iteration 2).
MoE archs get a looser tolerance: capacity-based dispatch drops differ
between whole-sequence and per-token routing (inherent to GShard-style MoE).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.registry import get_model

RNG = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch,tol", [
    ("mamba2-2.7b", 2e-2),
    ("zamba2-1.2b", 2e-2),
    ("llama3-8b", 2e-2),
    ("qwen3-moe-30b-a3b", 0.15),
])
def test_prefill_matches_stepwise(arch, tol):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init_params(RNG)
    B, S = 2, 12
    prompt = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)

    st = model.init_decode_state(B, 32)
    lgA, stA = model.prefill(params, {"tokens": prompt}, st)
    tok = jnp.argmax(lgA, -1).astype(jnp.int32)
    lgA2, _ = model.decode_step(params, stA, tok)

    stB = model.init_decode_state(B, 32)
    step = jax.jit(model.decode_step)
    for t in range(S):
        lgB, stB = step(params, stB, prompt[:, t])
    lgB2, _ = step(params, stB, tok)

    err1 = float(jnp.max(jnp.abs(jax.nn.softmax(lgA) - jax.nn.softmax(lgB))))
    err2 = float(jnp.max(jnp.abs(jax.nn.softmax(lgA2) - jax.nn.softmax(lgB2))))
    assert err1 < tol and err2 < tol, (arch, err1, err2)
