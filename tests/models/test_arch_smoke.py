"""Per-architecture smoke tests: REDUCED config, one forward + one decode
step on CPU, asserting output shapes and finiteness (assignment item (f))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.registry import get_model

RNG = jax.random.PRNGKey(0)
B, S = 2, 16


def make_batch(cfg, with_labels=False):
    b = {"tokens": jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        b = {"frames": jax.random.normal(RNG, (B, S, cfg.d_model), cfg.dtype),
             "tokens": jax.random.randint(RNG, (B, cfg.dec_len), 0,
                                          cfg.vocab_size)}
    elif cfg.family == "vlm":
        b = {"tokens": jax.random.randint(RNG, (B, S - cfg.num_patches), 0,
                                          cfg.vocab_size),
             "patches": jax.random.normal(RNG, (B, cfg.num_patches,
                                                cfg.d_model), cfg.dtype)}
    if with_labels:
        lab_len = cfg.dec_len if cfg.family == "audio" else S
        b["labels"] = jax.random.randint(RNG, (B, lab_len), 0, cfg.vocab_size)
    return b


@pytest.fixture(scope="module")
def built():
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        model = get_model(cfg)
        out[arch] = (cfg, model, model.init_params(RNG))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_exact_config_loads(arch):
    cfg = get_config(arch)
    assert cfg.name == arch
    assert cfg.num_layers > 0 and cfg.d_model > 0 and cfg.vocab_size > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch, built):
    cfg, model, params = built[arch]
    hidden, aux = jax.jit(lambda p, b: model.forward(p, b))(
        params, make_batch(cfg))
    exp_s = cfg.dec_len if cfg.family == "audio" else S
    assert hidden.shape == (B, exp_s, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch, built):
    cfg, model, params = built[arch]
    state = model.init_decode_state(B, 32)
    tok = jnp.zeros((B,), jnp.int32)
    step = jax.jit(model.decode_step)
    logits, state = step(params, state, tok)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    logits2, state = step(params, state, tok)
    assert int(state["pos"]) == 2


@pytest.mark.parametrize("arch", ["llama3-8b", "gemma2-27b", "zamba2-1.2b",
                                  "mamba2-2.7b"])
def test_train_step_decreases_loss(arch, built):
    from repro.training.train_step import (TrainConfig, init_train_state,
                                           make_train_step)

    cfg, model, _ = built[arch]
    tc = TrainConfig(num_microbatches=2, vocab_chunk=64, warmup_steps=1,
                     total_steps=50)
    step = jax.jit(make_train_step(model, tc))
    state = init_train_state(model, RNG)
    batch = make_batch(cfg, with_labels=True)
    losses = []
    for _ in range(4):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_decode_prefill_consistency():
    """Greedy decode after prefill matches teacher forcing argmax."""
    cfg = get_config("llama3-8b").reduced()
    model = get_model(cfg)
    params = model.init_params(RNG)
    prompt = jax.random.randint(RNG, (1, 8), 0, cfg.vocab_size)

    state = model.init_decode_state(1, 16)
    lg_pf, state = model.prefill(params, {"tokens": prompt}, state)

    # reference: full forward, take logits at the last position
    hidden, _ = model.forward(params, {"tokens": prompt})
    lg_ref = model.logits_of_hidden(params, hidden[:, -1])
    np.testing.assert_allclose(np.asarray(lg_pf), np.asarray(lg_ref),
                               rtol=2e-2, atol=2e-2)


def test_int8_kv_cache_close_to_bf16():
    cfg = get_config("llama3-8b").reduced()
    model = get_model(cfg)
    params = model.init_params(RNG)
    toks = jax.random.randint(RNG, (2,), 0, cfg.vocab_size)

    s16 = model.init_decode_state(2, 16)
    s8 = model.init_decode_state(2, 16, kv_dtype=jnp.int8)
    for _ in range(3):
        l16, s16 = model.decode_step(params, s16, toks)
        l8, s8 = model.decode_step(params, s8, toks)
    # int8 KV quantization should track bf16 logits closely
    p16 = jax.nn.softmax(l16, -1)
    p8 = jax.nn.softmax(l8, -1)
    assert float(jnp.max(jnp.abs(p16 - p8))) < 0.06
