"""Serve a small model with batched requests: prefill + greedy decode with
an int8 KV cache (the serving-side combiner integrations).

  PYTHONPATH=src python examples/serve_lm.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "qwen3-moe-30b-a3b", "--reduced",
                "--batch", "4", "--prompt-len", "12", "--max-new", "12",
                "--kv-dtype", "int8"]
    serve_main()
