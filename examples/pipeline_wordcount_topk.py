"""Two-job pipeline: word count feeding a count-of-counts histogram.

The classic follow-up job to word count reads only the counts table — a
``Pipeline`` fuses the two MapReduce jobs into one XLA executable, so the
K-row intermediate table never round-trips through memory, the producer's
value column is dead-code-eliminated when the consumer ignores it, and an
edge predicate (``where=``) is pushed below the shuffle.  The fused result
is bitwise identical to running the jobs separately.

  PYTHONPATH=src python examples/pipeline_wordcount_topk.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Pipeline, make_app

VOCAB = 256
BUCKETS = 16


def wc_map(item, emit):
    emit.emit(item % VOCAB, jnp.ones((), jnp.int32))


wordcount = make_app(
    map_fn=wc_map,
    reduce_fn=lambda k, vs, n: vs.sum(),
    key_space=VOCAB,
    value_aval=jax.ShapeDtypeStruct((), jnp.int32),
)


def hist_map(item, emit):
    # item is one (key, value, count) row of the word-count table; bucket
    # words by count magnitude — the "how hot is the hot set" histogram.
    count = item[1]
    emit.emit(jnp.clip(count // 32, 0, BUCKETS - 1).astype(jnp.int32),
              jnp.ones((), jnp.int32))


histogram = make_app(
    map_fn=hist_map,
    reduce_fn=lambda k, vs, n: vs.sum(),
    key_space=BUCKETS,
    value_aval=jax.ShapeDtypeStruct((), jnp.int32),
)


def main():
    rng = np.random.default_rng(0)
    # zipf-ish token stream: a hot head and a long tail
    items = jnp.asarray(
        rng.zipf(1.3, size=200_000) % VOCAB, dtype=jnp.int32)

    # only histogram words that actually occur >= 8 times: the predicate is
    # evaluated inside the fused consumer map, below the shuffle.
    pipe = Pipeline(wordcount).then(
        histogram, where=lambda key, count, n: count >= 8)

    fused = pipe.run(items)
    unfused = pipe.run_unfused(items)
    assert np.array_equal(np.asarray(fused.values),
                          np.asarray(unfused.values))

    print("count-of-counts buckets:", np.asarray(fused.values).tolist())
    print()
    print("fusion decisions:")
    for line in pipe.fusion_report():
        print(" ", line)
    n = int(items.shape[0])
    print()
    print(f"modeled bytes  fused: {pipe.model_bytes(n, fused=True)/1e6:.2f}MB"
          f"  unfused: {pipe.model_bytes(n, fused=False)/1e6:.2f}MB")


if __name__ == "__main__":
    main()
