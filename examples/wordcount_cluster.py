"""Distributed word count on a fake 4-device mesh: the combine flow merges
holder tables with an all-reduce (O(K)); the baseline shuffles raw pairs
with all-to-all (O(N)).  Prints both results + the collectives each flow
lowered to.

  PYTHONPATH=src python examples/wordcount_cluster.py
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import re
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import MapReduceApp, plan_execution
from repro.core import engine as eng

VOCAB = 64


class WordCount(MapReduceApp):
    key_space = VOCAB
    value_aval = jax.ShapeDtypeStruct((), jnp.int32)
    emit_capacity = 8
    max_values_per_key = 512

    def map(self, item, emit):
        emit(item, jnp.ones_like(item))

    def reduce(self, key, values, count):
        return jnp.sum(values)


mesh = jax.make_mesh((4,), ("data",))
rng = np.random.default_rng(0)
toks = jax.device_put(
    jnp.asarray(rng.integers(0, VOCAB, (128, 8)).astype(np.int32)),
    NamedSharding(mesh, P("data")))
app = WordCount()
want = np.bincount(np.asarray(toks).reshape(-1), minlength=VOCAB)

with mesh:
    for flow in ("auto", "reduce"):
        plan = plan_execution(app, flow=flow)
        k, v, c = eng.run_distributed(app, plan, toks, mesh=mesh)
        txt = jax.jit(partial(eng.run_distributed, app, plan, mesh=mesh)
                      ).lower(toks).compile().as_text()
        colls = sorted(set(re.findall(
            r"(all-reduce|all-gather|all-to-all|collective-permute)", txt)))
        print(f"{plan.flow:8s} flow -> collectives: {colls}")
        if plan.optimized:  # stream/combine: replicated O(K) tables
            assert np.array_equal(np.asarray(v), want)
print("distributed word count OK")
