"""Quickstart: the paper's Fig 2 word-count in MR4X.

The user writes map + reduce; the semantic-aware optimizer derives the
combiner and switches to the combine flow automatically.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MapReduce, MapReduceApp
from repro.data.pipeline import tokenize_words

TEXT = """the quick brown fox jumps over the lazy dog
the dog barks and the fox runs the end"""
VOCAB = 4096


class WordCount(MapReduceApp):
    key_space = VOCAB
    value_aval = jax.ShapeDtypeStruct((), jnp.int32)
    emit_capacity = 8
    max_values_per_key = 64

    def map(self, window, emit):          # window: [8] token ids
        emit(window, jnp.ones_like(window))

    def reduce(self, key, values, count):  # what the user writes...
        return jnp.sum(values)             # ...the combiner is DERIVED


ids = tokenize_words(TEXT, VOCAB)
pad = (-len(ids)) % 8
windows = np.pad(ids, (0, pad), constant_values=VOCAB).reshape(-1, 8)

mr = MapReduce(WordCount())
print(f"optimizer plan: {mr.plan.flow} ({mr.plan.reason})")
d = mr.plan.derivation
print(f"  detect {d.detect_s*1e6:.0f}us | synthesize {d.transform_s*1e6:.0f}us "
      f"| validate {d.validate_s*1e3:.1f}ms  (paper: 81us / 7.6ms)")

res = mr.run(jnp.asarray(windows))
inv = {}
for w in TEXT.split():
    inv[int(tokenize_words(w, VOCAB)[0])] = w.lower()
counts = {inv[k]: int(v) for k, v in res.to_dict().items() if k in inv}
print("word counts:", dict(sorted(counts.items(), key=lambda kv: -kv[1])))
assert counts["the"] == 5
