"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on CPU with the combiner-based gradient accumulation,
checkpointing every 50 steps.

  PYTHONPATH=src python examples/train_lm.py --steps 300
(defaults to 40 steps so the example completes quickly; pass --steps 300
for the full run)
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    args, _ = ap.parse_known_args()
    sys.argv = [sys.argv[0], "--arch", "llama3-8b", "--reduced",
                "--steps", str(args.steps), "--batch", "8", "--seq", "64",
                "--microbatches", "4", "--ckpt-dir", "/tmp/mr4x_ckpt",
                "--ckpt-every", "50"]
    train_main()
