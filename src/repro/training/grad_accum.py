"""Gradient accumulation through the paper's combiner machinery.

Microbatched training *is* MapReduce: map = per-microbatch gradient
computation, reduce = mean over microbatches (a single key: the parameter
pytree).  The semantic optimizer derives the (init=zeros, combine=add,
finalize=/n) triple from the user-visible mean reducer — the same derivation
path as the word-count benchmark — and the combine flow folds each
microbatch's gradients into the holder inside ``lax.scan``:

  * ``materialize`` (reduce flow): all M microbatch gradients are stacked
    ``[M, *param]`` then reduced — O(M · params) live memory.
  * ``combiner`` (combine flow): one holder, folded at emit time —
    O(params) live memory.  This is the paper's transformation applied to
    the training loop.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.optimizer import derive_combiner

#: the user-level reducer the optimizer analyzes (mean over microbatches).
def _mean_reducer(key, values, count):
    del key
    return jnp.sum(values, axis=0) / count.astype(values.dtype)


_CACHED_DERIVATION = None


def derive_grad_combiner():
    """Run the semantic optimizer on the mean reducer (provenance hook).

    Must run OUTSIDE any jit trace (the validation probes execute real
    computations); cached after the first call.
    """
    global _CACHED_DERIVATION
    if _CACHED_DERIVATION is None:
        import jax.core

        d = derive_combiner(_mean_reducer,
                            jax.ShapeDtypeStruct((), jnp.int32),
                            jax.ShapeDtypeStruct((4,), jnp.float32))
        assert d.combinable and d.strategy == "monoid", d.failure
        _CACHED_DERIVATION = d
    return _CACHED_DERIVATION


def split_microbatches(batch, num: int):
    def split(x):
        assert x.shape[0] % num == 0, (x.shape, num)
        return x.reshape((num, x.shape[0] // num) + x.shape[1:])

    return jax.tree.map(split, batch)


def _constrain(tree, pspecs):
    """Pin gradient/holder shardings to the parameter layout (ZeRO): without
    this, GSPMD may leave the f32 accumulators replicated — tens of GiB/chip
    on the large archs."""
    if pspecs is None:
        return tree
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, pspecs)


def accumulate_gradients(loss_fn, params, batch, *, num_microbatches: int = 1,
                         mode: str = "combiner", spec=None, pspecs=None,
                         mb_pspecs=None):
    """Returns ((loss, aux), grads) with grads averaged over microbatches.

    ``loss_fn(params, microbatch) -> (loss, aux)``.  ``spec`` is the derived
    combiner (pass it from build time when calling under jit; the derivation
    probes cannot run inside a trace).  ``pspecs``: parameter PartitionSpecs
    used to pin gradient shardings.  ``mb_pspecs``: the GLOBAL batch pspecs —
    microbatches keep the batch dim sharded (reshape would otherwise let
    GSPMD replicate them).
    """
    if num_microbatches == 1:
        (l, a), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return (l, a), _constrain(g, pspecs)

    mbs = split_microbatches(batch, num_microbatches)
    if mb_pspecs is not None:
        from jax.sharding import PartitionSpec as P

        mb_specs = jax.tree.map(lambda s: P(None, *s), mb_pspecs)
        mbs = _constrain(mbs, mb_specs)
    gfn = jax.value_and_grad(loss_fn, has_aux=True)
    spec = spec if spec is not None else derive_grad_combiner().spec
    n = jnp.float32(num_microbatches)

    if mode == "combiner":
        # combine flow: fold gradients into the holder at emit time
        def body(carry, mb):
            holder, loss_acc, k = carry
            (loss, aux), g = gfn(params, mb)
            g32 = _constrain(
                jax.tree.map(lambda x: x.astype(jnp.float32), g), pspecs)
            holder = jax.tree.map(
                lambda h, x: spec.combine((h,), spec.premap(x), k)[0],
                holder, g32)
            holder = _constrain(holder, pspecs)
            return (holder, loss_acc + loss, k + 1), aux

        holder0 = _constrain(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params), pspecs)
        (holder, loss_sum, _), auxs = jax.lax.scan(
            body, (holder0, jnp.float32(0.0), jnp.int32(0)), mbs)
        grads = jax.tree.map(
            lambda h: spec.finalize(0, (h,), n.astype(jnp.int32)), holder)
        loss = loss_sum / n
        aux = jax.tree.map(lambda a: jnp.mean(a, axis=0) if jnp.ndim(a) else a,
                           auxs)
        return (loss, aux), grads

    if mode == "materialize":
        # reduce flow: stack all microbatch grads, then reduce (baseline)
        def one(mb):
            (loss, aux), g = gfn(params, mb)
            return loss, aux, _constrain(jax.tree.map(
                lambda x: x.astype(jnp.float32), g), pspecs)

        losses, auxs, stacked = jax.lax.map(one, mbs)  # [M, *param] buffers
        grads = jax.tree.map(lambda g: jnp.mean(g, axis=0), stacked)
        aux = jax.tree.map(lambda a: jnp.mean(a, axis=0) if jnp.ndim(a) else a,
                           auxs)
        return (jnp.mean(losses), aux), grads

    raise ValueError(mode)
