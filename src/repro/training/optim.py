"""AdamW with fp32 master weights, built for ZeRO sharding.

Optimizer state leaves inherit the parameter PartitionSpecs (sharding.py),
so m/v/master are FSDP-sharded over the DP axes — ZeRO-1/3 semantics under
pjit without bespoke collectives (GSPMD inserts the reduce-scatter/all-gather
pattern).  Global-norm clipping included.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_opt_state(params):
    f32 = lambda p: p.astype(jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, opt_state, lr_scale=1.0):
    """Returns (new_params_in_model_dtype, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(g, m, v, mp):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        new_mp = mp - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                            + cfg.weight_decay * mp)
        return m, v, new_mp

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    flat_p = jax.tree.leaves(opt_state["master"])
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_master = jax.tree.unflatten(treedef, [o[2] for o in out])

    new_state = {"step": step, "master": new_master, "m": new_m, "v": new_v}
    return new_state, {"grad_norm": gnorm, "lr": lr}


def model_params(opt_state, dtype):
    """Cast the fp32 master copy to the model dtype for the forward pass."""
    return jax.tree.map(lambda p: p.astype(dtype), opt_state["master"])


def cosine_schedule(step, *, warmup: int = 100, total: int = 10000,
                    min_frac: float = 0.1):
    t = step.astype(jnp.float32)
    warm = jnp.minimum(t / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((t - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
