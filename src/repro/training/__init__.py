"""repro.training"""
