"""Losses — vocab-chunked cross-entropy via the logsumexp combiner.

For the big-vocab archs (152k–256k), materializing ``[B, S, V]`` logits in
f32 dominates training memory.  The combine-flow formulation streams vocab
chunks through the (m, l) logsumexp monoid (core/combiner.py) and
accumulates the label logit on the fly — the full logits tensor never
exists.  ``mode="materialize"`` keeps the baseline (reduce-flow) xent for
comparison; both are exposed to the benchmarks.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _softcap(x, cap):
    return jnp.tanh(x / cap) * cap if cap else x


def xent_materialize(hidden, unembed, labels, *, mask=None, softcap=None):
    """Baseline: full [B,S,V] logits then log_softmax."""
    logits = jnp.einsum("bse,ve->bsv", hidden, unembed).astype(jnp.float32)
    logits = _softcap(logits, softcap)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        mask = jnp.ones_like(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def xent_chunked(hidden, unembed, labels, *, mask=None, softcap=None,
                 chunk: int = 8192):
    """Combine flow: stream vocab chunks through the logsumexp monoid.

    holder per token = (m, l, label_logit); combine is associative, so this
    is exactly a CombinerSpec fold over the vocab axis (and under pjit the
    vocab-sharded version merges partials with the same monoid).
    """
    V = unembed.shape[0]
    chunk = min(chunk, V)
    pad = (-V) % chunk
    w = jnp.pad(unembed, ((0, pad), (0, 0))) if pad else unembed
    n_chunks = (V + pad) // chunk
    hf = hidden.astype(jnp.float32)

    @jax.checkpoint  # recompute chunk logits in backward: never keep [.., V]
    def fold(carry, i):
        m, l, lab = carry
        wc = jax.lax.dynamic_slice_in_dim(w, i * chunk, chunk, axis=0)
        logits = jnp.einsum("bse,ve->bsv", hf,
                            wc.astype(jnp.float32))
        logits = _softcap(logits, softcap)
        base = i * chunk
        vids = base + jnp.arange(chunk)
        valid = vids < V
        logits = jnp.where(valid[None, None, :], logits, -jnp.inf)
        # (m, l) monoid update against the chunk
        cm = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m, cm)
        l = l * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(logits - m_new[..., None]), axis=-1)
        # label-logit extraction for labels inside this chunk
        in_chunk = (labels >= base) & (labels < base + chunk)
        off = jnp.clip(labels - base, 0, chunk - 1)
        lab_here = jnp.take_along_axis(logits, off[..., None], axis=-1)[..., 0]
        lab = jnp.where(in_chunk, lab_here, lab)
        return (m_new, l, lab), None

    B, S = labels.shape
    init = (jnp.full((B, S), -jnp.inf, jnp.float32),
            jnp.zeros((B, S), jnp.float32),
            jnp.zeros((B, S), jnp.float32))
    (m, l, lab), _ = jax.lax.scan(fold, init, jnp.arange(n_chunks))
    nll = (m + jnp.log(l)) - lab  # logsumexp - label_logit
    if mask is None:
        mask = jnp.ones_like(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def xent_sharded(hidden, unembed, labels, *, mask=None, softcap=None,
                 logits_pspec=None):
    """Vocab-parallel xent for the mesh: logits stay V-sharded over 'model'.

    Each model shard owns a vocab slice; the stable-softmax statistics (max,
    sumexp) and the label logit are reductions over V — GSPMD lowers them to
    small [B,S] all-reduces, i.e. the logsumexp-monoid merge across shards.
    The label logit uses a masked sum (no gather) to stay collective-friendly.
    """
    logits = jnp.einsum("bse,ve->bsv", hidden.astype(jnp.float32),
                        unembed.astype(jnp.float32))
    logits = _softcap(logits, softcap)
    if logits_pspec is not None:
        logits = jax.lax.with_sharding_constraint(logits, logits_pspec)
    m = jnp.max(logits, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
    V = logits.shape[-1]
    onehot_mask = (jnp.arange(V)[None, None, :] == labels[..., None])
    lab = jnp.sum(jnp.where(onehot_mask, logits, 0.0), axis=-1)
    nll = lse - lab
    if mask is None:
        mask = jnp.ones_like(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def lm_loss(model, params, batch, *, mode: str = "chunked",
            moe_mode: str = "combiner", lb_coef: float = 0.01,
            vocab_chunk: int = 8192, logits_pspec=None):
    """Next-token LM loss for any registry model.

    batch needs "tokens" (+family extras) and "labels"; labels < 0 masked.
    """
    hidden, aux = model.forward(params, batch, moe_mode=moe_mode)
    labels = batch["labels"]
    # align: predict labels[t] from hidden[t] (labels are pre-shifted by the
    # data pipeline); for vlm, hidden includes the patch prefix.
    if hidden.shape[1] != labels.shape[1]:
        hidden = hidden[:, -labels.shape[1]:]
    w = model.unembed_matrix(params)
    mask = (labels >= 0).astype(jnp.float32)
    labels_ = jnp.maximum(labels, 0)
    if mode == "sharded":
        loss = xent_sharded(hidden, w, labels_, mask=mask,
                            softcap=model.logit_softcap,
                            logits_pspec=logits_pspec)
    elif mode == "chunked":
        loss = xent_chunked(hidden, w, labels_, mask=mask,
                            softcap=model.logit_softcap, chunk=vocab_chunk)
    else:
        loss = xent_materialize(hidden, w, labels_, mask=mask,
                                softcap=model.logit_softcap)
    total = loss + lb_coef * aux.get("load_balance_loss", 0.0)
    return total, {"xent": loss, **aux}
