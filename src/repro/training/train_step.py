"""Train-step factory: loss → grad accumulation → AdamW, pjit-ready."""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.registry import Model
from repro.training import losses, optim
from repro.training.grad_accum import accumulate_gradients


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adam: optim.AdamWConfig = optim.AdamWConfig()
    num_microbatches: int = 1
    accum_mode: str = "combiner"  # | "materialize"
    loss_mode: str = "chunked"  # | "materialize"
    moe_mode: str = "combiner"  # | "materialize"
    vocab_chunk: int = 8192
    warmup_steps: int = 100
    total_steps: int = 10000
    grad_compression: str = "none"  # | "int8" (DP all-reduce path)


def make_loss_fn(model: Model, tc: TrainConfig, *, logits_pspec=None):
    def loss_fn(params, batch):
        return losses.lm_loss(model, params, batch, mode=tc.loss_mode,
                              moe_mode=tc.moe_mode,
                              vocab_chunk=tc.vocab_chunk,
                              logits_pspec=logits_pspec)

    return loss_fn


def make_train_step(model: Model, tc: TrainConfig, *, param_pspecs=None,
                    batch_pspecs=None, logits_pspec=None):
    """Returns train_step(opt_state, batch) -> (opt_state, metrics).

    Pure function of (opt_state, batch): jit it with the param/batch
    shardings from distributed/sharding.py and pass ``param_pspecs`` /
    ``batch_pspecs`` / ``logits_pspec`` so gradient accumulators stay in the
    parameter layout (ZeRO), microbatches stay batch-sharded, and the loss
    logits stay vocab-sharded.  Gradient compression (int8 with error
    feedback) applies on the DP-reduction domain when enabled.
    """
    loss_fn = make_loss_fn(model, tc, logits_pspec=logits_pspec)
    from repro.training.grad_accum import derive_grad_combiner

    # derive the accumulation combiner at BUILD time (probes can't trace)
    grad_spec = (derive_grad_combiner().spec
                 if tc.num_microbatches > 1 else None)

    def train_step(opt_state, batch):
        params = optim.model_params(opt_state, model.cfg.dtype)
        (loss, aux), grads = accumulate_gradients(
            loss_fn, params, batch, num_microbatches=tc.num_microbatches,
            mode=tc.accum_mode, spec=grad_spec, pspecs=param_pspecs,
            mb_pspecs=batch_pspecs)

        if tc.grad_compression == "int8":
            from repro.distributed.compression import fake_quant_int8

            grads = jax.tree.map(fake_quant_int8, grads)

        lr_scale = optim.cosine_schedule(
            opt_state["step"], warmup=tc.warmup_steps, total=tc.total_steps)
        opt_state, stats = optim.adamw_update(tc.adam, grads, opt_state,
                                              lr_scale)
        metrics = {"loss": loss, **{k: v for k, v in aux.items()}, **stats}
        return opt_state, metrics

    return train_step


def init_train_state(model: Model, rng):
    params = model.init_params(rng)
    return optim.init_opt_state(params)


def abstract_train_state(model: Model):
    """Opt-state avals without allocation (dry-run path)."""
    return jax.eval_shape(
        lambda r: optim.init_opt_state(model.init_params(r)),
        jax.random.PRNGKey(0))
