"""Roofline terms from a compiled dry-run artifact.

Per (arch × shape × mesh):
  compute term    = HLO flops / peak_flops            (per chip)
  memory term     = HLO bytes accessed / hbm_bw       (per chip)
  collective term = Σ wire bytes / link_bw            (per chip)

``compiled.as_text()`` is the SPMD-partitioned module of one device, so
tensor shapes in collective ops are already per-chip; wire bytes apply the
standard algorithmic factors (ring all-reduce 2(n−1)/n, all-gather /
reduce-scatter (n−1)/n, all-to-all (n−1)/n, permute 1) with the group size n
parsed from ``replica_groups``.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import json
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_COLL_RE = re.compile(
    r"=\s*([a-z0-9_\[\]\(\),\s]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s32|u32|s16|u16|s8|u8|pred)"
                       r"\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}


def _dtype_bytes(dt: str) -> int:
    if dt.startswith("f8"):
        return 1
    return _DTYPE_BYTES.get(dt, 4)


def _line_tensor_bytes(line: str) -> int:
    """Sum of tensor bytes on the lhs of the op (covers tuple shapes)."""
    total = 0
    lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split("(", 1)[0]
    for dt, dims in _SHAPE_RE.findall(lhs):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _dtype_bytes(dt)
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]<=[N]
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def _wire_factor(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op in ("all-gather", "reduce-scatter", "all-to-all"):
        return float(n - 1) / n
    return 1.0  # collective-permute


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float  # per chip
    bytes_accessed: float  # per chip
    collective_bytes: float  # wire bytes per chip
    collective_ops: dict
    model_flops: float  # 6·N·D (global), for the usefulness ratio
    peak_memory_bytes: float

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step time: max of the three overlappable terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops × chips) — remat/redundancy waste."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-flops utilization at the roofline step time."""
        denom = self.step_s * self.chips * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "flops_per_chip": self.flops,
            "bytes_per_chip": self.bytes_accessed,
            "collective_bytes_per_chip": self.collective_bytes,
            "collective_ops": self.collective_ops,
            "model_flops": self.model_flops,
            "peak_memory_bytes": self.peak_memory_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "step_s": self.step_s, "useful_ratio": self.useful_ratio,
            "mfu": self.mfu,
        }


def collective_stats(hlo_text: str, default_group: int) -> tuple[float, dict]:
    """(wire bytes per chip, per-op {count, bytes}) from partitioned HLO."""
    per_op: dict = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(2).lower()
        b = _line_tensor_bytes(line)
        n = _group_size(line, default_group)
        wire = b * _wire_factor(op, n)
        total += wire
        rec = per_op.setdefault(op, {"count": 0, "bytes": 0.0})
        rec["count"] += 1
        rec["bytes"] += wire
    return total, per_op


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: float) -> Roofline:
    """Roofline terms via the trip-count-aware HLO parser.

    ``compiled.cost_analysis()`` counts while bodies once (useless under
    scan-over-layers); hlo_parser multiplies by known_trip_count.  The raw
    XLA numbers are kept in ``collective_ops['_xla_cost_analysis']`` as a
    cross-check.
    """
    from repro.roofline import hlo_parser

    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    text = compiled.as_text()
    hc = hlo_parser.analyze_text(text, default_group=chips)
    per_op = dict(hc.collective_ops)
    per_op["_xla_cost_analysis"] = {
        "flops_bodies_once": float(cost.get("flops", 0.0)),
        "bytes_bodies_once": float(cost.get("bytes accessed", 0.0)),
    }
    if hc.warnings:
        per_op["_warnings"] = hc.warnings[:5]
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes +
            mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    flops=hc.flops, bytes_accessed=hc.bytes_accessed,
                    collective_bytes=hc.collective_bytes,
                    collective_ops=per_op, model_flops=model_flops,
                    peak_memory_bytes=float(peak))


def mapreduce_flow_bytes(
    flow: str,
    *,
    n_pairs: int,
    key_space: int,
    value_bytes: int = 4,
    holder_bytes: int | None = None,
    chunk_pairs: int | None = None,
    key_block: int | None = None,
    max_values_per_key: int | None = None,
    sort_levels: int = 1,
) -> float:
    """First-order HBM-bytes model of the three collector flows (Figs 8/9).

    Complements the measured ``hlo_parser`` numbers in ``bench_memory`` with
    the analytic story; all terms assume the fused one-hot/masked lowerings
    (the pair→table fold itself stays on-chip), so each flow is charged for
    what it *materializes*:

    * reduce  — writes + re-reads the full pair stream around a sort (~3
      passes of key+value), then gathers O(K·Lmax) padded value windows.
    * combine — writes + re-reads the full pair stream once (map phase
      materializes, fold consumes), plus one table write.
    * stream  — never materializes the full stream: one pair-chunk buffer
      per scan step (written + read), plus the carried O(K) holder tables
      re-touched (read + write) once per chunk — the bytes-level form of
      the paper's "minimize data transfers before the reduce phase".
    * sort    — the radix-bucketed segment-reduce flow: each chunk's pairs
      are written + read once; the radix partition / packed sort works on
      the chunk in fast memory (the Pallas bucket-scatter keeps the
      partitioned copy VMEM-resident, never an extra HBM round-trip), and
      the carried tables are re-touched once per chunk — same O(N + K)
      bytes class as the stream flow, but O(N·log N + K) compute instead
      of the one-hot fold's O(N·K).  ``sort_levels > 1`` charges the
      multi-pass hierarchy's extra per-level key/permutation traffic
      (one int32 stream re-read + re-write per extra level — the digit
      sorts / inner partition passes past one bucket sweep).
    """
    if chunk_pairs is None:  # keep the model in sync with the engine
        from repro.core.engine import (DEFAULT_CHUNK_PAIRS,
                                       DEFAULT_SORT_CHUNK_PAIRS)
        chunk_pairs = (DEFAULT_SORT_CHUNK_PAIRS if flow == "sort"
                       else DEFAULT_CHUNK_PAIRS)
    K, N = key_space, n_pairs
    pair = 4 + value_bytes  # int32 key + value
    hold = (holder_bytes if holder_bytes is not None else value_bytes) + 4
    table = K * hold  # holder tables + int32 counts
    if flow == "reduce":
        lmax = max_values_per_key or max(N // max(K, 1), 1)
        return 3.0 * N * pair + 2.0 * K * lmax * value_bytes + table
    if flow == "combine":
        return 2.0 * N * pair + table
    if flow == "stream":
        n_chunks = max(1, -(-N // max(chunk_pairs, 1)))
        chunk = min(N, chunk_pairs)
        # key-blocked fold: the [K, D] table is partitioned into
        # ceil(K / key_block) blocks and each block's fold re-reads the
        # chunk's pairs (the table itself is still touched once per chunk:
        # the blocks tile it).  key_block == None / >= K -> single block.
        n_blocks = 1
        if key_block is not None and 0 < key_block < K:
            n_blocks = -(-K // key_block)
        return (2.0 * n_chunks * chunk * pair * n_blocks
                + 2.0 * n_chunks * table)
    if flow == "sort":
        n_chunks = max(1, -(-N // max(chunk_pairs, 1)))
        # pairs in/out once per chunk; the radix partition stays in fast
        # memory (VMEM bucket-scatter / fused packed sort); the carried
        # tables are re-touched (read + write) per chunk, minus the first
        # read (identity init).  Equal to the single-chunk combine-flow
        # bytes — the sort flow's win is the compute term
        # (see core/cost_model.py).  Extra hierarchy levels each re-touch
        # the int32 key/permutation stream once.
        return (2.0 * N * pair + (2.0 * n_chunks - 1.0) * table
                + (max(sort_levels, 1) - 1) * 2.0 * N * 4.0)
    raise ValueError(f"unknown flow {flow!r}")


def mapreduce_flow_peak_bytes(
    flow: str,
    *,
    n_pairs: int,
    key_space: int,
    value_bytes: int = 4,
    holder_bytes: int | None = None,
    chunk_pairs: int | None = None,
    key_block: int | None = None,
    max_values_per_key: int | None = None,
) -> float:
    """First-order peak-residency model — the paper's actual Figs 8/9 axis
    (JVM heap pressure).  The streaming flow's peak is O(K + chunk_pairs)
    and independent of N; the legacy flows grow with the full pair stream.
    """
    if chunk_pairs is None:  # keep the model in sync with the engine
        from repro.core.engine import (DEFAULT_CHUNK_PAIRS,
                                       DEFAULT_SORT_CHUNK_PAIRS)
        chunk_pairs = (DEFAULT_SORT_CHUNK_PAIRS if flow == "sort"
                       else DEFAULT_CHUNK_PAIRS)
    K, N = key_space, n_pairs
    pair = 4 + value_bytes
    hold = (holder_bytes if holder_bytes is not None else value_bytes) + 4
    table = K * hold
    if flow == "reduce":
        lmax = max_values_per_key or max(N // max(K, 1), 1)
        return 2.0 * N * pair + K * lmax * value_bytes  # stream + sorted copy
    if flow == "combine":
        return N * pair + table
    if flow == "stream":
        del key_block  # blocking bounds the VMEM working set, not HBM peak
        return min(N, chunk_pairs) * pair + table
    if flow == "sort":
        del key_block
        # chunk buffer + its partitioned/sorted copy + the carried tables
        return 2.0 * min(N, chunk_pairs) * pair + table
    raise ValueError(f"unknown flow {flow!r}")


def stream_working_set_bytes(
    *,
    chunk_pairs: int,
    key_block: int,
    d: int = 1,
    tile_n: int = 512,
    tile_d: int = 128,
) -> float:
    """Per-grid-step VMEM residency model of the key-blocked one-hot fold.

    The Pallas fold kernel keeps three residents per step: the
    ``[key_block, tile_d]`` holder-table block, the ``[tile_n, key_block]``
    one-hot tile, and the ``[tile_n, tile_d]`` value tile (all f32).  The
    autotuner sizes ``key_block`` so this fits the VMEM budget with
    double-buffering headroom; ``d`` is the flattened holder width
    (channels + the counts column)."""
    tn = min(tile_n, max(chunk_pairs, 8))
    td = min(tile_d, max(d, 1))
    return 4.0 * (key_block * td + tn * key_block + tn * td)


def pipeline_handoff_bytes(key_space: int, *, value_bytes: int = 4,
                           dead_value: bool = False) -> float:
    """HBM bytes of materializing one producer→consumer pipeline edge.

    An unfused pipeline ends the producer program by writing its dense
    ``[K]`` output table — (key int32, value, count int32) rows — and
    starts the consumer program by reading it back: a
    ``2 · K · row_bytes`` round-trip that exists only because the program
    boundary forces materialization.  The fused pipeline
    (``core/pipeline.py``) runs both stages in one program and elides the
    term entirely; with a dead value column
    (``StageSemantics.reads_value == False``) the unfused handoff still
    moves the value bytes — the producer cannot know its consumer — which
    is exactly the co-design gap this model quantifies."""
    row = 4 + 4 + (0 if dead_value else int(value_bytes))
    return 2.0 * float(key_space) * row


def model_flops_estimate(cfg, shape_kind: str, seq: int, batch: int,
                         n_params: int, n_active: int) -> float:
    """6·N·D train; 2·N·D per generated token for decode/prefill."""
    tokens = seq * batch
    n = n_active
    if shape_kind == "train":
        return 6.0 * n * tokens
    if shape_kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * batch  # decode: one token per sequence


def shuffle_wire_bytes(
    codec: str = "raw",
    *,
    n_pairs: int,
    key_space: int,
    num_shards: int,
    value_bytes: int = 4,
    value_dtype: str = "int32",
    capacity: int | None = None,
    plan=None,
) -> float:
    """Per-shard link bytes of one tiled all-to-all shuffle under a wire
    codec (``distributed/wire.py``).

    ``n_pairs`` is the GLOBAL pair count (the model splits it uniformly
    over the shards, matching the engine's data-axis partition);
    ``capacity``/``plan`` follow the engine's envelope-resolution chain.
    The encoded-tree bytes come from the wire layer's own accounting —
    ``wire.encoded_nbytes`` matches ``tree_nbytes(encode(...))`` leaf for
    leaf — times the standard all-to-all ``(S-1)/S`` factor, so the cost
    model's wire term is assertable against measured wire bytes
    (``bench_flow_sweep --wire``)."""
    import jax
    import jax.numpy as jnp

    from repro.distributed import wire as wirelib

    S = max(int(num_shards), 1)
    if S <= 1:
        return 0.0
    per = -(-max(int(n_pairs), 1) // S)
    itemsize = jnp.dtype(value_dtype).itemsize
    elems = max(1, int(value_bytes) // itemsize)
    fmt = wirelib.wire_format(
        key_space=int(key_space), num_shards=S, n_pairs=per,
        value_avals=jax.ShapeDtypeStruct((per, elems), value_dtype),
        codec=codec, capacity=capacity, plan=plan)
    return wirelib.wire_bytes_per_shard(fmt)
