"""Trip-count-aware cost accounting over SPMD-partitioned HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE — useless for
scan-over-layers/microbatches programs (underestimates by ~L×M).  This
module re-derives flops / bytes-accessed / collective wire bytes from
``compiled.as_text()``, multiplying every computation by its execution count
(XLA records ``known_trip_count`` in each while op's backend_config).

This is the container's "profiler": the perf loop reads these numbers plus
the HLO itself (no real-TPU timings exist here).

Accounting rules (mirrors HloCostAnalysis):
  flops:  dot = 2·|out|·|contracted|; elementwise/transcendental = |out|;
          reduce = |in|.  Counted inside fusion bodies (not at the call).
  bytes:  per top-level op = |out| + Σ|operands| for the ops that move HBM
          data on TPU (fusions, dots, reduces, data movement, collectives).
          STANDALONE elementwise/convert/broadcast ops contribute flops but
          no bytes: XLA:TPU fuses them into neighbors, while XLA:CPU (this
          container's lowering) leaves many unfused — counting their bytes
          would model CPU non-fusion, not the TPU target.
  wire:   collective ops × ring factor ((2(n−1)/n for all-reduce, (n−1)/n
          for gather/scatter/a2a) × execution count; n from replica_groups.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e\w*|s64|u64|s32|u32|s16|u16|"
                       r"s8|u8|pred|c64|c128)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s+->")
_OP_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s+=\s+(.+?)\s+"
                    r"([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")

#: zero-cost bookkeeping opcodes
_FREE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "after-all", "opt-barrier", "partition-id", "replica-id"}

#: opcodes that move HBM bytes on TPU (everything else standalone is
#: assumed fused into a neighbor by the TPU backend)
_MOVES_BYTES = {"fusion", "dot", "convolution", "reduce", "reduce-window",
                "copy", "concatenate", "slice", "pad", "sort",
                "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
                "select-and-scatter", "custom-call", "cholesky",
                "triangular-solve", "rng", "rng-bit-generator", "iota",
                "broadcast", "transpose", "reshape", "reverse"} | {
                    "all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute"}
#: ops whose cost is |input| flops
_REDUCE_LIKE = {"reduce", "reduce-window"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _dtype_bytes(dt: str) -> int:
    if dt.startswith("f8"):
        return 1
    return _DTYPE_BYTES.get(dt, 4)


def _shapes_of(type_str: str):
    """[(bytes, elems)] for possibly-tuple type strings."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((n * _dtype_bytes(dt), n))
    return out


def _tensor_bytes(type_str: str) -> int:
    return sum(b for b, _ in _shapes_of(type_str))


def _tensor_elems(type_str: str) -> int:
    return sum(e for _, e in _shapes_of(type_str))


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # operand list + attrs (raw)

    def operands(self):
        # operand refs appear before the first named attr; just grab %refs
        return re.findall(r"%([\w\.\-]+)", self.rest)


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    is_entry: bool = False


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if cur is None:
            if line.rstrip().endswith("{") and ("->" in line):
                m = _COMP_HDR_RE.match(line.strip())
                if m:
                    cur = Computation(m.group(1), [],
                                      line.startswith("ENTRY"))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            cur.ops.append(Op(m.group(1), m.group(2), m.group(3),
                              m.group(4)))
    return comps


def _wire_factor(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op in ("all-gather", "reduce-scatter", "all-to-all"):
        return float(n - 1) / n
    return 1.0


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(rest)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collective_ops: dict = dataclasses.field(default_factory=dict)
    warnings: list = dataclasses.field(default_factory=list)
    #: diagnostics for the perf loop: where the bytes/flops live
    bytes_by_opcode: dict = dataclasses.field(default_factory=dict)
    flops_by_opcode: dict = dataclasses.field(default_factory=dict)

    def top_bytes(self, n: int = 8):
        return sorted(self.bytes_by_opcode.items(), key=lambda kv: -kv[1])[:n]


def analyze_text(text: str, *, default_group: int = 1) -> HloCost:
    comps = parse_module(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    cost = HloCost()

    # execution multiplicity per computation + whether it is a fusion body
    mult: dict[str, float] = defaultdict(float)
    fused: set[str] = set()
    applied: set[str] = set()  # to_apply bodies: skip entirely

    # seed: walk from entry
    stack = [(entry.name, 1.0)]
    seen_edges = set()
    while stack:
        cname, m = stack.pop()
        mult[cname] += m
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            rest = op.rest
            if op.opcode == "fusion":
                mm = _CALLS_RE.search(rest)
                if mm:
                    fused.add(mm.group(1))
                    stack.append((mm.group(1), m))
            elif op.opcode == "while":
                trip = 1.0
                tm = _TRIP_RE.search(rest)
                if tm:
                    trip = float(tm.group(1))
                else:
                    cost.warnings.append(f"while without trip count in {cname}")
                bm = _BODY_RE.search(rest)
                cm = _COND_RE.search(rest)
                if bm:
                    stack.append((bm.group(1), m * trip))
                if cm:
                    stack.append((cm.group(1), m * (trip + 1)))
            elif op.opcode == "conditional":
                bm = _BRANCHES_RE.search(rest)
                if bm:
                    for b in re.findall(r"%?([\w\.\-]+)", bm.group(1)):
                        stack.append((b, m))  # conservative: all branches
            elif op.opcode in ("call", "async-start"):
                mm = _CALLS_RE.search(rest) or _TO_APPLY_RE.search(rest)
                if mm:
                    stack.append((mm.group(1), m))
            else:
                mm = _TO_APPLY_RE.search(rest)
                if mm:
                    applied.add(mm.group(1))

    # fusions whose root is a dynamic-update-slice run in place on TPU:
    # charge only the update slice, not the whole buffer
    inplace_update_bytes: dict[str, float] = {}
    for cname, comp in comps.items():
        shapes_local = {op.name: op.type_str for op in comp.ops}
        for op in comp.ops:
            if op.opcode == "dynamic-update-slice":
                ops_ = op.operands()
                upd = (_tensor_bytes(shapes_local.get(ops_[1], ""))
                       if len(ops_) > 1 else 0)
                inplace_update_bytes[cname] = (
                    inplace_update_bytes.get(cname, 0.0) + upd)

    # cost each computation once, scaled by multiplicity
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0 or cname in applied:
            continue
        in_fusion = cname in fused
        shapes = {op.name: op.type_str for op in comp.ops}

        for op in comp.ops:
            oc = op.opcode
            if oc in _FREE:
                continue
            out_elems = _tensor_elems(op.type_str)
            out_bytes = _tensor_bytes(op.type_str)
            opnd_bytes = sum(_tensor_bytes(shapes.get(o, ""))
                             for o in op.operands())

            # ---- flops ----
            if oc == "dot":
                ops_ = op.operands()
                lhs_shape = shapes.get(ops_[0], "") if ops_ else ""
                cdims = _CONTRACT_RE.search(op.rest)
                contracted = 1
                if cdims and lhs_shape:
                    parsed = _SHAPE_RE.search(lhs_shape)
                    if parsed:
                        dims = [int(d) for d in parsed.group(2).split(",") if d]
                        for ci in cdims.group(1).split(","):
                            if ci:
                                contracted *= dims[int(ci)]
                cost.flops += m * 2.0 * out_elems * contracted
            elif oc == "convolution":
                cost.flops += m * 2.0 * out_elems * 8  # coarse; warn once
                if "conv" not in str(cost.warnings):
                    cost.warnings.append("convolution flops are approximate")
            elif oc in _REDUCE_LIKE:
                ops_ = op.operands()
                in_elems = _tensor_elems(shapes.get(ops_[0], "")) if ops_ else 0
                cost.flops += m * in_elems
            elif oc in ("fusion", "while", "conditional", "call",
                        "custom-call", "scatter", "gather", "copy",
                        "broadcast", "iota", "concatenate", "slice",
                        "dynamic-slice", "dynamic-update-slice", "transpose",
                        "reshape", "reverse", "pad", "sort", "convert",
                        "reduce-precision", "select-and-scatter", "rng",
                        "rng-bit-generator", "cholesky", "triangular-solve"):
                pass  # bytes-only (or handled via sub-computation)
            elif oc in _COLLECTIVES or oc.endswith("-start") or \
                    oc.endswith("-done"):
                pass
            else:
                cost.flops += m * out_elems  # elementwise / transcendental

            # ---- bytes ----
            base = oc[:-6] if oc.endswith("-start") else oc
            if (not in_fusion and oc not in ("while", "conditional", "call")
                    and base in _MOVES_BYTES):
                if oc == "dynamic-update-slice":
                    # in-place on TPU: touches only the update slice
                    ops_ = op.operands()
                    upd = (_tensor_bytes(shapes.get(ops_[1], ""))
                           if len(ops_) > 1 else 0)
                    b = m * 2.0 * upd
                elif oc in ("dynamic-slice", "gather"):
                    b = m * 2.0 * out_bytes  # reads only what it emits
                elif oc == "scatter":
                    ops_ = op.operands()
                    upd = (_tensor_bytes(shapes.get(ops_[2], ""))
                           if len(ops_) > 2 else out_bytes)
                    b = m * 3.0 * upd  # read-modify-write of touched rows
                elif oc == "fusion":
                    called = _CALLS_RE.search(op.rest)
                    cn = called.group(1) if called else ""
                    if cn in inplace_update_bytes:
                        # in-place cache update: buffer aliased, only the
                        # slice moves; drop the buffer-sized operand+output
                        upd = inplace_update_bytes[cn]
                        big = max((_tensor_bytes(shapes.get(o, ""))
                                   for o in op.operands()), default=0)
                        b = m * (out_bytes + opnd_bytes
                                 - big - out_bytes + 2.0 * upd)
                        b = max(b, 0.0)
                    else:
                        b = m * (out_bytes + opnd_bytes)
                else:
                    b = m * (out_bytes + opnd_bytes)
                cost.bytes_accessed += b
                cost.bytes_by_opcode[oc] = cost.bytes_by_opcode.get(oc, 0.0) + b

            # ---- collectives ----
            base_oc = oc[:-6] if oc.endswith("-start") else oc
            if base_oc in _COLLECTIVES:
                n = _group_size(op.rest, default_group)
                wire = out_bytes * _wire_factor(base_oc, n)
                cost.collective_bytes += m * wire
                rec = cost.collective_ops.setdefault(
                    base_oc, {"count": 0.0, "bytes": 0.0})
                rec["count"] += m
                rec["bytes"] += m * wire

    return cost
