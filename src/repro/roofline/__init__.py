"""repro.roofline"""
