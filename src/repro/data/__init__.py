"""repro.data"""
