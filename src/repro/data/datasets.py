"""Synthetic datasets for the paper's 7 Phoenix benchmarks (Table 2).

Scaled to CPU-feasible sizes; the scale factor vs. the paper's inputs is
recorded in benchmarks/bench_phoenix_suite.py.  Key/value cardinality shape
(the paper's Small/Medium/Large classes) is preserved:

  HG  image pixels       -> 768 keys (256×3 channels), huge value count
  KM  3-d points         -> 100 cluster keys, large values
  LR  (x, y) points      -> 5 statistic keys (the sufficient statistics)
  MM  matrix tiles       -> medium keys, medium values
  PC  matrix rows        -> medium keys (row stats)
  SM  match keys         -> 4 keys, few values  (the paper's regression case)
  WC  zipf text          -> large keys, large values
"""

from __future__ import annotations

import numpy as np


def histogram_data(rng, *, pixels: int = 1 << 18):
    """24-bit bitmap -> [N, 3] uint8 rgb; keys = channel*256 + intensity."""
    return rng.integers(0, 256, size=(pixels, 3)).astype(np.int32)


def kmeans_data(rng, *, points: int = 1 << 14, clusters: int = 100, d: int = 3):
    centers = rng.standard_normal((clusters, d)) * 5
    assign = rng.integers(0, clusters, size=points)
    pts = centers[assign] + rng.standard_normal((points, d))
    return pts.astype(np.float32), assign.astype(np.int32), clusters


def linear_regression_data(rng, *, points: int = 1 << 16):
    x = rng.standard_normal(points).astype(np.float32)
    y = (2.5 * x + 1.0 + 0.1 * rng.standard_normal(points)).astype(np.float32)
    return np.stack([x, y], axis=1)  # [N, 2]


def matmul_data(rng, *, n: int = 96):
    a = rng.standard_normal((n, n)).astype(np.float32)
    b = rng.standard_normal((n, n)).astype(np.float32)
    return a, b


def pca_data(rng, *, rows: int = 128, cols: int = 64):
    return rng.standard_normal((rows, cols)).astype(np.float32)


def string_match_data(rng, *, n: int = 1 << 12, match_rate: float = 0.22):
    """Stream of candidate ids; 4 target keys (the paper's SM shape)."""
    hits = rng.random(n) < match_rate
    which = rng.integers(0, 4, size=n)
    return np.where(hits, which, -1).astype(np.int32)


def wordcount_data(rng, *, tokens: int = 1 << 16, vocab: int = 8192,
                   zipf_a: float = 1.2):
    t = rng.zipf(zipf_a, size=tokens) % vocab
    return t.astype(np.int32), vocab
