"""Deterministic, stateless data pipeline.

Design for 1000+ hosts: a batch is a pure function of (seed, step, host) —
``global_batch(seed, step)`` is identical everywhere it is computed, and
``host_batch`` slices the host's shard.  Restarts, elastic re-ranking and
speculative (straggler backup) re-execution all reproduce exactly the same
bytes with zero coordination (distributed/fault.py relies on this).

Tokenization: string keys become dense int ids here (DESIGN.md §10) — the
word-count pipeline hashes whitespace tokens into a fixed vocab, which is
the collector's ``key_space``.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    zipf_a: float = 1.2  # token distribution skew (WC-like workloads)


def _rng_for(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def global_batch(dc: DataConfig, step: int) -> dict:
    """Synthetic LM batch: zipf-distributed tokens, shifted labels."""
    rng = _rng_for(dc.seed, step)
    toks = rng.zipf(dc.zipf_a, size=(dc.global_batch, dc.seq_len + 1))
    toks = (toks % (dc.vocab_size - 1)) + 1
    toks = toks.astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


def host_batch(dc: DataConfig, step: int, host: int, num_hosts: int) -> dict:
    gb = global_batch(dc, step)
    per = dc.global_batch // num_hosts
    lo = host * per
    return {k: v[lo:lo + per] for k, v in gb.items()}


def tokenize_words(text: str, vocab: int) -> np.ndarray:
    """Whitespace tokens -> stable dense ids in [0, vocab)."""
    import zlib

    return np.asarray(
        [zlib.crc32(w.lower().encode()) % vocab for w in text.split()],
        np.int32)
