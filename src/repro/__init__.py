"""MR4X: co-designed MapReduce optimization flows on JAX/Pallas.

The headline surface re-exported here is the staged execution API
(``MapReduce`` → ``lower``/``optimize``/``compile``), multi-job
``Pipeline`` fusion, and the execution-option/flow vocabulary; the full
core surface lives in :mod:`repro.core`.
"""

from repro.core import (
    FLOWS,
    Compiled,
    Emitter,
    ExecutionOptions,
    ExecutionPlan,
    Lowered,
    LoweringFallbackWarning,
    MapReduce,
    MapReduceApp,
    MapReduceResult,
    Optimized,
    Pipeline,
    make_app,
)

__all__ = [
    "MapReduce",
    "MapReduceApp",
    "MapReduceResult",
    "make_app",
    "Emitter",
    "ExecutionOptions",
    "Lowered",
    "Optimized",
    "Compiled",
    "Pipeline",
    "FLOWS",
    "ExecutionPlan",
    "LoweringFallbackWarning",
]
