"""repro.checkpoint"""
