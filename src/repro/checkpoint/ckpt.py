"""Sharded, atomic, async checkpoints with restore-time resharding.

Layout:  <dir>/step_<N>/
            manifest.json       tree structure, shapes, dtypes, step
            arrays.npz          flat leaf arrays (leaf_<i>)
         <dir>/LATEST           text file naming the newest complete step

Writes go to ``step_<N>.tmp`` then ``os.replace`` (atomic on POSIX) — a
crashed writer never corrupts LATEST.  ``AsyncCheckpointer`` runs saves on a
writer thread so the train loop is not blocked (fault-tolerance posture:
checkpoint/restart is the recovery mechanism for node failures; see
distributed/fault.py).  ``restore(..., shardings=...)`` device_puts straight
into the (possibly different) mesh — elastic restarts reshard here.
"""

from __future__ import annotations

import json
import os
import queue
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)

    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        "shapes": [list(np.shape(a)) for a in arrays.values()],
        "dtypes": [str(np.asarray(a).dtype) for a in arrays.values()],
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        import shutil

        shutil.rmtree(final)
    os.replace(tmp, final)
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        import shutil

        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def has_step(ckpt_dir: str, step: int) -> bool:
    """Whether a COMPLETE checkpoint for ``step`` exists (the atomic
    ``os.replace`` means a present ``step_<N>`` directory is never a torn
    write).  Used by the resilient MapReduce driver to decide between
    restoring a shard's partial aggregate and re-executing the shard."""
    return os.path.isdir(os.path.join(ckpt_dir, f"step_{step}"))


def shard_partial_dir(ckpt_dir: str, shard: int) -> str:
    """Per-shard partial-aggregate checkpoint directory convention of
    ``engine.run_resilient``: each shard snapshots its monoid partial under
    its own subdirectory so recovery restores exactly the lost shards."""
    return os.path.join(ckpt_dir, f"shard_{shard}")


def service_state_dir(ckpt_dir: str) -> str:
    """Streaming-service state directory convention
    (``repro.streaming.MapReduceService``): the service snapshots its
    carried window-slot states — the same partial-aggregate format the
    resilient driver checkpoints per shard — under one subdirectory,
    keyed by the monotonically increasing ingested-batch id as the step,
    so a restarted service resumes bitwise where the snapshot was cut."""
    return os.path.join(ckpt_dir, "service")


def latest_step(ckpt_dir: str) -> int | None:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore(ckpt_dir: str, example_tree: Any, *, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``example_tree`` (avals ok).

    ``shardings``: optional pytree of NamedShardings — leaves are
    device_put with them, which RESHARDS onto whatever mesh they name
    (elastic restart path).
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with np.load(os.path.join(d, "arrays.npz")) as z:
        leaves = [z[f"leaf_{i}"] for i in range(len(z.files))]
    _, treedef = _flatten(example_tree)
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_flatten(shardings)[0]
        leaves = [jax.device_put(a, s) for a, s in zip(leaves, shard_leaves)]
    else:
        leaves = [jax.numpy.asarray(a) for a in leaves]
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class AsyncCheckpointer:
    """Background writer thread; at most one save in flight per step."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._err: Exception | None = None
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            try:
                save(self.ckpt_dir, step, tree, keep=self.keep)
            except Exception as e:  # surfaced on next submit/close
                self._err = e

    def submit(self, step: int, tree: Any):
        if self._err:
            raise self._err
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._q.put((step, host_tree))

    def close(self):
        self._q.put(None)
        self._t.join()
        if self._err:
            raise self._err
