"""Sharded, atomic, async, CHECKSUMMED checkpoints with resharding restore.

Layout:  <dir>/step_<N>/
            manifest.json       tree structure, shapes, dtypes, step,
                                checksum {algo, arrays}
            manifest.crc        <algo>:<hex crc of manifest.json bytes>
            arrays.npz          flat leaf arrays (leaf_<i>)
         <dir>/LATEST           text file naming the newest complete step
         <dir>/step_<N>.corrupt quarantined checkpoint (failed verification)

Writes go to ``step_<N>.tmp`` then ``os.replace`` (atomic on POSIX) — a
crashed writer never corrupts LATEST.  Atomic rename protects against a
*crashed writer*; it cannot protect against bit rot, a torn copy from a
remote store, or a byzantine disk — so every payload carries a CRC
(crc32c when the wheel is available, else zlib's crc32; the algorithm is
recorded in the manifest so readers verify with whatever wrote it).

``verify_step`` checks manifest + payload integrity; a failed check raises
``CheckpointCorruptError`` naming the step and path.  ``restore`` with an
explicit step quarantines a corrupt checkpoint (renamed to ``*.corrupt``
for post-mortem, never deleted) and raises; ``restore(step=None)`` walks
candidates newest-first, quarantining corrupt ones, and restores the
newest VALID checkpoint — the recovery caller (engine.run_resilient /
MapReduceService) then recomputes anything newer from its shards, which
the monoid semantics make bitwise-exact.

``AsyncCheckpointer`` runs saves on a writer thread so the train loop is
not blocked (fault-tolerance posture: checkpoint/restart is the recovery
mechanism for node failures; see distributed/fault.py).
``restore(..., shardings=...)`` device_puts straight into the (possibly
different) mesh — elastic restarts reshard here.
"""

from __future__ import annotations

import json
import os
import queue
import threading
import zlib
from typing import Any

import jax
import numpy as np

try:  # pragma: no cover - exercised only where the wheel exists
    from crc32c import crc32c as _crc_fn
    CRC_ALGO = "crc32c"
except ImportError:
    _crc_fn = zlib.crc32
    CRC_ALGO = "crc32"

_ALGOS = {"crc32": zlib.crc32, "crc32c": _crc_fn if CRC_ALGO == "crc32c"
          else None}


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed integrity verification (torn write, bit rot,
    truncated copy).  Carries the offending ``step`` and ``path`` so the
    operator knows exactly which artifact to inspect (it is quarantined
    to ``<path>.corrupt``, never silently deleted)."""

    def __init__(self, reason: str, *, step: int | None = None,
                 path: str | None = None):
        msg = f"corrupt checkpoint at step {step} ({path}): {reason}"
        super().__init__(msg)
        self.step = step
        self.path = path
        self.reason = reason


def _crc_bytes(data: bytes, algo: str = CRC_ALGO) -> int:
    fn = _ALGOS.get(algo)
    if fn is None:  # recorded by an algo we can't compute -> skip check
        return -1
    return fn(data) & 0xFFFFFFFF


def _crc_file(path: str, algo: str = CRC_ALGO) -> int:
    fn = _ALGOS.get(algo)
    if fn is None:
        return -1
    crc = 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(1 << 20)
            if not chunk:
                break
            crc = fn(chunk, crc)
    return crc & 0xFFFFFFFF


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)

    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    apath = os.path.join(tmp, "arrays.npz")
    np.savez(apath, **arrays)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        "shapes": [list(np.shape(a)) for a in arrays.values()],
        "dtypes": [str(np.asarray(a).dtype) for a in arrays.values()],
        "checksum": {"algo": CRC_ALGO, "arrays": _crc_file(apath)},
    }
    body = json.dumps(manifest).encode()
    with open(os.path.join(tmp, "manifest.json"), "wb") as f:
        f.write(body)
    with open(os.path.join(tmp, "manifest.crc"), "w") as f:
        f.write(f"{CRC_ALGO}:{_crc_bytes(body):08x}\n")
    if os.path.exists(final):
        import shutil

        shutil.rmtree(final)
    os.replace(tmp, final)
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    _gc(ckpt_dir, keep)
    return final


def _step_dirs(ckpt_dir: str) -> list[int]:
    """Step numbers of the complete (non-tmp, non-quarantined) checkpoint
    dirs — robust to ``step_<N>.corrupt`` neighbors and stray files."""
    out = []
    try:
        names = os.listdir(ckpt_dir)
    except FileNotFoundError:
        return []
    for d in names:
        if not d.startswith("step_") or d.endswith((".tmp", ".corrupt")):
            continue
        try:
            out.append(int(d.split("_", 1)[1]))
        except ValueError:
            continue
    return sorted(out)


def _gc(ckpt_dir: str, keep: int):
    for s in _step_dirs(ckpt_dir)[:-keep]:
        import shutil

        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def has_step(ckpt_dir: str, step: int) -> bool:
    """Whether a COMPLETE checkpoint for ``step`` exists (the atomic
    ``os.replace`` means a present ``step_<N>`` directory is never a torn
    write — but it may still fail checksum verification; see
    ``verify_step``).  Used by the resilient MapReduce driver to decide
    between restoring a shard's partial aggregate and re-executing."""
    return os.path.isdir(os.path.join(ckpt_dir, f"step_{step}"))


def verify_step(ckpt_dir: str, step: int) -> None:
    """Integrity-check one checkpoint; raises ``CheckpointCorruptError``
    (naming the step and path) on a torn, truncated, or bit-rotted
    artifact.  Checkpoints written before the checksum layer (no
    ``checksum`` manifest field) are accepted — the payload zip's own
    per-member CRCs still guard the actual array reads."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    if not os.path.isdir(d):
        raise FileNotFoundError(f"no checkpoint for step {step} under "
                                f"{ckpt_dir}")
    mpath = os.path.join(d, "manifest.json")
    apath = os.path.join(d, "arrays.npz")
    for p, what in ((mpath, "manifest.json"), (apath, "arrays.npz")):
        if not os.path.exists(p):
            raise CheckpointCorruptError(f"missing {what} (torn write)",
                                         step=step, path=d)
    with open(mpath, "rb") as f:
        body = f.read()
    cpath = os.path.join(d, "manifest.crc")
    if os.path.exists(cpath):
        with open(cpath) as f:
            rec = f.read().strip()
        try:
            algo, hexcrc = rec.split(":", 1)
            want = int(hexcrc, 16)
        except ValueError:
            raise CheckpointCorruptError(
                f"unparseable manifest.crc {rec!r}", step=step, path=d)
        got = _crc_bytes(body, algo)
        if got != -1 and got != want:
            raise CheckpointCorruptError(
                f"manifest checksum mismatch ({algo} {got:08x} != "
                f"{want:08x})", step=step, path=d)
    try:
        manifest = json.loads(body.decode())
    except (ValueError, UnicodeDecodeError) as e:
        raise CheckpointCorruptError(
            f"unparseable manifest (torn write?): {e}", step=step, path=d)
    ck = manifest.get("checksum")
    if ck:
        algo = ck.get("algo", "crc32")
        got = _crc_file(apath, algo)
        want = int(ck.get("arrays", -1))
        if got != -1 and got != want:
            raise CheckpointCorruptError(
                f"payload checksum mismatch ({algo} {got:08x} != "
                f"{want:08x})", step=step, path=d)


def has_valid_step(ckpt_dir: str, step: int) -> bool:
    """``has_step`` plus checksum verification, without raising."""
    try:
        verify_step(ckpt_dir, step)
    except (CheckpointCorruptError, FileNotFoundError):
        return False
    return True


def quarantine_step(ckpt_dir: str, step: int) -> str:
    """Move a corrupt checkpoint aside to ``step_<N>.corrupt`` for
    post-mortem (never deleted by ``_gc``); returns the new path."""
    src = os.path.join(ckpt_dir, f"step_{step}")
    dst = src + ".corrupt"
    if os.path.exists(dst):
        import shutil

        shutil.rmtree(dst, ignore_errors=True)
    os.replace(src, dst)
    return dst


def shard_partial_dir(ckpt_dir: str, shard: int) -> str:
    """Per-shard partial-aggregate checkpoint directory convention of
    ``engine.run_resilient``: each shard snapshots its monoid partial under
    its own subdirectory so recovery restores exactly the lost shards."""
    return os.path.join(ckpt_dir, f"shard_{shard}")


def service_state_dir(ckpt_dir: str) -> str:
    """Streaming-service state directory convention
    (``repro.streaming.MapReduceService``): the service snapshots its
    carried window-slot states — the same partial-aggregate format the
    resilient driver checkpoints per shard — under one subdirectory,
    keyed by the monotonically increasing ingested-batch id as the step,
    so a restarted service resumes bitwise where the snapshot was cut."""
    return os.path.join(ckpt_dir, "service")


def latest_step(ckpt_dir: str) -> int | None:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def _load_leaves(ckpt_dir: str, step: int) -> list[np.ndarray]:
    """Verify + read one checkpoint's leaf arrays; any read failure is a
    ``CheckpointCorruptError`` naming the step and path (np.load on a
    truncated zip raises cryptic internals otherwise)."""
    verify_step(ckpt_dir, step)
    d = os.path.join(ckpt_dir, f"step_{step}")
    try:
        with np.load(os.path.join(d, "arrays.npz")) as z:
            return [z[f"leaf_{i}"] for i in range(len(z.files))]
    except CheckpointCorruptError:
        raise
    except Exception as e:
        raise CheckpointCorruptError(
            f"unreadable arrays.npz ({type(e).__name__}: {e})",
            step=step, path=d)


def restore(ckpt_dir: str, example_tree: Any, *, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``example_tree`` (avals ok).

    With an explicit ``step``: a corrupt checkpoint is quarantined to
    ``step_<N>.corrupt`` and ``CheckpointCorruptError`` (naming step and
    path) propagates.  With ``step=None``: candidates are tried
    newest-first (LATEST, then the step-dir scan); corrupt ones are
    quarantined and skipped, and the newest VALID checkpoint is restored
    — a torn newest write therefore degrades to the previous snapshot
    instead of crashing the restart path.

    ``shardings``: optional pytree of NamedShardings — leaves are
    device_put with them, which RESHARDS onto whatever mesh they name
    (elastic restart path).
    """
    if step is not None:
        try:
            leaves = _load_leaves(ckpt_dir, step)
        except CheckpointCorruptError:
            if has_step(ckpt_dir, step):
                quarantine_step(ckpt_dir, step)
            raise
    else:
        latest = latest_step(ckpt_dir)
        candidates = sorted(set(_step_dirs(ckpt_dir))
                            | ({latest} if latest is not None else set()),
                            reverse=True)
        if not candidates:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
        leaves = None
        for cand in candidates:
            try:
                leaves = _load_leaves(ckpt_dir, cand)
            except FileNotFoundError:
                continue
            except CheckpointCorruptError as e:
                if has_step(ckpt_dir, cand):
                    q = quarantine_step(ckpt_dir, cand)
                    import warnings

                    warnings.warn(
                        f"skipping corrupt checkpoint step {cand} "
                        f"(quarantined to {q}): {e.reason}; falling back "
                        f"to the newest valid checkpoint", RuntimeWarning,
                        stacklevel=2)
                continue
            step = cand
            break
        if leaves is None:
            raise FileNotFoundError(
                f"no VALID checkpoint under {ckpt_dir} "
                f"(candidates {candidates} all corrupt or missing)")
    _, treedef = _flatten(example_tree)
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_flatten(shardings)[0]
        leaves = [jax.device_put(a, s) for a, s in zip(leaves, shard_leaves)]
    else:
        leaves = [jax.numpy.asarray(a) for a in leaves]
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class AsyncCheckpointer:
    """Background writer thread; at most one save in flight per step."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._err: Exception | None = None
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            try:
                save(self.ckpt_dir, step, tree, keep=self.keep)
            except Exception as e:  # surfaced on next submit/close
                self._err = e

    def submit(self, step: int, tree: Any):
        if self._err:
            raise self._err
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._q.put((step, host_tree))

    def close(self):
        self._q.put(None)
        self._t.join()
        if self._err:
            raise self._err
