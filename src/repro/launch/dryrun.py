import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes and derive the roofline terms (EXPERIMENTS.md §Dry-run / §Roofline).

The two lines above MUST precede every other import — jax locks the device
count at first init.  Do not replicate them in conftest/pyproject: smoke
tests and benchmarks must see one device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun
"""

import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import (SHAPES, all_cells, cell_supported, default_kv_dtype,
                           get_config, input_specs)
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models.registry import get_model
from repro.roofline import analysis as roofline
from repro.serving.serve_step import make_decode_step, make_prefill
from repro.training.train_step import (TrainConfig, abstract_train_state,
                                       make_train_step)


def _abstract(tree):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


#: train cells needing more microbatches to fit the 16 GiB v5e budget
#: (activation residency scales with per-microbatch tokens).
MB_OVERRIDES = {
    "qwen1.5-32b": 32,
    "qwen2.5-14b": 32,
    "gemma2-27b": 32,
    "internvl2-26b": 32,
    "llama4-scout-17b-a16e": 32,
}


def build_cell(arch: str, shape_name: str, mesh, *, microbatches: int = 16,
               tc_overrides: dict | None = None):
    """Returns (jit_fn, example_args (avals), donate_note) for the cell."""
    cfg = get_config(arch)
    model = get_model(cfg)
    shape = SHAPES[shape_name]
    from jax.sharding import NamedSharding

    from repro.distributed.act_sharding import set_mesh

    set_mesh(mesh)
    ns = lambda spec: NamedSharding(mesh, spec)

    if shape.kind == "train":
        mb = max(microbatches, MB_OVERRIDES.get(arch, 0))
        while shape.global_batch % mb:
            mb //= 2
        tc = TrainConfig(num_microbatches=mb, loss_mode="sharded",
                         **(tc_overrides or {}))
        opt_avals = abstract_train_state(model)
        batch_avals = input_specs(cfg, shape)
        pspecs = shd.param_pspecs(model.abstract_params(), mesh, fsdp=True)
        batch_ps = shd.batch_pspecs(batch_avals, mesh)
        from jax.sharding import PartitionSpec as P
        from repro.models.common import dp_axes, pick

        vshard = pick(mesh, cfg.vocab_size, "model")
        logits_ps = P(dp_axes(mesh) or None, None, vshard)
        step = make_train_step(model, tc, param_pspecs=pspecs,
                               batch_pspecs=batch_ps,
                               logits_pspec=logits_ps)
        opt_sh = jax.tree.map(
            ns, shd.param_pspecs(opt_avals, mesh, fsdp=True))
        batch_sh = jax.tree.map(ns, batch_ps)
        fn = jax.jit(step, in_shardings=(opt_sh, batch_sh),
                     out_shardings=(opt_sh, None), donate_argnums=(0,))
        return fn, (opt_avals, batch_avals)

    kv_dtype = default_kv_dtype(arch, shape_name)
    params_avals = model.abstract_params()
    # serve params are replicated over the DP axes unless they don't fit a
    # chip when only model-sharded (llama4-scout: ~200 GB bf16 / 16-way TP).
    serve_fsdp = arch in ("llama4-scout-17b-a16e",)
    params_sh = jax.tree.map(ns, shd.param_pspecs(params_avals, mesh,
                                                  fsdp=serve_fsdp))
    state_avals = jax.eval_shape(
        lambda: model.init_decode_state(shape.global_batch, shape.seq_len,
                                        kv_dtype=kv_dtype))
    state_sh = jax.tree.map(
        ns, shd.decode_state_pspecs(state_avals, mesh, cfg))

    if shape.kind == "prefill":
        batch_avals = input_specs(cfg, shape)
        batch_sh = jax.tree.map(ns, shd.batch_pspecs(batch_avals, mesh))
        pf = make_prefill(model)
        fn = jax.jit(pf, in_shardings=(params_sh, batch_sh, state_sh),
                     out_shardings=(None, state_sh), donate_argnums=(2,))
        return fn, (params_avals, batch_avals, state_avals)

    # decode
    tok_avals = input_specs(cfg, shape)["tokens"]
    tok_sh = ns(shd.tokens_pspec(shape.global_batch, mesh))
    rng_aval = jax.ShapeDtypeStruct((2,), jnp.uint32)
    dec = make_decode_step(model)
    fn = jax.jit(dec, in_shardings=(params_sh, state_sh, tok_sh, None),
                 out_shardings=(tok_sh, state_sh), donate_argnums=(1,))
    return fn, (params_avals, state_avals, tok_avals, rng_aval)


def _cpu_upcast_artifact_bytes(compiled) -> float:
    """Sum of >256 MiB f32 buffers produced by converting bf16/s8 tensors —
    the XLA:CPU bf16-matmul upcast artifact (absent on TPU)."""
    import math
    import re

    txt = compiled.as_text()
    shapes = {}
    for m in re.finditer(r"%([\w\.\-]+) = (bf16|s8|f32)\[([\d,]+)\]", txt):
        shapes[m.group(1)] = (m.group(2),
                              math.prod(int(x) for x in m.group(3).split(",")))
    total = 0.0
    seen = set()
    for m in re.finditer(
            r"%[\w\.\-]+ = f32\[([\d,]+)\][^=]*?(?:convert|copy)\(%([\w\.\-]+)\)",
            txt):
        elems = math.prod(int(x) for x in m.group(1).split(","))
        src = m.group(2)
        if elems * 4 < 256 * 2 ** 20 or src in seen:
            continue
        sdt = shapes.get(src, ("", 0))[0]
        if sdt in ("bf16", "s8"):
            seen.add(src)
            total += elems * 4
    return total


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             microbatches: int = 16, verbose: bool = True) -> dict:
    ok, why = cell_supported(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    chips = mesh.devices.size
    t0 = time.time()
    try:
        with mesh:
            fn, avals = build_cell(arch, shape_name, mesh,
                                   microbatches=microbatches)
            lowered = fn.lower(*avals)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cfg = get_config(arch)
            model = get_model(cfg)
            params_avals = model.abstract_params()
            from repro.models.registry import param_count

            n_params = sum(l.size for l in jax.tree.leaves(params_avals))
            if cfg.num_experts:
                from repro.models.registry import active_param_count

                n_active = active_param_count(cfg, params_avals)
            else:
                n_active = n_params
            shape = SHAPES[shape_name]
            mf = roofline.model_flops_estimate(
                cfg, shape.kind, shape.seq_len, shape.global_batch,
                n_params, n_active)
            rl = roofline.analyze(compiled, arch=arch, shape=shape_name,
                                  mesh_name=mesh_name, chips=chips,
                                  model_flops=mf)
            upcast = _cpu_upcast_artifact_bytes(compiled)
            out = {
                "arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "ok",
                "compile_s": round(time.time() - t0, 1),
                "n_params": int(n_params), "n_active": int(n_active),
                "memory": {
                    "argument_bytes": mem.argument_size_in_bytes,
                    "output_bytes": mem.output_size_in_bytes,
                    "temp_bytes": mem.temp_size_in_bytes,
                    "alias_bytes": mem.alias_size_in_bytes,
                    "peak_per_chip_gib": round(
                        (mem.argument_size_in_bytes + mem.output_size_in_bytes
                         + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
                        / 2 ** 30, 3),
                    # XLA:CPU upcasts bf16/int8 dot operands to f32 and
                    # hoists the converts (no native bf16 MXU); a TPU build
                    # never materializes these copies.  Adjusted peak is the
                    # TPU-native estimate.
                    "cpu_upcast_artifact_gib": round(upcast / 2 ** 30, 3),
                    # floor at argument+output residency: the artifact scan
                    # has no liveness info, so it can over-subtract temps
                    "peak_tpu_adjusted_gib": round(max(
                        (mem.argument_size_in_bytes + mem.output_size_in_bytes
                         + mem.temp_size_in_bytes - mem.alias_size_in_bytes
                         - upcast),
                        (mem.argument_size_in_bytes + mem.output_size_in_bytes
                         - mem.alias_size_in_bytes)) / 2 ** 30, 3),
                },
                "roofline": rl.to_dict(),
            }
            if verbose:
                print(f"[{arch} × {shape_name} × {mesh_name}] OK "
                      f"compile={out['compile_s']}s "
                      f"peak={out['memory']['peak_per_chip_gib']}GiB/chip "
                      f"(tpu-adj {out['memory']['peak_tpu_adjusted_gib']}) "
                      f"dominant={rl.dominant} step={rl.step_s*1e3:.2f}ms "
                      f"mfu={rl.mfu:.3f}")
                print("  memory_analysis:", mem)
                print("  cost_analysis: flops/chip=%.3e bytes/chip=%.3e"
                      % (rl.flops, rl.bytes_accessed))
                print("  collectives:", json.dumps(rl.collective_ops))
            return out
    except Exception as e:
        if verbose:
            traceback.print_exc()
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "compile_s": round(time.time() - t0, 1)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=16)
    ap.add_argument("--out", default=None, help="directory for JSON results")
    args = ap.parse_args()

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for (a, s, _, _) in all_cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape in cells:
        for m in meshes:
            r = run_cell(arch, shape, m, microbatches=args.microbatches)
            results.append(r)
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                fn = f"{arch}_{shape}_{m}.json".replace("/", "_")
                with open(os.path.join(args.out, fn), "w") as f:
                    json.dump(r, f, indent=1)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n== dry-run summary: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"of {len(results)} ==")
    for r in results:
        if r["status"] == "error":
            print(f"  ERROR {r['arch']} × {r['shape']} × {r['mesh']}: "
                  f"{r['error']}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
