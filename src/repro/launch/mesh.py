"""Production meshes.

Functions (not module-level constants) so importing never touches jax device
state.  Single pod: 16×16 = 256 chips (data, model).  Multi-pod: 2 pods =
512 chips (pod, data, model); the 'pod' axis carries only data parallelism
(gradient all-reduce crosses the DCN/ICI boundary once per step).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for subprocess tests with few fake devices."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
