"""Training driver: fault-tolerant loop with checkpoint/restart.

CPU-runnable end-to-end (reduced configs) and mesh-ready (full configs under
pjit with the sharding rules).  Restart semantics: on any step failure the
loop restores LATEST and continues (distributed/fault.RestartPolicy);
elastic restarts reuse checkpoint/restore with the new mesh's shardings.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 50 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.data.pipeline import DataConfig, global_batch
from repro.distributed.fault import RestartPolicy
from repro.models.registry import get_model
from repro.training.train_step import (TrainConfig, init_train_state,
                                       make_train_step)


def make_batch_fn(cfg, dc: DataConfig):
    def fn(step: int):
        b = global_batch(dc, step)
        if cfg.family == "audio":
            rng = np.random.default_rng(step)
            frames = rng.standard_normal(
                (dc.global_batch, dc.seq_len, cfg.d_model)).astype(np.float32)
            lab = b["tokens"][:, :cfg.dec_len]
            return {"frames": frames, "tokens": lab,
                    "labels": b["labels"][:, :cfg.dec_len]}
        if cfg.family == "vlm":
            rng = np.random.default_rng(step)
            pn = cfg.num_patches
            return {
                "tokens": b["tokens"],
                "patches": rng.standard_normal(
                    (dc.global_batch, pn, cfg.d_model)).astype(np.float32),
                "labels": np.concatenate(
                    [np.full((dc.global_batch, pn), -1, np.int32),
                     b["labels"]], axis=1),
            }
        return b

    return fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--accum-mode", default="combiner",
                    choices=["combiner", "materialize"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    tc = TrainConfig(num_microbatches=args.microbatches,
                     accum_mode=args.accum_mode,
                     vocab_chunk=min(8192, cfg.vocab_size),
                     warmup_steps=5, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, tc))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch)
    batch_fn = make_batch_fn(cfg, dc)

    state = init_train_state(model, jax.random.PRNGKey(0))
    start = 0
    writer = None
    if args.ckpt_dir:
        writer = ckpt.AsyncCheckpointer(args.ckpt_dir)
        if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
            state, start = ckpt.restore(args.ckpt_dir, state)
            print(f"resumed from step {start}")

    policy = RestartPolicy(max_restarts=3)
    i = start
    while i < args.steps:
        try:
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch_fn(i))
            dt = time.perf_counter() - t0
            print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
            if writer and (i + 1) % args.ckpt_every == 0:
                writer.submit(i + 1, state)
            i += 1
        except Exception as e:  # restart-from-latest semantics
            if not (args.ckpt_dir and policy.on_failure()):
                raise
            print(f"step {i} failed ({e}); restarting from LATEST")
            state, i = ckpt.restore(args.ckpt_dir, state)
    if writer:
        writer.submit(args.steps, state)
        writer.close()
    print("done")


if __name__ == "__main__":
    main()
