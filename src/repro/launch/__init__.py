"""repro.launch"""
