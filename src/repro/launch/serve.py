"""Serving driver: prefill + batched greedy decode.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
      --batch 4 --prompt-len 16 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.registry import get_model
from repro.serving.serve_step import ServeConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-dtype", default="model", choices=["model", "int8"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    sc = ServeConfig(temperature=args.temperature, kv_dtype=args.kv_dtype)

    extra = None
    if cfg.family == "audio":  # frontend stub: precomputed frame embeddings
        extra = {"frames": jax.random.normal(
            rng, (args.batch, args.prompt_len + args.max_new, cfg.d_model),
            cfg.dtype)}
    elif cfg.family == "vlm":  # frontend stub: precomputed patch embeddings
        extra = {"patches": jax.random.normal(
            rng, (args.batch, cfg.num_patches, cfg.d_model), cfg.dtype)}

    t0 = time.perf_counter()
    out = generate(model, params, prompts, max_new=args.max_new, sc=sc,
                   extra_batch=extra)
    dt = time.perf_counter() - t0
    toks = args.batch * args.max_new
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    for b in range(min(args.batch, 2)):
        print(f"  seq{b}: {list(map(int, out[b]))}")


if __name__ == "__main__":
    main()
