"""Multi-pass hierarchical radix partition for the sort flow.

The sort flow's shuffle on TPU: a chunk of emitted pairs is partitioned by
key into contiguous bucket regions (bucket ``b`` holds keys in
``[b·bucket_size, (b+1)·bucket_size)``), each region padded to a multiple
of ``pad_align`` pairs — exactly the alignment the ``segment_reduce`` kernel
needs so that every pair tile falls inside ONE aligned K-block of size
``bucket_size``.  The partition is the chunk-local form of the paper's
shuffle: pairs move once, bucket-by-bucket, and the reduce consumes
presorted segments instead of scattering per pair.

One level (``radix_partition``, the K ≤ fan-out·bucket regime):

Pass 1 (``_hist_kernel``): per-bucket pair counts via one-hot column sums —
a [Tn, B] compare + reduce per tile, MXU/VPU-friendly, no scatter.

Pass 2 (``_scatter_kernel``): sequential grid over pair tiles with a
VMEM-resident per-bucket cursor carried across tiles.  Each tile computes
its pairs' destination slots (bucket cursor + stable within-tile rank) and
stores them with per-pair dynamic writes — VMEM dynamic-update-slices, the
TPU scatter idiom; the partitioned copy never round-trips HBM between the
two passes and the reduce.  Within a bucket the original emission order is
preserved (stable), which the first-element idiom relies on.

Hierarchy (``radix_partition_multi``, K past one bucket sweep): the key
space is decomposed digit-by-digit over ``fanouts = (B1, …, BL)`` levels
with per-level ranges ``R_L = bucket_size`` and ``R_{l-1} = R_l · B_l``.
The top level is the standard two-pass kernel at fan-out ``B1``; every
inner level re-runs histogram + bucket-scatter *per parent bucket region*:
the parent layout is ``pad_align``-aligned and ``tile_n == pad_align``, so
each tile lies inside exactly ONE parent region, the one-hot sweep is
digit-local (``[Tn, B_l]``, never ``[Tn, num_leaves]``), and the tile's
counts/cursor updates land in the parent's row block of the composite
per-level cursor (the cursor carry that makes the batched sweep identical
to a per-region recursion).  Stability per level makes the final layout
bitwise equal to a single-level partition at ``bucket_size`` — which is the
test oracle.

Preconditions (ops.py enforces): the padded output fits the VMEM budget;
keys are int32 in ``[0, key_space]`` with invalid/pad slots carrying values
``>= num_buckets·range`` that drop into the trash slot.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _hist_kernel(keys_ref, out_ref, *, bucket_size: int, num_buckets: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]  # [Tn] int32; sentinel -> bucket >= num_buckets
    b = keys // bucket_size
    iota = lax.broadcasted_iota(jnp.int32, (keys.shape[0], num_buckets), 1)
    hit = (b[:, None] == iota)  # sentinel rows are all-zero
    out_ref[...] += jnp.sum(hit.astype(jnp.int32), axis=0)


def _scatter_kernel(starts_ref, keys_ref, vals_ref, out_keys_ref,
                    out_vals_ref, cursor_ref, *, bucket_size: int,
                    num_buckets: int, out_slots: int, sentinel: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cursor_ref[...] = starts_ref[...]
        # pad/trash slots must read as dropped pairs downstream
        out_keys_ref[...] = jnp.full_like(out_keys_ref, sentinel)
        out_vals_ref[...] = jnp.zeros_like(out_vals_ref)

    keys = keys_ref[...]  # [Tn]
    vals = vals_ref[...]  # [Tn, D]
    tn = keys.shape[0]
    b = keys // bucket_size
    valid = b < num_buckets
    bc = jnp.minimum(b, num_buckets - 1)

    # stable within-tile rank: pairs of the same bucket keep arrival order
    iota_n = lax.broadcasted_iota(jnp.int32, (tn, tn), 0)
    same = (bc[None, :] == bc[:, None]) & (iota_n.T <= iota_n)
    rank = jnp.sum(same & valid[None, :], axis=1) - 1

    cursor = cursor_ref[...]
    dst = jnp.where(valid, cursor[bc] + rank, out_slots - 1)  # trash slot

    def write(j, _):
        d = dst[j]
        out_keys_ref[pl.ds(d, 1)] = keys[j][None]
        out_vals_ref[pl.ds(d, 1), :] = vals[j][None, :]
        return 0

    lax.fori_loop(0, tn, write, 0)

    iota_b = lax.broadcasted_iota(jnp.int32, (tn, num_buckets), 1)
    tile_counts = jnp.sum(((b[:, None] == iota_b) &
                           valid[:, None]).astype(jnp.int32), axis=0)
    cursor_ref[...] = cursor + tile_counts


def _hist_level_kernel(keys_ref, out_ref, *, range_child: int, fanout: int,
                       num_buckets: int):
    """Inner-level histogram: region-local one-hot, composite accumulate.

    Tiles of the parent-partitioned input lie entirely inside ONE parent
    bucket region (regions are ``pad_align``-aligned and tile_n ==
    pad_align), so the one-hot sweep is only ``[Tn, fanout]`` wide and the
    tile's digit counts accumulate into the parent's row block of the
    composite ``[num_parents·fanout]`` histogram."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]  # [Tn]
    b = keys // range_child  # composite bucket id at this level
    valid = b < num_buckets  # pads/trash carry >= num_buckets·range_child
    digit = b % fanout
    # every valid key in the tile shares one parent region
    p = jnp.max(jnp.where(valid, b // fanout, 0))
    iota = lax.broadcasted_iota(jnp.int32, (keys.shape[0], fanout), 1)
    hit = (digit[:, None] == iota) & valid[:, None]
    out_ref[pl.ds(p * fanout, fanout)] += jnp.sum(hit.astype(jnp.int32),
                                                  axis=0)


def _scatter_level_kernel(starts_ref, keys_ref, vals_ref, out_keys_ref,
                          out_vals_ref, cursor_ref, *, range_child: int,
                          fanout: int, num_buckets: int, out_slots: int,
                          sentinel: int):
    """Inner-level bucket scatter: composite cursor, digit-local update.

    Same per-pair dynamic VMEM stores as ``_scatter_kernel``; the cursor is
    the full composite ``[num_parents·fanout]`` array (per-level cursor
    carry), but each tile only advances its parent's ``fanout`` rows — the
    batched equivalent of re-running the scatter per parent region."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cursor_ref[...] = starts_ref[...]
        out_keys_ref[...] = jnp.full_like(out_keys_ref, sentinel)
        out_vals_ref[...] = jnp.zeros_like(out_vals_ref)

    keys = keys_ref[...]  # [Tn]
    vals = vals_ref[...]  # [Tn, D]
    tn = keys.shape[0]
    b = keys // range_child
    valid = b < num_buckets
    bc = jnp.minimum(b, num_buckets - 1)

    # stable within-tile rank over composite ids (same as the top level)
    iota_n = lax.broadcasted_iota(jnp.int32, (tn, tn), 0)
    same = (bc[None, :] == bc[:, None]) & (iota_n.T <= iota_n)
    rank = jnp.sum(same & valid[None, :], axis=1) - 1

    cursor = cursor_ref[...]
    dst = jnp.where(valid, cursor[bc] + rank, out_slots - 1)  # trash slot

    def write(j, _):
        d = dst[j]
        out_keys_ref[pl.ds(d, 1)] = keys[j][None]
        out_vals_ref[pl.ds(d, 1), :] = vals[j][None, :]
        return 0

    lax.fori_loop(0, tn, write, 0)

    p = jnp.max(jnp.where(valid, b // fanout, 0))
    digit = b % fanout
    iota_f = lax.broadcasted_iota(jnp.int32, (tn, fanout), 1)
    counts = jnp.sum(((digit[:, None] == iota_f) &
                      valid[:, None]).astype(jnp.int32), axis=0)
    cur = cursor_ref[pl.ds(p * fanout, fanout)]
    cursor_ref[pl.ds(p * fanout, fanout)] = cur + counts


@functools.partial(jax.jit, static_argnames=(
    "key_space", "bucket_size", "pad_align", "tile_n", "interpret"))
def radix_partition(
    keys: jax.Array,
    values: jax.Array,
    key_space: int,
    *,
    bucket_size: int,
    pad_align: int = 256,
    tile_n: int = 256,
    interpret: bool = True,
):
    """Partition [N] keys + [N, D] values into padded bucket regions.

    Returns ``(pkeys [Np], pvals [Np, D], starts [B])`` with
    ``Np = N + B·pad_align + pad_align`` (static): bucket ``b`` occupies
    ``pkeys[starts[b] : starts[b] + padded_count[b]]``, every region is a
    ``pad_align`` multiple, pad slots carry the sentinel ``key_space`` and
    the final ``pad_align`` slots are the invalid-pair trash region.
    """
    n = keys.shape[0]
    d = values.shape[1]
    num_buckets = -(-key_space // bucket_size)
    tile_n = min(tile_n, max(n, 8))

    pad_n = (-n) % tile_n
    # tile padding must be INVALID (trash-bound), not the sentinel: when
    # key_space is not a bucket_size multiple the sentinel still maps into
    # the last bucket (harmless for real sentinel pairs — their rows are
    # cropped downstream — but padding must not consume bucket slots).
    invalid = num_buckets * bucket_size
    keys_p = jnp.pad(keys, (0, pad_n), constant_values=invalid)
    vals_p = jnp.pad(values.astype(jnp.float32), ((0, pad_n), (0, 0)))
    n_tiles = keys_p.shape[0] // tile_n

    hist = pl.pallas_call(
        functools.partial(_hist_kernel, bucket_size=bucket_size,
                          num_buckets=num_buckets),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((tile_n,), lambda i: (i,))],
        out_specs=pl.BlockSpec((num_buckets,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_buckets,), jnp.int32),
        interpret=interpret,
    )(keys_p)

    padded = -(-hist // pad_align) * pad_align  # per-bucket padded counts
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(padded)[:-1].astype(jnp.int32)])
    out_slots = n + num_buckets * pad_align + pad_align  # + trash region
    out_slots += (-out_slots) % pad_align

    pkeys, pvals = pl.pallas_call(
        functools.partial(_scatter_kernel, bucket_size=bucket_size,
                          num_buckets=num_buckets, out_slots=out_slots,
                          sentinel=key_space),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((num_buckets,), lambda i: (0,)),
            pl.BlockSpec((tile_n,), lambda i: (i,)),
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((out_slots,), lambda i: (0,)),
            pl.BlockSpec((out_slots, d), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((out_slots,), jnp.int32),
            jax.ShapeDtypeStruct((out_slots, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((num_buckets,), jnp.int32)],
        interpret=interpret,
    )(starts, keys_p, vals_p)
    # trash/pad slots may carry the invalid pad constant — normalize every
    # dropped slot to the one sentinel the consumers check for
    pkeys = jnp.minimum(pkeys, key_space)
    return pkeys, pvals, starts


@functools.partial(jax.jit, static_argnames=(
    "key_space", "bucket_size", "fanouts", "pad_align", "tile_n",
    "interpret"))
def radix_partition_multi(
    keys: jax.Array,
    values: jax.Array,
    key_space: int,
    *,
    bucket_size: int,
    fanouts: tuple[int, ...],
    pad_align: int = 256,
    tile_n: int = 256,
    interpret: bool = True,
):
    """Hierarchical partition into padded LEAF bucket regions.

    ``fanouts = (B1, …, BL)`` decomposes the key space digit-by-digit:
    level ``l`` partitions by ``key // R_l`` with ``R_L = bucket_size`` and
    ``R_{l-1} = R_l · B_l`` (so ``bucket_size · ΠB >= key_space``).  Level 1
    is the standard two-pass kernel; inner levels run the region-local
    kernels with the composite per-level cursor carry.  The final layout is
    bitwise identical to ``radix_partition(bucket_size=bucket_size)`` —
    leaf ``b`` at ``starts[b]``, regions ``pad_align`` multiples, sentinel
    pads, trailing trash region — without any level's one-hot sweep or
    per-level padding exceeding its fan-out.
    """
    if len(fanouts) <= 1:
        return radix_partition(keys, values, key_space,
                               bucket_size=bucket_size, pad_align=pad_align,
                               tile_n=tile_n, interpret=interpret)
    if tile_n != pad_align:
        raise ValueError(
            "radix_partition_multi needs tile_n == pad_align so inner-level "
            "tiles never straddle a parent bucket region")
    n = keys.shape[0]
    d = values.shape[1]
    # per-level ranges R_1 > … > R_L = bucket_size; the invalid/pad value is
    # the cover bucket_size·ΠB at EVERY level (w_l · R_l is level-invariant)
    ranges = [bucket_size]
    for B in reversed(fanouts[1:]):
        ranges.insert(0, ranges[0] * B)
    cover = ranges[0] * fanouts[0]
    if cover < key_space:
        raise ValueError(f"fanouts {fanouts} x bucket_size {bucket_size} "
                         f"cover {cover} < key_space {key_space}")

    pad_n = (-n) % tile_n
    pkeys = jnp.pad(keys, (0, pad_n), constant_values=cover)
    pvals = jnp.pad(values.astype(jnp.float32), ((0, pad_n), (0, 0)))

    nb_parent = 1  # real bucket count of the previous level
    starts = None
    for lvl, B in enumerate(fanouts):
        rng = ranges[lvl]
        nb = -(-key_space // rng)  # real buckets at this level
        # cursor/histogram rows: parent-row blocks of B rows each.  Level 1
        # has ONE parent (the whole chunk), so the level kernels reduce
        # exactly to the classic top-level sweep (digit == bucket id,
        # parent == 0) — no tile-alignment precondition needed there.
        width = nb if lvl == 0 else nb_parent * B
        fanout = nb if lvl == 0 else B
        n_tiles = pkeys.shape[0] // tile_n
        hist = pl.pallas_call(
            functools.partial(_hist_level_kernel, range_child=rng,
                              fanout=fanout, num_buckets=nb),
            grid=(n_tiles,),
            in_specs=[pl.BlockSpec((tile_n,), lambda i: (i,))],
            out_specs=pl.BlockSpec((width,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((width,), jnp.int32),
            interpret=interpret,
        )(pkeys)

        padded = -(-hist // pad_align) * pad_align
        starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                  jnp.cumsum(padded)[:-1].astype(jnp.int32)])
        out_slots = n + nb * pad_align + pad_align  # + trash region
        out_slots += (-out_slots) % pad_align

        scatter_fn = functools.partial(
            _scatter_level_kernel, range_child=rng, fanout=fanout,
            num_buckets=nb, out_slots=out_slots, sentinel=cover)
        pkeys, pvals = pl.pallas_call(
            scatter_fn,
            grid=(n_tiles,),
            in_specs=[
                pl.BlockSpec((width,), lambda i: (0,)),
                pl.BlockSpec((tile_n,), lambda i: (i,)),
                pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
            ],
            out_specs=[
                pl.BlockSpec((out_slots,), lambda i: (0,)),
                pl.BlockSpec((out_slots, d), lambda i: (0, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((out_slots,), jnp.int32),
                jax.ShapeDtypeStruct((out_slots, d), jnp.float32),
            ],
            scratch_shapes=[pltpu.VMEM((width,), jnp.int32)],
            interpret=interpret,
        )(starts, pkeys, pvals)
        nb_parent = nb

    # normalize once, at the leaf layout (same contract as single level)
    pkeys = jnp.minimum(pkeys, key_space)
    return pkeys, pvals, starts[: -(-key_space // bucket_size)]
