"""Two-pass radix partition: histogram + bucket-scatter, for the sort flow.

The sort flow's shuffle on TPU: a chunk of emitted pairs is partitioned by
key into ``num_buckets`` contiguous bucket regions (bucket ``b`` holds keys
in ``[b·bucket_size, (b+1)·bucket_size)``), each region padded to a multiple
of ``pad_align`` pairs — exactly the alignment the ``segment_reduce`` kernel
needs so that every pair tile falls inside ONE aligned K-block of size
``bucket_size``.  The partition is the chunk-local form of the paper's
shuffle: pairs move once, bucket-by-bucket, and the reduce consumes
presorted segments instead of scattering per pair.

Pass 1 (``_hist_kernel``): per-bucket pair counts via one-hot column sums —
a [Tn, B] compare + reduce per tile, MXU/VPU-friendly, no scatter.

Pass 2 (``_scatter_kernel``): sequential grid over pair tiles with a
VMEM-resident per-bucket cursor carried across tiles.  Each tile computes
its pairs' destination slots (bucket cursor + stable within-tile rank) and
stores them with per-pair dynamic writes — VMEM dynamic-update-slices, the
TPU scatter idiom; the partitioned copy never round-trips HBM between the
two passes and the reduce.  Within a bucket the original emission order is
preserved (stable), which the first-element idiom relies on.

Preconditions (ops.py enforces): the padded output fits the VMEM budget;
keys are int32 in ``[0, num_buckets·bucket_size]`` with the sentinel
``>= num_buckets·bucket_size`` dropped into the trash slot.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _hist_kernel(keys_ref, out_ref, *, bucket_size: int, num_buckets: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]  # [Tn] int32; sentinel -> bucket >= num_buckets
    b = keys // bucket_size
    iota = lax.broadcasted_iota(jnp.int32, (keys.shape[0], num_buckets), 1)
    hit = (b[:, None] == iota)  # sentinel rows are all-zero
    out_ref[...] += jnp.sum(hit.astype(jnp.int32), axis=0)


def _scatter_kernel(starts_ref, keys_ref, vals_ref, out_keys_ref,
                    out_vals_ref, cursor_ref, *, bucket_size: int,
                    num_buckets: int, out_slots: int, sentinel: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cursor_ref[...] = starts_ref[...]
        # pad/trash slots must read as dropped pairs downstream
        out_keys_ref[...] = jnp.full_like(out_keys_ref, sentinel)
        out_vals_ref[...] = jnp.zeros_like(out_vals_ref)

    keys = keys_ref[...]  # [Tn]
    vals = vals_ref[...]  # [Tn, D]
    tn = keys.shape[0]
    b = keys // bucket_size
    valid = b < num_buckets
    bc = jnp.minimum(b, num_buckets - 1)

    # stable within-tile rank: pairs of the same bucket keep arrival order
    iota_n = lax.broadcasted_iota(jnp.int32, (tn, tn), 0)
    same = (bc[None, :] == bc[:, None]) & (iota_n.T <= iota_n)
    rank = jnp.sum(same & valid[None, :], axis=1) - 1

    cursor = cursor_ref[...]
    dst = jnp.where(valid, cursor[bc] + rank, out_slots - 1)  # trash slot

    def write(j, _):
        d = dst[j]
        out_keys_ref[pl.ds(d, 1)] = keys[j][None]
        out_vals_ref[pl.ds(d, 1), :] = vals[j][None, :]
        return 0

    lax.fori_loop(0, tn, write, 0)

    iota_b = lax.broadcasted_iota(jnp.int32, (tn, num_buckets), 1)
    tile_counts = jnp.sum(((b[:, None] == iota_b) &
                           valid[:, None]).astype(jnp.int32), axis=0)
    cursor_ref[...] = cursor + tile_counts


@functools.partial(jax.jit, static_argnames=(
    "key_space", "bucket_size", "pad_align", "tile_n", "interpret"))
def radix_partition(
    keys: jax.Array,
    values: jax.Array,
    key_space: int,
    *,
    bucket_size: int,
    pad_align: int = 256,
    tile_n: int = 256,
    interpret: bool = True,
):
    """Partition [N] keys + [N, D] values into padded bucket regions.

    Returns ``(pkeys [Np], pvals [Np, D], starts [B])`` with
    ``Np = N + B·pad_align + pad_align`` (static): bucket ``b`` occupies
    ``pkeys[starts[b] : starts[b] + padded_count[b]]``, every region is a
    ``pad_align`` multiple, pad slots carry the sentinel ``key_space`` and
    the final ``pad_align`` slots are the invalid-pair trash region.
    """
    n = keys.shape[0]
    d = values.shape[1]
    num_buckets = -(-key_space // bucket_size)
    tile_n = min(tile_n, max(n, 8))

    pad_n = (-n) % tile_n
    # tile padding must be INVALID (trash-bound), not the sentinel: when
    # key_space is not a bucket_size multiple the sentinel still maps into
    # the last bucket (harmless for real sentinel pairs — their rows are
    # cropped downstream — but padding must not consume bucket slots).
    invalid = num_buckets * bucket_size
    keys_p = jnp.pad(keys, (0, pad_n), constant_values=invalid)
    vals_p = jnp.pad(values.astype(jnp.float32), ((0, pad_n), (0, 0)))
    n_tiles = keys_p.shape[0] // tile_n

    hist = pl.pallas_call(
        functools.partial(_hist_kernel, bucket_size=bucket_size,
                          num_buckets=num_buckets),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((tile_n,), lambda i: (i,))],
        out_specs=pl.BlockSpec((num_buckets,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_buckets,), jnp.int32),
        interpret=interpret,
    )(keys_p)

    padded = -(-hist // pad_align) * pad_align  # per-bucket padded counts
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(padded)[:-1].astype(jnp.int32)])
    out_slots = n + num_buckets * pad_align + pad_align  # + trash region
    out_slots += (-out_slots) % pad_align

    pkeys, pvals = pl.pallas_call(
        functools.partial(_scatter_kernel, bucket_size=bucket_size,
                          num_buckets=num_buckets, out_slots=out_slots,
                          sentinel=key_space),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((num_buckets,), lambda i: (0,)),
            pl.BlockSpec((tile_n,), lambda i: (i,)),
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((out_slots,), lambda i: (0,)),
            pl.BlockSpec((out_slots, d), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((out_slots,), jnp.int32),
            jax.ShapeDtypeStruct((out_slots, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((num_buckets,), jnp.int32)],
        interpret=interpret,
    )(starts, keys_p, vals_p)
    # trash/pad slots may carry the invalid pad constant — normalize every
    # dropped slot to the one sentinel the consumers check for
    pkeys = jnp.minimum(pkeys, key_space)
    return pkeys, pvals, starts
