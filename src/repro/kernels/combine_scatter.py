"""General monoid combining collector: atomics-free "scatter" on TPU.

GPUs implement the combining collector with atomic read-modify-write; TPU has
no atomics.  The TPU-native rethink: the holder table ``[K, D]`` lives in
VMEM as the kernel's accumulation block, and each emitted pair becomes a
*masked broadcast update* — ``table = op(table, where(iota_K == key, value,
identity))`` — executed on the VPU.  Pairs are streamed tile by tile from
HBM; the table never leaves VMEM until the stream ends (grid accumulation).

This path supports any scatter monoid (max/min as well as add).  For pure
sums prefer the MXU one-hot kernel (onehot_combine.py), which turns the same
update into matmuls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_IDENT = {"add": 0.0, "max": -jnp.inf, "min": jnp.inf}
_OPS = {"add": jnp.add, "max": jnp.maximum, "min": jnp.minimum}


def _kernel(keys_ref, vals_ref, out_ref, *, key_space: int, op: str,
            inner: int):
    i = pl.program_id(0)
    ident = jnp.float32(_IDENT[op])
    f = _OPS[op]

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, ident)

    keys = keys_ref[...]  # [Tn]
    vals = vals_ref[...]  # [Tn, D]
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (key_space, 1), 0)  # [K, 1]

    def body(p, table):
        key_p = keys[p]
        hit = (k_iota == key_p)  # [K, 1]
        update = jnp.where(hit, vals[p][None, :], ident)  # [K, D] bcast row
        return f(table, update)

    out_ref[...] = jax.lax.fori_loop(0, inner, body, out_ref[...])


@functools.partial(jax.jit, static_argnames=("key_space", "op", "tile_n",
                                             "interpret"))
def combine_scatter(
    keys: jax.Array,
    values: jax.Array,
    key_space: int,
    op: str = "add",
    *,
    tile_n: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """[N] keys, [N, D] values -> [K, D] monoid-combined table (f32)."""
    n, d = values.shape
    tile_n = min(tile_n, max(n, 8))
    pad_n = (-n) % tile_n
    keys_p = jnp.pad(keys, (0, pad_n), constant_values=key_space)
    vals_p = jnp.pad(values.astype(jnp.float32), ((0, pad_n), (0, 0)),
                     constant_values=_IDENT[op] if op != "add" else 0.0)
    np_ = keys_p.shape[0]

    out = pl.pallas_call(
        functools.partial(_kernel, key_space=key_space, op=op, inner=tile_n),
        grid=(np_ // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n,), lambda i: (i,)),
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((key_space, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((key_space, d), jnp.float32),
        interpret=interpret,
    )(keys_p, vals_p)
    return out
