"""jit'd public wrappers for the Pallas kernels.

Each wrapper validates preconditions, picks tile sizes against the VMEM
budget, and exposes an ``interpret`` flag (True on CPU — this container —
and False on real TPU, where the Mosaic pipeline compiles the same kernel).
"""

from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import combine_scatter as _cs
from repro.kernels import flash_decode as _fd
from repro.kernels import onehot_combine as _oc
from repro.kernels import radix_partition as _rp
from repro.kernels import segment_reduce as _sr

#: v5e VMEM budget we tile against (bytes); leave headroom for double buffers.
VMEM_BUDGET = 96 * 1024 * 1024  # of 128 MiB


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret_default() -> bool:
    """interpret-mode default: JAX_PALLAS_INTERPRET env override (the CI
    kernel job sets it to 1), else interpret everywhere but real TPU."""
    env = os.environ.get("JAX_PALLAS_INTERPRET", "").strip().lower()
    if env:
        return env not in ("0", "false", "no")
    return not _on_tpu()


def _pow2_floor(x: int) -> int:
    return 1 << (max(int(x), 1).bit_length() - 1)


def auto_key_block(key_space: int, *, d: int = 1, tile_n: int = 512,
                   tile_d: int = 128, budget: int = VMEM_BUDGET) -> int:
    """Largest power-of-two key block whose fold working set fits ``budget``.

    Per grid step the one-hot fold keeps a ``[Kb, Td]`` table block, a
    ``[Tn, Kb]`` one-hot tile, and a ``[Tn, Td]`` value tile resident (f32);
    half the budget is reserved for the pipeline's double buffers.  Returns
    ``key_space`` when the whole table fits (no blocking needed)."""
    td = min(tile_d, max(d, 1))
    usable = budget // 2 // 4 - tile_n * td  # f32 elems for the Kb terms
    blk = max(usable // (td + tile_n), 8)
    blk = _pow2_floor(blk)
    return key_space if blk >= key_space else blk


def onehot_combine(keys, values, key_space, *, tile_n=512, tile_d=128,
                   interpret=None):
    """Additive combine via MXU one-hot matmul. [N],[N,D] -> [K,D] f32."""
    if values.ndim != 2:
        raise ValueError("values must be [N, D]")
    d = values.shape[1]
    table_bytes = key_space * min(tile_d, d) * 4
    if table_bytes > VMEM_BUDGET:
        raise ValueError(
            f"key_space {key_space} too large for VMEM-resident table; use "
            "combine_scatter with key blocking or the jnp scatter path")
    interpret = _interpret_default() if interpret is None else interpret
    return _oc.onehot_combine(keys, values, key_space, tile_n=tile_n,
                              tile_d=tile_d, interpret=interpret)


def onehot_fold(keys, values, acc, key_space=None, *, tile_n=512, tile_d=128,
                block_k=None, interpret=None):
    """Streaming-chunk additive fold: ``acc + one_hot(keys)ᵀ @ values``.

    [N] keys, [N, D] values, [K, D] f32 acc -> [K, D] f32.  The carried
    holder table round-trips HBM once per chunk; the one-hot tile lives in
    VMEM only (grid accumulation).  Signature matches the streaming
    collector's ``fold_fn(keys, mat, acc)`` when ``key_space`` is omitted.

    ``block_k`` adds a key-block grid axis so only one ``[block_k, Td]``
    table block is VMEM-resident per step; ``None`` auto-sizes it against
    :data:`VMEM_BUDGET` (``key_space`` itself when the whole table fits).
    """
    if values.ndim != 2:
        raise ValueError("values must be [N, D]")
    if key_space is None:
        key_space = acc.shape[0]
    if acc.shape[0] != key_space or acc.shape[1] != values.shape[1]:
        raise ValueError(f"acc shape {acc.shape} != ({key_space}, "
                         f"{values.shape[1]})")
    n, d = values.shape
    if n == 0:  # empty chunk: nothing to fold
        return acc.astype(jnp.float32)
    tn, td = min(tile_n, max(n, 8)), min(tile_d, d)
    if block_k is None:
        block_k = auto_key_block(key_space, d=d, tile_n=tn, tile_d=td)
    block_k = min(block_k, key_space)
    # VMEM residents per grid step: the [Kb, Td] table block, the [Tn, Kb]
    # one-hot temp, and the [Tn, Td] value tile
    step_bytes = (block_k * td + tn * block_k + tn * td) * 4
    if step_bytes > VMEM_BUDGET:
        raise ValueError(
            f"key block {block_k} too large for VMEM-resident fold "
            f"(needs {step_bytes} bytes/step); shrink block_k or the chunk")
    interpret = _interpret_default() if interpret is None else interpret
    return _oc.onehot_fold(keys, values, acc, key_space, tile_n=tile_n,
                           tile_d=tile_d, block_k=block_k,
                           interpret=interpret)


def chunk_monoid_fold(keys, values, acc, op="add", *, tile_n=256,
                      block_k=None, interpret=None):
    """Streaming-chunk monoid fold of an UNSORTED pair tile into [K, D] acc.

    Signature matches the streaming collector's
    ``monoid_fold_fn(keys, mat, acc, op)``; key space is taken from acc.
    ``block_k`` adds the same key-block grid axis as :func:`onehot_fold`
    (``None`` auto-sizes against the VMEM budget).
    """
    if values.ndim != 2:
        raise ValueError("values must be [N, D]")
    key_space = acc.shape[0]
    n, d = values.shape
    if n == 0:  # empty chunk: nothing to fold
        return acc.astype(jnp.float32)
    tn = min(tile_n, max(n, 8))
    if block_k is None:
        # residents per step: [Kb, D] block + [Tn, Kb] mask + (max/min) the
        # [Tn, Kb, D] masked expansion
        per_key = d + tn + (tn * d if op != "add" else 0)
        usable = VMEM_BUDGET // 2 // 4
        block_k = _pow2_floor(max(usable // per_key, 8))
    block_k = min(block_k, key_space)
    step_elems = block_k * d + tn * block_k
    if op != "add":
        step_elems += tn * block_k * d
    if step_elems * 4 > VMEM_BUDGET:
        raise ValueError(
            f"holder block/mask too large for VMEM residency "
            f"({step_elems * 4} bytes/step); shrink block_k or the chunk")
    interpret = _interpret_default() if interpret is None else interpret
    return _sr.chunk_monoid_fold(keys, values, acc, key_space, op,
                                 tile_n=tile_n, block_k=block_k,
                                 interpret=interpret)


def auto_bucket_size(key_space: int, *, d: int = 1, pad_align: int = 256,
                     budget: int = VMEM_BUDGET) -> int:
    """Radix bucket width for the sort-flow pipeline.

    The bucket is the ``segment_reduce`` output block, so it must keep a
    ``[bucket, D]`` table block VMEM-resident; buckets much smaller than
    ``pad_align`` would drown in per-bucket padding, so the floor is a few
    K of keys and small key spaces keep a single bucket (plain segment
    reduce, no partition needed)."""
    blk = _pow2_floor(max(key_space // 64, 8 * pad_align))
    while blk > 8 and blk * max(d, 1) * 4 > budget // 8:
        blk //= 2
    return key_space if blk >= key_space else blk


#: per-level fan-out cap of the hierarchical radix partition: bounds each
#: level's [Tn, B] one-hot histogram sweep and the per-level region padding
#: (B·pad_align slots).  One level covers key_space <= fan-out·leaf.
MAX_RADIX_FANOUT = 32

#: level budget of the hierarchical partition — the knob ISSUE 4's fallback
#: warning reports against.  3 levels × fan-out 32 × a 16k leaf covers
#: K = 512M; anything past it degrades to the pure-JAX sorted fold with a
#: LoweringFallbackWarning instead of silently clamping the bucket count.
MAX_RADIX_LEVELS = 3

#: leaf bucket cap: the segment_reduce output block is [leaf, D] f32 and on
#: TPU D tiles at 128 lanes, so a 16k leaf keeps the block at 8 MB even for
#: wide holders — past this the hierarchy adds a level instead of growing
#: the leaf out of VMEM.
LEAF_BUCKET_CAP = 16384


@dataclasses.dataclass(frozen=True)
class RadixPlan:
    """Level decomposition of the sort-flow radix partition.

    ``fanouts == ()`` means no partition at all (single bucket — the plain
    segment reduce); ``len(fanouts) == 1`` is the classic single-level
    two-pass partition; more entries run the hierarchical multi-pass.
    ``feasible == False`` marks a key space whose decomposition would
    exceed ``max_levels`` — callers must NOT silently clamp; they emit a
    :class:`LoweringFallbackWarning` and take the pure-JAX sorted fold.
    """

    bucket_size: int
    fanouts: tuple[int, ...]
    key_space: int
    feasible: bool = True
    reason: str = ""

    @property
    def levels(self) -> int:
        return len(self.fanouts)

    @property
    def num_leaves(self) -> int:
        return -(-self.key_space // self.bucket_size)

    def describe(self) -> str:
        if not self.feasible:
            return f"INFEASIBLE ({self.reason})"
        if not self.fanouts:
            return "buckets=1 (single full segment reduce)"
        fan = "·".join(str(b) for b in self.fanouts)
        return (f"buckets={self.num_leaves}×{self.bucket_size}keys "
                f"levels={self.levels}({fan})")


def plan_radix_levels(key_space: int, *, d: int = 1, pad_align: int = 256,
                      max_fanout: int | None = None,
                      max_levels: int | None = None,
                      leaf_cap: int | None = None,
                      budget: int = VMEM_BUDGET,
                      skew_factor: float | None = None) -> RadixPlan:
    """Pick the leaf bucket and per-level fan-outs for a key space.

    The leaf is the ``segment_reduce`` block (VMEM-resident ``[leaf, D]``,
    capped at ``leaf_cap``); the leaf count is then decomposed into the
    fewest levels whose fan-outs stay within ``max_fanout`` (near-uniform
    powers of two).  A key space needing more than ``max_levels`` levels is
    reported infeasible — the caller warns and falls back instead of
    clamping the bucket count past the padded-layout envelope (the old
    silent degrade).  The budget knobs default to the module constants at
    call time (patchable in tests).

    ``skew_factor`` (the sampled fixed-width load imbalance from
    ``core/skew.py``, >= 1.0) halves the leaf cap per power of two of
    imbalance: under skew the hottest leaf's pair REGION (not the key
    count) dominates the partition's padded layout, so smaller leaves
    spread the hot range over more buckets and keep each region inside
    the VMEM envelope."""
    max_fanout = MAX_RADIX_FANOUT if max_fanout is None else max_fanout
    max_levels = MAX_RADIX_LEVELS if max_levels is None else max_levels
    leaf_cap = LEAF_BUCKET_CAP if leaf_cap is None else leaf_cap
    if skew_factor is not None and skew_factor > 1.0:
        shrink = 1 << int(np.ceil(np.log2(float(skew_factor))))
        leaf_cap = max(leaf_cap // shrink, pad_align)
    leaf = _pow2_floor(max(key_space // max_fanout, 8 * pad_align))
    leaf = min(leaf, _pow2_floor(leaf_cap))
    while leaf > 8 and leaf * max(d, 1) * 4 > budget // 8:
        leaf //= 2
    if leaf >= key_space:
        return RadixPlan(key_space, (), key_space)
    num_leaves = -(-key_space // leaf)
    # fan-outs are powers of two, so the cap that actually binds is the
    # pow2 floor of max_fanout — level count and bit split both use it
    # (a non-pow2 cap must never round a level's fan-out above itself)
    fan_bits = max(max_fanout.bit_length() - 1, 1)
    bits = max(num_leaves - 1, 1).bit_length()
    levels = -(-bits // fan_bits)
    if levels > max_levels:
        return RadixPlan(
            leaf, (), key_space, feasible=False,
            reason=f"key_space={key_space} needs {levels} radix levels at "
                   f"fan-out {1 << fan_bits} (leaf bucket {leaf}), over "
                   f"the max_levels={max_levels} budget")
    # near-uniform power-of-two fan-outs covering num_leaves
    base, extra = divmod(bits, levels)
    fanouts = tuple(1 << (base + (1 if i < extra else 0))
                    for i in range(levels))
    return RadixPlan(leaf, fanouts, key_space)


def radix_partition(keys, values, key_space, *, bucket_size=None,
                    fanouts=None, pad_align=256, tile_n=256, interpret=None):
    """Radix partition of a pair chunk into padded LEAF bucket regions.

    [N] keys + [N, D] values -> (pkeys, pvals, starts); leaf bucket ``b``
    holds keys in ``[b·bucket_size, (b+1)·bucket_size)``, every region a
    ``pad_align`` multiple (sentinel-padded) — the layout ``segment_reduce``
    consumes with ``block_k=bucket_size, tile_n=pad_align``.

    ``fanouts`` selects the hierarchical multi-pass decomposition (see
    :func:`plan_radix_levels`); ``None`` keeps the classic single-level
    two-pass partition."""
    if values.ndim != 2:
        raise ValueError("values must be [N, D]")
    n, d = values.shape
    if bucket_size is None:
        bucket_size = auto_bucket_size(key_space, d=d, pad_align=pad_align)
    num_buckets = -(-key_space // bucket_size)
    out_slots = n + num_buckets * pad_align + pad_align
    cursor_rows = num_buckets
    if fanouts:
        # the widest per-level cursor: the leaf level's parent·fanout rows
        cursor_rows = max(num_buckets, -(-key_space // (
            bucket_size * fanouts[-1])) * fanouts[-1])
    if (out_slots * (4 + 4 * d) + cursor_rows * 8) > VMEM_BUDGET:
        raise ValueError(
            f"radix partition of {n} pairs x {num_buckets} buckets does not "
            f"fit the VMEM budget; shrink the chunk or grow bucket_size")
    interpret = _interpret_default() if interpret is None else interpret
    if fanouts and len(fanouts) > 1:
        # forwarded as-is: the multi-pass driver enforces its documented
        # tile_n == pad_align contract (raises on mismatch)
        return _rp.radix_partition_multi(
            keys, values, key_space, bucket_size=bucket_size,
            fanouts=tuple(fanouts), pad_align=pad_align, tile_n=tile_n,
            interpret=interpret)
    return _rp.radix_partition(keys, values, key_space,
                               bucket_size=bucket_size, pad_align=pad_align,
                               tile_n=tile_n, interpret=interpret)


def sort_segment_fold(keys, values, acc, op="add", *, bucket_size=None,
                      fanouts=None, pad_align=256, interpret=None):
    """Sort-flow chunk fold: radix partition + bucket-wise segment reduce,
    merged into the carried ``[K, D]`` f32 accumulator.

    Signature matches the sort collector's ``sort_fold_fn(keys, mat, acc,
    op)``.  The partition guarantees every reduce tile falls inside one
    aligned ``bucket_size`` K-block, so ``segment_reduce`` runs with
    ``block_k=bucket_size`` — presorted segments, no per-pair scatter.

    ``bucket_size=None`` derives the level decomposition from
    :func:`plan_radix_levels` (multi-pass past one bucket sweep); an
    infeasible plan raises — the engine checks feasibility first and falls
    back to the pure-JAX sorted fold with a warning."""
    if values.ndim != 2:
        raise ValueError("values must be [N, D]")
    key_space = acc.shape[0]
    n, d = values.shape
    if n == 0:
        return acc.astype(jnp.float32)
    if bucket_size is None and fanouts is None:
        plan = plan_radix_levels(key_space, d=d, pad_align=pad_align)
        if not plan.feasible:
            raise ValueError(f"sort_segment_fold: {plan.reason}; use the "
                             f"pure-JAX sorted fold for this key space")
        bucket_size, fanouts = plan.bucket_size, plan.fanouts
    elif bucket_size is None:
        bucket_size = auto_bucket_size(key_space, d=d, pad_align=pad_align)
    pkeys, pvals, _ = radix_partition(
        keys, values, key_space, bucket_size=bucket_size, fanouts=fanouts,
        pad_align=pad_align, interpret=interpret)
    chunk = segment_reduce(pkeys, pvals, key_space, op,
                           tile_n=pad_align, block_k=bucket_size,
                           interpret=interpret)
    f = {"add": jnp.add, "max": jnp.maximum, "min": jnp.minimum}[op]
    return f(acc.astype(jnp.float32), chunk)


def combine_scatter(keys, values, key_space, op="add", *, tile_n=256,
                    interpret=None):
    """General monoid combine (masked broadcast update). -> [K, D] f32."""
    if values.ndim != 2:
        raise ValueError("values must be [N, D]")
    interpret = _interpret_default() if interpret is None else interpret
    return _cs.combine_scatter(keys, values, key_space, op, tile_n=tile_n,
                               interpret=interpret)


def segment_reduce(sorted_keys, sorted_values, key_space, op="add", *,
                   tile_n=256, block_k=None, interpret=None):
    """Baseline reduce phase over a key-sorted stream. -> [K, D] f32.

    block_k=None lets the wrapper choose: the smallest power-of-two block
    >= the max in-tile key spread (dynamic data -> computed on host if the
    keys are concrete, else full key space).
    """
    interpret = _interpret_default() if interpret is None else interpret
    if block_k is None:
        try:  # concrete keys: exploit sorted locality
            ks = np.asarray(sorted_keys)
            n = ks.shape[0]
            tn = min(tile_n, max(n, 8))
            pad = (-n) % tn
            ksp = np.pad(ks, (0, pad), constant_values=key_space)
            tiles = ksp.reshape(-1, tn)
            valid = tiles < key_space
            spread = 0
            for t, m in zip(tiles, valid):
                if m.any():
                    lo_blk = int(t[m].min())
                    hi_blk = int(t[m].max())
                    spread = max(spread, hi_blk - lo_blk + 1)
            blk = 1 << max(int(np.ceil(np.log2(max(spread, 1)))), 3)
            # aligned blocks: spread fitting a block is necessary AND the
            # tile must not straddle an alignment boundary; double once.
            while blk < key_space:
                ok = all((not m.any()) or
                         (int(t[m].min()) // blk == int(t[m].max()) // blk)
                         for t, m in zip(tiles, valid))
                if ok:
                    break
                blk *= 2
            block_k = min(blk, key_space)
        except jax.errors.TracerArrayConversionError:
            block_k = key_space
    return _sr.segment_reduce(sorted_keys, sorted_values, key_space, op,
                              tile_n=tile_n, block_k=block_k,
                              interpret=interpret)


def flash_decode(q, k, v, kv_len, *, tile_s=512, interpret=None):
    """Single-token GQA decode attention. -> [B, H, D] f32."""
    B, H, D = q.shape
    _, S, Hkv, _ = k.shape
    if H % Hkv:
        raise ValueError("H must be a multiple of Hkv (GQA)")
    interpret = _interpret_default() if interpret is None else interpret
    # keep K/V tile + holder within VMEM
    while tile_s * D * 4 * 2 + (H // Hkv) * (D + 2) * 4 > VMEM_BUDGET:
        tile_s //= 2
    return _fd.flash_decode(q, k, v, kv_len, tile_s=tile_s,
                            interpret=interpret)
