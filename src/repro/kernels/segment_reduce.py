"""Baseline reduce phase: segmented reduce over a key-sorted pair stream.

This kernel implements the *reduce-flow* hot loop (the execution path the
paper's optimizer eliminates): pairs arrive sorted by key after the shuffle,
and each key's run is reduced.  The TPU-idiomatic exploitation of sortedness
is VMEM *block locality*: a sorted tile of pairs touches a narrow band of the
key space, so the output block visited by tile ``i`` is chosen dynamically
via scalar prefetch (``block_ids[i] = sorted_keys[i*Tn] // Kb``) instead of
keeping the whole ``[K, D]`` table resident.  This is what lets the reduce
phase scale to large K — and it is still strictly worse than the combine
flow, which never materializes the sorted stream at all (the point of the
paper).

Precondition (enforced by ops.py): every tile's keys fall inside one aligned
K-block, i.e. ``Kb >= max in-tile key spread`` (guaranteed by choosing
``Kb = K`` in the worst case).  Cross-tile runs are handled by revisiting:
tiles are processed in order and the op is associative, so a run spanning
tiles accumulates correctly whenever consecutive tiles map to the same block;
when they don't, their key ranges are disjoint (sortedness), so no update is
lost.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_IDENT = {"add": 0.0, "max": -jnp.inf, "min": jnp.inf}


def _kernel(block_ids_ref, keys_ref, vals_ref, out_ref, *, block_k: int,
            op: str):
    i = pl.program_id(0)
    ident = jnp.float32(_IDENT[op])

    # first visit to this output block? (block_ids is non-decreasing)
    blk = block_ids_ref[i]
    prev_blk = block_ids_ref[jnp.maximum(i, 1) - 1]
    first_visit = (i == 0) | (blk != prev_blk)

    @pl.when(first_visit)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, ident)

    keys = keys_ref[...]  # [Tn] global key ids (sorted)
    vals = vals_ref[...]  # [Tn, D]
    local = keys - blk * block_k  # ids within this K-block
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (local.shape[0], block_k), 1)
    hit = (local[:, None] == k_iota)  # out-of-block / sentinel -> no hit

    if op == "add":
        onehot = hit.astype(vals.dtype)
        out_ref[...] += jax.lax.dot_general(
            onehot, vals, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    else:
        f = jnp.maximum if op == "max" else jnp.minimum
        masked = jnp.where(hit[:, :, None], vals[:, None, :], ident)
        out_ref[...] = f(out_ref[...], masked.max(0) if op == "max"
                         else masked.min(0))


def _chunk_fold_kernel(keys_ref, vals_ref, acc_ref, out_ref, *, op: str,
                       block_k: int):
    """Streaming-flow chunk fold for non-additive monoids: an UNSORTED pair
    tile is masked against the current key block's iota and monoid-reduced
    into the VMEM-resident [Kb, D] table block (loaded from the carried
    accumulator on the first tile).  Complements ``segment_reduce``, which
    needs a key-sorted stream; chunk streams arrive in emission order.  The
    key-block grid axis (outermost) bounds VMEM residency for large K."""
    b = pl.program_id(0)  # outermost: key-block index
    i = pl.program_id(1)  # innermost: pair-stream tile index

    @pl.when(i == 0)
    def _init():
        out_ref[...] = acc_ref[...]

    ident = jnp.float32(_IDENT[op])
    keys = keys_ref[...]  # [Tn] int32, unsorted, sentinel == key_space
    vals = vals_ref[...]  # [Tn, D] f32
    local = keys - b * block_k  # rebased; out-of-block/sentinel -> no hit
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (keys.shape[0], block_k), 1)
    hit = (local[:, None] == k_iota)

    if op == "add":
        onehot = hit.astype(vals.dtype)
        out_ref[...] += jax.lax.dot_general(
            onehot, vals, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    else:
        f = jnp.maximum if op == "max" else jnp.minimum
        masked = jnp.where(hit[:, :, None], vals[:, None, :], ident)
        out_ref[...] = f(out_ref[...], masked.max(0) if op == "max"
                         else masked.min(0))


@functools.partial(jax.jit, static_argnames=("key_space", "op", "tile_n",
                                             "block_k", "interpret"))
def chunk_monoid_fold(
    keys: jax.Array,
    values: jax.Array,
    acc: jax.Array,
    key_space: int,
    op: str = "add",
    *,
    tile_n: int = 256,
    block_k: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Unsorted [N] keys + [N, D] values folded into [K, D] acc (f32).

    ``acc`` rows for keys absent from the chunk are passed through
    unchanged, so repeated calls implement the holder-carry contract.
    ``block_k`` partitions the key space into grid blocks (see
    ``onehot_fold``); ``None`` keeps one block."""
    n, d = values.shape
    tile_n = min(tile_n, max(n, 8))
    if block_k is None or block_k >= key_space:
        block_k = key_space
    n_blocks = -(-key_space // block_k)
    pad_k = n_blocks * block_k - key_space

    pad_n = (-n) % tile_n
    keys_p = jnp.pad(keys, (0, pad_n), constant_values=key_space)
    vals_p = jnp.pad(values.astype(jnp.float32), ((0, pad_n), (0, 0)))
    # padded table rows absorb sentinel hits (cropped below); identity-fill
    # keeps the non-add merges well-defined there.
    acc_p = jnp.pad(acc.astype(jnp.float32), ((0, pad_k), (0, 0)),
                    constant_values=_IDENT[op] if op != "add" else 0.0)
    n_tiles = keys_p.shape[0] // tile_n

    out = pl.pallas_call(
        functools.partial(_chunk_fold_kernel, op=op, block_k=block_k),
        grid=(n_blocks, n_tiles),
        in_specs=[
            pl.BlockSpec((tile_n,), lambda b, i: (i,)),
            pl.BlockSpec((tile_n, d), lambda b, i: (i, 0)),
            pl.BlockSpec((block_k, d), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((block_k, d), lambda b, i: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * block_k, d), jnp.float32),
        interpret=interpret,
    )(keys_p, vals_p, acc_p)
    return out[:key_space]


@functools.partial(jax.jit, static_argnames=("key_space", "op", "tile_n",
                                             "block_k", "interpret"))
def segment_reduce(
    sorted_keys: jax.Array,
    sorted_values: jax.Array,
    key_space: int,
    op: str = "add",
    *,
    tile_n: int = 256,
    block_k: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Key-sorted [N] keys + [N, D] values -> [K, D] reduced table (f32).

    ``block_k`` must be >= the max key spread within any tile (ops.py
    computes a safe value; None means the full key space — always safe).
    """
    n, d = sorted_values.shape
    tile_n = min(tile_n, max(n, 8))
    if block_k is None:
        block_k = key_space
    pad_k = (-key_space) % block_k
    K_p = key_space + pad_k

    pad_n = (-n) % tile_n
    keys_p = jnp.pad(sorted_keys, (0, pad_n), constant_values=K_p)
    vals_p = jnp.pad(sorted_values.astype(jnp.float32), ((0, pad_n), (0, 0)))
    np_ = keys_p.shape[0]
    n_tiles = np_ // tile_n

    # scalar prefetch: which K-block each tile accumulates into
    tile_first_key = keys_p[:: tile_n][:n_tiles]
    block_ids = jnp.minimum(tile_first_key // block_k,
                            K_p // block_k - 1).astype(jnp.int32)

    from jax.experimental.pallas import tpu as pltpu

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile_n,), lambda i, blk: (i,)),
            pl.BlockSpec((tile_n, d), lambda i, blk: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_k, d), lambda i, blk: (blk[i], 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, block_k=block_k, op=op),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((K_p, d), jnp.float32),
        interpret=interpret,
    )(block_ids, keys_p, vals_p)
    out = out[:key_space]
    # K-blocks never visited by any tile keep uninitialized memory; reset
    # absent keys to the identity (also masks sentinel-padded keys).
    counts = jnp.zeros((K_p + 1,), jnp.int32).at[keys_p].add(1, mode="drop")
    out = jnp.where((counts[:key_space] > 0)[:, None], out,
                    jnp.float32(_IDENT[op]))
    return out
