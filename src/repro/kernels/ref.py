"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are tested against
(tests/kernels/*): same signatures, same dtypes, no tiling.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def onehot_combine(keys: jax.Array, values: jax.Array, key_space: int) -> jax.Array:
    """Sum-combine values by key: ``one_hot(keys)ᵀ @ values``.

    keys:   [N] int32 in [0, key_space]; == key_space -> dropped.
    values: [N, D] float.
    returns [key_space, D] float32 per-key sums.
    """
    oh = jax.nn.one_hot(keys, key_space, dtype=jnp.float32)  # sentinel -> 0s
    return jnp.einsum("nk,nd->kd", oh, values.astype(jnp.float32))


def _blocked_kd(per_block, key_space: int, block_k: int) -> jax.Array:
    """Assemble a [K, ...] table from ``per_block(lo) -> [block_k, ...]``
    ran sequentially over the key-block grid — the pure-JAX mirror of the
    kernels' key-block grid axis (one block's dense expansion live at a
    time)."""
    n_blocks = -(-key_space // block_k)
    lows = jnp.arange(n_blocks, dtype=jnp.int32) * block_k
    blocks = jax.lax.map(per_block, lows)
    return blocks.reshape((n_blocks * block_k,) + blocks.shape[2:])[:key_space]


def onehot_fold(keys: jax.Array, values: jax.Array, acc: jax.Array,
                key_space: int | None = None,
                block_k: int | None = None) -> jax.Array:
    """Streaming-chunk additive fold: ``acc + one_hot(keys)ᵀ @ values``.

    ``block_k`` computes the per-key sums one key block at a time (same
    result; bounds the live one-hot to ``[N, block_k]``)."""
    if key_space is None:
        key_space = acc.shape[0]
    if block_k is None or block_k >= key_space:
        return (acc.astype(jnp.float32)
                + onehot_combine(keys, values, key_space))
    iota = jnp.arange(block_k, dtype=jnp.int32)

    def one(lo):
        oh = ((keys[:, None] - lo) == iota[None, :]).astype(jnp.float32)
        return jnp.einsum("nk,nd->kd", oh, values.astype(jnp.float32))

    return acc.astype(jnp.float32) + _blocked_kd(one, key_space, block_k)


def chunk_monoid_fold(keys: jax.Array, values: jax.Array, acc: jax.Array,
                      op: str = "add",
                      block_k: int | None = None) -> jax.Array:
    """Monoid fold of an unsorted chunk into the carried [K, D] table.

    ``block_k`` reduces the chunk one key block at a time (same result)."""
    f = {"add": jnp.add, "max": jnp.maximum, "min": jnp.minimum}[op]
    key_space = acc.shape[0]
    if block_k is None or block_k >= key_space:
        chunk = combine_scatter(keys, values, key_space, op)
        return f(acc.astype(jnp.float32), chunk)
    ident = {"add": 0.0, "max": -jnp.inf, "min": jnp.inf}[op]
    red = {"add": jnp.sum, "max": jnp.max, "min": jnp.min}[op]
    iota = jnp.arange(block_k, dtype=jnp.int32)
    vals = values.astype(jnp.float32)

    def one(lo):
        hit = (keys[:, None] - lo) == iota[None, :]  # [N, Kb]
        masked = jnp.where(hit[:, :, None], vals[:, None, :], ident)
        return red(masked, axis=0)  # [Kb, D]

    return f(acc.astype(jnp.float32), _blocked_kd(one, key_space, block_k))


def combine_scatter(keys: jax.Array, values: jax.Array, key_space: int,
                    op: str = "add") -> jax.Array:
    """Monoid scatter-combine values by key into a [K, D] table.

    op in {add, max, min}.  Sentinel keys dropped.
    """
    K = key_space
    vals = values.astype(jnp.float32)
    if op == "add":
        init = jnp.zeros((K,) + vals.shape[1:], jnp.float32)
        return init.at[keys].add(vals, mode="drop")
    if op == "max":
        init = jnp.full((K,) + vals.shape[1:], -jnp.inf, jnp.float32)
        return init.at[keys].max(vals, mode="drop")
    if op == "min":
        init = jnp.full((K,) + vals.shape[1:], jnp.inf, jnp.float32)
        return init.at[keys].min(vals, mode="drop")
    raise ValueError(op)


def radix_partition(keys: jax.Array, values: jax.Array, key_space: int,
                    *, bucket_size: int, pad_align: int = 256):
    """Oracle for the two-pass radix partition kernel.

    ``jnp.argsort``-based ground truth with the kernel's exact padded
    layout: stable sort by bucket id, then place bucket ``b``'s pairs at
    ``starts[b] + rank`` where every bucket region is padded to a
    ``pad_align`` multiple (sentinel-filled) and the trailing ``pad_align``
    slots absorb invalid pairs.
    """
    n = keys.shape[0]
    num_buckets = -(-key_space // bucket_size)
    b = keys // bucket_size
    valid = b < num_buckets

    hist = jnp.sum((b[:, None] == jnp.arange(num_buckets)[None, :]) &
                   valid[:, None], axis=0).astype(jnp.int32)
    padded = -(-hist // pad_align) * pad_align
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(padded)[:-1].astype(jnp.int32)])
    out_slots = n + num_buckets * pad_align + pad_align
    out_slots += (-out_slots) % pad_align

    order = jnp.argsort(jnp.where(valid, b, num_buckets), stable=True)
    sb = jnp.where(valid, b, num_buckets)[order]
    excl = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(hist).astype(jnp.int32)])
    rank = jnp.arange(n, dtype=jnp.int32) - excl[jnp.minimum(sb, num_buckets)]
    dst = jnp.where(sb < num_buckets,
                    starts[jnp.minimum(sb, num_buckets - 1)] + rank,
                    out_slots - 1)
    pkeys = jnp.full((out_slots,), key_space, jnp.int32).at[dst].set(
        keys[order], mode="drop")
    pvals = jnp.zeros((out_slots,) + values.shape[1:], jnp.float32).at[
        dst].set(values[order].astype(jnp.float32), mode="drop")
    # the shared trash slot ends up holding the LAST invalid pair; the
    # kernel's contract only promises sentinel keys there — normalize.
    pkeys = pkeys.at[out_slots - 1].set(key_space)
    pvals = pvals.at[out_slots - 1].set(0.0)
    return pkeys, pvals, starts


def sort_segment_fold(keys: jax.Array, values: jax.Array, acc: jax.Array,
                      op: str = "add") -> jax.Array:
    """Oracle for the sort-flow fold: argsort + segment reduce, merged into
    the carried ``[K, D]`` accumulator (rows of absent keys unchanged)."""
    key_space = acc.shape[0]
    order = jnp.argsort(keys)
    chunk = segment_reduce(keys[order], values[order], key_space, op)
    f = {"add": jnp.add, "max": jnp.maximum, "min": jnp.minimum}[op]
    return f(acc.astype(jnp.float32), chunk)


def segment_reduce(sorted_keys: jax.Array, sorted_values: jax.Array,
                   key_space: int, op: str = "add") -> jax.Array:
    """Baseline reduce phase: segmented reduce over key-sorted pairs.

    Same output contract as combine_scatter; input must be sorted by key.
    (The kernel exploits sortedness for sequential-run accumulation; the
    oracle need not.)
    """
    return combine_scatter(sorted_keys, sorted_values, key_space, op)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 kv_len: jax.Array | int, scale: float | None = None) -> jax.Array:
    """Single-token decode attention (the (m, l, acc) combiner, unfused).

    q: [H, D]; k, v: [S, Hkv, D]; kv_len: #valid positions (<= S).
    GQA: H % Hkv == 0; head h attends kv head h // (H // Hkv).
    returns [H, D] float32.
    """
    H, D = q.shape
    S, Hkv, _ = k.shape
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    kg = jnp.repeat(kf, G, axis=1)  # [S, H, D]
    vg = jnp.repeat(vf, G, axis=1)
    logits = jnp.einsum("hd,shd->hs", qf, kg)
    mask = jnp.arange(S)[None, :] < kv_len
    logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hs,shd->hd", w, vg)
