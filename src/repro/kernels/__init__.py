"""Pallas TPU kernels for the collector hot paths (+ flash decode).

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU in interpret mode against the pure-jnp oracles in ref.py.
"""

from repro.kernels import ops, ref  # noqa: F401
