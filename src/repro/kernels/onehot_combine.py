"""MXU one-hot combiner: ``one_hot(keys)ᵀ @ values`` on the systolic array.

This is the TPU-native lowering of the paper's combining collector for
*additive* monoids: instead of a hash-table insert per emitted pair (the JVM
mechanism) or an atomic scatter-add (the GPU mechanism), each tile of emitted
pairs becomes a dense ``[K, Tn] @ [Tn, D]`` matmul that the MXU executes at
peak; the per-key holder table ``[K, D]`` stays resident in VMEM across the
whole pair stream (grid-accumulation), so the intermediate pairs are never
re-read from HBM — the combine happens "at emit time", exactly the paper's
execution-flow change.

Preconditions: K*D*4 + Tn*(K + D)*4 bytes within VMEM budget (ops.py checks).
Sentinel keys (== key_space) produce all-zero one-hot rows and are dropped.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(keys_ref, vals_ref, out_ref, *, key_space: int, n_tiles: int):
    i = pl.program_id(1)  # innermost: pair-stream tile index

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]  # [Tn] int32
    vals = vals_ref[...]  # [Tn, Td] f32
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (keys.shape[0], key_space), 1)
    onehot = (keys[:, None] == k_iota).astype(vals.dtype)  # [Tn, K]
    # MXU: [K, Tn] @ [Tn, Td] accumulated into the VMEM-resident table
    out_ref[...] += jax.lax.dot_general(
        onehot, vals, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _fold_kernel(keys_ref, vals_ref, acc_ref, out_ref, *, block_k: int):
    """Grid-accumulated ``acc + one_hot(keys)ᵀ @ vals`` — the streaming
    collector's per-chunk fold, over a key-block grid axis.  The accumulator
    block is loaded into the VMEM-resident output on the first pair tile and
    the chunk's tiles are accumulated on top, so each carried holder-table
    block round-trips HBM once per chunk (not per tile) and the one-hot
    never leaves VMEM.  Keys are rebased into the current key block; keys
    outside it (and sentinels) produce all-zero one-hot rows."""
    b = pl.program_id(0)  # outermost: key-block index
    i = pl.program_id(2)  # innermost: pair-stream tile index

    @pl.when(i == 0)
    def _init():
        out_ref[...] = acc_ref[...]

    keys = keys_ref[...]  # [Tn] int32
    vals = vals_ref[...]  # [Tn, Td] f32
    local = keys - b * block_k  # rebased: hits only within [0, block_k)
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (keys.shape[0], block_k), 1)
    onehot = (local[:, None] == k_iota).astype(vals.dtype)  # [Tn, Kb]
    out_ref[...] += jax.lax.dot_general(
        onehot, vals, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("key_space", "tile_n", "tile_d",
                                             "block_k", "interpret"))
def onehot_fold(
    keys: jax.Array,
    values: jax.Array,
    acc: jax.Array,
    key_space: int,
    *,
    tile_n: int = 512,
    tile_d: int = 128,
    block_k: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """[N] keys, [N, D] values, [K, D] acc -> acc + per-key sums (f32).

    ``block_k`` partitions the key space into ``ceil(K / block_k)`` grid
    blocks so only one ``[block_k, Td]`` table block (plus its one-hot tile)
    is VMEM-resident per step — the large-K form of the fold.  ``None``
    keeps the whole key space in one block."""
    n, d = values.shape
    tile_n = min(tile_n, max(n, 8))
    tile_d = min(tile_d, d)
    if block_k is None or block_k >= key_space:
        block_k = key_space
    n_blocks = -(-key_space // block_k)
    pad_k = n_blocks * block_k - key_space

    pad_n = (-n) % tile_n
    pad_d = (-d) % tile_d
    keys_p = jnp.pad(keys, (0, pad_n), constant_values=key_space)
    vals_p = jnp.pad(values.astype(jnp.float32), ((0, pad_n), (0, pad_d)))
    acc_p = jnp.pad(acc.astype(jnp.float32), ((0, pad_k), (0, pad_d)))
    np_, dp = vals_p.shape
    n_tiles = np_ // tile_n

    # N innermost: the (key-block, d) table tile stays resident across the
    # whole pair stream; the key-block axis is outermost so each block's
    # accumulator is initialized exactly once.
    grid = (n_blocks, dp // tile_d, n_tiles)
    out = pl.pallas_call(
        functools.partial(_fold_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n,), lambda b, j, i: (i,)),
            pl.BlockSpec((tile_n, tile_d), lambda b, j, i: (i, j)),
            pl.BlockSpec((block_k, tile_d), lambda b, j, i: (b, j)),
        ],
        out_specs=pl.BlockSpec((block_k, tile_d), lambda b, j, i: (b, j)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * block_k, dp), jnp.float32),
        interpret=interpret,
    )(keys_p, vals_p, acc_p)
    return out[:key_space, :d]


@functools.partial(jax.jit, static_argnames=("key_space", "tile_n", "tile_d",
                                             "interpret"))
def onehot_combine(
    keys: jax.Array,
    values: jax.Array,
    key_space: int,
    *,
    tile_n: int = 512,
    tile_d: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """[N] keys, [N, D] values -> [K, D] per-key sums (f32)."""
    n, d = values.shape
    tile_n = min(tile_n, max(n, 8))
    tile_d = min(tile_d, d)

    # pad N to a tile multiple (sentinel keys), D to a tile multiple (zeros)
    pad_n = (-n) % tile_n
    pad_d = (-d) % tile_d
    keys_p = jnp.pad(keys, (0, pad_n), constant_values=key_space)
    vals_p = jnp.pad(values.astype(jnp.float32), ((0, pad_n), (0, pad_d)))
    np_, dp = vals_p.shape
    n_tiles = np_ // tile_n

    grid = (dp // tile_d, n_tiles)  # N innermost: table tile stays resident
    out = pl.pallas_call(
        functools.partial(_kernel, key_space=key_space, n_tiles=n_tiles),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n,), lambda j, i: (i,)),
            pl.BlockSpec((tile_n, tile_d), lambda j, i: (i, j)),
        ],
        out_specs=pl.BlockSpec((key_space, tile_d), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((key_space, dp), jnp.float32),
        interpret=interpret,
    )(keys_p, vals_p)
    return out[:, :d]
