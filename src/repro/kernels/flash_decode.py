"""Flash-decode attention: the paper's combiner applied to KV tiles.

Decode attention for one new token is a reduction over the KV cache — and
softmax attention admits an *associative combiner* over KV tiles with holder
``(m, l, acc)`` (running max, rescaled normalizer, rescaled value-sum): the
exact shape of ``CombinerSpec`` (core/combiner.py:logsumexp_spec extended
with an accumulator).  The baseline "reduce flow" would materialize all
``[S]`` logits, softmax, then contract; the combine flow folds each KV tile
into the holder as it streams through VMEM — O(tile) live memory instead of
O(S), no second pass.  This kernel is that combine flow on TPU:

  grid = (batch, kv_heads, S_tiles)    (S innermost; holder VMEM-resident)
  per tile: logits = q·Kᵀ  (MXU) -> masked -> holder update (VPU) ->
            acc += softmax-weights · V (MXU); final tile writes acc / l.

GQA: the G = H/Hkv query heads of a KV group are processed together, so K/V
tiles are read once per group, not once per head.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30  # avoid -inf NaN propagation in f32 exp on all-masked tiles


def _kernel(kv_len_ref, q_ref, k_ref, v_ref, out_ref,
            m_ref, l_ref, acc_ref, *, tile_s: int, scale: float):
    b = pl.program_id(0)
    s = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # [G, D]
    k = k_ref[0, :, 0].astype(jnp.float32)  # [Ts, D]
    v = v_ref[0, :, 0].astype(jnp.float32)  # [Ts, D]

    logits = jax.lax.dot_general(  # [G, Ts] on the MXU
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    pos = s * tile_s + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(pos < kv_len_ref[b], logits, NEG_INF)

    m_prev = m_ref[...]  # [G, 1]
    m_new = jnp.maximum(m_prev, logits.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)  # [G, 1]
    p = jnp.exp(logits - m_new)  # [G, Ts]
    p = jnp.where(pos < kv_len_ref[b], p, 0.0)

    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(s == n_s - 1)
    def _emit():
        out_ref[0, 0] = (acc_ref[...] /
                         jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_s", "interpret"))
def flash_decode(
    q: jax.Array,  # [B, H, D]
    k: jax.Array,  # [B, S, Hkv, D]
    v: jax.Array,  # [B, S, Hkv, D]
    kv_len: jax.Array,  # [B] int32 valid lengths
    *,
    tile_s: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Single-token GQA decode attention -> [B, H, D] f32."""
    B, H, D = q.shape
    _, S, Hkv, _ = k.shape
    G = H // Hkv
    scale = 1.0 / (D ** 0.5)
    tile_s = min(tile_s, S)

    pad_s = (-S) % tile_s
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    Sp = S + pad_s

    qg = q.reshape(B, Hkv, G, D)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, Sp // tile_s),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, s, kvl: (b, h, 0, 0)),
            pl.BlockSpec((1, tile_s, 1, D), lambda b, h, s, kvl: (b, s, h, 0)),
            pl.BlockSpec((1, tile_s, 1, D), lambda b, h, s, kvl: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s, kvl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, tile_s=tile_s, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), jnp.float32),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qg, k, v)
    return out.reshape(B, H, D)
