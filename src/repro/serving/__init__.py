"""repro.serving"""
