"""Serve-step factory: batched decode with sampling, pjit-ready."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.registry import Model


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    temperature: float = 0.0  # 0 => greedy
    kv_dtype: str = "model"  # "model" | "int8"


def kv_dtype_of(model: Model, sc: ServeConfig):
    return jnp.int8 if sc.kv_dtype == "int8" else None


def make_decode_step(model: Model, sc: ServeConfig = ServeConfig()):
    """decode_step(params, state, tokens [B], rng) -> (next_tokens, state)."""

    def step(params, state, tokens, rng):
        logits, state = model.decode_step(params, state, tokens)
        if sc.temperature > 0:
            nxt = jax.random.categorical(rng, logits / sc.temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), state

    return step


def make_prefill(model: Model, sc: ServeConfig = ServeConfig()):
    def prefill(params, batch, state):
        logits, state = model.prefill(params, batch, state)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), state

    return prefill


def generate(model: Model, params, prompts, *, max_new: int = 16,
             sc: ServeConfig = ServeConfig(), rng=None, extra_batch=None):
    """Greedy/temperature generation loop (CPU example driver).

    ``extra_batch`` carries modality-stub inputs (whisper "frames",
    internvl "patches")."""
    B, S = prompts.shape
    extra_len = (extra_batch["patches"].shape[1]
                 if extra_batch and "patches" in extra_batch else 0)
    state = model.init_decode_state(B, S + max_new + extra_len,
                                    kv_dtype=kv_dtype_of(model, sc))
    rng = rng if rng is not None else jax.random.PRNGKey(0)

    pf = jax.jit(make_prefill(model, sc))
    step = jax.jit(make_decode_step(model, sc))

    nxt, state = pf(params, {"tokens": prompts, **(extra_batch or {})},
                    state)
    out = [nxt]
    for i in range(max_new - 1):
        rng, sub = jax.random.split(rng)
        nxt, state = step(params, state, nxt, sub)
        out.append(nxt)
    return jnp.stack(out, axis=1)
