"""Zamba2-style hybrid: Mamba2 backbone + a SHARED attention block.

Structure (simplified from arXiv:2411.15242, noted in DESIGN.md): the model
is ``G`` groups of ``hybrid_attn_every`` Mamba2 layers, each group followed
by one application of a *single shared* transformer block (shared weights,
distinct KV cache per call site); leftover Mamba2 layers close the stack.
The original's embedding-concat input to the shared block and LoRA-per-site
projections are omitted.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.common import ModelConfig
from repro.models.layers import (embed, init_embed, init_rmsnorm,
                                 init_swiglu, init_unembed, rmsnorm, swiglu)


def _layout(cfg: ModelConfig):
    k = cfg.hybrid_attn_every
    groups = cfg.num_layers // k
    leftover = cfg.num_layers - groups * k
    return groups, k, leftover


def init_params(cfg: ModelConfig, rng):
    ke, km, ks, ku = jax.random.split(rng, 4)
    groups, k, leftover = _layout(cfg)

    def mamba_layer(key):
        return {"ln": init_rmsnorm(cfg.d_model),
                "ssm": ssm_mod.init_ssm(key, cfg)}

    grouped = jax.vmap(jax.vmap(mamba_layer))(
        jax.random.split(km, groups * k).reshape(groups, k, 2))
    tail = (jax.vmap(mamba_layer)(jax.random.split(jax.random.fold_in(km, 7),
                                                   leftover))
            if leftover else None)
    ka, kf = jax.random.split(ks)
    shared = {
        "ln_attn": init_rmsnorm(cfg.d_model),
        "attn": attn.init_attn(ka, cfg),
        "ln_ffn": init_rmsnorm(cfg.d_model),
        "ffn": init_swiglu(kf, cfg.d_model, cfg.d_ff, cfg.dtype),
    }
    p = {
        "embed": init_embed(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "groups": grouped,  # [G, k, ...]
        "shared": shared,
        "ln_f": init_rmsnorm(cfg.d_model),
        "head": init_unembed(ku, cfg.vocab_size, cfg.d_model, cfg.dtype,
                             tie=cfg.tie_embeddings),
    }
    if tail is not None:
        p["tail"] = tail
    return p


def _mamba_block(cfg, p, x):
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    return x + ssm_mod.ssm_train(cfg, p["ssm"], h)


def _shared_block_train(cfg, p, x):
    h = rmsnorm(p["ln_attn"], x, cfg.norm_eps)
    x = x + attn.attn_train(cfg, p["attn"], h)
    h = rmsnorm(p["ln_ffn"], x, cfg.norm_eps)
    return x + swiglu(p["ffn"], h, cfg.act)


def forward(cfg: ModelConfig, params, batch, *, remat: bool = True, **_):
    x = embed(params["embed"], batch["tokens"])
    groups, k, leftover = _layout(cfg)

    def group_body(x, gp):
        def inner(x, p):
            f = jax.checkpoint(partial(_mamba_block, cfg)) if remat else \
                partial(_mamba_block, cfg)
            return f(p, x), None

        x, _ = jax.lax.scan(inner, x, gp)
        f = (jax.checkpoint(partial(_shared_block_train, cfg)) if remat
             else partial(_shared_block_train, cfg))
        return f(params["shared"], x), None

    x, _ = jax.lax.scan(group_body, x, params["groups"])
    if "tail" in params:
        def inner(x, p):
            return _mamba_block(cfg, p, x), None
        x, _ = jax.lax.scan(inner, x, params["tail"])
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return x, {"load_balance_loss": jnp.float32(0.0)}


def unembed_matrix(cfg, params):
    return (params["embed"]["table"] if cfg.tie_embeddings
            else params["head"]["w"])


def logits_of_hidden(cfg, params, hidden):
    return jnp.einsum("...e,ve->...v", hidden,
                      unembed_matrix(cfg, params)).astype(jnp.float32)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, *,
                      kv_dtype=None):
    groups, k, leftover = _layout(cfg)
    state = {
        "ssm_groups": ssm_mod.init_ssm_state(cfg, batch, groups * k),
        "cache": attn.init_kv_cache(cfg, batch, max_len, kv_dtype=kv_dtype,
                                    layers=groups),  # one per call site
        "pos": jnp.zeros((), jnp.int32),
    }
    if leftover:
        state["ssm_tail"] = ssm_mod.init_ssm_state(cfg, batch, leftover)
    return state


def decode_step(cfg: ModelConfig, params, state, tokens):
    groups, k, leftover = _layout(cfg)
    pos = state["pos"]
    x = embed(params["embed"], tokens[:, None])

    conv = state["ssm_groups"]["conv"].reshape(
        (groups, k) + state["ssm_groups"]["conv"].shape[1:])
    ssm_s = state["ssm_groups"]["ssm"].reshape(
        (groups, k) + state["ssm_groups"]["ssm"].shape[1:])

    def group_body(x, layer):
        gp, conv_g, ssm_g, cache_g = layer

        def inner(x, l):
            p, c, s = l
            h = rmsnorm(p["ln"], x, cfg.norm_eps)
            y, ns = ssm_mod.ssm_decode(cfg, p["ssm"], h, {"conv": c, "ssm": s})
            return x + y, (ns["conv"], ns["ssm"])

        x, (nc, nssm) = jax.lax.scan(inner, x, (gp, conv_g, ssm_g))
        h = rmsnorm(params["shared"]["ln_attn"], x, cfg.norm_eps)
        a, kv_new = attn.attn_decode(cfg, params["shared"]["attn"], h,
                                     cache_g, pos, deferred_write=True)
        x = x + a
        h = rmsnorm(params["shared"]["ln_ffn"], x, cfg.norm_eps)
        x = x + swiglu(params["shared"]["ffn"], h, cfg.act)
        return x, (nc, nssm, kv_new)

    x, (nconv, nssm, kv_stack) = jax.lax.scan(
        group_body, x, (params["groups"], conv, ssm_s, state["cache"]))

    new_state = dict(state)
    new_state["ssm_groups"] = {
        "conv": nconv.reshape((-1,) + nconv.shape[2:]),
        "ssm": nssm.reshape((-1,) + nssm.shape[2:]),
    }
    new_state["cache"] = attn.stacked_cache_write(
        state["cache"], kv_stack[0], kv_stack[1], pos)

    if leftover:
        def inner(x, l):
            p, c, s = l
            h = rmsnorm(p["ln"], x, cfg.norm_eps)
            y, ns = ssm_mod.ssm_decode(cfg, p["ssm"], h, {"conv": c, "ssm": s})
            return x + y, (ns["conv"], ns["ssm"])

        x, (tc, ts) = jax.lax.scan(
            inner, x, (params["tail"], state["ssm_tail"]["conv"],
                       state["ssm_tail"]["ssm"]))
        new_state["ssm_tail"] = {"conv": tc, "ssm": ts}

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = logits_of_hidden(cfg, params, x[:, 0])
    new_state["pos"] = pos + 1
    return logits, new_state


def prefill(cfg: ModelConfig, params, batch, state, **_):
    """Chunked-SSD prefill for the Mamba2 layers + full-sequence K/V
    computation for the shared-attention call sites (§Perf iteration 2)."""
    from repro.models.layers import apply_rope, rope_table

    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(params["embed"], tokens)
    groups, k, leftover = _layout(cfg)

    def group_body(x, gp):
        def inner(x, p):
            h = rmsnorm(p["ln"], x, cfg.norm_eps)
            y, st = ssm_mod.ssm_forward(cfg, p["ssm"], h, return_state=True)
            return x + y, st

        x, states = jax.lax.scan(inner, x, gp)
        sh = params["shared"]
        h = rmsnorm(sh["ln_attn"], x, cfg.norm_eps)
        kk, vv = attn._project_kv(cfg, sh["attn"], h)
        cos, sin = rope_table(jnp.arange(S), cfg.hd, cfg.rope_theta)
        k_r = apply_rope(kk, cos, sin)
        x = x + attn.attn_train(cfg, sh["attn"], h)
        h = rmsnorm(sh["ln_ffn"], x, cfg.norm_eps)
        x = x + swiglu(sh["ffn"], h, cfg.act)
        return x, (states, (k_r, vv))

    x, (g_states, (k_all, v_all)) = jax.lax.scan(group_body, x,
                                                 params["groups"])

    new_state = dict(state)
    new_state["ssm_groups"] = {
        "conv": g_states["conv"].reshape((-1,) + g_states["conv"].shape[2:]),
        "ssm": g_states["ssm"].reshape((-1,) + g_states["ssm"].shape[2:]),
    }
    Smax = state["cache"]["k"].shape[2]
    pad = [(0, 0), (0, 0), (0, Smax - S), (0, 0), (0, 0)]
    dt = state["cache"]["k"].dtype
    new_state["cache"] = {"k": jnp.pad(k_all.astype(dt), pad),
                          "v": jnp.pad(v_all.astype(dt), pad)}

    if leftover:
        def inner(x, p):
            h = rmsnorm(p["ln"], x, cfg.norm_eps)
            y, st = ssm_mod.ssm_forward(cfg, p["ssm"], h, return_state=True)
            return x + y, st

        x, t_states = jax.lax.scan(inner, x, params["tail"])
        new_state["ssm_tail"] = {"conv": t_states["conv"],
                                 "ssm": t_states["ssm"]}

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = logits_of_hidden(cfg, params, x[:, -1])
    new_state["pos"] = jnp.asarray(S, jnp.int32)
    return logits, new_state
