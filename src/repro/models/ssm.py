"""Mamba2 / SSD (state-space duality) blocks — attention-free sequence mixing.

The SSD algorithm is itself an instance of the paper's combiner abstraction:
the sequence is split into chunks, each chunk computes a local summary state,
and the inter-chunk recurrence

    state_c = decay_c * state_{c-1} + S_c

is an **associative combine** ((d1,s1)∘(d2,s2) = (d1·d2, s2 + d2·s1)) —
evaluated here with ``jax.lax.associative_scan``, the parallel fold of the
same monoid family used by core/combiner.py.

Single SSM group (n_groups=1); head layout follows Mamba2: d_inner = expand·E
split into H heads of P dims, state size N per head.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.layers import init_rmsnorm, rmsnorm


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_d_inner
    H = cfg.ssm_heads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    return d_in, H, P, N


def init_ssm(rng, cfg: ModelConfig):
    d_in, H, P, N = _dims(cfg)
    E = cfg.d_model
    conv_ch = d_in + 2 * N  # conv over (x, B, C)
    ks = jax.random.split(rng, 4)
    s = E ** -0.5
    proj_out = 2 * d_in + 2 * N + H  # z, x, B, C, dt
    return {
        "in_proj": (jax.random.normal(ks[0], (E, proj_out)) * s).astype(cfg.dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch)) *
                   cfg.ssm_conv ** -0.5).astype(cfg.dtype),
        "conv_b": jnp.zeros((conv_ch,), cfg.dtype),
        "A_log": jnp.zeros((H,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": init_rmsnorm(d_in),
        "out_proj": (jax.random.normal(ks[2], (d_in, E)) *
                     d_in ** -0.5).astype(cfg.dtype),
    }


def _split_proj(cfg, proj):
    d_in, H, P, N = _dims(cfg)
    z, xc, B, C, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    return z, xc, B, C, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv along seq. xbc [Bt,S,Ch]; w [W,Ch]."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(W):  # W is small (4); unrolled taps
        out = out + pad[:, i:i + xbc.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def _chunk_len(chunk: int, S: int) -> int:
    q = min(chunk, S)
    while S % q:
        q -= 1
    return q


def ssm_train(cfg: ModelConfig, p, x):
    """Chunked SSD forward. x [Bt, S, E] -> [Bt, S, E]."""
    y, _ = ssm_forward(cfg, p, x, return_state=False)
    return y


def ssm_forward(cfg: ModelConfig, p, x, *, return_state: bool = False):
    """Chunked SSD forward; optionally also returns the decode-ready state.

    The final SSM state falls out of the inter-chunk associative combine for
    free (the inclusive scan's last element), which is what makes chunked
    PREFILL possible: 1827 s of sequential token-scan on the 32k prefill
    cell collapses to one training-shaped forward (§Perf iteration 2).
    """
    d_in, H, P, N = _dims(cfg)
    Bt, S, E = x.shape
    Q = _chunk_len(cfg.ssm_chunk, S)
    nc = S // Q

    proj = jnp.einsum("bse,eo->bso", x, p["in_proj"])
    z, xc, Bm, Cm, dt = _split_proj(cfg, proj)
    xbc_raw = jnp.concatenate([xc, Bm, Cm], axis=-1)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xc, Bm, Cm = jnp.split(xbc, [d_in, d_in + N], axis=-1)

    xh = xc.reshape(Bt, nc, Q, H, P).astype(jnp.float32)
    Bm = Bm.reshape(Bt, nc, Q, N).astype(jnp.float32)
    Cm = Cm.reshape(Bt, nc, Q, N).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dt = dt.reshape(Bt, nc, Q, H)
    A = -jnp.exp(p["A_log"])  # [H], negative

    dA = dt * A  # [b, c, q, h]
    cs = jnp.cumsum(dA, axis=2)  # within-chunk cumulative decay

    # ---- intra-chunk (quadratic within Q) ----
    Lmat = jnp.exp(cs[:, :, :, None, :] - cs[:, :, None, :, :])  # [b,c,i,j,h]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(tri[None, None, :, :, None], Lmat, 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cm, Bm)  # single group
    W = scores[..., None] * Lmat * dt[:, :, None, :, :]  # [b,c,i,j,h]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", W, xh)

    # ---- chunk summaries + inter-chunk associative combine ----
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)  # [b,c,q,h]
    S_chunk = jnp.einsum("bcqn,bcqh,bcqhp->bchnp",
                         Bm, dt * decay_to_end, xh)
    chunk_decay = jnp.exp(cs[:, :, -1, :])  # [b,c,h]

    def combine(a, b):
        d1, s1 = a
        d2, s2 = b
        return d1 * d2, s2 + d2[..., None, None] * s1

    dec, states = jax.lax.associative_scan(
        combine, (chunk_decay, S_chunk), axis=1)
    # exclusive: state entering chunk c
    prev = jnp.concatenate(
        [jnp.zeros_like(states[:, :1]), states[:, :-1]], axis=1)

    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                         Cm, jnp.exp(cs), prev)

    y = (y_intra + y_inter).reshape(Bt, S, H, P)
    y = y + p["D"][None, None, :, None] * xc.reshape(Bt, S, H, P).astype(jnp.float32)
    y = y.reshape(Bt, S, d_in).astype(x.dtype)

    y = y * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])

    if not return_state:
        return out, None
    final_ssm = states[:, -1]  # [Bt, H, N, P] — last chunk's inclusive state
    W = cfg.ssm_conv
    padded = jnp.pad(xbc_raw, ((0, 0), (max(W - 1 - S, 0), 0), (0, 0)))
    conv_state = padded[:, padded.shape[1] - (W - 1):, :].astype(cfg.dtype)
    return out, {"conv": conv_state, "ssm": final_ssm}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_ssm_state(cfg: ModelConfig, batch: int, layers: int):
    d_in, H, P, N = _dims(cfg)
    conv_ch = d_in + 2 * N
    return {
        "conv": jnp.zeros((layers, batch, cfg.ssm_conv - 1, conv_ch),
                          cfg.dtype),
        "ssm": jnp.zeros((layers, batch, H, N, P), jnp.float32),
    }


def ssm_decode(cfg: ModelConfig, p, x, state):
    """One token. x [Bt,1,E]; state {conv [Bt,W-1,Ch], ssm [Bt,H,N,P]}."""
    d_in, H, P, N = _dims(cfg)
    Bt = x.shape[0]

    proj = jnp.einsum("bse,eo->bso", x, p["in_proj"])[:, 0]
    z, xc, Bm, Cm, dt = _split_proj(cfg, proj)

    xbc_new = jnp.concatenate([xc, Bm, Cm], axis=-1)  # [Bt, Ch]
    window = jnp.concatenate([state["conv"], xbc_new[:, None]], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]

    xc, Bm, Cm = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    xh = xc.reshape(Bt, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [Bt, H]
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)  # [Bt, H]

    ssm = state["ssm"] * dA[..., None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", Bm, dt, xh)
    y = jnp.einsum("bn,bhnp->bhp", Cm, ssm) + p["D"][None, :, None] * xh
    y = y.reshape(Bt, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    out = jnp.einsum("bd,de->be", y, p["out_proj"])[:, None]
    return out, {"conv": new_conv.astype(state["conv"].dtype), "ssm": ssm}
