"""GQA attention: training (full-sequence) and decode (KV cache) paths.

Options cover the assigned archs: QKV bias (qwen), attention/logit softcaps
and local+global alternation (gemma2), cross-attention (whisper decoder),
int8-quantized KV caches (serving), and sequence-sharded caches merged with
the flash-decode combiner (see kernels/flash_decode.py for the fused kernel;
the jnp path here is what the multi-pod dry-run lowers).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.layers import apply_rope, rope_table

NEG_INF = -1e30


def init_attn(rng, cfg: ModelConfig, *, cross: bool = False):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(rng, 4)
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, qd)) * s).astype(cfg.dtype),
        "wk": (jax.random.normal(ks[1], (d, kvd)) * s).astype(cfg.dtype),
        "wv": (jax.random.normal(ks[2], (d, kvd)) * s).astype(cfg.dtype),
        "wo": (jax.random.normal(ks[3], (qd, d)) * qd ** -0.5).astype(cfg.dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((qd,), cfg.dtype)
        p["bk"] = jnp.zeros((kvd,), cfg.dtype)
        p["bv"] = jnp.zeros((kvd,), cfg.dtype)
    return p


def _project_q(cfg, p, x):
    q = jnp.einsum("...d,dh->...h", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    return q.reshape(x.shape[:-1] + (cfg.num_heads, cfg.hd))


def _project_kv(cfg, p, x):
    k = jnp.einsum("...d,dh->...h", x, p["wk"])
    v = jnp.einsum("...d,dh->...h", x, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    shp = x.shape[:-1] + (cfg.num_kv_heads, cfg.hd)
    return k.reshape(shp), v.reshape(shp)


def _softcap(logits, cap):
    if cap is None:
        return logits
    return jnp.tanh(logits / cap) * cap


def _gqa_logits(q, k):
    """q [B,S,H,D], k [B,T,Kv,D] -> [B,Kv,G,S,T] (native GQA 5D layout).

    Staying 5D until after the T contraction avoids reshapes of sharded
    attention weights — the reshape is what pushes GSPMD into its
    replicate-and-repartition fallback on long sequences.
    """
    B, S, H, D = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qg = q.reshape(B, S, Kv, G, D)
    return jnp.einsum("bskgd,btkd->bkgst", qg, k)


def _gqa_out(w, v):
    """w [B,Kv,G,S,T], v [B,T,Kv,D] -> [B,S,H,D]."""
    B, Kv, G, S, T = w.shape
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, Kv * G, v.shape[3])


#: sequences longer than this use query-chunked attention automatically
#: (the [B,H,S,S] logits tensor would not fit HBM at 32k+).
CHUNK_THRESHOLD = 8192
QUERY_CHUNK = 1024


def _attend(cfg, q, k, v, *, causal, window, q_offset, kv_x_is_none, T):
    """Attention for a (possibly chunked) query block. q [B,Sq,H,D]."""
    from repro.distributed.act_sharding import attn_weights, batch_major

    Sq = q.shape[1]
    logits = _gqa_logits(q, k).astype(jnp.float32) * (cfg.hd ** -0.5)
    logits = attn_weights(logits)  # pin batch/head/query sharding
    logits = _softcap(logits, cfg.attn_softcap)
    if causal and kv_x_is_none:
        i = q_offset + jnp.arange(Sq)[:, None]
        j = jnp.arange(T)[None, :]
        mask = j <= i
        if window is not None:
            # window may be a traced per-layer int32; 0 means global
            w = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window), T)
            mask &= j > i - w
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    w = attn_weights(w)
    return batch_major(_gqa_out(w, v))


def attn_train(
    cfg: ModelConfig,
    p,
    x,
    *,
    positions=None,
    causal: bool = True,
    window: int | None = None,
    rope: bool = True,
    kv_x=None,  # cross-attention source (whisper decoder)
    query_chunk: int | None = None,
):
    """Full-sequence attention. x [B,S,E] -> [B,S,E].

    Long sequences are processed in query chunks (flash-attention pattern —
    each chunk folds the full KV via softmax; the [S,S] logits matrix is
    never materialized).  This is the training-side analogue of the
    flash-decode combiner.
    """
    from repro.distributed.act_sharding import heads_even, seq_major

    B, S, E = x.shape
    q = _project_q(cfg, p, x)
    src = x if kv_x is None else kv_x
    k, v = _project_kv(cfg, p, src)
    T = k.shape[1]

    if not heads_even(cfg.num_kv_heads):
        # sequence parallelism: uneven head counts (40 over 16) cannot carry
        # the model axis, so the query SEQUENCE does (Megatron-SP pattern);
        # K/V are gathered (GQA keeps them small)
        q = seq_major(q, axis=1)

    if rope and kv_x is None:
        pos = positions if positions is not None else jnp.arange(S)
        cos, sin = rope_table(pos, cfg.hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    if query_chunk is None and S > CHUNK_THRESHOLD:
        query_chunk = QUERY_CHUNK

    if query_chunk is None or S <= query_chunk:
        out = _attend(cfg, q, k, v, causal=causal, window=window,
                      q_offset=0, kv_x_is_none=kv_x is None, T=T)
    else:
        assert S % query_chunk == 0, (S, query_chunk)
        nq = S // query_chunk
        qc = q.reshape(B, nq, query_chunk, cfg.num_heads, cfg.hd)
        qc = jnp.moveaxis(qc, 1, 0)  # [nq, B, Qc, H, D]

        def body(_, args):
            qb, off = args
            o = _attend(cfg, qb, k, v, causal=causal, window=window,
                        q_offset=off, kv_x_is_none=kv_x is None, T=T)
            return None, o

        offsets = jnp.arange(nq) * query_chunk
        _, oc = jax.lax.scan(body, None, (qc, offsets))
        out = jnp.moveaxis(oc, 0, 1).reshape(B, S, cfg.num_heads, cfg.hd)

    return jnp.einsum("...h,hd->...d", out.reshape(B, S, -1), p["wo"])


# ---------------------------------------------------------------------------
# KV cache (bf16 or int8 with per-position-head scales)
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                  kv_dtype=None, layers: int | None = None):
    """Stacked-layer cache pytree: [L, B, S, Kv, D] (+ scales when int8)."""
    L = layers if layers is not None else cfg.num_layers
    kv_dtype = kv_dtype or cfg.dtype
    shape = (L, batch, max_len, cfg.num_kv_heads, cfg.hd)
    cache = {
        "k": jnp.zeros(shape, kv_dtype),
        "v": jnp.zeros(shape, kv_dtype),
    }
    if kv_dtype == jnp.int8:
        cache["k_scale"] = jnp.zeros(shape[:-1] + (1,), jnp.float32)
        cache["v_scale"] = jnp.zeros(shape[:-1] + (1,), jnp.float32)
    return cache


def _quantize(x):
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True).astype(jnp.float32) / 127.0
    s = jnp.maximum(s, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127).astype(jnp.int8)
    return q, s


def _dequant(q, s, dtype):
    return (q.astype(jnp.float32) * s).astype(dtype)


def cache_update(layer_cache, k_new, v_new, pos):
    """Write one token's K/V at position ``pos``. k_new [B,1,Kv,D]."""
    quant = layer_cache["k"].dtype == jnp.int8
    if quant:
        kq, ks = _quantize(k_new)
        vq, vs = _quantize(v_new)
        out = dict(layer_cache)
        out["k"] = jax.lax.dynamic_update_slice_in_dim(layer_cache["k"], kq, pos, 1)
        out["v"] = jax.lax.dynamic_update_slice_in_dim(layer_cache["v"], vq, pos, 1)
        out["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
            layer_cache["k_scale"], ks, pos, 1)
        out["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
            layer_cache["v_scale"], vs, pos, 1)
        return out
    return {
        "k": jax.lax.dynamic_update_slice_in_dim(
            layer_cache["k"], k_new.astype(layer_cache["k"].dtype), pos, 1),
        "v": jax.lax.dynamic_update_slice_in_dim(
            layer_cache["v"], v_new.astype(layer_cache["v"].dtype), pos, 1),
    }


def cache_kv(layer_cache, dtype):
    if layer_cache["k"].dtype == jnp.int8:
        return (_dequant(layer_cache["k"], layer_cache["k_scale"], dtype),
                _dequant(layer_cache["v"], layer_cache["v_scale"], dtype))
    return layer_cache["k"].astype(dtype), layer_cache["v"].astype(dtype)


def attn_decode(
    cfg: ModelConfig,
    p,
    x,  # [B, 1, E] current token hidden
    layer_cache,
    pos,  # scalar int32: next position index
    *,
    window: int | None = None,
    rope: bool = True,
    cross_kv=None,  # (k, v) precomputed encoder cross KV
    deferred_write: bool = False,
):
    """One decode step.

    deferred_write=False: update the cache in place, return (out, cache).
    deferred_write=True:  do NOT touch the cache — attend over the cache's
    first ``pos`` positions PLUS the in-register current-token K/V, and
    return (out, (k_new, v_new)).  Under scan-over-layers this avoids
    double-buffering the whole cache as scan xs/ys: the caller stacks the
    per-layer (k,v) and writes ONE token column for all layers afterwards.
    """
    B = x.shape[0]
    q = _project_q(cfg, p, x)  # [B,1,H,D]

    if cross_kv is None:
        k_new, v_new = _project_kv(cfg, p, x)
        if rope:
            cos, sin = rope_table(pos[None], cfg.hd, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k_new = apply_rope(k_new, cos, sin)
        if not deferred_write:
            layer_cache = cache_update(layer_cache, k_new, v_new, pos)
        k, v = cache_kv(layer_cache, x.dtype)
        T = k.shape[1]
        j = jnp.arange(T)
        valid = j <= pos if not deferred_write else j < pos
        if window is not None:
            w = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window), T)
            valid &= j > pos - w
    else:
        k, v = cross_kv
        T = k.shape[1]
        valid = jnp.ones((T,), bool)

    from repro.distributed.act_sharding import attn_weights

    logits = _gqa_logits(q, k).astype(jnp.float32) * (cfg.hd ** -0.5)
    logits = attn_weights(logits)
    logits = _softcap(logits, cfg.attn_softcap)
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)

    if cross_kv is None and deferred_write:
        # current token's logit against its own (in-register) K
        self_logit = _gqa_logits(q, k_new.astype(x.dtype)).astype(
            jnp.float32) * (cfg.hd ** -0.5)
        self_logit = _softcap(self_logit, cfg.attn_softcap)
        logits = jnp.concatenate([logits, self_logit], axis=-1)
        w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = (_gqa_out(w[..., :T], v)
               + _gqa_out(w[..., T:], v_new.astype(x.dtype)))
        out = out.reshape(B, 1, -1)
        return (jnp.einsum("...h,hd->...d", out, p["wo"]),
                (k_new, v_new))

    w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = _gqa_out(w, v).reshape(B, 1, -1)
    return jnp.einsum("...h,hd->...d", out, p["wo"]), layer_cache


def stacked_cache_write(cache, k_stack, v_stack, pos):
    """Write one token column for ALL layers: k_stack [L,B,1,Kv,D].

    One dynamic-update-slice on the donated buffer — aliasable in place.
    """
    quant = cache["k"].dtype == jnp.int8
    if quant:
        kq, ks = _quantize(k_stack)
        vq, vs = _quantize(v_stack)
        return {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, pos, 2),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, pos, 2),
            "k_scale": jax.lax.dynamic_update_slice_in_dim(
                cache["k_scale"], ks, pos, 2),
            "v_scale": jax.lax.dynamic_update_slice_in_dim(
                cache["v_scale"], vs, pos, 2),
        }
    return {
        "k": jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_stack.astype(cache["k"].dtype), pos, 2),
        "v": jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_stack.astype(cache["v"].dtype), pos, 2),
    }
