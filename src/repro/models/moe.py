"""Mixture-of-Experts FFN with the paper's two execution flows.

Token→expert routing *is* MapReduce: map emits (expert_id, token_hidden),
the shuffle groups by expert, the expert FFN is applied per group, and the
combine-back is a per-token weighted-sum reduction of top-k expert outputs.

Two combine-back modes, mirroring core/collector.py:
* ``materialize`` (reduce flow): gather per-(token, k) expert outputs into an
  explicit ``[N, k, E]`` buffer, then reduce over k.  O(N·k·E) intermediate.
* ``combiner`` (combine flow): scatter-add ``gate · expert_out`` directly
  into the token output holder (``.at[].add`` — the scatter-combine monoid).
  No intermediate buffer; the reduction happens at emit time.

Dispatch uses sort-based grouping with static capacity (GShard-style drops on
overflow), which keeps every shape static for pjit.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.layers import init_swiglu, swiglu


def init_moe(rng, cfg: ModelConfig):
    kr, ke = jax.random.split(rng)
    X, E, F = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(ke, 3)
    s_in, s_out = E ** -0.5, F ** -0.5
    return {
        "router": (jax.random.normal(kr, (E, X)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[0], (X, E, F)) * s_in).astype(cfg.dtype),
        "w_up": (jax.random.normal(ks[1], (X, E, F)) * s_in).astype(cfg.dtype),
        "w_down": (jax.random.normal(ks[2], (X, F, E)) * s_out).astype(cfg.dtype),
    }


def _expert_ffn(p, x, act):
    """x [X, C, E] -> [X, C, E]; per-expert SwiGLU."""
    from repro.models.layers import _ACT

    g = _ACT[act](jnp.einsum("xce,xef->xcf", x, p["w_gate"]))
    u = jnp.einsum("xce,xef->xcf", x, p["w_up"])
    return jnp.einsum("xcf,xfe->xce", g * u, p["w_down"])


def moe_ffn(
    cfg: ModelConfig,
    p,
    x,  # [B, S, E]
    *,
    mode: str = "combiner",
    capacity_factor: float = 1.25,
    act: str = "silu",
    per_row: bool = True,
):
    """Returns (out [B,S,E], aux) where aux has the load-balancing loss.

    per_row=True (default) runs the dispatch independently per BATCH ROW —
    the distributed engine's map-side local combine applied to routing: each
    data shard sorts/dispatches only its own tokens, so the argsort and the
    dispatch gather/scatter never cross shards; the only cross-shard
    collective left is the expert-parallel partial-sum all-reduce.  The
    global-dispatch path (per_row=False) is kept as the baseline — its
    global argsort is what made llama4-scout prefill collective-bound in the
    baseline roofline (EXPERIMENTS.md §Perf iteration 1).
    """
    B, S, E = x.shape
    if per_row and B > 1:
        f = partial(_moe_tokens, cfg, p, mode=mode,
                    capacity_factor=capacity_factor, act=act)
        out, aux = jax.vmap(f)(x)
        return out.reshape(B, S, E).astype(x.dtype), jax.tree.map(
            lambda a: jnp.mean(a), aux)
    out, aux = _moe_tokens(cfg, p, x.reshape(B * S, E), mode=mode,
                           capacity_factor=capacity_factor, act=act)
    return out.reshape(B, S, E).astype(x.dtype), aux


def _moe_tokens(
    cfg: ModelConfig,
    p,
    tokens,  # [N, E]
    *,
    mode: str = "combiner",
    capacity_factor: float = 1.25,
    act: str = "silu",
):
    """Dispatch + expert FFN + combine-back over a flat token block."""
    N, E = tokens.shape
    X, K = cfg.num_experts, cfg.num_experts_per_tok

    logits = jnp.einsum("ne,ex->nx", tokens.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, K)  # [N, K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): X * Σ_x f_x · P_x
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        (idx[..., None] == jnp.arange(X)).any(axis=1).astype(jnp.float32),
        axis=0)
    aux = {"load_balance_loss": X * jnp.sum(me * ce)}

    # ---- sort-based dispatch with static capacity ----
    C = int(max(1, -(-N * K // X) * capacity_factor))
    flat_x = idx.reshape(-1)  # [N*K] expert id per assignment
    order = jnp.argsort(flat_x)  # assignments grouped by expert
    sorted_x = flat_x[order]
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(jnp.bincount(flat_x, length=X)).astype(jnp.int32)[:-1]])
    rank = jnp.arange(N * K, dtype=jnp.int32) - starts[sorted_x]
    keep = rank < C
    slot = jnp.where(keep, sorted_x * C + rank, X * C)  # overflow -> dropped

    # slot -> source assignment (sentinel N*K = padding)
    src = jnp.full((X * C,), N * K, jnp.int32).at[slot].set(order, mode="drop")
    src_tok = jnp.minimum(src, N * K - 1) // K
    src_valid = src < N * K

    expert_in = jnp.where(
        src_valid[:, None], tokens[src_tok], 0).reshape(X, C, E)
    expert_out = _expert_ffn(p, expert_in, act).reshape(X * C, E)

    gate_of_src = gates.reshape(-1)[jnp.minimum(src, N * K - 1)]
    gate_of_src = jnp.where(src_valid, gate_of_src, 0.0)

    if mode == "combiner":
        # combine flow: scatter-add weighted outputs into the token holder
        out = jnp.zeros((N, E), expert_out.dtype).at[src_tok].add(
            expert_out * gate_of_src[:, None].astype(expert_out.dtype),
            mode="drop")
    elif mode == "materialize":
        # reduce flow: materialize [N, K, E] per-assignment outputs, reduce
        out_sorted = jnp.where(keep[:, None],
                               expert_out[jnp.minimum(slot, X * C - 1)], 0)
        assign_out = jnp.zeros((N * K, E), expert_out.dtype).at[order].set(
            out_sorted)
        per_k = assign_out.reshape(N, K, E)  # the materialized buffer
        out = jnp.sum(per_k * gates[..., None].astype(per_k.dtype), axis=1)
    else:
        raise ValueError(mode)

    return out, aux


def moe_ffn_decode(cfg: ModelConfig, p, x, *, act: str = "silu"):
    """Decode-time MoE for [B, 1, E].

    Uses the same capacity dispatch as training: gathering per-token expert
    weight slices (``w[idx]``) would materialize ``[B, K, E, F]`` — ~10 GiB
    for llama4-scout at batch 128 — while dispatch moves only activations
    and keeps the expert weights sharded in place.
    """
    out, _ = moe_ffn(cfg, p, x, mode="combiner", capacity_factor=2.0,
                     act=act)
    return out
