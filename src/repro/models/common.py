"""Model configuration + sharding helpers shared by all architectures."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | ssm | moe | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None  # default d_model // num_heads
    qkv_bias: bool = False
    # gemma2-style options
    logit_softcap: float | None = None
    attn_softcap: float | None = None
    sliding_window: int | None = None
    local_global_alternate: bool = False
    post_norms: bool = False  # gemma2 post-attn/post-ffn norms
    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # hybrid (zamba2): a shared attention block every k layers
    hybrid_attn_every: int = 6
    # encoder-decoder (whisper)
    enc_layers: int = 0
    dec_len: int = 448  # whisper max target positions
    # modality frontends are stubs: input_specs provides embeddings
    frontend: str | None = None  # None | "audio" | "vision"
    num_patches: int = 256  # vlm prefix length
    # numerics
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    act: str = "silu"
    dtype: Any = jnp.bfloat16
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.hd

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """May run the 500k-context decode shape (see DESIGN.md skips)."""
        return self.family in ("ssm", "hybrid")

    # --- SSM derived dims ---
    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        base = dict(
            num_layers=2,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            d_ff=128,
            vocab_size=128,
            head_dim=16,
            sliding_window=self.sliding_window and 32,
            num_experts=min(self.num_experts, 4),
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16,
            ssm_chunk=8,
            hybrid_attn_every=2,
            enc_layers=2 if self.enc_layers else 0,
            dec_len=16,
            num_patches=4,
            dtype=jnp.float32,
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------


def _fits(dim: int, mesh, axes) -> bool:
    if axes is None:
        return True
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return dim % n == 0


def pick(mesh, dim: int, *candidates):
    """First sharding candidate (axis name / tuple / None) dividing dim."""
    for c in candidates:
        if _fits(dim, mesh, c):
            return c
    return None


def dp_axes(mesh) -> tuple:
    """Data-parallel axes: ('pod','data') on the multi-pod mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def param_spec(mesh, shape: tuple, kinds: tuple) -> P:
    """Build a PartitionSpec for a parameter.

    ``kinds[i]`` ∈ {"model", "fsdp", "expert", None}: preferred role of dim i.
    "model": tensor-parallel; "fsdp": ZeRO-3 over the data axes; "expert":
    expert-parallel over 'model'.  Falls back to replication when the dim is
    not divisible.
    """
    dp = dp_axes(mesh)
    spec = []
    used_model = False
    for dim, kind in zip(shape, kinds):
        if kind == "model" and not used_model:
            c = pick(mesh, dim, "model")
            spec.append(c)
            used_model = c is not None
        elif kind == "expert" and not used_model:
            c = pick(mesh, dim, "model")
            spec.append(c)
            used_model = c is not None
        elif kind == "fsdp":
            spec.append(pick(mesh, dim, dp, dp[-1] if dp else None))
        else:
            spec.append(None)
    return P(*spec)
