"""Shared layers: norms, rotary embeddings, MLPs, embedding/unembedding."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int):
    return {"scale": jnp.zeros((d,), jnp.float32)}  # (1 + scale) convention


def rmsnorm(p, x, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"])
    return y.astype(x.dtype)


def init_layernorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (half-rotation convention)
# ---------------------------------------------------------------------------


def rope_table(positions: jax.Array, hd: int, theta: float):
    """positions [S] -> (cos, sin) [S, hd/2] in f32."""
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array):
    """x [..., S, H, hd]; cos/sin [S, hd/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    shape = (1,) * (x.ndim - 3) + (cos.shape[0], 1, half)
    c = cos.reshape(shape).astype(x.dtype)
    s = sin.reshape(shape).astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

_ACT = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def init_swiglu(rng, d: int, f: int, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in = d ** -0.5
    s_out = f ** -0.5
    return {
        "w_gate": (jax.random.normal(k1, (d, f)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k2, (d, f)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (f, d)) * s_out).astype(dtype),
    }


def swiglu(p, x, act: str = "silu"):
    g = _ACT[act](jnp.einsum("...d,df->...f", x, p["w_gate"]))
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    return jnp.einsum("...f,fd->...d", g * u, p["w_down"])


def init_mlp(rng, d: int, f: int, dtype):
    k1, k2 = jax.random.split(rng)
    return {
        "w1": (jax.random.normal(k1, (d, f)) * d ** -0.5).astype(dtype),
        "b1": jnp.zeros((f,), dtype),
        "w2": (jax.random.normal(k2, (f, d)) * f ** -0.5).astype(dtype),
        "b2": jnp.zeros((d,), dtype),
    }


def mlp(p, x, act: str = "gelu"):
    h = _ACT[act](jnp.einsum("...d,df->...f", x, p["w1"]) + p["b1"])
    return jnp.einsum("...f,fd->...d", h, p["w2"]) + p["b2"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embed(rng, vocab: int, d: int, dtype):
    return {"table": (jax.random.normal(rng, (vocab, d)) * d ** -0.5
                      ).astype(dtype)}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p_embed, p_head, x, *, tie: bool):
    w = p_embed["table"] if tie else p_head["w"]
    return jnp.einsum("...d,vd->...v", x, w)


def init_unembed(rng, vocab: int, d: int, dtype, *, tie: bool):
    if tie:
        return {}
    return {"w": (jax.random.normal(rng, (vocab, d)) * d ** -0.5).astype(dtype)}
