"""Model registry: family -> implementation module, plus a uniform facade."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.models import hybrid, mamba, transformer, whisper
from repro.models.common import ModelConfig

_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": mamba,
    "hybrid": hybrid,
    "audio": whisper,
}


@dataclasses.dataclass(frozen=True)
class Model:
    """Uniform facade over the family modules."""

    cfg: ModelConfig
    module: Any

    def init_params(self, rng):
        return self.module.init_params(self.cfg, rng)

    def abstract_params(self, rng=None):
        """Param avals without allocation (dry-run path)."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(lambda r: self.module.init_params(self.cfg, r),
                              rng)

    def forward(self, params, batch, **kw):
        return self.module.forward(self.cfg, params, batch, **kw)

    def logits_of_hidden(self, params, hidden):
        return self.module.logits_of_hidden(self.cfg, params, hidden)

    def unembed_matrix(self, params):
        return self.module.unembed_matrix(self.cfg, params)

    def init_decode_state(self, batch: int, max_len: int, *, kv_dtype=None):
        return self.module.init_decode_state(self.cfg, batch, max_len,
                                             kv_dtype=kv_dtype)

    def decode_step(self, params, state, tokens):
        return self.module.decode_step(self.cfg, params, state, tokens)

    def prefill(self, params, batch, state, **kw):
        return self.module.prefill(self.cfg, params, batch, state, **kw)

    @property
    def logit_softcap(self):
        return self.cfg.logit_softcap


def get_model(cfg: ModelConfig) -> Model:
    if cfg.family not in _FAMILY_MODULES:
        raise KeyError(f"unknown family {cfg.family}")
    return Model(cfg, _FAMILY_MODULES[cfg.family])


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def active_param_count(cfg: ModelConfig, params) -> int:
    """Active params per token (MoE: top-k of the expert pool)."""
    total = param_count(params)
    if not cfg.num_experts:
        return total
    expert = 0

    def walk(p, in_moe=False):
        nonlocal expert
        if isinstance(p, dict):
            for k, v in p.items():
                if in_moe and k in ("w_gate", "w_up", "w_down"):
                    expert += sum(x.size for x in jax.tree.leaves(v))
                else:
                    walk(v, in_moe or k == "moe")

    walk(params)
    frac = cfg.num_experts_per_tok / cfg.num_experts
    return int(total - expert * (1 - frac))
