"""Whisper-style encoder-decoder backbone — the `audio` family.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings [B, S_frames, d_model].  Encoder: bidirectional
MHA + GELU MLP with LayerNorm (pre-norm).  Decoder: causal self-attention +
cross-attention over encoder output, learned positions, max ``dec_len``
target positions.

Shape mapping for the assigned decode cells (DESIGN.md): ``seq_len`` is the
ENCODER frame count; decode steps attend to a self-KV of up to ``dec_len``
and cross-attend to all ``seq_len`` encoder states.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import ModelConfig
from repro.models.layers import (embed, init_embed, init_layernorm, init_mlp,
                                 init_unembed, layernorm, mlp)


def _sinusoid(S: int, d: int):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_enc_layer(rng, cfg: ModelConfig):
    ka, kf = jax.random.split(rng)
    return {
        "ln_attn": init_layernorm(cfg.d_model),
        "attn": attn.init_attn(ka, cfg),
        "ln_ffn": init_layernorm(cfg.d_model),
        "ffn": init_mlp(kf, cfg.d_model, cfg.d_ff, cfg.dtype),
    }


def init_dec_layer(rng, cfg: ModelConfig):
    ka, kc, kf = jax.random.split(rng, 3)
    return {
        "ln_self": init_layernorm(cfg.d_model),
        "self": attn.init_attn(ka, cfg),
        "ln_cross": init_layernorm(cfg.d_model),
        "cross": attn.init_attn(kc, cfg, cross=True),
        "ln_ffn": init_layernorm(cfg.d_model),
        "ffn": init_mlp(kf, cfg.d_model, cfg.d_ff, cfg.dtype),
    }


def init_params(cfg: ModelConfig, rng):
    ke, kenc, kdec, kp, ku = jax.random.split(rng, 5)
    L_enc = cfg.enc_layers or cfg.num_layers
    enc = jax.vmap(partial(init_enc_layer, cfg=cfg))(
        jax.random.split(kenc, L_enc))
    dec = jax.vmap(partial(init_dec_layer, cfg=cfg))(
        jax.random.split(kdec, cfg.num_layers))
    return {
        "embed": init_embed(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "pos_dec": (jax.random.normal(kp, (cfg.dec_len, cfg.d_model)) *
                    0.01).astype(cfg.dtype),
        "enc_layers": enc,
        "ln_enc_f": init_layernorm(cfg.d_model),
        "dec_layers": dec,
        "ln_dec_f": init_layernorm(cfg.d_model),
        "head": init_unembed(ku, cfg.vocab_size, cfg.d_model, cfg.dtype,
                             tie=cfg.tie_embeddings),
    }


def encode(cfg: ModelConfig, params, frames, *, remat: bool = True):
    """frames [B, S, E] (stub frontend output) -> [B, S, E]."""
    x = frames.astype(cfg.dtype) + _sinusoid(
        frames.shape[1], cfg.d_model).astype(cfg.dtype)

    def body(x, p):
        def block(p, x):
            h = layernorm(p["ln_attn"], x, cfg.norm_eps)
            x = x + attn.attn_train(cfg, p["attn"], h, causal=False,
                                    rope=False)
            h = layernorm(p["ln_ffn"], x, cfg.norm_eps)
            return x + mlp(p["ffn"], h, "gelu")
        f = jax.checkpoint(block) if remat else block
        return f(p, x), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return layernorm(params["ln_enc_f"], x, cfg.norm_eps)


def forward(cfg: ModelConfig, params, batch, *, remat: bool = True, **_):
    """batch: {"frames": [B,Sf,E], "tokens": [B,St]} -> decoder hidden."""
    enc_out = encode(cfg, params, batch["frames"], remat=remat)
    tokens = batch["tokens"]
    St = tokens.shape[1]
    x = embed(params["embed"], tokens) + params["pos_dec"][:St]

    def body(x, p):
        def block(p, x):
            h = layernorm(p["ln_self"], x, cfg.norm_eps)
            x = x + attn.attn_train(cfg, p["self"], h, rope=False)
            h = layernorm(p["ln_cross"], x, cfg.norm_eps)
            x = x + attn.attn_train(cfg, p["cross"], h, kv_x=enc_out,
                                    rope=False)
            h = layernorm(p["ln_ffn"], x, cfg.norm_eps)
            return x + mlp(p["ffn"], h, "gelu")
        f = jax.checkpoint(block) if remat else block
        return f(p, x), None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = layernorm(params["ln_dec_f"], x, cfg.norm_eps)
    return x, {"load_balance_loss": jnp.float32(0.0)}


def unembed_matrix(cfg, params):
    return (params["embed"]["table"] if cfg.tie_embeddings
            else params["head"]["w"])


def logits_of_hidden(cfg, params, hidden):
    return jnp.einsum("...e,ve->...v", hidden,
                      unembed_matrix(cfg, params)).astype(jnp.float32)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, *,
                      kv_dtype=None):
    """Self-KV capped at dec_len; cross-KV [L,B,Sf,Kv,D] filled at prefill."""
    L = cfg.num_layers
    self_len = min(max_len, cfg.dec_len)
    return {
        "cache": attn.init_kv_cache(cfg, batch, self_len, kv_dtype=kv_dtype),
        "cross_k": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, cfg.hd),
                             cfg.dtype),
        "cross_v": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, cfg.hd),
                             cfg.dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(cfg: ModelConfig, params, batch, state, **_):
    """Encode frames, precompute per-layer cross KV, decode the BOS token."""
    enc_out = encode(cfg, params, batch["frames"], remat=False)

    def cross_kv(p):
        return attn._project_kv(cfg, p["cross"], enc_out)

    ck, cv = jax.vmap(cross_kv)(params["dec_layers"])
    state = dict(state)
    state["cross_k"], state["cross_v"] = ck, cv
    bos = batch["tokens"][:, 0] if "tokens" in batch else jnp.zeros(
        (enc_out.shape[0],), jnp.int32)
    return decode_step(cfg, params, state, bos)


def decode_step(cfg: ModelConfig, params, state, tokens):
    pos = state["pos"]
    x = embed(params["embed"], tokens[:, None])
    x = x + jax.lax.dynamic_slice_in_dim(params["pos_dec"],
                                         jnp.minimum(pos, cfg.dec_len - 1),
                                         1, axis=0)

    pos_c = jnp.minimum(pos, cfg.dec_len - 1)

    def body(x, layer):
        p, cache_l, ck, cv = layer
        h = layernorm(p["ln_self"], x, cfg.norm_eps)
        a, kv_new = attn.attn_decode(cfg, p["self"], h, cache_l, pos_c,
                                     rope=False, deferred_write=True)
        x = x + a
        h = layernorm(p["ln_cross"], x, cfg.norm_eps)
        c, _ = attn.attn_decode(cfg, p["cross"], h, cache_l, pos,
                                cross_kv=(ck, cv), rope=False)
        x = x + c
        h = layernorm(p["ln_ffn"], x, cfg.norm_eps)
        return x + mlp(p["ffn"], h, "gelu"), kv_new

    x, (k_stack, v_stack) = jax.lax.scan(
        body, x, (params["dec_layers"], state["cache"],
                  state["cross_k"], state["cross_v"]))
    x = layernorm(params["ln_dec_f"], x, cfg.norm_eps)
    logits = logits_of_hidden(cfg, params, x[:, 0])
    new_state = dict(state)
    new_state["cache"] = attn.stacked_cache_write(state["cache"], k_stack,
                                                  v_stack, pos_c)
    new_state["pos"] = pos + 1
    return logits, new_state
