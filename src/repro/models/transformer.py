"""Decoder-only transformer LM covering the dense, moe and vlm families.

Variants driven by ModelConfig:
  * GQA with optional QKV bias (qwen), attn/logit softcaps + local/global
    alternation + post-norms (gemma2), RoPE everywhere.
  * MoE FFN (llama4-scout 16e top-1, qwen3 128e top-8) with combiner or
    materialize combine-back (models/moe.py).
  * VLM (internvl2): the ViT frontend is a stub — precomputed patch
    embeddings are concatenated in front of the text embeddings.

Layers are stacked and scanned (``lax.scan`` over stacked params) so the HLO
stays one-layer-sized for the multi-pod dry-run; remat is applied per layer.

Training forward returns final *hidden* states (losses handle the unembed
with the vocab-parallel logsumexp combiner — the [B,S,V] logits tensor is
never materialized for the big-vocab archs).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.common import ModelConfig
from repro.models.layers import (embed, init_embed, init_rmsnorm,
                                 init_swiglu, init_unembed, rmsnorm, swiglu)


def _layer_windows(cfg: ModelConfig):
    """Per-layer sliding window sizes (0 = global). gemma2 alternates."""
    if cfg.sliding_window and cfg.local_global_alternate:
        return [cfg.sliding_window if i % 2 == 0 else 0
                for i in range(cfg.num_layers)]
    if cfg.sliding_window:
        return [cfg.sliding_window] * cfg.num_layers
    return [0] * cfg.num_layers


def init_layer(rng, cfg: ModelConfig):
    ka, kf = jax.random.split(rng)
    p = {
        "ln_attn": init_rmsnorm(cfg.d_model),
        "attn": attn.init_attn(ka, cfg),
        "ln_ffn": init_rmsnorm(cfg.d_model),
    }
    if cfg.num_experts:
        p["moe"] = moe_mod.init_moe(kf, cfg)
    else:
        p["ffn"] = init_swiglu(kf, cfg.d_model, cfg.d_ff, cfg.dtype)
    if cfg.post_norms:
        p["ln_post_attn"] = init_rmsnorm(cfg.d_model)
        p["ln_post_ffn"] = init_rmsnorm(cfg.d_model)
    return p


def init_params(cfg: ModelConfig, rng):
    ke, kl, ku = jax.random.split(rng, 3)
    layer_keys = jax.random.split(kl, cfg.num_layers)
    layers = jax.vmap(partial(init_layer, cfg=cfg))(layer_keys)
    return {
        "embed": init_embed(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "layers": layers,  # stacked [L, ...]
        "ln_f": init_rmsnorm(cfg.d_model),
        "head": init_unembed(ku, cfg.vocab_size, cfg.d_model, cfg.dtype,
                             tie=cfg.tie_embeddings),
    }


def _block_train(cfg, p, x, window, *, moe_mode):
    h = rmsnorm(p["ln_attn"], x, cfg.norm_eps)
    a = attn.attn_train(cfg, p["attn"], h, window=window)  # traced; 0=global
    if cfg.post_norms:
        a = rmsnorm(p["ln_post_attn"], a, cfg.norm_eps)
    x = x + a
    h = rmsnorm(p["ln_ffn"], x, cfg.norm_eps)
    if cfg.num_experts:
        f, aux = moe_mod.moe_ffn(cfg, p["moe"], h, mode=moe_mode)
    else:
        f, aux = swiglu(p["ffn"], h, cfg.act), {"load_balance_loss": 0.0}
    if cfg.post_norms:
        f = rmsnorm(p["ln_post_ffn"], f, cfg.norm_eps)
    return x + f, aux["load_balance_loss"]


def forward(cfg: ModelConfig, params, batch, *, moe_mode: str = "combiner",
            remat: bool = True):
    """batch: {"tokens": [B,S]} (+ "patches": [B,Pn,E] for vlm).

    Returns (hidden [B,S,E], aux dict).
    """
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens)
    if cfg.family == "vlm":
        patches = batch["patches"].astype(x.dtype)  # stub frontend output
        x = jnp.concatenate([patches, x], axis=1)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    windows = jnp.asarray(_layer_windows(cfg), jnp.int32)

    def body(x, layer):
        p, window = layer
        f = partial(_block_train, cfg, moe_mode=moe_mode)
        if remat:
            f = jax.checkpoint(f)
        x, lb = f(p, x, window)
        return x, lb

    x, lbs = jax.lax.scan(body, x, (params["layers"], windows))
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return x, {"load_balance_loss": jnp.mean(lbs)}


def unembed_matrix(cfg: ModelConfig, params):
    """[V, E] output projection (tied or untied)."""
    return (params["embed"]["table"] if cfg.tie_embeddings
            else params["head"]["w"])


def logits_of_hidden(cfg: ModelConfig, params, hidden):
    w = unembed_matrix(cfg, params)
    logits = jnp.einsum("...e,ve->...v", hidden, w).astype(jnp.float32)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, *,
                      kv_dtype=None):
    return {
        "cache": attn.init_kv_cache(cfg, batch, max_len, kv_dtype=kv_dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(cfg: ModelConfig, params, state, tokens):
    """tokens [B] -> (logits [B,V], new state). One generated token.

    The cache is READ-ONLY inside the layer scan (deferred-write attention);
    all layers' new K/V are stacked and written as one token column after
    the scan — the cache buffer aliases in place instead of double-buffering
    through scan xs/ys (halves decode HBM residency).
    """
    pos = state["pos"]
    x = embed(params["embed"], tokens[:, None])
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    windows = jnp.asarray(_layer_windows(cfg), jnp.int32)

    def body(x, layer):
        p, cache_l, window = layer
        h = rmsnorm(p["ln_attn"], x, cfg.norm_eps)
        a, kv_new = attn.attn_decode(cfg, p["attn"], h, cache_l, pos,
                                     window=window, deferred_write=True)
        if cfg.post_norms:
            a = rmsnorm(p["ln_post_attn"], a, cfg.norm_eps)
        x = x + a
        h = rmsnorm(p["ln_ffn"], x, cfg.norm_eps)
        if cfg.num_experts:
            f = moe_mod.moe_ffn_decode(cfg, p["moe"], h)
        else:
            f = swiglu(p["ffn"], h, cfg.act)
        if cfg.post_norms:
            f = rmsnorm(p["ln_post_ffn"], f, cfg.norm_eps)
        return x + f, kv_new

    x, (k_stack, v_stack) = jax.lax.scan(
        body, x, (params["layers"], state["cache"], windows))
    new_cache = attn.stacked_cache_write(state["cache"], k_stack, v_stack,
                                         pos)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = logits_of_hidden(cfg, params, x[:, 0])
    return logits, {"cache": new_cache, "pos": pos + 1}


def prefill(cfg: ModelConfig, params, batch, state, *,
            moe_mode: str = "combiner"):
    """Teacher-forced prefill: run the train forward AND fill the KV cache.

    Returns (last-position logits [B,V], state).  The per-layer prompt K/V
    come out of the layer scan as stacked ys and BECOME the cache directly
    (padded to the cache window) — the zero-initialized input cache is dead
    and DCE'd, so only one cache-sized buffer ever lives.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(params["embed"], tokens)
    if cfg.family == "vlm":
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
        S = x.shape[1]
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    windows = jnp.asarray(_layer_windows(cfg), jnp.int32)
    from repro.models.layers import apply_rope, rope_table

    def body(x, layer):
        p, window = layer
        h = rmsnorm(p["ln_attn"], x, cfg.norm_eps)
        k, v = attn._project_kv(cfg, p["attn"], h)
        cos, sin = rope_table(jnp.arange(S), cfg.hd, cfg.rope_theta)
        k_r = apply_rope(k, cos, sin)
        a = attn.attn_train(cfg, p["attn"], h, window=window)
        if cfg.post_norms:
            a = rmsnorm(p["ln_post_attn"], a, cfg.norm_eps)
        x = x + a
        h = rmsnorm(p["ln_ffn"], x, cfg.norm_eps)
        if cfg.num_experts:
            f, _ = moe_mod.moe_ffn(cfg, p["moe"], h, mode=moe_mode)
        else:
            f = swiglu(p["ffn"], h, cfg.act)
        if cfg.post_norms:
            f = rmsnorm(p["ln_post_ffn"], f, cfg.norm_eps)
        return x + f, (k_r, v)

    x, (k_all, v_all) = jax.lax.scan(body, x, (params["layers"], windows))

    Smax = state["cache"]["k"].shape[2]
    pad = [(0, 0), (0, 0), (0, Smax - S), (0, 0), (0, 0)]
    quant = state["cache"]["k"].dtype == jnp.int8
    if quant:
        kq, ks = attn._quantize(k_all)
        vq, vs = attn._quantize(v_all)
        new_cache = {
            "k": jnp.pad(kq, pad), "v": jnp.pad(vq, pad),
            "k_scale": jnp.pad(ks, pad[:-1] + [(0, 0)]),
            "v_scale": jnp.pad(vs, pad[:-1] + [(0, 0)]),
        }
    else:
        dt = state["cache"]["k"].dtype
        new_cache = {"k": jnp.pad(k_all.astype(dt), pad),
                     "v": jnp.pad(v_all.astype(dt), pad)}

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = logits_of_hidden(cfg, params, x[:, -1])
    return logits, {"cache": new_cache, "pos": jnp.asarray(S, jnp.int32)}
