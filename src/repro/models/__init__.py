"""repro.models"""
