"""Mamba2 LM: pure SSM stack (attention-free) — the `ssm` family."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import ssm as ssm_mod
from repro.models.common import ModelConfig
from repro.models.layers import (embed, init_embed, init_rmsnorm,
                                 init_unembed, rmsnorm)


def init_params(cfg: ModelConfig, rng):
    ke, kl, ku = jax.random.split(rng, 3)
    layer_keys = jax.random.split(kl, cfg.num_layers)
    layers = jax.vmap(lambda k: {
        "ln": init_rmsnorm(cfg.d_model),
        "ssm": ssm_mod.init_ssm(k, cfg),
    })(layer_keys)
    return {
        "embed": init_embed(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "layers": layers,
        "ln_f": init_rmsnorm(cfg.d_model),
        "head": init_unembed(ku, cfg.vocab_size, cfg.d_model, cfg.dtype,
                             tie=cfg.tie_embeddings),
    }


def forward(cfg: ModelConfig, params, batch, *, remat: bool = True, **_):
    x = embed(params["embed"], batch["tokens"])

    def body(x, p):
        def block(p, x):
            h = rmsnorm(p["ln"], x, cfg.norm_eps)
            return x + ssm_mod.ssm_train(cfg, p["ssm"], h)
        f = jax.checkpoint(block) if remat else block
        return f(p, x), 0.0

    x, _ = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return x, {"load_balance_loss": jnp.float32(0.0)}


def unembed_matrix(cfg, params):
    return (params["embed"]["table"] if cfg.tie_embeddings
            else params["head"]["w"])


def logits_of_hidden(cfg, params, hidden):
    w = unembed_matrix(cfg, params)
    return jnp.einsum("...e,ve->...v", hidden, w).astype(jnp.float32)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, *,
                      kv_dtype=None):
    del max_len, kv_dtype  # O(1) state: no KV cache
    return {
        "ssm": ssm_mod.init_ssm_state(cfg, batch, cfg.num_layers),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(cfg: ModelConfig, params, state, tokens):
    x = embed(params["embed"], tokens[:, None])

    def body(x, layer):
        p, conv, ssm_s = layer
        h = rmsnorm(p["ln"], x, cfg.norm_eps)
        y, new_state = ssm_mod.ssm_decode(cfg, p["ssm"], h,
                                          {"conv": conv, "ssm": ssm_s})
        return x + y, (new_state["conv"], new_state["ssm"])

    x, (new_conv, new_ssm) = jax.lax.scan(
        body, x, (params["layers"], state["ssm"]["conv"],
                  state["ssm"]["ssm"]))
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = logits_of_hidden(cfg, params, x[:, 0])
    return logits, {"ssm": {"conv": new_conv, "ssm": new_ssm},
                    "pos": state["pos"] + 1}


def prefill(cfg: ModelConfig, params, batch, state, **_):
    """Chunked-SSD prefill: one training-shaped forward; the decode state
    falls out of the inter-chunk associative combine (§Perf iteration 2 —
    the baseline token-scan prefill cost 1827 s on the 32k cell)."""
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens)

    def body(x, p):
        h = rmsnorm(p["ln"], x, cfg.norm_eps)
        y, st = ssm_mod.ssm_forward(cfg, p["ssm"], h, return_state=True)
        return x + y, st

    x, states = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = logits_of_hidden(cfg, params, x[:, -1])
    new_state = {
        "ssm": {"conv": states["conv"], "ssm": states["ssm"]},
        "pos": jnp.asarray(tokens.shape[1], jnp.int32),
    }
    return logits, new_state
