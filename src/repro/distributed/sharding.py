"""Sharding rules: parameters, optimizer state, batches, decode state.

Strategy (DESIGN.md §7): DP over ('pod','data'); Megatron-style TP over
'model' (column→row pairs); ZeRO-3 FSDP of the non-TP param dim over the DP
axes; expert parallelism over 'model'; KV caches head-sharded when the kv
head count divides the model axis, else sequence-sharded (merged by the
flash-decode combiner).  Every rule falls back to replication when a dim is
not divisible — `pick` guarantees even shards, which jax requires for
input shardings.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig, dp_axes, pick

# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------


def _rule_for(path: tuple[str, ...], shape: tuple[int, ...], mesh,
              fsdp: bool) -> P:
    """PartitionSpec for the TRAILING dims the rule understands; leading
    stacking dims (layers / groups) are padded with None by the caller."""
    dp = dp_axes(mesh) if fsdp else ()
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""

    def fs(dim):
        return pick(mesh, dim, dp or None, dp[-1] if dp else None)

    def mp(dim):
        return pick(mesh, dim, "model")

    if name == "table":  # embedding [V, E]
        v, e = shape
        if mp(v) is not None:
            return P(mp(v), fs(e))
        return P(fs(v), mp(e))
    if name == "w" and parent == "head":  # unembed [V, E]
        v, e = shape
        if mp(v) is not None:
            return P(mp(v), fs(e))
        return P(fs(v), mp(e))
    if name in ("wq", "wk", "wv"):  # [E, H*D] column-parallel
        return P(fs(shape[0]), mp(shape[1]))
    if name == "wo":  # [H*D, E] row-parallel
        return P(mp(shape[0]), fs(shape[1]))
    if name in ("bq", "bk", "bv"):
        return P(mp(shape[0]))
    if name in ("w_gate", "w_up"):
        if len(shape) == 3:  # MoE experts [X, E, F]
            return P(mp(shape[0]), fs(shape[1]), None)
        return P(fs(shape[0]), mp(shape[1]))  # dense [E, F]
    if name == "w_down":
        if len(shape) == 3:  # [X, F, E]
            return P(mp(shape[0]), None, fs(shape[2]))
        return P(mp(shape[0]), fs(shape[1]))  # [F, E]
    if name == "router":  # [E, X]
        return P(fs(shape[0]), None)
    if name in ("w1", "w2"):  # whisper mlp
        if name == "w1":
            return P(fs(shape[0]), mp(shape[1]))
        return P(mp(shape[0]), fs(shape[1]))
    if name in ("b1",):
        return P(mp(shape[0]))
    if name in ("b2",):
        return P(None)
    if name == "in_proj":  # ssm [E, O]
        return P(fs(shape[0]), mp(shape[1]))
    if name == "out_proj":  # ssm [d_in, E]
        return P(mp(shape[0]), fs(shape[1]))
    if name == "conv_w":  # [W, Ch]
        return P(None, mp(shape[1]))
    if name == "conv_b":
        return P(mp(shape[0]))
    if name in ("A_log", "D", "dt_bias"):
        return P(mp(shape[0]))
    if name == "pos_dec":  # [dec_len, E]
        return P(None, fs(shape[1]))
    # norms / scalars: replicated
    return P(*([None] * len(shape)))


_STACK_KEYS = ("layers", "groups", "tail", "enc_layers", "dec_layers")


def param_pspecs(params, mesh, *, fsdp: bool = True):
    """Pytree of PartitionSpecs matching ``params``."""

    def one(path, leaf):
        keys = tuple(getattr(p, "key", getattr(p, "idx", None))
                     for p in path)
        keys = tuple(str(k) for k in keys if k is not None)
        n_stack = 0
        for k in keys:
            if k in _STACK_KEYS:
                n_stack += 1
                if k == "groups":
                    n_stack += 1  # zamba groups are [G, k, ...]
        shape = tuple(leaf.shape)
        trailing = shape[n_stack:]
        spec = _rule_for(keys, trailing, mesh, fsdp)
        return P(*([None] * n_stack + list(spec)))

    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params, mesh, *, fsdp: bool = True):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(params, mesh, fsdp=fsdp))


# ---------------------------------------------------------------------------
# Batch / decode-state rules
# ---------------------------------------------------------------------------


def batch_pspecs(batch_avals, mesh):
    """Shard the global batch dim over the DP axes; seq replicated."""
    dp = dp_axes(mesh)

    def one(path, leaf):
        b = leaf.shape[0] if leaf.ndim else 1
        ax = pick(mesh, b, dp or None, dp[-1] if dp else None)
        return P(*([ax] + [None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(one, batch_avals)


def decode_state_pspecs(state_avals, mesh, cfg: ModelConfig):
    """KV caches [L,B,S,Kv,D]: batch over DP; heads over model when
    divisible, else sequence over model (flash-decode combiner merge).
    SSM states [L,B,H,N,P]: heads over model.  pos: replicated."""
    dp = dp_axes(mesh)

    def one(path, leaf):
        keys = tuple(str(getattr(p, "key", "")) for p in path)
        name = keys[-1] if keys else ""
        shp = leaf.shape
        if name in ("k", "v", "k_scale", "v_scale") or name.startswith("cross_"):
            # [L, B, S, Kv, D(|1)]
            b_ax = pick(mesh, shp[1], dp or None, dp[-1] if dp else None)
            if pick(mesh, shp[3], "model") is not None:
                return P(None, b_ax, None, "model", None)
            seq_axes = ("model",) if b_ax else ("data", "model")
            s_ax = pick(mesh, shp[2], seq_axes if b_ax is None else "model")
            return P(None, b_ax, s_ax, None, None)
        if name == "ssm":  # [L, B, H, N, P]
            b_ax = pick(mesh, shp[1], dp or None, dp[-1] if dp else None)
            return P(None, b_ax, pick(mesh, shp[2], "model"), None, None)
        if name == "conv":  # [L, B, W-1, Ch]
            b_ax = pick(mesh, shp[1], dp or None, dp[-1] if dp else None)
            return P(None, b_ax, None, pick(mesh, shp[3], "model"))
        if name == "pos":
            return P()
        # fallback: shard dim 1 (batch-like) if possible
        if leaf.ndim >= 2:
            b_ax = pick(mesh, shp[1], dp or None, dp[-1] if dp else None)
            return P(*([None, b_ax] + [None] * (leaf.ndim - 2)))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(one, state_avals)


def tokens_pspec(batch: int, mesh) -> P:
    dp = dp_axes(mesh)
    return P(pick(mesh, batch, dp or None, dp[-1] if dp else None))
