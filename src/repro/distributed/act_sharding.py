"""Activation-sharding hints, settable by launchers, no-op otherwise.

GSPMD occasionally re-shards long-sequence attention intermediates by heads
and REPLICATES the batch dim (the "involuntary full rematerialization"
path), blowing up prefill memory ~16×.  Model code is mesh-agnostic, so the
launcher (dryrun/train/serve) registers the mesh here and the attention/MoE
layers pin their intermediates:

* ``batch_major(x)``   — dim 0 over the DP axes.
* ``attn_weights(x)``  — [B, H, Sq, T] softmax logits/weights: batch over
  DP, heads over 'model' when divisible, else the QUERY dim over 'model'
  (sequence parallelism — always divisible for the assigned shapes).

With no mesh registered (CPU tests) everything is a no-op.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

_MESH = None


def set_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


def clear() -> None:
    set_mesh(None)


def _dp():
    from repro.models.common import dp_axes

    return dp_axes(_MESH)


def batch_major(x):
    """Constrain dim 0 to the DP axes, rest unconstrained."""
    if _MESH is None or x.ndim == 0:
        return x
    dp = _dp()
    n = 1
    for a in dp:
        n *= _MESH.shape[a]
    if not dp or x.shape[0] % n:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(dp, *([None] * (x.ndim - 1))))


def seq_major(x, axis: int = 1):
    """Shard a sequence axis over 'model' (Megatron sequence parallelism).

    Used for the q path of archs whose head count does not divide the model
    axis (qwen 40-head family): flat-head sharding would cut inside a head,
    so the query SEQUENCE carries the model-parallel dim instead."""
    if _MESH is None or "model" not in _MESH.shape:
        return x
    if x.ndim <= axis or x.shape[axis] % _MESH.shape["model"]:
        return x
    dp = _dp()
    ndp = 1
    for a in dp:
        ndp *= _MESH.shape[a]
    b_ax = dp if (dp and x.shape[0] % ndp == 0) else None
    spec = [b_ax] + [None] * (x.ndim - 1)
    spec[axis] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


def heads_even(n_heads: int) -> bool:
    if _MESH is None or "model" not in _MESH.shape:
        return True
    return n_heads % _MESH.shape["model"] == 0


def attn_weights(x):
    """[B, Kv, G, Sq, T] attention logits/weights (native GQA layout).

    Preference: KV heads over 'model' (matches head-sharded caches), else
    query positions over 'model' (sequence parallelism; always divisible for
    the assigned train/prefill shapes), else cache positions over 'model'
    (decode with sequence-sharded KV — the flash-decode-combiner layout)."""
    if _MESH is None or x.ndim != 5 or "model" not in _MESH.shape:
        return batch_major(x)
    dp = _dp()
    ndp = 1
    for a in dp:
        ndp *= _MESH.shape[a]
    m = _MESH.shape["model"]
    b_ax = dp if (dp and x.shape[0] % ndp == 0) else None
    if x.shape[1] % m == 0:
        spec = P(b_ax, "model", None, None, None)
    elif x.shape[3] % m == 0:
        spec = P(b_ax, None, None, "model", None)
    elif x.shape[4] % m == 0:
        spec = P(b_ax, None, None, None, "model")
    else:
        spec = P(b_ax, None, None, None, None)
    return jax.lax.with_sharding_constraint(x, spec)
