"""Gradient compression for the DP-reduction path.

Two layers:
* :func:`fake_quant_int8` — quantize→dequantize with per-leaf scale applied
  before the (GSPMD-inserted) gradient all-reduce under pjit.  Numerically
  equivalent to transmitting int8 on the wire; the pjit program cannot
  express the quantized collective itself, so bytes-on-wire savings are
  realized only under the shard_map path below (the pjit path is used for
  accuracy experiments / error-feedback studies).
* :func:`compressed_psum` — the shard_map building block that actually moves
  int8: all_gather(int8 + f32 scale) then dequant-sum locally.  Wire bytes:
  ~N/4 of the f32 all-reduce (visible in the HLO as an int8 all-gather —
  the dry-run roofline counts it).

Error feedback (:class:`ErrorFeedback`) carries the quantization residual
into the next step, the standard fix for biased compression.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _scale_of(x):
    return jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0


def quant_int8(x):
    s = _scale_of(x)
    q = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def dequant_int8(q, s, dtype=jnp.float32):
    return q.astype(dtype) * s


def fake_quant_int8(x):
    q, s = quant_int8(x.astype(jnp.float32))
    return dequant_int8(q, s, jnp.float32)


def compressed_psum(x, axis_name: str):
    """int8-on-the-wire mean-preserving sum across ``axis_name``."""
    q, s = quant_int8(x.astype(jnp.float32))
    gq = lax.all_gather(q, axis_name)  # int8 bytes on the interconnect
    gs = lax.all_gather(s, axis_name)
    deq = gq.astype(jnp.float32) * gs.reshape(
        (-1,) + (1,) * (gq.ndim - 1))
    return jnp.sum(deq, axis=0)


class ErrorFeedback:
    """e_{t} = g_t + e_{t-1} - Q(g_t + e_{t-1}); carried as extra state."""

    @staticmethod
    def init(grads):
        return jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    @staticmethod
    def apply(grads, residual):
        """Returns (compressed grads to transmit, new residual)."""
        def one(g, e):
            x = g.astype(jnp.float32) + e
            c = fake_quant_int8(x)
            return c, x - c

        out = jax.tree.map(one, grads, residual)
        comp = jax.tree.map(lambda t: t[0], out,
                            is_leaf=lambda t: isinstance(t, tuple))
        res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
        return comp, res
