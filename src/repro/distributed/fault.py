"""Fault tolerance: restart-from-checkpoint, heartbeats, stragglers.

At 1000+ nodes the design assumptions are:

* **State recovery** is checkpoint/restart (checkpoint/ckpt.py): any failure
  collapses to "restart the job from LATEST on the surviving mesh"
  (elastic.py reshards).  No in-band parameter reconstruction.
* **Failure detection** is heartbeat-based: every host appends
  ``(host_id, step, wall_time)``; the coordinator declares a host dead after
  ``timeout_s`` silence.  In this single-process container the monitor is
  exercised by tests with synthetic clocks; on a real cluster the same logic
  runs over a shared filesystem or KV store.
* **Straggler mitigation** is *stateless deterministic data assignment*:
  shard = f(step, host_index, num_hosts) — a restarted or re-ranked host
  computes its assignment locally, no coordination, and a backup host can
  recompute any shard (speculative re-execution, MapReduce's own trick).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class HostState:
    host_id: int
    last_step: int = -1
    last_beat: float = 0.0


class HeartbeatMonitor:
    """Declares hosts dead after ``timeout_s`` without a heartbeat."""

    def __init__(self, num_hosts: int, *, timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.clock = clock
        self.hosts = {i: HostState(i) for i in range(num_hosts)}

    def beat(self, host_id: int, step: int):
        h = self.hosts[host_id]
        h.last_step = step
        h.last_beat = self.clock()

    def dead_hosts(self) -> list[int]:
        now = self.clock()
        return [i for i, h in self.hosts.items()
                if now - h.last_beat > self.timeout_s]

    def alive_hosts(self) -> list[int]:
        dead = set(self.dead_hosts())
        return [i for i in self.hosts if i not in dead]

    def stragglers(self, *, lag: int = 2) -> list[int]:
        """Hosts alive but >= ``lag`` steps behind the front-runner."""
        alive = self.alive_hosts()
        if not alive:
            return []
        front = max(self.hosts[i].last_step for i in alive)
        return [i for i in alive if front - self.hosts[i].last_step >= lag]


def shard_for(step: int, host_index: int, num_hosts: int,
              num_shards: int) -> list[int]:
    """Deterministic, stateless shard assignment.

    Rotates assignments across steps so a persistently slow host does not
    pin the same shard (straggler decorrelation), and any host can compute
    any other host's assignment for speculative backup execution.
    """
    per = num_shards // num_hosts
    assert num_shards % num_hosts == 0
    base = (host_index + step) % num_hosts
    return [(base * per + i) % num_shards for i in range(per)]


def backup_assignment(step: int, dead_host: int, num_hosts: int,
                      num_shards: int) -> tuple[int, list[int]]:
    """Which surviving host re-executes a dead host's shards: the next
    alive rank (deterministic, no coordination)."""
    backup = (dead_host + 1) % num_hosts
    return backup, shard_for(step, dead_host, num_hosts, num_shards)


@dataclasses.dataclass
class RestartPolicy:
    """Restart-from-latest semantics used by launch/train.py."""

    max_restarts: int = 100
    restarts: int = 0

    def on_failure(self) -> bool:
        self.restarts += 1
        return self.restarts <= self.max_restarts
