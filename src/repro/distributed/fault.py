"""Fault tolerance: restart-from-checkpoint, heartbeats, stragglers.

At 1000+ nodes the design assumptions are:

* **State recovery** is checkpoint/restart (checkpoint/ckpt.py): any failure
  collapses to "restart the job from LATEST on the surviving mesh"
  (elastic.py reshards).  For MapReduce partials the recovery unit is finer:
  derived combiners are *monoids*, so any shard's holder table can be
  recomputed or re-merged after a failure with bitwise-identical results —
  ``core/engine.run_resilient`` checkpoints per-shard partial aggregates and
  restores or re-executes only the lost shards.
* **Failure detection** is heartbeat-based: every host appends
  ``(host_id, step, wall_time)``; the coordinator declares a host dead after
  ``timeout_s`` silence.  In this single-process container the monitor is
  exercised by tests with synthetic clocks; on a real cluster the same logic
  runs over a shared filesystem or KV store.
* **Straggler mitigation** is *stateless deterministic data assignment*:
  shard = f(step, host_index, num_hosts) — a restarted or re-ranked host
  computes its assignment locally, no coordination, and a backup host can
  recompute any shard (speculative re-execution, MapReduce's own trick).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable


@dataclasses.dataclass
class HostState:
    host_id: int
    last_step: int = -1
    last_beat: float = 0.0
    ever_beat: bool = False


class HeartbeatMonitor:
    """Declares hosts dead after ``timeout_s`` without a heartbeat.

    ``last_beat`` is initialized from the injected ``clock`` at
    construction — NOT 0.0, which against ``time.monotonic()`` (seconds
    since an arbitrary epoch, typically boot) declared every host dead
    before its first beat.  Hosts that have never beaten get an extra
    ``grace_s`` startup allowance (default: one more timeout) on top of
    the timeout before they are declared dead, so a slow-to-join host is
    not buried while it is still binding its devices.
    """

    def __init__(self, num_hosts: int, *, timeout_s: float = 60.0,
                 grace_s: float | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.grace_s = timeout_s if grace_s is None else grace_s
        self.clock = clock
        now = self.clock()
        self.hosts = {i: HostState(i, last_beat=now) for i in range(num_hosts)}

    def beat(self, host_id: int, step: int):
        h = self.hosts[host_id]
        h.last_step = step
        h.last_beat = self.clock()
        h.ever_beat = True

    def dead_hosts(self) -> list[int]:
        now = self.clock()
        out = []
        for i, h in self.hosts.items():
            limit = self.timeout_s + (0.0 if h.ever_beat else self.grace_s)
            if now - h.last_beat > limit:
                out.append(i)
        return out

    def alive_hosts(self) -> list[int]:
        dead = set(self.dead_hosts())
        return [i for i in self.hosts if i not in dead]

    def stragglers(self, *, lag: int = 2) -> list[int]:
        """Hosts alive but >= ``lag`` steps behind the front-runner."""
        alive = self.alive_hosts()
        if not alive:
            return []
        front = max(self.hosts[i].last_step for i in alive)
        return [i for i in alive if front - self.hosts[i].last_step >= lag]


def shard_for(step: int, host_index: int, num_hosts: int,
              num_shards: int) -> list[int]:
    """Deterministic, stateless shard assignment.

    Rotates assignments across steps so a persistently slow host does not
    pin the same shard (straggler decorrelation), and any host can compute
    any other host's assignment for speculative backup execution.

    The assignment is round-robin over rotated host ranks, so it stays a
    partition (every shard owned exactly once) for ANY ``num_shards`` /
    ``num_hosts`` pair — an elastic remesh from 8 to 7 hosts must not crash
    the recovery path it exists to serve.  Per-host load is balanced to
    within one shard (``floor`` vs ``ceil`` of ``num_shards/num_hosts``).
    """
    if num_hosts <= 0:
        raise ValueError(f"num_hosts must be positive, got {num_hosts}")
    if not 0 <= host_index < num_hosts:
        raise ValueError(
            f"host_index {host_index} out of range [0, {num_hosts})")
    if num_shards < 0:
        raise ValueError(f"num_shards must be >= 0, got {num_shards}")
    base = (host_index + step) % num_hosts
    return [s for s in range(num_shards) if s % num_hosts == base]


def backup_assignment(step: int, dead_host: int, num_hosts: int,
                      num_shards: int, *, alive: list[int] | None = None
                      ) -> tuple[int, list[int]]:
    """Which surviving host re-executes a dead host's shards: the next
    alive rank (deterministic, no coordination — every survivor computes
    the same answer locally).  ``alive`` restricts the candidates when the
    caller knows which ranks still beat; without it, the next rank."""
    if num_hosts <= 1:
        raise ValueError("no surviving host available for backup execution")
    if not 0 <= dead_host < num_hosts:
        raise ValueError(
            f"dead_host {dead_host} out of range [0, {num_hosts})")
    candidates = [(dead_host + k) % num_hosts for k in range(1, num_hosts)]
    if alive is not None:
        alive_set = set(alive)
        filtered = [c for c in candidates if c in alive_set]
        if filtered:
            candidates = filtered
    return candidates[0], shard_for(step, dead_host, num_hosts, num_shards)


@dataclasses.dataclass
class RestartPolicy:
    """Restart-from-latest semantics used by launch/train.py."""

    max_restarts: int = 100
    restarts: int = 0

    def on_failure(self) -> bool:
        self.restarts += 1
        return self.restarts <= self.max_restarts


# ---------------------------------------------------------------------------
# Deterministic fault injection + recovery bookkeeping for run_resilient
# ---------------------------------------------------------------------------


class StepClock:
    """Synthetic monotonic clock for deterministic failure drills."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float = 1.0) -> float:
        self.t += dt
        return self.t


@dataclasses.dataclass(frozen=True)
class FaultInjection:
    """Deterministic failure script consumed by ``engine.run_resilient``.

    The driver simulates the cluster events a production deployment
    actually has, in a single process, so recovery is testable bit-for-bit:

    * ``dead_hosts`` crash after completing ``die_after_shards`` of their
      assigned shards — their in-memory partials are lost; checkpoints
      they wrote before dying survive unless ``checkpoint_survives`` is
      False (e.g. host-local disk died with the host).
    * ``straggler_hosts`` stay alive (keep heartbeating) but make no
      progress this round — their shards are speculatively re-executed on
      the deterministic backup rank.
    * ``resize_to`` shrinks or grows the host count after the map phase
      (elastic event): the driver remeshes, recomputes the stateless
      assignment, and re-runs only the shards whose partials were lost
      with the removed hosts.
    """

    dead_hosts: tuple[int, ...] = ()
    die_after_shards: int = 0
    checkpoint_survives: bool = True
    straggler_hosts: tuple[int, ...] = ()
    resize_to: int | None = None


@dataclasses.dataclass
class RecoveryLog:
    """What ``run_resilient`` did to produce its answer — who computed,
    restored, re-executed or speculated which shard, and what the shuffle
    overflow counters saw.  Summarized onto ``plan.recovery``."""

    num_hosts: int
    num_shards: int
    step: int
    #: (shard, host) pairs completed in the primary map phase.
    computed: list = dataclasses.field(default_factory=list)
    #: shards restored from checkpointed partial aggregates.
    restored: list = dataclasses.field(default_factory=list)
    #: (shard, backup_host) recomputed after a detected host death.
    recomputed: list = dataclasses.field(default_factory=list)
    #: (shard, backup_host) speculatively re-executed for stragglers.
    speculated: list = dataclasses.field(default_factory=list)
    dead_hosts: list = dataclasses.field(default_factory=list)
    straggler_hosts: list = dataclasses.field(default_factory=list)
    #: (old_hosts, new_hosts) when an elastic resize happened, else None.
    resized: tuple | None = None
    #: shards whose owner changed across the resize.
    moved: list = dataclasses.field(default_factory=list)
    #: per-source-shard count of shuffle pairs past the all-to-all capacity
    #: (reduce/sort flows only; () for the table-merge flows).
    shuffle_overflow: tuple = ()
    #: the mesh run_resilient ended on (None when driven mesh-less).
    final_mesh: Any = None
    #: lease holder elected at the start of a coordinated run, else None.
    coordinator: int | None = None
    #: (old_holder, new_holder, epoch) when the lease failed over.
    failover: tuple | None = None
    #: shards whose durable partials failed checksum verification and
    #: were quarantined to ``*.corrupt`` then recomputed.
    corrupt: list = dataclasses.field(default_factory=list)
    #: hosts whose beats/writes a chaos partition dropped at the wire.
    partitioned: list = dataclasses.field(default_factory=list)
    #: raw control-plane event lines (retries, backoffs, lease adoptions,
    #: quarantines) from the CoordinationStore — no silent retries.
    store_events: tuple = ()
    #: skew shuffle-plan provenance (boundary spans + split-key shard
    #: ownership lines) when the run routed by a ``skew.ShufflePlan``.
    skew_plan: tuple = ()
    #: content fingerprint of the boundary layout stamped into the
    #: checkpointable wire format (0 = legacy fixed-width ranges).
    boundary_epoch: int = 0
    #: shards whose durable partials carried a STALE boundary epoch
    #: (bucketized under different key ranges) — rejected at restore and
    #: recomputed deterministically.
    epoch_rejects: list = dataclasses.field(default_factory=list)

    def summary(self) -> tuple[str, ...]:
        """Human-readable recovery events for ``plan.recovery``."""
        lines = [
            f"resilient run: {self.num_shards} shards over "
            f"{self.num_hosts} hosts at step {self.step}; "
            f"{len(self.computed)} computed in the primary phase"]
        if self.coordinator is not None and self.failover is None:
            lines.append(
                f"coordinator: host {self.coordinator} held the lease "
                f"for the whole run")
        if self.failover is not None:
            old, new, epoch = self.failover
            lines.append(
                f"failover: coordinator {old} lost the lease; host {new} "
                f"adopted the durable ledger at epoch {epoch} and "
                f"resumed phase B from checkpointed partials")
        if self.partitioned:
            lines.append(
                f"partitioned hosts {sorted(self.partitioned)}: beats and "
                f"writes dropped at the transport; shards recovered on "
                f"live ranks")
        if self.corrupt:
            lines.append(
                f"corrupt checkpoints: shards {sorted(self.corrupt)} "
                f"failed checksum verification, quarantined to *.corrupt "
                f"and recomputed deterministically")
        if self.epoch_rejects:
            lines.append(
                f"stale boundary epochs: shards "
                f"{sorted(self.epoch_rejects)} checkpointed under "
                f"different skew boundaries (epoch != "
                f"{self.boundary_epoch}); rejected and recomputed")
        for line in self.skew_plan:
            lines.append(f"skew: {line}")
        if self.dead_hosts:
            lines.append(
                f"detected dead hosts {sorted(self.dead_hosts)}; "
                f"restored {sorted(self.restored)} from checkpointed "
                f"partials, recomputed "
                f"{sorted(s for s, _ in self.recomputed)} on backup ranks "
                f"{sorted(set(h for _, h in self.recomputed))}")
        if self.straggler_hosts:
            lines.append(
                f"stragglers {sorted(self.straggler_hosts)}: speculatively "
                f"re-executed {sorted(s for s, _ in self.speculated)} on "
                f"backup ranks "
                f"{sorted(set(h for _, h in self.speculated))}")
        if self.resized is not None:
            lines.append(
                f"elastic resize {self.resized[0]} -> {self.resized[1]} "
                f"hosts: {len(self.moved)} shard assignments moved, "
                f"re-ran only the shards whose partials were lost")
        total_ovf = int(sum(self.shuffle_overflow)) if len(
            self.shuffle_overflow) else 0
        if total_ovf:
            lines.append(
                f"shuffle overflow: {total_ovf} pairs past capacity "
                f"(per-shard {tuple(int(x) for x in self.shuffle_overflow)})")
        lines.extend(self.store_events)
        return tuple(lines)
