"""Deterministic chaos scripting for the resilient driver + streaming.

``FaultInjection`` (fault.py) scripts the *basic* cluster events — host
deaths, stragglers, elastic resizes.  ``ChaosPlan`` extends it into a
multi-fault drill language for the durable control plane:

* ``kill_coordinator(after=k)`` — the current lease holder dies after
  completing ``k`` of its shards; the drill asserts the lowest-ranked
  survivor adopts the lease + ledger and phase B resumes bitwise.
* ``corrupt_checkpoint(*shards)`` — those shards' durable partials are
  bit-flipped on disk AND their in-memory copies dropped (the holder's
  memory died with the corruption event), forcing the
  verify → quarantine → recompute path.
* ``partition(*hosts)`` — the hosts keep computing but their beats and
  store writes are dropped at the transport; the cluster declares them
  dead and recomputes their shards.
* ``delay_store(ops, kinds)`` — the first N matching store operations
  raise ``StoreTimeout``; the RetryPolicy's bounded deterministic backoff
  must absorb them (backoff → success, every attempt on the record).
* ``straggler(*hosts)`` / ``kill_host`` / ``resize`` — pass through to
  the base ``FaultInjection`` semantics.

Every fault is deterministic (no RNG): the same plan replays the same
drill bit-for-bit, which is what lets tests assert recovered output is
bitwise-identical to the clean run.
"""

from __future__ import annotations

import dataclasses
import os

from repro.checkpoint import ckpt
from repro.distributed.fault import FaultInjection


@dataclasses.dataclass(frozen=True)
class ChaosPlan:
    """A scripted multi-fault drill.  Build fluently:

    >>> plan = (ChaosPlan()
    ...         .kill_coordinator(after=1)
    ...         .corrupt_checkpoint(3)
    ...         .delay_store(2)
    ...         .straggler(5))

    Consumed by ``engine.run_resilient(chaos=plan)``; the parts that map
    onto the base ``FaultInjection`` are merged by ``resolve_injection``,
    the control-plane faults (corruption, partitions, store delays) are
    applied by the driver against the CoordinationStore + checkpoint
    layer directly.
    """

    #: kill the current lease holder after it completes this many shards
    #: (None = coordinator survives).
    kill_coordinator_after: int | None = None
    dead_hosts: tuple[int, ...] = ()
    die_after_shards: int = 0
    checkpoint_survives: bool = True
    straggler_hosts: tuple[int, ...] = ()
    partition_hosts: tuple[int, ...] = ()
    #: shards whose durable partials are bit-flipped (and in-memory copies
    #: dropped) after the map phase.
    corrupt_shards: tuple[int, ...] = ()
    #: arm CoordinationStore.inject_store_faults with (ops, kinds).
    store_fail_ops: int = 0
    store_fail_kinds: tuple[str, ...] = ("ckpt",)
    resize_to: int | None = None

    # -- fluent builders (frozen: each returns a new plan) ------------------

    def kill_coordinator(self, *, after: int = 0) -> "ChaosPlan":
        return dataclasses.replace(self, kill_coordinator_after=int(after))

    def kill_host(self, *hosts: int, after: int = 0,
                  checkpoint_survives: bool = True) -> "ChaosPlan":
        return dataclasses.replace(
            self, dead_hosts=tuple(sorted(set(self.dead_hosts)
                                          | set(int(h) for h in hosts))),
            die_after_shards=int(after),
            checkpoint_survives=bool(checkpoint_survives))

    def corrupt_checkpoint(self, *shards: int) -> "ChaosPlan":
        return dataclasses.replace(
            self, corrupt_shards=tuple(sorted(set(self.corrupt_shards)
                                              | set(int(s) for s in shards))))

    def partition(self, *hosts: int) -> "ChaosPlan":
        return dataclasses.replace(
            self, partition_hosts=tuple(sorted(set(self.partition_hosts)
                                               | set(int(h) for h in hosts))))

    def delay_store(self, ops: int,
                    kinds: tuple[str, ...] = ("ckpt",)) -> "ChaosPlan":
        return dataclasses.replace(self, store_fail_ops=int(ops),
                                   store_fail_kinds=tuple(kinds))

    def straggler(self, *hosts: int) -> "ChaosPlan":
        return dataclasses.replace(
            self, straggler_hosts=tuple(sorted(set(self.straggler_hosts)
                                               | set(int(h) for h in hosts))))

    def resize(self, to: int) -> "ChaosPlan":
        return dataclasses.replace(self, resize_to=int(to))

    # -- resolution ---------------------------------------------------------

    def resolve_injection(self, base: FaultInjection | None,
                          coordinator: int) -> FaultInjection:
        """Merge this plan (given the elected coordinator's rank) with an
        optional base ``FaultInjection`` into the script the resilient
        driver's existing death/straggler/resize machinery consumes.
        ``die_after_shards`` is a single global knob in FaultInjection, so
        a kill-coordinator ``after`` takes precedence when set."""
        base = base if base is not None else FaultInjection()
        dead = set(base.dead_hosts) | set(self.dead_hosts)
        die_after = max(base.die_after_shards, self.die_after_shards)
        if self.kill_coordinator_after is not None:
            dead.add(int(coordinator))
            die_after = int(self.kill_coordinator_after)
        return FaultInjection(
            dead_hosts=tuple(sorted(dead)),
            die_after_shards=die_after,
            checkpoint_survives=(base.checkpoint_survives
                                 and self.checkpoint_survives),
            straggler_hosts=tuple(sorted(set(base.straggler_hosts)
                                         | set(self.straggler_hosts))),
            resize_to=(self.resize_to if self.resize_to is not None
                       else base.resize_to),
        )

    def describe(self) -> tuple[str, ...]:
        out = []
        if self.kill_coordinator_after is not None:
            out.append(f"kill coordinator after "
                       f"{self.kill_coordinator_after} shards")
        if self.dead_hosts:
            out.append(f"kill hosts {list(self.dead_hosts)} after "
                       f"{self.die_after_shards} shards"
                       + ("" if self.checkpoint_survives
                          else " (checkpoints lost)"))
        if self.corrupt_shards:
            out.append(f"corrupt shard partials {list(self.corrupt_shards)}")
        if self.partition_hosts:
            out.append(f"partition hosts {list(self.partition_hosts)}")
        if self.store_fail_ops:
            out.append(f"delay first {self.store_fail_ops} store ops "
                       f"(kinds {list(self.store_fail_kinds)})")
        if self.straggler_hosts:
            out.append(f"stragglers {list(self.straggler_hosts)}")
        if self.resize_to is not None:
            out.append(f"elastic resize to {self.resize_to} hosts")
        return tuple(out)


# ---------------------------------------------------------------------------
# Deterministic corruption primitives
# ---------------------------------------------------------------------------


def corrupt_payload(path: str, *, nbytes: int = 64) -> None:
    """Deterministically flip the first ``nbytes`` of a file in place
    (XOR 0xFF) — models bit rot / a torn remote copy without any RNG."""
    with open(path, "r+b") as f:
        head = f.read(nbytes)
        f.seek(0)
        f.write(bytes(b ^ 0xFF for b in head))


def truncate_payload(path: str, *, keep: int = 16) -> None:
    """Deterministically truncate a file to ``keep`` bytes — models a
    torn write that escaped the atomic-rename discipline (e.g. a partial
    object-store upload)."""
    with open(path, "r+b") as f:
        f.truncate(keep)


def corrupt_shard_partial(ckpt_dir: str, shard: int, step: int) -> str | None:
    """Corrupt the durable partial checkpoint of one shard (the payload
    bytes, so the manifest CRC catches it); returns the corrupted path or
    None when that shard has no checkpoint on disk."""
    d = os.path.join(ckpt.shard_partial_dir(ckpt_dir, shard),
                     f"step_{step}")
    apath = os.path.join(d, "arrays.npz")
    if not os.path.exists(apath):
        return None
    corrupt_payload(apath)
    return apath


def corrupt_service_checkpoint(ckpt_dir: str, step: int) -> str | None:
    """Corrupt a streaming-service snapshot (``service/step_<N>``) —
    drives the MapReduceService torn-restore drill."""
    d = os.path.join(ckpt.service_state_dir(ckpt_dir), f"step_{step}")
    apath = os.path.join(d, "arrays.npz")
    if not os.path.exists(apath):
        return None
    truncate_payload(apath)
    return apath
