"""Durable multi-host coordination: KV store, heartbeats, leases, retries.

``run_resilient`` (core/engine.py) simulated a cluster with an in-process
``HeartbeatMonitor`` — one Python object that every "host" poked directly.
That is the single point of failure the 1000-node posture cannot have: the
coordinator's memory IS the cluster state, so a coordinator crash loses the
recovery ledger even though every per-shard partial is durably checkpointed.

This module moves the control plane onto a durable store:

* ``KVStore`` — the pluggable interface (``put/get/delete/keys``).
  ``FileKVStore`` is the shared-filesystem implementation (every write is
  ``tmp + os.replace``, atomic on POSIX, so readers never see torn values);
  ``MemKVStore`` backs mesh-less unit tests and property drills.
* ``CoordinationStore`` — the control-plane schema over a KVStore:
  ``hosts/<h>`` heartbeat records, ``lease`` for coordinator election,
  ``ledger/shard_<s>`` per-shard completion records (the durable
  ``RecoveryLog``).  Every store operation goes through ``retried()`` so a
  flaky store is survived with a bounded, deterministic backoff — and every
  retry is recorded onto ``events`` (no silent retries).
* **Lease-based election.**  ``elect(alive)`` is pure and deterministic:
  the lowest-ranked live host wins.  ``CoordinationStore.adopt`` grants the
  lease only to that winner and only when the current lease is expired or
  its holder is dead, so for ANY alive-set exactly one host adopts — no
  quorum protocol needed because rank order is total.  Failover = the new
  coordinator re-reads the ledger from the store and resumes phase B from
  durable per-shard partials, bitwise-identical (partials are pure
  functions of their shards; merges are monoids).
* ``RetryPolicy`` — capped exponential backoff with a jitter-free
  deterministic schedule (reproducibility over thundering-herd avoidance:
  drills must be bit-stable) and a per-operation wall-clock timeout.
* ``DurableHeartbeatMonitor`` — the ``fault.HeartbeatMonitor`` interface
  backed by the store, plus ``partition()``: a partitioned host keeps
  computing but its beats and writes never reach the store, so the
  cluster correctly declares it dead and recomputes its shards.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Iterable


class StoreTimeout(OSError):
    """A store operation timed out (injected by chaos drills; in production
    the filesystem/KV client raises its own OSError subclass)."""


class RetryError(RuntimeError):
    """A store operation failed after exhausting its bounded retry budget."""

    def __init__(self, op: str, attempts: int, last: BaseException):
        super().__init__(
            f"{op}: failed after {attempts} bounded attempts "
            f"({type(last).__name__}: {last})")
        self.op = op
        self.attempts = attempts
        self.last = last


# ---------------------------------------------------------------------------
# KV stores
# ---------------------------------------------------------------------------


class KVStore:
    """Pluggable durable key-value interface.  Keys are ``/``-separated
    paths (``hosts/3``, ``ledger/shard_7``); values are bytes.  ``put``
    must be atomic: a concurrent reader sees the old value or the new one,
    never a torn write."""

    def put(self, key: str, value: bytes) -> None:
        raise NotImplementedError

    def get(self, key: str) -> bytes | None:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def keys(self, prefix: str = "") -> list[str]:
        raise NotImplementedError


class MemKVStore(KVStore):
    """In-memory store for unit tests and mesh-less property drills."""

    def __init__(self):
        self._d: dict[str, bytes] = {}

    def put(self, key: str, value: bytes) -> None:
        self._d[key] = bytes(value)

    def get(self, key: str) -> bytes | None:
        return self._d.get(key)

    def delete(self, key: str) -> None:
        self._d.pop(key, None)

    def keys(self, prefix: str = "") -> list[str]:
        return sorted(k for k in self._d if k.startswith(prefix))


class FileKVStore(KVStore):
    """Shared-filesystem store: one file per key under ``root``.

    Atomicity is ``tmp + os.replace`` — the same discipline as
    checkpoint/ckpt.py — so a crashed writer never leaves a torn value
    for the next coordinator to trip over.
    """

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str) -> str:
        if not key or key.startswith(("/", ".")) or ".." in key:
            raise ValueError(f"bad store key: {key!r}")
        return os.path.join(self.root, *key.split("/"))

    def put(self, key: str, value: bytes) -> None:
        p = self._path(key)
        os.makedirs(os.path.dirname(p), exist_ok=True)
        tmp = p + ".tmp"
        with open(tmp, "wb") as f:
            f.write(value)
        os.replace(tmp, p)

    def get(self, key: str) -> bytes | None:
        try:
            with open(self._path(key), "rb") as f:
                return f.read()
        except FileNotFoundError:
            return None

    def delete(self, key: str) -> None:
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass

    def keys(self, prefix: str = "") -> list[str]:
        out = []
        for dirpath, _, files in os.walk(self.root):
            rel = os.path.relpath(dirpath, self.root)
            rel = "" if rel == "." else rel.replace(os.sep, "/") + "/"
            for fn in files:
                if fn.endswith(".tmp"):
                    continue
                k = rel + fn
                if k.startswith(prefix):
                    out.append(k)
        return sorted(out)


# ---------------------------------------------------------------------------
# Retry policy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded, deterministic retry for store/shard operations.

    The backoff schedule is capped exponential and JITTER-FREE: drills must
    be reproducible bit-for-bit, so two runs of the same chaos script take
    the same schedule (``schedule()`` is a pure function of the policy).
    ``timeout_s`` bounds the total wall-clock per operation; retries never
    loop unboundedly — after ``max_attempts`` (or the deadline) the last
    error is re-raised wrapped in ``RetryError``.
    """

    max_attempts: int = 5
    base_delay_s: float = 0.05
    multiplier: float = 2.0
    max_delay_s: float = 2.0
    timeout_s: float = 30.0
    #: exception types that are retried; everything else propagates
    #: immediately (a corrupt checkpoint is not transient).
    retry_on: tuple = (OSError, TimeoutError)
    #: never retried even if they match ``retry_on`` (a missing checkpoint
    #: will stay missing no matter how patiently we re-read it).
    no_retry: tuple = (FileNotFoundError,)

    def schedule(self) -> tuple[float, ...]:
        """Deterministic backoff delays between attempts (len = retries)."""
        out = []
        d = self.base_delay_s
        for _ in range(max(0, self.max_attempts - 1)):
            out.append(min(d, self.max_delay_s))
            d *= self.multiplier
        return tuple(out)

    def call(self, fn: Callable[[], Any], *, op: str = "store op",
             sleep: Callable[[float], Any] | None = None,
             clock: Callable[[], float] | None = None,
             on_event: Callable[[str], Any] | None = None) -> Any:
        """Run ``fn`` under this policy.  Every retry emits an event line
        (attempt number, error, backoff taken) via ``on_event`` — no
        silent retries — and eventual success after retries is recorded
        too, so ``plan.recovery`` shows the full story."""
        sleep = time.sleep if sleep is None else sleep
        clock = time.monotonic if clock is None else clock
        emit = on_event if on_event is not None else (lambda s: None)
        delays = self.schedule()
        deadline = clock() + self.timeout_s
        last: BaseException | None = None
        for attempt in range(1, self.max_attempts + 1):
            try:
                result = fn()
            except self.retry_on as e:
                if isinstance(e, self.no_retry):
                    raise
                last = e
                out_of_budget = (attempt >= self.max_attempts
                                 or clock() >= deadline)
                if out_of_budget:
                    emit(f"retry: {op} FAILED after {attempt} bounded "
                         f"attempts ({type(e).__name__}: {e})")
                    raise RetryError(op, attempt, e) from e
                delay = delays[attempt - 1]
                emit(f"retry: {op} attempt {attempt}/{self.max_attempts} "
                     f"failed ({type(e).__name__}: {e}); backing off "
                     f"{delay:g}s")
                sleep(delay)
            else:
                if attempt > 1:
                    emit(f"retry: {op} succeeded on attempt "
                         f"{attempt}/{self.max_attempts}")
                return result
        raise RetryError(op, self.max_attempts, last)  # pragma: no cover


# ---------------------------------------------------------------------------
# Lease-based coordinator election
# ---------------------------------------------------------------------------


def elect(alive: Iterable[int]) -> int:
    """Deterministic coordinator election: the lowest-ranked live host.

    Pure and total — every survivor computes the same winner locally from
    the same alive-set, so election needs no consensus round-trip.  Raises
    ``ValueError`` on an empty alive-set (nobody left to coordinate).
    """
    alive = sorted(set(int(a) for a in alive))
    if not alive:
        raise ValueError("cannot elect a coordinator from an empty alive-set")
    return alive[0]


@dataclasses.dataclass(frozen=True)
class Lease:
    """Coordinator lease record stored under the ``lease`` key."""

    holder: int
    epoch: int
    granted_at: float
    expires_at: float

    def to_json(self) -> bytes:
        return json.dumps(dataclasses.asdict(self)).encode()

    @classmethod
    def from_json(cls, raw: bytes) -> "Lease":
        d = json.loads(raw.decode())
        return cls(holder=int(d["holder"]), epoch=int(d["epoch"]),
                   granted_at=float(d["granted_at"]),
                   expires_at=float(d["expires_at"]))


# ---------------------------------------------------------------------------
# Coordination store
# ---------------------------------------------------------------------------


class CoordinationStore:
    """Control-plane schema over a ``KVStore``.

    Store layout (all values JSON):

    ======================  =================================================
    ``hosts/<h>``           heartbeat record {host, step, time, ever}
    ``lease``               coordinator lease {holder, epoch, granted_at,
                            expires_at}
    ``ledger/shard_<s>``    durable RecoveryLog entry {shard, host, step} —
                            written by the host as it completes the shard,
                            read by a failover coordinator during adoption
    ======================  =================================================

    All writes funnel through ``retried()`` (bounded ``RetryPolicy``
    backoff, per-op timeout) and optionally through the chaos fault gate
    (``inject_store_faults``), which raises ``StoreTimeout`` for the first
    N matching operations — deterministic "delayed store" drills.
    ``events`` accumulates every retry/lease/partition event for
    ``plan.recovery``.
    """

    def __init__(self, store: KVStore | str, *,
                 retry: RetryPolicy | None = None,
                 lease_ttl_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], Any] | None = None):
        if isinstance(store, str):
            store = FileKVStore(store)
        self.kv = store
        self.retry = retry if retry is not None else RetryPolicy()
        self.lease_ttl_s = lease_ttl_s
        self.clock = clock
        # default sleep: advance a synthetic clock if we were given one,
        # else real time.sleep — keeps drills instant AND deterministic.
        if sleep is None:
            sleep = getattr(clock, "advance", None) or time.sleep
        self.sleep = sleep
        self.events: list[str] = []
        self._fail_ops = 0
        self._fail_kinds: tuple[str, ...] = ()

    # -- chaos fault gate ---------------------------------------------------

    def inject_store_faults(self, ops: int,
                            kinds: tuple[str, ...] = ("put",)) -> None:
        """Arm the deterministic delayed-store drill: the next ``ops``
        operations whose kind is in ``kinds`` raise ``StoreTimeout``
        before touching the store, then behave normally — exercising the
        backoff → success path."""
        self._fail_ops = int(ops)
        self._fail_kinds = tuple(kinds)

    def _maybe_fail(self, kind: str, op: str) -> None:
        if self._fail_ops > 0 and kind in self._fail_kinds:
            self._fail_ops -= 1
            raise StoreTimeout(f"injected store timeout ({op})")

    def retried(self, op: str, fn: Callable[[], Any], *,
                kind: str = "put") -> Any:
        """Run ``fn`` under the store's retry policy + chaos fault gate,
        recording every retry onto ``events``."""

        def gated():
            self._maybe_fail(kind, op)
            return fn()

        return self.retry.call(gated, op=op, sleep=self.sleep,
                               clock=self.clock,
                               on_event=self.events.append)

    # -- heartbeats ---------------------------------------------------------

    def register_host(self, host: int) -> None:
        rec = {"host": int(host), "step": -1, "time": self.clock(),
               "ever": False}
        self.retried(f"register host {host}",
                     lambda: self.kv.put(f"hosts/{host}",
                                         json.dumps(rec).encode()),
                     kind="register")

    def beat(self, host: int, step: int) -> None:
        rec = {"host": int(host), "step": int(step), "time": self.clock(),
               "ever": True}
        self.retried(f"heartbeat host {host}",
                     lambda: self.kv.put(f"hosts/{host}",
                                         json.dumps(rec).encode()),
                     kind="beat")

    def host_records(self) -> dict[int, dict]:
        out = {}
        for k in self.kv.keys("hosts/"):
            raw = self.kv.get(k)
            if raw is None:
                continue
            try:
                rec = json.loads(raw.decode())
            except (ValueError, UnicodeDecodeError):
                continue  # torn record: treat as missing, host re-beats
            out[int(rec["host"])] = rec
        return out

    # -- lease --------------------------------------------------------------

    def lease(self) -> Lease | None:
        raw = self.kv.get("lease")
        return None if raw is None else Lease.from_json(raw)

    def adopt(self, host: int, alive: Iterable[int], *,
              ttl_s: float | None = None) -> Lease | None:
        """Try to take the coordinator lease as ``host``.

        Returns the (possibly pre-existing) lease if ``host`` ends up the
        coordinator, else None.  Exactly one host in ``alive`` can ever
        win: a live unexpired holder keeps the lease, otherwise only
        ``elect(alive)`` may adopt, bumping the epoch.
        """
        alive = set(int(a) for a in alive)
        now = self.clock()
        cur = self.lease()
        if cur is not None and cur.expires_at > now and cur.holder in alive:
            return cur if cur.holder == host else None
        winner = elect(alive)
        if host != winner:
            return None
        ttl = self.lease_ttl_s if ttl_s is None else ttl_s
        new = Lease(holder=host, epoch=(cur.epoch + 1 if cur else 1),
                    granted_at=now, expires_at=now + ttl)
        self.retried(f"lease adoption by host {host}",
                     lambda: self.kv.put("lease", new.to_json()),
                     kind="lease")
        if cur is None:
            self.events.append(
                f"lease: host {host} elected coordinator "
                f"(epoch {new.epoch}, ttl {ttl:g}s)")
        else:
            why = ("expired" if cur.expires_at <= now else
                   f"holder {cur.holder} dead")
            self.events.append(
                f"lease: host {host} adopted coordination from host "
                f"{cur.holder} ({why}) at epoch {new.epoch}")
        return new

    def renew(self, lease: Lease, *, ttl_s: float | None = None) -> Lease:
        now = self.clock()
        ttl = self.lease_ttl_s if ttl_s is None else ttl_s
        new = dataclasses.replace(lease, granted_at=now, expires_at=now + ttl)
        self.retried(f"lease renewal by host {lease.holder}",
                     lambda: self.kv.put("lease", new.to_json()),
                     kind="lease")
        return new

    # -- durable recovery ledger -------------------------------------------

    def record_shard(self, shard: int, host: int, step: int) -> None:
        """Durably record that ``host`` completed ``shard`` — written by
        the worker itself (not the coordinator), so the ledger survives a
        coordinator death and the failover host adopts it from the store."""
        rec = {"shard": int(shard), "host": int(host), "step": int(step)}
        self.retried(f"ledger record shard {shard}",
                     lambda: self.kv.put(f"ledger/shard_{shard}",
                                         json.dumps(rec).encode()),
                     kind="ledger")

    def load_ledger(self, step: int | None = None) -> dict[int, int]:
        """shard -> host completion records (the adopted RecoveryLog)."""
        out = {}
        for k in self.kv.keys("ledger/"):
            raw = self.kv.get(k)
            if raw is None:
                continue
            try:
                rec = json.loads(raw.decode())
            except (ValueError, UnicodeDecodeError):
                continue
            if step is None or int(rec.get("step", -1)) == int(step):
                out[int(rec["shard"])] = int(rec["host"])
        return out

    def clear_ledger(self) -> None:
        for k in self.kv.keys("ledger/"):
            self.kv.delete(k)


# ---------------------------------------------------------------------------
# Store-backed heartbeat monitor
# ---------------------------------------------------------------------------


class DurableHeartbeatMonitor:
    """``fault.HeartbeatMonitor`` interface backed by a CoordinationStore.

    The liveness rule is identical (timeout + startup grace for hosts that
    never beat) but the records live in the durable store, so a failover
    coordinator reads the same truth the dead one saw.  ``partition(h)``
    models a network partition: host ``h``'s beats are dropped at the
    transport, so the cluster declares it dead and recovers its shards
    even though the host itself keeps running.
    """

    def __init__(self, coord: CoordinationStore, num_hosts: int, *,
                 timeout_s: float = 60.0, grace_s: float | None = None,
                 clock: Callable[[], float] | None = None):
        self.coord = coord
        self.num_hosts = num_hosts
        self.timeout_s = timeout_s
        self.grace_s = timeout_s if grace_s is None else grace_s
        self.clock = coord.clock if clock is None else clock
        self.partitioned: set[int] = set()
        for i in range(num_hosts):
            coord.register_host(i)

    def partition(self, host: int) -> None:
        if host not in self.partitioned:
            self.partitioned.add(host)
            self.coord.events.append(
                f"partition: host {host} unreachable — heartbeats and "
                f"store writes dropped at the transport")

    def heal(self, host: int) -> None:
        self.partitioned.discard(host)

    def beat(self, host_id: int, step: int) -> None:
        if host_id in self.partitioned:
            return  # dropped on the wire
        self.coord.beat(host_id, step)

    def _records(self) -> dict[int, dict]:
        recs = self.coord.host_records()
        # hosts with no surviving record at all count as never-beaten
        for i in range(self.num_hosts):
            recs.setdefault(i, {"host": i, "step": -1, "time": 0.0,
                                "ever": False})
        return recs

    def dead_hosts(self) -> list[int]:
        now = self.clock()
        out = []
        for i, rec in sorted(self._records().items()):
            limit = self.timeout_s + (0.0 if rec.get("ever") else self.grace_s)
            if now - float(rec.get("time", 0.0)) > limit:
                out.append(i)
        return out

    def alive_hosts(self) -> list[int]:
        dead = set(self.dead_hosts())
        return [i for i in sorted(self._records()) if i not in dead]

    def stragglers(self, *, lag: int = 2) -> list[int]:
        recs = self._records()
        alive = self.alive_hosts()
        if not alive:
            return []
        front = max(int(recs[i].get("step", -1)) for i in alive)
        return [i for i in alive
                if front - int(recs[i].get("step", -1)) >= lag]
