"""repro.distributed"""
