"""The shuffle wire layer: one owner for the all-to-all wire format.

Three layers used to hard-code the same undocumented format — the
``lax.all_to_all`` send buckets in ``core/engine.py``, the resilient
driver's checkpointable per-shard partials, and the npz trees
``checkpoint/ckpt.py`` persists.  This module is now the single source
of truth: a :class:`WireFormat` record (codec + capacity envelope +
per-destination key layout, resolved once by :func:`wire_format`) and
pluggable codecs that encode/decode around the collective AND around
the checkpoint store, so a compressed wire compresses recovery traffic
for free.

Codecs (``ShuffleOptions.wire``):

``raw``
    The legacy layout, bit for bit: ``keys [S, B] int32`` + the value
    tree ``[S, B, ...]`` per destination bucket.
``delta``
    Exact/lossless key compression.  The framework *knows* each
    destination bucket holds keys from one shard's key range (the sort
    flow's send buckets are the top-level radix buckets), so every key
    is stored as its delta from the destination's range base — a
    residual in ``[0, span)`` — bit-packed at the static width
    ``ceil(log2(span + n_hot + 1))`` instead of 32 bits.  Hot split
    keys and the pad sentinel get reserved symbols past the span.
    Slot order is untouched, so decode reproduces the raw bucket
    bitwise and every downstream flow is bit-identical.
``packed``
    ``delta`` keys plus narrow value packing — explicit opt-in, since
    it can change bits: integer value leaves are cast to int8 (exact
    iff every value fits [-128, 127] — the int-exact-monoid contract is
    the caller's), and float leaves reuse the
    ``distributed/compression.py`` int8 quantization per destination
    row (bounded error ≤ scale/2, with a per-row f32 scale riding the
    wire as one extra scalar per destination).

The encoded tree is what rides the wire and what the resilient driver
checkpoints; ``WireFormat.epoch`` fingerprints the full layout (codec,
capacity, ranges, value dtypes, skew-plan epoch) so a stale or
foreign-codec partial is rejected at restore instead of silently
merged.
"""

from __future__ import annotations

import dataclasses
import math
import zlib

import jax
import jax.numpy as jnp
import numpy as np

CODECS = ("raw", "delta", "packed")


def shuffle_bucket_capacity(n_pairs: int, num_shards: int) -> int:
    """Default per-destination send capacity of the all-to-all shuffle:
    2x the uniform share, the Phoenix fixed-buffer posture.  A skewed key
    distribution can exceed it — the shuffle COUNTS what falls past the
    capacity and the engine surfaces it (``LoweringFallbackWarning``, plan
    diagnostics, or a hard error under ``strict_shuffle``) instead of the
    old behaviour of silently dropping the pairs."""
    return -(-2 * n_pairs // num_shards)


def resolve_capacity(n_pairs: int, num_shards: int, *,
                     capacity: int | None = None, plan=None) -> int:
    """The one capacity-resolution chain (explicit -> sampled envelope ->
    legacy 2x uniform) — previously duplicated between the live shuffle
    and the resilient partial builder."""
    if capacity:
        return int(capacity)
    if plan is not None:
        return int(plan.capacity_for(n_pairs))
    return shuffle_bucket_capacity(n_pairs, num_shards)


@dataclasses.dataclass(frozen=True)
class WireFormat:
    """Static description of one shuffle's wire layout.

    Frozen and tuple-valued so it hashes into jit closures; everything
    here is resolved host-side (from static shapes and the skew plan),
    never from traced values."""

    codec: str
    num_shards: int
    #: per-destination bucket capacity B (slots, pairs).
    capacity: int
    key_space: int
    #: per-destination key-range base (len ``num_shards``).
    lo: tuple[int, ...]
    #: widest destination range width — every non-hot key's residual
    #: ``k - lo[dest]`` lives in ``[0, span)``.
    span: int
    #: hot split keys (routed outside their owner's range; they get
    #: reserved symbols past the span).
    hot_keys: tuple[int, ...] = ()
    #: ``skew.ShufflePlan.epoch`` of the routing plan (0 = fixed-width).
    plan_epoch: int = 0
    #: value-leaf layout in flatten order: (dtype name, elements/pair).
    value_leaves: tuple[tuple[str, int], ...] = (("int32", 1),)

    def __post_init__(self):
        if self.codec not in CODECS:
            raise ValueError(
                f"unknown wire codec {self.codec!r}; expected one of "
                f"{CODECS}")
        if len(self.lo) != self.num_shards:
            raise ValueError(
                f"need one range base per destination "
                f"({self.num_shards}), got {len(self.lo)}")

    @property
    def n_hot(self) -> int:
        return len(self.hot_keys)

    @property
    def n_symbols(self) -> int:
        """Residuals [0, span) + one symbol per hot key + the pad
        sentinel."""
        return self.span + self.n_hot + 1

    @property
    def delta_bits(self) -> int:
        """Static bit width of one packed key symbol."""
        return max(1, math.ceil(math.log2(self.n_symbols)))

    @property
    def packed_row_bytes(self) -> int:
        """Bytes of one destination's bit-packed key lane."""
        return -(-self.capacity * self.delta_bits // 8)

    @property
    def epoch(self) -> int:
        """Content fingerprint of the full wire layout — stamped into
        checkpointed partials so restore can reject stale boundaries,
        foreign codecs, resized capacity envelopes, or changed value
        layouts (all of which change the meaning of the stored bytes)."""
        return zlib.crc32(repr((
            self.codec, self.num_shards, self.capacity, self.key_space,
            self.lo, self.span, self.hot_keys, self.plan_epoch,
            self.value_leaves)).encode())


def wire_format(*, key_space: int, num_shards: int, n_pairs: int,
                value_avals, codec: str = "raw",
                capacity: int | None = None, plan=None) -> WireFormat:
    """Resolve the wire layout for one shuffle.

    ``value_avals`` is the value pytree of one shard's pair stream (or
    shape/dtype structs of it); ``plan`` a ``skew.ShufflePlan`` or None
    for the legacy fixed-width ranges.  ``capacity=None`` derives the
    envelope (:func:`resolve_capacity`)."""
    S = num_shards
    B = resolve_capacity(n_pairs, S, capacity=capacity, plan=plan)
    if plan is None:
        k_local = -(-key_space // S)
        lo = tuple(d * k_local for d in range(S))
        span = k_local
        hot: tuple[int, ...] = ()
        plan_epoch = 0
    else:
        lo = tuple(plan.boundaries[:-1])
        span = plan.width
        hot = tuple(plan.hot_keys)
        plan_epoch = plan.epoch
    leaves = tuple(
        (str(jnp.dtype(l.dtype)), int(np.prod(l.shape[1:], dtype=np.int64)))
        for l in jax.tree.leaves(value_avals))
    return WireFormat(codec=codec, num_shards=S, capacity=B,
                      key_space=key_space, lo=lo, span=span, hot_keys=hot,
                      plan_epoch=plan_epoch, value_leaves=leaves)


# ---------------------------------------------------------------------------
# Bucketize: pair stream -> per-destination send buckets
# ---------------------------------------------------------------------------


def bucketize(fmt: WireFormat, stream, plan=None):
    """Pack a shard's pair stream into per-destination send buckets.

    Range partitioning: key k -> shard ``k // ceil(K/S)`` — the shard key
    ranges are the top-level radix buckets, which is why the sort flow can
    reuse this machinery verbatim.  This is the wire format of the
    all-to-all (``engine._shuffle_pairs``) AND the checkpointable
    per-shard partial of the resilient driver (``engine.run_resilient``):
    the send buckets are a pure function of the shard's items, so a lost
    shard's contribution to every key range can be deterministically
    recomputed.

    ``plan`` (a ``skew.ShufflePlan``) replaces the fixed-width arithmetic
    with sampled balanced range boundaries (searchsorted routing) and
    round-robins each hot key's occurrences over its split destinations;
    ``None`` keeps the legacy path bitwise.  It must be the plan ``fmt``
    was resolved from.

    Returns ``(send_keys [S, B], send_vals [S, B, ...], overflow)`` where
    ``overflow`` counts the valid pairs that did NOT fit their
    destination bucket (silently dropped by the pre-PR-5 shuffle).
    """
    K = fmt.key_space
    S = fmt.num_shards
    B = fmt.capacity
    plan_epoch = plan.epoch if plan is not None else 0
    if plan_epoch != fmt.plan_epoch:
        raise ValueError(
            f"shuffle plan (epoch {plan_epoch}) is not the one this "
            f"WireFormat was resolved from (epoch {fmt.plan_epoch})")

    if plan is None:
        k_local = -(-K // S)
        tgt = jnp.where(stream.valid, stream.keys // k_local, S)
    else:
        cuts = jnp.asarray(plan.boundaries[1:-1], jnp.int32)
        tgt = jnp.searchsorted(cuts, stream.keys,
                               side="right").astype(jnp.int32)
        if plan.hot_keys:
            hk = jnp.asarray(plan.hot_keys, jnp.int32)
            hw = jnp.asarray(plan.hot_ways, jnp.int32)
            owners = jnp.asarray(
                [plan.hot_owner(k) for k in plan.hot_keys], jnp.int32)
            eq = stream.keys[:, None] == hk[None, :]  # [n, H]
            is_hot = jnp.any(eq, axis=1)
            hidx = jnp.argmax(eq, axis=1)
            # occurrence rank of each hot pair within its key: round-robin
            # over the split destinations starting at the range owner
            occ = jnp.take_along_axis(
                jnp.cumsum(eq.astype(jnp.int32), axis=0),
                hidx[:, None], axis=1)[:, 0] - 1
            dest = (owners[hidx] + occ % hw[hidx]) % S
            tgt = jnp.where(is_hot, dest, tgt)
        tgt = jnp.where(stream.valid, tgt, S)
    oh = (tgt[:, None] == jnp.arange(S)[None, :]).astype(jnp.int32)
    rank = jnp.take_along_axis(
        jnp.cumsum(oh, axis=0), jnp.minimum(tgt, S - 1)[:, None],
        axis=1)[:, 0] - 1
    ok = stream.valid & (rank < B)
    overflow = jnp.sum(stream.valid & (rank >= B)).astype(jnp.int32)
    slot = jnp.where(ok, jnp.minimum(tgt, S - 1) * B + rank, S * B)

    send_keys = jnp.full((S * B,), K, jnp.int32).at[slot].set(
        stream.keys, mode="drop").reshape(S, B)
    send_vals = jax.tree.map(
        lambda v: jnp.zeros((S * B,) + v.shape[1:], v.dtype).at[slot].set(
            v, mode="drop").reshape((S, B) + v.shape[1:]),
        stream.values)
    return send_keys, send_vals, overflow


# ---------------------------------------------------------------------------
# Bit-packed key lane (delta/packed codecs)
# ---------------------------------------------------------------------------


def _pack_symbols(sym, w: int):
    """``[R, B] int32`` symbols < 2**w -> ``[R, ceil(B*w/8)] uint8``,
    little-endian within and across bytes (jit-compatible, static
    shapes)."""
    R, B = sym.shape
    bits = (sym[:, :, None] >> jnp.arange(w, dtype=jnp.int32)) & 1
    flat = bits.reshape(R, B * w)
    pad = (-(B * w)) % 8
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    grouped = flat.reshape(R, -1, 8)
    return jnp.sum(grouped << jnp.arange(8, dtype=jnp.int32),
                   axis=-1).astype(jnp.uint8)


def _unpack_symbols(packed, capacity: int, w: int):
    """Inverse of :func:`_pack_symbols`: ``[R, P] uint8`` ->
    ``[R, capacity] int32``."""
    R = packed.shape[0]
    bits = (packed[:, :, None].astype(jnp.int32)
            >> jnp.arange(8, dtype=jnp.int32)) & 1
    flat = bits.reshape(R, -1)[:, :capacity * w]
    grouped = flat.reshape(R, capacity, w)
    return jnp.sum(grouped << jnp.arange(w, dtype=jnp.int32),
                   axis=-1).astype(jnp.int32)


def _symbols_of(fmt: WireFormat, send_keys):
    """Keys ``[S, B]`` -> bounded symbols: range residual, hot index past
    the span, or the pad sentinel ``span + n_hot``."""
    lo = jnp.asarray(fmt.lo, jnp.int32)[:, None]
    sym = send_keys - lo
    if fmt.hot_keys:
        hk = jnp.asarray(fmt.hot_keys, jnp.int32)
        eq = send_keys[:, :, None] == hk
        sym = jnp.where(jnp.any(eq, axis=-1),
                        fmt.span + jnp.argmax(eq, axis=-1).astype(jnp.int32),
                        sym)
    return jnp.where(send_keys >= fmt.key_space, fmt.span + fmt.n_hot, sym)


def _keys_of(fmt: WireFormat, sym, dest_index):
    """Symbols ``[R, B]`` (received rows, one source per row) -> exact
    keys for destination ``dest_index`` (traceable)."""
    lo = jnp.asarray(fmt.lo, jnp.int32)[dest_index]
    # hot symbols + the sentinel decode through one static table
    tail = jnp.asarray(fmt.hot_keys + (fmt.key_space,), jnp.int32)
    hot_i = jnp.clip(sym - fmt.span, 0, fmt.n_hot)
    return jnp.where(sym < fmt.span, lo + sym, tail[hot_i]).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Codecs: encode (send side) / decode (receive side)
# ---------------------------------------------------------------------------


def _float_leaf(dt) -> bool:
    return jnp.issubdtype(jnp.dtype(dt), jnp.floating)


def encode(fmt: WireFormat, send_keys, send_vals) -> dict:
    """Bucketized sends -> the encoded tree that rides the all-to-all
    (every leaf keeps a leading destination axis of ``num_shards``) and
    lands in checkpointed partials."""
    if fmt.codec == "raw":
        return {"keys": send_keys, "vals": send_vals}
    bits = _pack_symbols(_symbols_of(fmt, send_keys), fmt.delta_bits)
    if fmt.codec == "delta":
        return {"bits": bits, "vals": send_vals}
    # packed: narrow every value leaf to int8; float leaves quantize per
    # destination row with the compression.py path (scale rides along)
    from repro.distributed import compression as comp

    leaves, treedef = jax.tree.flatten(send_vals)
    out, scales = [], []
    for leaf in leaves:
        if _float_leaf(leaf.dtype):
            q, s = jax.vmap(comp.quant_int8)(leaf)
            out.append(q)
            scales.append(s)
        elif leaf.dtype.itemsize > 1:
            out.append(leaf.astype(jnp.int8))
        else:
            out.append(leaf)
    enc = {"bits": bits, "vals": jax.tree.unflatten(treedef, out)}
    if scales:
        enc["scales"] = tuple(scales)
    return enc


def decode(fmt: WireFormat, enc: dict, dest_index):
    """Encoded rows (one source per row, the all-to-all receive layout or
    the resilient driver's host-side assembly of the same buckets) ->
    ``(recv_keys [R, B], recv_vals [R, B, ...])`` for destination
    ``dest_index`` (static or traced)."""
    if fmt.codec == "raw":
        return enc["keys"], enc["vals"]
    sym = _unpack_symbols(enc["bits"], fmt.capacity, fmt.delta_bits)
    keys = _keys_of(fmt, sym, dest_index)
    if fmt.codec == "delta":
        return keys, enc["vals"]
    leaves, treedef = jax.tree.flatten(enc["vals"])
    scales = list(enc.get("scales", ()))
    out = []
    for leaf, (dt, _) in zip(leaves, fmt.value_leaves):
        dt = jnp.dtype(dt)
        if _float_leaf(dt):
            s = scales.pop(0)
            out.append(leaf.astype(dt)
                       * s.reshape((-1,) + (1,) * (leaf.ndim - 1)))
        else:
            out.append(leaf.astype(dt))
    return keys, jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Byte accounting (cost model / roofline / benchmarks)
# ---------------------------------------------------------------------------


def tree_nbytes(tree) -> int:
    """Total bytes of a pytree of arrays (or shape/dtype structs)."""
    return int(sum(int(np.prod(l.shape, dtype=np.int64))
                   * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(tree)))


def encoded_nbytes(fmt: WireFormat) -> int:
    """Exact bytes of one source shard's encoded tree (all S destination
    buckets) — matches ``tree_nbytes(encode(...))`` leaf for leaf."""
    S, B = fmt.num_shards, fmt.capacity
    if fmt.codec == "raw":
        key_b = S * B * 4
    else:
        key_b = S * fmt.packed_row_bytes
    val_b = 0
    for dt, elems in fmt.value_leaves:
        itemsize = jnp.dtype(dt).itemsize
        if fmt.codec == "packed":
            per = 1 if itemsize > 1 else itemsize
            val_b += S * B * elems * per
            if _float_leaf(dt):
                val_b += S * 4  # the per-destination f32 scale
        else:
            val_b += S * B * elems * itemsize
    return key_b + val_b


def raw_nbytes(fmt: WireFormat) -> int:
    """Bytes the same buckets take under the ``raw`` codec."""
    return encoded_nbytes(dataclasses.replace(fmt, codec="raw"))


def wire_bytes_per_shard(fmt: WireFormat) -> float:
    """Per-shard bytes actually crossing links in the tiled all-to-all:
    each shard keeps its own bucket, so ``(S-1)/S`` of the encoded tree
    is wire traffic (the standard all-to-all algorithmic factor)."""
    S = fmt.num_shards
    if S <= 1:
        return 0.0
    return encoded_nbytes(fmt) * (S - 1) / S
