"""Elastic scaling: remesh to the surviving device count and reshard.

Recovery path after losing nodes (or adding them):
  1. rebuild a mesh over the live devices (largest (data, model) grid that
     preserves the model axis if possible),
  2. recompute all shardings against the new mesh (sharding.py rules are
     mesh-relative, so this is automatic),
  3. restore LATEST with ``restore(..., shardings=new)`` — device_put
     reshards every leaf onto the new topology.

Tested in tests/integration/test_elastic.py by running save on an 8-device
fake mesh and restoring on a 4-device one in a subprocess.
"""

from __future__ import annotations

import jax

from repro.checkpoint import ckpt
from repro.distributed import sharding as shd


def best_mesh(devices=None, *, model_parallel: int | None = None,
              axis_names=("data", "model")):
    """Largest (data × model) mesh over the live devices.

    With a single axis name (e.g. ``("data",)``), builds the flat
    data-parallel mesh over every live device — the shape
    ``engine.run_resilient`` remeshes to after an elastic host-count
    change.
    """
    import numpy as np

    from jax.sharding import Mesh

    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    if len(axis_names) == 1:
        return Mesh(np.asarray(devices), axis_names)
    if model_parallel is None:
        # keep model axis as large a power of two as fits
        model_parallel = 1
        while model_parallel * 2 <= min(n, 16) and n % (model_parallel * 2) == 0:
            model_parallel *= 2
    data = n // model_parallel
    arr = np.asarray(devices[: data * model_parallel]).reshape(
        data, model_parallel)
    return Mesh(arr, axis_names)


def elastic_restore(ckpt_dir: str, example_tree, mesh, *, fsdp: bool = True,
                    retry=None):
    """Restore the newest VALID checkpoint resharded onto ``mesh``
    (corrupt snapshots are quarantined and skipped by the checksum layer
    in ckpt.restore).  Returns (tree, step).

    ``retry``: optional ``coordination.RetryPolicy`` — a flaky store read
    is retried on its bounded deterministic backoff schedule instead of
    failing the whole elastic restart.
    """
    shardings = shd.param_shardings(example_tree, mesh, fsdp=fsdp)

    def _load():
        return ckpt.restore(ckpt_dir, example_tree, shardings=shardings)

    if retry is None:
        return _load()
    return retry.call(_load, op=f"elastic restore from {ckpt_dir}")
