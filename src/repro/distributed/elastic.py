"""Elastic scaling: remesh to the surviving device count and reshard.

Recovery path after losing nodes (or adding them):
  1. rebuild a mesh over the live devices (largest (data, model) grid that
     preserves the model axis if possible),
  2. recompute all shardings against the new mesh (sharding.py rules are
     mesh-relative, so this is automatic),
  3. restore LATEST with ``restore(..., shardings=new)`` — device_put
     reshards every leaf onto the new topology.

Tested in tests/integration/test_elastic.py by running save on an 8-device
fake mesh and restoring on a 4-device one in a subprocess.
"""

from __future__ import annotations

import jax

from repro.checkpoint import ckpt
from repro.distributed import sharding as shd


def best_mesh(devices=None, *, model_parallel: int | None = None,
              axis_names=("data", "model")):
    """Largest (data × model) mesh over the live devices."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    if model_parallel is None:
        # keep model axis as large a power of two as fits
        model_parallel = 1
        while model_parallel * 2 <= min(n, 16) and n % (model_parallel * 2) == 0:
            model_parallel *= 2
    data = n // model_parallel
    import numpy as np

    arr = np.asarray(devices[: data * model_parallel]).reshape(
        data, model_parallel)
    from jax.sharding import Mesh

    return Mesh(arr, axis_names)


def elastic_restore(ckpt_dir: str, example_tree, mesh, *, fsdp: bool = True):
    """Restore LATEST resharded onto ``mesh``. Returns (tree, step)."""
    shardings = shd.param_shardings(example_tree, mesh, fsdp=fsdp)
    return ckpt.restore(ckpt_dir, example_tree, shardings=shardings)
