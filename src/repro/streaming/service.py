"""MapReduceService: a long-lived, continuously-ingesting MapReduce.

The batch engine answers "fold these N items"; the production posture for
millions of users is a service that absorbs micro-batches *forever* and
answers live queries.  The paper's semantic argument carries over intact:
the derived combiner is a monoid, so partial tables can be folded into and
merged at any time — merge-on-arrival is exact, not approximate.

Staging: the service compiles ONCE through the PR 6 staged path
(``lower().optimize().compile()`` at mode="streaming").  The compiled
artifact is a pure AOT ingest executable
``(state, padded_items, n_valid) -> state`` sized to ``batch_capacity``;
every ``ingest()`` thereafter is a plain dispatch — zero re-traces,
re-tunes and re-compiles, assertable via ``plan_cache.stats_snapshot()``.
Micro-batches smaller than the capacity are padded and masked (pad
emissions go to the sentinel key), so ONE executable serves every batch
size — the pow2-bucket serving idea taken to its streaming limit.

Consistency: the whole mutable service state lives in one immutable
:class:`_ServiceState` record behind a single reference.  ``ingest()``
builds a *new* record (JAX arrays are immutable — the old tables are
never written through) and swaps the reference; ``snapshot()`` reads the
reference once and works off that frozen view.  That is the
double-buffered table swap: snapshots are consistent without pausing
ingestion and without copying tables.

Durability: every ``ckpt_every`` batches the slot states are snapshotted
atomically via ``checkpoint/ckpt.py`` (tmp + ``os.replace``), keyed by
the monotonically increasing batch id.  ``restore()`` reloads the newest
complete snapshot bitwise, so a restarted service continues exactly where
the checkpoint was cut — the same partial-aggregate argument that made
``run_resilient`` recovery exact.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core import engine as eng
from repro.core import plan_cache as pc
from repro.core.api import ExecutionOptions, MapReduce, MapReduceResult
from repro.streaming.windows import Window


class ServiceFailedError(RuntimeError):
    """The service was marked failed (fatal ingestion-worker death or an
    explicit ``fail()``); ingestion is refused but ``snapshot()`` keeps
    serving the last consistent state — readers outlive a broken writer
    path, and a warm ``restore()`` clears the mark."""


@dataclasses.dataclass(frozen=True)
class _ServiceState:
    """One immutable generation of the service: swap-on-ingest."""

    slots: tuple  # per-window-slot carried combiner states
    batch_id: int  # micro-batches ingested so far (monotonic)
    n_items: int  # items ingested so far


class MapReduceService:
    """Continuous-ingestion MapReduce over a staged, compiled-once plan.

    Build via :meth:`MapReduce.serve`::

        mr = MapReduce(app, streaming=True)
        svc = mr.serve(batch_capacity=512, window=sliding(8, 2),
                       ckpt_dir="/ckpts", ckpt_every=16)
        svc.ingest(items)                # folds one micro-batch
        res = svc.snapshot()             # live MapReduceResult, no pause

    ``window=None`` aggregates globally (nothing ever expires); a
    :class:`~repro.streaming.Window` bounds results to the trailing
    micro-batches via ring-buffered per-slot tables (see windows.py).
    Windowed serving requires the derived combiner's partials to be
    mergeable (``derivation.mergeable_partials``) — the per-slot partials
    are merged at query time.
    """

    def __init__(self, mr: MapReduce, *, batch_capacity: int,
                 window: Window | None = None,
                 options: ExecutionOptions | None = None,
                 item_spec: Any = None,
                 ckpt_dir: str | None = None, ckpt_every: int = 0,
                 keep_ckpts: int = 3, retry_policy: Any = None):
        if batch_capacity <= 0:
            raise ValueError("batch_capacity must be positive")
        if mr.plan.flow != "stream":
            raise ValueError(
                f"MapReduceService needs the stream flow (micro-batches "
                f"fold into its carried holder tables); this plan chose "
                f"{mr.plan.flow!r} — construct MapReduce(app, "
                f"streaming=True)")
        d = mr.plan.derivation
        if (window is not None and d is not None
                and not d.mergeable_partials):
            raise ValueError(
                "windowed serving merges per-slot partial tables at query "
                "time, but this combiner's partials are not mergeable "
                f"({mr.plan.spec.describe}); use window=None (global "
                "aggregation) or a merge-capable reducer")
        self.mr = mr
        self.app = mr.app
        self.spec = mr.plan.spec
        self.batch_capacity = int(batch_capacity)
        self.window = window
        cap = max(self.app.emit_capacity, 1)
        opts = options if options is not None else ExecutionOptions()
        if opts.chunk_pairs is None:
            # one fold per ingest: the chunk is the micro-batch itself, so
            # N ingests replay exactly the chunk sequence of a batch run
            # with this chunk_pairs — the bitwise-parity alignment
            opts = dataclasses.replace(
                opts, chunk_pairs=self.batch_capacity * cap)
        self.options = opts
        self._ckpt_dir = (ckpt.service_state_dir(ckpt_dir)
                          if ckpt_dir is not None else None)
        self.ckpt_every = int(ckpt_every)
        self.keep_ckpts = int(keep_ckpts)
        self.retry_policy = retry_policy
        self._lock = threading.Lock()  # serializes writers, never readers
        self._compiled = None
        self._state: _ServiceState | None = None
        self._failed: BaseException | None = None
        #: control-plane event lines (retries/backoffs on checkpoint and
        #: restore, failure marks) — shown by explain(), mirrored onto
        #: the compiled plan's ``recovery`` diagnostics
        self.events: list[str] = []
        if item_spec is not None:
            self._compile(item_spec)

    # -- failure state ------------------------------------------------------

    def fail(self, exc: BaseException) -> None:
        """Mark the service failed (called by the ingestion front end on
        fatal worker death).  Ingestion is refused from here on;
        snapshots keep serving the last published state."""
        self._failed = exc
        self._record(f"service marked FAILED: {type(exc).__name__}: {exc}; "
                     f"snapshots still serve the last consistent state")

    @property
    def failed(self) -> BaseException | None:
        """The failure the service was marked with, or None."""
        return self._failed

    def _record(self, line: str) -> None:
        self.events.append(line)
        if self._compiled is not None:
            self._compiled.plan.recovery += (line,)

    def _retried(self, op: str, fn):
        if self.retry_policy is None:
            return fn()
        return self.retry_policy.call(fn, op=op, on_event=self._record)

    # -- staging ------------------------------------------------------------

    @property
    def n_slots(self) -> int:
        return self.window.n_slots if self.window is not None else 1

    def _compile(self, item_spec) -> None:
        """Stage and AOT-compile the ingest executable (once)."""
        batch_spec = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(
                (self.batch_capacity,) + tuple(a.shape), a.dtype),
            pc.items_spec_of(item_spec))
        self._compiled = self.mr.lower(
            batch_spec, options=self.options, mode="streaming"
        ).optimize().compile()
        self._state = _ServiceState(
            slots=tuple(self._compiled.init_state()
                        for _ in range(self.n_slots)),
            batch_id=0, n_items=0)

    def _ensure_compiled(self, items) -> None:
        if self._compiled is None:
            self._compile(jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(tuple(a.shape[1:]), a.dtype),
                items))

    # -- ingestion ----------------------------------------------------------

    def ingest(self, items) -> int:
        """Fold one micro-batch (≤ ``batch_capacity`` items) into the live
        tables; returns the batch id (1-based count of batches ingested).

        Thread-safe single-writer: concurrent callers serialize on the
        service lock; snapshots never wait on it."""
        if self._failed is not None:
            raise ServiceFailedError(
                f"service is marked failed "
                f"({type(self._failed).__name__}: {self._failed}); "
                f"snapshot() still serves, restore() a checkpoint to "
                f"resume ingestion") from self._failed
        items = jax.tree.map(jnp.asarray, items)
        n = int(jax.tree.leaves(items)[0].shape[0])
        if n > self.batch_capacity:
            raise ValueError(
                f"micro-batch of {n} items exceeds batch_capacity="
                f"{self.batch_capacity}; split it or raise the capacity")
        self._ensure_compiled(items)
        if n < self.batch_capacity:
            pad = self.batch_capacity - n
            items = jax.tree.map(
                lambda a: jnp.concatenate(
                    [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)]), items)
        with self._lock:
            st = self._state
            b = st.batch_id  # 0-based id of the incoming batch
            slots = list(st.slots)
            if self.window is not None:
                i = self.window.slot_of(b)
                # first batch of a new slide period: re-initialize the
                # slot, overwriting (expiring) the oldest period's tables
                seed = (self._compiled.init_state()
                        if b % self.window.slide == 0 else slots[i])
            else:
                i, seed = 0, slots[0]
            slots[i] = self._compiled.ingest_state(seed, items, n)
            new = _ServiceState(tuple(slots), b + 1, st.n_items + n)
            self._state = new  # atomic publish: snapshots see old or new
            if (self._ckpt_dir is not None and self.ckpt_every > 0
                    and new.batch_id % self.ckpt_every == 0):
                self._checkpoint(new)
        return new.batch_id

    # -- queries ------------------------------------------------------------

    def _live_slots(self, st: _ServiceState) -> list:
        """Live slot states, oldest period first (deterministic merge
        order — what makes restore-then-snapshot bitwise reproducible)."""
        if self.window is None or st.batch_id == 0:
            return [st.slots[0]] if self.window is None else []
        p = self.window.period_of(st.batch_id - 1)  # current period
        live = min(p + 1, self.window.n_slots)
        return [st.slots[(p - k) % self.window.n_slots]
                for k in range(live - 1, -1, -1)]

    def snapshot(self) -> MapReduceResult:
        """Consistent view of the live tables — ingestion is NOT paused.

        Reads the current state reference once (one immutable generation)
        and finalizes/merges off that view; a concurrent ingest publishes
        a new generation without disturbing this one."""
        if self._state is None:
            raise RuntimeError(
                "service not staged yet: ingest a first micro-batch or "
                "construct with item_spec=... to compile eagerly")
        st = self._state
        states = self._live_slots(st)
        if len(states) == 1:
            g = self._compiled.finalize_state(states[0])
            keys, values, counts = g.keys, g.values, g.counts
        elif not states:  # windowed service before any ingest
            g = self._compiled.finalize_state(self._compiled.init_state())
            keys, values, counts = g.keys, g.values, g.counts
        else:
            pairs = [self._compiled.state_tables(s) for s in states]
            keys, values, counts = eng.merge_partial_tables(
                self.app, self.spec,
                [t for t, _ in pairs], [c for _, c in pairs])
        return MapReduceResult(keys, values, counts,
                               plan=self._compiled.plan,
                               batch_id=st.batch_id)

    @property
    def batch_id(self) -> int:
        """Micro-batches ingested so far."""
        return self._state.batch_id if self._state is not None else 0

    @property
    def n_items(self) -> int:
        """Items ingested so far."""
        return self._state.n_items if self._state is not None else 0

    # -- durability ---------------------------------------------------------

    def _state_tree(self, st: _ServiceState) -> dict:
        return {"slots": list(st.slots),
                "meta": np.asarray([st.batch_id, st.n_items], np.int64)}

    def _checkpoint(self, st: _ServiceState) -> None:
        self._retried(
            f"checkpoint batch {st.batch_id}",
            lambda: ckpt.save(self._ckpt_dir, st.batch_id,
                              self._state_tree(st), keep=self.keep_ckpts))

    def checkpoint(self) -> str:
        """Snapshot the current state to the checkpoint dir now (atomic);
        returns the written path."""
        if self._ckpt_dir is None:
            raise RuntimeError("service was built without ckpt_dir")
        if self._state is None:
            raise RuntimeError("nothing to checkpoint: service not staged")
        with self._lock:
            st = self._state
            return self._retried(
                f"checkpoint batch {st.batch_id}",
                lambda: ckpt.save(self._ckpt_dir, st.batch_id,
                                  self._state_tree(st),
                                  keep=self.keep_ckpts))

    def restore(self, ckpt_dir: str | None = None,
                *, step: int | None = None) -> int:
        """Warm restart: load the newest VALID checkpoint (or ``step``)
        and resume bitwise-identical to the service that wrote it.

        Integrity: every snapshot is checksummed (checkpoint/ckpt.py).
        With an explicit ``step``, a torn or corrupt snapshot raises
        :class:`~repro.checkpoint.ckpt.CheckpointCorruptError` naming the
        step and path (the artifact is quarantined to ``*.corrupt``).
        With ``step=None``, corrupt candidates are quarantined and
        skipped and the newest VALID snapshot is restored — a torn
        newest write degrades to the previous snapshot instead of
        crashing the restart.  ``retry_policy`` (if set) retries flaky
        store reads on its bounded deterministic backoff.

        The service must be staged first (construct with ``item_spec=``,
        or over the same app after one ingest) so the state structure is
        known.  A successful restore clears a ``failed`` mark.  Returns
        the restored batch id."""
        d = (ckpt.service_state_dir(ckpt_dir) if ckpt_dir is not None
             else self._ckpt_dir)
        if d is None:
            raise RuntimeError("no checkpoint dir: pass ckpt_dir=...")
        if self._compiled is None:
            raise RuntimeError(
                "service not staged: construct with item_spec=... so the "
                "carried-state structure is known before restore")
        example = self._state_tree(_ServiceState(
            slots=tuple(self._compiled.init_state()
                        for _ in range(self.n_slots)),
            batch_id=0, n_items=0))
        tree, step = self._retried(
            f"service restore from {d}",
            lambda: ckpt.restore(d, example, step=step))
        with self._lock:
            self._state = _ServiceState(
                slots=tuple(tree["slots"]),
                batch_id=int(tree["meta"][0]),
                n_items=int(tree["meta"][1]))
            if self._failed is not None:
                self._record(f"service failure mark cleared by restore of "
                             f"batch {step}")
                self._failed = None
        return step

    # -- introspection -------------------------------------------------------

    def explain(self) -> str:
        """The service's decision record, one format with the batch entry
        points: the compiled plan (flow, combiner, tiling, plan-cache and
        compiled-cache provenance), then the serving configuration —
        window, table residency (roofline model), checkpoint cadence."""
        from repro.roofline import analysis

        lines = []
        if self._compiled is not None:
            lines.append(self._compiled.explain())
        else:
            lines.append(self.mr.explain())
            lines.append("mode: streaming (not staged yet — no item spec)")
        cap = max(self.app.emit_capacity, 1)
        lines.append(
            f"service: batch_capacity={self.batch_capacity} items "
            f"({self.batch_capacity * cap} pairs/ingest), ingested "
            f"{self.batch_id} batches / {self.n_items} items")
        lines.append("window: "
                     + (self.window.describe() if self.window is not None
                        else "global (no expiry)"))
        K = self.app.key_space
        _, holder_bytes = self.spec.holder_width(self.app.value_aval)
        table_bytes = K * (holder_bytes + 4)  # + int32 counts
        value_bytes = int(jnp.dtype(self.app.value_aval.dtype).itemsize
                          * max(1, int(np.prod(self.app.value_aval.shape))))
        peak = analysis.mapreduce_flow_peak_bytes(
            "stream", n_pairs=self.batch_capacity * cap, key_space=K,
            value_bytes=value_bytes, holder_bytes=holder_bytes,
            chunk_pairs=self.options.chunk_pairs)
        lines.append(
            f"residency: holder tables {table_bytes:,} B/slot x "
            f"{self.n_slots} slot(s) = {table_bytes * self.n_slots:,} B "
            f"resident; ~{peak:,.0f} B peak per ingest (roofline stream "
            f"model, K={K})")
        if self._ckpt_dir is not None and self.ckpt_every > 0:
            last = ckpt.latest_step(self._ckpt_dir)
            lines.append(
                f"checkpoint: {self._ckpt_dir} every {self.ckpt_every} "
                f"batches (keep={self.keep_ckpts}, last="
                f"{'none' if last is None else f'batch {last}'})")
        else:
            lines.append("checkpoint: off")
        if self._failed is not None:
            lines.append(f"state: FAILED ({type(self._failed).__name__}: "
                         f"{self._failed}) — snapshots only")
        for ev in self.events:
            lines.append(f"event: {ev}")
        return "\n".join(lines)
