"""Bounded background ingestion front end for :class:`MapReduceService`.

The telemetry-server shape: producers enqueue micro-batches, one worker
thread drains the queue into ``service.ingest`` — so the service's
single-writer lock is never contended and producers get **backpressure**
(a full queue blocks ``put``) instead of unbounded buffering.  Snapshot
queries run concurrently against the service; they never touch the queue.
"""

from __future__ import annotations

import queue
import threading


class IngestionQueue:
    """Single-consumer micro-batch queue feeding a MapReduceService.

    ``put(items)`` enqueues (blocking when ``maxsize`` batches are
    pending); the worker folds them in arrival order, preserving the
    service's deterministic fold sequence.  A worker-side exception is
    re-raised on the next ``put``/``join``/``close``.
    """

    def __init__(self, service, *, maxsize: int = 8):
        self.service = service
        self._q: queue.Queue = queue.Queue(maxsize=maxsize)
        self._err: Exception | None = None
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            batch = self._q.get()
            try:
                if batch is None:
                    return
                if self._err is None:
                    self.service.ingest(batch)
            except Exception as e:  # surfaced on the producer side
                self._err = e
            finally:
                self._q.task_done()

    def _raise_pending(self):
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def put(self, items, *, timeout: float | None = None) -> None:
        """Enqueue one micro-batch; blocks while the queue is full."""
        self._raise_pending()
        self._q.put(items, timeout=timeout)

    @property
    def pending(self) -> int:
        """Batches enqueued but not yet folded (approximate)."""
        return self._q.qsize()

    def join(self) -> None:
        """Block until every enqueued batch has been folded."""
        self._q.join()
        self._raise_pending()

    def close(self) -> None:
        """Drain, stop the worker and surface any pending error."""
        self._q.put(None)
        self._t.join()
        self._raise_pending()
