"""Bounded background ingestion front end for :class:`MapReduceService`.

The telemetry-server shape: producers enqueue micro-batches, one worker
thread drains the queue into ``service.ingest`` — so the service's
single-writer lock is never contended and producers get **backpressure**
(a full queue blocks ``put``) instead of unbounded buffering.  Snapshot
queries run concurrently against the service; they never touch the queue.

Failure posture (the seed bug this file exists to not have): the worker
thread is the only consumer of a BOUNDED queue, so a worker that dies
silently strands every producer blocked in ``put`` forever.  Two distinct
failure classes are handled separately:

* **Poison batch** — ``service.ingest`` rejects one batch (bad shape,
  over capacity).  The batch is quarantined (recorded on ``quarantined``
  with its arrival sequence number and the exception), the error is
  surfaced on the next ``put``/``join``/``close``, and the worker KEEPS
  consuming — later good batches still fold, and the service keeps
  serving snapshots.  One bad producer does not take down the pipeline.
* **Fatal worker death** — anything that escapes the per-batch handler
  (``BaseException``: a ``MemoryError``, interpreter shutdown...).  The
  worker marks itself dead, marks the service failed
  (``service.fail(exc)``), and drains the queue so blocked producers
  unblock; every subsequent ``put`` raises ``WorkerDiedError``
  immediately instead of blocking on a queue nobody will ever drain.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time


class WorkerDiedError(RuntimeError):
    """The ingestion worker thread died fatally; the queue is closed."""


@dataclasses.dataclass(frozen=True)
class PoisonBatch:
    """One quarantined micro-batch: its arrival sequence number (1-based,
    the batch id it WOULD have been folded as next) and the exception
    ``service.ingest`` raised for it."""

    seq: int
    error: Exception


class IngestionQueue:
    """Single-consumer micro-batch queue feeding a MapReduceService.

    ``put(items)`` enqueues (blocking when ``maxsize`` batches are
    pending); the worker folds them in arrival order, preserving the
    service's deterministic fold sequence.  A worker-side exception is
    re-raised on the next ``put``/``join``/``close``; the offending batch
    is quarantined on ``quarantined`` and later batches still fold.
    """

    def __init__(self, service, *, maxsize: int = 8):
        self.service = service
        self._q: queue.Queue = queue.Queue(maxsize=maxsize)
        self._err: Exception | None = None
        self._fatal: BaseException | None = None
        self._dead = False
        self._seq = 0
        self.quarantined: list[PoisonBatch] = []
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        try:
            while True:
                item = self._q.get()
                try:
                    if item is None:
                        return
                    seq, batch = item
                    try:
                        self.service.ingest(batch)
                    except Exception as e:  # poison batch: quarantine it
                        self.quarantined.append(PoisonBatch(seq, e))
                        if self._err is None:  # first error wins the raise
                            self._err = e
                finally:
                    self._q.task_done()
        except BaseException as e:  # fatal: unstrand producers, then die
            self._fatal = e
            self._dead = True
            fail = getattr(self.service, "fail", None)
            if fail is not None:
                try:
                    fail(e)
                except Exception:
                    pass
            while True:  # drain so producers blocked in put() unblock
                try:
                    self._q.get_nowait()
                    self._q.task_done()
                except queue.Empty:
                    return

    def _raise_pending(self):
        if self._fatal is not None:
            raise WorkerDiedError(
                f"ingestion worker died: {type(self._fatal).__name__}: "
                f"{self._fatal}") from self._fatal
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def put(self, items, *, timeout: float | None = None) -> None:
        """Enqueue one micro-batch; blocks while the queue is full.
        Raises the pending poison-batch error if one is queued, or
        ``WorkerDiedError`` immediately (no deadlock) if the worker died.
        """
        self._raise_pending()
        self._seq += 1
        item = (self._seq, items)
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if self._dead:
                self._raise_pending()
            wait = 0.05
            if deadline is not None:
                wait = min(wait, deadline - time.monotonic())
                if wait <= 0:
                    raise queue.Full
            try:
                self._q.put(item, timeout=wait)
                return
            except queue.Full:
                continue

    @property
    def pending(self) -> int:
        """Batches enqueued but not yet folded (approximate)."""
        return self._q.qsize()

    def join(self) -> None:
        """Block until every enqueued batch has been folded."""
        self._q.join()
        self._raise_pending()

    def close(self) -> None:
        """Drain, stop the worker and surface any pending error."""
        if not self._dead:
            try:
                self._q.put(None, timeout=5.0)
            except queue.Full:
                pass
        self._t.join(timeout=10.0)
        self._raise_pending()
