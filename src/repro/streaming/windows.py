"""Window configurations for the continuous-ingestion service.

Windows are **count-based** over micro-batches (the service's unit of
arrival): a window covers the trailing ``size`` micro-batches and advances
every ``slide``.  The implementation is a ring of ``size // slide`` window
*slots*, each an independent carried combiner state accumulating one
slide-period of micro-batches:

* ingest   — the incoming batch folds into the current period's slot; on
  entering a new period the oldest slot is re-initialized first (that
  overwrite IS the expiry — no per-item timestamps, no re-scan).
* query    — the live slots' partial tables are merged with the derived
  combiner's merge (``engine.merge_partial_tables``), oldest first.  The
  monoid-partials argument from the resilience work applies unchanged:
  the merged answer is bitwise the batch answer over exactly the covered
  micro-batches — windowing is exact by construction; only the window
  *boundary* is quantized to ``slide`` batches.

``tumbling(size)`` is the non-overlapping special case (``slide == size``,
one slot): queries during a period see that period's batches only, and the
table resets when the next period starts.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Window:
    """Count-based window: the trailing ``size`` micro-batches, advancing
    every ``slide`` (``slide == size`` -> tumbling).  ``size`` must be a
    multiple of ``slide``; the ring holds ``size // slide`` slots."""

    size: int
    slide: int

    def __post_init__(self):
        if self.size <= 0 or self.slide <= 0:
            raise ValueError(f"window size/slide must be positive, got "
                             f"size={self.size} slide={self.slide}")
        if self.size % self.slide != 0:
            raise ValueError(f"window size must be a multiple of slide "
                             f"(ring-of-slots expiry), got size={self.size} "
                             f"slide={self.slide}")

    @property
    def n_slots(self) -> int:
        return self.size // self.slide

    def period_of(self, batch_id: int) -> int:
        """Slide period the 0-based ``batch_id`` falls in."""
        return batch_id // self.slide

    def slot_of(self, batch_id: int) -> int:
        """Ring slot the 0-based ``batch_id`` folds into."""
        return self.period_of(batch_id) % self.n_slots

    def describe(self) -> str:
        kind = "tumbling" if self.slide == self.size else "sliding"
        return (f"{kind} size={self.size} slide={self.slide} batches "
                f"({self.n_slots} slot(s); expiry at slide granularity)")


def tumbling(size: int) -> Window:
    """Non-overlapping window of ``size`` micro-batches (one slot)."""
    return Window(size=size, slide=size)


def sliding(size: int, slide: int) -> Window:
    """Overlapping window: trailing ``size`` batches, advancing every
    ``slide`` (``size // slide`` ring slots)."""
    return Window(size=size, slide=slide)
