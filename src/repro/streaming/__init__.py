"""Continuous-ingestion streaming service over the staged MapReduce plan.

``MapReduce(app, streaming=True).serve(batch_capacity=...)`` stages the
plan once and returns a :class:`MapReduceService`: micro-batches fold
incrementally into persistent holder tables (bitwise the batch answer),
with windowed aggregation (:func:`tumbling` / :func:`sliding`), live
:meth:`~MapReduceService.snapshot` queries, and checkpointed warm
restarts.  :class:`IngestionQueue` is the bounded background front end;
a poison batch is quarantined (:class:`PoisonBatch`), a fatal worker
death surfaces as :class:`WorkerDiedError` and marks the service failed
(:class:`ServiceFailedError` on further ingests — snapshots keep
serving).
"""

from repro.streaming.ingest import IngestionQueue, PoisonBatch, \
    WorkerDiedError
from repro.streaming.service import MapReduceService, ServiceFailedError
from repro.streaming.windows import Window, sliding, tumbling

__all__ = [
    "MapReduceService",
    "ServiceFailedError",
    "IngestionQueue",
    "PoisonBatch",
    "WorkerDiedError",
    "Window",
    "tumbling",
    "sliding",
]
