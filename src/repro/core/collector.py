"""Intermediate (key, value) collectors — the two execution flows.

Paper §2.4/§3.1: MR4J's collector is a thread-safe hash table; a new key
instantiates a new value *list* (reduce flow) or a new *holder* (combine
flow).  The TPU-native equivalents:

* :func:`reduce_flow`  — **materializing collector**: the full pair stream is
  written out, sorted by key, grouped, and the user reduce is applied per key
  over gathered padded windows.  Costs O(N) pair buffer + a sort + an
  O(K·Lmax) window gather — the HBM analogue of the JVM heap pressure the
  paper measures in Figs 8/9.

* :func:`combine_flow` — **combining collector**: each emitted value is folded
  into a per-key holder table at emit time.  O(K) state, single pass, no sort,
  no reduce phase.  Lowers to (in preference order)
    - MXU one-hot matmul      (additive monoids, small key space),
    - ``table.at[keys].op()`` scatter-combine (any scatter monoid),
    - vectorized first-occurrence gather (the first-element idiom),
    - sorted segment fold     (generic streaming combiners, e.g. scan folds).

Keys are dense int32 ids in ``[0, key_space)``; invalid emissions use the
sentinel ``key_space`` and are dropped by out-of-bounds scatter semantics.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import combiner as C

SENTINEL = "sentinel"  # invalid-pair key == key_space


@dataclasses.dataclass(frozen=True)
class PairStream:
    """Flat emitted pairs. keys[i] == key_space marks an invalid slot."""

    keys: jax.Array  # [N] int32 in [0, key_space]
    values: jax.Array  # [N, *value_shape]
    key_space: int

    @property
    def valid(self) -> jax.Array:
        return self.keys < self.key_space


@dataclasses.dataclass(frozen=True)
class Grouped:
    """Result table over the dense key space."""

    keys: jax.Array  # [K] == arange(K)
    values: Any  # [K, *out_shape] (pytree)
    counts: jax.Array  # [K] int32; count == 0 -> key never emitted


# ---------------------------------------------------------------------------
# Reduce flow (baseline; the paper's un-optimized execution flow)
# ---------------------------------------------------------------------------


def reduce_flow(
    reduce_fn: Callable,
    stream: PairStream,
    *,
    max_values_per_key: int,
    pad_value,
) -> Grouped:
    """Materialize → sort → group → per-key reduce.

    ``max_values_per_key`` is the static bound Lmax on values per key (the
    paper's Phoenix buffers have the same role); counts are clipped to it.
    """
    K = stream.key_space
    Lmax = max_values_per_key
    keys = stream.keys
    values = stream.values
    n = keys.shape[0]

    order = jnp.argsort(keys)  # sentinel keys sort last
    skeys = keys[order]
    svals = jax.tree.map(lambda v: v[order], values)

    counts = jnp.bincount(keys, length=K + 1)[:K].astype(jnp.int32)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1].astype(jnp.int32)])

    def pad_tail(v):
        pad_shape = (Lmax,) + v.shape[1:]
        pad = jnp.full(pad_shape, pad_value, v.dtype)
        return jnp.concatenate([v, pad], axis=0)

    svals_p = jax.tree.map(pad_tail, svals)

    def one_key(k, off, cnt):
        def win_of(v):
            w = lax.dynamic_slice_in_dim(v, off, Lmax, axis=0)
            mask = (jnp.arange(Lmax) < cnt)
            bshape = (Lmax,) + (1,) * (w.ndim - 1)
            return jnp.where(mask.reshape(bshape), w,
                             jnp.asarray(pad_value, w.dtype))
        win = jax.tree.map(win_of, svals_p)
        cc = jnp.minimum(cnt, Lmax)
        return reduce_fn(k, win, cc)

    out = jax.vmap(one_key)(jnp.arange(K, dtype=jnp.int32), offsets, counts)
    return Grouped(jnp.arange(K, dtype=jnp.int32), out, counts)


# ---------------------------------------------------------------------------
# Combine flow (the optimizer's execution flow)
# ---------------------------------------------------------------------------


def _premap_stream(spec: C.CombinerSpec, values) -> tuple:
    """vmap the per-value premap over the pair stream."""
    return jax.vmap(spec.premap)(values)


def combine_scatter(spec: C.CombinerSpec, stream: PairStream) -> tuple[Any, jax.Array]:
    """Holder tables via ``table.at[keys].<monoid-op>`` scatter-combine."""
    assert spec.monoids is not None
    K = stream.key_space
    mapped = _premap_stream(spec, stream.values)
    leaf_avals = [jax.ShapeDtypeStruct(m.shape[1:], m.dtype) for m in mapped]
    tables = []
    for mono, chan, aval in zip(spec.monoids, mapped, leaf_avals):
        init = jnp.broadcast_to(mono.identity_like(aval), (K,) + tuple(aval.shape))
        upd = getattr(init.at[stream.keys], mono.scatter_method)
        tables.append(upd(chan, mode="drop"))
    counts = jnp.zeros((K,), jnp.int32).at[stream.keys].add(
        stream.valid.astype(jnp.int32), mode="drop")
    return tuple(tables), counts


def combine_onehot(
    spec: C.CombinerSpec,
    stream: PairStream,
    *,
    onehot_fn: Callable | None = None,
    block_pairs: int = 1024,
) -> tuple[Any, jax.Array]:
    """Additive monoids on the MXU: ``one_hot(keys)ᵀ @ premap(values)``.

    ``onehot_fn(keys, mat, K)`` may be the Pallas kernel (kernels/ops.py);
    defaults to a jnp einsum with the same semantics.
    """
    assert spec.mxu_lowerable
    K = stream.key_space
    mapped = _premap_stream(spec, stream.values)
    counts_chan = stream.valid.astype(jnp.float32)

    def default_onehot(keys, mat, k):
        oh = jax.nn.one_hot(keys, k, dtype=mat.dtype)  # sentinel -> all-zero
        return jnp.einsum("nk,nd->kd", oh, mat)

    f = onehot_fn or default_onehot
    tables = []
    for chan in mapped:
        flat = chan.reshape(chan.shape[0], -1).astype(jnp.float32)
        tab = f(stream.keys, flat, K)
        tables.append(tab.reshape((K,) + chan.shape[1:]).astype(chan.dtype))
    counts = f(stream.keys, counts_chan[:, None], K)[:, 0].astype(jnp.int32)
    return tuple(tables), counts


def combine_first(spec: C.CombinerSpec, stream: PairStream) -> tuple[Any, jax.Array]:
    """First-element idiom, vectorized: scatter-min of arrival order."""
    K = stream.key_space
    n = stream.keys.shape[0]
    mapped = _premap_stream(spec, stream.values)
    order = jnp.arange(n, dtype=jnp.int32)
    first_pos = jnp.full((K,), n, jnp.int32).at[stream.keys].min(
        order, mode="drop")
    safe = jnp.minimum(first_pos, n - 1)
    counts = jnp.zeros((K,), jnp.int32).at[stream.keys].add(
        stream.valid.astype(jnp.int32), mode="drop")
    tables = tuple(chan[safe] for chan in mapped)
    return tables, counts


def combine_segment(spec: C.CombinerSpec, stream: PairStream) -> tuple[Any, jax.Array]:
    """Generic streaming combiner: sort by key, sequential fold per segment.

    Correctness fallback for non-scatter combiners (scan folds, coupled
    holders).  One ``lax.scan`` over the sorted stream; holder written back
    on segment close.
    """
    K = stream.key_space
    n = stream.keys.shape[0]
    order = jnp.argsort(stream.keys)
    skeys = stream.keys[order]
    svals = jax.tree.map(lambda v: v[order], stream.values)

    vaval = jax.tree.map(
        lambda v: jax.ShapeDtypeStruct(v.shape[1:], v.dtype), svals)
    h0 = spec.init(vaval)
    tables0 = jax.tree.map(
        lambda l: jnp.tile(l[None], (K,) + (1,) * jnp.ndim(l)), h0)
    counts0 = jnp.zeros((K,), jnp.int32)

    def step(carry, xs):
        tables, counts = carry
        k, v = xs
        valid = k < K
        ks = jnp.minimum(k, K - 1)
        # holders live in the table: gather the key's holder, fold, scatter
        # back (sequential over the sorted stream, so no conflicts).
        h = jax.tree.map(lambda t: t[ks], tables)
        nk = counts[ks]
        h2 = spec.combine(h, spec.premap(v), nk)
        tables = jax.tree.map(
            lambda t, new, old: t.at[ks].set(jnp.where(valid, new, old)),
            tables, h2, h)
        counts = counts.at[ks].add(valid.astype(jnp.int32))
        return (tables, counts), None

    (tables, counts), _ = lax.scan(step, (tables0, counts0), (skeys, svals))
    return tables, counts


def finalize_tables(spec: C.CombinerSpec, tables, counts, key_space: int) -> Grouped:
    keys = jnp.arange(key_space, dtype=jnp.int32)
    vals = jax.vmap(spec.finalize)(keys, tables, counts)
    return Grouped(keys, vals, counts)


def combine_flow(
    spec: C.CombinerSpec,
    stream: PairStream,
    *,
    impl: str = "auto",
    onehot_fn: Callable | None = None,
    onehot_max_keys: int = 2048,
) -> Grouped:
    """Run the combining collector with the best available lowering."""
    if impl == "auto":
        if spec.strategy == C.STRATEGY_SIZE:
            impl = "scatter"  # counts only; scatter path handles it
        elif spec.strategy == C.STRATEGY_FIRST:
            impl = "first"
        elif (spec.mxu_lowerable and stream.key_space <= onehot_max_keys
              and onehot_fn is not None):
            impl = "onehot"
        elif spec.scatter_lowerable:
            impl = "scatter"
        else:
            impl = "segment"

    if impl == "scatter":
        if spec.strategy == C.STRATEGY_SIZE:
            counts = jnp.zeros((stream.key_space,), jnp.int32).at[
                stream.keys].add(stream.valid.astype(jnp.int32), mode="drop")
            tables = ()
        else:
            tables, counts = combine_scatter(spec, stream)
    elif impl == "onehot":
        tables, counts = combine_onehot(spec, stream, onehot_fn=onehot_fn)
    elif impl == "first":
        tables, counts = combine_first(spec, stream)
    elif impl == "segment":
        tables, counts = combine_segment(spec, stream)
    else:
        raise ValueError(f"unknown combine impl {impl!r}")
    return finalize_tables(spec, tables, counts, stream.key_space)
