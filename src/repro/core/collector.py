"""Intermediate (key, value) collectors — the two execution flows.

Paper §2.4/§3.1: MR4J's collector is a thread-safe hash table; a new key
instantiates a new value *list* (reduce flow) or a new *holder* (combine
flow).  The TPU-native equivalents:

* :func:`reduce_flow`  — **materializing collector**: the full pair stream is
  written out, sorted by key, grouped, and the user reduce is applied per key
  over gathered padded windows.  Costs O(N) pair buffer + a sort + an
  O(K·Lmax) window gather — the HBM analogue of the JVM heap pressure the
  paper measures in Figs 8/9.

* :func:`combine_flow` — **combining collector**: each emitted value is folded
  into a per-key holder table at emit time.  O(K) state, single pass, no sort,
  no reduce phase.  Lowers to (in preference order)
    - MXU one-hot matmul      (additive monoids, small key space),
    - ``table.at[keys].op()`` scatter-combine (any scatter monoid),
    - vectorized first-occurrence gather (the first-element idiom),
    - sorted segment fold     (generic streaming combiners, e.g. scan folds).

Keys are dense int32 ids in ``[0, key_space)``; invalid emissions use the
sentinel ``key_space`` and are dropped by out-of-bounds scatter semantics.
"""

from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import combiner as C

SENTINEL = "sentinel"  # invalid-pair key == key_space

#: legacy one-hot key-space cutoff for the single-shot combine flow (and
#: the onehot_combine kernel's VMEM-resident table envelope); the default
#: for ``combine_flow(onehot_max_keys=...)``.
ONEHOT_MAX_KEYS = 2048


class LoweringFallbackWarning(UserWarning):
    """A collector lowering silently available in principle was not taken.

    Emitted (at trace time, once per compilation) when an MXU-lowerable
    combiner degrades to the exact-scatter fallback — the optimizer's plan
    records the same decision so ``MapReduce.explain()`` shows it."""


def _emit_fallback(msg: str, on_fallback: Callable | None,
                   stacklevel: int = 3) -> None:
    """Route a fallback diagnostic to ``on_fallback`` when given, else warn.

    The engine passes a per-plan callback that warns ONCE per plan and
    appends every message to the plan's diagnostic list, so re-traces of
    the same plan (every chunked scan body, each new input shape) no
    longer spam one :class:`LoweringFallbackWarning` per trace while the
    plan record stays complete."""
    if on_fallback is not None:
        on_fallback(msg)
    else:
        warnings.warn(msg, LoweringFallbackWarning, stacklevel=stacklevel)


@dataclasses.dataclass(frozen=True)
class PairStream:
    """Flat emitted pairs. keys[i] == key_space marks an invalid slot."""

    keys: jax.Array  # [N] int32 in [0, key_space]
    values: jax.Array  # [N, *value_shape]
    key_space: int

    @property
    def valid(self) -> jax.Array:
        return self.keys < self.key_space


@dataclasses.dataclass(frozen=True)
class Grouped:
    """Result table over the dense key space."""

    keys: jax.Array  # [K] == arange(K)
    values: Any  # [K, *out_shape] (pytree)
    counts: jax.Array  # [K] int32; count == 0 -> key never emitted


# ---------------------------------------------------------------------------
# Reduce flow (baseline; the paper's un-optimized execution flow)
# ---------------------------------------------------------------------------


def reduce_flow(
    reduce_fn: Callable,
    stream: PairStream,
    *,
    max_values_per_key: int,
    pad_value,
) -> Grouped:
    """Materialize → sort → group → per-key reduce.

    ``max_values_per_key`` is the static bound Lmax on values per key (the
    paper's Phoenix buffers have the same role); counts are clipped to it.
    """
    K = stream.key_space
    Lmax = max_values_per_key
    keys = stream.keys
    values = stream.values
    n = keys.shape[0]

    order = jnp.argsort(keys)  # sentinel keys sort last
    skeys = keys[order]
    svals = jax.tree.map(lambda v: v[order], values)

    counts = jnp.bincount(keys, length=K + 1)[:K].astype(jnp.int32)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1].astype(jnp.int32)])

    def pad_tail(v):
        pad_shape = (Lmax,) + v.shape[1:]
        pad = jnp.full(pad_shape, pad_value, v.dtype)
        return jnp.concatenate([v, pad], axis=0)

    svals_p = jax.tree.map(pad_tail, svals)

    def one_key(k, off, cnt):
        def win_of(v):
            w = lax.dynamic_slice_in_dim(v, off, Lmax, axis=0)
            mask = (jnp.arange(Lmax) < cnt)
            bshape = (Lmax,) + (1,) * (w.ndim - 1)
            return jnp.where(mask.reshape(bshape), w,
                             jnp.asarray(pad_value, w.dtype))
        win = jax.tree.map(win_of, svals_p)
        cc = jnp.minimum(cnt, Lmax)
        return reduce_fn(k, win, cc)

    out = jax.vmap(one_key)(jnp.arange(K, dtype=jnp.int32), offsets, counts)
    return Grouped(jnp.arange(K, dtype=jnp.int32), out, counts)


# ---------------------------------------------------------------------------
# Combine flow (the optimizer's execution flow)
# ---------------------------------------------------------------------------


def _premap_stream(spec: C.CombinerSpec, values) -> tuple:
    """vmap the per-value premap over the pair stream."""
    return jax.vmap(spec.premap)(values)


def combine_scatter(spec: C.CombinerSpec, stream: PairStream) -> tuple[Any, jax.Array]:
    """Holder tables via ``table.at[keys].<monoid-op>`` scatter-combine."""
    assert spec.monoids is not None
    K = stream.key_space
    mapped = _premap_stream(spec, stream.values)
    leaf_avals = [jax.ShapeDtypeStruct(m.shape[1:], m.dtype) for m in mapped]
    tables = []
    for mono, chan, aval in zip(spec.monoids, mapped, leaf_avals):
        init = jnp.broadcast_to(mono.identity_like(aval), (K,) + tuple(aval.shape))
        upd = getattr(init.at[stream.keys], mono.scatter_method)
        tables.append(upd(chan, mode="drop"))
    counts = jnp.zeros((K,), jnp.int32).at[stream.keys].add(
        stream.valid.astype(jnp.int32), mode="drop")
    return tuple(tables), counts


def combine_onehot(
    spec: C.CombinerSpec,
    stream: PairStream,
    *,
    onehot_fn: Callable | None = None,
    block_pairs: int = 1024,
) -> tuple[Any, jax.Array]:
    """Additive monoids on the MXU: ``one_hot(keys)ᵀ @ premap(values)``.

    ``onehot_fn(keys, mat, K)`` may be the Pallas kernel (kernels/ops.py);
    defaults to a jnp einsum with the same semantics.
    """
    assert spec.mxu_lowerable
    K = stream.key_space
    mapped = _premap_stream(spec, stream.values)

    def default_onehot(keys, mat, k):
        oh = jax.nn.one_hot(keys, k, dtype=mat.dtype)  # sentinel -> all-zero
        return jnp.einsum("nk,nd->kd", oh, mat)

    tables = []
    for chan in mapped:
        if onehot_fn is not None:  # Pallas kernel contract is f32
            acc_dt = jnp.float32
        else:  # integer channels contract exactly in their own dtype
            acc_dt = (chan.dtype if jnp.issubdtype(chan.dtype, jnp.integer)
                      else jnp.float32)
        flat = chan.reshape(chan.shape[0], -1).astype(acc_dt)
        tab = (onehot_fn or default_onehot)(stream.keys, flat, K)
        tables.append(tab.reshape((K,) + chan.shape[1:]).astype(chan.dtype))
    if onehot_fn is not None:
        counts_chan = stream.valid.astype(jnp.float32)
        counts = onehot_fn(stream.keys, counts_chan[:, None],
                           K)[:, 0].astype(jnp.int32)
    else:
        counts = default_onehot(stream.keys,
                                stream.valid.astype(jnp.int32)[:, None],
                                K)[:, 0]
    return tuple(tables), counts


def combine_first(spec: C.CombinerSpec, stream: PairStream) -> tuple[Any, jax.Array]:
    """First-element idiom, vectorized: scatter-min of arrival order."""
    K = stream.key_space
    n = stream.keys.shape[0]
    mapped = _premap_stream(spec, stream.values)
    order = jnp.arange(n, dtype=jnp.int32)
    first_pos = jnp.full((K,), n, jnp.int32).at[stream.keys].min(
        order, mode="drop")
    safe = jnp.minimum(first_pos, n - 1)
    counts = jnp.zeros((K,), jnp.int32).at[stream.keys].add(
        stream.valid.astype(jnp.int32), mode="drop")
    tables = tuple(chan[safe] for chan in mapped)
    return tables, counts


def _sequential_fold(spec: C.CombinerSpec, tables, counts, keys, values
                     ) -> tuple[Any, jax.Array]:
    """Fold a pair stream into carried holder tables, one pair at a time.

    One ``lax.scan`` over the pairs; each step gathers the key's holder row,
    applies ``spec.combine`` and writes the row back (a dynamic-update-slice,
    in-place on TPU).  Correctness fallback for combiners with coupled
    holders (scan folds, logsumexp) that have no dense/monoid lowering.
    """
    K = counts.shape[0]

    def step(carry, xs):
        tables, counts = carry
        k, v = xs
        valid = k < K
        ks = jnp.minimum(k, K - 1)
        # holders live in the table: gather the key's holder, fold, write
        # back (sequential over the stream, so no conflicts).
        h = jax.tree.map(lambda t: t[ks], tables)
        nk = counts[ks]
        h2 = spec.combine(h, spec.premap(v), nk)
        tables = jax.tree.map(
            lambda t, new, old: t.at[ks].set(jnp.where(valid, new, old)),
            tables, h2, h)
        counts = counts.at[ks].add(valid.astype(jnp.int32))
        return (tables, counts), None

    (tables, counts), _ = lax.scan(step, (tables, counts), (keys, values))
    return tables, counts


def combine_segment(spec: C.CombinerSpec, stream: PairStream) -> tuple[Any, jax.Array]:
    """Generic streaming combiner: sort by key, sequential fold per segment.

    Correctness fallback for non-scatter combiners (scan folds, coupled
    holders).  One ``lax.scan`` over the sorted stream; holder written back
    on segment close.
    """
    order = jnp.argsort(stream.keys)
    skeys = stream.keys[order]
    svals = jax.tree.map(lambda v: v[order], stream.values)

    vaval = jax.tree.map(
        lambda v: jax.ShapeDtypeStruct(v.shape[1:], v.dtype), svals)
    tables0, counts0 = spec.init_tables(stream.key_space, vaval)
    return _sequential_fold(spec, tables0, counts0, skeys, svals)


def finalize_tables(spec: C.CombinerSpec, tables, counts, key_space: int) -> Grouped:
    keys = jnp.arange(key_space, dtype=jnp.int32)
    vals = jax.vmap(spec.finalize)(keys, tables, counts)
    return Grouped(keys, vals, counts)


def combine_flow(
    spec: C.CombinerSpec,
    stream: PairStream,
    *,
    impl: str = "auto",
    onehot_fn: Callable | None = None,
    onehot_max_keys: int = ONEHOT_MAX_KEYS,
    on_fallback: Callable | None = None,
) -> Grouped:
    """Run the combining collector with the best available lowering.

    One-hot eligibility: the legacy key-space cutoff (``K <=
    onehot_max_keys``, where materializing the ``[N, K]`` expansion is the
    combine flow's documented cost) — OR, new in PR 2, ANY key space while
    the pair count stays inside the fused-contraction regime
    (``N <= ADDITIVE_FOLD_PAIRS_FUSED``, where XLA keeps the one-hot
    on-chip), so large-K low-N workloads no longer silently hit the
    scatter fallback.  When neither holds, the single-shot combine flow
    cannot keep the expansion affordable (exactly what the chunked
    streaming flow fixes) and it degrades to scatter with a
    :class:`LoweringFallbackWarning`.
    """
    if impl == "auto":
        n = stream.keys.shape[0]
        # the fused-regime widening applies to the pure-JAX einsum only:
        # the onehot_combine kernel has no key-block axis, so past the
        # legacy cutoff its [K, Td] table would outgrow VMEM.
        onehot_ok = (stream.key_space <= onehot_max_keys
                     or (onehot_fn is None
                         and n <= ADDITIVE_FOLD_PAIRS_FUSED))
        if spec.strategy == C.STRATEGY_SIZE:
            impl = "scatter"  # counts only; scatter path handles it
        elif spec.strategy == C.STRATEGY_FIRST:
            impl = "first"
        elif spec.mxu_lowerable and onehot_ok:
            # MXU-native for additive monoids; without a Pallas kernel the
            # jnp einsum default applies — still preferable to the scatter
            # path, which XLA:CPU serializes into a per-pair while loop.
            impl = "onehot"
        elif spec.scatter_lowerable:
            if spec.mxu_lowerable:
                if onehot_fn is not None:
                    reason = (f"key_space={stream.key_space} > "
                              f"{onehot_max_keys} exceeds the "
                              f"onehot_combine kernel's VMEM-resident "
                              f"table cutoff")
                else:
                    reason = (f"key_space={stream.key_space} > "
                              f"{onehot_max_keys} and {n} pairs exceed "
                              f"the fused one-hot contraction regime "
                              f"(N <= {ADDITIVE_FOLD_PAIRS_FUSED})")
                _emit_fallback(
                    f"combine flow: {reason}; degrading to the exact "
                    f"scatter fallback (serialized on XLA:CPU). The "
                    f"chunked stream flow keeps large pair streams on the "
                    f"one-hot path.", on_fallback)
            impl = "scatter"
        else:
            impl = "segment"

    if impl == "scatter":
        if spec.strategy == C.STRATEGY_SIZE:
            counts = jnp.zeros((stream.key_space,), jnp.int32).at[
                stream.keys].add(stream.valid.astype(jnp.int32), mode="drop")
            tables = ()
        else:
            tables, counts = combine_scatter(spec, stream)
    elif impl == "onehot":
        tables, counts = combine_onehot(spec, stream, onehot_fn=onehot_fn)
    elif impl == "first":
        tables, counts = combine_first(spec, stream)
    elif impl == "segment":
        tables, counts = combine_segment(spec, stream)
    else:
        raise ValueError(f"unknown combine impl {impl!r}")
    return finalize_tables(spec, tables, counts, stream.key_space)


# ---------------------------------------------------------------------------
# Streaming combine flow (map+combine fusion)
# ---------------------------------------------------------------------------


#: largest chunk_pairs × key_block masked expansion (mask elements) the
#: non-additive dense folds may materialize per chunk (64 MB at f32).  Key
#: blocking divides the expansion — a blocked fold materializes one
#: [chunk, key_block] mask at a time — so large-K apps stay on the dense
#: path by shrinking the block instead of regressing to serialized scatters.
DENSE_FOLD_ELEMS_BUDGET = 1 << 24

#: largest per-fold pair count for which the pure-JAX one-hot contraction
#: stays scatter-free AND on-chip: XLA's dot strength reduction keeps the
#: ``[N, K]`` one-hot fused into the contraction (never materialized in
#: HBM) while the reduction axis N is small — measured on XLA:CPU the
#: regime holds to N≈3072 for ANY key space and breaks at N=4096, where
#: the full expansion round-trips HBM.  The streaming flow's chunking is
#: what keeps every fold inside this regime (the legacy combine flow
#: cannot: it contracts all N pairs at once).  The Pallas kernels are
#: exempt — their one-hot tile is VMEM-resident by construction.
ADDITIVE_FOLD_PAIRS_FUSED = 2048


def stream_mode(spec: C.CombinerSpec, *, dense_ok: bool = True,
                additive_ok: bool | None = None) -> str:
    """Pick the per-chunk fold lowering for the streaming collector.

    ``dense_ok`` gates the masked-expansion folds (max/min/mul/bool);
    ``additive_ok`` gates the one-hot matmul fold (defaults to ``dense_ok``
    for backward compatibility — the budgets differ, see above).
    """
    if additive_ok is None:
        additive_ok = dense_ok
    if spec.strategy == C.STRATEGY_SIZE:
        return "size"
    if spec.strategy == C.STRATEGY_FIRST:
        return "first"
    if spec.mxu_lowerable and additive_ok:
        return "additive"
    if spec.scatter_lowerable:
        return "dense" if dense_ok else "scatter"
    return "sequential"


def pow2_floor(x: int) -> int:
    """Largest power of two <= max(x, 1)."""
    return 1 << (max(int(x), 1).bit_length() - 1)


def choose_dense_key_block(key_space: int, chunk_pairs: int | None,
                     *, budget: int = DENSE_FOLD_ELEMS_BUDGET) -> int:
    """Largest power-of-two key block whose ``chunk × block`` masked
    expansion fits ``budget``; ``key_space`` itself when no blocking is
    needed.  Floor of 8 keys (the masked fold needs a non-trivial tile)."""
    if chunk_pairs is None or chunk_pairs * key_space <= budget:
        return key_space
    return pow2_floor(max(budget // max(chunk_pairs, 1), 8))


class StreamCombiner:
    """Chunked scatter-free fold of a pair stream into carried holder tables.

    The engine's streaming flow threads ``state`` through a ``lax.scan`` over
    map chunks; :meth:`fold_chunk` folds one chunk's emitted pairs into the
    state.  The emitted-pair buffer therefore only ever exists one chunk at a
    time — the fused version of the paper's combining collector ("the combine
    happens at emit time"), which is what un-inverts the Figs 8/9 bytes
    story: the legacy combine flow materialized the full ``N × capacity``
    pair buffer before folding.

    Per-chunk lowerings (dense/scatter-free wherever the chunk × key-space
    expansion fits :data:`DENSE_FOLD_ELEMS_BUDGET` — a per-pair table
    scatter is what XLA:CPU serializes into an O(N·K)-bytes while loop):

    * additive — one fused ``one_hot(keys)ᵀ @ [channels | 1]`` matmul per
      chunk into an f32 accumulator ``[K, ΣD + 1]``; the trailing ones
      column carries the counts, so the one-hot matrix is touched once.
      ``fold_fn(keys, mat, acc)`` may be the Pallas grid-accumulation kernel
      (kernels/ops.onehot_fold); defaults to a pure-JAX dot (CPU fallback).
    * dense    — per-monoid identity-masked reduction over the chunk axis,
      merged into the tables with the monoid op (max/min/mul/bool).
      ``monoid_fold_fn(keys, mat, acc, op)`` may be the Pallas chunk kernel.
    * first    — vectorized first-occurrence gather, kept only where the
      carried count is still zero.
    * size     — counts only.
    * scatter  — exact ``table.at[keys].<op>`` folds, selected only when the
      scatter-free lowerings cannot stay on-chip (pure-JAX additive folds:
      ``chunk`` beyond :data:`ADDITIVE_FOLD_PAIRS_FUSED` — the Pallas
      kernel path has no such limit; masked folds: ``chunk × key_block``
      beyond :data:`DENSE_FOLD_ELEMS_BUDGET` at the minimum block).  Emits
      :class:`LoweringFallbackWarning` when an MXU-lowerable spec degrades
      this way.
    * sequential — per-pair gather/combine/write-back scan (coupled holders).

    ``key_block`` partitions the ``[K, D]`` holder tables into
    ``ceil(K / key_block)`` key blocks: the dense folds materialize (and the
    Pallas kernels keep VMEM-resident) one block at a time, so large key
    spaces keep the scatter-free lowerings.  ``None`` means unblocked.
    ``mode`` forces a specific fold lowering (benchmark A/B hook).
    """

    def __init__(self, spec: C.CombinerSpec, key_space: int, value_aval,
                 *, fold_fn: Callable | None = None,
                 monoid_fold_fn: Callable | None = None,
                 chunk_pairs: int | None = None,
                 key_block: int | None = None,
                 mode: str | None = None,
                 on_fallback: Callable | None = None):
        self.spec = spec
        self.key_space = key_space
        self.value_aval = value_aval
        self.fold_fn = fold_fn
        self.monoid_fold_fn = monoid_fold_fn
        if key_block is not None:
            key_block = max(1, min(int(key_block), key_space))
            if key_block == key_space:
                key_block = None  # single block == unblocked
        self.key_block = key_block
        eff_block = key_block if key_block is not None else key_space
        holder = spec.holder_avals(value_aval)
        self._holder_leaves, self._holder_treedef = jax.tree.flatten(holder)
        # kernel-path exemptions from the pure-JAX budgets apply only when
        # the kernels will actually run: the fused additive kernel needs
        # all-float holders (see _fused_acc), the monoid kernel f32 tables
        # and add/max/min monoids (see _fold_dense's per-leaf kern_ok).
        kernel_additive = (fold_fn is not None
                          and spec.kernel_additive_ok(value_aval))
        kernel_monoid = (monoid_fold_fn is not None
                         and spec.kernel_monoid_ok(value_aval))
        self._dense_ok = (kernel_monoid or chunk_pairs is None or
                          chunk_pairs * eff_block <= DENSE_FOLD_ELEMS_BUDGET)
        # the Pallas fold kernel keeps its one-hot tile VMEM-resident at any
        # chunk size; the pure-JAX contraction stays fused (on-chip) only
        # while the per-fold pair count is inside the fused regime.
        additive_ok = (kernel_additive or chunk_pairs is None or
                       chunk_pairs <= ADDITIVE_FOLD_PAIRS_FUSED)
        self.mode = (mode if mode is not None else
                     stream_mode(spec, dense_ok=self._dense_ok,
                                 additive_ok=additive_ok))
        if mode is None and spec.mxu_lowerable and self.mode == "scatter":
            _emit_fallback(
                f"stream flow: dense fold budgets exceeded at key_space="
                f"{key_space}, chunk_pairs={chunk_pairs}, key_block="
                f"{eff_block}; degrading to the exact scatter fold "
                f"(serialized on XLA:CPU). Shrink stream_chunk_pairs or the "
                f"key block.", on_fallback)

    # -- state ---------------------------------------------------------------

    @property
    def _fused_acc(self) -> bool:
        # the Pallas fold kernel folds all channels + the counts column in
        # one grid-accumulated matmul, so its carry is one f32 matrix.
        # Float holders only: an f32 running accumulator caps exact integer
        # accumulation at 2^24 per key, while the per-leaf path below adds
        # exact per-chunk deltas into tables of the holder's own dtype.
        # (The fused counts column shares the 2^24-pairs-per-key bound.)
        return (self.mode == "additive" and self.fold_fn is not None
                and all(jnp.issubdtype(l.dtype, jnp.floating)
                        for l in self._holder_leaves))

    def init_state(self):
        if self.mode == "size":
            return jnp.zeros((self.key_space,), jnp.int32)
        if self._fused_acc:
            d_tot = sum(int(np.prod(l.shape)) for l in self._holder_leaves)
            return jnp.zeros((self.key_space, d_tot + 1), jnp.float32)
        return self.spec.init_tables(self.key_space, self.value_aval)

    def tables_counts(self, state) -> tuple[Any, jax.Array]:
        """Un-finalized (tables, counts) from the carried state."""
        if self.mode == "size":
            return (), state
        if self._fused_acc:
            acc = state
            tabs, off = [], 0
            for aval in self._holder_leaves:
                size = int(np.prod(aval.shape))
                tabs.append(acc[:, off:off + size]
                            .reshape((self.key_space,) + tuple(aval.shape))
                            .astype(aval.dtype))
                off += size
            tables = jax.tree.unflatten(self._holder_treedef, tabs)
            return tables, acc[:, -1].astype(jnp.int32)
        return state

    def finalize(self, state) -> Grouped:
        tables, counts = self.tables_counts(state)
        return finalize_tables(self.spec, tables, counts, self.key_space)

    # -- per-chunk folds -----------------------------------------------------

    def _onehot(self, keys: jax.Array, dtype=jnp.float32) -> jax.Array:
        k_iota = jnp.arange(self.key_space, dtype=jnp.int32)
        return (keys[:, None] == k_iota[None, :]).astype(dtype)

    def _block_lows(self) -> tuple[jax.Array, int, int]:
        """(block starts, block size, block count) of the key-block grid."""
        Kb = self.key_block
        nb = -(-self.key_space // Kb)
        return jnp.arange(nb, dtype=jnp.int32) * Kb, Kb, nb

    def _blocked(self, per_block: Callable):
        """Run ``per_block(lo) -> [Kb, ...]`` (or a pytree of such) over
        the key-block grid and reassemble the full ``[K, ...]`` axis.
        ``lax.map`` keeps the blocks sequential, so only one block's dense
        expansion is live at a time — the pure-JAX mirror of the kernels'
        key-block grid axis."""
        lows, Kb, nb = self._block_lows()
        blocks = lax.map(per_block, lows)  # pytree of [nb, Kb, ...]
        return jax.tree.map(
            lambda b: b.reshape((nb * Kb,) + b.shape[2:])[: self.key_space],
            blocks)

    def _block_hits(self, keys: jax.Array, lo: jax.Array) -> jax.Array:
        """[n, Kb] bool hit mask of ``keys`` against block ``[lo, lo+Kb)``.

        Sentinel keys (== key_space) either rebase outside ``[0, Kb)`` or
        land in the padded tail rows that ``_blocked`` crops off."""
        iota = jnp.arange(self.key_block, dtype=jnp.int32)
        return (keys[:, None] - lo) == iota[None, :]

    def _blocked_matmul(self, keys: jax.Array, flat: jax.Array) -> jax.Array:
        """[K, D] per-key sums of ``flat`` rows, one key block at a time."""
        def one(lo):
            oh = self._block_hits(keys, lo).astype(flat.dtype)
            return jnp.einsum("nk,nd->kd", oh, flat)
        return self._blocked(one)

    def _chunk_counts(self, stream: PairStream) -> jax.Array:
        if not self._dense_ok:
            return jnp.zeros((self.key_space,), jnp.int32).at[stream.keys].add(
                stream.valid.astype(jnp.int32), mode="drop")
        if self.key_block is not None:
            ones = stream.valid.astype(jnp.int32)[:, None]
            return self._blocked_matmul(stream.keys, ones)[:, 0]
        return jnp.sum(self._onehot(stream.keys, jnp.int32), axis=0)

    def fold_chunk(self, state, stream: PairStream):
        assert stream.key_space == self.key_space
        if self.mode == "size":
            return state + self._chunk_counts(stream)
        if self._fused_acc:
            n = stream.keys.shape[0]
            mapped = _premap_stream(self.spec, stream.values)
            cols = [l.reshape(n, -1).astype(jnp.float32)
                    for l in jax.tree.leaves(mapped)]
            cols.append(stream.valid.astype(jnp.float32)[:, None])  # counts
            return self.fold_fn(stream.keys, jnp.concatenate(cols, axis=1),
                                state)
        tables, counts = state
        if self.mode == "additive":
            return self._fold_additive(tables, counts, stream)
        if self.mode == "dense":
            return self._fold_dense(tables, counts, stream)
        if self.mode == "scatter":
            return self._fold_scatter(tables, counts, stream)
        if self.mode == "first":
            return self._fold_first(tables, counts, stream)
        return _sequential_fold(self.spec, tables, counts,
                                stream.keys, stream.values)

    def _fold_scatter(self, tables, counts, stream: PairStream):
        # exact large-K fallback: same per-chunk semantics as combine_scatter
        # but folding into the *carried* tables instead of identity ones
        mapped = _premap_stream(self.spec, stream.values)
        out = []
        for mono, tab, chan in zip(self.spec.monoids, jax.tree.leaves(tables),
                                   jax.tree.leaves(mapped)):
            upd = getattr(tab.at[stream.keys], mono.scatter_method)
            out.append(upd(chan.astype(tab.dtype), mode="drop"))
        tables = jax.tree.unflatten(self._holder_treedef, out)
        return tables, counts + self._chunk_counts(stream)

    def _fold_additive(self, tables, counts, stream: PairStream):
        # One ``one_hotᵀ @ channel`` contraction per holder leaf — the same
        # lowering as the legacy one-hot collector, which XLA fuses with the
        # one-hot generation (the [chunk, K] one-hot never reaches HBM; the
        # Pallas fold kernel behaves the same way, building the one-hot tile
        # in VMEM per grid step).  Integer channels contract in the table's
        # own integer dtype — exact over its full range, where an f32
        # contraction would round per-chunk sums beyond 2^24.
        n = stream.keys.shape[0]
        mapped = _premap_stream(self.spec, stream.values)

        def onehot(dtype):
            return jax.nn.one_hot(stream.keys, self.key_space, dtype=dtype)

        def delta_of(flat):
            if self.key_block is not None:  # key-blocked contraction
                return self._blocked_matmul(stream.keys, flat)
            return jnp.einsum("nk,nd->kd", onehot(flat.dtype), flat)

        # Deliberately one contraction per holder leaf plus one for the
        # counts — NOT a single concatenated [n, ΣD+1] matrix like the
        # fused kernel's accumulator: XLA:CPU's dot strength reduction
        # keeps a matvec-shaped (D=1) one-hot contraction fused/on-chip,
        # while a concatenated D>=2 matmat materializes the whole
        # [chunk, K] one-hot in HBM (measured: 0.014 MB vs 4.2 MB at
        # K=512, chunk=1024).  Integer channels also need their own
        # dtype's exact contraction.
        out = []
        for tab, chan in zip(jax.tree.leaves(tables),
                             jax.tree.leaves(mapped)):
            acc_dt = (tab.dtype if jnp.issubdtype(tab.dtype, jnp.integer)
                      else jnp.float32)
            flat = chan.reshape(n, -1).astype(acc_dt)
            delta = delta_of(flat).reshape(tab.shape)
            out.append(tab + delta.astype(tab.dtype))
        tables = jax.tree.unflatten(self._holder_treedef, out)
        counts = counts + delta_of(
            stream.valid.astype(jnp.int32)[:, None])[:, 0]
        return tables, counts

    def _fold_dense(self, tables, counts, stream: PairStream):
        mapped = _premap_stream(self.spec, stream.values)
        chans = jax.tree.leaves(mapped)
        tabs = jax.tree.leaves(tables)
        blocked = self.key_block is not None
        out: list = [None] * len(tabs)
        pending = []  # (slot, monoid, masked_reduce) for one shared sweep

        for i, (mono, tab, chan) in enumerate(zip(self.spec.monoids, tabs,
                                                  chans)):
            kern_ok = (self.monoid_fold_fn is not None
                       and tab.dtype == jnp.float32
                       and mono.name in ("add", "max", "min"))
            if kern_ok:
                n = chan.shape[0]
                red = self.monoid_fold_fn(
                    stream.keys, chan.reshape(n, -1).astype(jnp.float32),
                    tab.reshape(self.key_space, -1), mono.name)
                out[i] = red.reshape(tab.shape).astype(tab.dtype)
                continue

            def masked_reduce(hits, chan=chan, mono=mono,
                              ident=mono.identity(chan.dtype)):
                bshape = hits.shape + (1,) * (chan.ndim - 1)
                masked = jnp.where(hits.reshape(bshape), chan[:, None], ident)
                return mono.dense_reduce(masked, axis=0)

            pending.append((i, mono, masked_reduce))

        # one hit-mask pass serves every pending leaf AND the counts (the
        # blocked sweep builds each [chunk, key_block] mask exactly once —
        # separate lax.map calls cannot be CSE'd by XLA)
        if blocked:
            def per_block(lo):
                hits = self._block_hits(stream.keys, lo)
                return (tuple(mr(hits) for _, _, mr in pending),
                        jnp.sum(hits, axis=0, dtype=jnp.int32))
            reds, cnt = self._blocked(per_block)
        else:
            oh = self._onehot(stream.keys, jnp.bool_)
            reds = tuple(mr(oh) for _, _, mr in pending)
            cnt = jnp.sum(oh, axis=0, dtype=jnp.int32)
        for (i, mono, _), red in zip(pending, reds):
            out[i] = mono.op(tabs[i], red.astype(tabs[i].dtype))
        tables = jax.tree.unflatten(self._holder_treedef, out)
        return tables, counts + cnt

    def _fold_first(self, tables, counts, stream: PairStream):
        n = stream.keys.shape[0]
        mapped = _premap_stream(self.spec, stream.values)
        pos = jnp.arange(n, dtype=jnp.int32)
        if self._dense_ok and self.key_block is not None:
            first_pos = self._blocked(
                lambda lo: jnp.min(jnp.where(
                    self._block_hits(stream.keys, lo), pos[:, None], n),
                    axis=0))
        elif self._dense_ok:
            oh = self._onehot(stream.keys, jnp.bool_)
            first_pos = jnp.min(jnp.where(oh, pos[:, None], n), axis=0)
        else:  # large key space: scatter-min of arrival order (exact)
            first_pos = jnp.full((self.key_space,), n, jnp.int32).at[
                stream.keys].min(pos, mode="drop")
        fresh = (first_pos < n) & (counts == 0)
        safe = jnp.minimum(first_pos, n - 1)
        out = []
        for tab, chan in zip(jax.tree.leaves(tables),
                             jax.tree.leaves(mapped)):
            sel = fresh.reshape((self.key_space,) + (1,) * (chan.ndim - 1))
            out.append(jnp.where(sel, chan[safe], tab))
        tables = jax.tree.unflatten(self._holder_treedef, out)
        return tables, counts + self._chunk_counts(stream)


# ---------------------------------------------------------------------------
# Sort flow (radix-bucketed segment reduce)
# ---------------------------------------------------------------------------


def sort_radix_passes(n: int, key_space: int) -> int:
    """Packed-sort passes the pure-JAX stable key sort needs at this size.

    1 while ``(key, index)`` fits one 31-bit packed word; past that the
    multi-pass radix splits the key into ``31 - idx_bits``-wide digits and
    pays one packed sort per digit (the K = 256k–4M regime at the default
    chunk sizes).  The cost model prices the sort term with this."""
    idx_bits = max(n - 1, 0).bit_length()
    key_bits = max(key_space, 1).bit_length()  # sentinel == key_space
    if key_bits + idx_bits <= 31:
        return 1
    return -(-key_bits // max(31 - idx_bits, 1))


def stable_sort_by_key(keys: jax.Array, key_space: int, *,
                       impl: str = "auto") -> tuple[jax.Array, jax.Array]:
    """Stable key sort of ``keys`` (sentinel == key_space sorts last).

    Returns ``(sorted_keys, order)``.  When ``(key, index)`` fits 31 bits
    the sort runs as ONE int32 sort of the packed words — measurably faster
    on XLA:CPU than the two-operand comparator sort, which is the whole
    wall-clock budget of the pure-JAX sort flow.  Past 31 bits the sort no
    longer silently degrades to the comparator: ``impl="auto"`` runs the
    multi-pass LSD radix — a ``lax.scan`` over digit levels, one packed
    ``(digit, index)`` sort per level (digits are ``31 - idx_bits`` wide,
    so every level keeps the packed fast path; per-level stability makes
    the composition exactly the stable full-key sort).  Measured at
    K=1M, n=16384 the two-level radix is ~4.8× faster than the two-key
    comparator sort it replaces.  ``impl`` forces a lowering for A/B
    benchmarks: "packed" | "radix" | "two_key".  Keys must already be in
    ``[0, key_space]`` (the Emitter guarantees it).
    """
    n = keys.shape[0]
    idx_bits = max(n - 1, 0).bit_length()
    key_bits = max(key_space, 1).bit_length()  # sentinel == key_space
    iota = jnp.arange(n, dtype=jnp.int32)
    if impl == "auto":
        impl = "packed" if key_bits + idx_bits <= 31 else "radix"
    if impl == "packed":
        if key_bits + idx_bits > 31:
            raise ValueError(
                f"packed sort needs key_bits + idx_bits <= 31, got "
                f"{key_bits} + {idx_bits}; use impl='radix'")
        packed = (keys << idx_bits) | iota
        sp = lax.sort(packed)
        return sp >> idx_bits, sp & ((1 << idx_bits) - 1)
    if impl == "two_key":
        sk, order = lax.sort((keys, iota), num_keys=2)  # lexicographic
        return sk, order
    if impl == "radix":
        digit_bits = max(31 - idx_bits, 1)
        levels = -(-key_bits // digit_bits)
        digit_mask = (1 << digit_bits) - 1
        idx_mask = (1 << idx_bits) - 1

        def body(perm, shift):
            digit = (keys[perm] >> shift) & digit_mask
            sp = lax.sort((digit << idx_bits) | iota)
            return perm[sp & idx_mask], None

        shifts = jnp.arange(levels, dtype=jnp.int32) * digit_bits
        perm, _ = lax.scan(body, iota, shifts)
        return keys[perm], perm
    raise ValueError(f"unknown sort impl {impl!r}")


def segmented_scan(op: Callable, flags: jax.Array, vals: jax.Array
                   ) -> jax.Array:
    """Inclusive segmented scan: ``op``-accumulate, restarting at ``flags``.

    ``flags[i]`` marks the start of a new segment.  Standard associative
    lift: ``(fa, va) ⊕ (fb, vb) = (fa|fb, vb if fb else op(va, vb))`` —
    O(N log N) vectorized work, no serial dependency.
    """
    def comb(a, b):
        fa, va = a
        fb, vb = b
        sel = fb.reshape(fb.shape + (1,) * (va.ndim - fb.ndim))
        return fa | fb, jnp.where(sel, vb, op(va, vb))

    _, out = lax.associative_scan(comb, (flags, vals), axis=0)
    return out


def _run_aggregate(mono: C.Monoid, flat: jax.Array, is_start: jax.Array,
                   start_pos: jax.Array) -> jax.Array:
    """Per-run ``mono`` aggregate of a key-sorted channel, valid at run ends.

    Additive monoids use the cumsum-difference form (one pass); the rest go
    through :func:`segmented_scan`.
    """
    if mono.is_additive:
        csum = jnp.cumsum(flat, axis=0)
        prev = jnp.where(
            (start_pos > 0).reshape((-1,) + (1,) * (flat.ndim - 1)),
            csum[jnp.maximum(start_pos - 1, 0)], jnp.zeros_like(flat))
        return csum - prev
    return segmented_scan(mono.op, is_start, flat)


class SortCombiner:
    """Chunked sort-based fold: partition by key, reduce presorted segments.

    The fourth execution flow (``flow="sort"``): each chunk's pairs are
    stably sorted by key, per-run monoid aggregates are computed with
    vectorized segmented scans (cumsum-difference for additive monoids),
    and ONE aggregate per distinct key is merged into the carried holder
    tables with the monoid's scatter method — O(N·log N + K) compute and
    O(N + K) bytes per chunk, versus the one-hot fold's O(N·K) compute.
    This is what dominates the stream flow at large sparse key spaces
    (``core/cost_model.py`` quantifies the crossover).

    Under ``use_kernels`` the per-chunk fold runs as the Pallas radix
    pipeline instead: the (possibly multi-pass hierarchical) histogram +
    bucket-scatter partition (``kernels/radix_partition.py``) feeding the
    existing ``segment_reduce`` kernel leaf-bucket-by-leaf-bucket —
    ``sort_fold_fn(keys, mat, acc, op)`` with the same merge contract as
    the pure-JAX path (the leaf-bucket aggregates land in the carried
    holder tables through the monoid merge, exactly like the single-level
    fold).  The pure-JAX lowering mirrors the hierarchy with the
    multi-pass packed radix sort (``stable_sort_by_key(impl="radix")``,
    a ``lax.scan`` over digit levels) once the packed 31-bit single-sort
    regime runs out; ``sort_impl`` forces a lowering for A/B benchmarks.
    Same interface as :class:`StreamCombiner` (init_state / fold_chunk /
    tables_counts / finalize) so the engine's chunk scan is shared.

    Modes: ``monoid`` (scatter-merge of run aggregates), ``first``
    (run-start gather — the stable sort makes the first pair of each run
    the first-arrived), ``size`` (run lengths only; the payload is never
    gathered), ``sequential`` (coupled holders: sorted sequential fold, the
    chunked form of ``combine_segment``).
    """

    def __init__(self, spec: C.CombinerSpec, key_space: int, value_aval,
                 *, sort_fold_fn: Callable | None = None,
                 mode: str | None = None, sort_impl: str = "auto"):
        self.spec = spec
        self.key_space = key_space
        self.value_aval = value_aval
        self.sort_impl = sort_impl
        holder = spec.holder_avals(value_aval)
        self._holder_leaves, self._holder_treedef = jax.tree.flatten(holder)
        if mode is None:
            if spec.strategy == C.STRATEGY_SIZE:
                mode = "size"
            elif spec.strategy == C.STRATEGY_FIRST:
                mode = "first"
            elif spec.scatter_lowerable:
                mode = "monoid"
            else:
                mode = "sequential"
        self.mode = mode
        # the radix kernel pipeline accumulates f32 and supports
        # add/max/min — same envelope as the chunk monoid-fold kernel
        self._use_kernel = (sort_fold_fn is not None and mode == "monoid"
                            and spec.kernel_monoid_ok(value_aval))
        self.sort_fold_fn = sort_fold_fn

    # -- state (same contract as StreamCombiner) -----------------------------

    @property
    def _fused_acc(self) -> bool:
        # all-additive float-holder specs carry one [K, D+1] f32 matrix so
        # the per-chunk run aggregates land in ONE scatter (channels + the
        # counts column share the cumsum and the merge) — same exactness
        # envelope as StreamCombiner's fused kernel accumulator (2^24
        # integer bound on the f32 counts column).
        return (self.mode == "monoid" and not self._use_kernel
                and self.spec.mxu_lowerable
                and all(jnp.issubdtype(l.dtype, jnp.floating)
                        for l in self._holder_leaves))

    def init_state(self):
        if self.mode == "size":
            return jnp.zeros((self.key_space,), jnp.int32)
        if self._fused_acc:
            d_tot = sum(int(np.prod(l.shape)) for l in self._holder_leaves)
            return jnp.zeros((self.key_space, d_tot + 1), jnp.float32)
        return self.spec.init_tables(self.key_space, self.value_aval)

    def tables_counts(self, state) -> tuple[Any, jax.Array]:
        if self.mode == "size":
            return (), state
        if self._fused_acc:
            acc = state
            tabs, off = [], 0
            for aval in self._holder_leaves:
                size = int(np.prod(aval.shape))
                tabs.append(acc[:, off:off + size]
                            .reshape((self.key_space,) + tuple(aval.shape))
                            .astype(aval.dtype))
                off += size
            tables = jax.tree.unflatten(self._holder_treedef, tabs)
            return tables, acc[:, -1].astype(jnp.int32)
        return state

    def finalize(self, state) -> Grouped:
        tables, counts = self.tables_counts(state)
        return finalize_tables(self.spec, tables, counts, self.key_space)

    # -- per-chunk fold ------------------------------------------------------

    def _run_layout(self, sk: jax.Array):
        """(is_start, start_pos, run_len, end_target) of the sorted runs."""
        n = sk.shape[0]
        pos = jnp.arange(n, dtype=jnp.int32)
        if n == 1:
            is_start = jnp.ones((1,), bool)
            is_end = jnp.ones((1,), bool)
        else:
            change = sk[1:] != sk[:-1]
            is_start = jnp.concatenate([jnp.ones((1,), bool), change])
            is_end = jnp.concatenate([change, jnp.ones((1,), bool)])
        start_pos = lax.cummax(jnp.where(is_start, pos, 0))
        run_len = pos - start_pos + 1
        # run ends scatter to their key; everything else to the dropped
        # sentinel slot.  Sentinel-key runs (== key_space) drop themselves.
        tgt = jnp.where(is_end, sk, self.key_space)
        return is_start, start_pos, run_len, tgt

    def fold_chunk(self, state, stream: PairStream):
        assert stream.key_space == self.key_space
        n = stream.keys.shape[0]
        if n == 0:
            return state
        if self.mode == "monoid" and self._use_kernel:
            return self._fold_kernel(state, stream)
        sk, order = stable_sort_by_key(stream.keys, self.key_space,
                                       impl=self.sort_impl)
        if self.mode == "size":
            _, _, run_len, tgt = self._run_layout(sk)
            return state.at[tgt].add(run_len, mode="drop")
        if self._fused_acc:
            svals = jax.tree.map(lambda v: v[order], stream.values)
            mapped = _premap_stream(self.spec, svals)
            is_start, start_pos, _, tgt = self._run_layout(sk)
            cols = [l.reshape(n, -1).astype(jnp.float32)
                    for l in jax.tree.leaves(mapped)]
            cols.append((sk < self.key_space).astype(jnp.float32)[:, None])
            agg = _run_aggregate(C.ADD, jnp.concatenate(cols, axis=1),
                                 is_start, start_pos)
            return state.at[tgt].add(agg, mode="drop")
        tables, counts = state
        if self.mode == "sequential":
            svals = jax.tree.map(lambda v: v[order], stream.values)
            return _sequential_fold(self.spec, tables, counts, sk, svals)
        svals = jax.tree.map(lambda v: v[order], stream.values)
        mapped = _premap_stream(self.spec, svals)
        is_start, start_pos, run_len, tgt = self._run_layout(sk)
        if self.mode == "first":
            return self._fold_first(tables, counts, mapped, sk,
                                    is_start, run_len, tgt)
        out = []
        for mono, tab, chan in zip(self.spec.monoids,
                                   jax.tree.leaves(tables),
                                   jax.tree.leaves(mapped)):
            acc_dt = (tab.dtype if jnp.issubdtype(tab.dtype, jnp.integer)
                      or tab.dtype == jnp.bool_ else jnp.float32)
            agg = _run_aggregate(mono, chan.astype(acc_dt), is_start,
                                 start_pos)
            upd = getattr(tab.at[tgt], mono.scatter_method)
            out.append(upd(agg.astype(tab.dtype), mode="drop"))
        tables = jax.tree.unflatten(self._holder_treedef, out)
        counts = counts.at[tgt].add(run_len, mode="drop")
        return tables, counts

    def _fold_first(self, tables, counts, mapped, sk, is_start, run_len,
                    tgt):
        """Keep the first-arriving value per key across chunk boundaries.

        The stable sort preserves emission order within a run, so the run
        START carries the chunk-first value; it lands only where the
        carried count is still zero."""
        K = self.key_space
        tgt_s = jnp.where(is_start, sk, K)
        cnt_delta = jnp.zeros((K,), jnp.int32).at[tgt].add(
            run_len, mode="drop")
        fresh = (counts == 0) & (cnt_delta > 0)
        out = []
        for tab, chan in zip(jax.tree.leaves(tables),
                             jax.tree.leaves(mapped)):
            cand = jnp.zeros_like(tab).at[tgt_s].set(
                chan.astype(tab.dtype), mode="drop")
            sel = fresh.reshape((K,) + (1,) * (chan.ndim - 1))
            out.append(jnp.where(sel, cand, tab))
        tables = jax.tree.unflatten(self._holder_treedef, out)
        return tables, counts + cnt_delta

    def _fold_kernel(self, state, stream: PairStream):
        """Radix partition + segment_reduce Pallas pipeline, per leaf.

        The counts column rides along with the first additive leaf (one
        partition serves channels + counts); only all-max/min specs pay a
        separate counts pass — each pipeline run re-partitions the keys,
        so sharing it matters."""
        tables, counts = state
        n = stream.keys.shape[0]
        mapped = _premap_stream(self.spec, stream.values)
        ones = stream.valid.astype(jnp.float32)[:, None]
        out = []
        new_counts = None
        for mono, tab, chan in zip(self.spec.monoids,
                                   jax.tree.leaves(tables),
                                   jax.tree.leaves(mapped)):
            flat = chan.reshape(n, -1).astype(jnp.float32)
            acc = tab.reshape(self.key_space, -1)
            if mono.name == "add" and new_counts is None:
                flat = jnp.concatenate([flat, ones], axis=1)
                acc = jnp.concatenate(
                    [acc, counts.astype(jnp.float32)[:, None]], axis=1)
                red = self.sort_fold_fn(stream.keys, flat, acc, "add")
                new_counts = red[:, -1].astype(jnp.int32)
                red = red[:, :-1]
            else:
                red = self.sort_fold_fn(stream.keys, flat, acc, mono.name)
            out.append(red.reshape(tab.shape).astype(tab.dtype))
        tables = jax.tree.unflatten(self._holder_treedef, out)
        if new_counts is None:
            new_counts = self.sort_fold_fn(
                stream.keys, ones, counts.astype(jnp.float32)[:, None],
                "add")[:, 0].astype(jnp.int32)
        return tables, new_counts


def sort_flow(
    spec: C.CombinerSpec,
    stream: PairStream,
    *,
    sort_fold_fn: Callable | None = None,
    mode: str | None = None,
    sort_impl: str = "auto",
) -> Grouped:
    """Single-shot sort flow: one chunk through :class:`SortCombiner`."""
    value_aval = jax.tree.map(
        lambda v: jax.ShapeDtypeStruct(v.shape[1:], v.dtype), stream.values)
    sc = SortCombiner(spec, stream.key_space, value_aval,
                      sort_fold_fn=sort_fold_fn, mode=mode,
                      sort_impl=sort_impl)
    state = sc.fold_chunk(sc.init_state(), stream)
    return sc.finalize(state)
