"""Semantic analysis of user ``reduce`` functions via jaxpr inspection.

This is the JAX-native analogue of MR4J's Java-agent bytecode analysis
(paper §3.1.1/§3.2): where MR4J parses reduce-method bytecode into a program
dependency graph and copies adjusted bytecodes into generated
``initialize``/``combine``/``finalize`` methods, we trace the user's reduce
function to a jaxpr and slice it into

    ``premap`` (elementwise, per emitted value — map-side)
  ∘ ``monoid reduction frontier`` (reduce_sum/max/min/prod/and/or, or a
    lax.scan fold, or the paper's two idioms: first-element and size-only)
  ∘ ``finalize`` (arbitrary post-processing of the reduced scalars).

The contract (identical to the paper's):  ``reduce(key, values, count)`` where
``values`` has shape ``[L, *value_shape]``, entries ``values[count:]`` are the
app-declared pad value, and the reduction must be insensitive to the order of
values (MapReduce semantics).  The analyzer never *executes* user code with
real data; it works on abstract values, like the paper's class-load-time
transformation.

Key invariant used throughout: a var is *tainted* iff its value varies with
the position along the values axis.  Untainted vars that carry the L axis are
only accepted when produced by a broadcast INTO axis 0 (uniform along L), so
dropping the axis is always sound for the streaming rewrite.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import combiner as C

# ---------------------------------------------------------------------------
# Primitive tables
# ---------------------------------------------------------------------------

#: value-axis reduction primitive -> monoid (the frontier the paper's
#: optimizer maps onto its ``combine`` method).
REDUCE_MONOIDS = {
    "reduce_sum": C.ADD,
    "reduce_prod": C.MUL,
    "reduce_max": C.MAX,
    "reduce_min": C.MIN,
    "reduce_and": C.AND,
    "reduce_or": C.OR,
}

#: elementwise primitives allowed in the premap slice (position-preserving
#: along the values axis).  Mirrors the paper's "adjusted bytecodes" that are
#: copied verbatim into the generated combine method.
ELEMENTWISE = {
    "add", "sub", "mul", "div", "rem", "pow", "integer_pow",
    "max", "min", "exp", "exp2", "log", "log1p", "expm1",
    "tanh", "sin", "cos", "tan", "asin", "acos", "atan", "atan2",
    "sinh", "cosh", "logistic", "sqrt", "rsqrt", "cbrt",
    "neg", "abs", "sign", "floor", "ceil", "round", "is_finite",
    "not", "and", "or", "xor",
    "shift_left", "shift_right_logical", "shift_right_arithmetic",
    "eq", "ne", "lt", "gt", "le", "ge", "select_n",
    "convert_element_type", "erf", "erfc", "erf_inv", "clamp",
    "nextafter", "copy", "reduce_precision", "stop_gradient", "square",
}

#: call-like primitives we transparently recurse into (inline).
CALL_PRIMS = {"jit", "pjit", "closed_call", "custom_jvp_call", "custom_vjp_call",
              "custom_jvp_call_jaxpr", "remat", "checkpoint"}


class ExtractionFailure(Exception):
    """Raised when the reduce fn cannot be sliced into a combiner triple."""


def _sub_jaxpr(eqn):
    p = eqn.params
    for k in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if k in p:
            return p[k]
    raise ExtractionFailure(f"call primitive {eqn.primitive.name} without jaxpr")


def _is_lit(v) -> bool:
    return hasattr(v, "val")


# ---------------------------------------------------------------------------
# Inlining: flatten call-like eqns so the analysis sees one flat jaxpr.
# ---------------------------------------------------------------------------


def _inline(jaxpr, consts):
    """Flatten (jaxpr, consts) -> (eqns, const_env, invars, outvars)."""
    const_env: dict[Any, Any] = {}
    flat_eqns: list = []

    def go(jx, jconsts, sub: dict):
        for cv, cval in zip(jx.constvars, jconsts):
            const_env[cv] = cval

        def resolve(v):
            return v if _is_lit(v) else sub.get(v, v)

        for eqn in jx.eqns:
            name = eqn.primitive.name
            if name in CALL_PRIMS:
                cj = _sub_jaxpr(eqn)
                inner, inner_consts = cj.jaxpr, cj.consts
                inner_sub: dict = {}
                args = [resolve(v) for v in eqn.invars]
                n = min(len(inner.invars), len(args))
                for iv, av in zip(inner.invars[:n], args[:n]):
                    inner_sub[iv] = av
                go(inner, inner_consts, inner_sub)
                for ov, inner_ov in zip(eqn.outvars, inner.outvars):
                    sub[ov] = (inner_ov if _is_lit(inner_ov)
                               else inner_sub.get(inner_ov, inner_ov))
            else:
                flat_eqns.append(eqn.replace(invars=[resolve(v) for v in eqn.invars]))

    top_sub: dict = {}
    go(jaxpr, consts, top_sub)
    outvars = [v if _is_lit(v) else top_sub.get(v, v) for v in jaxpr.outvars]
    return flat_eqns, const_env, list(jaxpr.invars), outvars


# ---------------------------------------------------------------------------
# Frontier description
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Frontier:
    kind: str  # "monoid" | "first" | "scan"
    eqn: Any
    monoid: C.Monoid | None = None
    #: for monoid frontiers: reduction axes other than the L axis (already
    #: shifted by -1 into dropped-value coordinates); applied in the premap.
    extra_axes: tuple[int, ...] = ()


@dataclasses.dataclass
class Analysis:
    """Everything the optimizer needs to synthesize a CombinerSpec."""

    eqns: list
    const_env: dict
    invars: list  # [key, values, count]
    outvars: list
    tainted: set
    frontiers: list
    premap_ids: set  # id(eqn) of tainted pre-frontier eqns (in eqns order)
    producer: dict  # var -> eqn
    value_aval: jax.ShapeDtypeStruct
    max_len: int

    @property
    def premap_eqns(self):
        return [e for e in self.eqns if id(e) in self.premap_ids]


# ---------------------------------------------------------------------------
# The analyzer
# ---------------------------------------------------------------------------


def analyze(
    reduce_fn: Callable,
    key_aval: Any,
    value_aval: jax.ShapeDtypeStruct,
    *,
    max_len: int = 8,
) -> Analysis:
    """Trace + slice ``reduce_fn(key, values, count)``.

    Raises :class:`ExtractionFailure` if the function is not expressible as
    premap ∘ frontier ∘ finalize under the rules in the module docstring.
    """
    values_aval = jax.ShapeDtypeStruct((max_len,) + tuple(value_aval.shape),
                                       value_aval.dtype)
    count_aval = jax.ShapeDtypeStruct((), jnp.int32)
    closed = jax.make_jaxpr(reduce_fn)(key_aval, values_aval, count_aval)
    eqns, const_env, invars, outvars = _inline(closed.jaxpr, closed.consts)
    if len(invars) != 3:
        raise ExtractionFailure("reduce must take exactly (key, values, count)")
    key_var, values_var, count_var = invars

    tainted: set = {values_var}
    count_tainted: set = {count_var}
    key_tainted: set = {key_var}
    frontiers: list[Frontier] = []
    premap_ids: set = set()
    producer: dict = {}
    L = max_len

    def any_in(vars_, s):
        return any((not _is_lit(v)) and v in s for v in vars_)

    def check_uniform_operands(eqn):
        """Untainted operands of a premap eqn must be safe to L-drop."""
        for v in eqn.invars:
            if _is_lit(v) or v in tainted:
                continue
            shape = tuple(v.aval.shape)
            if not shape or shape[0] != L:
                continue  # no L axis: scalar/trailing-broadcast, safe as-is
            prod = producer.get(v)
            ok = (
                prod is not None
                and prod.primitive.name == "broadcast_in_dim"
                and 0 not in tuple(prod.params["broadcast_dimensions"])
            )
            if not ok:
                raise ExtractionFailure(
                    f"{eqn.primitive.name}: untainted operand carries the "
                    "values axis but is not a uniform broadcast (possible "
                    "position-dependent input, e.g. iota)")

    for eqn in eqns:
        for ov in eqn.outvars:
            producer[ov] = eqn
        name = eqn.primitive.name
        in_tainted = any_in(eqn.invars, tainted)
        if not in_tainted:
            if any_in(eqn.invars, count_tainted):
                count_tainted.update(eqn.outvars)
            if any_in(eqn.invars, key_tainted):
                key_tainted.update(eqn.outvars)
            continue

        # ----- tainted eqn: must be premap-elementwise or a frontier -----
        if any_in(eqn.invars, count_tainted):
            raise ExtractionFailure(
                f"{name}: count flows into the per-value (map-side) slice; "
                "a streaming combine cannot know the final count")
        if any_in(eqn.invars, key_tainted):
            raise ExtractionFailure(
                f"{name}: key flows into the per-value slice (keyed premap "
                "unsupported)")

        def accept_premap():
            check_uniform_operands(eqn)
            premap_ids.add(id(eqn))
            tainted.update(eqn.outvars)

        if name in REDUCE_MONOIDS:
            axes = tuple(eqn.params["axes"])
            (operand,) = eqn.invars
            if operand.aval.shape[:1] != (L,):
                raise ExtractionFailure(f"{name}: operand lost the values axis")
            if 0 in axes:
                extra = tuple(a - 1 for a in axes if a != 0)
                frontiers.append(Frontier("monoid", eqn,
                                          monoid=REDUCE_MONOIDS[name],
                                          extra_axes=extra))
                continue  # frontier output is clean
            accept_premap()  # positionwise reduction over value dims
            continue

        if name == "slice":
            starts = tuple(eqn.params["start_indices"])
            limits = tuple(eqn.params["limit_indices"])
            strides = eqn.params.get("strides")
            op = eqn.invars[0]
            stride_ok = strides is None or all(s == 1 for s in strides)
            if (op.aval.shape[:1] == (L,) and starts[0] == 0 and limits[0] == L
                    and stride_ok):
                accept_premap()  # trailing-dim slice, e.g. values[:, 0:1]
                continue
            first_elem = (
                op.aval.shape[:1] == (L,) and starts[0] == 0 and limits[0] == 1
                and starts[1:] == (0,) * (len(starts) - 1)
                and limits[1:] == tuple(op.aval.shape[1:]) and stride_ok
            )
            if first_elem:
                frontiers.append(Frontier("first", eqn))  # paper idiom 1
                continue
            raise ExtractionFailure("slice of values other than values[0] / "
                                    "full-axis trailing slices")

        if name == "squeeze":
            dims = tuple(eqn.params["dimensions"])
            if 0 not in dims:
                accept_premap()
                continue
            raise ExtractionFailure("squeeze removes the values axis")

        if name == "scan":
            nc, nk = eqn.params["num_consts"], eqn.params["num_carry"]
            if any_in(eqn.invars[:nc + nk], tainted):
                raise ExtractionFailure("values flow into scan consts/init")
            frontiers.append(Frontier("scan", eqn))
            continue

        if name in ELEMENTWISE:
            for v in eqn.invars:
                if not _is_lit(v) and v in tainted and v.aval.shape[:1] != (L,):
                    raise ExtractionFailure(f"{name}: tainted operand lost L axis")
            accept_premap()
            continue

        if name == "broadcast_in_dim":
            bd = tuple(eqn.params["broadcast_dimensions"])
            shape = tuple(eqn.params["shape"])
            if bd[:1] == (0,) and shape[:1] == (L,):
                accept_premap()
                continue
            raise ExtractionFailure("broadcast moves/duplicates the L axis")

        if name == "transpose":
            if tuple(eqn.params["permutation"])[:1] == (0,):
                accept_premap()
                continue
            raise ExtractionFailure("transpose moves the L axis")

        if name == "reshape":
            if (tuple(eqn.params["new_sizes"])[:1] == (L,)
                    and eqn.params.get("dimensions") is None):
                accept_premap()
                continue
            raise ExtractionFailure("reshape folds the L axis")

        raise ExtractionFailure(f"primitive {name} not allowed on values")

    if any_in(outvars, tainted):
        raise ExtractionFailure("raw values escape to the reducer output")
    if sum(1 for f in frontiers if f.kind == "scan") > 1:
        raise ExtractionFailure("multiple scan folds unsupported")
    if any(f.kind == "scan" for f in frontiers) and len(frontiers) != 1:
        raise ExtractionFailure("scan fold mixed with other frontiers")

    # scan ys outputs must be dead (streaming combine has no per-step output)
    for f in frontiers:
        if f.kind != "scan":
            continue
        e = f.eqn
        nk = e.params["num_carry"]
        ys = set(e.outvars[nk:])
        if ys:
            used = set()
            for other in eqns:
                if other is e:
                    continue
                used.update(v for v in other.invars if not _is_lit(v))
            used.update(v for v in outvars if not _is_lit(v))
            if ys & used:
                raise ExtractionFailure("scan per-step outputs (ys) are used")

    return Analysis(
        eqns=eqns, const_env=const_env, invars=invars, outvars=outvars,
        tainted=tainted, frontiers=frontiers, premap_ids=premap_ids,
        producer=producer, value_aval=value_aval, max_len=L,
    )


# ---------------------------------------------------------------------------
# Surgical evaluators — the generated method bodies (paper Fig 4).
# ---------------------------------------------------------------------------


def _bind_dropped(eqn, args):
    """Evaluate a premap eqn with the L axis dropped from its operands."""
    name = eqn.primitive.name
    params = dict(eqn.params)
    if name == "broadcast_in_dim":
        params["shape"] = tuple(eqn.params["shape"])[1:]
        params["broadcast_dimensions"] = tuple(
            d - 1 for d in eqn.params["broadcast_dimensions"][1:])
    elif name == "transpose":
        params["permutation"] = tuple(
            p - 1 for p in eqn.params["permutation"][1:])
    elif name == "reshape":
        params["new_sizes"] = tuple(eqn.params["new_sizes"])[1:]
    elif name == "slice":
        params["start_indices"] = tuple(eqn.params["start_indices"])[1:]
        params["limit_indices"] = tuple(eqn.params["limit_indices"])[1:]
        if eqn.params.get("strides") is not None:
            params["strides"] = tuple(eqn.params["strides"])[1:]
    elif name == "squeeze":
        params["dimensions"] = tuple(
            d - 1 for d in eqn.params["dimensions"])
    elif name in REDUCE_MONOIDS:  # positionwise reduction over value dims
        params["axes"] = tuple(a - 1 for a in eqn.params["axes"])
    out = eqn.primitive.bind(*args, **params)
    return out if eqn.primitive.multiple_results else [out]


def frontier_channels(an: Analysis) -> list[tuple[Frontier, Any]]:
    """(frontier, input var) per premap channel; scan xs expand to several."""
    chans = []
    for f in an.frontiers:
        if f.kind == "scan":
            e = f.eqn
            nc, nk = e.params["num_consts"], e.params["num_carry"]
            for v in e.invars[nc + nk:]:
                chans.append((f, v))
        else:
            chans.append((f, f.eqn.invars[0]))
    return chans


def build_premap(an: Analysis) -> Callable:
    """premap(v) -> tuple of frontier input channels (dropped-L shapes).

    This is the map-side slice MR4J copies into ``combine`` before the fold.
    """
    chans = frontier_channels(an)
    values_var = an.invars[1]
    const_env = an.const_env
    premap_ids = an.premap_ids
    tainted = an.tainted
    producer = an.producer
    L = an.max_len

    def premap(v):
        env: dict = {values_var: v}

        def read(x):
            if _is_lit(x):
                return x.val
            if x in env:
                return env[x]
            if x in const_env:
                val = const_env[x]
                if jnp.ndim(val) and jnp.shape(val)[0] == L:
                    raise ExtractionFailure(
                        "captured [L]-shaped constant in premap")
                return val
            # untainted intermediate: evaluate its (constant) producer chain
            prod = producer.get(x)
            if prod is None:
                raise ExtractionFailure(f"premap: unbound var {x}")
            args = [read(a) for a in prod.invars]
            if (prod.primitive.name == "broadcast_in_dim"
                    and tuple(prod.params["shape"])[:1] == (L,)
                    and 0 not in tuple(prod.params["broadcast_dimensions"])):
                # uniform broadcast into the L axis: drop it
                params = dict(prod.params)
                params["shape"] = tuple(prod.params["shape"])[1:]
                params["broadcast_dimensions"] = tuple(
                    d - 1 for d in prod.params["broadcast_dimensions"])
                outs = [prod.primitive.bind(*args, **params)]
            else:
                o = prod.primitive.bind(*args, **prod.params)
                outs = o if prod.primitive.multiple_results else [o]
            for ov, oval in zip(prod.outvars, outs):
                env[ov] = oval
            return env[x]

        for eqn in an.eqns:
            if id(eqn) not in premap_ids:
                continue
            args = [read(x) for x in eqn.invars]
            outs = _bind_dropped(eqn, args)
            for ov, o in zip(eqn.outvars, outs):
                env[ov] = o

        out = []
        for f, iv in chans:
            x = read(iv)
            if f.kind == "monoid" and f.extra_axes:
                x = lax.reduce(x, np.asarray(f.monoid.identity(x.dtype)),
                               f.monoid.op, f.extra_axes)
            out.append(x)
        return tuple(out)

    return premap


def build_finalize(an: Analysis, holder_slots: Sequence[Sequence[Any]]) -> Callable:
    """finalize(key, holders, count) -> reducer output.

    ``holder_slots[i]`` lists the frontier-i outvars to substitute with the
    corresponding holder leaves (monoid/first: 1 var; scan: num_carry vars).
    Demand-driven: eqns feeding only the premap slice are skipped.
    """
    key_var, values_var, count_var = an.invars
    const_env = an.const_env
    frontier_eqn_ids = {id(f.eqn) for f in an.frontiers}
    premap_ids = an.premap_ids

    def finalize(key, holders, count):
        env: dict = {key_var: key, count_var: count}
        env.update(const_env)
        for slots, leaves in zip(holder_slots, holders):
            hl = list(leaves) if isinstance(leaves, (list, tuple)) else [leaves]
            for var, leaf in zip(slots, hl):
                # re-add dims the trace expects (first idiom keeps [1, ...]);
                # different-SIZED leaves pass through unchanged — elementwise
                # finalizes are shape-polymorphic (used by grad accumulation
                # to apply a spec derived on a small proxy aval).
                want = tuple(var.aval.shape)
                have = tuple(jnp.shape(leaf))
                if want != have and int(np.prod(want)) == int(np.prod(have)):
                    leaf = jnp.reshape(leaf, want)
                env[var] = leaf

        def read(x):
            if _is_lit(x):
                return x.val
            return env[x]

        for eqn in an.eqns:
            if id(eqn) in frontier_eqn_ids or id(eqn) in premap_ids:
                continue
            try:
                args = [read(x) for x in eqn.invars]
            except KeyError:
                continue  # feeds only the premap slice
            out = eqn.primitive.bind(*args, **eqn.params)
            outs = out if eqn.primitive.multiple_results else [out]
            for ov, o in zip(eqn.outvars, outs):
                env[ov] = o

        res = [read(v) for v in an.outvars]
        return res[0] if len(res) == 1 else tuple(res)

    return finalize


def eval_const_operands(an: Analysis, vars_: Sequence[Any]) -> list:
    """Evaluate vars that must be constants (scan consts / carry inits)."""
    env: dict = dict(an.const_env)

    def read(x):
        if _is_lit(x):
            return x.val
        if x in env:
            return env[x]
        prod = an.producer.get(x)
        if prod is None or any_tainted(prod):
            raise ExtractionFailure(
                "scan const/init is not a trace-time constant")
        args = [read(a) for a in prod.invars]
        out = prod.primitive.bind(*args, **prod.params)
        outs = out if prod.primitive.multiple_results else [out]
        for ov, o in zip(prod.outvars, outs):
            env[ov] = o
        return env[x]

    def any_tainted(eqn):
        return any((not _is_lit(v)) and v in an.tainted for v in eqn.invars)

    return [read(v) for v in vars_]
