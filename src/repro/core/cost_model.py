"""Roofline + compute cost model for execution-flow selection.

The paper's optimizer flips ONE flag from MapReduce semantics alone; the
follow-up literature (Manimal/Jahani et al., Casper) shows the real win is
*selecting among semantically equivalent plans by cost*.  This module gives
the planner that cost function: it extends the analytic HBM-bytes models in
``roofline.analysis`` with the COMPUTE terms that actually separate the
flows —

* stream  — the scatter-free one-hot fold burns ``O(N·K)`` masked
  compare/accumulate work (key-blocking tiles it, the total is unchanged);
* sort    — the radix-bucketed segment reduce pays ``O(N·log N)`` for the
  partition plus ``O(N + K)`` for the segmented fold and table pass;
* combine — the legacy single-shot flow: the fused one-hot contraction
  while the pair count stays in the fused regime, else the exact scatter,
  which XLA:CPU serializes per pair;
* reduce  — the paper's baseline: sort + per-pair grouping + the
  ``O(K·Lmax)`` padded window gather.

Two backend profiles translate the terms into seconds:

* ``cpu`` — per-term throughput coefficients measured on XLA:CPU in this
  container (single core; the serialized scatter and the strength-reduced
  one-hot both get their measured constants, which is what makes the
  stream/sort crossover land where ``bench_flow_sweep`` measures it);
* ``tpu`` — roofline: ``max(bytes / HBM_BW, flops / PEAK_FLOPS)`` with the
  one-hot fold priced at MXU rates (the crossover moves far right: the MXU
  makes O(N·K) cheap until K is huge — the co-design point of the paper).

``choose_flow`` ranks the candidate flows for a workload; the planner
records the full report on the plan so ``MapReduce.explain()`` can show
*why* a flow was picked (paper §3.2 step 6, made quantitative).
"""

from __future__ import annotations

import dataclasses
import math

from repro.roofline import analysis as roofline

#: XLA:CPU per-term throughput coefficients (seconds per unit), measured in
#: this container (median-of-10, jit-compiled, single core):
#:   dispatch  — per-call fixed cost of a jitted executable
#:   pair      — map emission + per-pair plumbing (mask, reshape, premap)
#:   nk        — one element of the fused one-hot compare/accumulate sweep
#:               (measured 1.4–2.5 ns/elem across K = 256..32768)
#:   sortn     — one pair through one packed-sort comparator level
#:   seg       — one pair through the segmented-aggregate + run-end pass
#:   scatter   — one serialized scatter row update (XLA:CPU scatter loop)
#:   table     — one holder-table row touch (init/merge/finalize)
#:   window    — one padded reduce-flow window element (gather + reduce)
CPU_COEFF = {
    "dispatch": 60e-6,
    "pair": 3.0e-8,
    "nk": 1.8e-9,
    "sortn": 6.0e-9,
    "seg": 6.0e-8,
    "scatter": 1.0e-7,
    "table": 2.5e-9,
    "window": 4.0e-9,
}

#: TPU compute rates: the one-hot fold runs on the MXU (priced against the
#: bf16 peak with a conservative 25% utilization for the skinny D), the
#: segment/window work on the VPU (~1e11 elem/s class), and the radix
#: bucket-scatter's per-pair dynamic VMEM stores on the scalar unit
#: (~1e8 pairs/s per partition pass) — the term that keeps the MXU one-hot
#: fold the TPU winner until K reaches the few-hundred-k range (the
#: co-design point: same semantics, different crossover per architecture).
TPU_VPU_ELEMS = 1.0e11
TPU_MXU_UTIL = 0.25
TPU_SCALAR_PAIRS = 1.0e8
RADIX_PASSES = 2


@dataclasses.dataclass(frozen=True)
class FlowCost:
    """One flow's modeled cost for a workload."""

    flow: str
    est_s: float  # modeled wall-clock (backend profile)
    model_bytes: float  # analytic HBM bytes (roofline flow model)
    terms: tuple[tuple[str, float], ...]  # named seconds contributions

    def describe(self) -> str:
        parts = " ".join(f"{k}={v * 1e6:.0f}us" for k, v in self.terms
                         if v * 1e6 >= 0.5)
        return (f"{self.flow}: est={self.est_s * 1e6:.0f}us "
                f"bytes={self.model_bytes / 1e6:.2f}MB ({parts})")


@dataclasses.dataclass(frozen=True)
class CostReport:
    """The planner's decision record: every candidate, ranked."""

    chosen: str
    n_pairs: int
    key_space: int
    backend: str
    costs: tuple[FlowCost, ...]  # sorted, cheapest first

    def cost_of(self, flow: str) -> FlowCost | None:
        for c in self.costs:
            if c.flow == flow:
                return c
        return None

    def describe(self) -> str:
        lines = [f"cost model [{self.backend}] N={self.n_pairs} "
                 f"K={self.key_space} -> {self.chosen}"]
        for c in self.costs:
            mark = "*" if c.flow == self.chosen else " "
            lines.append(f"  {mark} {c.describe()}")
        return "\n".join(lines)


def _cpu_terms(flow: str, *, n, k, d, lmax, chunk_pairs, fused_combine,
               sort_passes=1):
    c = CPU_COEFF
    logn = max(math.log2(max(min(n, chunk_pairs), 2)), 1.0)
    terms = [("dispatch", c["dispatch"]), ("map", c["pair"] * n)]
    if flow == "stream":
        # scatter-free one-hot fold: O(N·K·D) masked sweep (key blocking
        # tiles it; the total element count is invariant)
        terms.append(("onehot", c["nk"] * n * k * d))
        terms.append(("table", c["table"] * k * d))
    elif flow == "sort":
        # one packed digit sort per radix pass: past the 31-bit packed
        # regime the pure-JAX lowering pays ceil(key_bits / digit_bits)
        # passes (collector.sort_radix_passes), each n·log n
        terms.append(("sort", c["sortn"] * n * logn * max(sort_passes, 1)))
        terms.append(("segments", c["seg"] * n * d))
        terms.append(("table", c["table"] * k * d))
    elif flow == "combine":
        if fused_combine:
            terms.append(("onehot", c["nk"] * n * k * d))
        else:
            terms.append(("scatter", c["scatter"] * n * (d + 1)))
        terms.append(("table", c["table"] * k * d))
    elif flow == "reduce":
        terms.append(("sort", c["sortn"] * n * logn))
        terms.append(("group", c["scatter"] * n))  # bincount/offsets
        terms.append(("windows", c["window"] * k * lmax * d))
    else:
        raise ValueError(f"unknown flow {flow!r}")
    return terms


def _tpu_terms(flow: str, *, n, k, d, lmax, model_bytes, fused_combine,
               sort_levels=1):
    mem_s = model_bytes / roofline.HBM_BW
    if flow in ("stream", "combine"):
        flops = 2.0 * n * k * d  # one-hot contraction on the MXU
        comp_s = flops / (roofline.PEAK_FLOPS * TPU_MXU_UTIL)
    elif flow == "sort":
        # hist + bucket-scatter per hierarchy level: the per-pair dynamic
        # VMEM stores run on the scalar unit once per level
        comp_s = (n * RADIX_PASSES * max(sort_levels, 1) / TPU_SCALAR_PAIRS
                  + (n * d + k * d) / TPU_VPU_ELEMS)
    else:  # reduce
        logn = max(math.log2(max(n, 2)), 1.0)
        comp_s = (n * logn + k * lmax * d) / TPU_VPU_ELEMS
    return [("memory", mem_s), ("compute", comp_s)]


def estimate_flow_cost(
    flow: str,
    *,
    n_pairs: int,
    key_space: int,
    d: int = 1,
    value_bytes: int = 4,
    holder_bytes: int | None = None,
    chunk_pairs: int | None = None,
    max_values_per_key: int | None = None,
    backend: str = "cpu",
    skew_factor: float = 1.0,
    num_shards: int = 1,
    wire: str = "raw",
    shuffle_capacity: int | None = None,
    value_dtype: str = "int32",
) -> FlowCost:
    """Model one flow's cost for a workload (see module docstring).

    ``skew_factor`` is the sampled key-distribution imbalance (max range
    load over the uniform share, >= 1.0, from ``core/skew.py``): the
    shuffled flows (sort/reduce) are paced by their HOTTEST shard, so
    their estimate scales by the imbalance — which is how ``flow="auto"``
    prices a skewed all-to-all against the skew-immune stream flow.  The
    table-merge flows are unaffected (their per-shard work is
    item-partitioned, not key-partitioned).

    ``num_shards > 1`` adds the network term for the shuffled flows: the
    per-shard all-to-all wire bytes under the ``wire`` codec
    (``roofline.shuffle_wire_bytes``, exact against the wire layer's
    encoded-tree accounting) over the link bandwidth — which is how
    ``flow="auto"`` and ``explain()`` price wire compression."""
    n, k = max(int(n_pairs), 1), max(int(key_space), 1)
    lmax = max_values_per_key or max(n // k, 1)
    chunk = chunk_pairs or n
    from repro.core import collector as col

    # the sort flow's level count per lowering: pure-JAX digit-sort passes
    # on the cpu profile, hierarchical partition levels (kernel path) on
    # tpu — derived only when the sort flow is the one being priced
    sort_levels = 1
    if flow == "sort":
        if backend == "tpu":
            try:
                from repro.kernels import ops

                rplan = ops.plan_radix_levels(k, d=d + 1)
                sort_levels = max(rplan.levels, 1) if rplan.feasible else 1
            except Exception:  # pragma: no cover
                sort_levels = 1
        else:
            sort_levels = col.sort_radix_passes(max(min(n, chunk), 1), k)
    model_bytes = roofline.mapreduce_flow_bytes(
        flow, n_pairs=n, key_space=k, value_bytes=value_bytes,
        holder_bytes=holder_bytes, chunk_pairs=chunk,
        max_values_per_key=lmax,
        sort_levels=sort_levels if flow == "sort" else 1)
    # the legacy combine flow keeps the fused one-hot contraction only
    # while N is inside the fused regime or K under the legacy cutoff
    fused_combine = (n <= col.ADDITIVE_FOLD_PAIRS_FUSED
                     or k <= col.ONEHOT_MAX_KEYS)
    if backend == "cpu":
        terms = _cpu_terms(flow, n=n, k=k, d=d, lmax=lmax,
                           chunk_pairs=chunk, fused_combine=fused_combine,
                           sort_passes=sort_levels)
        est = sum(v for _, v in terms)
    elif backend == "tpu":
        terms = _tpu_terms(flow, n=n, k=k, d=d, lmax=lmax,
                           model_bytes=model_bytes,
                           fused_combine=fused_combine,
                           sort_levels=sort_levels)
        est = max(v for _, v in terms)  # overlappable roofline terms
    else:
        raise ValueError(f"unknown backend profile {backend!r}")
    S = max(int(num_shards), 1)
    if S > 1 and flow in ("sort", "reduce"):
        # the all-to-all's link traffic, under the configured wire codec —
        # added before the skew scaling so a hot destination paces the
        # wire the same way it paces the compute
        wire_s = roofline.shuffle_wire_bytes(
            wire, n_pairs=n, key_space=k, num_shards=S,
            value_bytes=value_bytes, value_dtype=value_dtype,
            capacity=shuffle_capacity) / roofline.LINK_BW
        terms = list(terms) + [("wire", wire_s)]
        est += wire_s
    sf = max(float(skew_factor), 1.0)
    if sf > 1.0 and flow in ("sort", "reduce"):
        # the all-to-all flows finish when their hottest destination
        # shard does: scale the whole estimate by the imbalance factor
        extra = est * (sf - 1.0)
        terms = list(terms) + [("skew", extra)]
        est += extra
    return FlowCost(flow=flow, est_s=est, model_bytes=model_bytes,
                    terms=tuple(terms))


def default_backend() -> str:
    """Profile for the current JAX backend ("tpu" on TPU, else "cpu")."""
    import jax

    return "tpu" if jax.default_backend() == "tpu" else "cpu"


def choose_flow(
    *,
    n_pairs: int,
    key_space: int,
    d: int = 1,
    value_bytes: int = 4,
    holder_bytes: int | None = None,
    chunk_pairs: int | None = None,
    max_values_per_key: int | None = None,
    candidates: tuple[str, ...] = ("stream", "sort"),
    backend: str | None = None,
    skew_factor: float = 1.0,
    num_shards: int = 1,
    wire: str = "raw",
    shuffle_capacity: int | None = None,
    value_dtype: str = "int32",
) -> CostReport:
    """Rank ``candidates`` by modeled cost and pick the cheapest.

    The planner restricts ``candidates`` to the flows the derived combiner
    can actually run (e.g. no sort flow for coupled-holder scan specs —
    its sequential fallback has no edge over the stream flow's).
    """
    backend = backend or default_backend()
    costs = sorted(
        (estimate_flow_cost(f, n_pairs=n_pairs, key_space=key_space, d=d,
                            value_bytes=value_bytes,
                            holder_bytes=holder_bytes,
                            chunk_pairs=chunk_pairs,
                            max_values_per_key=max_values_per_key,
                            backend=backend, skew_factor=skew_factor,
                            num_shards=num_shards, wire=wire,
                            shuffle_capacity=shuffle_capacity,
                            value_dtype=value_dtype)
         for f in candidates),
        key=lambda fc: fc.est_s)
    return CostReport(chosen=costs[0].flow, n_pairs=n_pairs,
                      key_space=key_space, backend=backend,
                      costs=tuple(costs))


def pipeline_overhead_s(n_stages: int, *, handoff_bytes: float = 0.0,
                        fused: bool = True,
                        backend: str | None = None) -> float:
    """Model the per-call overhead a pipeline's *structure* adds.

    A fused pipeline is one executable: one dispatch, intermediates live in
    registers/VMEM.  The unfused form pays one dispatch per stage plus the
    materialized intermediate tables crossing HBM (``handoff_bytes``, from
    ``roofline.pipeline_handoff_bytes`` summed over the DAG edges) — the
    co-design point ``Pipeline.compile`` removes.
    """
    backend = backend or default_backend()
    dispatches = 1 if fused else max(1, int(n_stages))
    secs = dispatches * CPU_COEFF["dispatch"]
    if not fused and handoff_bytes:
        bw = roofline.HBM_BW if backend == "tpu" else 2.0e10
        secs += float(handoff_bytes) / bw
    return secs
