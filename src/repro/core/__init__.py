"""MR4X core: the paper's contribution as a composable JAX module."""

from repro.core.api import (  # noqa: F401
    Emitter,
    MapReduce,
    MapReduceApp,
    MapReduceResult,
    make_app,
)
from repro.core.combiner import (  # noqa: F401
    CombinerSpec,
    Monoid,
    count_spec,
    logsumexp_spec,
    max_spec,
    mean_spec,
    min_spec,
    monoid_spec,
    product_spec,
    sum_spec,
)
from repro.core.autotune import (  # noqa: F401
    StreamTiling,
    autotune_sort,
    autotune_stream,
)
from repro.core.collector import LoweringFallbackWarning  # noqa: F401
from repro.core.cost_model import (  # noqa: F401
    CostReport,
    FlowCost,
    choose_flow,
    estimate_flow_cost,
)
from repro.core.optimizer import Derivation, derive_combiner  # noqa: F401
from repro.core.plan import ExecutionPlan, plan_execution  # noqa: F401
