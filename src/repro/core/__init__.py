"""MR4X core: the paper's contribution as a composable JAX module."""

from repro.core.api import (
    Compiled,
    Emitter,
    ExecutionOptions,
    Lowered,
    MapReduce,
    MapReduceApp,
    MapReduceResult,
    Optimized,
    make_app,
)
from repro.core.combiner import (
    CombinerSpec,
    Monoid,
    count_spec,
    logsumexp_spec,
    max_spec,
    mean_spec,
    min_spec,
    monoid_spec,
    product_spec,
    sum_spec,
)
from repro.core.autotune import (
    StreamTiling,
    autotune_sort,
    autotune_stream,
)
from repro.core.collector import LoweringFallbackWarning
from repro.core.cost_model import (
    CostReport,
    FlowCost,
    choose_flow,
    estimate_flow_cost,
)
from repro.core.optimizer import Derivation, derive_combiner
from repro.core.pipeline import Pipeline, StageSemantics, extract_semantics
from repro.core.plan import FLOWS, ExecutionPlan, plan_execution
from repro.core.plan_cache import CacheStats, stats_snapshot
from repro.core.skew import ShuffleOptions, ShufflePlan, SkewProfile

#: the public execution surface — ``from repro.core import *`` pulls exactly
#: this; anything else in the submodules is implementation detail.
__all__ = [
    # apps + staged execution
    "MapReduce",
    "MapReduceApp",
    "MapReduceResult",
    "make_app",
    "Emitter",
    "ExecutionOptions",
    "ShuffleOptions",
    "ShufflePlan",
    "SkewProfile",
    "Lowered",
    "Optimized",
    "Compiled",
    # multi-job DAGs
    "Pipeline",
    "StageSemantics",
    "extract_semantics",
    # planning + flows
    "FLOWS",
    "ExecutionPlan",
    "plan_execution",
    "CostReport",
    "FlowCost",
    "choose_flow",
    "estimate_flow_cost",
    "Derivation",
    "derive_combiner",
    # combiner algebra
    "CombinerSpec",
    "Monoid",
    "monoid_spec",
    "sum_spec",
    "count_spec",
    "mean_spec",
    "min_spec",
    "max_spec",
    "product_spec",
    "logsumexp_spec",
    # tiling + caching
    "StreamTiling",
    "autotune_stream",
    "autotune_sort",
    "CacheStats",
    "stats_snapshot",
    # warnings
    "LoweringFallbackWarning",
]
