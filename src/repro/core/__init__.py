"""MR4X core: the paper's contribution as a composable JAX module."""

from repro.core.api import (  # noqa: F401
    Emitter,
    MapReduce,
    MapReduceApp,
    MapReduceResult,
    make_app,
)
from repro.core.combiner import (  # noqa: F401
    CombinerSpec,
    Monoid,
    count_spec,
    logsumexp_spec,
    max_spec,
    mean_spec,
    min_spec,
    monoid_spec,
    product_spec,
    sum_spec,
)
from repro.core.autotune import StreamTiling, autotune_stream  # noqa: F401
from repro.core.collector import LoweringFallbackWarning  # noqa: F401
from repro.core.optimizer import Derivation, derive_combiner  # noqa: F401
from repro.core.plan import ExecutionPlan, plan_execution  # noqa: F401
