"""Execution planning: run the optimizer, pick the flow, record stats.

The paper's runtime "sets the flag to return a constant of true ... to enable
the optimized combining execution flow" (§3.2 step 6).  ``plan_execution`` is
that decision point, plus the bookkeeping used by
``benchmarks/bench_optimizer_overhead.py`` to reproduce the paper's
81 µs detection / 7.6 ms transformation table.

Beyond the paper (following the plan-selection line of Jahani et al. and
Casper): when the caller supplies a workload-size hint the planner does not
just flip one flag — it ranks the semantically equivalent flows (the
streaming one-hot fold vs the sort-based radix fold) with the roofline +
compute cost model (``core/cost_model.py``) and records the full report on
the plan, so ``explain()`` shows the quantitative decision.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import combiner as C
from repro.core import cost_model as cm
from repro.core.optimizer import Derivation, derive_combiner

FLOWS = ("auto", "stream", "sort", "combine", "reduce")


@dataclasses.dataclass
class ExecutionPlan:
    flow: str  # "stream" | "sort" | "combine" | "reduce"
    derivation: Derivation | None
    spec: C.CombinerSpec | None
    reason: str = ""
    #: the autotuner's StreamTiling when the stream/sort flow was selected
    #: (attached by the API layer, which owns the tiling knobs).
    tiling: object | None = None
    #: the cost model's ranking when a workload hint enabled it.
    cost: cm.CostReport | None = None
    #: human-readable optimizer/lowering decisions worth surfacing — e.g.
    #: the one-hot -> scatter fallback that used to happen silently.
    diagnostics: tuple[str, ...] = ()
    #: fault-recovery events from ``engine.run_resilient`` — which shards
    #: were restored from checkpointed partials, recomputed on backup
    #: ranks, or speculatively re-executed, and any elastic remesh; plus
    #: the durable control plane's provenance: lease elections and
    #: failovers (which host adopted coordination, at what epoch), every
    #: store retry with the backoff delay taken (no silent retries), and
    #: checksum quarantines of corrupt checkpoints.  The monoid-merge
    #: recovery argument makes these pure bookkeeping: the answer is
    #: bitwise the no-failure one.
    recovery: tuple[str, ...] = ()
    #: staged-compilation bookkeeping (api.Lowered/Optimized/Compiled):
    #: the furthest stage this plan has reached, the content cache key it
    #: was stored/looked-up under, and how the lookup went ("hit" | "miss"
    #: | "file-hit"; "" when the cache was bypassed).
    stage: str = ""
    cache_key: str | None = None
    cache_event: str = ""
    #: pipeline-fusion decisions (core/pipeline.py): one line per DAG edge
    #: — fused handoff, eliminated dead columns, pushed-down filters.
    fusion: tuple[str, ...] = ()
    #: skew-adaptive shuffle provenance (core/skew.py): the sampled
    #: histogram summary (heavy hitters, imbalance factor, sample-vs-cache
    #: source), the balanced range boundaries, and any hot-key splits.
    skew: tuple[str, ...] = ()
    #: shuffle wire-codec provenance (distributed/wire.py): the codec the
    #: all-to-all + checkpointed partials ride under and its modeled
    #: encoded-vs-raw bytes.
    wire: tuple[str, ...] = ()

    @property
    def optimized(self) -> bool:
        """True when a derived/manual combiner replaced the baseline flow."""
        return self.flow in ("stream", "sort", "combine")

    def explain(self) -> str:
        """Multi-line report of what the optimizer decided and why —
        flow, derivation, the cost-model ranking, the autotuned tiling,
        any lowering diagnostics (the paper's §3.2 decision, made
        inspectable), plus the staged-compilation stage / plan-cache
        outcome and pipeline-fusion decisions when present."""
        lines = [f"flow: {self.flow} ({self.reason})"]
        if self.stage:
            lines.append(f"stage: {self.stage}")
        if self.cache_key is not None:
            ev = self.cache_event or "off"
            lines.append(f"plan-cache: {ev} key={self.cache_key}")
        d = self.derivation
        if d is not None:
            v = "validated" if d.validated else "trusted"
            lines.append(f"combiner: {d.strategy}"
                         + (f" [{self.spec.describe}] ({v})"
                            if self.spec is not None else "")
                         + (f" — {d.failure}" if d.failure else ""))
            lines.append(f"optimizer: detect={d.detect_s * 1e6:.0f}us "
                         f"transform={d.transform_s * 1e3:.2f}ms "
                         f"validate={d.validate_s * 1e3:.2f}ms")
        if self.cost is not None:
            lines.append(self.cost.describe())
        if self.tiling is not None:
            lines.append(f"tiling: {self.tiling.describe()}")
            for note in getattr(self.tiling, "notes", ()):
                lines.append(f"  - {note}")
        for decision in self.fusion:
            lines.append(f"fusion: {decision}")
        for line in self.skew:
            lines.append(f"skew: {line}")
        for line in self.wire:
            lines.append(f"wire: {line}")
        for diag in self.diagnostics:
            lines.append(f"diagnostic: {diag}")
        for event in self.recovery:
            lines.append(f"recovery: {event}")
        return "\n".join(lines)


def _cost_candidates(spec: C.CombinerSpec) -> tuple[str, ...]:
    """Flows the cost model may choose for this combiner.

    The sort flow's vectorized run-aggregate path needs scatter monoids (or
    the first/size idioms, whose run layout it exploits directly); coupled
    holders would fall back to the sequential fold, which has no edge over
    the stream flow's — don't offer it.
    """
    if (spec.scatter_lowerable
            or spec.strategy in (C.STRATEGY_FIRST, C.STRATEGY_SIZE)):
        return ("stream", "sort")
    return ("stream",)


def flow_cost_report(app, spec: C.CombinerSpec, n_pairs_hint: int,
                     *, skew_factor: float = 1.0, num_shards: int = 1,
                     wire: str = "raw",
                     shuffle_capacity: int | None = None) -> cm.CostReport:
    """Rank the eligible flows for ``app``/``spec`` at a workload size.

    The planner calls this under ``flow="auto"``; benchmarks use it
    directly to check the model's verdict against measured winners without
    re-running combiner derivation (the spec is already in hand).

    ``num_shards > 1`` prices the shuffled flows' all-to-all link traffic
    under the ``wire`` codec (see ``cost_model.estimate_flow_cost``)."""
    value_bytes = int(jnp.dtype(app.value_aval.dtype).itemsize *
                      max(1, int(np.prod(app.value_aval.shape))))
    d, holder_bytes = spec.holder_width(app.value_aval)
    return cm.choose_flow(
        n_pairs=n_pairs_hint, key_space=app.key_space, d=d,
        value_bytes=value_bytes, holder_bytes=holder_bytes,
        max_values_per_key=getattr(app, "max_values_per_key", None),
        candidates=_cost_candidates(spec), skew_factor=skew_factor,
        num_shards=num_shards, wire=wire,
        shuffle_capacity=shuffle_capacity,
        value_dtype=str(app.value_aval.dtype))


def plan_execution(app, *, flow: str = "auto",
                   trust_semantics: bool = False,
                   n_pairs_hint: int | None = None,
                   streaming: bool = False) -> ExecutionPlan:
    """Pick the execution flow.

    flow="auto" runs the optimizer and, when a combiner is derived, selects
    the flow the optimizer recommends.  Without a workload hint that is the
    streaming fused flow (the paper's one-flag behaviour); with
    ``n_pairs_hint`` the cost model ranks the equivalent flows (stream vs
    sort) for that workload size and the cheapest wins — the report lands
    on ``plan.cost``.  "stream" / "sort" / "combine" force the respective
    optimized flow (error if no combiner can be derived); "reduce" forces
    the paper's baseline.

    ``streaming=True`` plans for continuous ingestion (the
    ``MapReduceService`` path): the flow is pinned to "stream" — the only
    flow whose carried holder tables can absorb micro-batches
    incrementally — and a combiner MUST be derivable, since an unbounded
    stream cannot be buffered for the baseline reduce flow.  Chunk sizing
    is then the micro-batch shape itself (one fold per ingest), applied by
    the service at the compile stage.
    """
    if flow not in FLOWS:
        raise ValueError(f"unknown flow {flow!r}")
    if streaming:
        if flow not in ("auto", "stream"):
            raise ValueError(
                f"streaming execution requires the stream flow (its carried "
                f"holder tables are what micro-batches fold into); got "
                f"flow={flow!r}")
        flow = "stream"
    if flow == "reduce":
        return ExecutionPlan("reduce", None, None, reason="forced by user")

    spec = getattr(app, "manual_combiner", None)
    if spec is not None:
        d = Derivation(spec=spec, strategy=C.STRATEGY_MANUAL, reapply_ok=False,
                       validated=False, detect_s=0.0, transform_s=0.0)
        reason = "manual combiner"
        derived = d
    else:
        key_aval = jax.ShapeDtypeStruct((), jnp.int32)
        derived = derive_combiner(app.reduce, key_aval, app.value_aval,
                                  trust_semantics=trust_semantics)
        if not derived.combinable:
            if streaming:
                raise ValueError(
                    f"streaming execution needs a derived combiner (an "
                    f"unbounded stream cannot be buffered for the reduce "
                    f"flow) but derivation failed: {derived.failure}")
            if flow in ("combine", "stream", "sort"):
                raise ValueError(
                    f"{flow} flow forced but derivation failed: "
                    f"{derived.failure}")
            return ExecutionPlan("reduce", derived, None,
                                 reason=f"not combinable: {derived.failure}")
        spec = derived.spec
        reason = f"derived ({derived.strategy})"

    if streaming:
        reason += "; streaming pins the stream flow"
    if flow != "auto":
        return ExecutionPlan(flow, derived, spec, reason=reason)
    if n_pairs_hint is not None:
        report = flow_cost_report(app, spec, n_pairs_hint)
        return ExecutionPlan(
            report.chosen, derived, spec, cost=report,
            reason=f"{reason}; cost model [{report.backend}] at "
                   f"N={n_pairs_hint}")
    return ExecutionPlan(derived.recommended_flow, derived, spec,
                         reason=reason)
