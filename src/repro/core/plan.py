"""Execution planning: run the optimizer, pick the flow, record stats.

The paper's runtime "sets the flag to return a constant of true ... to enable
the optimized combining execution flow" (§3.2 step 6).  ``plan_execution`` is
that decision point, plus the bookkeeping used by
``benchmarks/bench_optimizer_overhead.py`` to reproduce the paper's
81 µs detection / 7.6 ms transformation table.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import combiner as C
from repro.core.optimizer import Derivation, derive_combiner


@dataclasses.dataclass
class ExecutionPlan:
    flow: str  # "stream" | "combine" | "reduce"
    derivation: Derivation | None
    spec: C.CombinerSpec | None
    reason: str = ""
    #: the autotuner's StreamTiling when the streaming flow was selected
    #: (attached by the API layer, which owns the tiling knobs).
    tiling: object | None = None
    #: human-readable optimizer/lowering decisions worth surfacing — e.g.
    #: the one-hot -> scatter fallback that used to happen silently.
    diagnostics: tuple[str, ...] = ()

    @property
    def optimized(self) -> bool:
        """True when a derived/manual combiner replaced the baseline flow."""
        return self.flow in ("stream", "combine")

    def explain(self) -> str:
        """Multi-line report of what the optimizer decided and why —
        flow, derivation, the autotuned tiling, and any lowering
        diagnostics (the paper's §3.2 decision, made inspectable)."""
        lines = [f"flow: {self.flow} ({self.reason})"]
        d = self.derivation
        if d is not None:
            v = "validated" if d.validated else "trusted"
            lines.append(f"combiner: {d.strategy}"
                         + (f" [{self.spec.describe}] ({v})"
                            if self.spec is not None else "")
                         + (f" — {d.failure}" if d.failure else ""))
            lines.append(f"optimizer: detect={d.detect_s * 1e6:.0f}us "
                         f"transform={d.transform_s * 1e3:.2f}ms "
                         f"validate={d.validate_s * 1e3:.2f}ms")
        if self.tiling is not None:
            lines.append(f"tiling: {self.tiling.describe()}")
            for note in getattr(self.tiling, "notes", ()):
                lines.append(f"  - {note}")
        for diag in self.diagnostics:
            lines.append(f"diagnostic: {diag}")
        return "\n".join(lines)


def plan_execution(app, *, flow: str = "auto",
                   trust_semantics: bool = False) -> ExecutionPlan:
    """Pick the execution flow.

    flow="auto" runs the optimizer and, when a combiner is derived, selects
    the flow the optimizer recommends (the streaming fused flow).  "stream"
    and "combine" force the respective optimized flow (error if no combiner
    can be derived); "reduce" forces the paper's baseline.
    """
    if flow not in ("auto", "stream", "combine", "reduce"):
        raise ValueError(f"unknown flow {flow!r}")
    if flow == "reduce":
        return ExecutionPlan("reduce", None, None, reason="forced by user")

    spec = getattr(app, "manual_combiner", None)
    if spec is not None:
        d = Derivation(spec=spec, strategy=C.STRATEGY_MANUAL, reapply_ok=False,
                       validated=False, detect_s=0.0, transform_s=0.0)
        chosen = d.recommended_flow if flow == "auto" else flow
        return ExecutionPlan(chosen, d, spec, reason="manual combiner")

    key_aval = jax.ShapeDtypeStruct((), jnp.int32)
    d = derive_combiner(app.reduce, key_aval, app.value_aval,
                        trust_semantics=trust_semantics)
    if d.combinable:
        chosen = d.recommended_flow if flow == "auto" else flow
        return ExecutionPlan(chosen, d, d.spec,
                             reason=f"derived ({d.strategy})")
    if flow in ("combine", "stream"):
        raise ValueError(
            f"{flow} flow forced but derivation failed: {d.failure}")
    return ExecutionPlan("reduce", d, None,
                         reason=f"not combinable: {d.failure}")
