"""Roofline-driven autotuner for the streaming fold's tiling knobs.

The paper's optimizer picks the execution strategy from MapReduce semantics
alone; this module extends the same principle to the strategy's *sizing*:
``stream_chunk_pairs`` and the key-block size are derived from the analytic
flow-bytes / peak-residency / VMEM working-set models in
``roofline.analysis`` instead of fixed constants, so large-K workloads keep
the scatter-free one-hot fold and the chunk size balances the two HBM terms
the streaming flow pays for.

Model-driven selection (the default, ``source="model"``):

* ``chunk_pairs`` — the streaming flow's modeled bytes are
  ``2·N·pair + 2·(N/chunk)·table``: monotonically improved by larger
  chunks, while peak residency ``chunk·pair + table`` grows with them.
  The knee is ``chunk·pair_bytes ≈ table_bytes`` (peak stays within 2× of
  the table floor while the table re-touch term stops dominating), clamped
  to ``[DEFAULT_CHUNK_PAIRS, MAX_CHUNK_PAIRS]``.  The pure-JAX additive
  fold is additionally capped at ``ADDITIVE_FOLD_PAIRS_FUSED`` pairs per
  fold — the measured regime in which XLA keeps the one-hot contraction
  on-chip (beyond it the ``[chunk, K]`` expansion round-trips HBM); the
  Pallas kernel path is exempt, its one-hot tile is VMEM-resident at any
  chunk size.
* ``key_block`` — sized per lowering from its memory model: the Pallas
  fold kernels keep a ``[Kb, Td]`` table block plus a ``[Tn, Kb]`` one-hot
  tile VMEM-resident (``stream_working_set_bytes`` vs ``VMEM_BUDGET`` with
  double-buffer headroom); the pure-JAX folds keep one ``[chunk, Kb]``
  expansion live per block (``DENSE_FOLD_ELEMS_BUDGET``) — measured on
  XLA:CPU, an unblocked large-K fold inside the chunk scan materializes
  the whole ``[chunk, K]`` expansion (268 MB peak at K=32k), while the
  blocked fold stays fused (0.6 MB peak, O(K + chunk) for real).

``probe=True`` additionally times 3 candidate chunk sizes on a synthetic
workload (measured micro-probe mode) and keeps the fastest.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import collector as col
from repro.roofline import analysis as roofline

#: env var pointing at the persistent per-app tuning cache (JSON file).
#: Unset (the default, and in CI) -> measured micro-probe results are not
#: persisted and every probing run re-measures.
TUNE_CACHE_ENV = "JAX_PALLAS_TUNE_CACHE"

#: chunk-size clamp: floor keeps small workloads on the pre-autotuner
#: single-chunk behaviour; the cap bounds compile-time unrolling and the
#: pair-chunk buffer.
MAX_CHUNK_PAIRS = 1 << 16


def _pow2_round(x: int) -> int:
    lo = col.pow2_floor(x)
    return lo * 2 if x - lo > 2 * lo - x else lo


@dataclasses.dataclass(frozen=True)
class StreamTiling:
    """The autotuner's decision record, carried on the ExecutionPlan so
    ``explain()`` and the roofline reports show the chosen tiling."""

    chunk_pairs: int
    key_block: int  # == key_space -> single block (unblocked)
    key_space: int
    mode: str  # expected stream fold lowering (collector.stream_mode)
    source: str  # "model" | "probe" | "manual"
    model_bytes: float  # analytic HBM bytes at n_pairs_hint
    model_peak_bytes: float  # analytic peak residency
    working_set_bytes: float  # per-grid-step VMEM model (kernel path)
    n_pairs_hint: int
    notes: tuple[str, ...] = ()
    #: hierarchical radix fan-outs of the sort flow's kernel partition
    #: (() == single level / no partition); the pure-JAX lowering's
    #: multi-pass packed-sort count is recorded in sort_passes.
    level_fanouts: tuple[int, ...] = ()
    sort_passes: int = 1

    @property
    def n_key_blocks(self) -> int:
        return -(-self.key_space // self.key_block)

    @property
    def blocked(self) -> bool:
        return self.key_block < self.key_space

    @property
    def levels(self) -> int:
        return max(len(self.level_fanouts), 1)

    def describe(self) -> str:
        if self.mode == "sort":
            blk = (f"buckets={self.n_key_blocks}×{self.key_block}keys"
                   if self.blocked else "buckets=1 (single full sort)")
            if len(self.level_fanouts) > 1:
                fan = "·".join(str(b) for b in self.level_fanouts)
                blk += f" levels={len(self.level_fanouts)}({fan})"
            if self.sort_passes > 1:
                blk += f" sort_passes={self.sort_passes}"
        else:
            blk = (f"key_block={self.key_block}×{self.n_key_blocks}"
                   if self.blocked else f"key_block={self.key_block} (single)")
        return (f"chunk_pairs={self.chunk_pairs} {blk} mode={self.mode} "
                f"[{self.source}] peak≈{self.model_peak_bytes / 1e6:.2f}MB "
                f"vmem_step≈{self.working_set_bytes / 1e6:.2f}MB")


# ---------------------------------------------------------------------------
# Persistent per-app tuning cache (file-backed, opt-in via env var)
# ---------------------------------------------------------------------------


#: key prefix of the skew planner's histogram decisions (core/skew.py),
#: which share this cache file with the StreamTiling entries — same
#: micro-probe posture, same opt-in persistence.
SKEW_KEY_PREFIX = "skew|"


def tune_cache_path() -> str | None:
    """Path of the persistent tuning cache, or None when disabled."""
    p = os.environ.get(TUNE_CACHE_ENV, "").strip()
    return p or None


def _tune_cache_key(app, spec, *, use_kernels: bool,
                    n_pairs_hint: int | None) -> str:
    aval = app.value_aval
    return "|".join([
        type(app).__name__,
        f"K={app.key_space}",
        f"cap={app.emit_capacity}",
        f"v={jnp.dtype(aval.dtype).name}{tuple(aval.shape)}",
        f"spec={spec.describe or spec.strategy}",
        f"N={n_pairs_hint or 0}",
        f"kern={int(use_kernels)}",
    ])


def load_tune_cache(path: str) -> dict:
    """Read the cache file; IO/parse failures read as an empty cache."""
    try:
        with open(path) as f:
            data = json.load(f)
        return data if isinstance(data, dict) else {}
    except (OSError, ValueError):
        return {}


def store_tune_entry(path: str, key: str, entry: dict) -> bool:
    """Merge one measured entry into the cache file (advisory: best-effort,
    failures are swallowed — the cache must never break a run)."""
    try:
        cache = load_tune_cache(path)
        cache[key] = entry
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(cache, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
        return True
    except OSError:
        return False


def choose_chunk_pairs(key_space: int, *, holder_bytes: int, pair_bytes: int,
                       emit_capacity: int = 1,
                       n_pairs_hint: int | None = None,
                       fused_cap: bool = False) -> int:
    """Model-balanced chunk size (see module docstring).

    ``fused_cap=True`` applies the pure-JAX additive fold's
    fused-contraction regime cap (``ADDITIVE_FOLD_PAIRS_FUSED``)."""
    from repro.core.engine import DEFAULT_CHUNK_PAIRS

    table_bytes = key_space * (holder_bytes + 4)  # + int32 counts
    chunk = _pow2_round(max(table_bytes // max(pair_bytes, 1), 1))
    chunk = max(DEFAULT_CHUNK_PAIRS, min(chunk, MAX_CHUNK_PAIRS))
    if fused_cap:
        chunk = min(chunk, col.ADDITIVE_FOLD_PAIRS_FUSED)
    chunk = max(chunk, emit_capacity)
    if n_pairs_hint is not None and n_pairs_hint > 0:
        # no point chunking beyond the workload (keeps single-chunk fusion)
        chunk = min(max(chunk, 1), max(_pow2_round(n_pairs_hint),
                                       emit_capacity))
        chunk = max(chunk, emit_capacity)
    return chunk


def choose_key_block(key_space: int, chunk_pairs: int, *, d: int,
                     use_kernels: bool,
                     tile_n: int = 512, tile_d: int = 128) -> int:
    """Key-block size per lowering memory model (see module docstring)."""
    if use_kernels:
        try:
            from repro.kernels import ops

            return ops.auto_key_block(key_space, d=d,
                                      tile_n=min(tile_n, chunk_pairs),
                                      tile_d=tile_d)
        except Exception:  # pragma: no cover
            pass
    # pure-JAX folds: one [chunk, Kb] expansion live per block — inside a
    # multi-chunk scan XLA materializes anything bigger (measured: an
    # unblocked K=32k fold in the scan body costs 268 MB peak / O(N·K)
    # bytes; blocked at this budget, 0.6 MB / O(N + K))
    return col.choose_dense_key_block(key_space, chunk_pairs)


def autotune_stream(
    app,
    spec,
    *,
    use_kernels: bool = False,
    chunk_pairs: int | str = "auto",
    key_block: int | str | None = "auto",
    n_pairs_hint: int | None = None,
    probe: bool = False,
    probe_pairs: int = 2048,
    probe_items: Any | None = None,
) -> StreamTiling:
    """Pick the streaming-fold tiling for ``app`` under ``spec``.

    ``chunk_pairs`` / ``key_block`` accept explicit ints to pin either knob
    (``source="manual"`` when both are pinned); ``key_block=None`` disables
    blocking.  ``probe=True`` enables the measured micro-probe refinement
    (on ``probe_items`` when given, else a synthetic workload).
    """
    from repro.core import plan_cache as pc

    pc.STATS.autotunes += 1
    notes: list[str] = []
    value_bytes = int(jnp.dtype(app.value_aval.dtype).itemsize *
                      max(1, int(np.prod(app.value_aval.shape))))
    pair_bytes = 4 + value_bytes
    d, holder_bytes = spec.holder_width(app.value_aval)
    K = app.key_space
    # kernel-path exemptions mirror StreamCombiner's (same CombinerSpec
    # predicates): when the kernels won't actually run — e.g. integer
    # holders under use_kernels=True — the pure-JAX budgets apply.
    kernel_additive = use_kernels and spec.kernel_additive_ok(app.value_aval)
    kernel_monoid = use_kernels and spec.kernel_monoid_ok(app.value_aval)

    manual_chunk = isinstance(chunk_pairs, int)
    if manual_chunk:
        chunk = int(chunk_pairs)
    else:
        chunk = choose_chunk_pairs(
            K, holder_bytes=holder_bytes, pair_bytes=pair_bytes,
            emit_capacity=app.emit_capacity, n_pairs_hint=n_pairs_hint,
            fused_cap=spec.mxu_lowerable and not kernel_additive)

    manual_block = key_block is None or isinstance(key_block, int)
    def pick_block(chunk_now: int) -> int:
        if key_block is None:
            return K
        if isinstance(key_block, int):
            return max(1, min(int(key_block), K))
        if kernel_monoid and not spec.mxu_lowerable:
            # chunk_monoid_fold auto-sizes its own key block (its VMEM
            # model carries the extra [Tn, Kb, D] masked-expansion term);
            # pinning the additive model's block here could overflow it
            return K
        return choose_key_block(K, chunk_now, d=d + 1,
                                use_kernels=kernel_additive)

    blk = pick_block(chunk)
    measured = False
    cached = False
    if probe and not manual_chunk:
        # persistent micro-probe cache (opt-in via JAX_PALLAS_TUNE_CACHE):
        # a prior run's measured chunk for the same app/shape/lowering is
        # reused instead of re-timing the candidates.
        cache_path = tune_cache_path()
        ckey = (None if cache_path is None else
                _tune_cache_key(app, spec, use_kernels=use_kernels,
                                n_pairs_hint=n_pairs_hint))
        if cache_path is not None:
            hit = load_tune_cache(cache_path).get(ckey)
            if isinstance(hit, dict) and "chunk_pairs" in hit:
                chunk = int(hit["chunk_pairs"])
                cached = True
                notes.append(f"probe cache hit: chunk={chunk} "
                             f"({hit.get('t_us', 0):.0f}us/fold measured "
                             f"by a previous run)")
        if not cached:
            chunk, measured = _probe_chunk(
                app, spec, chunk, use_kernels=use_kernels,
                key_block=None if blk >= K else blk,
                probe_pairs=probe_pairs, notes=notes, items=probe_items)
            if measured and cache_path is not None:
                t_us = _last_probe_us(notes)
                if store_tune_entry(cache_path, ckey,
                                    {"chunk_pairs": int(chunk),
                                     "t_us": t_us}):
                    notes.append(f"probe cache: stored chunk={chunk} "
                                 f"under {cache_path}")
        blk = pick_block(chunk)  # block budgets depend on the chunk

    additive_ok = (kernel_additive
                   or chunk <= col.ADDITIVE_FOLD_PAIRS_FUSED)
    dense_ok = (kernel_monoid
                or chunk * blk <= col.DENSE_FOLD_ELEMS_BUDGET)
    mode = col.stream_mode(spec, dense_ok=dense_ok, additive_ok=additive_ok)
    if spec.mxu_lowerable and mode == "scatter":
        notes.append(
            f"FALLBACK: chunk_pairs={chunk} is outside the fused one-hot "
            f"contraction regime (N <= {col.ADDITIVE_FOLD_PAIRS_FUSED} "
            f"pure-JAX) at key_space={K}; exact scatter fold selected — "
            f"serialized on XLA:CPU, O(N·K) bytes through the roofline "
            f"model. Shrink stream_chunk_pairs (or use_kernels=True) to "
            f"restore the one-hot path.")
    if blk < K:
        notes.append(f"key-blocked fold: {-(-K // blk)} blocks of {blk} "
                     f"keys (working set bounded per block)")

    hint = n_pairs_hint if n_pairs_hint else max(chunk * 4, 1 << 16)
    kb_arg = None if blk >= K else blk
    model_bytes = roofline.mapreduce_flow_bytes(
        "stream", n_pairs=hint, key_space=K, value_bytes=value_bytes,
        holder_bytes=holder_bytes, chunk_pairs=chunk, key_block=kb_arg)
    model_peak = roofline.mapreduce_flow_peak_bytes(
        "stream", n_pairs=hint, key_space=K, value_bytes=value_bytes,
        holder_bytes=holder_bytes, chunk_pairs=chunk, key_block=kb_arg)
    working_set = roofline.stream_working_set_bytes(
        chunk_pairs=chunk, key_block=blk, d=d + 1)

    source = ("manual" if manual_chunk and manual_block
              else "cache" if cached
              else "probe" if measured else "model")
    return StreamTiling(
        chunk_pairs=chunk, key_block=blk, key_space=K, mode=mode,
        source=source, model_bytes=model_bytes, model_peak_bytes=model_peak,
        working_set_bytes=working_set, n_pairs_hint=hint,
        notes=tuple(notes))


def _last_probe_us(notes: list) -> float:
    """Best-candidate time recorded by the last probe note (for the cache)."""
    for n in reversed(notes):
        if n.startswith("probe: measured") and "us/fold" in n:
            try:
                return float(n.rsplit("(", 1)[1].split("us/fold")[0])
            except (IndexError, ValueError):  # pragma: no cover
                return 0.0
    return 0.0


def autotune_sort(
    app,
    spec,
    *,
    use_kernels: bool = False,
    chunk_pairs: int | str = "auto",
    n_pairs_hint: int | None = None,
) -> StreamTiling:
    """Pick the sort-flow tiling: chunk size + radix level decomposition.

    The sort flow touches the O(K) tables once per chunk and its per-pair
    cost grows only as log(chunk), so the chunk is sized as large as the
    clamp allows (bounded by the workload hint — no point chunking beyond
    the stream).  ``key_block`` records the LEAF radix bucket width and
    ``level_fanouts`` the hierarchical decomposition the Pallas pipeline
    partitions with (``kernels/ops.plan_radix_levels``, sized against the
    VMEM budget); the pure-JAX lowering sorts each chunk instead —
    ``sort_passes`` packed digit sorts once the 31-bit packed regime runs
    out (noted).  An infeasible level plan (key space past the level
    budget) is noted here; the engine fires the
    :class:`LoweringFallbackWarning` when the kernel path is actually
    requested.
    """
    from repro.core import plan_cache as pc

    pc.STATS.autotunes += 1
    notes: list[str] = []
    value_bytes = int(jnp.dtype(app.value_aval.dtype).itemsize *
                      max(1, int(np.prod(app.value_aval.shape))))
    pair_bytes = 4 + value_bytes
    d, holder_bytes = spec.holder_width(app.value_aval)
    K = app.key_space

    manual_chunk = isinstance(chunk_pairs, int)
    if manual_chunk:
        chunk = int(chunk_pairs)
    else:
        from repro.core.engine import DEFAULT_SORT_CHUNK_PAIRS

        chunk = DEFAULT_SORT_CHUNK_PAIRS
        if n_pairs_hint is not None and n_pairs_hint > 0:
            chunk = min(chunk, _pow2_round(n_pairs_hint))
        chunk = max(min(chunk, MAX_CHUNK_PAIRS), app.emit_capacity, 1)

    fanouts: tuple[int, ...] = ()
    kernels_feasible = False
    try:
        from repro.kernels import ops

        plan = ops.plan_radix_levels(K, d=d + 1)
        if plan.feasible:
            kernels_feasible = True
            bucket = plan.bucket_size
            fanouts = plan.fanouts
            if plan.levels > 1:
                notes.append(
                    f"hierarchical radix partition: {plan.describe()} — "
                    f"key space past one bucket sweep, each level's "
                    f"fan-out bounded at {ops.MAX_RADIX_FANOUT}")
        else:
            bucket = K
            notes.append(
                f"LEVEL BUDGET: {plan.reason}; the kernel pipeline "
                f"degrades to the pure-JAX multi-pass sorted fold "
                f"(LoweringFallbackWarning at run time)")
    except Exception:  # pragma: no cover
        bucket = K
    sort_passes = col.sort_radix_passes(min(chunk, MAX_CHUNK_PAIRS), K)
    if not use_kernels:
        if sort_passes > 1:
            notes.append(
                f"pure-JAX lowering: (key, index) no longer fits one "
                f"31-bit packed word at chunk={chunk} — multi-pass packed "
                f"radix sort, {sort_passes} digit sorts per chunk "
                f"(lax.scan over levels)")
        else:
            notes.append("pure-JAX lowering: one packed stable sort per "
                         "chunk (the radix buckets below are the kernel "
                         "pipeline's partition granularity)")

    hint = n_pairs_hint if n_pairs_hint else max(chunk * 4, 1 << 16)
    # bytes model per ACTUAL lowering: the kernel hierarchy only when its
    # plan is feasible — the infeasible fallback runs the pure-JAX
    # multi-pass sort and pays its per-pass traffic
    levels = (max(len(fanouts), 1) if use_kernels and kernels_feasible
              else sort_passes)
    model_bytes = roofline.mapreduce_flow_bytes(
        "sort", n_pairs=hint, key_space=K, value_bytes=value_bytes,
        holder_bytes=holder_bytes, chunk_pairs=chunk, sort_levels=levels)
    model_peak = roofline.mapreduce_flow_peak_bytes(
        "sort", n_pairs=hint, key_space=K, value_bytes=value_bytes,
        holder_bytes=holder_bytes, chunk_pairs=chunk)
    working_set = (min(chunk, hint) * pair_bytes * 2.0 + bucket * (d + 1) * 4.0
                   if use_kernels else 0.0)
    return StreamTiling(
        chunk_pairs=chunk, key_block=bucket, key_space=K, mode="sort",
        source="manual" if manual_chunk else "model",
        model_bytes=model_bytes, model_peak_bytes=model_peak,
        working_set_bytes=working_set, n_pairs_hint=hint,
        notes=tuple(notes), level_fanouts=fanouts, sort_passes=sort_passes)


def _probe_chunk(app, spec, chunk: int, *, use_kernels: bool,
                 key_block: int | None, probe_pairs: int,
                 notes: list, items: Any | None = None) -> tuple[int, bool]:
    """Measured micro-probe: time the streaming fold at chunk/2, chunk and
    2·chunk on a real or synthetic workload and keep the fastest.  Costs a
    few jit compilations — opt-in, and advisory (failures keep the model's
    choice).  Returns ``(chunk, measured)``; ``measured`` is False when no
    candidate could be timed (e.g. the synthetic items don't match the
    app's item structure — pass ``probe_items`` in that case)."""
    import time

    from repro.core import engine as eng
    from repro.core import plan_cache as pc

    pc.STATS.probes += 1
    cap = max(app.emit_capacity, 1)
    if items is None:
        n_items = max(probe_pairs // cap, 4)
        rng = np.random.default_rng(0)
        shape = (n_items,) + tuple(app.value_aval.shape)
        if jnp.issubdtype(app.value_aval.dtype, jnp.integer):
            items = jnp.asarray(rng.integers(0, max(app.key_space, 2),
                                             size=shape).astype(np.int32))
        else:
            items = jnp.asarray(rng.standard_normal(shape).astype(np.float32))

    candidates = sorted({max(chunk // 2, cap), chunk,
                         min(chunk * 2, MAX_CHUNK_PAIRS)})
    best, best_t = chunk, float("inf")
    for c in candidates:
        try:
            fn = jax.jit(lambda x, c=c: eng.stream_local_tables(
                app, spec, x, chunk_pairs=c, use_kernels=use_kernels,
                key_block=key_block))
            out = fn(items)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(3):
                jax.block_until_ready(fn(items))
            t = (time.perf_counter() - t0) / 3
        except Exception as e:  # probe is advisory, never fatal
            notes.append(f"probe: chunk={c} failed ({type(e).__name__})")
            continue
        if t < best_t:
            best, best_t = c, t
    if best_t == float("inf"):
        notes.append("probe: no candidate measurable; keeping the model's "
                     "choice (pass probe_items shaped like the app's items)")
        return chunk, False
    notes.append(f"probe: measured {candidates} -> chunk={best} "
                 f"({best_t * 1e6:.0f}us/fold)")
    return best, True
