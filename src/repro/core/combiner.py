"""CombinerSpec: the load-bearing abstraction of the reproduction.

The paper's semantic-aware optimizer rewrites a user ``reduce`` method into a
triple ``initialize() -> Holder``, ``combine(Holder, V)``, ``finalize(Holder)
-> V`` (MR4J §3.1.1).  In this JAX port the triple (plus a cross-shard
``merge`` and an elementwise ``premap``) is reified as :class:`CombinerSpec`.

The spec is consumed by:
  * the MapReduce engine's combine flow (``core/engine.py``),
  * gradient accumulation (``training/grad_accum.py``),
  * MoE combine-back (``models/moe.py``),
  * vocab-parallel cross entropy (``training/losses.py``),
  * flash-decode attention (``kernels/flash_decode.py``).

The paper *assumes* associativity from MapReduce semantics ("assuming that the
operation is associative due to the semantics of the MapReduce framework",
§3.2 step 4).  We keep that contract but additionally provide cheap numeric
probes (:func:`validate_combiner`) used by the optimizer unless
``trust_semantics=True``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# ---------------------------------------------------------------------------
# Monoid identities for the reduction primitives the semantic analyzer
# recognizes.  Mirrors MR4J's Holder initialization ("provides an initial
# intermediate representation for values").
# ---------------------------------------------------------------------------


def _min_identity(dtype) -> Any:
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.array(jnp.iinfo(dtype).max, dtype)
    if dtype == jnp.bool_:
        return jnp.array(True, dtype)
    raise TypeError(f"no min identity for {dtype}")


def _max_identity(dtype) -> Any:
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(-jnp.inf, dtype)
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.array(jnp.iinfo(dtype).min, dtype)
    if dtype == jnp.bool_:
        return jnp.array(False, dtype)
    raise TypeError(f"no max identity for {dtype}")


@dataclasses.dataclass(frozen=True)
class Monoid:
    """A binary associative operation with identity, on a single array leaf."""

    name: str
    op: Callable[[jax.Array, jax.Array], jax.Array]
    identity: Callable[[Any], jax.Array]  # dtype -> scalar identity
    #: jnp.ndarray.at[...] method name usable for scatter-combine, if any.
    scatter_method: str | None = None
    #: whether ``op`` distributes as a plain sum (enables MXU one-hot matmul).
    is_additive: bool = False

    def identity_like(self, aval: jax.ShapeDtypeStruct) -> jax.Array:
        return jnp.full(aval.shape, self.identity(aval.dtype), aval.dtype)

    def dense_reduce(self, masked: jax.Array, axis: int = 0) -> jax.Array:
        """Reduce an identity-masked dense expansion along ``axis``.

        The streaming collector's scatter-free per-chunk fold: entries not
        belonging to a key carry ``identity`` and are absorbed by the op.
        """
        return _DENSE_REDUCE[self.name](masked, axis=axis)


#: dense (masked) reductions over the pair axis — the scatter-free lowering
#: used by the streaming collector: reduce a [chunk, K, ...] identity-masked
#: expansion instead of a per-pair table scatter (which XLA:CPU serializes
#: into a while loop touching the whole table every iteration).
_DENSE_REDUCE = {
    "add": jnp.sum,
    "mul": jnp.prod,
    "max": jnp.max,
    "min": jnp.min,
    "and": jnp.all,
    "or": jnp.any,
}


ADD = Monoid("add", jnp.add, lambda dt: jnp.zeros((), dt), "add", is_additive=True)
MUL = Monoid("mul", jnp.multiply, lambda dt: jnp.ones((), dt), "multiply")
MAX = Monoid("max", jnp.maximum, _max_identity, "max")
MIN = Monoid("min", jnp.minimum, _min_identity, "min")
AND = Monoid("and", jnp.logical_and, lambda dt: jnp.ones((), jnp.bool_), "min")
OR = Monoid("or", jnp.logical_or, lambda dt: jnp.zeros((), jnp.bool_), "max")

MONOIDS = {m.name: m for m in (ADD, MUL, MAX, MIN, AND, OR)}


# ---------------------------------------------------------------------------
# CombinerSpec
# ---------------------------------------------------------------------------

#: How the spec was obtained — mirrors the paper's transformation cases.
STRATEGY_MONOID = "monoid"  # full jaxpr extraction: premap . monoid-reduce . finalize
STRATEGY_FIRST = "idiom_first"  # paper idiom: reducer uses only values[0]
STRATEGY_SIZE = "idiom_size"  # paper idiom: reducer uses only the count
STRATEGY_SCAN = "scan_fold"  # reducer is a lax.scan/fori fold over values
STRATEGY_REAPPLY = "reapply"  # Hadoop-style: reduce re-applied to partials
STRATEGY_MANUAL = "manual"  # user-supplied spec (escape hatch)


@dataclasses.dataclass(frozen=True)
class CombinerSpec:
    """initialize/combine/finalize triple plus cross-shard merge and premap.

    Shapes: a "value" is one emitted value (any pytree of arrays); a "holder"
    is the intermediate accumulation state for one key (any pytree).  The
    engine vectorizes holders into dense tables ``[K_cap, *leaf.shape]``.

    * ``init(value_aval) -> holder``            identity holder
    * ``premap(value) -> mapped``               elementwise pre-map (map-side)
    * ``combine(holder, mapped, n) -> holder``  fold one mapped value; ``n`` is
                                                the number already folded (used
                                                by the first-element idiom)
    * ``merge(a, b, na, nb) -> holder``         associative merge of partial
                                                holders with their fold counts
                                                (cross-tile / cross-shard);
                                                ``None`` if only local folding
                                                is sound (rare: scan folds that
                                                failed the reapply probe)
    * ``finalize(key, holder, count) -> value`` convert holder to final value
    """

    strategy: str
    init: Callable[[PyTree], PyTree]
    premap: Callable[[PyTree], PyTree]
    combine: Callable[[PyTree, PyTree, jax.Array], PyTree]
    merge: Callable[[PyTree, PyTree, jax.Array, jax.Array], PyTree] | None
    finalize: Callable[[Any, PyTree, jax.Array], PyTree]
    #: per-holder-leaf monoids when strategy == monoid (enables scatter /
    #: one-hot-matmul lowering in the collector and Pallas kernels).
    monoids: tuple[Monoid, ...] | None = None
    #: human-readable provenance for logs / EXPERIMENTS.md.
    describe: str = ""
    #: when merge is None: cross-shard merge may re-apply the user reduce to
    #: finalized partials (Hadoop combiner contract), validated by probe.
    reapply_ok: bool = False

    @property
    def scatter_lowerable(self) -> bool:
        """True if the combine can lower to ``table.at[keys].<op>`` scatters."""
        return self.monoids is not None and all(
            m.scatter_method is not None for m in self.monoids
        )

    @property
    def mxu_lowerable(self) -> bool:
        """True if the combine is a pure sum (one-hot matmul on the MXU)."""
        return self.monoids is not None and all(m.is_additive for m in self.monoids)

    def holder_avals(self, value_aval: PyTree) -> PyTree:
        """Shape/dtype of the holder for a given value aval."""
        return jax.eval_shape(lambda v: self.init(v), value_aval)

    def holder_width(self, value_aval: PyTree) -> tuple[int, int]:
        """(flattened holder elems per key, holder bytes per key).

        The streaming autotuner sizes the key-block grid and the chunk
        balance from these (the fused fold's accumulator width is
        ``elems + 1`` for the counts column)."""
        leaves = jax.tree.leaves(self.holder_avals(value_aval))
        elems = sum(int(np.prod(l.shape)) for l in leaves)
        nbytes = sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
                     for l in leaves)
        return max(elems, 1), nbytes

    def kernel_additive_ok(self, value_aval: PyTree) -> bool:
        """Whether the fused additive Pallas fold can carry this spec's
        holders: the kernel accumulates one f32 matrix, so it is exact
        only for float holders (integer tables take the per-leaf path,
        which adds exact per-chunk deltas in the holder's own dtype).
        Callers AND this with the kernel actually being supplied."""
        return self.mxu_lowerable and all(
            jnp.issubdtype(l.dtype, jnp.floating)
            for l in jax.tree.leaves(self.holder_avals(value_aval)))

    def kernel_monoid_ok(self, value_aval: PyTree) -> bool:
        """Whether the chunk monoid-fold Pallas kernel can carry this
        spec's holders (f32 tables, add/max/min monoids on every leaf).
        Callers AND this with the kernel actually being supplied."""
        return (self.monoids is not None and len(self.monoids) > 0
                and all(m.name in ("add", "max", "min")
                        for m in self.monoids)
                and all(l.dtype == jnp.float32
                        for l in jax.tree.leaves(
                            self.holder_avals(value_aval))))

    def init_tables(self, key_space: int, value_aval: PyTree) -> tuple[PyTree, jax.Array]:
        """Identity-initialized dense holder tables ``[K, *holder]`` + counts.

        This is the holder-carry form of the spec: the streaming collector
        threads these tables through a chunked ``lax.scan`` and folds each
        map chunk into them, so the full intermediate pair buffer is never
        materialized (the paper's combining collector, fused with the map).
        """
        h0 = self.init(value_aval)
        tables = jax.tree.map(
            lambda l: jnp.tile(jnp.asarray(l)[None],
                               (key_space,) + (1,) * jnp.ndim(l)), h0)
        counts = jnp.zeros((key_space,), jnp.int32)
        return tables, counts


def monoid_spec(
    monoid: Monoid | str,
    *,
    premap: Callable = lambda v: v,
    finalize: Callable | None = None,
    describe: str = "",
) -> CombinerSpec:
    """Convenience constructor for single-monoid combiners (sum, max, ...)."""
    m = MONOIDS[monoid] if isinstance(monoid, str) else monoid

    def init(value_aval):
        mapped = jax.eval_shape(premap, value_aval)
        return jax.tree.map(m.identity_like, mapped)

    def combine(holder, mapped, n):
        del n
        return jax.tree.map(m.op, holder, mapped)

    def merge(a, b, na, nb):
        del na, nb
        return jax.tree.map(m.op, a, b)

    def default_finalize(key, holder, count):
        del key, count
        return holder

    return CombinerSpec(
        strategy=STRATEGY_MONOID,
        init=init,
        premap=premap,
        combine=combine,
        merge=merge,
        finalize=finalize or default_finalize,
        monoids=(m,),
        describe=describe or f"monoid<{m.name}>",
    )


def product_spec(specs: Sequence[CombinerSpec], finalize, describe="") -> CombinerSpec:
    """Product of combiners: holder is a tuple of the component holders.

    This is how multi-statistic reducers (mean = (sum, count), variance =
    (sum, sumsq), k-means centroid = (coord-sum, point-count)) are expressed —
    the paper's K-Means case ("the combiner or the intermediate value contain
    the running sum", §4.1.3).
    """
    specs = tuple(specs)

    def init(value_aval):
        return tuple(s.init(value_aval) for s in specs)

    def premap(value):
        return tuple(s.premap(value) for s in specs)

    def combine(holder, mapped, n):
        return tuple(s.combine(h, m, n) for s, h, m in zip(specs, holder, mapped))

    def merge(a, b, na, nb):
        return tuple(s.merge(x, y, na, nb) for s, x, y in zip(specs, a, b))

    mono: tuple[Monoid, ...] | None = ()
    for s in specs:
        if s.monoids is None:
            mono = None
            break
        mono = mono + s.monoids  # type: ignore[operator]

    return CombinerSpec(
        strategy=STRATEGY_MONOID if mono is not None else STRATEGY_SCAN,
        init=init,
        premap=premap,
        combine=combine,
        merge=merge if all(s.merge is not None for s in specs) else None,
        finalize=finalize,
        monoids=mono,
        describe=describe or "product(" + ",".join(s.describe for s in specs) + ")",
    )


# ---------------------------------------------------------------------------
# Well-known specs used across the framework (beyond-paper consumers).
# ---------------------------------------------------------------------------


def sum_spec(**kw) -> CombinerSpec:
    return monoid_spec(ADD, describe="sum", **kw)


def max_spec(**kw) -> CombinerSpec:
    return monoid_spec(MAX, describe="max", **kw)


def min_spec(**kw) -> CombinerSpec:
    return monoid_spec(MIN, describe="min", **kw)


def mean_spec() -> CombinerSpec:
    def finalize(key, holder, count):
        del key
        c = jnp.maximum(count, 1).astype(holder.dtype)
        return holder / c

    return monoid_spec(ADD, finalize=finalize, describe="mean")


def count_spec() -> CombinerSpec:
    """The size-only idiom: the result is a function of the count alone."""

    def init(value_aval):
        return ()

    def combine(holder, mapped, n):
        return ()

    def finalize(key, holder, count):
        del key, holder
        return count

    return CombinerSpec(
        strategy=STRATEGY_SIZE,
        init=init,
        premap=lambda v: (),
        combine=combine,
        merge=lambda a, b, na, nb: (),
        finalize=finalize,
        monoids=(),
        describe="count",
    )


def logsumexp_spec() -> CombinerSpec:
    """(m, l) running-max / rescaled-sum monoid.

    The numerically stable streaming logsumexp used by the vocab-parallel
    cross-entropy and (extended with an accumulator) by flash-decode.
    """

    def init(value_aval):
        dt = value_aval.dtype
        return (
            jnp.full(value_aval.shape, -jnp.inf, dt),
            jnp.zeros(value_aval.shape, dt),
        )

    def premap(v):
        return (v, jnp.ones_like(v))

    def _merge2(a, b):
        ma, la = a
        mb, lb = b
        m = jnp.maximum(ma, mb)
        # exp(-inf - -inf) guard: where both -inf, contribute 0.
        sa = jnp.where(jnp.isneginf(ma), 0.0, la * jnp.exp(ma - m))
        sb = jnp.where(jnp.isneginf(mb), 0.0, lb * jnp.exp(mb - m))
        return (m, sa + sb)

    def _merge(a, b, na, nb):
        del na, nb
        return _merge2(a, b)

    def combine(holder, mapped, n):
        del n
        return _merge2(holder, mapped)

    def finalize(key, holder, count):
        del key, count
        m, l = holder
        return m + jnp.log(l)

    return CombinerSpec(
        strategy=STRATEGY_MONOID,
        init=init,
        premap=premap,
        combine=combine,
        merge=_merge,
        finalize=finalize,
        monoids=None,  # not scatter-lowerable: two-leaf coupled update
        describe="logsumexp",
    )


# ---------------------------------------------------------------------------
# Algebraic validation probes.
# ---------------------------------------------------------------------------


def _rand_values(rng: np.random.Generator, aval: jax.ShapeDtypeStruct, n: int):
    shape = (n,) + tuple(aval.shape)
    if jnp.issubdtype(aval.dtype, jnp.floating):
        return jnp.asarray(rng.standard_normal(shape), aval.dtype)
    if jnp.issubdtype(aval.dtype, jnp.integer):
        return jnp.asarray(rng.integers(-4, 5, size=shape), aval.dtype)
    if aval.dtype == jnp.bool_:
        return jnp.asarray(rng.integers(0, 2, size=shape).astype(bool))
    raise TypeError(aval.dtype)


def fold_values(spec: CombinerSpec, values: jax.Array, key=0) -> PyTree:
    """Reference streaming fold of ``values[0..n)`` through the spec."""
    aval = jax.ShapeDtypeStruct(values.shape[1:], values.dtype)
    holder = spec.init(aval)

    def body(carry, v):
        h, n = carry
        h = spec.combine(h, spec.premap(v), n)
        return (h, n + 1), None

    (holder, _), _ = jax.lax.scan(body, (holder, jnp.int32(0)), values)
    return holder


def finalize_fold(spec: CombinerSpec, values: jax.Array, key=0) -> PyTree:
    h = fold_values(spec, values, key)
    return spec.finalize(key, h, jnp.int32(values.shape[0]))


def validate_combiner(
    spec: CombinerSpec,
    reduce_fn: Callable,
    value_aval: jax.ShapeDtypeStruct,
    *,
    key_sample: Any = 0,
    trials: int = 4,
    n_values: int = 9,
    rtol: float = 1e-4,
    atol: float = 1e-4,
    seed: int = 0,
) -> bool:
    """Numeric probes that the derived combiner reproduces the user reduce.

    Checks, on random value batches:
      1. fold equivalence  — finalize(fold(values)) == reduce(key, values, n)
      2. split-merge       — merge(fold(A), fold(B)) == fold(A ++ B)
      3. permutation safety — reduce invariant under value permutation
                              (the MapReduce contract the paper relies on).
                              Skipped for the first-element idiom, whose
                              contract is "any representative value".
    """
    rng = np.random.default_rng(seed)

    def close(a, b):
        la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
        if len(la) != len(lb):
            return False
        return all(
            np.allclose(np.asarray(x, np.float64), np.asarray(y, np.float64),
                        rtol=rtol, atol=atol)
            for x, y in zip(la, lb)
        )

    for _ in range(trials):
        vals = _rand_values(rng, value_aval, n_values)
        n = jnp.int32(n_values)
        want = reduce_fn(key_sample, vals, n)

        # 1. fold equivalence
        got = finalize_fold(spec, vals, key_sample)
        if not close(got, want):
            return False

        # 3. permutation invariance of the user reduce itself
        if spec.strategy != STRATEGY_FIRST:
            perm = rng.permutation(n_values)
            want_p = reduce_fn(key_sample, vals[perm], n)
            if not close(want, want_p):
                return False

        # 2. split-merge
        if spec.merge is not None:
            k = n_values // 2
            ha = fold_values(spec, vals[:k], key_sample)
            hb = fold_values(spec, vals[k:], key_sample)
            hm = spec.merge(ha, hb, jnp.int32(k), jnp.int32(n_values - k))
            got_m = spec.finalize(key_sample, hm, n)
            if not close(got_m, want):
                return False
    return True
