"""Skew-adaptive shuffle planning: sampled histograms -> balanced ranges.

PR 5 made shuffle overflow under key skew *detected*; this module makes it
*handled*, following the data-statistics-driven replanning line (Jahani et
al.; Casper): the framework samples the emitted key distribution, derives
**balanced range boundaries** for the sort/reduce all-to-all instead of the
fixed-width ``k // ceil(K/S)`` radix ranges, and **splits hot keys** across
several destination shards — exact, because the derived combiner is a
monoid, so per-destination partial aggregates of one key recombine to the
unsplit answer (``engine.merge_tables_collective`` /
``engine._merge_tables_host``).

The user surface is one frozen :class:`ShuffleOptions` record carried as
``ExecutionOptions.shuffle``:

* ``capacity`` / ``strict`` — the former flat ``shuffle_capacity`` /
  ``strict_shuffle`` knobs (which now forward here with a
  ``DeprecationWarning``).
* ``skew="auto"`` — sample a key histogram at ``lower()`` time (concrete
  items in hand), derive boundaries + hot-key splits, and memoize the
  decision in-process and (opt-in) in the ``JAX_PALLAS_TUNE_CACHE`` file
  alongside the autotuner's ``StreamTiling`` entries.
* explicit ``boundaries=`` — bypass sampling entirely (tests, replay).

The resolved record is what the plan-cache key digests (``repr`` of the
frozen dataclass), so warm repeat traffic re-derives nothing.

Derivation policy (host-side numpy, sample-sized — micro-probe cheap):

* fixed-width imbalance ``max(range load) / (total/S)`` at or under
  :data:`SNAP_IMBALANCE` snaps to the identity plan (``boundaries=None``)
  — the engine then runs the bitwise-legacy fixed-width arithmetic, which
  is what makes "skew-planned == fixed-width on uniform keys" trivially
  exact.
* keys holding more than :data:`HOT_KEY_FRACTION` of a uniform shard
  share are *hot*: they are carved out of the range balancing and split
  round-robin over ``min(hot_key_split_max, S, ceil(mass/half-share))``
  consecutive shards starting at the range owner (only when the combiner
  is a commutative dense monoid — see :func:`hot_split_ok`).
* boundaries are prefix cuts of the residual histogram's cumulative mass
  at ``j/S``, forced strictly increasing so every shard owns a non-empty
  key range (the engine's static range width is ``max(span)``).
* the default per-destination capacity envelope derives from the sampled
  p-max destination load plus :data:`CAPACITY_SLACK` headroom instead of
  the uniform ``2N/S`` assumption (:meth:`ShufflePlan.capacity_for`).
"""

from __future__ import annotations

import bisect
import dataclasses
import hashlib
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

#: hard cap on the sampled pair count — keeps the probe micro-sized no
#: matter the workload (mirrors the autotuner's probe posture).
SAMPLE_PAIR_CAP = 4096
#: fixed-width imbalance at/below this snaps to the identity plan (the
#: legacy fixed-width path, bitwise) — mild skew is not worth replanning.
SNAP_IMBALANCE = 1.25
#: a key holding more than this fraction of a uniform shard share is hot.
HOT_KEY_FRACTION = 0.5
#: at most this many keys are split (the histogram head; the tail is
#: handled by the range balancing).
MAX_HOT_KEYS = 8
#: headroom multiplier on the sampled p-max destination load when deriving
#: the default capacity envelope (sampling error must not overflow it).
CAPACITY_SLACK = 1.5
#: per-range load cap (x the uniform share) the boundary cuts balance to —
#: within it, the cuts minimize the WIDEST range span instead, because the
#: phase-B table width is static at max-span (a sparse tail range would
#: otherwise inflate every shard's dense table).
BOUNDARY_LOAD_SLACK = 1.25

#: monoids whose dense reduction is order-insensitive in both the
#: collective (psum/pmax/...) and host (``dense_reduce``) merge paths —
#: the exactness envelope of hot-key splitting.
_COMMUTATIVE_MONOIDS = frozenset({"add", "max", "min", "and", "or", "mul"})

#: module-level counters (``plan_cache.stats_snapshot`` style): how many
#: histogram probes ran vs how many resolutions were served from cache.
SKEW_STATS = {"samples": 0, "cache_hits": 0, "resolves": 0}

#: in-process memo of resolved decisions, keyed by content
#: (app signature + shard count + sampled item bytes).
_MEMO: dict[str, dict] = {}


def stats_snapshot() -> dict:
    return dict(SKEW_STATS)


def clear_memo() -> None:
    _MEMO.clear()


# ---------------------------------------------------------------------------
# The options record (ExecutionOptions.shuffle)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShuffleOptions:
    """The unified shuffle option surface (``ExecutionOptions.shuffle``).

    The first block is user intent; the second is the *resolved* planning
    state filled in by :func:`resolve_shuffle_options` (or passed
    explicitly) — keeping it on the frozen record is what makes the
    plan-cache key digest the full decision for free (``repr``)."""

    #: per-destination send capacity; None derives it (from the sampled
    #: p-max load when a skew plan exists, else the legacy 2x uniform).
    capacity: int | None = None
    #: raise on shuffle overflow instead of warning.
    strict: bool = False
    #: "auto" samples a key histogram at lower() time and replans the
    #: sort/reduce all-to-all; "off" keeps the fixed-width ranges.
    skew: str = "off"
    #: fraction of items the histogram probe maps (clamped by
    #: SAMPLE_PAIR_CAP pairs).
    sample_fraction: float = 0.25
    #: max destination shards one hot key may be split over (>=2 enables
    #: splitting; the monoid-merge gate still applies).
    hot_key_split_max: int = 4
    #: shuffle wire codec ("raw" | "delta" | "packed") — see
    #: ``distributed/wire.py``.  "delta" is lossless (bit-packed key
    #: residuals); "packed" additionally narrows values to int8 and is an
    #: explicit opt-in because it can change bits.
    wire: str = "raw"
    # -- resolved planning state -------------------------------------------
    #: S+1 ascending key cuts (boundaries[j] <= k < boundaries[j+1] ->
    #: shard j); None means fixed-width legacy ranges.
    boundaries: tuple[int, ...] | None = None
    hot_keys: tuple[int, ...] = ()
    hot_ways: tuple[int, ...] = ()
    #: fixed-width imbalance factor the sample measured (max range load /
    #: uniform share).
    imbalance: float | None = None
    #: largest destination load fraction under the derived plan — the
    #: default capacity envelope derives from it.
    max_dest_frac: float | None = None
    #: provenance: "sample" | "cache" | "file-cache" | "explicit".
    source: str | None = None

    def __post_init__(self):
        if self.skew not in ("auto", "off"):
            raise ValueError(f"ShuffleOptions.skew must be 'auto' or 'off', "
                             f"got {self.skew!r}")
        from repro.distributed import wire as wirelib

        if self.wire not in wirelib.CODECS:
            raise ValueError(
                f"ShuffleOptions.wire must be one of {wirelib.CODECS}, "
                f"got {self.wire!r}")
        if self.boundaries is not None:
            object.__setattr__(self, "boundaries",
                               tuple(int(b) for b in self.boundaries))
        object.__setattr__(self, "hot_keys",
                           tuple(int(k) for k in self.hot_keys))
        object.__setattr__(self, "hot_ways",
                           tuple(int(w) for w in self.hot_ways))
        if len(self.hot_keys) != len(self.hot_ways):
            raise ValueError("hot_keys and hot_ways must pair up")


@dataclasses.dataclass(frozen=True)
class SkewProfile:
    """What the histogram probe saw — ``explain()`` provenance."""

    n_sampled_pairs: int
    imbalance: float
    #: (key, sampled count) of the heaviest keys, descending.
    top_keys: tuple[tuple[int, int], ...]
    source: str

    def describe(self) -> tuple[str, ...]:
        top = ", ".join(f"{k}:{c}" for k, c in self.top_keys)
        return (
            f"histogram: {self.n_sampled_pairs} sampled pairs "
            f"({self.source}); fixed-width imbalance "
            f"{self.imbalance:.2f}x; heavy hitters [{top}]",
        )


# ---------------------------------------------------------------------------
# The engine-facing resolved plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShufflePlan:
    """Resolved boundary/hot-split plan the engine routes by.

    Frozen + tuple-valued so it hashes into jit closures and ``repr``s
    into cache keys.  ``width`` is the static per-shard range span (the
    shard_map out-width must be uniform); narrow ranges pad with
    zero-count rows exactly like the legacy ``ceil(K/S)`` padding."""

    key_space: int
    num_shards: int
    boundaries: tuple[int, ...]
    hot_keys: tuple[int, ...] = ()
    hot_ways: tuple[int, ...] = ()
    imbalance: float | None = None
    max_dest_frac: float | None = None

    def __post_init__(self):
        b, S, K = self.boundaries, self.num_shards, self.key_space
        if len(b) != S + 1:
            raise ValueError(f"need {S + 1} boundaries for {S} shards, "
                             f"got {len(b)}")
        if b[0] != 0 or b[-1] != K:
            raise ValueError(f"boundaries must span [0, {K}], got "
                             f"[{b[0]}, {b[-1]}]")
        if any(b[i + 1] <= b[i] for i in range(S)):
            raise ValueError("boundaries must be strictly increasing")
        for k, w in zip(self.hot_keys, self.hot_ways):
            if not 0 <= k < K:
                raise ValueError(f"hot key {k} outside [0, {K})")
            if w < 2:
                raise ValueError(f"hot key {k} split {w} ways (<2)")
        if len(self.hot_keys) != len(set(self.hot_keys)):
            raise ValueError("duplicate hot keys")

    @property
    def width(self) -> int:
        """Static per-shard range width: the widest boundary span."""
        b = self.boundaries
        return max(b[i + 1] - b[i] for i in range(self.num_shards))

    @property
    def epoch(self) -> int:
        """Content fingerprint of the boundary/hot layout — stamped into
        the resilient driver's checkpointable wire format so a partial
        checkpointed under different boundaries is never merged."""
        return zlib.crc32(repr((self.boundaries, self.hot_keys,
                                self.hot_ways)).encode())

    def hot_owner(self, key: int) -> int:
        """Range owner of a hot key (the shard whose boundary span holds
        it) — the split destinations start there, and the merged hot row
        lands back in the owner's output range."""
        return bisect.bisect_right(self.boundaries, key) - 1

    def hot_dests(self, i: int) -> tuple[int, ...]:
        owner = self.hot_owner(self.hot_keys[i])
        return tuple((owner + m) % self.num_shards
                     for m in range(self.hot_ways[i]))

    def capacity_for(self, n_pairs: int) -> int:
        """Default per-destination send capacity: sampled p-max
        destination load + :data:`CAPACITY_SLACK` headroom (the bugfix
        over the uniform ``2N/S`` assumption, which a skewed
        distribution overflows).  The legacy ``2N/S`` envelope stays the
        FLOOR: the sample sees aggregate loads, not per-source-shard
        variance, so the derived envelope must only ever widen."""
        from repro.core import engine as eng

        S = self.num_shards
        legacy = eng.shuffle_bucket_capacity(n_pairs, S)
        if self.max_dest_frac is None:
            return legacy
        frac = min(1.0, float(self.max_dest_frac))
        cap = int(np.ceil(n_pairs * frac * CAPACITY_SLACK))
        return max(min(n_pairs, max(cap, 8)), legacy)

    def describe(self) -> tuple[str, ...]:
        b = self.boundaries
        spans = [b[i + 1] - b[i] for i in range(self.num_shards)]
        lines = [
            f"boundaries: {self.num_shards} ranges over K={self.key_space}"
            f" width={self.width} (spans {min(spans)}..{max(spans)})"
            + (f" imbalance={self.imbalance:.2f}x"
               if self.imbalance is not None else "")
            + (f" p-max dest {self.max_dest_frac:.3f}"
               if self.max_dest_frac is not None else "")]
        if self.hot_keys:
            parts = ", ".join(
                f"{k}x{w}@{self.hot_dests(i)}"
                for i, (k, w) in enumerate(zip(self.hot_keys,
                                               self.hot_ways)))
            lines.append(f"hot keys split: {parts} "
                         f"(partial-aggregate recombine in phase B)")
        return tuple(lines)


def hot_split_ok(flow: str, spec, value_aval) -> bool:
    """Hot-key splitting is exact only when every holder leaf merges with
    a commutative dense monoid: the split destinations' partials recombine
    through ``merge_tables_collective``/``_merge_tables_host``, whose
    reductions must be order-insensitive AND defined for every leaf (the
    generic ``spec.merge``/reapply paths see per-key value *lists*, which
    a split would reorder)."""
    if flow != "sort" or spec is None:
        return False
    if spec.merge is None or spec.monoids is None:
        return False
    # memoized on the (frozen) spec: holder_avals is an eval_shape trace,
    # and this gate sits on the staged path's per-lower() hot loop
    sig = str(jax.tree.map(lambda a: (tuple(a.shape), str(a.dtype)),
                           value_aval))
    tag = f"_hot_split_ok_{sig}"
    cached = spec.__dict__.get(tag)
    if cached is None:
        leaves = jax.tree.leaves(spec.holder_avals(value_aval))
        cached = (len(spec.monoids) == len(leaves)
                  and all(m.name in _COMMUTATIVE_MONOIDS
                          for m in spec.monoids))
        object.__setattr__(spec, tag, cached)
    return cached


# ---------------------------------------------------------------------------
# Sampling + derivation
# ---------------------------------------------------------------------------


def _sample_indices(n_items: int, sample_fraction: float,
                    emit_capacity: int) -> np.ndarray:
    """Deterministic strided subsample of the item axis, pair-capped.

    Inputs small enough to fit the pair cap are histogrammed EXACTLY —
    fractional sampling of a tiny input is all noise and no savings, and
    a noisy histogram on genuinely uniform keys would defeat the identity
    snap (and with it the bitwise-legacy parity guarantee)."""
    cap_items = max(1, SAMPLE_PAIR_CAP // max(emit_capacity, 1))
    want = int(np.ceil(n_items * max(min(sample_fraction, 1.0), 0.0)))
    want = max(want, min(n_items, cap_items))
    want = max(1, min(want, cap_items))
    stride = max(1, n_items // want)
    return np.arange(0, n_items, stride)[:want]


def sample_key_histogram(app, items, *,
                         sample_fraction: float = 0.25) -> np.ndarray:
    """Map a strided item subsample eagerly and histogram the valid keys.

    Reuses the engine's ``map_phase`` (the autotune micro-probe posture:
    tiny, eager, host-side) — the histogram is over EMITTED keys, i.e. the
    distribution the all-to-all actually routes."""
    from repro.core import engine as eng

    leaves = jax.tree.leaves(items)
    n = int(leaves[0].shape[0])
    idx = _sample_indices(n, sample_fraction,
                          int(getattr(app, "emit_capacity", 16)))
    sub = jax.tree.map(lambda a: jnp.asarray(a)[idx], items)
    stream = eng.map_phase(app, sub)
    keys = np.asarray(stream.keys)
    valid = np.asarray(stream.valid)
    SKEW_STATS["samples"] += 1
    return np.bincount(keys[valid], minlength=app.key_space
                       ).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class SkewDecision:
    """Raw derivation output (pre-``ShuffleOptions`` packaging)."""

    boundaries: tuple[int, ...] | None
    hot_keys: tuple[int, ...]
    hot_ways: tuple[int, ...]
    imbalance: float
    max_dest_frac: float | None
    top_keys: tuple[tuple[int, int], ...]
    n_sampled_pairs: int


def _balanced_cuts(residual: np.ndarray, K: int, S: int,
                   rtotal: int, n_pairs: int | None = None) -> list[int]:
    """S contiguous ranges covering [0, K): cap each range's load at
    :data:`BOUNDARY_LOAD_SLACK` x the uniform share, and under that cap
    MINIMIZE the widest span (binary search) — the engine's phase-B dense
    tables are statically sized at max-span on EVERY shard, so one sparse
    wide tail range taxes the whole mesh.

    Tightening the load cap narrows the ranges around the histogram head
    and widens the tail spans; relaxing it does the opposite but inflates
    the p-max capacity envelope every receive buffer is sized to.  Which
    side wins depends on the workload: with ``n_pairs`` known, the slack
    candidates are scored by the estimated phase-B row count (S receive
    buckets of the p-max envelope + one static-width table) and the
    cheapest wins; without it, the cap is traded up just until the widest
    span meets the ~1.25x span budget.
    """
    cum = np.cumsum(residual)
    min_span = -(-K // S)
    span_budget = min_span + min_span // 4

    def cuts_for(load_cap: float, span_cap: int) -> list[int] | None:
        bounds = [0]
        for _ in range(S):
            start = bounds[-1]
            if start >= K:
                break
            base = float(cum[start - 1]) if start else 0.0
            b = int(np.searchsorted(cum, base + load_cap, side="right"))
            b = max(start + 1, min(b, start + span_cap, K))
            bounds.append(b)
        return bounds if bounds[-1] == K else None

    def min_span_cuts(load_cap: float) -> list[int] | None:
        if cuts_for(load_cap, K) is None:
            # infeasible for S CONTIGUOUS ranges (the greedy stops just
            # short of a heavy key S times over)
            return None
        lo, hi = min_span, K
        while lo < hi:
            mid = (lo + hi) // 2
            if cuts_for(load_cap, mid) is not None:
                hi = mid
            else:
                lo = mid + 1
        return cuts_for(load_cap, lo)

    candidates = []
    for slack in (BOUNDARY_LOAD_SLACK, 1.5, 2.0, 3.0, 4.0, 8.0, float(S)):
        # a single key's mass is indivisible across contiguous cuts, so
        # the cap can never sit below the heaviest residual key
        cap = max(slack * rtotal / S, float(residual.max()))
        got = min_span_cuts(cap)
        if got is not None:
            candidates.append(got)
    if not candidates:  # slack >= S is one range holding all: feasible
        candidates = [min_span_cuts(float(rtotal) + 1.0)]

    if n_pairs is not None:
        def phase_b_rows(b) -> float:
            width = int(max(np.diff(b)))
            loads = np.add.reduceat(residual, np.asarray(b[:-1]))
            frac = float(loads.max()) / max(rtotal, 1)
            envelope = (n_pairs / S) * frac * CAPACITY_SLACK
            return S * envelope + width

        bounds = min(candidates, key=phase_b_rows)
    else:
        bounds = candidates[-1]
        for got in candidates:
            if max(np.diff(got)) <= span_budget:
                bounds = got
                break
    # the greedy may cover K in fewer than S ranges: split the widest
    # spans (shrinking the static width further) until there are exactly S
    while len(bounds) - 1 < S:
        spans = np.diff(bounds)
        i = int(spans.argmax())
        bounds.insert(i + 1, bounds[i] + int(spans[i]) // 2)
    return bounds


def derive(hist: np.ndarray, num_shards: int, *,
           hot_key_split_max: int = 4,
           mergeable: bool = False,
           n_pairs: int | None = None) -> SkewDecision:
    """Derive balanced boundaries + hot-key splits from a key histogram.

    Pure host-side numpy over the (sample-sized) histogram; deterministic.
    ``n_pairs`` (the run's total emitted pair count, when known) lets the
    cut selection score the span-vs-load trade by estimated phase-B rows.
    """
    hist = np.asarray(hist, np.int64)
    K = int(hist.shape[0])
    S = int(num_shards)
    total = int(hist.sum())
    order = np.argsort(hist)[::-1]
    top = tuple((int(k), int(hist[k])) for k in order[:5] if hist[k] > 0)

    def identity(imb: float) -> SkewDecision:
        return SkewDecision(None, (), (), imb, None, top, total)

    if total == 0 or S <= 1 or K < S:
        return identity(1.0)

    uniform = total / S
    # fixed-width range loads (the legacy k // ceil(K/S) layout)
    K_local = -(-K // S)
    fixed_loads = np.add.reduceat(hist, np.arange(0, K, K_local))
    imbalance = float(fixed_loads.max() / uniform)
    if imbalance <= SNAP_IMBALANCE:
        return identity(imbalance)

    # hot keys: more than HOT_KEY_FRACTION of a uniform share, head-capped
    hot_keys: list[int] = []
    hot_ways: list[int] = []
    if mergeable and hot_key_split_max >= 2 and S >= 2:
        thresh = HOT_KEY_FRACTION * uniform
        for k in order[:MAX_HOT_KEYS]:
            if hist[k] > thresh:
                hot_keys.append(int(k))
                hot_ways.append(int(min(
                    hot_key_split_max, S,
                    max(2, int(np.ceil(hist[k] / max(thresh, 1.0)))))))
    residual = hist.copy()
    residual[hot_keys] = 0
    rtotal = int(residual.sum())

    bounds = _balanced_cuts(residual, K, S, rtotal, n_pairs=n_pairs)

    # p-max destination load fraction under the derived plan: residual
    # range loads + each hot key's mass spread over its destinations
    starts = np.asarray(bounds[:-1])
    loads = np.add.reduceat(residual, starts).astype(np.float64)
    # np.add.reduceat repeats a slice when consecutive starts collide —
    # cannot happen here (strictly increasing), but an empty final range
    # can't either (bounds end at K)
    for i, (k, w) in enumerate(zip(hot_keys, hot_ways)):
        owner = bisect.bisect_right(bounds, k) - 1
        share = hist[k] / w
        for m in range(w):
            loads[(owner + m) % S] += share
    max_dest_frac = float(loads.max() / total)
    return SkewDecision(tuple(int(b) for b in bounds), tuple(hot_keys),
                        tuple(hot_ways), imbalance, max_dest_frac, top,
                        total)


# ---------------------------------------------------------------------------
# Resolution (lower()-time): options -> resolved options (+ profile)
# ---------------------------------------------------------------------------


def _resolve_memo_key(app, num_shards: int, options: ShuffleOptions,
                      items, *, mergeable: bool) -> str:
    """Content key for the resolution memo: app signature (autotune cache
    key style) + shard count + derivation gates + the BYTES of the strided
    item subsample — hashed before any mapping, so a warm hit skips the
    probe entirely.  ``mergeable`` is part of the key because it changes
    the derivation itself: a hot-split decision's boundaries AND capacity
    envelope assume the split spreads the head key's mass."""
    aval = app.value_aval
    head = "|".join([
        "skew", type(app).__name__, f"K={app.key_space}",
        f"cap={app.emit_capacity}",
        f"v={jnp.dtype(aval.dtype).name}{tuple(aval.shape)}",
        f"S={num_shards}", f"frac={options.sample_fraction}",
        f"split={options.hot_key_split_max}",
        f"merge={int(mergeable)}",
    ])
    h = hashlib.sha256(head.encode())
    leaves = jax.tree.leaves(items)
    n = int(leaves[0].shape[0])
    # n feeds the derivation's phase-B row scoring, not just the sample
    h.update(f"n={n}".encode())
    idx = _sample_indices(n, options.sample_fraction,
                          int(getattr(app, "emit_capacity", 16)))
    h.update(np.asarray(idx).tobytes())
    for leaf in leaves:
        h.update(np.ascontiguousarray(np.asarray(leaf)[idx]).tobytes())
    from repro.core import autotune as at

    return f"{at.SKEW_KEY_PREFIX}{h.hexdigest()[:16]}"


def _decision_entry(d: SkewDecision) -> dict:
    return {
        "boundaries": list(d.boundaries) if d.boundaries is not None
        else None,
        "hot_keys": list(d.hot_keys), "hot_ways": list(d.hot_ways),
        "imbalance": d.imbalance, "max_dest_frac": d.max_dest_frac,
        "top_keys": [list(t) for t in d.top_keys],
        "n_sampled_pairs": d.n_sampled_pairs,
    }


def _entry_decision(e: dict) -> SkewDecision:
    return SkewDecision(
        tuple(e["boundaries"]) if e.get("boundaries") is not None else None,
        tuple(e.get("hot_keys", ())), tuple(e.get("hot_ways", ())),
        float(e.get("imbalance", 1.0)), e.get("max_dest_frac"),
        tuple((int(k), int(c)) for k, c in e.get("top_keys", ())),
        int(e.get("n_sampled_pairs", 0)))


def resolve_shuffle_options(app, plan, items, *, num_shards: int,
                            options: ShuffleOptions | None
                            ) -> tuple[ShuffleOptions,
                                       SkewProfile | None]:
    """Fill a ``ShuffleOptions`` record's planning state from the data.

    Called at ``MapReduce.lower()`` time — the one stage with concrete
    items in hand.  Explicit boundaries pass through untouched; otherwise
    ``skew="auto"`` on a multi-shard sort/reduce plan samples (or recalls)
    the key histogram and bakes the derived decision into the returned
    frozen record, which the plan-cache key then digests."""
    opts = options if options is not None else ShuffleOptions()
    if opts.boundaries is not None:
        src = opts.source or "explicit"
        return (dataclasses.replace(opts, source=src),
                SkewProfile(0, opts.imbalance or 0.0, (), src))
    if (opts.skew != "auto" or num_shards <= 1
            or plan.flow not in ("sort", "reduce")):
        return opts, None

    mergeable = (opts.hot_key_split_max >= 2
                 and hot_split_ok(plan.flow, plan.spec, app.value_aval))
    key = _resolve_memo_key(app, num_shards, opts, items,
                            mergeable=mergeable)
    decision = None
    source = "sample"
    if key in _MEMO:
        decision = _entry_decision(_MEMO[key])
        source = "cache"
        SKEW_STATS["cache_hits"] += 1
    else:
        from repro.core import autotune as at

        path = at.tune_cache_path()
        if path is not None:
            e = at.load_tune_cache(path).get(key)
            if isinstance(e, dict):
                decision = _entry_decision(e)
                source = "file-cache"
                SKEW_STATS["cache_hits"] += 1
        if decision is None:
            hist = sample_key_histogram(
                app, items, sample_fraction=opts.sample_fraction)
            n_items = int(jax.tree.leaves(items)[0].shape[0])
            decision = derive(
                hist, num_shards,
                hot_key_split_max=opts.hot_key_split_max,
                mergeable=mergeable,
                n_pairs=n_items * int(getattr(app, "emit_capacity", 1)))
        _MEMO[key] = _decision_entry(decision)
        if path is not None and source == "sample":
            at.store_tune_entry(path, key, _MEMO[key])
    SKEW_STATS["resolves"] += 1

    profile = SkewProfile(decision.n_sampled_pairs, decision.imbalance,
                          decision.top_keys, source)
    resolved = dataclasses.replace(
        opts, boundaries=decision.boundaries,
        hot_keys=decision.hot_keys if mergeable else (),
        hot_ways=decision.hot_ways if mergeable else (),
        imbalance=decision.imbalance,
        max_dest_frac=decision.max_dest_frac, source=source)
    return resolved, profile


def plan_from_options(key_space: int, num_shards: int,
                      options: ShuffleOptions | None, *,
                      flow: str | None = None, spec=None,
                      value_aval=None) -> ShufflePlan | None:
    """Build the engine-facing :class:`ShufflePlan` from resolved options.

    ``None`` (no boundaries) keeps the engine on the bitwise-legacy
    fixed-width path.  Hot keys on a plan whose flow/combiner cannot
    recombine split partials exactly are a hard error — never a silent
    wrong answer."""
    if options is None or options.boundaries is None:
        return None
    if options.hot_keys and flow is not None:
        if not hot_split_ok(flow, spec, value_aval):
            raise ValueError(
                f"hot-key splitting needs the sort flow with a fully "
                f"commutative-monoid combiner (flow={flow!r}); drop "
                f"hot_keys from ShuffleOptions or let skew='auto' gate it")
    return ShufflePlan(
        key_space=key_space, num_shards=num_shards,
        boundaries=options.boundaries, hot_keys=options.hot_keys,
        hot_ways=options.hot_ways, imbalance=options.imbalance,
        max_dest_frac=options.max_dest_frac)
