"""Content-keyed compiled-plan cache (ROADMAP: the serving unlock).

``MapReduce.run`` used to re-run the optimizer (a jaxpr trace + numeric
validation probes), re-autotune the tiling and rebuild its jitted
executable on every construction — the opposite of the serving posture,
where the same app shape arrives millions of times.  This module gives the
staged ``lower()/optimize()/compile()`` path (``core/api.py``) a
process-wide cache keyed by *content*, not object identity:

    reduce-jaxpr hash x map-jaxpr hash x K x value dtype/shape x N-bucket
    x flow x lowering knobs x mesh shape

so repeat traffic — same app semantics, same shapes — never re-derives,
never re-tunes and never re-compiles, no matter how many ``MapReduce`` /
``Pipeline`` objects the caller constructs.  The JaCe/JAX AOT stage
architecture is the model: the cache sits between ``optimize()`` and
``compile()`` and stores the whole stage-chain result.

Two layers:

* **in-memory** (``_PLANS`` / ``_COMPILED``) — full hits: the cached
  ``ExecutionPlan`` (with its live ``CombinerSpec`` closures), the
  autotuned ``StreamTiling`` and the compiled executable are reused
  directly.  Zero optimizer traces, zero autotune calls, zero XLA
  compiles on a hit (asserted via :data:`STATS` counters in the tests).
* **file-backed** (opt-in via ``JAX_PALLAS_PLAN_CACHE``) — a JSON side
  file persisting the *decisions* (flow, chunk size, key block, level
  fan-outs) across processes.  Combiner closures and executables cannot
  be serialized, so a file hit still derives and compiles — but skips the
  autotune probes.  Exactly like ``JAX_PALLAS_TUNE_CACHE`` the file layer
  is advisory and corrupt-safe: unreadable files, malformed entries and
  stale schemas are ignored, never fatal.

Counters (``STATS``) are bumped at the places the cache is meant to make
idle — ``optimizer.derive_combiner`` (the optimizer's trace), the
``autotune_stream``/``autotune_sort`` calls, the measured micro-probe, and
the staged ``compile()`` — so tests can assert "warm traffic does none of
this" instead of trusting the docs.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import os
from typing import Any

#: env var pointing at the persistent plan-decision cache (JSON file).
#: Unset (the default, and in CI) -> plan decisions are not persisted.
PLAN_CACHE_ENV = "JAX_PALLAS_PLAN_CACHE"


# ---------------------------------------------------------------------------
# Counters: what the cache is supposed to save, made assertable
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CacheStats:
    """Process-wide event counters (see module docstring).

    ``derives`` counts optimizer runs (each is a jaxpr trace + validation
    probes), ``autotunes`` the tiling autotuner calls, ``probes`` the
    measured micro-probe invocations, ``compiles`` the staged XLA
    compiles.  ``hits``/``misses`` are in-memory compiled-plan lookups;
    ``plan_hits``/``plan_misses`` the plan-stage (pre-shape) lookups;
    ``file_hits`` the advisory file-layer hits."""

    derives: int = 0
    autotunes: int = 0
    probes: int = 0
    compiles: int = 0
    hits: int = 0
    misses: int = 0
    plan_hits: int = 0
    plan_misses: int = 0
    file_hits: int = 0

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)


STATS = CacheStats()


def stats_snapshot() -> dict:
    """Copy of the counters — diff two snapshots to assert cache behaviour."""
    return STATS.snapshot()


# ---------------------------------------------------------------------------
# Content fingerprints
# ---------------------------------------------------------------------------


#: fallback identity for untraceable map/reduce fns.  A monotonic counter
#: stored on the app — unlike ``id(app)``, never reused after the app is
#: garbage-collected, so a fallback key can never alias another app's
#: cached plan.
_FALLBACK_UIDS = itertools.count()


def _fallback_uid(app) -> int:
    memo = app.__dict__.setdefault("_plan_cache_fp", {})
    if "uid" not in memo:
        memo["uid"] = next(_FALLBACK_UIDS)
    return memo["uid"]


def _digest(*parts: str) -> str:
    h = hashlib.sha256()
    for p in parts:
        h.update(p.encode())
        h.update(b"\x00")
    return h.hexdigest()[:16]


def _aval_sig(aval) -> str:
    import jax.numpy as jnp

    return f"{jnp.dtype(aval.dtype).name}{tuple(aval.shape)}"


def _jaxpr_sig(closed) -> str:
    """Content signature of a ClosedJaxpr: the printed program plus a hash
    of every captured constant's BYTES — ``str(jaxpr)`` alone elides large
    const values, so two closures differing only in a captured lookup
    table would otherwise collide."""
    import numpy as np

    parts = [str(closed)]
    for c in getattr(closed, "consts", ()):
        try:
            a = np.asarray(c)
            parts.append(f"{a.dtype}{a.shape}:"
                         + hashlib.sha256(a.tobytes()).hexdigest()[:12])
        except Exception:
            parts.append(repr(c))
    return "\x00".join(parts)


def _app_attr_sig(app) -> str:
    return "|".join([
        f"K={app.key_space}",
        f"v={_aval_sig(app.value_aval)}",
        f"cap={app.emit_capacity}",
        f"lmax={getattr(app, 'max_values_per_key', 0)}",
        f"pad={app.pad_value!r}",
    ])


def reduce_fingerprint(app) -> str:
    """Content hash of the app's reduce semantics: the jaxpr of
    ``reduce(key, values, count)`` (traced once, memoized on the app
    instance) plus the attributes the planner keys on.  Two app objects
    with identical reduce code and shapes share the fingerprint — that is
    what makes the cache *content*-keyed rather than id-keyed."""
    memo = app.__dict__.setdefault("_plan_cache_fp", {})
    if "reduce" not in memo:
        import jax
        import jax.numpy as jnp

        aval = app.value_aval
        try:
            jaxpr = jax.make_jaxpr(app.reduce)(
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((4,) + tuple(aval.shape), aval.dtype),
                jax.ShapeDtypeStruct((), jnp.int32))
            sig = _jaxpr_sig(jaxpr)
        except Exception:  # untraceable reduce: fall back to identity
            sig = f"uid:{_fallback_uid(app)}:{type(app).__qualname__}"
        memo["reduce"] = _digest(sig, _app_attr_sig(app))
    return memo["reduce"]


def map_fingerprint(app, item_spec) -> str:
    """Content hash of the app's map semantics over one item of
    ``item_spec``: the jaxpr of ``map(item, emit)`` through a recording
    emitter (traced once per item spec, memoized on the app instance)."""
    spec_sig = _spec_sig(item_spec)
    memo = app.__dict__.setdefault("_plan_cache_fp", {})
    key = f"map:{spec_sig}"
    if key not in memo:
        import jax

        from repro.core import engine as eng

        def one(item):
            em = eng.Emitter(app.emit_capacity, app.key_space, app.value_aval)
            app.map(item, em)
            return em.pairs()

        try:
            sig = _jaxpr_sig(jax.make_jaxpr(one)(item_spec))
        except Exception:
            sig = f"uid:{_fallback_uid(app)}:{type(app).__qualname__}"
        memo[key] = _digest(sig, spec_sig)
    return memo[key]


def _spec_sig(spec_tree) -> str:
    import jax

    leaves, treedef = jax.tree.flatten(spec_tree)
    return f"{treedef}:" + ",".join(_aval_sig(x) for x in leaves)


def items_spec_of(items):
    """ShapeDtypeStruct pytree of ``items`` (concrete arrays pass through
    ``jax.eval_shape``-style; specs are returned unchanged)."""
    import jax

    return jax.tree.map(
        lambda a: (a if isinstance(a, jax.ShapeDtypeStruct)
                   else jax.ShapeDtypeStruct(a.shape, a.dtype)), items)


def item_spec_of(items_spec):
    """One-item spec: ``items_spec`` with the leading (batch) axis dropped."""
    import jax

    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(tuple(a.shape[1:]), a.dtype),
        items_spec)


def bucket_items(n: int, policy: str = "exact") -> int:
    """The N-bucket of the cache key: ``"exact"`` keeps the true item
    count (one executable per shape — jit's contract); ``"pow2"`` rounds
    up to the next power of two so nearby batch sizes share one padded
    executable (the serving case; ``Compiled`` masks the pad rows)."""
    if policy == "exact":
        return int(n)
    if policy == "pow2":
        b = 1
        while b < n:
            b <<= 1
        return b
    raise ValueError(f"unknown items bucket policy {policy!r}")


def plan_key(app, *, flow: str, trust_semantics: bool,
             n_pairs_hint: int | None, use_kernels: bool,
             combine_impl: str, chunk_pairs, key_block,
             autotune_probe: bool, streaming: bool = False) -> str:
    """Key of the plan stage (derivation + flow selection + tiling) —
    everything :class:`MapReduce` resolves before it sees item shapes."""
    return _digest(
        "plan", reduce_fingerprint(app), _app_attr_sig(app),
        f"flow={flow}", f"trust={trust_semantics}",
        f"hint={n_pairs_hint}", f"kern={use_kernels}",
        f"impl={combine_impl}", f"chunk={chunk_pairs}",
        f"blk={key_block}", f"probe={autotune_probe}",
        f"streaming={streaming}")


def compiled_key(app, items_spec, *, plan_key: str, flow: str,
                 n_bucket: int, mesh=None, data_axis: str = "data",
                 mode: str = "local", extra: tuple = ()) -> str:
    """Key of the compiled stage: the plan key x the map jaxpr over the
    item spec x the (bucketed) batch shape x the mesh topology x the
    execution mode and any residual lowering knobs."""
    mesh_sig = ("none" if mesh is None else
                f"{dict(zip(mesh.axis_names, mesh.devices.shape))}")
    return _digest(
        "compiled", plan_key,
        map_fingerprint(app, item_spec_of(items_spec)),
        _spec_sig(items_spec), f"N={n_bucket}", f"flow={flow}",
        f"mesh={mesh_sig}", f"axis={data_axis}", f"mode={mode}",
        *[str(x) for x in extra])


# ---------------------------------------------------------------------------
# In-memory cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PlanEntry:
    """Cached plan stage: the resolved plan (template), tiling and the
    lowering knobs the API layer derived from them."""

    plan: Any
    tiling: Any
    stream_chunk_pairs: int
    key_block: int | None
    bucket_size: int | None
    level_fanouts: tuple[int, ...] | None


@dataclasses.dataclass
class CompiledEntry:
    """Cached compile stage: the executable plus everything ``explain()``
    and the result plumbing need."""

    executable: Any
    plan: Any
    tiling: Any
    n_bucket: int
    mode: str  # "local" | "distributed"
    aux: Any = None


_PLANS: dict[str, PlanEntry] = {}
_COMPILED: dict[str, CompiledEntry] = {}


def plan_get(key: str) -> PlanEntry | None:
    hit = _PLANS.get(key)
    if hit is None:
        STATS.plan_misses += 1
    else:
        STATS.plan_hits += 1
    return hit


def plan_put(key: str, entry: PlanEntry) -> None:
    _PLANS[key] = entry


def compiled_get(key: str) -> CompiledEntry | None:
    hit = _COMPILED.get(key)
    if hit is None:
        STATS.misses += 1
    else:
        STATS.hits += 1
    return hit


def compiled_put(key: str, entry: CompiledEntry) -> None:
    _COMPILED[key] = entry


def clear() -> None:
    """Drop both in-memory layers (tests; the file layer is untouched)."""
    _PLANS.clear()
    _COMPILED.clear()


def sizes() -> tuple[int, int]:
    return len(_PLANS), len(_COMPILED)


# ---------------------------------------------------------------------------
# File-backed advisory layer (cross-process plan decisions)
# ---------------------------------------------------------------------------

#: fields a file entry must carry with these exact types to be trusted;
#: anything else — hand-edited files, entries from an older schema, plain
#: corruption — reads as "no entry" (the tune-cache corrupt-safe contract).
_FILE_SCHEMA = {"flow": str, "chunk_pairs": int}
_FILE_OPTIONAL = {"key_block": int, "bucket_size": int,
                  "level_fanouts": list}


def plan_cache_path() -> str | None:
    p = os.environ.get(PLAN_CACHE_ENV, "").strip()
    return p or None


def _load_file(path: str) -> dict:
    try:
        with open(path) as f:
            data = json.load(f)
        return data if isinstance(data, dict) else {}
    except (OSError, ValueError):
        return {}


def _entry_valid(entry) -> bool:
    if not isinstance(entry, dict):
        return False
    for field, typ in _FILE_SCHEMA.items():
        if not isinstance(entry.get(field), typ):
            return False
    for field, typ in _FILE_OPTIONAL.items():
        if field in entry and entry[field] is not None \
                and not isinstance(entry[field], typ):
            return False
    if entry["flow"] not in ("stream", "sort", "combine", "reduce"):
        return False
    return True


def file_get(key: str) -> dict | None:
    """Validated file-layer entry for ``key``, or None (missing file,
    corrupt JSON, malformed/stale entry — all read the same: no entry)."""
    path = plan_cache_path()
    if path is None:
        return None
    entry = _load_file(path).get(key)
    if not _entry_valid(entry):
        return None
    STATS.file_hits += 1
    return entry


def file_put(key: str, entry: dict) -> bool:
    """Best-effort merge into the file layer (atomic replace; failures are
    swallowed — the cache must never break a run)."""
    path = plan_cache_path()
    if path is None:
        return False
    try:
        cache = _load_file(path)
        cache[key] = entry
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(cache, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
        return True
    except OSError:
        return False


def file_entry_from(plan, tiling) -> dict:
    """Serializable decision record of a resolved plan stage."""
    entry: dict[str, Any] = {"flow": plan.flow}
    if tiling is not None:
        entry["chunk_pairs"] = int(tiling.chunk_pairs)
        entry["key_block"] = int(tiling.key_block)
        entry["level_fanouts"] = [int(f) for f in tiling.level_fanouts]
    else:
        from repro.core.engine import DEFAULT_CHUNK_PAIRS

        entry["chunk_pairs"] = int(DEFAULT_CHUNK_PAIRS)
    return entry
