"""Public MR4X API — mirrors the paper's Fig 2 user code shape.

The user supplies only a :class:`Mapper` and :class:`Reducer` (or subclasses
:class:`MapReduceApp`) and calls :meth:`MapReduce.run`.  Everything else —
combiner derivation, flow selection, lowering, distribution — is the
framework's job, "transparently to the user" (paper abstract).

Word count, for comparison with the paper's Fig 2::

    class WordCount(MapReduceApp):
        key_space = VOCAB
        value_aval = jax.ShapeDtypeStruct((), jnp.int32)

        def map(self, item, emit):          # item: [window] token ids
            emit(item, jnp.ones_like(item)) # one (word, 1) pair per token

        def reduce(self, key, values, count):
            return jnp.sum(values)

    result = MapReduce(WordCount()).run(token_windows)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import autotune as at
from repro.core import collector as col
from repro.core import engine as eng
from repro.core import combiner as C
from repro.core.optimizer import Derivation, derive_combiner
from repro.core.plan import ExecutionPlan, plan_execution


class MapReduceApp:
    """Subclass and provide map/reduce; set the class attributes.

    Attributes
    ----------
    key_space: dense key-id capacity K (keys are int32 in [0, K)).
    value_aval: ShapeDtypeStruct of one emitted value.
    pad_value: padding used for the reduce-flow value windows.
    max_values_per_key: static Lmax bound for the reduce flow.
    emit_capacity: max pairs one ``map(item, ...)`` call may emit.
    """

    key_space: int = 0
    value_aval: jax.ShapeDtypeStruct = jax.ShapeDtypeStruct((), jnp.float32)
    pad_value: Any = 0
    max_values_per_key: int = 64
    emit_capacity: int = 16

    # -- user hooks ---------------------------------------------------------
    def map(self, item, emit) -> None:
        raise NotImplementedError

    def reduce(self, key, values, count):
        raise NotImplementedError

    # optional: supply a hand-written combiner (Phoenix-style) to bypass the
    # optimizer — used in benchmarks to compare manual vs derived combiners.
    manual_combiner: C.CombinerSpec | None = None


# Functional-style construction (paper Fig 2 uses anonymous classes).
def make_app(map_fn: Callable, reduce_fn: Callable, **attrs) -> MapReduceApp:
    app = MapReduceApp()
    app.map = map_fn  # type: ignore[method-assign]
    app.reduce = reduce_fn  # type: ignore[method-assign]
    for k, v in attrs.items():
        setattr(app, k, v)
    return app


#: re-exported: the emitter type handed to user map functions.
Emitter = eng.Emitter


@dataclasses.dataclass
class MapReduceResult:
    keys: jax.Array  # [K] = arange(K)
    values: Any  # [K, ...]
    counts: jax.Array  # [K]; 0 == key never emitted
    plan: "ExecutionPlan | None" = None
    #: fault.RecoveryLog when the result came from run_resilient.
    recovery: Any = None

    def to_dict(self) -> dict:
        """Host-side {key: value} for present keys (tests / small results)."""
        import numpy as np

        counts = np.asarray(self.counts)
        vals = np.asarray(self.values)
        return {int(k): vals[k] for k in np.nonzero(counts > 0)[0]}


class MapReduce:
    """``MapReduce(app).run(items)`` — the framework entry point.

    flow:
      * "auto"    derive a combiner; when possible, run the optimizer's
                  recommended flow, else reduce (the paper's optimizer
                  behaviour).  With ``n_pairs_hint`` the recommendation
                  comes from the roofline+compute cost model
                  (``core/cost_model.py``), which ranks the stream and
                  sort flows for that workload size; without a hint the
                  streaming fused flow is kept (one-flag behaviour).
      * "stream"  force the streaming map+combine fusion (error if not
                  derivable): map chunks fold straight into holder tables,
                  the full pair buffer is never materialized
      * "sort"    force the sort-based flow (error if not derivable):
                  chunks are radix-partitioned / stably sorted by key and
                  ONE aggregate per distinct key merges into the holder
                  tables — O(N·log N + K) compute vs the one-hot fold's
                  O(N·K), the winner at large sparse key spaces.  Past one
                  bucket sweep the partition runs the multi-pass hierarchy
                  (``kernels/ops.plan_radix_levels``; the pure-JAX sort a
                  multi-pass packed digit radix), so K in the millions
                  keeps the fast path — ``explain()`` shows levels×buckets
      * "combine" force the legacy combine flow (materialize pairs, fold
                  once); kept for A/B benchmarks
      * "reduce"  force the baseline flow (paper's un-optimized MR4J)

    n_pairs_hint — expected emitted pairs per run; enables cost-model flow
    selection under ``flow="auto"`` and sharpens the autotuned tiling.

    stream_chunk_pairs bounds the emitted pairs materialized per streaming
    chunk (peak intermediate state ≈ key_space + stream_chunk_pairs).  The
    default ``"auto"`` lets the roofline-driven autotuner size it (and the
    key-block partition of the holder tables) from the analytic flow-bytes
    and VMEM working-set models; pass an int to pin it.  stream_key_block
    partitions the ``[K, D]`` holder tables for large key spaces
    ("auto" / int / None to disable blocking).  autotune_probe=True adds
    the measured micro-probe refinement on top of the model (persisted
    across runs when ``JAX_PALLAS_TUNE_CACHE`` points at a cache file).
    The decision is recorded on the plan — see :meth:`explain`.
    """

    def __init__(
        self,
        app: MapReduceApp,
        *,
        flow: str = "auto",
        trust_semantics: bool = False,
        combine_impl: str = "auto",
        use_kernels: bool = False,
        n_pairs_hint: int | None = None,
        stream_chunk_pairs: int | str = "auto",
        stream_key_block: int | str | None = "auto",
        autotune_probe: bool = False,
        donate: bool = False,
    ):
        if app.key_space <= 0:
            raise ValueError("app.key_space must be positive")
        self.app = app
        self.flow = flow
        self.combine_impl = combine_impl
        self.use_kernels = use_kernels
        self.plan = plan_execution(app, flow=flow,
                                   trust_semantics=trust_semantics,
                                   n_pairs_hint=n_pairs_hint)
        self.tiling = None
        key_block = None
        bucket_size = None
        level_fanouts = None
        if self.plan.flow == "stream":
            self.tiling = at.autotune_stream(
                app, self.plan.spec, use_kernels=use_kernels,
                chunk_pairs=stream_chunk_pairs, key_block=stream_key_block,
                n_pairs_hint=n_pairs_hint, probe=autotune_probe)
            self.plan.tiling = self.tiling
            stream_chunk_pairs = self.tiling.chunk_pairs
            key_block = (self.tiling.key_block if self.tiling.blocked
                         else None)
            if self.tiling.mode == "scatter" and self.plan.spec.mxu_lowerable:
                self.plan.diagnostics += (
                    "stream fold degraded to exact scatter (dense budgets "
                    "exceeded) — see tiling notes",)
        elif self.plan.flow == "sort":
            self.tiling = at.autotune_sort(
                app, self.plan.spec, use_kernels=use_kernels,
                chunk_pairs=stream_chunk_pairs, n_pairs_hint=n_pairs_hint)
            self.plan.tiling = self.tiling
            stream_chunk_pairs = self.tiling.chunk_pairs
            bucket_size = (self.tiling.key_block if self.tiling.blocked
                           else None)
            # the hierarchical level decomposition rides with the bucket;
            # an infeasible plan leaves bucket_size=None so the engine
            # re-checks and fires the LoweringFallbackWarning on the plan
            level_fanouts = (self.tiling.level_fanouts
                             if bucket_size is not None else None)
        elif not isinstance(stream_chunk_pairs, int):
            stream_chunk_pairs = eng.DEFAULT_CHUNK_PAIRS
        if (self.plan.flow == "combine" and self.plan.spec is not None
                and self.plan.spec.mxu_lowerable
                and app.key_space > col.ONEHOT_MAX_KEYS):
            # below the legacy key-space cutoff the one-hot path holds at
            # any pair count — nothing to flag there
            if use_kernels:
                self.plan.diagnostics += (
                    f"combine flow: key_space={app.key_space} > "
                    f"{col.ONEHOT_MAX_KEYS} exceeds the onehot_combine "
                    f"kernel's VMEM-resident table cutoff; the collector "
                    f"uses the exact scatter fallback "
                    f"(LoweringFallbackWarning at trace time) — the "
                    f"streaming flow's key-blocked fold kernel has no such "
                    f"limit",)
            else:
                self.plan.diagnostics += (
                    f"combine flow: at key_space={app.key_space} > "
                    f"{col.ONEHOT_MAX_KEYS} the one-hot lowering holds up "
                    f"to {col.ADDITIVE_FOLD_PAIRS_FUSED} pairs (the fused-"
                    f"contraction regime); beyond that the collector "
                    f"degrades to the exact scatter fallback "
                    f"(LoweringFallbackWarning at trace time) — the "
                    f"chunked stream flow has no such limit",)
        self.stream_chunk_pairs = stream_chunk_pairs
        self._run = jax.jit(partial(eng.run_local, app, self.plan,
                                    combine_impl=combine_impl,
                                    use_kernels=use_kernels,
                                    chunk_pairs=stream_chunk_pairs,
                                    key_block=key_block,
                                    bucket_size=bucket_size,
                                    level_fanouts=level_fanouts))

    def run(self, items) -> MapReduceResult:
        keys, values, counts = self._run(items)
        return MapReduceResult(keys, values, counts, plan=self.plan)

    def run_distributed(self, items, *, mesh, **kwargs) -> MapReduceResult:
        """``engine.run_distributed`` with this instance's plan/lowering
        knobs — shard_map over the mesh's data axis.  Keyword arguments
        pass through (``scatter_output``, ``shuffle_capacity``,
        ``strict_shuffle``, ...)."""
        kwargs.setdefault("combine_impl", self.combine_impl)
        kwargs.setdefault("use_kernels", self.use_kernels)
        keys, values, counts = eng.run_distributed(
            self.app, self.plan, items, mesh=mesh, **kwargs)
        return MapReduceResult(keys, values, counts, plan=self.plan)

    def run_resilient(self, items, *, mesh=None, **kwargs) -> MapReduceResult:
        """Fault-tolerant distributed run (``engine.run_resilient``):
        deterministic shard re-execution, checkpointed partial-aggregate
        recovery (``ckpt_dir=...``), straggler speculation and elastic
        remesh — the result is bitwise the fault-free
        :meth:`run_distributed` answer.  The recovery ledger lands on
        ``result.recovery`` and, summarized, on ``plan.recovery`` (shown
        by :meth:`explain`)."""
        kwargs.setdefault("combine_impl", self.combine_impl)
        kwargs.setdefault("use_kernels", self.use_kernels)
        keys, values, counts, log = eng.run_resilient(
            self.app, self.plan, items, mesh=mesh, **kwargs)
        return MapReduceResult(keys, values, counts, plan=self.plan,
                               recovery=log)

    def explain(self) -> str:
        """The optimizer's decision record: flow, derived combiner, the
        autotuned tiling and any lowering diagnostics."""
        return self.plan.explain()

    # Lowering hooks for benchmarks / dry-run analysis.
    def lower(self, items):
        return self._run.lower(items)
