"""Public MR4X API — mirrors the paper's Fig 2 user code shape.

The user supplies only a :class:`Mapper` and :class:`Reducer` (or subclasses
:class:`MapReduceApp`) and calls :meth:`MapReduce.run`.  Everything else —
combiner derivation, flow selection, lowering, distribution — is the
framework's job, "transparently to the user" (paper abstract).

Word count, for comparison with the paper's Fig 2::

    class WordCount(MapReduceApp):
        key_space = VOCAB
        value_aval = jax.ShapeDtypeStruct((), jnp.int32)

        def map(self, item, emit):          # item: [window] token ids
            emit(item, jnp.ones_like(item)) # one (word, 1) pair per token

        def reduce(self, key, values, count):
            return jnp.sum(values)

    result = MapReduce(WordCount()).run(token_windows)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import collector as col
from repro.core import engine as eng
from repro.core import combiner as C
from repro.core.optimizer import Derivation, derive_combiner
from repro.core.plan import ExecutionPlan, plan_execution


class MapReduceApp:
    """Subclass and provide map/reduce; set the class attributes.

    Attributes
    ----------
    key_space: dense key-id capacity K (keys are int32 in [0, K)).
    value_aval: ShapeDtypeStruct of one emitted value.
    pad_value: padding used for the reduce-flow value windows.
    max_values_per_key: static Lmax bound for the reduce flow.
    emit_capacity: max pairs one ``map(item, ...)`` call may emit.
    """

    key_space: int = 0
    value_aval: jax.ShapeDtypeStruct = jax.ShapeDtypeStruct((), jnp.float32)
    pad_value: Any = 0
    max_values_per_key: int = 64
    emit_capacity: int = 16

    # -- user hooks ---------------------------------------------------------
    def map(self, item, emit) -> None:
        raise NotImplementedError

    def reduce(self, key, values, count):
        raise NotImplementedError

    # optional: supply a hand-written combiner (Phoenix-style) to bypass the
    # optimizer — used in benchmarks to compare manual vs derived combiners.
    manual_combiner: C.CombinerSpec | None = None


# Functional-style construction (paper Fig 2 uses anonymous classes).
def make_app(map_fn: Callable, reduce_fn: Callable, **attrs) -> MapReduceApp:
    app = MapReduceApp()
    app.map = map_fn  # type: ignore[method-assign]
    app.reduce = reduce_fn  # type: ignore[method-assign]
    for k, v in attrs.items():
        setattr(app, k, v)
    return app


#: re-exported: the emitter type handed to user map functions.
Emitter = eng.Emitter


@dataclasses.dataclass
class MapReduceResult:
    keys: jax.Array  # [K] = arange(K)
    values: Any  # [K, ...]
    counts: jax.Array  # [K]; 0 == key never emitted
    plan: "ExecutionPlan | None" = None

    def to_dict(self) -> dict:
        """Host-side {key: value} for present keys (tests / small results)."""
        import numpy as np

        counts = np.asarray(self.counts)
        vals = np.asarray(self.values)
        return {int(k): vals[k] for k in np.nonzero(counts > 0)[0]}


class MapReduce:
    """``MapReduce(app).run(items)`` — the framework entry point.

    flow:
      * "auto"    derive a combiner; when possible, run the optimizer's
                  recommended flow (the streaming fused flow), else reduce
                  (exactly the paper's optimizer behaviour)
      * "stream"  force the streaming map+combine fusion (error if not
                  derivable): map chunks fold straight into holder tables,
                  the full pair buffer is never materialized
      * "combine" force the legacy combine flow (materialize pairs, fold
                  once); kept for A/B benchmarks
      * "reduce"  force the baseline flow (paper's un-optimized MR4J)

    stream_chunk_pairs bounds the emitted pairs materialized per streaming
    chunk (peak intermediate state ≈ key_space + stream_chunk_pairs).
    """

    def __init__(
        self,
        app: MapReduceApp,
        *,
        flow: str = "auto",
        trust_semantics: bool = False,
        combine_impl: str = "auto",
        use_kernels: bool = False,
        stream_chunk_pairs: int = eng.DEFAULT_CHUNK_PAIRS,
        donate: bool = False,
    ):
        if app.key_space <= 0:
            raise ValueError("app.key_space must be positive")
        self.app = app
        self.flow = flow
        self.combine_impl = combine_impl
        self.use_kernels = use_kernels
        self.stream_chunk_pairs = stream_chunk_pairs
        self.plan = plan_execution(app, flow=flow,
                                   trust_semantics=trust_semantics)
        self._run = jax.jit(partial(eng.run_local, app, self.plan,
                                    combine_impl=combine_impl,
                                    use_kernels=use_kernels,
                                    chunk_pairs=stream_chunk_pairs))

    def run(self, items) -> MapReduceResult:
        keys, values, counts = self._run(items)
        return MapReduceResult(keys, values, counts, plan=self.plan)

    # Lowering hooks for benchmarks / dry-run analysis.
    def lower(self, items):
        return self._run.lower(items)
