"""Public MR4X API — mirrors the paper's Fig 2 user code shape.

The user supplies only a :class:`Mapper` and :class:`Reducer` (or subclasses
:class:`MapReduceApp`) and calls :meth:`MapReduce.run`.  Everything else —
combiner derivation, flow selection, lowering, distribution — is the
framework's job, "transparently to the user" (paper abstract).

Word count, for comparison with the paper's Fig 2::

    class WordCount(MapReduceApp):
        key_space = VOCAB
        value_aval = jax.ShapeDtypeStruct((), jnp.int32)

        def map(self, item, emit):          # item: [window] token ids
            emit(item, jnp.ones_like(item)) # one (word, 1) pair per token

        def reduce(self, key, values, count):
            return jnp.sum(values)

    result = MapReduce(WordCount()).run(token_windows)

Staged compilation (the JaCe/JAX-AOT stage architecture)::

    mr = MapReduce(WordCount())           # plan stage (cached by content)
    lowered = mr.lower(items)             # bind an item spec
    optimized = lowered.optimize()        # bind execution options
    compiled = optimized.compile()        # AOT compile (cached by content)
    result = compiled(items)              # dispatch only — zero re-traces

``run()``/``run_distributed()``/``run_resilient()`` are thin wrappers over
this path; every stage answers :meth:`explain`.  Execution-time knobs
travel in one :class:`ExecutionOptions` record accepted by all three run
methods — the pre-``ExecutionOptions`` scattered kwargs (deprecated with a
forwarding shim for one release) are now a ``TypeError``.

Long-lived serving: :meth:`MapReduce.serve` stages the same plan into a
:class:`repro.streaming.MapReduceService` — micro-batches fold
incrementally into persistent holder tables (mode="streaming"), with
windowed aggregation, live snapshots and checkpointed warm restarts.

Every entry point — ``run*``, ``Compiled.__call__`` and
``service.snapshot()`` — returns the same :class:`MapReduceResult`.
"""

from __future__ import annotations

import dataclasses
import warnings as _warnings
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune as at
from repro.core import collector as col
from repro.core import engine as eng
from repro.core import combiner as C
from repro.core import plan_cache as pc
from repro.core import skew as sk
from repro.core.skew import ShuffleOptions
from repro.core.optimizer import Derivation, derive_combiner
from repro.core.plan import ExecutionPlan, plan_execution


class MapReduceApp:
    """Subclass and provide map/reduce; set the class attributes.

    Attributes
    ----------
    key_space: dense key-id capacity K (keys are int32 in [0, K)).
    value_aval: ShapeDtypeStruct of one emitted value.
    pad_value: padding used for the reduce-flow value windows.
    max_values_per_key: static Lmax bound for the reduce flow.
    emit_capacity: max pairs one ``map(item, ...)`` call may emit.
    """

    key_space: int = 0
    value_aval: jax.ShapeDtypeStruct = jax.ShapeDtypeStruct((), jnp.float32)
    pad_value: Any = 0
    max_values_per_key: int = 64
    emit_capacity: int = 16

    # -- user hooks ---------------------------------------------------------
    def map(self, item, emit) -> None:
        raise NotImplementedError

    def reduce(self, key, values, count):
        raise NotImplementedError

    # optional: supply a hand-written combiner (Phoenix-style) to bypass the
    # optimizer — used in benchmarks to compare manual vs derived combiners.
    manual_combiner: C.CombinerSpec | None = None


# Functional-style construction (paper Fig 2 uses anonymous classes).
def make_app(map_fn: Callable, reduce_fn: Callable, **attrs) -> MapReduceApp:
    app = MapReduceApp()
    app.map = map_fn  # type: ignore[method-assign]
    app.reduce = reduce_fn  # type: ignore[method-assign]
    for k, v in attrs.items():
        setattr(app, k, v)
    return app


#: re-exported: the emitter type handed to user map functions.
Emitter = eng.Emitter


# ---------------------------------------------------------------------------
# ExecutionOptions: the one execution-time kwarg surface
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ExecutionOptions:
    """Execution-time knobs for ``run``/``run_distributed``/``run_resilient``.

    One record replaces the three methods' formerly scattered kwargs;
    fields irrelevant to a given method are simply ignored by it.  The
    ``None`` defaults on the lowering overrides mean "inherit the
    MapReduce constructor's choice".

    Distribution: ``mesh`` + ``data_axis`` select the shard_map data axis;
    ``scatter_output`` key-shards stream/combine results; ``shuffle``
    (a :class:`repro.core.skew.ShuffleOptions`) is the unified all-to-all
    surface — capacity/strict envelope plus the skew-adaptive planner
    (sampled histograms, balanced range boundaries, hot-key splitting).
    The flat ``shuffle_capacity``/``strict_shuffle`` fields are its
    deprecated spelling: non-default values forward into a
    ``ShuffleOptions`` with a ``DeprecationWarning`` (one release), and
    whenever ``shuffle`` is set it is authoritative — the flat fields are
    overwritten to mirror it.  Resilience (``run_resilient``): ``num_hosts`` /
    ``num_shards`` / ``ckpt_dir`` / ``step`` / ``inject`` / ``timeout_s``
    / ``straggler_lag``, plus the durable control plane ``coord`` /
    ``retry`` / ``chaos``.  Serving: ``items_bucket="pow2"`` pads the batch
    axis to the next power of two so nearby batch sizes share one compiled
    executable (pad rows are masked out; local runs only);
    ``cache=False`` bypasses the content-keyed plan/executable cache.
    """

    # distribution
    mesh: Any = None
    data_axis: str = "data"
    scatter_output: bool = False
    shuffle_capacity: int | None = None
    strict_shuffle: bool = False
    #: the unified shuffle surface (skew.ShuffleOptions); None + default
    #: flat fields keeps the bitwise-legacy fixed-width shuffle.
    shuffle: sk.ShuffleOptions | None = None
    # resilience
    num_hosts: int | None = None
    num_shards: int | None = None
    ckpt_dir: str | None = None
    step: int = 0
    inject: Any = None
    timeout_s: float = 60.0
    straggler_lag: int = 1
    #: durable control plane (coordination.CoordinationStore | KVStore |
    #: path); defaults to <ckpt_dir>/coord when chaos/retry ask for one.
    coord: Any = None
    #: coordination.RetryPolicy bounding store/restore ops (deterministic
    #: capped backoff; every retry lands on plan.recovery).
    retry: Any = None
    #: chaos.ChaosPlan multi-fault drill script.
    chaos: Any = None
    # lowering overrides (None -> the MapReduce constructor's choice)
    combine_impl: str | None = None
    use_kernels: bool | None = None
    chunk_pairs: int | None = None
    key_block: int | None = None
    bucket_size: int | None = None
    level_fanouts: tuple[int, ...] | None = None
    # serving
    items_bucket: str = "exact"
    cache: bool = True

    def __post_init__(self):
        sh = self.shuffle
        if sh is None:
            if self.shuffle_capacity is not None or self.strict_shuffle:
                _warnings.warn(
                    "ExecutionOptions(shuffle_capacity=..., "
                    "strict_shuffle=...) are deprecated; pass "
                    "shuffle=ShuffleOptions(capacity=..., strict=...) "
                    "instead", DeprecationWarning, stacklevel=3)
                object.__setattr__(self, "shuffle", sk.ShuffleOptions(
                    capacity=self.shuffle_capacity,
                    strict=self.strict_shuffle))
            return
        if not isinstance(sh, sk.ShuffleOptions):
            raise TypeError(
                f"ExecutionOptions.shuffle must be a skew.ShuffleOptions, "
                f"got {type(sh).__name__}")
        # the record is authoritative: mirror onto the flat fields so both
        # read surfaces agree and dataclasses.replace round-trips silently
        object.__setattr__(self, "shuffle_capacity", sh.capacity)
        object.__setattr__(self, "strict_shuffle", sh.strict)


_OPTION_FIELDS = {f.name for f in dataclasses.fields(ExecutionOptions)}


def _resolve_options(options: ExecutionOptions | None, legacy: dict,
                     *, method: str, mesh=None) -> ExecutionOptions:
    """Reject the retired scattered kwargs; resolve the options record.

    ``mesh`` stays a first-class argument on the distributed entry points.
    The pre-``ExecutionOptions`` scattered kwargs went through one release
    of ``DeprecationWarning``-and-forward; the forwarding is now removed
    and both known-but-retired and unknown kwargs raise ``TypeError`` —
    the former with a pointer at the replacement field."""
    opts = options if options is not None else ExecutionOptions()
    if legacy:
        retired = sorted(set(legacy) & _OPTION_FIELDS)
        if retired:
            raise TypeError(
                f"{method}({', '.join(retired)}=...) scattered keyword "
                f"arguments were removed; pass "
                f"options=ExecutionOptions({retired[0]}=...) instead")
        raise TypeError(f"{method}() got unexpected keyword arguments "
                        f"{sorted(legacy)}")
    if mesh is not None:
        opts = dataclasses.replace(opts, mesh=mesh)
    return opts


@dataclasses.dataclass
class MapReduceResult:
    """The one result record of every execution surface.

    ``run()``, ``run_distributed()``, ``run_resilient()``,
    ``Compiled.__call__`` and ``MapReduceService.snapshot()`` all return
    this; the entry points differ only in which optional fields are
    populated (``recovery`` from resilient runs, ``batch_id`` from
    service snapshots)."""

    keys: jax.Array  # [K] = arange(K)
    values: Any  # [K, ...]
    counts: jax.Array  # [K]; 0 == key never emitted
    plan: "ExecutionPlan | None" = None
    #: fault.RecoveryLog when the result came from run_resilient.
    recovery: Any = None
    #: id of the last micro-batch folded in, when the result is a
    #: MapReduceService snapshot (None for batch runs).
    batch_id: int | None = None

    @property
    def diagnostics(self) -> tuple[str, ...]:
        """The plan's optimizer/lowering diagnostics (empty without a
        plan) — one accessor across all entry points."""
        return self.plan.diagnostics if self.plan is not None else ()

    def __iter__(self):
        """Bare-tuple unpacking shim: ``keys, values, counts = result``
        still works but is deprecated — use the named fields."""
        _warnings.warn(
            "unpacking MapReduceResult as a bare (keys, values, counts) "
            "tuple is deprecated; use the named fields "
            "(.keys/.values/.counts)", DeprecationWarning, stacklevel=2)
        return iter((self.keys, self.values, self.counts))

    def to_dict(self) -> dict:
        """Host-side {key: value} for present keys (tests / small results)."""
        import numpy as np

        counts = np.asarray(self.counts)
        vals = np.asarray(self.values)
        return {int(k): vals[k] for k in np.nonzero(counts > 0)[0]}


class MapReduce:
    """``MapReduce(app).run(items)`` — the framework entry point.

    flow:
      * "auto"    derive a combiner; when possible, run the optimizer's
                  recommended flow, else reduce (the paper's optimizer
                  behaviour).  With ``n_pairs_hint`` the recommendation
                  comes from the roofline+compute cost model
                  (``core/cost_model.py``), which ranks the stream and
                  sort flows for that workload size; without a hint the
                  streaming fused flow is kept (one-flag behaviour).
      * "stream"  force the streaming map+combine fusion (error if not
                  derivable): map chunks fold straight into holder tables,
                  the full pair buffer is never materialized
      * "sort"    force the sort-based flow (error if not derivable):
                  chunks are radix-partitioned / stably sorted by key and
                  ONE aggregate per distinct key merges into the holder
                  tables — O(N·log N + K) compute vs the one-hot fold's
                  O(N·K), the winner at large sparse key spaces.  Past one
                  bucket sweep the partition runs the multi-pass hierarchy
                  (``kernels/ops.plan_radix_levels``; the pure-JAX sort a
                  multi-pass packed digit radix), so K in the millions
                  keeps the fast path — ``explain()`` shows levels×buckets
      * "combine" force the legacy combine flow (materialize pairs, fold
                  once); kept for A/B benchmarks
      * "reduce"  force the baseline flow (paper's un-optimized MR4J)

    n_pairs_hint — expected emitted pairs per run; enables cost-model flow
    selection under ``flow="auto"`` and sharpens the autotuned tiling.

    stream_chunk_pairs bounds the emitted pairs materialized per streaming
    chunk (peak intermediate state ≈ key_space + stream_chunk_pairs).  The
    default ``"auto"`` lets the roofline-driven autotuner size it (and the
    key-block partition of the holder tables) from the analytic flow-bytes
    and VMEM working-set models; pass an int to pin it.  stream_key_block
    partitions the ``[K, D]`` holder tables for large key spaces
    ("auto" / int / None to disable blocking).  autotune_probe=True adds
    the measured micro-probe refinement on top of the model (persisted
    across runs when ``JAX_PALLAS_TUNE_CACHE`` points at a cache file).
    The decision is recorded on the plan — see :meth:`explain`.

    Construction is the **plan stage** of the staged pipeline and is
    content-cached (``core/plan_cache.py``): a second MapReduce over an
    app with identical reduce jaxpr, shapes and knobs reuses the first's
    derivation, flow choice and tiling without re-running the optimizer
    (``cache=False`` opts out).  ``lower()`` → ``optimize()`` →
    ``compile()`` continue the stages; ``run*`` wrap them.

    ``streaming=True`` plans for continuous ingestion: the flow is pinned
    to "stream" and a combiner must be derivable (an unbounded stream
    cannot be buffered for the reduce flow); :meth:`serve` then stages
    the plan into a long-lived ``MapReduceService``.
    """

    def __init__(
        self,
        app: MapReduceApp,
        *,
        flow: str = "auto",
        trust_semantics: bool = False,
        combine_impl: str = "auto",
        use_kernels: bool = False,
        n_pairs_hint: int | None = None,
        stream_chunk_pairs: int | str = "auto",
        stream_key_block: int | str | None = "auto",
        autotune_probe: bool = False,
        donate: bool = False,
        cache: bool = True,
        streaming: bool = False,
    ):
        if app.key_space <= 0:
            raise ValueError("app.key_space must be positive")
        self.app = app
        self.flow = flow
        self.combine_impl = combine_impl
        self.use_kernels = use_kernels
        self.cache = cache
        self.streaming = streaming
        self._plan_key = pc.plan_key(
            app, flow=flow, trust_semantics=trust_semantics,
            n_pairs_hint=n_pairs_hint, use_kernels=use_kernels,
            combine_impl=combine_impl, chunk_pairs=stream_chunk_pairs,
            key_block=stream_key_block, autotune_probe=autotune_probe,
            streaming=streaming)

        entry = pc.plan_get(self._plan_key) if cache else None
        if entry is not None:
            # full in-memory hit: reuse the derivation (live combiner
            # closures), flow choice and tiling — zero optimizer traces,
            # zero autotune calls.  Fresh plan INSTANCE per MapReduce so
            # run-time diagnostics never pollute the cached template.
            self.plan = dataclasses.replace(
                entry.plan, recovery=(), stage="planned",
                cache_key=self._plan_key, cache_event="hit")
            self.tiling = entry.tiling
            self.stream_chunk_pairs = entry.stream_chunk_pairs
            self._key_block = entry.key_block
            self._bucket_size = entry.bucket_size
            self._level_fanouts = entry.level_fanouts
            return

        cache_event = "miss" if cache else ""
        fentry = pc.file_get(self._plan_key) if cache else None
        if (fentry is not None and not isinstance(stream_chunk_pairs, int)
                and fentry["flow"] in ("stream", "sort")):
            # cross-process advisory hit: pin the persisted tiling decision
            # so the (potentially measured) autotune probes are skipped;
            # derivation and compilation still run — closures and
            # executables don't serialize.
            stream_chunk_pairs = int(fentry["chunk_pairs"])
            if fentry.get("key_block") is not None \
                    and not isinstance(stream_key_block, int):
                stream_key_block = int(fentry["key_block"])
            cache_event = "file-hit"

        self.plan = plan_execution(app, flow=flow,
                                   trust_semantics=trust_semantics,
                                   n_pairs_hint=n_pairs_hint,
                                   streaming=streaming)
        self.tiling = None
        key_block = None
        bucket_size = None
        level_fanouts = None
        if self.plan.flow == "stream":
            self.tiling = at.autotune_stream(
                app, self.plan.spec, use_kernels=use_kernels,
                chunk_pairs=stream_chunk_pairs, key_block=stream_key_block,
                n_pairs_hint=n_pairs_hint, probe=autotune_probe)
            self.plan.tiling = self.tiling
            stream_chunk_pairs = self.tiling.chunk_pairs
            key_block = (self.tiling.key_block if self.tiling.blocked
                         else None)
            if self.tiling.mode == "scatter" and self.plan.spec.mxu_lowerable:
                self.plan.diagnostics += (
                    "stream fold degraded to exact scatter (dense budgets "
                    "exceeded) — see tiling notes",)
        elif self.plan.flow == "sort":
            self.tiling = at.autotune_sort(
                app, self.plan.spec, use_kernels=use_kernels,
                chunk_pairs=stream_chunk_pairs, n_pairs_hint=n_pairs_hint)
            self.plan.tiling = self.tiling
            stream_chunk_pairs = self.tiling.chunk_pairs
            bucket_size = (self.tiling.key_block if self.tiling.blocked
                           else None)
            # the hierarchical level decomposition rides with the bucket;
            # an infeasible plan leaves bucket_size=None so the engine
            # re-checks and fires the LoweringFallbackWarning on the plan
            level_fanouts = (self.tiling.level_fanouts
                             if bucket_size is not None else None)
        elif not isinstance(stream_chunk_pairs, int):
            stream_chunk_pairs = eng.DEFAULT_CHUNK_PAIRS
        if (self.plan.flow == "combine" and self.plan.spec is not None
                and self.plan.spec.mxu_lowerable
                and app.key_space > col.ONEHOT_MAX_KEYS):
            # below the legacy key-space cutoff the one-hot path holds at
            # any pair count — nothing to flag there
            if use_kernels:
                self.plan.diagnostics += (
                    f"combine flow: key_space={app.key_space} > "
                    f"{col.ONEHOT_MAX_KEYS} exceeds the onehot_combine "
                    f"kernel's VMEM-resident table cutoff; the collector "
                    f"uses the exact scatter fallback "
                    f"(LoweringFallbackWarning at trace time) — the "
                    f"streaming flow's key-blocked fold kernel has no such "
                    f"limit",)
            else:
                self.plan.diagnostics += (
                    f"combine flow: at key_space={app.key_space} > "
                    f"{col.ONEHOT_MAX_KEYS} the one-hot lowering holds up "
                    f"to {col.ADDITIVE_FOLD_PAIRS_FUSED} pairs (the fused-"
                    f"contraction regime); beyond that the collector "
                    f"degrades to the exact scatter fallback "
                    f"(LoweringFallbackWarning at trace time) — the "
                    f"chunked stream flow has no such limit",)
        self.stream_chunk_pairs = stream_chunk_pairs
        self._key_block = key_block
        self._bucket_size = bucket_size
        self._level_fanouts = level_fanouts
        self.plan.stage = "planned"
        self.plan.cache_key = self._plan_key
        self.plan.cache_event = cache_event
        if cache:
            # snapshot NOW: the template must not see diagnostics a later
            # run of this instance appends
            pc.plan_put(self._plan_key, pc.PlanEntry(
                plan=dataclasses.replace(self.plan),
                tiling=self.tiling,
                stream_chunk_pairs=stream_chunk_pairs,
                key_block=key_block, bucket_size=bucket_size,
                level_fanouts=level_fanouts))
            pc.file_put(self._plan_key,
                        pc.file_entry_from(self.plan, self.tiling))

    # -- lowering knob resolution ------------------------------------------

    def _knobs(self, opts: ExecutionOptions) -> dict:
        """Engine kwargs for this plan under ``opts`` overrides."""
        return dict(
            combine_impl=(self.combine_impl if opts.combine_impl is None
                          else opts.combine_impl),
            use_kernels=(self.use_kernels if opts.use_kernels is None
                         else opts.use_kernels),
            chunk_pairs=(self.stream_chunk_pairs if opts.chunk_pairs is None
                         else opts.chunk_pairs),
            key_block=(self._key_block if opts.key_block is None
                       else opts.key_block),
            bucket_size=(self._bucket_size if opts.bucket_size is None
                         else opts.bucket_size),
            level_fanouts=(self._level_fanouts if opts.level_fanouts is None
                           else opts.level_fanouts),
        )

    # -- staged execution surface ------------------------------------------

    def lower(self, items, *, options: ExecutionOptions | None = None,
              mode: str | None = None) -> "Lowered":
        """Stage 1: bind this plan to an item spec (concrete arrays or a
        ShapeDtypeStruct pytree).  ``mode`` defaults to "local", or
        "distributed" when ``options.mesh`` is set.

        With ``options.shuffle.skew="auto"`` and concrete items, this is
        where the skew planner samples the emitted key histogram and bakes
        balanced boundaries / hot-key splits into the frozen
        ``ShuffleOptions`` (spec-only lowering skips the probe and keeps
        the fixed-width ranges)."""
        opts = options if options is not None else ExecutionOptions()
        rmode = _infer_mode(opts, mode)
        if rmode in ("distributed", "resilient"):
            opts = self._resolve_shuffle(opts, items, rmode)
        return Lowered(self, pc.items_spec_of(items), opts, mode=rmode)

    def _resolve_shuffle(self, opts: ExecutionOptions, items,
                         mode: str) -> ExecutionOptions:
        """Lower()-time skew resolution: sample/recall the key histogram
        and return options with the decision baked into ``opts.shuffle``;
        provenance lands on ``plan.skew`` (shown by ``explain()``).  A
        non-raw wire codec additionally lands its modeled
        encoded-vs-raw bytes on ``plan.wire``."""
        sh = opts.shuffle
        if sh is not None and sh.wire != "raw":
            self.plan.wire = self._wire_provenance(opts, items, mode)
        if sh is None or (sh.skew != "auto" and sh.boundaries is None):
            return opts
        leaves = jax.tree.leaves(items)
        if any(isinstance(l, jax.ShapeDtypeStruct) for l in leaves):
            return opts  # spec-only lowering: nothing to sample
        S = _shard_count(opts, mode)
        if S is None or S <= 1:
            return opts
        resolved, profile = sk.resolve_shuffle_options(
            self.app, self.plan, items, num_shards=S, options=sh)
        lines: list[str] = []
        if profile is not None:
            lines.extend(profile.describe())
        splan = sk.plan_from_options(
            self.app.key_space, S, resolved, flow=self.plan.flow,
            spec=self.plan.spec, value_aval=self.app.value_aval)
        if splan is not None:
            lines.extend(splan.describe())
        elif profile is not None and resolved.boundaries is None:
            lines.append(
                f"plan: fixed-width ranges kept (imbalance at/under the "
                f"{sk.SNAP_IMBALANCE}x snap threshold)")
        if lines:
            self.plan.skew = tuple(lines)
        if resolved is sh:
            return opts
        return dataclasses.replace(opts, shuffle=resolved)

    def _wire_provenance(self, opts: ExecutionOptions, items,
                         mode: str) -> tuple[str, ...]:
        """``explain()`` lines for a non-raw shuffle wire codec: which
        codec the all-to-all (and the resilient driver's checkpointed
        partials) ride under, plus the modeled encoded-vs-raw bytes when
        the item count is known at lower() time."""
        from repro.roofline import analysis as roofline

        sh = opts.shuffle
        lines = [f"codec {sh.wire} on the all-to-all + checkpointed "
                 f"partials (distributed/wire.py)"]
        S = _shard_count(opts, mode)
        leaves = jax.tree.leaves(items)
        if (S and S > 1 and leaves
                and not any(isinstance(l, jax.ShapeDtypeStruct)
                            for l in leaves)):
            n_pairs = int(leaves[0].shape[0]) * self.app.emit_capacity
            value_bytes = int(
                jnp.dtype(self.app.value_aval.dtype).itemsize
                * max(1, int(np.prod(self.app.value_aval.shape))))
            kw = dict(n_pairs=n_pairs, key_space=self.app.key_space,
                      num_shards=S, value_bytes=value_bytes,
                      value_dtype=str(self.app.value_aval.dtype),
                      capacity=sh.capacity)
            enc_b = roofline.shuffle_wire_bytes(sh.wire, **kw)
            raw_b = roofline.shuffle_wire_bytes("raw", **kw)
            if raw_b > 0:
                lines.append(
                    f"modeled wire bytes/shard: {enc_b / 1e3:.1f}kB "
                    f"({enc_b / raw_b:.2f}x raw {raw_b / 1e3:.1f}kB) "
                    f"at S={S}")
        return tuple(lines)

    def run(self, items, *, options: ExecutionOptions | None = None,
            **legacy) -> MapReduceResult:
        opts = _resolve_options(options, legacy, method="run")
        return self.lower(items, options=opts, mode="local"
                          ).optimize().compile()(items)

    def run_distributed(self, items, *, mesh=None,
                        options: ExecutionOptions | None = None,
                        **legacy) -> MapReduceResult:
        """Distributed run — shard_map over the mesh's data axis.

        ``options`` carries ``scatter_output``, ``shuffle_capacity``,
        ``strict_shuffle``, ...; the mesh may come as the ``mesh=``
        argument or on the options."""
        opts = _resolve_options(options, legacy, method="run_distributed",
                                mesh=mesh)
        if opts.mesh is None:
            raise TypeError("run_distributed requires a mesh (pass mesh=... "
                            "or options=ExecutionOptions(mesh=...))")
        return self.lower(items, options=opts, mode="distributed"
                          ).optimize().compile()(items)

    def run_resilient(self, items, *, mesh=None,
                      options: ExecutionOptions | None = None,
                      **legacy) -> MapReduceResult:
        """Fault-tolerant distributed run (``engine.run_resilient``):
        deterministic shard re-execution, checkpointed partial-aggregate
        recovery (``ckpt_dir=...``), straggler speculation and elastic
        remesh — the result is bitwise the fault-free
        :meth:`run_distributed` answer.  The recovery ledger lands on
        ``result.recovery`` and, summarized, on ``plan.recovery`` (shown
        by :meth:`explain`)."""
        opts = _resolve_options(options, legacy, method="run_resilient",
                                mesh=mesh)
        return self.lower(items, options=opts, mode="resilient"
                          ).optimize().compile()(items)

    def serve(self, *, batch_capacity: int, window=None,
              options: ExecutionOptions | None = None,
              item_spec=None,
              ckpt_dir: str | None = None, ckpt_every: int = 0,
              keep_ckpts: int = 3, retry_policy=None):
        """Stage this plan into a long-lived
        :class:`repro.streaming.MapReduceService`.

        The staged path runs once (``lower().optimize().compile()`` at
        mode="streaming"); every subsequent ``service.ingest(items)`` is a
        plain dispatch of the AOT ingest executable — no re-trace, no
        re-tune, no re-compile.  Micro-batches of up to ``batch_capacity``
        items fold incrementally into persistent holder tables;
        ``window`` (a :class:`repro.streaming.Window`) bounds aggregation
        to the trailing micro-batches; ``ckpt_dir``/``ckpt_every`` enable
        periodic atomic table checkpoints for warm restarts
        (:meth:`MapReduceService.restore`).

        ``item_spec`` (a ShapeDtypeStruct pytree of ONE item) compiles the
        ingest executable eagerly — required before ``restore()`` on a
        fresh service; omitted, staging happens at the first ingest.
        """
        from repro.streaming import MapReduceService

        return MapReduceService(
            self, batch_capacity=batch_capacity, window=window,
            options=options, item_spec=item_spec, ckpt_dir=ckpt_dir,
            ckpt_every=ckpt_every, keep_ckpts=keep_ckpts,
            retry_policy=retry_policy)

    def explain(self) -> str:
        """The optimizer's decision record: flow, derived combiner, the
        autotuned tiling and any lowering diagnostics."""
        return self.plan.explain()


# ---------------------------------------------------------------------------
# The explicit stages: Lowered -> Optimized -> Compiled
# ---------------------------------------------------------------------------


def _shard_count(opts: ExecutionOptions, mode: str) -> int | None:
    """Shard count a run in ``mode`` will see — mirrors
    ``engine.run_resilient``'s host/shard resolution so the skew plan is
    derived for the exact all-to-all it will route.  None when the mesh
    is not known yet (distributed mode without a mesh)."""
    mesh_hosts = (int(opts.mesh.shape[opts.data_axis])
                  if opts.mesh is not None else None)
    if mode == "distributed":
        return mesh_hosts
    H = opts.num_hosts if opts.num_hosts is not None else (mesh_hosts or 1)
    return int(opts.num_shards if opts.num_shards is not None
               else (mesh_hosts or H))


def _infer_mode(opts: ExecutionOptions, mode: str | None) -> str:
    if mode is not None:
        if mode not in ("local", "distributed", "resilient", "streaming"):
            raise ValueError(f"unknown execution mode {mode!r}")
        return mode
    return "local" if opts.mesh is None else "distributed"


class Lowered:
    """Stage 1 of the staged path: plan × item spec.

    ``optimize(...)`` binds/overrides execution options; ``compile()`` is
    the shortcut ``optimize().compile()`` (kept so the long-standing
    ``mr.lower(items).compile()`` introspection idiom works unchanged)."""

    def __init__(self, mr: MapReduce, items_spec, options: ExecutionOptions,
                 *, mode: str | None = None):
        self.mr = mr
        self.items_spec = items_spec
        self.options = options
        self.mode = _infer_mode(options, mode)

    def optimize(self, options: ExecutionOptions | None = None,
                 **hints) -> "Optimized":
        """Stage 2: fix the execution options.  ``hints`` are individual
        ExecutionOptions field overrides (e.g. ``items_bucket="pow2"``)."""
        opts = options if options is not None else self.options
        if hints:
            unknown = sorted(set(hints) - _OPTION_FIELDS)
            if unknown:
                raise TypeError(f"optimize() got unknown hints {unknown}")
            opts = dataclasses.replace(opts, **hints)
        return Optimized(self.mr, self.items_spec, opts, mode=self.mode)

    def compile(self) -> "Compiled":
        return self.optimize().compile()

    def explain(self) -> str:
        plan = dataclasses.replace(self.mr.plan, stage="lowered")
        return (plan.explain()
                + f"\nitems: {pc._spec_sig(self.items_spec)}")


class Optimized:
    """Stage 2: plan × item spec × execution options (mode resolved)."""

    def __init__(self, mr: MapReduce, items_spec, options: ExecutionOptions,
                 *, mode: str):
        self.mr = mr
        self.items_spec = items_spec
        self.options = options
        self.mode = mode
        n = jax.tree.leaves(items_spec)[0].shape[0]
        self.n_items = int(n)
        if options.items_bucket != "exact" and mode != "local":
            # pow2 batch bucketing needs the local flows' n_valid masking;
            # the shard_map'd paths keep jit's exact-shape contract.
            self.n_bucket = self.n_items
        else:
            self.n_bucket = pc.bucket_items(self.n_items,
                                            options.items_bucket)
        self.cache_key = self._cache_key()

    def _cache_key(self) -> str | None:
        if self.mode == "resilient":
            return None  # host driver: rebuilt per call, nothing compiled
        opts = self.options
        knobs = self.mr._knobs(opts)
        spec = self.items_spec
        padded = self.n_bucket != self.n_items
        if padded:
            # pow2 bucketing: the executable is traced at the padded shape,
            # so every N in the bucket must map to the same key
            spec = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(
                    (self.n_bucket,) + tuple(a.shape[1:]), a.dtype), spec)
        return pc.compiled_key(
            self.mr.app, spec, plan_key=self.mr._plan_key,
            flow=self.mr.plan.flow, n_bucket=self.n_bucket, mesh=opts.mesh,
            data_axis=opts.data_axis, mode=self.mode,
            # `padded` distinguishes the (items, n_valid) calling convention
            # from the exact (items,) one at the same traced shape — e.g. a
            # pow2 batch of 5 padded to 8 vs an exact-fit batch of 8
            # repr(opts.shuffle) digests the FULL resolved shuffle record —
            # capacity/strict plus the skew planner's boundaries and hot
            # splits — so warm repeats re-derive nothing and two plans with
            # different boundary layouts never share an executable
            extra=(f"padded={padded}", f"bucket={opts.items_bucket}",
                   opts.scatter_output, opts.shuffle_capacity,
                   repr(opts.shuffle),
                   knobs["combine_impl"], knobs["use_kernels"],
                   knobs["chunk_pairs"], knobs["key_block"],
                   knobs["bucket_size"], knobs["level_fanouts"]))

    def compile(self) -> "Compiled":
        """Stage 3: produce the executable.  Content-cached — a warm hit
        returns the stored executable with zero traces, zero autotune
        calls and zero XLA compiles."""
        use_cache = self.options.cache and self.cache_key is not None
        if use_cache:
            ent = pc.compiled_get(self.cache_key)
            if ent is not None:
                return Compiled(self, ent, cache_event="hit")
        ent = self._build()
        if use_cache:
            pc.compiled_put(self.cache_key, ent)
        return Compiled(self, ent,
                        cache_event="miss" if use_cache else "")

    def _build(self) -> pc.CompiledEntry:
        mr, opts = self.mr, self.options
        knobs = mr._knobs(opts)
        plan = mr.plan
        if self.mode == "local":
            pc.STATS.compiles += 1
            if self.n_bucket == self.n_items:
                fn = jax.jit(partial(eng.run_local, mr.app, plan, **knobs))
                executable = fn.lower(self.items_spec).compile()
            else:
                padded = jax.tree.map(
                    lambda a: jax.ShapeDtypeStruct(
                        (self.n_bucket,) + tuple(a.shape[1:]), a.dtype),
                    self.items_spec)
                fn = jax.jit(lambda items, n_valid: eng.run_local(
                    mr.app, plan, items, n_valid=n_valid, **knobs))
                executable = fn.lower(
                    padded, jax.ShapeDtypeStruct((), jnp.int32)).compile()
            return pc.CompiledEntry(executable=executable, plan=plan,
                                    tiling=mr.tiling, n_bucket=self.n_bucket,
                                    mode="local")
        if self.mode == "streaming":
            if plan.flow != "stream":
                raise ValueError(
                    f"streaming mode requires the stream flow (plan chose "
                    f"{plan.flow!r}); construct MapReduce(app, "
                    f"streaming=True) or flow='stream'")
            pc.STATS.compiles += 1
            sc, ingest = eng.build_stream_ingest(
                mr.app, plan.spec, batch_items=self.n_bucket,
                chunk_pairs=knobs["chunk_pairs"],
                use_kernels=knobs["use_kernels"],
                key_block=knobs["key_block"],
                on_fallback=eng._plan_fallback_cb(plan))
            state_spec = jax.eval_shape(sc.init_state)
            # AOT: (state, padded items, n_valid) -> state.  One executable
            # serves every micro-batch size in [0, batch_capacity] — the
            # pad rows are masked to the sentinel key, contributing exact
            # zero to the fold.
            executable = jax.jit(ingest).lower(
                state_spec, self.items_spec,
                jax.ShapeDtypeStruct((), jnp.int32)).compile()
            return pc.CompiledEntry(executable=executable, plan=plan,
                                    tiling=mr.tiling, n_bucket=self.n_bucket,
                                    mode="streaming", aux=sc)
        if self.mode == "distributed":
            pc.STATS.compiles += 1
            S = opts.mesh.shape[opts.data_axis]
            chunk_pairs, key_block = eng._distributed_tiling(
                mr.app, plan, self.items_spec, S,
                use_kernels=knobs["use_kernels"],
                chunk_pairs=opts.chunk_pairs, key_block=opts.key_block)
            jitted, post = eng.build_distributed_fn(
                mr.app, plan, mesh=opts.mesh, data_axis=opts.data_axis,
                combine_impl=knobs["combine_impl"],
                use_kernels=knobs["use_kernels"],
                scatter_output=opts.scatter_output,
                shuffle_capacity=opts.shuffle_capacity,
                chunk_pairs=chunk_pairs, key_block=key_block,
                bucket_size=knobs["bucket_size"],
                level_fanouts=knobs["level_fanouts"],
                shuffle_plan=sk.plan_from_options(
                    mr.app.key_space, S, opts.shuffle, flow=plan.flow,
                    spec=plan.spec, value_aval=mr.app.value_aval),
                wire=(opts.shuffle.wire if opts.shuffle is not None
                      else "raw"))
            # the persistent jitted shard_map IS the executable: repeat
            # calls hit jit's trace cache instead of rebuilding the
            # shard_map per call like the old run_distributed did
            return pc.CompiledEntry(executable=jitted, plan=plan,
                                    tiling=mr.tiling, n_bucket=self.n_bucket,
                                    mode="distributed", aux=post)

        S_res = _shard_count(opts, "resilient")
        res_plan = sk.plan_from_options(
            mr.app.key_space, S_res, opts.shuffle, flow=plan.flow,
            spec=plan.spec, value_aval=mr.app.value_aval)
        # resilient mode is never plan-cached (the drive closure is a host
        # driver, not an executable), so the jitted phase functions cache
        # on the MapReduce instance — repeat run_resilient() calls pay
        # dispatch, not re-trace/re-compile, of phases A and B
        jits = mr.__dict__.setdefault("_resilient_jits", {})

        def drive(items):  # resilient host driver — not XLA-compilable
            return eng.run_resilient(
                mr.app, plan, items, mesh=opts.mesh,
                num_hosts=opts.num_hosts, num_shards=opts.num_shards,
                data_axis=opts.data_axis, step=opts.step,
                ckpt_dir=opts.ckpt_dir, inject=opts.inject,
                timeout_s=opts.timeout_s, straggler_lag=opts.straggler_lag,
                combine_impl=knobs["combine_impl"],
                use_kernels=knobs["use_kernels"],
                shuffle_capacity=opts.shuffle_capacity,
                chunk_pairs=opts.chunk_pairs, key_block=opts.key_block,
                bucket_size=opts.bucket_size,
                level_fanouts=opts.level_fanouts,
                strict_shuffle=opts.strict_shuffle,
                shuffle_plan=res_plan,
                wire=(opts.shuffle.wire if opts.shuffle is not None
                      else "raw"),
                coord=opts.coord, retry=opts.retry, chaos=opts.chaos,
                jit_cache=jits)

        return pc.CompiledEntry(executable=drive, plan=plan,
                                tiling=mr.tiling, n_bucket=self.n_bucket,
                                mode="resilient")

    def explain(self) -> str:
        plan = dataclasses.replace(self.mr.plan, stage="optimized")
        lines = [plan.explain(),
                 f"mode: {self.mode}",
                 f"items: {pc._spec_sig(self.items_spec)} "
                 f"(N={self.n_items} bucket={self.n_bucket} "
                 f"policy={self.options.items_bucket})"]
        if self.cache_key is not None:
            lines.append(f"compiled-cache key: {self.cache_key}")
        return "\n".join(lines)


class Compiled:
    """Stage 3: the executable.  ``compiled(items)`` dispatches (AOT for
    local runs; a persistent jitted shard_map for distributed); the XLA
    introspection surface (``as_text``/``memory_analysis``/
    ``cost_analysis``) passes through on local executables."""

    def __init__(self, opt: Optimized, entry: pc.CompiledEntry,
                 *, cache_event: str):
        self.options = opt.options
        self.mode = entry.mode
        self.items_spec = opt.items_spec
        self.n_items = opt.n_items
        self.n_bucket = entry.n_bucket
        self.cache_key = opt.cache_key
        self.cache_event = cache_event
        self._entry = entry
        # a fresh copy of the plan the executable was traced with: run-time
        # diagnostics (shuffle overflow, lowering fallbacks) land here
        # without polluting other Compiled objects sharing the cache entry
        self.plan = dataclasses.replace(entry.plan, stage="compiled")

    def __call__(self, items) -> MapReduceResult:
        if self.mode == "streaming":
            raise TypeError(
                "a streaming-mode Compiled is an incremental ingest "
                "executable, not a batch job — drive it through "
                "MapReduceService (MapReduce.serve(...)) or via "
                "init_state()/ingest_state()")
        if self.mode == "local":
            items = jax.tree.map(jnp.asarray, items)
            if self.n_bucket != self.n_items:
                pad = self.n_bucket - self.n_items
                items = jax.tree.map(
                    lambda a: jnp.concatenate(
                        [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)]),
                    items)
                keys, values, counts = self._entry.executable(
                    items, jnp.int32(self.n_items))
            else:
                keys, values, counts = self._entry.executable(items)
            return MapReduceResult(keys, values, counts, plan=self.plan)
        if self.mode == "distributed":
            out = self._entry.executable(items)
            keys, values, counts = self._entry.aux(
                out, strict_shuffle=self.options.strict_shuffle)
            return MapReduceResult(keys, values, counts, plan=self.plan)
        keys, values, counts, log = self._entry.executable(items)
        return MapReduceResult(keys, values, counts, plan=self.plan,
                               recovery=log)

    # -- streaming-mode surface (driven by repro.streaming.MapReduceService)

    def init_state(self):
        """Fresh carried combiner state (streaming mode)."""
        return self._entry.aux.init_state()

    def ingest_state(self, state, items, n_valid):
        """Fold one padded micro-batch into ``state`` (streaming mode).

        Pure AOT dispatch: ``items`` must already be padded to the lowered
        ``batch_capacity`` shape; ``n_valid`` masks the tail."""
        return self._entry.executable(state, items, jnp.int32(n_valid))

    def state_tables(self, state):
        """Un-finalized ``(tables, counts)`` view of a carried state."""
        return self._entry.aux.tables_counts(state)

    def finalize_state(self, state):
        """Finalized ``Grouped(keys, values, counts)`` of a carried state."""
        return self._entry.aux.finalize(state)

    # -- XLA introspection pass-through (local AOT executables) -------------

    def as_text(self) -> str:
        return self._entry.executable.as_text()

    def memory_analysis(self):
        return self._entry.executable.memory_analysis()

    def cost_analysis(self):
        return self._entry.executable.cost_analysis()

    def explain(self) -> str:
        lines = [self.plan.explain(), f"mode: {self.mode}"]
        if self.cache_key is not None:
            lines.append(f"compiled-cache: {self.cache_event or 'off'} "
                         f"key={self.cache_key}")
        if self.n_bucket != self.n_items:
            lines.append(f"items: padded N={self.n_items} -> "
                         f"bucket={self.n_bucket} (pad rows masked)")
        return "\n".join(lines)
